"""End-to-end driver: the paper's scaling experiment on re-synthesized
workloads (patents / orkut / webgraph analogues), distributed over every
local device with the paper's privatized-histogram reduction.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/census_scaling.py
"""

import time

import jax
import numpy as np

from repro.core import (
    PAPER_WORKLOADS, build_plan, census_batagelj_mrvar, census_dict,
    default_mesh, paper_workload, triad_census_distributed)

SIZES = {"patents": (30_000, 3.0), "orkut": (5_000, 40.0),
         "webgraph": (15_000, 15.0)}


def main():
    mesh = default_mesh()
    ndev = len(jax.devices())
    print(f"devices: {ndev}  (mesh {mesh.axis_names})\n")

    for name, meta in PAPER_WORKLOADS.items():
        n, deg = SIZES[name]
        g = paper_workload(name, n=n, avg_degree=deg, seed=0)
        plan = build_plan(g, pad_to=ndev)
        st = plan.balance_stats(ndev)
        t0 = time.perf_counter()
        census = triad_census_distributed(plan, mesh=mesh)
        dt = time.perf_counter() - t0
        # serial reference (the paper's Fig-5 algorithm) on a reduced
        # same-family graph (the python oracle is O(items) in slow loops)
        g_small = paper_workload(name, n=min(g.n, 1500),
                                 avg_degree=min(deg, 8.0), seed=0)
        t1 = time.perf_counter()
        ref = census_batagelj_mrvar(g_small)
        dt_ref = time.perf_counter() - t1
        assert (triad_census_distributed(
            build_plan(g_small, pad_to=ndev), mesh=mesh) == ref).all()
        d = census_dict(census)
        print(f"== {name}  (outdeg exponent target "
              f"{meta['exponent']})")
        print(f"   n={g.n} arcs={g.num_arcs} work_items={plan.num_items}")
        print(f"   distributed census: {dt:.3f}s "
              f"({plan.num_items / dt:.3g} items/s, incl. compile on "
              f"first call); serial B&M oracle (reduced graph): "
              f"{dt_ref:.3f}s, equal ✓")
        print(f"   balance (max/mean work): flat plan "
              f"{st['flat_max_over_mean']:.4f} vs naive pair split "
              f"{st['pair_max_over_mean']:.2f}")
        print(f"   top connected triads: "
              + ", ".join(f"{k}={v}" for k, v in
                          sorted(d.items(), key=lambda kv: -kv[1])[1:5]))
        for shards in (64, 256, 512):
            p = build_plan(g, pad_to=shards)
            s = p.balance_stats(shards)
            print(f"   modeled speedup @{shards} shards: "
                  f"{shards / s['flat_max_over_mean']:.1f}x")
        print()


if __name__ == "__main__":
    main()
