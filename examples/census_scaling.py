"""End-to-end driver: the paper's scaling experiment on re-synthesized
workloads (patents / orkut / webgraph analogues), distributed over every
local device with the paper's privatized-histogram reduction — followed by
the out-of-core streaming demo: a workload whose monolithic flat plan
exceeds the (stand-in) host plan-memory budget by >8x, completed by the
chunked CensusEngine under that budget.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/census_scaling.py
"""

import time

import jax
import numpy as np

from repro.core import (
    CensusEngine, PAPER_WORKLOADS, build_plan, census_batagelj_mrvar,
    census_dict, default_mesh, pair_space, paper_workload,
    triad_census_distributed)
from repro.analysis.report import streaming_section

SIZES = {"patents": (30_000, 3.0), "orkut": (5_000, 40.0),
         "webgraph": (15_000, 15.0)}

#: stand-in for the host plan-memory ceiling: on a real billion-edge run
#: this is the RAM that the monolithic O(W) item arrays would blow past;
#: here it is sized so the demo workload's full plan exceeds it >= 8x
PLAN_BUDGET_BYTES = 12 << 20

#: workload for the streaming demo — its monolithic packed-item plan is
#: ~130 MB, > 8x PLAN_BUDGET_BYTES: it "does not fit" under the budget
#: and only completes in streaming mode
STREAM_SIZE = ("webgraph", 6_000, 10.0)


def streaming_demo(mesh):
    name, n, deg = STREAM_SIZE
    g = paper_workload(name, n=n, avg_degree=deg, seed=0)
    w_pre = pair_space(g).num_items_preprune
    mono_bytes = 8 * w_pre
    max_items = PLAN_BUDGET_BYTES // 8     # 8 packed bytes per item
    print(f"== streaming  ({name} n={n} avg_deg={deg})")
    print(f"   monolithic plan: ~{mono_bytes / 1e6:.0f} MB of packed "
          f"items — {mono_bytes / PLAN_BUDGET_BYTES:.1f}x over the "
          f"{PLAN_BUDGET_BYTES / 1e6:.0f} MB plan budget; "
          "streaming instead")
    engine = CensusEngine(mesh=mesh, backend="jnp")
    t0 = time.perf_counter()
    census = engine.run(g, max_items=max_items,
                        progress=lambda k, total, items: print(
                            f"   chunk {k + 1}/{total}: {items} items",
                            end="\r"))
    dt = time.perf_counter() - t0
    st = engine.stats
    print(f"\n   streamed census: {dt:.3f}s, {st.chunks} chunks, "
          f"peak plan bytes {st.peak_plan_bytes / 1e6:.1f} MB "
          f"(vs {st.monolithic_plan_bytes / 1e6:.0f} MB monolithic), "
          f"step compiles: {st.step_compiles}")
    # parity on a reduced same-family graph (oracle is slow python)
    g_small = paper_workload(name, n=1200, avg_degree=8.0, seed=0)
    eng2 = CensusEngine(mesh=mesh, backend="jnp")
    assert (eng2.run(g_small, max_items=max(max_items // 64, 1)) ==
            census_batagelj_mrvar(g_small)).all()
    print("   reduced-graph streamed census == serial B&M oracle ✓")
    d = census_dict(census)
    print("   top connected triads: "
          + ", ".join(f"{k}={v}" for k, v in
                      sorted(d.items(), key=lambda kv: -kv[1])[1:5]))
    print()
    print(streaming_section(st))


def main():
    mesh = default_mesh()
    ndev = len(jax.devices())
    print(f"devices: {ndev}  (mesh {mesh.axis_names})\n")

    for name, meta in PAPER_WORKLOADS.items():
        n, deg = SIZES[name]
        g = paper_workload(name, n=n, avg_degree=deg, seed=0)
        plan = build_plan(g, pad_to=ndev)
        st = plan.balance_stats(ndev)
        t0 = time.perf_counter()
        census = triad_census_distributed(plan, mesh=mesh)
        dt = time.perf_counter() - t0
        # serial reference (the paper's Fig-5 algorithm) on a reduced
        # same-family graph (the python oracle is O(items) in slow loops)
        g_small = paper_workload(name, n=min(g.n, 1500),
                                 avg_degree=min(deg, 8.0), seed=0)
        t1 = time.perf_counter()
        ref = census_batagelj_mrvar(g_small)
        dt_ref = time.perf_counter() - t1
        assert (triad_census_distributed(
            build_plan(g_small, pad_to=ndev), mesh=mesh) == ref).all()
        d = census_dict(census)
        print(f"== {name}  (outdeg exponent target "
              f"{meta['exponent']})")
        print(f"   n={g.n} arcs={g.num_arcs} work_items={plan.num_items}")
        print(f"   distributed census: {dt:.3f}s "
              f"({plan.num_items / dt:.3g} items/s, incl. compile on "
              f"first call); serial B&M oracle (reduced graph): "
              f"{dt_ref:.3f}s, equal ✓")
        print(f"   balance (max/mean work): flat plan "
              f"{st['flat_max_over_mean']:.4f} vs naive pair split "
              f"{st['pair_max_over_mean']:.2f}")
        print(f"   top connected triads: "
              + ", ".join(f"{k}={v}" for k, v in
                          sorted(d.items(), key=lambda kv: -kv[1])[1:5]))
        for shards in (64, 256, 512):
            p = build_plan(g, pad_to=shards)
            s = p.balance_stats(shards)
            print(f"   modeled speedup @{shards} shards: "
                  f"{shards / s['flat_max_over_mean']:.1f}x")
        print()

    streaming_demo(mesh)


if __name__ == "__main__":
    main()
