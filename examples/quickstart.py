"""Quickstart: exact triad census of a scale-free digraph.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import (
    build_plan, census_bruteforce, census_dict, from_edges,
    scale_free_digraph, triad_census)


def main():
    # a small scale-free graph (orkut-like mutual density)
    g = scale_free_digraph(n=2_000, avg_degree=8, exponent=2.1,
                           mutual_p=0.5, seed=42)
    plan = build_plan(g)
    print(f"graph: n={g.n} arcs={g.num_arcs} pairs={plan.num_pairs} "
          f"work_items={plan.num_items} max_deg={plan.max_degree}")

    census = triad_census(plan)
    print("\n16-type triad census (Holland–Leinhardt order):")
    for name, count in census_dict(census).items():
        print(f"  {name:>5}: {count}")
    total = g.n * (g.n - 1) * (g.n - 2) // 6
    assert census.sum() == total
    print(f"\nsum == C(n,3) == {total} ✓")

    # validate on a small brute-forceable subgraph
    sub = scale_free_digraph(n=60, avg_degree=6, exponent=2.1,
                             mutual_p=0.5, seed=7)
    from repro.core import to_dense
    assert (triad_census(build_plan(sub)) ==
            census_bruteforce(to_dense(sub))).all()
    print("matches O(n^3) brute force on a 60-node graph ✓")


if __name__ == "__main__":
    main()
