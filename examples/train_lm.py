"""End-to-end training driver: a small GQA LM trained for a few hundred
steps on CPU with the full production stack — sharded train step, AdamW,
deterministic data pipeline, async checkpointing, fault coordinator
(with an injected failure to demonstrate recovery).

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/train_lm.py --steps 200
"""

import argparse
import dataclasses
import tempfile
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.configs.base import ShapeSpec
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.models.model import count_params, make_params
from repro.train.checkpoint import CheckpointManager
from repro.train.fault import Coordinator, StragglerDetector
from repro.train.optimizer import OptConfig, init_state
from repro.train.train_loop import build_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--inject-failure", action="store_true", default=True)
    args = ap.parse_args()

    # a genuinely trainable-on-CPU config of the selected family
    cfg = dataclasses.replace(
        get_config(args.arch).reduced(),
        num_layers=4, d_model=256, d_ff=1024, vocab_size=2048)
    n = len(jax.devices())
    mesh = jax.make_mesh((n, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    shape = ShapeSpec("cpu_demo", "train", args.seq, args.batch)
    opt_cfg = OptConfig(lr=1e-3, warmup_steps=20, total_steps=args.steps,
                        weight_decay=0.01)
    step_fn, shardings, _ = build_train_step(
        cfg, mesh, shape, opt_cfg, q_chunk=args.seq, remat=False)
    jstep = jax.jit(step_fn, donate_argnums=(0, 1))

    params = make_params(cfg, seed=0)
    opt = init_state(params)
    print(f"arch family {args.arch}: {count_params(cfg)/1e6:.1f}M params, "
          f"{n} devices, batch {args.batch}x{args.seq}")

    pipe = TokenPipeline(DataConfig(vocab_size=cfg.vocab_size,
                                    batch=args.batch, seq_len=args.seq,
                                    zipf_a=1.2, seed=0))
    ckdir = tempfile.mkdtemp(prefix="repro_ckpt_")
    mgr = CheckpointManager(ckdir, keep=2)

    state = {"params": params, "opt": opt, "step": np.int64(0)}
    injected = {"done": not args.inject_failure}

    def wrapped_step(st, batch):
        if not injected["done"] and int(st["step"]) == args.steps // 2:
            injected["done"] = True
            raise RuntimeError("injected node failure (demo)")
        p, o, metrics = jstep(st["params"], st["opt"], batch)
        return ({"params": p, "opt": o, "step": st["step"] + 1}, metrics)

    losses = []

    def batch_fn(s):
        return {k: jax.numpy.asarray(v) for k, v in
                pipe.batch_at(s).items()}

    coord = Coordinator(wrapped_step, batch_fn, mgr, ckpt_every=50,
                        straggler=StragglerDetector())
    t0 = time.time()
    state, last, hist = coord.run(state, 0, args.steps)
    dt = time.time() - t0

    for h in hist:
        losses.append(h.get("loss", float("nan")))
    first = np.nanmean(losses[:10])
    final = np.nanmean(losses[-10:])
    toks = args.steps * args.batch * args.seq
    print(f"\ntrained {last} steps in {dt:.1f}s "
          f"({toks / dt:.0f} tok/s incl. compile)")
    print(f"loss: first-10 avg {first:.3f} -> last-10 avg {final:.3f}")
    print(f"recoveries: {len(coord.restarts)} "
          f"{[r['error'] for r in coord.restarts]}")
    print(f"checkpoints kept: {mgr.all_steps()} under {ckdir}")
    assert final < first, "loss should decrease"
    print("loss decreased ✓")


if __name__ == "__main__":
    main()
