"""Serving demo: batched prefill + decode generation with KV-cache
management (ring buffers for local-attention layers).

    PYTHONPATH=src python examples/serve_lm.py --arch recurrentgemma-2b
"""

import argparse
import time

import numpy as np

from repro.configs import get_config
from repro.models.model import count_params, make_params
from repro.serve.engine import ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=24)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    params = make_params(cfg, seed=0)
    eng = ServeEngine(cfg, params, max_seq_len=128, q_chunk=16)
    print(f"{args.arch} (reduced, {count_params(cfg)/1e6:.1f}M): "
          f"batch={args.batch} prompt={args.prompt_len} "
          f"new={args.new_tokens}")

    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size,
                           (args.batch, args.prompt_len)).astype(np.int32)
    src = None
    if cfg.is_encdec:
        src = rng.normal(size=(args.batch, args.prompt_len,
                               cfg.d_model)).astype(np.float32)

    t0 = time.time()
    out = eng.generate(prompts, max_new_tokens=args.new_tokens,
                       temperature=0.8, seed=1, src_embeds=src)
    dt = time.time() - t0
    new = out[:, args.prompt_len:]
    print(f"generated {new.size} tokens in {dt:.1f}s "
          f"(incl. compile): {new.size / dt:.1f} tok/s")
    for i, row in enumerate(new[:2]):
        print(f"  seq{i}: {row.tolist()}")
    assert out.shape == (args.batch, args.prompt_len + args.new_tokens)
    print("shapes ✓")


if __name__ == "__main__":
    main()
