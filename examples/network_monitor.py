"""The paper's application (Figs 3–4): triadic monitoring of computer
network traffic with anomaly alarms.

Synthesizes background peer-to-peer traffic, injects a port-scanning burst
(one source fanning out — 021D triads) in later windows, and shows the
monitor flagging exactly those windows.

The monitor runs every window through one resident engine session
(graph arrays uploaded per window, chunk step compiled once for the whole
stream); with ``--stride`` below the window size, consecutive windows
overlap and are delta-updated incrementally — only the pairs whose rows
the arc churn touched are recounted, bit-identically to a full recompute.
(On this zipf workload every window churns arcs of the hub hosts, so the
affected pairs cover most of the graph and the per-window summary shows
little item reduction; the ``temporal_*`` benchmark rows use a
backbone-plus-ephemeral-flows stream where the same machinery cuts
items 3-9x.)

    PYTHONPATH=src python examples/network_monitor.py
    PYTHONPATH=src python examples/network_monitor.py \
        --backend pallas-fused --stride 600 --verbose
    PYTHONPATH=src python examples/network_monitor.py --mesh 4 --stride 600
    PYTHONPATH=src python examples/network_monitor.py --inject-faults 0
"""

import argparse
import os
import sys

import numpy as np

#: kept in sync with repro.core.census.BACKENDS (imported lazily in main
#: so --mesh can force virtual devices before the first jax import)
BACKENDS = ("jnp", "pallas", "pallas-fused")


def background_traffic(rng, n_hosts, n_edges):
    # zipf-ish client/server mix with ~30% reciprocity, exactly n_edges
    # (the reciprocated arcs ride inside the budget so the mutual-dyad
    # mix — which keeps the 021D baseline low — is preserved)
    k = int(n_edges / 1.25)
    src = (rng.zipf(1.5, k) - 1) % n_hosts
    dst = rng.integers(0, n_hosts, k)
    back = rng.random(k) < 0.3
    src2 = np.concatenate([src, dst[back]])
    dst2 = np.concatenate([dst, src[back]])
    short = n_edges - src2.size
    if short > 0:
        src2 = np.concatenate([src2, (rng.zipf(1.5, short) - 1) % n_hosts])
        dst2 = np.concatenate([dst2, rng.integers(0, n_hosts, short)])
    return src2[:n_edges], dst2[:n_edges]


def scan_burst(rng, n_hosts, n_targets):
    scanner = int(rng.integers(0, n_hosts))
    targets = rng.choice(n_hosts, size=n_targets, replace=False)
    return np.full(n_targets, scanner), targets


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--backend", choices=BACKENDS, default="jnp",
                    help="census backend for every window (default jnp)")
    ap.add_argument("--stride", type=int, default=None,
                    help="edges between windows (default: the window "
                         "size, i.e. tumbling; smaller values slide "
                         "incrementally)")
    ap.add_argument("--window", type=int, default=1200,
                    help="edges per census window")
    ap.add_argument("--windows", type=int, default=30,
                    help="logical traffic windows to synthesize")
    ap.add_argument("--no-incremental", action="store_true",
                    help="full per-window recompute even when sliding")
    ap.add_argument("--threshold", type=float, default=3.5,
                    help="z-score alarm threshold (sliding windows "
                         "dilute a burst across the overlap, so their "
                         "peak z is lower than tumbling)")
    ap.add_argument("--emit", choices=("device", "host"), default=None,
                    help="work-item emission mode (default: the engine "
                         "default, device — stream O(pairs) descriptors "
                         "and expand pairs→items in-kernel)")
    ap.add_argument("--mesh", type=int, default=None, metavar="N",
                    help="build an N-device mesh and PARTITION each "
                         "window's graph across it (each device holds "
                         "only its pair shard's local subgraph; delta "
                         "updates dispatch only the owning shards); "
                         "prints the per-window shard report")
    ap.add_argument("--inject-faults", type=int, default=None,
                    metavar="SEED",
                    help="adversarial mode: deterministically inject "
                         "transient dispatch failures, a poisoned "
                         "result, and one burst long enough to exhaust "
                         "the retry budget — the monitor must survive, "
                         "retrying what it can and logging the rest as "
                         "degraded windows instead of dying")
    ap.add_argument("--index", dest="index", action="store_true",
                    default=True,
                    help="maintain a persistent pair-space index so "
                         "each slide edits the plan by the delta "
                         "(default)")
    ap.add_argument("--no-index", dest="index", action="store_false",
                    help="rebuild the pair space from scratch every "
                         "window — the parity oracle for --index")
    ap.add_argument("--profile-host", action="store_true",
                    help="print the per-window host planning time split "
                         "(pair-space / delta-merge / item-emission "
                         "buckets) next to the device dispatch numbers")
    ap.add_argument("--verbose", action="store_true",
                    help="print the per-window engine summary lines")
    args = ap.parse_args()

    if args.mesh is not None and args.mesh >= 1 \
            and "jax" not in sys.modules:
        # force enough virtual host devices BEFORE the first jax import
        flags = os.environ.get("XLA_FLAGS", "")
        if "xla_force_host_platform_device_count" not in flags:
            os.environ["XLA_FLAGS"] = (
                flags + f" --xla_force_host_platform_device_count="
                f"{args.mesh}").strip()
    from repro.core import (
        Fault, FaultPlan, SECURITY_PATTERNS, TriadMonitor, default_mesh)

    mesh = default_mesh(args.mesh) if args.mesh is not None else None
    rng = np.random.default_rng(0)
    n_hosts, per_window = 400, args.window
    # overlapping windows arrive window/stride times as often, so scale
    # the trailing-history length to cover the same span of traffic
    stride = args.stride if args.stride is not None else per_window
    history = 10 * max(1, per_window // stride)
    faults = None
    if args.inject_faults is not None:
        frng = np.random.default_rng(args.inject_faults)
        ndev = args.mesh if args.mesh is not None else 1
        dev = int(frng.integers(ndev))
        # occurrences count DISPATCHES, not windows: each window's
        # census is ~20-50 chunk dispatches on the defaults, and a
        # failure in the very first window has no previous census to
        # carry forward, so aim the burst well past it
        burst = int(frng.integers(60, 200))
        faults = FaultPlan(seed=args.inject_faults, faults=[
            # a 3-deep consecutive burst outlasts the default retry
            # budget (2) -> exactly one degraded window
            *(Fault("dispatch", "error", device=dev, occurrence=burst + i)
              for i in range(3)),
            # a lone transient error and a poisoned result: both
            # retried/re-dispatched invisibly
            Fault("dispatch", "error", device=dev,
                  occurrence=int(frng.integers(250, 400))),
            Fault("dispatch", "poison", device=dev,
                  occurrence=int(frng.integers(450, 600))),
        ])
    monitor = TriadMonitor(
        n_hosts, window=per_window, stride=stride, history=history,
        threshold=args.threshold, backend=args.backend,
        incremental=not args.no_incremental,
        max_items=4096, emit=args.emit, index=args.index,
        mesh=mesh, partition=mesh is not None, faults=faults)

    scan_size = 200
    attack_windows = {25, 26, 27}
    attack_spans = []
    for w in range(args.windows):
        src, dst = background_traffic(
            rng, n_hosts,
            per_window - (scan_size if w in attack_windows else 0))
        if w in attack_windows:
            s2, d2 = scan_burst(rng, n_hosts, scan_size)
            src, dst = np.concatenate([src, s2]), np.concatenate([dst, d2])
            attack_spans.append((w * per_window, (w + 1) * per_window))
        monitor.observe(src, dst)

    alarms = monitor.alarms()
    stride = monitor.stride
    print(f"monitored {len(monitor.window_stats)} windows of "
          f"{per_window} flows (stride {stride}) over {n_hosts} hosts "
          f"on backend={args.backend}; injected scans in logical windows "
          f"{sorted(attack_windows)}\n")
    print("patterns:", {k: v for k, v in SECURITY_PATTERNS.items()})

    # per-window engine summary: items dispatched vs a full recompute,
    # affected pairs for incremental slides, any alarms on that window
    alarms_at = {}
    for a in alarms:
        alarms_at.setdefault(a["window"], []).append(a)
    total_items = total_full = 0
    print("\nper-window engine summary "
          "(items dispatched / full-recompute items):")
    for t, st in enumerate(monitor.window_stats):
        if st is None:      # degraded window: census carried forward
            print(f"  window {t:>3}  DEGRADED (census carried forward; "
                  f"next window recomputes in full)")
            continue
        total_items += st.items
        total_full += st.full_items
        fired = ",".join(f"{a['pattern']}(z={a['zscore']:.1f})"
                         for a in alarms_at.get(t, []))
        shard = ""
        if st.partitioned:
            # per-window shard report: dispatched items per shard, their
            # imbalance, and the per-device resident graph bytes vs what
            # replication would hold
            shard = (f" shards={st.shard_items}"
                     f" mom={st.shard_max_over_mean:.2f}"
                     f" gbytes={st.graph_resident_bytes}"
                     f"/{st.graph_replicated_bytes}")
        host = ""
        if args.profile_host:
            host = (f" host={st.plan_host_seconds * 1e3:.2f}ms"
                    f"[pair={st.host_pair_seconds * 1e3:.2f}"
                    f" merge={st.host_merge_seconds * 1e3:.2f}"
                    f" emit={st.host_emit_seconds * 1e3:.2f}]"
                    f"{'' if st.indexed else ' (no index)'}")
        line = (f"  window {t:>3}  items={st.items:>7}/{st.full_items:<7}"
                f" chunks={st.chunks:<2} affected_pairs="
                f"{st.affected_pairs:<5}{shard}{host} "
                f"{('ALARM ' + fired) if fired else ''}")
        if args.verbose or fired or args.profile_host:
            print(line)
    print(f"\ntotals: {total_items} items dispatched vs {total_full} for "
          f"full per-window recomputes "
          f"({total_full / max(total_items, 1):.2f}x reduction); "
          f"chunk step compiles: "
          f"{sum(s.step_compiles for s in monitor.window_stats if s)}")
    if args.profile_host:
        live = [s for s in monitor.window_stats if s is not None]
        pair = sum(s.host_pair_seconds for s in live)
        merge = sum(s.host_merge_seconds for s in live)
        emit = sum(s.host_emit_seconds for s in live)
        mode = "indexed" if args.index else "full per-window rebuild"
        print(f"host planning totals ({mode}): "
              f"{(pair + merge + emit) * 1e3:.1f}ms = "
              f"pair-space {pair * 1e3:.1f}ms + delta-merge "
              f"{merge * 1e3:.1f}ms + emission {emit * 1e3:.1f}ms "
              f"over {len(live)} windows")
    if args.inject_faults is not None:
        sess = monitor._session
        print(f"\nfault injection (seed {args.inject_faults}): "
              f"{sess.retries if sess else 0} retried dispatches, "
              f"{len(monitor.degraded)} degraded window(s) — the stream "
              f"survived")
        for d in monitor.degraded:
            print(f"  degraded window {d['window']}: {d['error']}")
    if mesh is not None and monitor.window_stats:
        last = next(s for s in reversed(monitor.window_stats)
                    if s is not None)
        moms = [s.shard_max_over_mean for s in monitor.window_stats
                if s is not None and s.partitioned and s.items]
        print(f"\nshard report ({args.mesh}-device mesh, partitioned "
              f"graph): per-device resident graph bytes "
              f"{last.graph_resident_bytes} vs replicated "
              f"{last.graph_replicated_bytes} "
              f"({last.graph_replicated_bytes / max(last.graph_resident_bytes, 1):.2f}x);"
              f" dispatch max/mean over windows: "
              f"mean {np.mean(moms) if moms else 1.0:.2f} "
              f"max {np.max(moms) if moms else 1.0:.2f}")

    # map flagged stream windows back onto the injected attack spans
    flagged = {a["window"] for a in alarms}
    hit_spans = set()
    for t in flagged:
        lo = t * stride
        for k, (alo, ahi) in enumerate(attack_spans):
            if lo < ahi and alo < lo + per_window:
                hit_spans.add(k)
    print(f"\ndetected {len(hit_spans)}/{len(attack_spans)} attack bursts"
          f"{' ✓' if hit_spans else ''}; alarm windows: {sorted(flagged)}")


if __name__ == "__main__":
    main()
