"""The paper's application (Figs 3–4): triadic monitoring of computer
network traffic with anomaly alarms.

Synthesizes background peer-to-peer traffic, injects a port-scanning burst
(one source fanning out — 021D triads) in later windows, and shows the
monitor flagging exactly those windows.

    PYTHONPATH=src python examples/network_monitor.py
"""

import numpy as np

from repro.core import SECURITY_PATTERNS, TriadMonitor


def background_traffic(rng, n_hosts, n_edges):
    # zipf-ish client/server mix with some reciprocity
    src = (rng.zipf(1.5, n_edges) - 1) % n_hosts
    dst = rng.integers(0, n_hosts, n_edges)
    back = rng.random(n_edges) < 0.3
    return (np.concatenate([src, dst[back]]),
            np.concatenate([dst, src[back]]))


def scan_burst(rng, n_hosts, n_targets):
    scanner = int(rng.integers(0, n_hosts))
    targets = rng.choice(n_hosts, size=n_targets, replace=False)
    return np.full(n_targets, scanner), targets


def main():
    rng = np.random.default_rng(0)
    n_hosts, per_window = 400, 1200
    monitor = TriadMonitor(n_nodes=n_hosts, history=10, threshold=4.0)

    attack_windows = {25, 26, 27}
    for w in range(30):
        src, dst = background_traffic(rng, n_hosts, per_window)
        if w in attack_windows:
            s2, d2 = scan_burst(rng, n_hosts, 150)
            src, dst = np.concatenate([src, s2]), np.concatenate([dst, d2])
        monitor.observe(src, dst)

    alarms = monitor.alarms()
    print(f"monitored {30} windows of {per_window} flows over "
          f"{n_hosts} hosts; injected scans in windows "
          f"{sorted(attack_windows)}\n")
    print("patterns:", {k: v for k, v in SECURITY_PATTERNS.items()})
    print("\nalarms:")
    for a in alarms:
        print(f"  window {a['window']:>2}  pattern={a['pattern']:<10} "
              f"z={a['zscore']:.1f}")
    flagged = {a["window"] for a in alarms}
    hits = flagged & attack_windows
    print(f"\ndetected {len(hits)}/{len(attack_windows)} attack windows"
          f"{' ✓' if hits else ''}; "
          f"false alarms: {sorted(flagged - attack_windows)}")


if __name__ == "__main__":
    main()
