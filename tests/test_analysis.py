"""HLO collective parser (trip-count correction) and roofline math."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.analysis.hlo import (
    collective_summary, parse_computations, shape_bytes)
from repro.analysis.roofline import (
    analyze_record, analytic_hbm_bytes, model_flops)


class TestShapeBytes:
    def test_simple(self):
        assert shape_bytes("bf16[16,4096]") == 16 * 4096 * 2
        assert shape_bytes("f32[8]") == 32
        assert shape_bytes("pred[4,4]") == 16

    def test_multiple_and_unknown(self):
        s = "tuple(f32[2,2], s32[3]) nonsense[9] u8[10]"
        assert shape_bytes(s) == 16 + 12 + 10


class TestCollectiveParser:
    def _hlo_for(self, fn, args, mesh, in_specs):
        sh = tuple(NamedSharding(mesh, s) for s in in_specs)
        return jax.jit(fn, in_shardings=sh).lower(*args).compile().as_text()

    def test_psum_detected(self):
        n = len(jax.devices())
        mesh = jax.make_mesh((n,), ("d",),
                             axis_types=(jax.sharding.AxisType.Auto,))
        def f(x):
            return jax.shard_map(
                lambda c: jax.lax.psum(c, "d"), mesh=mesh,
                in_specs=P("d"), out_specs=P())(x)
        x = jax.ShapeDtypeStruct((n * 4, 128), jnp.float32)
        hlo = self._hlo_for(f, (x,), mesh, [P("d")])
        s = collective_summary(hlo)
        assert s["counts_by_kind"].get("all-reduce", 0) >= 1
        assert s["total_bytes"] > 0

    def test_scan_trip_multiplication(self):
        """A psum inside a 7-iteration scan must count ~7x the bytes of
        the same psum outside."""
        n = len(jax.devices())
        if n < 2:
            pytest.skip("needs >= 2 devices")
        mesh = jax.make_mesh((n,), ("d",),
                             axis_types=(jax.sharding.AxisType.Auto,))

        def inner(x):
            def body(c, _):
                c = jax.lax.psum(c, "d") / n
                return c, None
            y, _ = jax.lax.scan(body, x, None, length=7)
            return y

        def f(x):
            return jax.shard_map(inner, mesh=mesh, in_specs=P(None, "d"),
                                 out_specs=P(None, "d"),
                                 check_vma=False)(x)

        x = jax.ShapeDtypeStruct((8, n * 16), jnp.float32)
        hlo = self._hlo_for(f, (x,), mesh, [P(None, "d")])
        s = collective_summary(hlo)

        def g(x):
            return jax.shard_map(
                lambda c: jax.lax.psum(c, "d"), mesh=mesh,
                in_specs=P(None, "d"), out_specs=P(None))(x)
        hlo1 = self._hlo_for(g, (x,), mesh, [P(None, "d")])
        s1 = collective_summary(hlo1)
        assert s1["total_bytes"] > 0
        ratio = s["total_bytes"] / s1["total_bytes"]
        assert 5.0 <= ratio <= 9.0, ratio

    def test_parse_computations_structure(self):
        hlo = """
HloModule m

%body (p: (s32[], f32[4])) -> (s32[], f32[4]) {
  %ar = f32[4] all-reduce(f32[4] %x), replica_groups={}
  ROOT %t = (s32[], f32[4]) tuple(%i, %ar)
}

%cond (p: (s32[], f32[4])) -> pred[] {
  %c = s32[] constant(12)
  ROOT %lt = pred[] compare(%i, %c), direction=LT
}

ENTRY %main () -> f32[4] {
  %w = (s32[], f32[4]) while(%init), condition=%cond, body=%body
}
"""
        comps = parse_computations(hlo)
        assert "body" in comps and "cond" in comps
        s = collective_summary(hlo)
        assert s["counts_by_kind"]["all-reduce"] == 12
        assert s["bytes_by_kind"]["all-reduce"] == 12 * 2 * 16


class TestRoofline:
    def test_model_flops_train_vs_decode(self):
        t = model_flops("qwen2-0.5b", "train_4k")
        d = model_flops("qwen2-0.5b", "decode_32k")
        assert t > d * 1000
        assert t > 0 and d > 0

    def test_analytic_bytes_positive_all_cells(self):
        from repro.configs import all_configs, shapes_for
        for arch, cfg in all_configs().items():
            for shape in shapes_for(cfg):
                b = analytic_hbm_bytes(arch, shape.name)
                assert b > 0, (arch, shape.name)

    def test_analyze_record(self):
        rec = {
            "status": "ok", "arch": "qwen2-0.5b", "shape": "train_4k",
            "mesh": "16x16", "devices": 256,
            "cost_corrected": {"flops": 4.2e15,
                               "bytes_accessed": 3.7e14,
                               "collective_bytes": 4e11},
            "cost_scope": "global",
            "memory": {"temp_bytes": 8.2e9, "argument_bytes": 5.5e7},
        }
        row = analyze_record(rec)
        assert row.dominant in ("compute", "memory", "collective")
        assert row.fits
        assert 0 < row.roofline_frac <= 1.5
        assert 0.2 < row.useful_ratio < 1.5

    def test_decode_memory_dominated(self):
        """decode_32k on a dense model must be memory-bound (KV reads)."""
        rec = {
            "status": "ok", "arch": "qwen2.5-32b", "shape": "decode_32k",
            "mesh": "16x16", "devices": 256,
            "cost_corrected": {"flops": 8.4e12 / 256,
                               "collective_bytes": 1e7},
            "cost_scope": "per_device",
            "memory": {"temp_bytes": 1e9, "argument_bytes": 1e9},
        }
        row = analyze_record(rec)
        assert row.dominant == "memory"
