"""Fused single-pass census kernel: parity vs the Batagelj-Mrvar oracle and
the jnp backend, packed work-item encoding round-trips, degree-oriented
planning equivalence + work reduction, and edge cases."""

import numpy as np
import pytest

from repro.core import (
    PAPER_WORKLOADS, build_plan, census_batagelj_mrvar, census_dict,
    from_edges, pack_items, paper_workload, triad_census,
    triad_census_distributed, unpack_items)

#: small-size analogues of the paper's three workloads (fused kernel runs
#: in interpret mode on CPU here; full sizes live in benchmarks/)
SMALL_SIZES = {
    "patents": (600, 3.0),
    "orkut": (250, 12.0),
    "webgraph": (400, 6.0),
}


class TestFusedParity:
    @pytest.mark.parametrize("name", sorted(PAPER_WORKLOADS))
    def test_matches_bm_oracle(self, name):
        n, deg = SMALL_SIZES[name]
        g = paper_workload(name, n=n, avg_degree=deg, seed=0)
        plan = build_plan(g)
        got = triad_census(plan, backend="pallas-fused")
        np.testing.assert_array_equal(got, census_batagelj_mrvar(g))

    @pytest.mark.parametrize("name", sorted(PAPER_WORKLOADS))
    @pytest.mark.parametrize("orient", ["none", "degree"])
    def test_matches_jnp_backend(self, name, orient):
        n, deg = SMALL_SIZES[name]
        g = paper_workload(name, n=n, avg_degree=deg, seed=1)
        plan = build_plan(g, orient=orient)
        fused = triad_census(plan, backend="pallas-fused")
        ref = triad_census(plan, backend="jnp")
        np.testing.assert_array_equal(fused, ref)

    def test_distributed_fused(self):
        import jax
        g = paper_workload("orkut", n=200, avg_degree=10.0, seed=2)
        plan = build_plan(g, pad_to=len(jax.devices()), orient="degree")
        got = triad_census_distributed(plan, backend="pallas-fused")
        np.testing.assert_array_equal(got, census_batagelj_mrvar(g))

    def test_unknown_backend_rejected(self):
        g = from_edges([0], [1], n=3)
        with pytest.raises(ValueError):
            triad_census(build_plan(g), backend="cuda")


class TestFusedEdgeCases:
    def test_empty_graph(self):
        g = from_edges([], [], n=10)
        c = triad_census(build_plan(g), backend="pallas-fused")
        assert c[0] == 120 and c[1:].sum() == 0

    def test_single_pair(self):
        # one asymmetric arc among 5 nodes: 3 triads of 012, rest 003
        g = from_edges([0], [1], n=5)
        c = census_dict(triad_census(build_plan(g),
                                     backend="pallas-fused"))
        assert c["012"] == 3 and c["003"] == 7
        assert sum(c.values()) == 10

    def test_all_mutual_clique(self):
        # complete mutual digraph on 7 nodes: every triad is 300
        n = 7
        src, dst = np.nonzero(~np.eye(n, dtype=bool))
        g = from_edges(src, dst, n=n)
        for orient in ("none", "degree"):
            c = census_dict(triad_census(build_plan(g, orient=orient),
                                         backend="pallas-fused"))
            assert c["300"] == n * (n - 1) * (n - 2) // 6


class TestPackedEncoding:
    def test_roundtrip_exact(self):
        rng = np.random.default_rng(0)
        m = 10_000
        slot = rng.integers(0, 2**30, m)
        side = rng.integers(0, 2, m)
        pair = rng.integers(0, 2**30, m)
        valid = rng.integers(0, 2, m).astype(bool)
        sp, pv = pack_items(slot, side, pair, valid)
        assert sp.dtype == np.int32 and pv.dtype == np.int32
        s2, d2, p2, v2 = unpack_items(sp, pv)
        np.testing.assert_array_equal(s2, slot)
        np.testing.assert_array_equal(d2, side)
        np.testing.assert_array_equal(p2, pair)
        np.testing.assert_array_equal(v2, valid)

    def test_plan_views_decode_packed_words(self):
        g = paper_workload("webgraph", n=300, avg_degree=6.0, seed=3)
        plan = build_plan(g, pad_to=64)
        s, d, p, v = unpack_items(plan.item_sp, plan.item_pv)
        np.testing.assert_array_equal(plan.item_slot, s)
        np.testing.assert_array_equal(plan.item_side, d)
        np.testing.assert_array_equal(plan.item_pair, p)
        np.testing.assert_array_equal(plan.item_valid, v)
        assert int(plan.item_valid.sum()) == plan.num_items
        # decoded fields are in range for the device gathers
        assert plan.item_slot.max() < g.packed.shape[0]
        assert plan.item_pair.max() < plan.num_pairs


class TestDegreeOrientedPlanning:
    @pytest.mark.parametrize("name", sorted(PAPER_WORKLOADS))
    def test_reduces_items_on_power_law(self, name):
        n, deg = SMALL_SIZES[name]
        g = paper_workload(name, n=n, avg_degree=deg, seed=0)
        base = build_plan(g)
        orient = build_plan(g, orient="degree")
        assert orient.num_items < base.num_items
        assert orient.orient == "degree"

    def test_same_census_all_backends(self):
        g = paper_workload("orkut", n=200, avg_degree=10.0, seed=5)
        want = census_batagelj_mrvar(g)
        plan = build_plan(g, orient="degree")
        for backend in ("jnp", "pallas", "pallas-fused"):
            np.testing.assert_array_equal(
                triad_census(plan, backend=backend), want)

    def test_inter_side_bit_set_by_degree(self):
        g = paper_workload("patents", n=400, avg_degree=4.0, seed=6)
        plan = build_plan(g, orient="degree")
        deg = g.degrees
        inter_side = plan.pair_code >> 2
        want = (deg[plan.pair_v] < deg[plan.pair_u]).astype(np.int32)
        np.testing.assert_array_equal(inter_side, want)
        # default plans never set the bit
        base = build_plan(g)
        assert (base.pair_code >> 2 == 0).all()

    def test_rejects_unknown_orient(self):
        g = from_edges([0], [1], n=3)
        with pytest.raises(ValueError):
            build_plan(g, orient="random")
