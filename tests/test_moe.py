"""MoE dispatch: grouped-GSPMD path semantics + shard_map path parity."""

import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config
from repro.models.common import init_params
from repro.models.moe import apply_moe, moe_schema


def _setup(seed=0, shared=0):
    cfg = dataclasses.replace(
        get_config("deepseek-moe-16b").reduced(),
        num_experts=8, top_k=2, num_shared_experts=shared, d_model=64,
        d_ff=96)
    p = init_params(moe_schema(cfg), jax.random.PRNGKey(seed))
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(4, 16, 64)), jnp.float32) * 0.5
    return cfg, p, x


class TestGroupedDispatch:
    def test_groups_equivalent_when_capacity_ample(self):
        """With ample capacity, group count must not change the output."""
        cfg, p, x = _setup()
        y1, m1 = apply_moe(cfg, p, x, capacity_factor=16.0, groups=1)
        y4, m4 = apply_moe(cfg, p, x, capacity_factor=16.0, groups=4)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y4),
                                   rtol=2e-3, atol=2e-3)
        assert int(m1["dropped_tokens"]) == 0
        assert int(m4["dropped_tokens"]) == 0
        np.testing.assert_array_equal(np.asarray(m1["expert_load"]),
                                      np.asarray(m4["expert_load"]))

    def test_capacity_drops_tokens(self):
        cfg, p, x = _setup()
        _, m = apply_moe(cfg, p, x, capacity_factor=0.25, groups=1)
        assert int(m["dropped_tokens"]) > 0

    def test_shared_experts_add_signal(self):
        cfg, p, x = _setup(shared=1)
        y_with, _ = apply_moe(cfg, p, x, capacity_factor=16.0)
        cfg0 = dataclasses.replace(cfg, num_shared_experts=0)
        y_wo, _ = apply_moe(cfg0, {k: v for k, v in p.items()
                                   if not k.startswith("shared")},
                            x, capacity_factor=16.0)
        assert float(jnp.abs(y_with - y_wo).max()) > 1e-4

    def test_load_sums_to_assignments(self):
        cfg, p, x = _setup()
        _, m = apply_moe(cfg, p, x, capacity_factor=16.0)
        t = x.shape[0] * x.shape[1]
        assert int(m["expert_load"].sum()) == t * cfg.top_k


@pytest.mark.skipif(len(jax.devices()) < 4, reason="needs 4 devices")
class TestShardMapParity:
    def test_matches_grouped_path(self):
        from repro.models.moe_shard import make_sharded_moe
        from repro.parallel.sharding import spec_for_axes
        cfg, p, x = _setup(shared=1)
        mesh = jax.make_mesh((2, 2), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,) * 2)
        schema = moe_schema(cfg)
        specs = {k: spec_for_axes(d.axes, d.shape, mesh)
                 for k, d in schema.items()}
        moe_fn = make_sharded_moe(cfg, mesh, "data", specs,
                                  capacity_factor=16.0)
        y_sm, m_sm = jax.jit(moe_fn)(p, x)
        # reference: per-device groups = 4 (2 data x 2 model seq shards
        # -> shard_map groups tokens as (b/2, s/2) blocks; with ample
        # capacity and no drops, output is group-independent)
        y_ref, m_ref = apply_moe(cfg, p, x, capacity_factor=16.0,
                                 groups=1)
        np.testing.assert_allclose(
            np.asarray(y_sm, np.float32), np.asarray(y_ref, np.float32),
            rtol=5e-2, atol=5e-2)
        np.testing.assert_array_equal(np.asarray(m_sm["expert_load"]),
                                      np.asarray(m_ref["expert_load"]))
        assert int(m_sm["dropped_tokens"]) == 0

    def test_grad_flows(self):
        from repro.models.moe_shard import make_sharded_moe
        from repro.parallel.sharding import spec_for_axes
        cfg, p, x = _setup()
        mesh = jax.make_mesh((2, 2), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,) * 2)
        schema = moe_schema(cfg)
        specs = {k: spec_for_axes(d.axes, d.shape, mesh)
                 for k, d in schema.items()}
        moe_fn = make_sharded_moe(cfg, mesh, "data", specs,
                                  capacity_factor=16.0)

        def loss(pp):
            y, _ = moe_fn(pp, x)
            return jnp.sum(y.astype(jnp.float32) ** 2)

        g = jax.jit(jax.grad(loss))(p)
        total = sum(float(jnp.abs(l.astype(jnp.float32)).sum())
                    for l in jax.tree.leaves(g))
        assert np.isfinite(total) and total > 0
