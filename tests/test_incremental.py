"""Incremental census subsystem: CSR delta edits, subset planning,
affected-pair algebra, and the resident engine session.

The central property: for ANY edge delta, the session's incremental
update is bit-identical to a from-scratch census of the edited graph —
for every backend, both orient modes, and both drivers."""

import numpy as np
import pytest

from repro.core import (
    CensusEngine, apply_delta, base_for_pairs, build_plan, canonical_pairs,
    census_batagelj_mrvar, default_mesh, emit_items, emit_items_for_pairs,
    from_edges, from_pairs, pair_space, triad_census,
    verify_delta_closure)
from repro.core.digraph import arcs_to_pairs, clean_arcs
from repro.core.incremental import (
    affected_pair_ids, combine, host_runner, subset_contribution)
from repro.core.planner import global_bases


def random_graph(rng, n=None, p=None):
    n = n or int(rng.integers(3, 40))
    a = rng.random((n, n)) < (p or float(rng.uniform(0.05, 0.4)))
    np.fill_diagonal(a, False)
    return from_edges(*np.nonzero(a), n=n), a


def random_arcs(rng, n, k):
    return rng.integers(0, n, k), rng.integers(0, n, k)


# ------------------------------------------------------------ digraph delta


class TestApplyDelta:
    @pytest.mark.parametrize("seed", range(8))
    def test_matches_dense_rebuild(self, seed):
        rng = np.random.default_rng(seed)
        g, a = random_graph(rng)
        n = g.n
        asrc, adst = random_arcs(rng, n, int(rng.integers(0, 25)))
        dsrc, ddst = random_arcs(rng, n, int(rng.integers(0, 25)))
        g2, delta = apply_delta(g, asrc, adst, dsrc, ddst)
        g2.validate()
        b = a.copy()
        b[dsrc, ddst] = False          # removals first, then insertions
        b[asrc, adst] = True
        np.fill_diagonal(b, False)
        want = from_edges(*np.nonzero(b), n=n)
        np.testing.assert_array_equal(g2.indptr, want.indptr)
        np.testing.assert_array_equal(g2.packed, want.packed)
        assert g2.num_arcs == want.num_arcs
        # recorded pair codes match both graphs
        for lo, hi, oc, nc in zip(delta.pair_lo, delta.pair_hi,
                                  delta.old_code, delta.new_code):
            assert oc != nc
            assert oc == (int(a[lo, hi]) | (int(a[hi, lo]) << 1))
            assert nc == (int(b[lo, hi]) | (int(b[hi, lo]) << 1))
        # touched == endpoints of changed pairs
        np.testing.assert_array_equal(
            delta.touched,
            np.unique(np.concatenate([delta.pair_lo, delta.pair_hi])))

    def test_noop_deltas_return_same_graph(self):
        g = from_edges([0, 1], [1, 2], n=4)
        for args in ((), ([0], [1]),                 # existing arc added
                     (None, None, [3], [2]),         # absent arc removed
                     ([2], [2])):                    # self-loop dropped
            g2, delta = apply_delta(g, *args)
            assert g2 is g and delta.num_changed == 0

    def test_remove_then_add_same_arc_keeps_it(self):
        g = from_edges([0], [1], n=3)
        g2, delta = apply_delta(g, add_src=[0], add_dst=[1],
                                del_src=[0], del_dst=[1])
        assert g2 is g and delta.num_changed == 0

    def test_empty_graph_insert(self):
        g = from_edges([], [], n=5)
        g2, delta = apply_delta(g, [0, 1], [1, 0])
        assert g2.num_arcs == 2 and delta.num_changed == 1
        assert delta.old_code[0] == 0 and delta.new_code[0] == 3

    def test_delete_everything(self):
        g = from_edges([0, 1, 2], [1, 2, 0], n=3)
        g2, delta = apply_delta(g, del_src=[0, 1, 2], del_dst=[1, 2, 0])
        assert g2.num_arcs == 0 and g2.num_pairs == 0
        assert delta.num_changed == 3
        assert (delta.new_code == 0).all()

    def test_rejects_out_of_range(self):
        g = from_edges([0], [1], n=3)
        with pytest.raises(ValueError):
            apply_delta(g, [0], [3])

    def test_from_edges_composes_from_stages(self):
        rng = np.random.default_rng(5)
        n = 20
        src, dst = random_arcs(rng, n, 60)
        want = from_edges(src, dst, n=n)
        cs, cd, n2 = clean_arcs(src, dst, n)
        got = from_pairs(n2, *arcs_to_pairs(cs, cd, n2),
                         num_arcs=cs.shape[0])
        np.testing.assert_array_equal(got.packed, want.packed)
        np.testing.assert_array_equal(got.indptr, want.indptr)
        assert got.num_arcs == want.num_arcs

    def test_canonical_pairs_roundtrip(self):
        rng = np.random.default_rng(7)
        g, _ = random_graph(rng, n=25)
        pu, pv, code = canonical_pairs(g)
        assert (pu < pv).all()
        g2 = from_pairs(g.n, pu, pv, code)
        np.testing.assert_array_equal(g2.packed, g.packed)
        assert g2.num_arcs == g.num_arcs


# ------------------------------------------------------------ subset planner


class TestSubsetPlanning:
    @pytest.mark.parametrize("orient", ["none", "degree"])
    def test_all_pairs_subset_equals_full_emission(self, orient):
        rng = np.random.default_rng(11)
        g, _ = random_graph(rng, n=30, p=0.2)
        space = pair_space(g, orient=orient)
        full = emit_items(space, 0, space.num_items_preprune)
        sub = emit_items_for_pairs(space, np.arange(space.num_pairs))
        for f, s in zip(full, sub):
            np.testing.assert_array_equal(f, s)

    @pytest.mark.parametrize("orient", ["none", "degree"])
    def test_num_items_postprune_closed_form(self, orient):
        rng = np.random.default_rng(13)
        for _ in range(6):
            g, _ = random_graph(rng)
            space = pair_space(g, orient=orient)
            full = emit_items(space, 0, space.num_items_preprune)
            assert space.num_items_postprune() == full[0].shape[0]

    def test_bases_partition_additively(self):
        rng = np.random.default_rng(17)
        g, _ = random_graph(rng, n=35, p=0.25)
        for orient in ("none", "degree"):
            space = pair_space(g, orient=orient)
            ids = rng.permutation(space.num_pairs)
            cut = space.num_pairs // 3
            parts = (ids[:cut], ids[cut:2 * cut], ids[2 * cut:])
            asym = sum(base_for_pairs(space, p)[0] for p in parts)
            mut = sum(base_for_pairs(space, p)[1] for p in parts)
            assert (asym, mut) == global_bases(space)

    @pytest.mark.parametrize("orient", ["none", "degree"])
    def test_contributions_partition_to_full_census(self, orient):
        """Random pair partition: summed subset contributions == census."""
        rng = np.random.default_rng(19)
        g, _ = random_graph(rng, n=28, p=0.22)
        space = pair_space(g, orient=orient)
        run = host_runner(space)
        ids = rng.permutation(space.num_pairs)
        cut = space.num_pairs // 2
        c1, n1 = subset_contribution(space, ids[:cut], run)
        c2, n2 = subset_contribution(space, ids[cut:], run)
        zero = np.zeros(16, np.int64)
        got = combine(zero, zero, c1 + c2, g.n)
        want = triad_census(build_plan(g, orient=orient))
        np.testing.assert_array_equal(got, want)
        assert n1 + n2 == space.num_items_postprune()

    def test_rejects_bad_pair_ids(self):
        g = from_edges([0, 1], [1, 2], n=4)
        space = pair_space(g)
        with pytest.raises(ValueError):
            emit_items_for_pairs(space, [space.num_pairs])
        with pytest.raises(ValueError):
            emit_items_for_pairs(space, [-1])

    def test_empty_subset(self):
        g = from_edges([0, 1], [1, 2], n=4)
        space = pair_space(g)
        items = emit_items_for_pairs(space, [])
        assert all(a.shape == (0,) for a in items)
        assert base_for_pairs(space, []) == (0, 0)


# ------------------------------------------------------------ delta algebra


class TestDeltaAlgebra:
    @pytest.mark.parametrize("seed", range(6))
    @pytest.mark.parametrize("orient", ["none", "degree"])
    def test_delta_closure_invariant(self, seed, orient):
        rng = np.random.default_rng(100 + seed)
        g, _ = random_graph(rng)
        g2, delta = apply_delta(
            g, *random_arcs(rng, g.n, int(rng.integers(1, 20))),
            *random_arcs(rng, g.n, int(rng.integers(1, 20))))
        verify_delta_closure(pair_space(g, orient=orient),
                             pair_space(g2, orient=orient), delta)

    def test_affected_pairs_key_on_endpoints(self):
        g = from_edges([0, 1, 3], [1, 2, 4], n=6)
        space = pair_space(g)
        aff = affected_pair_ids(space, [1])
        keys = set(zip(space.pair_u[aff], space.pair_v[aff]))
        assert keys == {(0, 1), (1, 2)}
        assert affected_pair_ids(space, []).shape == (0,)

    @pytest.mark.parametrize("orient", ["none", "degree"])
    def test_host_incremental_update_is_exact(self, orient):
        """Pure host-side delta update (no session) — the algebra alone."""
        rng = np.random.default_rng(23)
        g, _ = random_graph(rng, n=30, p=0.2)
        g2, delta = apply_delta(g, *random_arcs(rng, g.n, 8),
                                *random_arcs(rng, g.n, 8))
        sp_old = pair_space(g, orient=orient)
        sp_new = pair_space(g2, orient=orient)
        c_old = triad_census(build_plan(g, orient=orient))
        old_c, _ = subset_contribution(
            sp_old, affected_pair_ids(sp_old, delta.touched),
            host_runner(sp_old))
        new_c, _ = subset_contribution(
            sp_new, affected_pair_ids(sp_new, delta.touched),
            host_runner(sp_new))
        got = combine(c_old, old_c, new_c, g.n)
        want = triad_census(build_plan(g2, orient=orient))
        np.testing.assert_array_equal(got, want)
        np.testing.assert_array_equal(want, census_batagelj_mrvar(g2))


# ------------------------------------------------------------ engine session

#: pallas backends run interpret-mode kernels per dispatch on CPU — they
#: sweep fewer delta steps than the pure-XLA backend
SESSION_STEPS = {"jnp": 4, "pallas": 2, "pallas-fused": 2}


class TestEngineSession:
    @pytest.mark.parametrize("backend", ["jnp", "pallas", "pallas-fused"])
    @pytest.mark.parametrize("orient", ["none", "degree"])
    def test_update_bit_identical_to_full(self, backend, orient):
        """The acceptance property: incremental == from-scratch, all
        3 backends x both orients."""
        rng = np.random.default_rng(31)
        g, _ = random_graph(rng, n=26, p=0.18)
        session = CensusEngine(backend=backend).session(
            g, orient=orient, max_items=64)
        np.testing.assert_array_equal(
            session.census(),
            triad_census(build_plan(g, orient=orient), backend=backend))
        for _ in range(SESSION_STEPS[backend]):
            add = random_arcs(rng, g.n, int(rng.integers(1, 10)))
            rem = random_arcs(rng, g.n, int(rng.integers(1, 10)))
            got = session.update(*add, *rem)
            g, _ = apply_delta(g, *add, *rem)
            want = triad_census(build_plan(g, orient=orient),
                                backend=backend)
            np.testing.assert_array_equal(got, want)
        np.testing.assert_array_equal(got, census_batagelj_mrvar(g))

    def test_mesh_session(self):
        rng = np.random.default_rng(37)
        g, _ = random_graph(rng, n=24, p=0.2)
        session = CensusEngine(mesh=default_mesh()).session(g, max_items=64)
        session.census()
        add = random_arcs(rng, g.n, 6)
        got = session.update(*add)
        g2, _ = apply_delta(g, *add)
        np.testing.assert_array_equal(got, census_batagelj_mrvar(g2))
        assert session.chunk_shape % session.engine.ndev == 0

    def test_compile_once_across_updates(self):
        rng = np.random.default_rng(41)
        g, _ = random_graph(rng, n=40, p=0.1)
        session = CensusEngine(backend="jnp").session(g, max_items=128)
        session.census()
        compiles = [session.stats.step_compiles]
        for _ in range(4):
            session.update(*random_arcs(rng, g.n, 5),
                           *random_arcs(rng, g.n, 5))
            compiles.append(session.stats.step_compiles)
        # the census() dispatch may compile the step once; every delta
        # update afterwards reuses it (fixed shapes + pinned search depth)
        assert sum(compiles) <= 1, compiles

    def test_update_requires_baseline(self):
        g = from_edges([0], [1], n=3)
        session = CensusEngine().session(g)
        with pytest.raises(RuntimeError):
            session.update([1], [2])

    def test_empty_delta_short_circuits(self):
        g = from_edges([0, 1], [1, 2], n=4)
        session = CensusEngine().session(g)
        c0 = session.census()
        got = session.update([0], [1])       # already present
        np.testing.assert_array_equal(got, c0)
        assert session.stats.items == 0 and session.stats.chunks == 0

    def test_set_graph_rebases(self):
        rng = np.random.default_rng(43)
        g1, _ = random_graph(rng, n=20, p=0.2)
        g2, _ = random_graph(rng, n=20, p=0.2)
        session = CensusEngine().session(g1)
        session.census()
        session.set_graph(g2)
        assert session.counts is None
        np.testing.assert_array_equal(session.census(),
                                      census_batagelj_mrvar(g2))
        with pytest.raises(ValueError):
            session.set_graph(from_edges([0], [1], n=21))

    def test_stats_track_reduction(self):
        """Small deltas on a larger graph: the session recounts far fewer
        items than a full recompute would (the whole point)."""
        rng = np.random.default_rng(47)
        g, _ = random_graph(rng, n=300, p=0.02)
        session = CensusEngine().session(g, max_items=512)
        session.census()
        full0 = session.stats
        assert full0.items == full0.full_items > 0
        session.update([0, 1], [2, 3])
        st = session.stats
        assert st.full_items > 0 and st.affected_pairs > 0
        assert st.items < st.full_items / 2
        assert st.peak_plan_bytes == 8 * session.chunk_shape

    def test_capacity_growth_keeps_exactness(self):
        """A delta that doubles the graph forces device-buffer growth."""
        rng = np.random.default_rng(53)
        g, _ = random_graph(rng, n=30, p=0.05)
        session = CensusEngine().session(g, max_items=64)
        session.census()
        add = random_arcs(rng, g.n, 400)
        got = session.update(*add)
        g2, _ = apply_delta(g, *add)
        np.testing.assert_array_equal(got, census_batagelj_mrvar(g2))
