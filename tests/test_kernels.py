"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode,
plus end-to-end census equality through the kernel backend."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import (
    pair_codes, pair_codes_ref, tricode_histogram, tricode_histogram_ref)
from repro.kernels.tricode_hist import BLOCK_ITEMS


class TestTricodeHistogram:
    @pytest.mark.parametrize("w", [1, 100, BLOCK_ITEMS, BLOCK_ITEMS + 1,
                                   3 * BLOCK_ITEMS, 50_000])
    def test_matches_ref(self, w):
        rng = np.random.default_rng(w)
        tri = jnp.asarray(rng.integers(0, 64, size=w), jnp.int32)
        mask = jnp.asarray(rng.random(w) < 0.7)
        got = tricode_histogram(tri, mask, interpret=True)
        want = tricode_histogram_ref(jnp.where(mask, tri, 64))
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
        assert int(got.sum()) == int(mask.sum())

    def test_all_masked(self):
        tri = jnp.zeros(BLOCK_ITEMS, jnp.int32)
        mask = jnp.zeros(BLOCK_ITEMS, bool)
        assert int(tricode_histogram(tri, mask, interpret=True).sum()) == 0

    def test_single_class(self):
        tri = jnp.full((2 * BLOCK_ITEMS,), 63, jnp.int32)
        mask = jnp.ones(2 * BLOCK_ITEMS, bool)
        hist = tricode_histogram(tri, mask, interpret=True)
        assert int(hist[63]) == 2 * BLOCK_ITEMS
        assert int(hist.sum()) == 2 * BLOCK_ITEMS


class TestPairCodes:
    @pytest.mark.parametrize("b", [1, 7, 8, 33])
    @pytest.mark.parametrize("hit_rate", [0.0, 0.3, 1.0])
    def test_matches_ref(self, b, hit_rate):
        rng = np.random.default_rng(b * 17 + int(hit_rate * 10))
        # sorted unique key rows with codes in {1,2,3}
        k = np.sort(rng.choice(10_000, size=(b, 128), replace=False, axis=-1)
                    if False else
                    np.stack([rng.choice(10_000, size=128, replace=False)
                              for _ in range(b)]), axis=1).astype(np.int32)
        kc = rng.integers(1, 4, size=(b, 128)).astype(np.int32)
        take = rng.random((b, 128)) < hit_rate
        q = np.where(take, k, -5 - rng.integers(0, 100, size=(b, 128)))
        q = q.astype(np.int32)
        got = pair_codes(jnp.asarray(q), jnp.asarray(k), jnp.asarray(kc),
                         interpret=True)
        want = pair_codes_ref(jnp.asarray(q), jnp.asarray(k), jnp.asarray(kc))
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
        # misses produce exactly 0
        np.testing.assert_array_equal(np.asarray(got)[~take], 0)


class TestCensusThroughKernel:
    @pytest.mark.parametrize("seed", range(3))
    def test_pallas_backend_census(self, seed):
        from repro.core import (build_plan, triad_census,
                                census_batagelj_mrvar, scale_free_digraph)
        g = scale_free_digraph(n=300, avg_degree=6, exponent=2.2,
                               mutual_p=0.3, seed=seed)
        plan = build_plan(g)
        got = triad_census(plan, backend="pallas")
        want = census_batagelj_mrvar(g)
        np.testing.assert_array_equal(got, want)
