"""Fault-tolerant shard streams: inject, retry, fail over, resume —
and stay bit-identical.

The tentpole contract:

* **Bit-identity under faults** — because the host int64 merge is
  order-invariant and windows are independent, ANY window may be
  retried, re-routed to a surviving device, or re-counted after a
  resume without changing a single census lane.  Seeded fault plans
  (producer errors, dispatch errors, slow devices, poisoned results,
  mid-run device retirements) across 1/2/4/8-device meshes × orients ×
  emit modes must reproduce the fault-free census exactly.
* **Accounting** — every recovery action is visible:
  ``EngineStats.retries/failovers/watchdog_fires/retired_devices``.
* **Checkpoint/resume** — a run killed mid-stream resumes from its
  journal to the exact same census, skipping completed windows.
* **Sessions** — the resident sessions retry transient faults on the
  same device and reject poisoned partials; context managers reap the
  device buffers on exceptions.
* **Guard rails** — int32-overflow plans fail loudly at plan time
  (:class:`PlanOverflowError`), and the ingestion edge rejects ragged /
  non-finite / out-of-range input before it reaches the CSR editors.
"""

import os
import threading
import time

import numpy as np
import pytest

from repro.core import (
    CensusEngine, Fault, FaultError, FaultPlan, InjectedFault,
    PlanChunker, PlanOverflowError, ProducerStalledError,
    ShardStreamPipeline, TriadMonitor, default_mesh, from_edges,
    partition_graph, scale_free_digraph, shard_report)
from repro.core.faults import FaultInjector, poison_result
from repro.core.plan_stream import ShardSchedule


def pl_graph(n=120, deg=4, seed=3):
    return scale_free_digraph(n=n, avg_degree=deg, exponent=2.2,
                              mutual_p=0.3, seed=seed)


# ------------------------------------------------------------ fault plans


class TestFaultPlan:
    def test_seeded_is_deterministic(self):
        a = FaultPlan.seeded(11, 8, producer_errors=2, dispatch_errors=2,
                             retire_devices=1, delays=1, poisons=1)
        b = FaultPlan.seeded(11, 8, producer_errors=2, dispatch_errors=2,
                             retire_devices=1, delays=1, poisons=1)
        assert a.faults == b.faults
        c = FaultPlan.seeded(12, 8, producer_errors=2, dispatch_errors=2,
                             retire_devices=1, delays=1, poisons=1)
        assert a.faults != c.faults

    def test_retirements_spare_device_zero(self):
        for seed in range(20):
            plan = FaultPlan.seeded(seed, 8, retire_devices=3)
            retired = {f.device for f in plan.faults if f.persistent}
            assert 0 not in retired and len(retired) == 3

    def test_fault_validation(self):
        with pytest.raises(ValueError, match="site"):
            Fault("nowhere")
        with pytest.raises(ValueError, match="kind"):
            Fault("dispatch", "explode")
        with pytest.raises(ValueError, match="persistent"):
            Fault("producer", "error", persistent=True)

    def test_injector_occurrence_matching(self):
        inj = FaultPlan(faults=[
            Fault("dispatch", "error", device=1, occurrence=1)]).injector()
        inj.fire("dispatch", shard=1, device=1)        # occurrence 0: ok
        with pytest.raises(InjectedFault):
            inj.fire("dispatch", shard=1, device=1)    # occurrence 1
        inj.fire("dispatch", shard=1, device=1)        # transient: gone
        inj.fire("dispatch", shard=0, device=0)        # other stream: ok

    def test_persistent_fault_kills_the_device(self):
        inj = FaultPlan(faults=[
            Fault("dispatch", "error", device=2, occurrence=0,
                  persistent=True)]).injector()
        with pytest.raises(InjectedFault):
            inj.fire("dispatch", shard=2, device=2)
        assert inj.device_is_dead(2)
        with pytest.raises(InjectedFault):   # every later op fails too
            inj.fire("upload", shard=5, device=2)
        inj.fire("dispatch", shard=3, device=3)   # survivors unaffected

    def test_poison_is_taken_once(self):
        inj = FaultPlan(faults=[
            Fault("dispatch", "poison", occurrence=0)]).injector()
        inj.fire("dispatch", shard=0, device=0)
        assert inj.take_poison()
        assert not inj.take_poison()

    def test_poison_result_fails_validation(self):
        hist = np.arange(64, dtype=np.int64)
        inter = np.array([3, 4, 5], dtype=np.int64)
        ph, pi = poison_result(hist, inter)
        assert (ph < 0).all()
        from repro.core.engine import _validate_partials
        with pytest.raises(FaultError):
            _validate_partials(ph, pi)
        _validate_partials(hist, inter)   # clean partials pass


# ---------------------------------------------------- pipeline robustness


class TestPipelineRecovery:
    def test_producer_error_restarts_from_skip(self):
        """A producer that dies mid-stream is restarted with the count of
        windows already delivered; nothing is lost or duplicated."""
        attempts = {"n": 0}

        def flaky(skip=0):
            attempts["n"] += 1
            for k in range(skip, 6):
                if k == 3 and attempts["n"] == 1:
                    raise RuntimeError("flake")
                yield k

        pipe = ShardStreamPipeline(
            [flaky()], restart=lambda slot, skip: flaky(skip),
            backoff=0.0)
        got = [w for _, w in pipe]
        pipe.close()
        assert got == list(range(6))
        assert pipe.producer_retries == 1

    def test_producer_error_without_restart_propagates(self):
        def dead():
            yield 0
            raise RuntimeError("no recovery")

        pipe = ShardStreamPipeline([dead()])
        with pytest.raises(RuntimeError, match="no recovery"):
            list(pipe)
        pipe.close()

    def test_retry_budget_exhaustion_propagates(self):
        def always(skip=0):
            raise RuntimeError("permafail")
            yield  # pragma: no cover

        pipe = ShardStreamPipeline(
            [always()], restart=lambda slot, skip: always(skip),
            max_retries=2, backoff=0.0)
        with pytest.raises(RuntimeError, match="permafail"):
            list(pipe)
        pipe.close()
        assert pipe.producer_retries == 2

    def test_watchdog_restarts_hung_producer(self):
        """A producer that hangs (no put, queue empty) past the watchdog
        timeout is cancelled and regenerated from its skip count."""
        hang = threading.Event()

        def hung(skip=0):
            for k in range(skip, 4):
                if k == 2 and not hang.is_set():
                    hang.set()
                    time.sleep(30)       # never finishes in time
                yield k

        pipe = ShardStreamPipeline(
            [hung()], restart=lambda slot, skip: hung(skip),
            watchdog=0.3, backoff=0.0)
        got = [w for _, w in pipe]
        pipe.close()
        assert got == list(range(4))
        assert pipe.watchdog_fires >= 1

    def test_watchdog_exhaustion_raises_stalled(self):
        def hung(skip=0):
            time.sleep(30)
            yield 0  # pragma: no cover

        pipe = ShardStreamPipeline(
            [hung()], restart=lambda slot, skip: hung(skip),
            watchdog=0.2, max_retries=1, backoff=0.0)
        with pytest.raises(ProducerStalledError):
            list(pipe)
        pipe.close()

    def test_context_manager_reaps_threads(self):
        def slow():
            for k in range(1000):
                yield k

        with ShardStreamPipeline([slow(), slow()], depth=2) as pipe:
            next(iter(pipe))
            threads = list(pipe._threads)
        for t in threads:
            t.join(timeout=5)
            assert not t.is_alive()

    def test_context_manager_reaps_on_exception(self):
        def src():
            yield from range(100)

        try:
            with ShardStreamPipeline([src()]) as pipe:
                raise KeyboardInterrupt
        except KeyboardInterrupt:
            pass
        for t in pipe._threads:
            t.join(timeout=5)
            assert not t.is_alive()


# -------------------------------------------------- engine runs, faulted


@pytest.fixture(scope="module")
def g():
    return pl_graph()


@pytest.fixture(scope="module")
def reference(g):
    """Fault-free reference censuses keyed by orient."""
    eng = CensusEngine()
    return {orient: eng.run(g, orient=orient)
            for orient in ("none", "degree")}


class TestFaultedRunsBitIdentical:
    @pytest.mark.parametrize("ndev", [1, 2, 4, 8])
    @pytest.mark.parametrize("emit", ["device", "host"])
    def test_transient_faults_all_meshes(self, g, reference, ndev, emit):
        plan = FaultPlan.seeded(
            31 + ndev, ndev, producer_errors=1, dispatch_errors=1,
            retire_devices=1 if ndev > 1 else 0)
        eng = CensusEngine(mesh=default_mesh(ndev), partition=True,
                           schedule="async", faults=plan,
                           retry_backoff=0.0)
        got = eng.run(g, max_items=900, emit=emit)
        assert (got == reference["none"]).all()
        st = eng.stats
        assert st.retries >= 1
        if ndev > 1:
            assert st.failovers >= 1 and st.retired_devices

    @pytest.mark.parametrize("orient", ["none", "degree"])
    def test_orients_with_retirement(self, g, reference, orient):
        plan = FaultPlan.seeded(5, 8, producer_errors=1,
                                dispatch_errors=2, retire_devices=1)
        eng = CensusEngine(mesh=default_mesh(8), partition=True,
                           schedule="async", faults=plan,
                           retry_backoff=0.0)
        got = eng.run(g, max_items=900, orient=orient)
        assert (got == reference[orient]).all()
        assert eng.stats.failovers >= 1

    def test_slow_device_and_poison(self, g, reference):
        plan = FaultPlan.seeded(9, 4, producer_errors=0,
                                dispatch_errors=0, delays=2, poisons=2,
                                delay_seconds=0.05)
        eng = CensusEngine(mesh=default_mesh(4), partition=True,
                          schedule="async", faults=plan,
                          retry_backoff=0.0)
        got = eng.run(g, max_items=900)
        assert (got == reference["none"]).all()
        assert eng.stats.retries >= 1   # each poison forces a re-dispatch

    def test_every_device_retired_raises(self, g):
        plan = FaultPlan(faults=[
            Fault("dispatch", "error", device=d, occurrence=0,
                  persistent=True) for d in range(2)])
        eng = CensusEngine(mesh=default_mesh(2), partition=True,
                          schedule="async", faults=plan,
                          retry_backoff=0.0)
        with pytest.raises(FaultError, match="every device"):
            eng.run(g, max_items=900)

    def test_shard_report_failure_section(self, g):
        plan = FaultPlan.seeded(5, 8, producer_errors=1,
                                dispatch_errors=2, retire_devices=1)
        eng = CensusEngine(mesh=default_mesh(8), partition=True,
                          schedule="async", faults=plan,
                          retry_backoff=0.0)
        eng.run(g, max_items=900)
        part = partition_graph(g, num_shards=8)
        text = shard_report(part, stats=eng.stats)
        assert "fault tolerance:" in text
        assert "retired devices" in text and "failovers" in text
        assert "fault tolerance:" not in shard_report(part)


# ----------------------------------------------------- checkpoint/resume


class _Killer:
    """Progress callback that raises after ``at`` landed windows."""

    def __init__(self, at):
        self.at = at
        self.seen = 0

    def __call__(self, done, total, num=None):
        self.seen += 1
        if self.seen == self.at:
            raise KeyboardInterrupt


class TestCheckpointResume:
    @pytest.mark.parametrize("emit", ["device", "host"])
    def test_resume_equals_uninterrupted(self, tmp_path, g, reference,
                                         emit):
        ck = str(tmp_path / "run.ckpt")
        eng = CensusEngine(mesh=default_mesh(4), partition=True,
                          schedule="async")
        with pytest.raises(KeyboardInterrupt):
            eng.run(g, max_items=900, emit=emit, checkpoint=ck,
                    progress=_Killer(4))
        assert os.path.getsize(ck) > 0
        got = eng.resume(g, ck, max_items=900, emit=emit)
        assert (got == reference["none"]).all()
        assert eng.stats.resumed_windows >= 1

    def test_resume_under_further_faults(self, tmp_path, g, reference):
        """Kill a run, then resume it WITH a fault plan that retires a
        device — the journal windows stay skipped, the remainder fails
        over, and the census is still exact."""
        ck = str(tmp_path / "run.ckpt")
        eng = CensusEngine(mesh=default_mesh(4), partition=True,
                          schedule="async")
        with pytest.raises(KeyboardInterrupt):
            eng.run(g, max_items=900, checkpoint=ck, progress=_Killer(3))
        plan = FaultPlan.seeded(2, 4, producer_errors=0,
                                dispatch_errors=1, retire_devices=1)
        eng2 = CensusEngine(mesh=default_mesh(4), partition=True,
                           schedule="async", faults=plan,
                           retry_backoff=0.0)
        got = eng2.resume(g, ck, max_items=900)
        assert (got == reference["none"]).all()
        assert eng2.stats.resumed_windows >= 1
        assert eng2.stats.failovers >= 1

    def test_completed_checkpoint_dispatches_nothing(self, tmp_path, g,
                                                     reference):
        ck = str(tmp_path / "run.ckpt")
        eng = CensusEngine(mesh=default_mesh(4), partition=True,
                          schedule="async")
        want = eng.run(g, max_items=900, checkpoint=ck)
        assert (want == reference["none"]).all()
        windows = eng.stats.resumed_windows + sum(eng.stats.shard_steps)
        got = eng.resume(g, ck, max_items=900)
        assert (got == want).all()
        assert eng.stats.resumed_windows == windows
        assert sum(eng.stats.shard_steps) == 0

    def test_fingerprint_mismatch_rejected(self, tmp_path, g):
        ck = str(tmp_path / "run.ckpt")
        eng = CensusEngine(mesh=default_mesh(4), partition=True,
                          schedule="async")
        eng.run(g, max_items=900, checkpoint=ck)
        other = pl_graph(seed=99)
        with pytest.raises(FaultError, match="different run"):
            eng.resume(other, ck, max_items=900)

    def test_checkpoint_requires_async_partitioned(self, tmp_path, g):
        eng = CensusEngine(mesh=default_mesh(4))
        with pytest.raises(ValueError, match="checkpoint"):
            eng.run(g, max_items=900,
                    checkpoint=str(tmp_path / "x.ckpt"))

    def test_resume_missing_file_raises(self, g):
        eng = CensusEngine(mesh=default_mesh(4), partition=True,
                          schedule="async")
        with pytest.raises(FileNotFoundError):
            eng.resume(g, "/nonexistent/run.ckpt", max_items=900)

    def test_compact_checkpoint_resumes_identically(self, tmp_path, g,
                                                    reference):
        """Kill a run mid-stream, fold its journal, and resume from the
        compacted form — same census, smaller file, and a second
        compaction after completion leaves one record per shard."""
        ck = str(tmp_path / "run.ckpt")
        eng = CensusEngine(mesh=default_mesh(4), partition=True,
                          schedule="async")
        with pytest.raises(KeyboardInterrupt):
            eng.run(g, max_items=900, checkpoint=ck, progress=_Killer(4))
        info = CensusEngine.compact_checkpoint(ck)
        assert info["records"] >= info["compacted"] >= 1
        assert info["compacted_bytes"] == os.path.getsize(ck)
        assert info["compacted_bytes"] <= info["bytes"]
        eng2 = CensusEngine(mesh=default_mesh(4), partition=True,
                           schedule="async")
        got = eng2.resume(g, ck, max_items=900)
        assert (got == reference["none"]).all()
        assert eng2.stats.resumed_windows >= 1
        # the completed journal (compacted snapshot + appended tail)
        # compacts again and then resumes with zero dispatches
        info2 = CensusEngine.compact_checkpoint(ck)
        assert info2["compacted"] >= info["compacted"]
        got2 = eng2.resume(g, ck, max_items=900)
        assert (got2 == reference["none"]).all()
        assert sum(eng2.stats.shard_steps) == 0

    def test_compact_checkpoint_rejects_bad_journals(self, tmp_path):
        with pytest.raises(FileNotFoundError):
            CensusEngine.compact_checkpoint(
                str(tmp_path / "missing.ckpt"))
        empty = tmp_path / "empty.ckpt"
        empty.write_text("")
        with pytest.raises(FaultError, match="empty"):
            CensusEngine.compact_checkpoint(str(empty))
        bad = tmp_path / "bad.ckpt"
        bad.write_text('{"v": 99}\n')
        with pytest.raises(FaultError, match="version"):
            CensusEngine.compact_checkpoint(str(bad))


# ----------------------------------------------------------- sessions


class TestSessionFaults:
    @pytest.mark.parametrize("partition", [False, True])
    def test_session_retries_transient_faults(self, g, reference,
                                              partition):
        plan = FaultPlan(faults=[
            Fault("dispatch", "error", occurrence=1),
            Fault("dispatch", "poison", occurrence=3),
            Fault("upload", "error", occurrence=5)])
        eng = CensusEngine(mesh=default_mesh(4), partition=partition,
                          faults=plan, retry_backoff=0.0)
        with eng.session(g, max_items=900) as s:
            got = s.census()
            assert (got == reference["none"]).all()
            assert s.retries >= 2
            assert s.stats.retries == s.retries

    @pytest.mark.parametrize("partition", [False, True])
    def test_session_budget_exhaustion_raises(self, g, partition):
        plan = FaultPlan(faults=[
            Fault("dispatch", "error", occurrence=2 + i)
            for i in range(4)])
        eng = CensusEngine(mesh=default_mesh(4), partition=partition,
                          faults=plan, max_retries=2, retry_backoff=0.0)
        with eng.session(g, max_items=900) as s:
            with pytest.raises(FaultError):
                s.census()

    @pytest.mark.parametrize("partition", [False, True])
    def test_context_manager_closes(self, g, partition):
        eng = CensusEngine(mesh=default_mesh(4), partition=partition)
        with eng.session(g, max_items=900) as s:
            s.census()
        with pytest.raises(RuntimeError, match="closed"):
            s.census()
        with pytest.raises(RuntimeError, match="closed"):
            s.update([0], [1])
        s.close()     # idempotent

    @pytest.mark.parametrize("partition", [False, True])
    def test_checkpoint_warm_resume(self, tmp_path, g, reference,
                                    partition):
        """A census checkpointed from one session warm-resumes updates in
        a fresh session bit-identically to a never-interrupted one."""
        ck = str(tmp_path / "sess.ckpt")
        eng = CensusEngine(mesh=default_mesh(4), partition=partition)
        with eng.session(g, max_items=900) as s:
            s.census()
            s.save_checkpoint(ck)
        with eng.session(g, max_items=900) as warm:
            assert (warm.load_checkpoint(ck) == reference["none"]).all()
            c_warm = warm.update([0, 1, 2], [3, 4, 5])
        with eng.session(g, max_items=900) as cold:
            cold.census()
            c_cold = cold.update([0, 1, 2], [3, 4, 5])
        assert (c_warm == c_cold).all()

    def test_checkpoint_mismatch_rejected(self, tmp_path, g):
        ck = str(tmp_path / "sess.ckpt")
        eng = CensusEngine(mesh=default_mesh(4))
        with eng.session(g, max_items=900) as s:
            s.census()
            s.save_checkpoint(ck)
        with eng.session(pl_graph(seed=99), max_items=900) as other:
            with pytest.raises(FaultError, match="does not match"):
                other.load_checkpoint(ck)

    def test_checkpoint_without_census_raises(self, tmp_path, g):
        eng = CensusEngine(mesh=default_mesh(4))
        with eng.session(g, max_items=900) as s:
            with pytest.raises(RuntimeError, match="census"):
                s.save_checkpoint(str(tmp_path / "x.ckpt"))


# ------------------------------------------------------------ monitor


class TestMonitorDegradation:
    def _stream(self, seed=0, n=120, batch=150, batches=8):
        rng = np.random.default_rng(seed)
        return [(rng.integers(0, n, batch), rng.integers(0, n, batch))
                for _ in range(batches)]

    def test_monitor_survives_budget_exhaustion(self):
        plan = FaultPlan(faults=[
            Fault("dispatch", "error", device=0, occurrence=6 + i)
            for i in range(3)])
        mon = TriadMonitor(120, window=300, stride=150, history=3,
                           faults=plan, max_retries=2, retry_backoff=0.0)
        ref = TriadMonitor(120, window=300, stride=150, history=3)
        for src, dst in self._stream():
            mon.observe(src, dst)
        for src, dst in self._stream():
            ref.observe(src, dst)
        assert len(mon.degraded) >= 1
        deg = {d["window"] for d in mon.degraded}
        A, B = mon.censuses, ref.censuses
        assert A.shape == B.shape
        for t in range(A.shape[0]):
            if t in deg:     # carried forward from the previous window
                assert (A[t] == A[t - 1]).all()
            else:            # recomputed in full: bit-identical again
                assert (A[t] == B[t]).all()
        assert mon.window_stats[min(deg)] is None

    def test_monitor_transparent_retries(self):
        plan = FaultPlan(faults=[Fault("dispatch", "error", occurrence=2)])
        mon = TriadMonitor(120, window=300, stride=150, history=3,
                           faults=plan, retry_backoff=0.0)
        ref = TriadMonitor(120, window=300, stride=150, history=3)
        for src, dst in self._stream():
            mon.observe(src, dst)
        for src, dst in self._stream():
            ref.observe(src, dst)
        assert not mon.degraded
        assert (mon.censuses == ref.censuses).all()
        assert mon._session.retries >= 1


class TestIngestionValidation:
    def test_monitor_rejects_ragged(self):
        mon = TriadMonitor(10, window=4)
        with pytest.raises(ValueError, match="ragged"):
            mon.observe(np.array([[0, 1], [2]], dtype=object), [1, 2])

    def test_monitor_rejects_out_of_range(self):
        mon = TriadMonitor(10, window=4)
        with pytest.raises(ValueError, match="out of range"):
            mon.observe([0, 99], [1, 2])

    def test_monitor_rejects_bad_timestamps(self):
        mon = TriadMonitor(10, window=4)
        with pytest.raises(ValueError, match="NaN"):
            mon.observe([0, 1], [1, 2], t=[1.0, float("nan")])
        with pytest.raises(ValueError, match="negative"):
            mon.observe([0, 1], [1, 2], t=[-1.0, 2.0])
        with pytest.raises(ValueError, match="mismatch"):
            mon.observe([0, 1], [1, 2], t=[1.0])
        mon.observe([0, 1], [1, 2], t=[1.0, 2.0])
        with pytest.raises(ValueError, match="regressed"):
            mon.observe([0, 1], [1, 2], t=[0.5, 3.0])

    def test_clean_arcs_actionable_errors(self):
        with pytest.raises(ValueError, match="ragged"):
            from_edges(np.array([[0, 1], [2]], dtype=object), [1, 2])
        with pytest.raises(ValueError, match="non-finite"):
            from_edges([0.0, float("nan")], [1.0, 2.0], n=4)
        with pytest.raises(ValueError, match=r"out of range \[0, 4\)"):
            from_edges([0, 9], [1, 2], n=4)
        with pytest.raises(ValueError, match="mismatch: 2 != 3"):
            from_edges([0, 1], [1, 2, 3])

    def test_apply_delta_validates(self):
        from repro.core import apply_delta
        g = pl_graph(n=20)
        with pytest.raises(ValueError, match="out of range"):
            apply_delta(g, [0, 99], [1, 2])
        with pytest.raises(ValueError, match="non-finite"):
            apply_delta(g, None, None, [float("inf")], [1.0])


# ----------------------------------------------------- overflow guards


class TestPlanOverflowGuard:
    def test_is_a_value_error(self):
        assert issubclass(PlanOverflowError, ValueError)

    def test_chunker_rejects_near_2_31_window(self):
        from types import SimpleNamespace
        big = SimpleNamespace(num_items_preprune=2**31 + 5)
        with pytest.raises(PlanOverflowError, match="int32"):
            PlanChunker(None, 2**31, space=big)
        # a budget under the lane limit is fine at construction time
        small = SimpleNamespace(num_items_preprune=2**31 + 5)
        try:
            PlanChunker(None, 2**20, space=small)
        except PlanOverflowError:      # pragma: no cover
            pytest.fail("sub-limit budget must not raise")
        except Exception:
            pass   # later attrs of the fake space may be missing

    def test_shard_schedule_rejects_near_2_31_window(self):
        from types import SimpleNamespace
        big = SimpleNamespace(num_items_preprune=2**31 + 7)
        with pytest.raises(PlanOverflowError, match="int32"):
            ShardSchedule([big], None, 1)

    def test_engine_guard(self):
        from repro.core.engine import _guard_chunk_shape
        with pytest.raises(PlanOverflowError, match="int32"):
            _guard_chunk_shape(2**31)
        assert _guard_chunk_shape(2**31 - 1) == 2**31 - 1
