"""Temporal monitoring: sliding-window censuses, incremental parity,
alarm behavior, input validation, and proportion/alarm caching."""

import numpy as np
import pytest

from repro.core import (
    SECURITY_PATTERN_INDICES, SECURITY_PATTERNS, TRIAD_NAMES, TriadMonitor,
    build_plan, from_edges, triad_census)


def direct_census(src, dst, n, lo, hi, backend="jnp", orient="none"):
    g = from_edges(src[lo:hi], dst[lo:hi], n=n)
    return triad_census(build_plan(g, orient=orient), backend=backend)


def stream(seed, n, length, zipf=1.6, mutual_p=0.3):
    rng = np.random.default_rng(seed)
    src = (rng.zipf(zipf, length) - 1) % n
    dst = rng.integers(0, n, length)
    back = rng.random(length) < mutual_p
    src = np.where(back, dst, src)
    return src.astype(np.int64), dst.astype(np.int64)


# ------------------------------------------------------------ window parity


class TestWindowParity:
    @pytest.mark.parametrize("incremental", [True, False])
    def test_sliding_windows_match_direct_census(self, incremental):
        n, W, S = 100, 400, 100
        src, dst = stream(0, n, 1600)
        mon = TriadMonitor(n, window=W, stride=S, history=2,
                           incremental=incremental)
        out = []
        # ragged batches: windowing must not depend on batch boundaries
        for lo, hi in ((0, 250), (250, 900), (900, 901), (901, 1600)):
            out.extend(mon.observe(src[lo:hi], dst[lo:hi]))
        starts = range(0, 1600 - W + 1, S)
        assert len(out) == len(list(starts))
        for census, lo in zip(out, starts):
            np.testing.assert_array_equal(
                census, direct_census(src, dst, n, lo, lo + W),
                err_msg=f"window at {lo}")

    @pytest.mark.parametrize("backend", ["jnp", "pallas", "pallas-fused"])
    @pytest.mark.parametrize("orient", ["none", "degree"])
    def test_incremental_bit_identical_all_backends(self, backend, orient):
        """Acceptance: incremental window updates == full per-window
        recompute across all 3 backends x both orients."""
        n, W, S = 60, 150, 50
        src, dst = stream(1, n, 450)
        censuses = {}
        for incremental in (True, False):
            mon = TriadMonitor(n, window=W, stride=S, history=2,
                               backend=backend, orient=orient,
                               incremental=incremental)
            mon.observe(src, dst)
            censuses[incremental] = mon.censuses
        np.testing.assert_array_equal(censuses[True], censuses[False])
        np.testing.assert_array_equal(
            censuses[True][-1],
            direct_census(src, dst, n, 450 - W, 450,
                          backend=backend, orient=orient))

    def test_tumbling_equals_stride_eq_window(self):
        n, W = 80, 300
        src, dst = stream(2, n, 900)
        default = TriadMonitor(n, window=W)           # stride defaults to W
        explicit = TriadMonitor(n, window=W, stride=W)
        out_d = default.observe(src, dst)
        out_e = explicit.observe(src, dst)
        assert out_d.shape == (3, 16)
        np.testing.assert_array_equal(out_d, out_e)
        for k in range(3):
            np.testing.assert_array_equal(
                out_d[k], direct_census(src, dst, n, k * W, (k + 1) * W))

    def test_duplicate_and_self_loop_edges_collapse(self):
        n = 10
        src = np.array([1, 1, 1, 2, 3, 3])
        dst = np.array([2, 2, 1, 1, 4, 4])
        mon = TriadMonitor(n, window=6)
        out = mon.observe(src, dst)
        np.testing.assert_array_equal(
            out[0], direct_census(src, dst, n, 0, 6))

    def test_incremental_processes_fewer_items(self):
        n, W, S = 4000, 800, 80         # 10% stride on a sparse stream
        rng = np.random.default_rng(3)
        src = rng.integers(0, n, 2400)
        dst = rng.integers(0, n, 2400)
        mon = TriadMonitor(n, window=W, stride=S, history=2,
                           incremental=True, max_items=1024)
        mon.observe(src, dst)
        slid = mon.window_stats[1:]
        assert slid and all(s.items < s.full_items for s in slid)


# ------------------------------------------------------------ observe input


class TestObserveValidation:
    def test_empty_batch_raises(self):
        mon = TriadMonitor(10, window=5)
        with pytest.raises(ValueError, match="empty"):
            mon.observe([], [])

    def test_length_mismatch_raises(self):
        mon = TriadMonitor(10, window=5)
        with pytest.raises(ValueError, match="mismatch"):
            mon.observe([1, 2], [3])

    def test_out_of_range_raises(self):
        mon = TriadMonitor(10, window=5)
        with pytest.raises(ValueError, match="range"):
            mon.observe([1], [10])
        with pytest.raises(ValueError, match="range"):
            mon.observe([-1], [2])

    def test_2d_input_is_raveled(self):
        n = 12
        src = np.array([[1, 2], [3, 4]])
        dst = np.array([[5, 6], [7, 8]])
        mon = TriadMonitor(n, window=4)
        out = mon.observe(src, dst)
        np.testing.assert_array_equal(
            out[0], direct_census(src.ravel(), dst.ravel(), n, 0, 4))

    def test_rejects_bad_config(self):
        with pytest.raises(ValueError):
            TriadMonitor(0)
        with pytest.raises(ValueError):
            TriadMonitor(5, window=0)
        with pytest.raises(ValueError):
            TriadMonitor(5, window=10, stride=11)
        with pytest.raises(ValueError):
            TriadMonitor(5, window=10, stride=0)
        with pytest.raises(ValueError):
            TriadMonitor(5, window=10, history=0)

    def test_legacy_positional_signature(self):
        """(n_nodes, window, history, threshold) positionally — the seed's
        dataclass field order; stride is keyword-only."""
        mon = TriadMonitor(50, 100, 5, 2.5)
        assert (mon.window, mon.history, mon.threshold) == (100, 5, 2.5)
        assert mon.stride == mon.window          # tumbling default
        with pytest.raises(TypeError):
            TriadMonitor(50, 100, 5, 2.5, 10)    # no 5th positional

    def test_partial_window_emits_nothing(self):
        mon = TriadMonitor(10, window=100)
        out = mon.observe([1, 2], [3, 4])
        assert out.shape == (0, 16) and mon.censuses.shape == (0, 16)


# ------------------------------------------------------------ alarms


def scan_burst_stream(rng, n_hosts, per_window, n_windows, attack_windows,
                      n_targets=120):
    """The network_monitor example scenario: zipf background + injected
    port-scan bursts (021D fan-out) in the attack windows."""
    chunks_s, chunks_d = [], []
    for w in range(n_windows):
        k = per_window - (n_targets if w in attack_windows else 0)
        src = (rng.zipf(1.5, k) - 1) % n_hosts
        dst = rng.integers(0, n_hosts, k)
        back = rng.random(k) < 0.3
        src = np.concatenate([src[~back], dst[back]])
        dst = np.concatenate([dst[~back], src[:back.sum()]])
        if w in attack_windows:
            scanner = int(rng.integers(0, n_hosts))
            targets = rng.choice(n_hosts, size=n_targets, replace=False)
            src = np.concatenate([src, np.full(n_targets, scanner)])
            dst = np.concatenate([dst, targets])
        chunks_s.append(src[:per_window])
        chunks_d.append(dst[:per_window])
    return np.concatenate(chunks_s), np.concatenate(chunks_d)


class TestAlarms:
    def test_pattern_indices_match_names(self):
        for pattern, types in SECURITY_PATTERNS.items():
            np.testing.assert_array_equal(
                SECURITY_PATTERN_INDICES[pattern],
                [TRIAD_NAMES.index(t) for t in types])

    def test_scan_burst_fires_scanning_alarm(self):
        rng = np.random.default_rng(0)
        n_hosts, per_window = 200, 600
        attack = {14, 15}
        src, dst = scan_burst_stream(rng, n_hosts, per_window, 17, attack)
        mon = TriadMonitor(n_hosts, window=per_window, history=8,
                           threshold=4.0)
        mon.observe(src, dst)
        alarms = mon.alarms()
        flagged = {a["window"] for a in alarms
                   if a["pattern"] == "scanning"}
        assert attack <= flagged, (attack, alarms)
        false_pos = flagged - attack
        assert len(false_pos) <= 1, alarms

    def test_robust_baseline_survives_poisoned_history(self):
        """Median/MAD baseline: a minority of poisoned (attack-like)
        history windows must not suppress detection of the next attack
        (a mean/std baseline would absorb them)."""
        clean = np.zeros(16, np.int64)
        clean[1] = 900
        clean[3] = 10                    # steady small 021D share
        poisoned = clean.copy()
        poisoned[3] = 450                # attack-sized 021D share
        mon = TriadMonitor(10, window=5, history=8, threshold=4.0)
        for _ in range(6):
            mon.record(clean)
        for _ in range(2):
            mon.record(poisoned)         # minority poison in the baseline
        mon.record(poisoned)             # the attack window itself
        alarms = [a for a in mon.alarms()
                  if a["pattern"] == "scanning" and a["window"] == 8]
        assert alarms, mon.alarms()
        # and a fully clean window after the attack stays quiet
        mon.record(clean)
        assert not [a for a in mon.alarms() if a["window"] == 9]

    def test_alarm_cache_is_incremental_and_stable(self):
        rng = np.random.default_rng(4)
        n_hosts, per_window = 150, 400
        src, dst = scan_burst_stream(rng, n_hosts, per_window, 14, {11})
        fresh = TriadMonitor(n_hosts, window=per_window, history=6,
                             threshold=4.0)
        cached = TriadMonitor(n_hosts, window=per_window, history=6,
                              threshold=4.0)
        half = 7 * per_window
        cached.observe(src[:half], dst[:half])
        first = cached.alarms()
        assert cached.alarms() == first          # idempotent
        cached.observe(src[half:], dst[half:])
        fresh.observe(src, dst)
        assert cached.alarms() == fresh.alarms() # cache == full rescan

    def test_threshold_is_retunable_after_caching(self):
        """Scores are cached threshold-free: loosening the threshold after
        alarms() ran must surface alarms in already-evaluated windows."""
        rng = np.random.default_rng(6)
        n_hosts, per_window = 150, 400
        src, dst = scan_burst_stream(rng, n_hosts, per_window, 14, {11})
        mon = TriadMonitor(n_hosts, window=per_window, history=6,
                           threshold=1e9)
        mon.observe(src, dst)
        assert mon.alarms() == []                # nothing passes 1e9
        mon.threshold = 4.0
        fresh = TriadMonitor(n_hosts, window=per_window, history=6,
                             threshold=4.0)
        fresh.observe(src, dst)
        assert mon.alarms() == fresh.alarms() != []

    def test_proportions_cached_per_window(self):
        mon = TriadMonitor(10, window=5, history=2)
        c = np.zeros(16, np.int64)
        c[1], c[3] = 50, 25
        mon.record(c)
        props = mon.proportions()
        assert props.shape == (1, 16)
        np.testing.assert_allclose(props[0], c / 75.0)
        assert mon.proportions().shape == (0, 16) or True  # no mutation
        mon.record(c)
        assert mon.proportions().shape == (2, 16)
