"""Persistent pair-space index: delta edits vs the full-rebuild oracle.

The central property: after ANY stream of deltas, the index's edited
:class:`PairSpace` is bit-identical — array for array, dtype for dtype —
to ``pair_space(g_new)`` rebuilt from scratch, its affected-pair answers
match the O(P) scan, its maintained costs match a fresh recount, and a
session opened with ``index=True`` produces the exact censuses of the
``index=False`` oracle across emit modes and partition layouts.  Plus
the corruption contract: a stale or externally-mutated index raises
:class:`IndexCorruptionError` instead of planning from drifted state.
"""

import numpy as np
import pytest

from repro.core import (
    CensusEngine, IndexCorruptionError, PairSpaceIndex, apply_delta,
    census_batagelj_mrvar, default_mesh, from_edges, pair_space,
    subset_descriptor_windows)
from repro.core.digraph import SplicePlan
from repro.core.incremental import affected_pair_ids
from repro.core.planner import postprune_pair_counts


def random_graph(rng, n=None, p=None):
    n = n or int(rng.integers(3, 40))
    a = rng.random((n, n)) < (p or float(rng.uniform(0.05, 0.4)))
    np.fill_diagonal(a, False)
    return from_edges(*np.nonzero(a), n=n), a


def random_arcs(rng, n, k):
    return rng.integers(0, n, k), rng.integers(0, n, k)


#: PairSpace array fields whose exact (value + dtype) equality defines
#: "bit-identical to the rebuild"
SPACE_ARRAYS = ("indptr", "packed", "nbr", "deg", "pair_u", "pair_v",
                "pair_code", "counts", "offsets", "pair_term", "pair_mut")


def assert_space_equal(got, want):
    assert got.n == want.n
    assert got.orient == want.orient
    assert got.prune_self == want.prune_self
    assert got.max_degree == want.max_degree
    assert got.search_iters == want.search_iters
    for name in SPACE_ARRAYS:
        a, b = getattr(got, name), getattr(want, name)
        assert a.dtype == b.dtype, f"{name}: {a.dtype} != {b.dtype}"
        np.testing.assert_array_equal(a, b, err_msg=name)


def assert_index_matches_rebuild(index, g):
    """Full parity bundle: space, affected ids, costs, self-check."""
    want = pair_space(g, orient=index.space.orient,
                      prune_self=index.space.prune_self)
    assert_space_equal(index.space, want)
    np.testing.assert_array_equal(index.costs,
                                  postprune_pair_counts(want))
    index.verify(g)


# ------------------------------------------------------- delta-edit parity


class TestSplicePlan:
    def test_matches_delete_insert(self):
        """The shared-permutation splice is exactly np.delete followed by
        np.insert, for any mix of deletions and (possibly duplicated)
        insertion points, including empty and fully-deleted arrays."""
        rng = np.random.default_rng(5)
        for _ in range(50):
            num = int(rng.integers(0, 30))
            arr = rng.integers(0, 1000, num)
            n_del = int(rng.integers(0, num + 1))
            del_pos = np.sort(rng.choice(num, n_del, replace=False)
                              ).astype(np.int64) if num else \
                np.zeros(0, np.int64)
            ins_pos = np.sort(rng.integers(0, num + 1,
                                           int(rng.integers(0, 6))))
            vals = rng.integers(0, 1000, ins_pos.shape[0])
            want = np.delete(arr, del_pos)
            want = np.insert(want,
                             ins_pos - np.searchsorted(del_pos, ins_pos),
                             vals)
            plan = SplicePlan(num, del_pos, ins_pos.astype(np.int64))
            got = plan.splice(arr, vals)
            assert got.dtype == arr.dtype
            np.testing.assert_array_equal(got, want)
            # surviving positions re-address to their post-splice slots
            keep = np.setdiff1d(np.arange(num), del_pos)
            if keep.size:
                np.testing.assert_array_equal(
                    got[plan.readdress(keep)], arr[keep])


class TestIndexParity:
    @pytest.mark.parametrize("orient", ["none", "degree"])
    @pytest.mark.parametrize("seed", range(6))
    def test_random_churn_stream(self, seed, orient):
        """Adds + removes interleaved over many steps — the index never
        drifts from the from-scratch rebuild."""
        rng = np.random.default_rng(seed)
        g, _ = random_graph(rng)
        index = PairSpaceIndex(g, orient=orient)
        for _ in range(6):
            add = random_arcs(rng, g.n, int(rng.integers(0, 20)))
            rem = random_arcs(rng, g.n, int(rng.integers(0, 20)))
            g2, delta = apply_delta(g, *add, *rem)
            space = index.apply(delta, g2)
            assert space is index.space
            assert_index_matches_rebuild(index, g2)
            # affected-id parity against the O(P) oracle, both via the
            # index method and via the dispatching module function
            want_aff = affected_pair_ids(space, delta.touched)
            np.testing.assert_array_equal(
                index.affected_pair_ids(delta.touched), want_aff)
            np.testing.assert_array_equal(
                affected_pair_ids(index, delta.touched), want_aff)
            g = g2

    @pytest.mark.parametrize("orient", ["none", "degree"])
    def test_hub_turnover(self, orient):
        """Deleting and re-wiring a hub vertex churns a large fraction of
        the pair space at once — the splice path's bulk case."""
        rng = np.random.default_rng(99)
        n = 30
        src = np.concatenate([np.zeros(n - 1, np.int64),
                              rng.integers(0, n, 40)])
        dst = np.concatenate([np.arange(1, n, dtype=np.int64),
                              rng.integers(0, n, 40)])
        g = from_edges(src, dst, n=n)
        index = PairSpaceIndex(g, orient=orient)
        # retire hub 0 entirely, crown vertex 1 the new hub
        g2, delta = apply_delta(
            g, np.full(n - 2, 1), np.arange(2, n),
            np.zeros(n - 1, np.int64), np.arange(1, n))
        index.apply(delta, g2)
        assert_index_matches_rebuild(index, g2)
        # and tear the new hub down again
        g3, delta3 = apply_delta(g2, [], [], np.full(n - 2, 1),
                                 np.arange(2, n))
        index.apply(delta3, g3)
        assert_index_matches_rebuild(index, g3)

    def test_empty_delta_is_noop(self):
        g = from_edges([0, 1, 2], [1, 2, 0], n=5)
        g2, delta = apply_delta(g, [0], [1])     # already present
        assert g2 is g and delta.num_changed == 0
        index = PairSpaceIndex(g)
        space_before = index.space
        assert index.apply(delta, g2) is space_before
        assert_index_matches_rebuild(index, g)

    def test_grow_from_empty_and_back(self):
        """The structural edge cases: a graph with no arcs at all on
        either side of the delta."""
        g = from_edges([], [], n=6)
        index = PairSpaceIndex(g)
        assert index.space.num_pairs == 0
        g2, delta = apply_delta(g, [0, 1, 4], [1, 2, 5])
        index.apply(delta, g2)
        assert_index_matches_rebuild(index, g2)
        g3, delta3 = apply_delta(g2, [], [], [0, 1, 4], [1, 2, 5])
        index.apply(delta3, g3)
        assert index.space.num_pairs == 0
        assert_index_matches_rebuild(index, g3)

    def test_prebuilt_space_reuse(self):
        g = from_edges([0, 1], [1, 2], n=4)
        space = pair_space(g, orient="degree")
        index = PairSpaceIndex(g, orient="degree", space=space)
        assert index.space is space
        with pytest.raises(ValueError):
            PairSpaceIndex(g, orient="none", space=space)

    def test_subset_descriptor_windows_accepts_index(self):
        rng = np.random.default_rng(7)
        g, _ = random_graph(rng, n=20, p=0.3)
        index = PairSpaceIndex(g)
        ids = np.arange(min(5, index.space.num_pairs))
        via_index = list(subset_descriptor_windows(index, ids, 64, 8, 1))
        via_space = list(subset_descriptor_windows(index.space, ids,
                                                   64, 8, 1))
        assert len(via_index) == len(via_space)
        for a, b in zip(via_index, via_space):
            assert (a.start, a.stop, a.num_descs) == \
                (b.start, b.stop, b.num_descs)
            np.testing.assert_array_equal(a.desc_pair, b.desc_pair)


# ------------------------------------------------------------- corruption


class TestCorruption:
    def test_external_mutation_detected(self):
        rng = np.random.default_rng(3)
        g, _ = random_graph(rng, n=15, p=0.3)
        index = PairSpaceIndex(g)
        index.verify(g)
        index.space.packed[0] ^= 1      # bit rot / external mutation
        with pytest.raises(IndexCorruptionError):
            index.verify()

    def test_wrong_graph_detected(self):
        rng = np.random.default_rng(4)
        g1, _ = random_graph(rng, n=15, p=0.3)
        g2, _ = random_graph(rng, n=15, p=0.3)
        index = PairSpaceIndex(g1)
        with pytest.raises(IndexCorruptionError):
            index.verify(g2)

    def test_stale_delta_detected(self):
        """A delta computed against a DIFFERENT graph state must not be
        silently applied — its old codes disagree with the tracked ones."""
        rng = np.random.default_rng(5)
        g, _ = random_graph(rng, n=15, p=0.3)
        index = PairSpaceIndex(g)
        g2, delta = apply_delta(g, *random_arcs(rng, g.n, 8))
        index.apply(delta, g2)
        with pytest.raises(IndexCorruptionError):
            index.apply(delta, g2)       # applying the same delta twice

    def test_key_cache_drift_detected(self):
        g = from_edges([0, 1], [1, 2], n=4)
        index = PairSpaceIndex(g)
        index._keys = index._keys.copy()
        index._keys[0] += 1
        with pytest.raises(IndexCorruptionError):
            index.verify()


# ------------------------------------------------------- session parity

#: pallas backends run interpret-mode kernels per dispatch on CPU — they
#: sweep fewer delta steps than the pure-XLA backend
SESSION_STEPS = {"jnp": 4, "pallas": 2, "pallas-fused": 2}


def _delta_stream(rng, g, steps):
    """Yield (add, rem) batches including an empty-churn step."""
    for i in range(steps):
        if i == 1:
            yield ([], []), ([], [])      # empty delta mid-stream
            continue
        yield (random_arcs(rng, g.n, int(rng.integers(1, 10))),
               random_arcs(rng, g.n, int(rng.integers(1, 10))))


class TestSessionParity:
    @pytest.mark.parametrize("emit", ["device", "host"])
    @pytest.mark.parametrize("orient", ["none", "degree"])
    def test_plain_session_matches_oracle(self, orient, emit):
        """index=True census == index=False census == reference, every
        step — the plain-session acceptance property."""
        rng = np.random.default_rng(11)
        g, _ = random_graph(rng, n=26, p=0.18)
        engine = CensusEngine(backend="jnp")
        live = engine.session(g, orient=orient, max_items=64, emit=emit,
                              index=True)
        oracle = engine.session(g, orient=orient, max_items=64, emit=emit,
                                index=False)
        np.testing.assert_array_equal(live.census(), oracle.census())
        g_cur = g
        for add, rem in _delta_stream(rng, g, 4):
            got = live.update(*add, *rem)
            want = oracle.update(*add, *rem)
            np.testing.assert_array_equal(got, want)
            # the maintained cost vector answers the post-prune item
            # stat; it must equal the oracle's full recompute
            assert live.stats.full_items == oracle.stats.full_items
            g_cur, _ = apply_delta(g_cur, *add, *rem)
        np.testing.assert_array_equal(got, census_batagelj_mrvar(g_cur))
        assert live.stats.indexed and not oracle.stats.indexed

    @pytest.mark.parametrize("mesh_shape", [None, (2, 2)])
    def test_partitioned_session_matches_oracle(self, mesh_shape):
        """1D (mesh_shape None) and 2D partitioned sessions: the index
        routes owner shards identically to the rebuild path."""
        rng = np.random.default_rng(13)
        g, _ = random_graph(rng, n=24, p=0.2)
        kw = (dict(partition_2d=mesh_shape) if mesh_shape
              else dict(partition=True))
        sessions = []
        for index in (True, False):
            engine = CensusEngine(mesh=default_mesh(4), backend="jnp",
                                  **kw)
            sessions.append(engine.session(g, max_items=64, index=index))
        live, oracle = sessions
        np.testing.assert_array_equal(live.census(), oracle.census())
        g_cur = g
        for add, rem in _delta_stream(rng, g, 3):
            got = live.update(*add, *rem)
            np.testing.assert_array_equal(got, oracle.update(*add, *rem))
            assert live.stats.full_items == oracle.stats.full_items
            g_cur, _ = apply_delta(g_cur, *add, *rem)
        np.testing.assert_array_equal(got, census_batagelj_mrvar(g_cur))

    def test_host_phase_timing_reported(self):
        rng = np.random.default_rng(17)
        g, _ = random_graph(rng, n=24, p=0.2)
        session = CensusEngine(backend="jnp").session(g, max_items=64)
        session.census()
        assert session.stats.host_pair_seconds > 0        # space build
        session.update(*random_arcs(rng, g.n, 5),
                       *random_arcs(rng, g.n, 5))
        st = session.stats
        assert st.indexed
        assert st.host_merge_seconds > 0                  # apply_delta
        assert st.plan_host_seconds == pytest.approx(
            st.host_pair_seconds + st.host_merge_seconds
            + st.host_emit_seconds)
        assert "host[" in st.summary()
