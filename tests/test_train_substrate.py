"""Optimizer, checkpoint/restore (incl. resharding), fault coordinator,
data pipeline determinism."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.data.pipeline import DataConfig, TokenPipeline, host_shard
from repro.train.checkpoint import CheckpointManager
from repro.train.fault import Coordinator, StragglerDetector, Watchdog
from repro.train.optimizer import (
    OptConfig, apply_update, global_norm, init_state, schedule)


class TestOptimizer:
    def test_adamw_reduces_quadratic(self):
        params = {"w": jnp.ones(8) * 5.0}
        cfg = OptConfig(lr=0.1, warmup_steps=0, total_steps=100,
                        weight_decay=0.0)
        state = init_state(params)
        for _ in range(60):
            grads = {"w": 2 * params["w"]}
            params, state, m = apply_update(cfg, params, grads, state)
        assert float(jnp.abs(params["w"]).max()) < 1.0
        assert int(state["step"]) == 60

    def test_clip(self):
        params = {"w": jnp.zeros(4)}
        cfg = OptConfig(lr=1e-3, clip_norm=1.0, warmup_steps=0)
        state = init_state(params)
        grads = {"w": jnp.full(4, 1e6)}
        _, _, m = apply_update(cfg, params, grads, state)
        assert float(m["grad_norm"]) > 1e5  # pre-clip norm reported

    def test_schedule_warmup_and_decay(self):
        cfg = OptConfig(lr=1.0, warmup_steps=10, total_steps=110,
                        min_lr_ratio=0.1)
        assert float(schedule(cfg, jnp.asarray(5))) == pytest.approx(0.5)
        assert float(schedule(cfg, jnp.asarray(10))) == pytest.approx(1.0)
        end = float(schedule(cfg, jnp.asarray(110)))
        assert end == pytest.approx(0.1, abs=1e-3)

    def test_lion(self):
        params = {"w": jnp.ones(8) * 5.0}
        cfg = OptConfig(lr=0.05, warmup_steps=0, kind="lion",
                        weight_decay=0.0)
        state = init_state(params)
        for _ in range(80):
            params, state, _ = apply_update(
                cfg, params, {"w": 2 * params["w"]}, state)
        assert float(jnp.abs(params["w"]).max()) < 1.5


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        mgr = CheckpointManager(tmp_path, keep=2)
        state = {"params": {"w": jnp.arange(12.0).reshape(3, 4)},
                 "step": jnp.asarray(7)}
        mgr.save(7, state)
        skeleton = jax.tree.map(lambda a: np.zeros_like(a), state)
        restored, step = mgr.restore(skeleton)
        assert step == 7
        np.testing.assert_array_equal(
            np.asarray(restored["params"]["w"]),
            np.arange(12.0).reshape(3, 4))

    def test_retention_and_latest(self, tmp_path):
        mgr = CheckpointManager(tmp_path, keep=2)
        for s in (1, 2, 3):
            mgr.save(s, {"x": jnp.asarray(s)})
        assert mgr.all_steps() == [2, 3]
        assert mgr.latest_step() == 3

    def test_restore_to_mesh(self, tmp_path):
        mgr = CheckpointManager(tmp_path)
        n = len(jax.devices())
        arr = jnp.arange(4 * n, dtype=jnp.float32).reshape(n, 4)
        mgr.save(1, {"w": arr})
        from jax.sharding import NamedSharding, PartitionSpec as P
        mesh = jax.make_mesh((n,), ("data",),
                             axis_types=(jax.sharding.AxisType.Auto,))
        sh = {"w": NamedSharding(mesh, P("data", None))}
        restored, _ = mgr.restore({"w": np.zeros((n, 4))}, shardings=sh)
        np.testing.assert_array_equal(np.asarray(restored["w"]),
                                      np.asarray(arr))
        assert len(restored["w"].sharding.device_set) == n

    def test_async_save(self, tmp_path):
        mgr = CheckpointManager(tmp_path)
        fut = mgr.save_async(5, {"x": jnp.ones(3)})
        fut.result(timeout=30)
        assert mgr.latest_step() == 5


class TestFault:
    def _mk(self, tmp_path, fail_at=None):
        calls = {"n": 0}

        def step_fn(state, batch):
            if fail_at and state["step"] == fail_at and calls["n"] == 0:
                calls["n"] += 1
                raise RuntimeError("injected node failure")
            return ({"acc": state["acc"] + batch["tokens"].sum(),
                     "step": state["step"] + 1}, {"loss": 1.0})

        pipe = TokenPipeline(DataConfig(vocab_size=97, batch=2, seq_len=8))
        mgr = CheckpointManager(tmp_path, keep=3)
        return step_fn, pipe, mgr

    def test_recovery_replays_exactly(self, tmp_path):
        step_fn, pipe, mgr = self._mk(tmp_path, fail_at=7)
        batch_fn = lambda s: pipe.batch_at(s)
        coord = Coordinator(
            lambda st, b: step_fn(st, b), batch_fn, mgr, ckpt_every=5)
        state0 = {"acc": np.int64(0), "step": np.int64(0)}
        final, last, hist = coord.run(dict(state0), 0, 12)
        assert coord.failures == 1 and len(coord.restarts) == 1
        # reference run without failure
        step_ok, pipe2, mgr2 = self._mk(tmp_path / "ref")
        coord2 = Coordinator(step_ok, batch_fn, mgr2, ckpt_every=5)
        ref, _, _ = coord2.run(dict(state0), 0, 12)
        assert int(final["acc"]) == int(ref["acc"])

    def test_too_many_failures_raises(self, tmp_path):
        pipe = TokenPipeline(DataConfig(vocab_size=7, batch=1, seq_len=4))
        mgr = CheckpointManager(tmp_path)
        def bad(state, batch):
            raise RuntimeError("permafail")
        coord = Coordinator(bad, pipe.batch_at, mgr, max_failures=2)
        with pytest.raises(RuntimeError):
            coord.run({"step": 0}, 0, 5)

    def test_straggler_detection(self):
        det = StragglerDetector(factor=2.0)
        for i in range(20):
            det.observe(i, 1.0)
        assert det.observe(20, 5.0) is True
        assert det.events and det.events[0]["step"] == 20

    def test_watchdog(self):
        wd = Watchdog(timeout_s=0.2)
        wd.start()
        import time
        time.sleep(0.6)
        assert wd.fired
        wd.stop()


class TestPipeline:
    def test_determinism(self):
        cfg = DataConfig(vocab_size=1000, batch=4, seq_len=16, seed=3)
        p1, p2 = TokenPipeline(cfg), TokenPipeline(cfg)
        for s in (0, 5, 99):
            b1, b2 = p1.batch_at(s), p2.batch_at(s)
            np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
        assert not np.array_equal(p1.batch_at(0)["tokens"],
                                  p1.batch_at(1)["tokens"])

    def test_labels_shift(self):
        cfg = DataConfig(vocab_size=50, batch=2, seq_len=8)
        b = TokenPipeline(cfg).batch_at(0)
        assert b["tokens"].shape == (2, 8) and b["labels"].shape == (2, 8)

    def test_prefetch_iterator_resume(self):
        cfg = DataConfig(vocab_size=100, batch=2, seq_len=4)
        pipe = TokenPipeline(cfg)
        it = pipe.iterate(start_step=10)
        step, batch = next(it)
        assert step == 10
        np.testing.assert_array_equal(batch["tokens"],
                                      pipe.batch_at(10)["tokens"])
        it.close()

    def test_host_shard(self):
        b = {"tokens": np.arange(8)[:, None]}
        s0 = host_shard(b, 0, 2)["tokens"]
        s1 = host_shard(b, 1, 2)["tokens"]
        assert s0.shape[0] == 4 and s1[0, 0] == 4
