"""Test harness setup.

* Forces 8 virtual CPU devices (before the first jax import) so the
  mesh/sharding/distributed suites exercise real multi-device code paths
  on the single-core CPU host.
* Imports :mod:`repro.compat`, which installs forward-compat aliases
  (``jax.shard_map``, ``jax.sharding.AxisType``, ``make_mesh`` accepting
  ``axis_types``) on older jax releases — the suites are written against
  the modern API.
"""

import os
import sys

if "jax" not in sys.modules:
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            flags + " --xla_force_host_platform_device_count=8").strip()

import repro.compat  # noqa: E402,F401
