"""Per-architecture smoke tests: reduced configs, one forward + one train
step on CPU, asserting output shapes and finiteness (no NaNs)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import all_configs, get_config
from repro.models.model import (
    decode_step, init_cache, layer_groups, loss_fn, make_params,
    count_params, forward)
from repro.models.common import pad_vocab

ARCHS = sorted(all_configs())

B, S = 2, 32


def make_batch(cfg, rng, b=B, s=S):
    batch = {
        "tokens": jnp.asarray(
            rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32),
        "labels": jnp.asarray(
            rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32),
    }
    if cfg.is_encdec:
        batch["src_embeds"] = jnp.asarray(
            rng.normal(size=(b, s // 2, cfg.d_model)), jnp.bfloat16)
    if cfg.modality == "vlm":
        batch["vision_embeds"] = jnp.asarray(
            rng.normal(size=(b, s, cfg.d_model)), jnp.bfloat16)
        batch["vision_mask"] = jnp.asarray(
            rng.random((b, s)) < 0.25)
        pos = np.broadcast_to(np.arange(s, dtype=np.int32), (3, b, s))
        batch["positions3"] = jnp.asarray(pos)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_train_step(arch):
    cfg = get_config(arch).reduced()
    rng = np.random.default_rng(0)
    params = make_params(cfg, seed=0)
    batch = make_batch(cfg, rng)

    x, metrics, _ = forward(cfg, params, batch, q_chunk=16, rec_chunk=8)
    assert x.shape == (B, S, cfg.d_model)
    assert bool(jnp.isfinite(x.astype(jnp.float32)).all())

    (loss, m), grads = jax.value_and_grad(
        lambda p: loss_fn(cfg, p, batch, q_chunk=16, rec_chunk=8),
        has_aux=True)(params)
    assert bool(jnp.isfinite(loss)), arch
    gnorms = jax.tree.map(
        lambda g: bool(jnp.isfinite(g.astype(jnp.float32)).all()), grads)
    assert all(jax.tree.leaves(gnorms)), arch
    # at least one nonzero gradient
    total = sum(float(jnp.sum(jnp.abs(g.astype(jnp.float32))))
                for g in jax.tree.leaves(grads))
    assert total > 0, arch


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step(arch):
    cfg = get_config(arch).reduced()
    rng = np.random.default_rng(1)
    params = make_params(cfg, seed=1)
    cache = init_cache(cfg, batch=B, seq_len=S,
                       src_len=S // 2 if cfg.is_encdec else 0)
    tok = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, 1)), jnp.int32)
    logits, cache = decode_step(cfg, params, tok, cache)
    vp = pad_vocab(cfg.vocab_size)
    assert logits.shape == (B, 1, vp)
    real = logits[..., :cfg.vocab_size].astype(jnp.float32)
    assert bool(jnp.isfinite(real).all()), arch
    assert int(cache["pos"]) == 1
    # padded vocab is masked out (when padding exists)
    if vp > cfg.vocab_size:
        assert float(logits[..., cfg.vocab_size:].max()) < -1e30

    logits2, cache = decode_step(cfg, params, tok, cache)
    assert int(cache["pos"]) == 2
    assert bool(jnp.isfinite(
        logits2[..., :cfg.vocab_size].astype(jnp.float32)).all())


@pytest.mark.parametrize("arch", ARCHS)
def test_layer_grouping_covers_all_layers(arch):
    cfg = get_config(arch)
    groups = layer_groups(cfg)
    total = sum(len(chunk) * reps for chunk, reps in groups)
    assert total == cfg.num_layers, (arch, groups)


@pytest.mark.parametrize("arch", ARCHS)
def test_param_count_sane(arch):
    cfg = get_config(arch)
    n = count_params(cfg)
    # the arch ids carry rough sizes; allow generous bounds (vocab padding,
    # backbone-only for audio/vlm)
    expected = {
        "nemotron-4-15b": (12e9, 18e9),
        "qwen2-0.5b": (0.3e9, 0.8e9),
        "qwen2.5-32b": (28e9, 36e9),
        "stablelm-12b": (10e9, 14e9),
        "xlstm-1.3b": (0.9e9, 1.9e9),
        "seamless-m4t-medium": (0.5e9, 1.8e9),
        "qwen2-vl-2b": (1.2e9, 2.4e9),
        "granite-moe-3b-a800m": (2.2e9, 4.2e9),
        "deepseek-moe-16b": (13e9, 20e9),
        "recurrentgemma-2b": (2.0e9, 3.6e9),
    }[arch]
    assert expected[0] < n < expected[1], (arch, n)
