"""Device-side work-item emission: descriptor windows, in-kernel
pair→item expansion, and the ``emit="device"`` engine/session paths.

The central property: the device-emission census — host ships O(pairs)
descriptors, the kernel expands each flat index back to its work item and
applies the pruning predicate in place — is bit-identical to host
emission for every backend, both orient modes, any chunk budget, and all
three execution paths (full runs, streamed chunks, incremental updates),
while shipping far fewer host→device plan bytes.
"""

import numpy as np
import pytest

from repro.core import (
    CensusEngine, PlanChunker, apply_delta, census_batagelj_mrvar,
    default_mesh, descriptor_window, from_edges, iter_descriptor_windows,
    pair_space, scale_free_digraph, triad_census_graph)
from repro.core.planner import (
    DESC_ANCHOR_STRIDE, DESC_CUM_PAD, emit_items, num_desc_anchors,
    prune_items)


def hub_graph(n=24, hub_out=16, extra=40, seed=0):
    """Graph with a guaranteed hub pair costing > hub_out items."""
    rng = np.random.default_rng(seed)
    src = [0] * hub_out + list(rng.integers(0, n, extra))
    dst = list(range(1, hub_out + 1)) + list(rng.integers(0, n, extra))
    return from_edges(src, dst, n=max(n, hub_out + 1))


def expand_window_np(space, win):
    """Numpy reference of the device expansion (including the anchored
    search bound), returning the window's PRUNED (pair, slot, side)."""
    nd = win.num_descs
    cum = win.desc_cum[:nd].astype(np.int64)
    idx = np.arange(win.num_preprune, dtype=np.int64)
    d = np.searchsorted(cum, idx, side="right") - 1
    # the anchored range must always contain the true descriptor
    a = idx // DESC_ANCHOR_STRIDE
    lo_d = win.anchors[a].astype(np.int64)
    assert (d >= lo_d).all()
    assert (d < lo_d + DESC_ANCHOR_STRIDE // 2 + 1).all()
    pair = win.desc_pair[d].astype(np.int64)
    within = win.desc_within0[d] + idx - cum[d]
    u = space.pair_u[pair]
    deg_u = space.deg[u]
    side = (within >= deg_u).astype(np.int8)
    slot = np.where(side == 0, space.indptr[u] + within,
                    space.indptr[space.pair_v[pair]] + within - deg_u)
    return prune_items(space, pair, slot, side)


# --------------------------------------------------------- descriptors


class TestDescriptorWindows:
    @pytest.mark.parametrize("orient", ["none", "degree"])
    @pytest.mark.parametrize("max_items", [3, 17, 101, 10**6])
    def test_expansion_partitions_the_item_space(self, orient, max_items):
        """Expanding every chunk's descriptor window reproduces exactly
        the host planner's emitted items, chunk by chunk."""
        g = hub_graph()
        ck = PlanChunker(g, max_items, orient=orient)
        for k in range(ck.num_chunks):
            win = ck.descriptors(k)
            got = expand_window_np(ck.space, win)
            want = emit_items(ck.space, win.start, win.stop)
            for a, b in zip(got, want):
                np.testing.assert_array_equal(a, b)

    def test_padding_and_shapes(self):
        g = hub_graph(seed=2)
        ck = PlanChunker(g, 37)
        for k in range(ck.num_chunks):
            win = ck.descriptors(k)
            assert win.desc_pair.shape == (ck.desc_shape,)
            assert win.anchors.shape == (ck.num_anchors,)
            assert (win.desc_cum[win.num_descs:] == DESC_CUM_PAD).all()
            assert (win.desc_pair[win.num_descs:] == 0).all()
            words = win.device_words()
            assert words.shape == (1 + 3 * ck.desc_shape
                                   + ck.num_anchors,)
            assert words[0] == win.num_preprune

    def test_hub_pair_spans_three_plus_chunks(self):
        """A hub pair split across >= 3 chunks surfaces as the SAME pair
        id in consecutive windows with advancing within-pair offsets —
        the intra-pair split expressed as offset windows."""
        g = hub_graph(hub_out=16)
        ck = PlanChunker(g, max_items=4)
        seen = {}             # pair id -> list of (chunk, within0)
        for k in range(ck.num_chunks):
            win = ck.descriptors(k)
            for j in range(win.num_descs):
                seen.setdefault(int(win.desc_pair[j]), []).append(
                    (k, int(win.desc_within0[j])))
        split = {p: v for p, v in seen.items() if len(v) >= 3}
        assert split, "no pair spanned >= 3 chunks"
        for spans in split.values():
            w0 = [w for _, w in spans]
            assert w0[0] == 0 and all(b > a for a, b in zip(w0, w0[1:]))

    def test_subset_windows_respect_both_caps(self):
        from repro.core import subset_descriptor_windows
        g = scale_free_digraph(n=80, avg_degree=5, exponent=2.2,
                               mutual_p=0.3, seed=11)
        space = pair_space(g)
        ids = np.arange(0, space.num_pairs, 2)
        total = int(space.counts[ids].sum())
        wins = list(subset_descriptor_windows(space, ids, 64, 8,
                                              num_desc_anchors(64)))
        assert sum(w.num_preprune for w in wins) == total
        assert all(w.num_preprune <= 64 for w in wins)
        assert all(w.num_descs <= 8 for w in wins)
        # windows tile the subset space exactly
        stops = [w.stop for w in wins]
        starts = [w.start for w in wins]
        assert starts[0] == 0 and stops[-1] == total
        assert starts[1:] == stops[:-1]

    def test_window_bounds_validated(self):
        space = pair_space(hub_graph())
        with pytest.raises(ValueError, match="outside"):
            descriptor_window(space.offsets, 0,
                              space.num_items_preprune + 1, 10**6,
                              num_desc_anchors(64))
        with pytest.raises(ValueError, match="desc_shape"):
            descriptor_window(space.offsets, 0,
                              space.num_items_preprune, 1,
                              num_desc_anchors(64))

    def test_empty_window(self):
        space = pair_space(hub_graph())
        win = descriptor_window(space.offsets, 5, 5, 4,
                                num_desc_anchors(16))
        assert win.num_descs == 0 and win.num_preprune == 0


# ------------------------------------------------------------- engines


class TestDeviceEmitParity:
    @pytest.mark.parametrize("orient", ["none", "degree"])
    @pytest.mark.parametrize("backend", ["jnp", "pallas", "pallas-fused"])
    def test_run_matches_oracle(self, orient, backend):
        g = scale_free_digraph(n=60, avg_degree=5, exponent=2.2,
                               mutual_p=0.3, seed=5)
        want = census_batagelj_mrvar(g)
        for max_items in (None, 64):
            engine = CensusEngine(backend=backend)   # emit="device"
            got = engine.run(g, max_items=max_items, orient=orient)
            np.testing.assert_array_equal(got, want)
            assert engine.stats.emit == "device"

    @pytest.mark.parametrize("orient", ["none", "degree"])
    def test_device_counts_match_host_schedule(self, orient):
        """Device-counted valid items per chunk equal the host plan's
        post-prune counts — same schedule, same numbers, no host items."""
        g = scale_free_digraph(n=100, avg_degree=6, exponent=2.2,
                               mutual_p=0.3, seed=6)
        dev = CensusEngine(backend="jnp", emit="device")
        host = CensusEngine(backend="jnp", emit="host")
        c_dev = dev.run(g, max_items=200, orient=orient)
        c_host = host.run(g, max_items=200, orient=orient)
        np.testing.assert_array_equal(c_dev, c_host)
        assert dev.stats.chunk_items == host.stats.chunk_items
        assert dev.stats.items == host.stats.items
        assert dev.stats.plan_upload_bytes < host.stats.plan_upload_bytes

    def test_mesh_device_emit(self):
        g = scale_free_digraph(n=50, avg_degree=5, exponent=2.2,
                               mutual_p=0.3, seed=8)
        want = census_batagelj_mrvar(g)
        got = triad_census_graph(g, mesh=default_mesh(), max_items=128)
        np.testing.assert_array_equal(got, want)

    def test_progress_hook_reports_device_counts(self):
        g = hub_graph(seed=3)
        seen = []
        engine = CensusEngine(backend="jnp")
        engine.run(g, max_items=50,
                   progress=lambda k, total, items: seen.append(
                       (k, total, items)))
        assert [k for k, _, _ in seen] == list(range(len(seen)))
        assert [i for _, _, i in seen] == engine.stats.chunk_items

    def test_zero_item_pairs(self):
        """A single mutual dyad: every pre-prune item is a self item, so
        the device dispatches a window whose keep count is zero and the
        census resolves from the closed forms — bit-identical to host."""
        g = from_edges([0, 1], [1, 0], n=5)
        want = census_batagelj_mrvar(g)
        for emit in ("device", "host"):
            engine = CensusEngine(backend="jnp", emit=emit)
            got = engine.run(g)
            np.testing.assert_array_equal(got, want)
            assert engine.stats.items == 0
        # device mode also agrees on the fused backend
        engine = CensusEngine(backend="pallas-fused")
        np.testing.assert_array_equal(engine.run(g), want)

    def test_empty_graph(self):
        g = from_edges(np.zeros(0, np.int64), np.zeros(0, np.int64), n=6)
        engine = CensusEngine(backend="jnp")
        got = engine.run(g)
        want = np.zeros(16, np.int64)
        want[0] = 6 * 5 * 4 // 6
        np.testing.assert_array_equal(got, want)
        assert engine.stats.chunks == 0

    def test_unknown_emit_rejected(self):
        with pytest.raises(ValueError, match="emit"):
            CensusEngine(emit="telepathy")
        with pytest.raises(ValueError, match="emit"):
            CensusEngine().run(hub_graph(), emit="telepathy")


# ------------------------------------------------------------ sessions


def random_arcs(rng, n, k):
    return rng.integers(0, n, k), rng.integers(0, n, k)


class TestDeviceEmitSession:
    @pytest.mark.parametrize("backend", ["jnp", "pallas-fused"])
    @pytest.mark.parametrize("orient", ["none", "degree"])
    def test_updates_match_oracle(self, backend, orient):
        rng = np.random.default_rng(13)
        g = scale_free_digraph(n=40, avg_degree=4, exponent=2.2,
                               mutual_p=0.3, seed=13)
        session = CensusEngine(backend=backend).session(
            g, orient=orient, max_items=128)
        assert session.emit == "device"
        np.testing.assert_array_equal(session.census(),
                                      census_batagelj_mrvar(g))
        for _ in range(3):
            add, rem = random_arcs(rng, g.n, 6), random_arcs(rng, g.n, 6)
            got = session.update(*add, *rem)
            g, _ = apply_delta(g, *add, *rem)
            np.testing.assert_array_equal(got, census_batagelj_mrvar(g))

    def test_device_session_matches_host_session_stats(self):
        rng = np.random.default_rng(17)
        g = scale_free_digraph(n=60, avg_degree=5, exponent=2.2,
                               mutual_p=0.3, seed=17)
        add, rem = random_arcs(rng, g.n, 10), random_arcs(rng, g.n, 10)
        out = {}
        for emit in ("host", "device"):
            s = CensusEngine(backend="jnp", emit=emit).session(
                g, max_items=256)
            c0 = s.census()
            c1 = s.update(*add, *rem)
            out[emit] = (c0, c1, s.stats.items, s.stats.full_items)
        np.testing.assert_array_equal(out["host"][0], out["device"][0])
        np.testing.assert_array_equal(out["host"][1], out["device"][1])
        # device-counted subset items equal the host emission's count
        assert out["host"][2] == out["device"][2]
        assert out["host"][3] == out["device"][3]

    def test_empty_delta_short_circuits_without_dispatch(self, monkeypatch):
        """A no-op delta must return the running census with NO descriptor
        upload and NO device dispatch at all."""
        import repro.core.engine as engine_mod
        g = from_edges([0, 1, 2], [1, 2, 3], n=5)
        session = CensusEngine(backend="jnp").session(g)
        c0 = session.census()
        calls = []
        real_step = engine_mod._desc_step
        monkeypatch.setattr(
            engine_mod, "_desc_step",
            lambda *a, **k: calls.append(1) or real_step(*a, **k))
        got = session.update([0], [1])        # arc already present
        np.testing.assert_array_equal(got, c0)
        assert calls == []
        assert session.stats.chunks == 0 and session.stats.items == 0

    def test_compile_once_across_updates(self):
        rng = np.random.default_rng(19)
        g = scale_free_digraph(n=45, avg_degree=4, exponent=2.2,
                               mutual_p=0.3, seed=19)
        session = CensusEngine(backend="jnp").session(g, max_items=144)
        session.census()
        compiles = [session.stats.step_compiles]
        for _ in range(4):
            session.update(*random_arcs(rng, g.n, 5),
                           *random_arcs(rng, g.n, 5))
            compiles.append(session.stats.step_compiles)
            assert session.stats.capacity_recompiles == 0
        assert sum(compiles) <= 1, compiles

    def test_capacity_growth_recompiles_exactly_once(self):
        """Growing the resident buffers past capacity recompiles the step
        exactly once, attributed to ``capacity_recompiles`` (never
        ``step_compiles``); a same-capacity follow-up recompiles nothing.
        Unique n/max_items keep this test's jit entries out of every
        other test's cache."""
        g = scale_free_digraph(n=83, avg_degree=3, exponent=2.3,
                               mutual_p=0.2, seed=23)
        assert 128 < g.num_pairs < 256          # initial pair cap == 256
        session = CensusEngine(backend="jnp").session(g, max_items=277)
        session.census()
        first = (session.stats.step_compiles
                 + session.stats.capacity_recompiles)
        assert first == 1                       # fresh shapes compile once
        assert session.stats.capacity_recompiles == 0
        # push pairs past 256: the pair/entry caps double
        add_src = np.repeat(np.arange(40), 8)
        add_dst = (np.arange(320) * 7 + 1) % 83
        g2, _ = apply_delta(g, add_src, add_dst)
        assert g2.num_pairs > 256
        got = session.update(add_src, add_dst)
        np.testing.assert_array_equal(got, census_batagelj_mrvar(g2))
        assert session.stats.capacity_recompiles == 1
        assert session.stats.step_compiles == 0
        # steady state: same capacities, no compiles of either kind
        session.update([0], [2])
        assert session.stats.capacity_recompiles == 0
        assert session.stats.step_compiles == 0

    def test_monitor_device_emit_bit_identical(self):
        from repro.core import TriadMonitor
        rng = np.random.default_rng(29)
        src = rng.integers(0, 80, 3000)
        dst = rng.integers(0, 80, 3000)
        mons = {e: TriadMonitor(80, window=500, stride=100, history=2,
                                max_items=1024, emit=e)
                for e in ("host", "device")}
        for m in mons.values():
            m.observe(src, dst)
        np.testing.assert_array_equal(mons["host"].censuses,
                                      mons["device"].censuses)
        assert all(s.emit == "device"
                   for s in mons["device"].window_stats)
