"""Serving engine: prefill+decode consistency and generation smoke."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models.model import make_params, serve_prefill
from repro.serve.engine import ServeEngine, prefill_to_decode_cache


@pytest.mark.parametrize("arch", ["qwen2-0.5b", "recurrentgemma-2b",
                                  "xlstm-1.3b"])
def test_prefill_decode_matches_full_forward(arch):
    """Next-token logits via (prefill P-1, decode 1) must match a full
    P-token prefill (modulo bf16 path differences)."""
    cfg = get_config(arch).reduced()
    params = make_params(cfg, seed=0)
    rng = np.random.default_rng(0)
    p_len = 12
    toks = rng.integers(0, cfg.vocab_size, (2, p_len)).astype(np.int32)

    full_logits, _ = serve_prefill(cfg, params,
                                   {"tokens": jnp.asarray(toks)}, q_chunk=8)
    pre_logits, caches = serve_prefill(
        cfg, params, {"tokens": jnp.asarray(toks[:, :-1])}, q_chunk=8)
    cache = prefill_to_decode_cache(cfg, caches, p_len - 1, capacity=32,
                                    params=params)
    from repro.models.model import decode_step
    step_logits, cache = decode_step(cfg, params,
                                     jnp.asarray(toks[:, -1:]), cache)
    a = np.asarray(full_logits[:, 0, :cfg.vocab_size], np.float32)
    b = np.asarray(step_logits[:, 0, :cfg.vocab_size], np.float32)
    # bf16 compute on two different code paths (chunked prefill vs single
    # decode step): values track closely but not bit-exactly
    assert np.corrcoef(a.ravel(), b.ravel())[0, 1] > 0.999
    np.testing.assert_allclose(a, b, rtol=0.2, atol=0.6)
    assert (a.argmax(-1) == b.argmax(-1)).mean() >= 0.5


@pytest.mark.parametrize("arch", ["qwen2-0.5b", "recurrentgemma-2b"])
def test_generate_shapes_and_determinism(arch):
    cfg = get_config(arch).reduced()
    params = make_params(cfg, seed=1)
    eng = ServeEngine(cfg, params, max_seq_len=64, q_chunk=8)
    toks = np.random.default_rng(1).integers(
        0, cfg.vocab_size, (2, 8)).astype(np.int32)
    out1 = eng.generate(toks, max_new_tokens=6)
    out2 = eng.generate(toks, max_new_tokens=6)
    assert out1.shape == (2, 14)
    np.testing.assert_array_equal(out1, out2)        # greedy is determin.
    assert (out1[:, :8] == toks).all()
    assert (out1 >= 0).all() and (out1 < cfg.vocab_size).all()


def test_generate_temperature_sampling():
    cfg = get_config("qwen2-0.5b").reduced()
    params = make_params(cfg, seed=2)
    eng = ServeEngine(cfg, params, max_seq_len=64, q_chunk=8)
    toks = np.zeros((1, 4), np.int32)
    a = eng.generate(toks, max_new_tokens=8, temperature=1.0, seed=0)
    b = eng.generate(toks, max_new_tokens=8, temperature=1.0, seed=1)
    assert a.shape == b.shape == (1, 12)
