"""Device-side megastep: scan K descriptor windows per dispatch.

The tentpole contract:

* **Bit-identity** — ``schedule="async"`` with any
  ``max_windows_per_dispatch`` K equals the lock-step collective oracle
  equals the reference census, across 1/2/4/8-device meshes × both
  orients × both emit modes × K∈{1,2,8}.  The megastep returns per-
  window STACKED int32 partials and the host sums them in int64, so a
  K-window scan is bit-identical to K single-window dispatches.
* **Dispatch amortization** — at an equal window budget, K=8 issues at
  most half the device dispatches of K=1 (the whole point: Python
  dispatch cost is paid once per K windows).
* **Compile-once** — the megabatch buffer is fixed ``(cap, words)``
  shape with zero-padded masked rows, so the jitted megastep compiles
  once per device no matter how the adaptive K schedule moves.
* **Adaptive K** — consumer stalls shrink K (producer-bound), producer
  backlog grows K (dispatch-bound), monotonically within [1, cap].
* **Short-circuit** — zero-window shards never get a producer thread
  or a rotation slot, and the megastep path never enters the
  cross-shard collective primitives.
"""

import time

import numpy as np
import pytest

from repro.core import (
    CensusEngine, ShardStreamPipeline, TriadMonitor, WindowBatcher,
    census_batagelj_mrvar, default_mesh, lpt_assign_heap, pair_space,
    partition_graph, scale_free_digraph)


def pl_graph(n=100, deg=5, seed=7):
    return scale_free_digraph(n=n, avg_degree=deg, exponent=2.2,
                              mutual_p=0.3, seed=seed)


def skewed_partition(g, num_shards, factor=4.0, orient="none"):
    """Shard 0 holds ``factor``× each other shard's pre-prune items;
    the rest are LPT-balanced across shards 1..ns-1."""
    space = pair_space(g, orient=orient)
    costs = space.counts.astype(np.int64)
    order = np.argsort(-costs, kind="stable")
    total = int(costs.sum())
    target0 = total * factor / (factor + (num_shards - 1))
    csum = np.cumsum(costs[order])
    k = int(np.searchsorted(csum, target0)) + 1
    owner = np.empty(space.num_pairs, np.int64)
    owner[order[:k]] = 0
    rest = order[k:]
    owner[rest] = 1 + lpt_assign_heap(costs[rest], num_shards - 1)
    return partition_graph(num_shards=num_shards, space=space,
                           owner=owner)


def rows_of(n, words=3):
    """n distinct nonzero int32 window rows (leading word > 0, as real
    ``device_words`` always have ``num_preprune >= 1``)."""
    return [np.full(words, i + 1, dtype=np.int32) for i in range(n)]


# ------------------------------------------------------ WindowBatcher


class TestWindowBatcher:
    def test_validation(self):
        with pytest.raises(ValueError):
            WindowBatcher(0, 4)
        with pytest.raises(ValueError):
            WindowBatcher(4, 0)

    def test_start_defaults_to_cap_and_clamps(self):
        assert WindowBatcher(8, 4).k == 8
        assert WindowBatcher(8, 4, start=3).k == 3
        assert WindowBatcher(8, 4, start=99).k == 8
        assert WindowBatcher(8, 4, start=0).k == 1

    def test_shrink_grow_monotone_within_bounds(self):
        b = WindowBatcher(8, 4)
        ks = []
        for _ in range(5):
            b.shrink()
            ks.append(b.k)
        assert ks == [4, 2, 1, 1, 1]      # halves, floors at 1
        ks = []
        for _ in range(5):
            b.grow()
            ks.append(b.k)
        assert ks == [2, 4, 8, 8, 8]      # doubles, caps at cap

    def test_wrap_coalesces_fixed_shape_with_zero_pad(self):
        b = WindowBatcher(4, 3)
        batches = list(b.wrap(rows_of(6)))
        assert len(batches) == 2
        full, real = batches[0]
        assert full.shape == (4, 3) and full.dtype == np.int32
        assert real == 4
        np.testing.assert_array_equal(full, np.stack(rows_of(6)[:4]))
        tail, real = batches[1]
        assert tail.shape == (4, 3)       # shape never depends on fill
        assert real == 2
        np.testing.assert_array_equal(tail[:2], np.stack(rows_of(6)[4:]))
        # padding rows are all-zero → num_preprune word 0 → masked out
        np.testing.assert_array_equal(tail[2:], 0)

    def test_wrap_k_larger_than_stream(self):
        b = WindowBatcher(8, 3)
        batches = list(b.wrap(rows_of(3)))
        assert len(batches) == 1
        buf, real = batches[0]
        assert buf.shape == (8, 3) and real == 3
        np.testing.assert_array_equal(buf[3:], 0)

    def test_wrap_empty_source(self):
        assert list(WindowBatcher(4, 3).wrap([])) == []

    def test_wrap_snapshots_current_k_per_batch(self):
        b = WindowBatcher(8, 3, start=2)
        gen = b.wrap(rows_of(10))
        _, real = next(gen)
        assert real == 2                  # filled at k=2
        b.grow()                          # adaptive move between batches
        _, real = next(gen)
        assert real == 4                  # next batch sees k=4


# --------------------------------------- adaptive feedback in the pipe


class TestAdaptiveK:
    def test_consumer_stall_shrinks_k(self):
        """Slow producer + fast consumer: once at least one batch has
        been consumed, each stall halves k."""
        b = WindowBatcher(8, 2, start=4)

        def slow():
            for i in range(12):
                time.sleep(0.03)
                yield np.array([1, i], np.int32)

        pipe = ShardStreamPipeline([slow()], depth=2, batch=b)
        got = sum(real for _, (_, real) in pipe)
        pipe.close()
        assert got == 12                  # every window lands exactly once
        assert pipe.stalls > 0
        assert b.k < 4

    def test_producer_backlog_grows_k(self):
        """Fast producer + slow consumer on a depth-1 queue: puts block,
        k doubles toward cap."""
        b = WindowBatcher(8, 2, start=1)

        def fast():
            for i in range(12):
                yield np.array([1, i], np.int32)

        pipe = ShardStreamPipeline([fast()], depth=1, batch=b)
        got = 0
        for _, (_, real) in pipe:
            time.sleep(0.08)              # device busy: consumer behind
            got += real
        pipe.close()
        assert got == 12
        assert b.k > 1

    def test_startup_latency_is_not_starvation(self):
        """The very first stall (nothing consumed yet) must NOT shrink
        k — producer warm-up is not a bottleneck signal."""
        b = WindowBatcher(8, 2)

        def warmup():
            time.sleep(0.08)              # consumer stalls before row 0
            for i in range(4):
                yield np.array([1, i], np.int32)

        pipe = ShardStreamPipeline([warmup()], depth=2, batch=b)
        got = sum(real for _, (_, real) in pipe)
        pipe.close()
        assert got == 4
        assert pipe.stalls >= 1
        assert b.k == 8                   # grace: no shrink before use


# -------------------------------------------------------- bit-identity


class TestMegastepBitIdentity:
    @pytest.mark.parametrize("orient", ["none", "degree"])
    @pytest.mark.parametrize("cap", [1, 2, 8])
    def test_k_matrix_vs_lockstep_and_reference(self, cap, orient):
        g = pl_graph(n=70, seed=13)
        want = census_batagelj_mrvar(g)
        part = skewed_partition(g, 4, orient=orient)
        lock = CensusEngine(mesh=default_mesh(4), backend="jnp",
                            partition=True, emit="device",
                            schedule="lockstep")
        ref = lock.run(g, max_items=120, part=part)
        np.testing.assert_array_equal(ref, want)
        eng = CensusEngine(mesh=default_mesh(4), backend="jnp",
                           partition=True, emit="device",
                           schedule="async",
                           max_windows_per_dispatch=cap)
        got = eng.run(g, max_items=120, part=part)
        np.testing.assert_array_equal(got, want)
        st = eng.stats
        assert st.dispatch_batch_limit == cap
        assert 1 <= st.windows_per_dispatch_max <= cap
        # same windows as the lock-step oracle, fewer dispatches
        assert st.shard_steps == lock.stats.shard_steps

    @pytest.mark.parametrize("ndev", [1, 2, 8])
    def test_device_count_sweep(self, ndev):
        g = pl_graph(n=60, seed=5)
        want = census_batagelj_mrvar(g)
        eng = CensusEngine(mesh=default_mesh(ndev), backend="jnp",
                           partition=True, schedule="async",
                           max_windows_per_dispatch=8)
        np.testing.assert_array_equal(eng.run(g, max_items=100), want)

    @pytest.mark.parametrize("backend", ["pallas", "pallas-fused"])
    def test_pallas_backends_through_scan(self, backend):
        g = pl_graph(n=40, deg=4, seed=8)
        want = census_batagelj_mrvar(g)
        eng = CensusEngine(mesh=default_mesh(4), backend=backend,
                           partition=True, schedule="async",
                           max_windows_per_dispatch=4)
        np.testing.assert_array_equal(eng.run(g, max_items=80), want)

    def test_host_emit_stays_single_window_oracle(self):
        """``emit="host"`` ignores the megastep: cap is pinned to 1 so
        the PR 6 one-window-per-dispatch path stays the oracle."""
        g = pl_graph(n=60, seed=29)
        eng = CensusEngine(mesh=default_mesh(4), backend="jnp",
                           partition=True, emit="host",
                           schedule="async",
                           max_windows_per_dispatch=8)
        np.testing.assert_array_equal(eng.run(g, max_items=100),
                                      census_batagelj_mrvar(g))
        st = eng.stats
        assert st.dispatch_batch_limit == 1
        assert st.windows_per_dispatch_max == 1
        assert st.dispatches_total == st.chunks


# --------------------------------------------------------------- stats


class TestMegastepStats:
    def test_ragged_tail_pad_identity(self):
        """Windows not divisible by K: the tail batch pads, and the pad
        bytes obey cap × dispatches − real windows exactly."""
        g = pl_graph(n=70, seed=13)
        part = skewed_partition(g, 4)
        eng = CensusEngine(mesh=default_mesh(4), backend="jnp",
                           partition=True, schedule="async",
                           max_windows_per_dispatch=8)
        eng.run(g, max_items=120, part=part)
        st = eng.stats
        windows = sum(st.shard_steps)
        assert st.chunks == windows == len(st.chunk_items)
        assert st.dispatches_total < windows
        assert st.plan_upload_bytes_total == \
            st.plan_upload_bytes * windows
        assert st.plan_pad_bytes_total == st.plan_upload_bytes * \
            (st.dispatch_batch_limit * st.dispatches_total - windows)
        assert st.plan_pad_bytes_total > 0     # ragged tails exist
        assert st.windows_per_dispatch_mean == \
            pytest.approx(windows / st.dispatches_total)
        assert "win/disp" in st.summary()
        assert f"dispatches={st.dispatches_total}" in st.summary()

    def test_k_exceeds_total_windows(self):
        """cap far above any shard's window count: the engine clamps
        the effective batch capacity to the longest shard queue, so
        short schedules never upload dead pad rows — one dispatch per
        shard, zero pad bytes."""
        g = pl_graph(n=40, deg=3, seed=2)
        eng = CensusEngine(mesh=default_mesh(4), backend="jnp",
                           partition=True, schedule="async",
                           max_windows_per_dispatch=64)
        got = eng.run(g)                  # unstreamed: 1 window/shard
        np.testing.assert_array_equal(got, census_batagelj_mrvar(g))
        st = eng.stats
        assert st.dispatches_total == \
            sum(1 for t in st.shard_steps if t > 0)
        assert st.dispatch_batch_limit == max(st.shard_steps) == 1
        assert st.plan_pad_bytes_total == 0

    def test_dispatch_reduction_at_equal_window_budget(self):
        """The headline: same windows, ≥2× fewer dispatches at K=8."""
        g = pl_graph(n=90, seed=11)
        part = skewed_partition(g, 4)
        disp = {}
        for cap in (1, 8):
            eng = CensusEngine(mesh=default_mesh(4), backend="jnp",
                               partition=True, schedule="async",
                               max_windows_per_dispatch=cap)
            eng.run(g, max_items=100, part=part)
            disp[cap] = eng.stats.dispatches_total
            if cap == 1:
                windows = sum(eng.stats.shard_steps)
            else:
                assert sum(eng.stats.shard_steps) == windows
        assert disp[8] * 2 <= disp[1]

    def test_compiles_once_per_device_across_k_schedule(self):
        """Fixed (cap, words) megabatch shape: one compiled step per
        device regardless of how many windows each batch really holds,
        and a second run recompiles nothing."""
        g = pl_graph(n=90, seed=21)
        eng = CensusEngine(mesh=default_mesh(4), backend="jnp",
                           partition=True, schedule="async",
                           max_windows_per_dispatch=8)
        eng.run(g, max_items=64)
        assert eng.stats.dispatches_total >= 4
        assert eng.stats.step_compiles <= 4
        eng.run(g, max_items=64)          # warm cache
        assert eng.stats.step_compiles == 0

    def test_lockstep_stats_surface(self):
        g = pl_graph(n=70, seed=13)
        part = skewed_partition(g, 4)
        eng = CensusEngine(mesh=default_mesh(4), backend="jnp",
                           partition=True, emit="device",
                           schedule="lockstep")
        eng.run(g, max_items=120, part=part)
        st = eng.stats
        assert st.dispatch_batch_limit == 1
        assert st.dispatches_total == st.chunks
        assert st.windows_per_dispatch_mean == \
            pytest.approx(sum(st.shard_steps) / st.dispatches_total)
        assert st.windows_per_dispatch_max == \
            sum(1 for t in st.shard_steps if t > 0)

    def test_ctor_validation(self):
        with pytest.raises(ValueError):
            CensusEngine(mesh=default_mesh(2), partition=True,
                         pipeline_depth=0)
        with pytest.raises(ValueError):
            CensusEngine(mesh=default_mesh(2), partition=True,
                         max_windows_per_dispatch=0)

    def test_pipeline_depth_configurable_and_surfaced(self):
        g = pl_graph(n=50, seed=4)
        eng = CensusEngine(mesh=default_mesh(2), backend="jnp",
                           partition=True, schedule="async",
                           pipeline_depth=3)
        np.testing.assert_array_equal(eng.run(g, max_items=80),
                                      census_batagelj_mrvar(g))
        assert eng.pipeline_depth == 3
        assert eng.stats.pipeline_depth == 3

    def test_triad_monitor_forwards_knobs(self):
        mon = TriadMonitor(50, window=40, mesh=default_mesh(2),
                           partition=True, pipeline_depth=3,
                           max_windows_per_dispatch=4)
        assert mon.engine.pipeline_depth == 3
        assert mon.engine.max_windows_per_dispatch == 4


# ------------------------------------- short-circuit + no collectives


class TestShortCircuitAndIsolation:
    def test_empty_shards_never_enter_rotation(self, monkeypatch):
        """All pairs on shard 0 of a 4-device mesh: the pipeline is
        built with ONE source, not four — drained/empty shards are
        short-circuited out before any thread or queue exists."""
        import repro.core.engine as engine_mod
        seen = []
        real = engine_mod.ShardStreamPipeline

        class Spy(real):
            def __init__(self, sources, **kw):
                sources = list(sources)
                seen.append(len(sources))
                super().__init__(sources, **kw)

        monkeypatch.setattr(engine_mod, "ShardStreamPipeline", Spy)
        g = pl_graph(n=60, seed=17)
        space = pair_space(g, orient="none")
        part = partition_graph(
            num_shards=4, space=space,
            owner=np.zeros(space.num_pairs, np.int64))
        eng = CensusEngine(mesh=default_mesh(4), backend="jnp",
                           partition=True, schedule="async",
                           max_windows_per_dispatch=8)
        got = eng.run(g, max_items=100, part=part)
        np.testing.assert_array_equal(got, census_batagelj_mrvar(g))
        assert seen == [1]
        st = eng.stats
        assert st.shard_steps[0] > 0
        assert all(t == 0 for t in st.shard_steps[1:])

    @pytest.mark.parametrize("cap", [2, 8])
    def test_megastep_never_enters_collectives(self, cap, monkeypatch):
        """Poison the lock-step collective primitives: the megastep
        path is single-device dispatches + host merge only."""
        import repro.core.engine as engine_mod

        def poison(*a, **k):
            raise AssertionError(
                "async megastep entered a cross-shard collective")

        monkeypatch.setattr(engine_mod, "_part_desc_step", poison)
        monkeypatch.setattr(engine_mod, "_part_chunk_step", poison)
        g = pl_graph(n=70, seed=13)
        eng = CensusEngine(mesh=default_mesh(4), backend="jnp",
                           partition=True, schedule="async",
                           max_windows_per_dispatch=cap)
        got = eng.run(g, max_items=120,
                      part=skewed_partition(g, 4))
        np.testing.assert_array_equal(got, census_batagelj_mrvar(g))
