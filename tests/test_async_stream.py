"""Async per-shard streams: no inter-shard barrier, pipelined host side.

The tentpole contract:

* **Bit-identity** — ``schedule="async"`` (independent per-device
  dispatches, host int64 merge) equals ``schedule="lockstep"`` (the
  collective psum oracle) equals the reference census, across
  1/2/4/8-device meshes × both orients × both emit modes, on balanced,
  skewed and empty-shard partitions.  Integer sums make the merge order
  unobservable.
* **No cross-shard synchronization** — the async path never enters the
  collective lock-step primitives (``_part_desc_step`` /
  ``_part_chunk_step``); each window is a single-device dispatch.
* **Skew** — a shard with 4× everyone else's chunk queue finishes late
  WITHOUT holding the other shards' queues: total dispatches equal the
  sum of real windows, not ``ndev × max``.
* **Stats** — per-shard step counts, stall/idle counters, pipeline depth
  and per-shard upload attribution are exact under both schedules.
"""

import numpy as np
import pytest

from repro.core import (
    CensusEngine, ShardStreamPipeline, census_batagelj_mrvar,
    default_mesh, lpt_assign_heap, pair_space, partition_graph,
    scale_free_digraph, triad_census_graph)
from repro.core.plan_stream import ShardSchedule


def pl_graph(n=100, deg=5, seed=7):
    return scale_free_digraph(n=n, avg_degree=deg, exponent=2.2,
                              mutual_p=0.3, seed=seed)


def skewed_partition(g, num_shards, factor=4.0, orient="none"):
    """Deliberately imbalanced partition: shard 0 gets the heaviest
    pairs until it holds ``factor``× each other shard's pre-prune items
    (and therefore ~``factor``× the chunk-queue length); the rest are
    LPT-balanced across shards 1..ns-1."""
    space = pair_space(g, orient=orient)
    costs = space.counts.astype(np.int64)     # pre-prune items per pair
    order = np.argsort(-costs, kind="stable")
    total = int(costs.sum())
    target0 = total * factor / (factor + (num_shards - 1))
    csum = np.cumsum(costs[order])
    k = int(np.searchsorted(csum, target0)) + 1
    owner = np.empty(space.num_pairs, np.int64)
    owner[order[:k]] = 0
    rest = order[k:]
    owner[rest] = 1 + lpt_assign_heap(costs[rest], num_shards - 1)
    return partition_graph(num_shards=num_shards, space=space,
                           owner=owner)


# ----------------------------------------------------------- pipeline


class TestShardStreamPipeline:
    def test_yields_every_window_tagged_with_shard(self):
        srcs = [iter([10, 11]), iter([20]), iter([30, 31, 32])]
        pipe = ShardStreamPipeline(srcs, depth=2)
        got = sorted(pipe)
        pipe.close()
        assert got == [(0, 10), (0, 11), (1, 20), (2, 30), (2, 31),
                       (2, 32)]

    def test_empty_sources(self):
        pipe = ShardStreamPipeline([iter([]), iter([1]), iter([])])
        assert sorted(pipe) == [(1, 1)]
        pipe.close()

    def test_skewed_sources_no_barrier(self):
        """A 1-window shard ends after its window; the 8-window shard
        keeps streaming — consumption order can interleave but never
        waits for the long shard to finish a 'step'."""
        pipe = ShardStreamPipeline(
            [iter(range(8)), iter([100])], depth=2)
        got = list(pipe)
        pipe.close()
        assert got.count((1, 100)) == 1
        assert [w for s, w in got if s == 0] == list(range(8))

    def test_producer_exception_reraises_in_consumer(self):
        def bad():
            yield 1
            raise RuntimeError("producer blew up")

        pipe = ShardStreamPipeline([bad(), iter([2])])
        with pytest.raises(RuntimeError, match="blew up"):
            for _ in pipe:
                pass
        pipe.close()

    def test_slow_producer_counts_stalls(self):
        import time

        def slow():
            for i in range(3):
                time.sleep(0.05)
                yield i

        pipe = ShardStreamPipeline([slow()], depth=2)
        assert [w for _, w in pipe] == [0, 1, 2]
        assert pipe.stalls >= 1
        pipe.close()

    def test_close_is_idempotent_and_unblocks_producers(self):
        pipe = ShardStreamPipeline([iter(range(10_000))], depth=1)
        next(iter(pipe))
        pipe.close()
        pipe.close()
        assert all(not t.is_alive() for t in pipe._threads)

    def test_depth_validation(self):
        with pytest.raises(ValueError, match="depth"):
            ShardStreamPipeline([iter([])], depth=0)


# ----------------------------------------------------- shard schedule


class TestPerShardSchedule:
    def test_steps_for_and_totals(self):
        g = pl_graph(n=90, seed=3)
        part = skewed_partition(g, 4)
        sched = ShardSchedule([sh.space for sh in part.shards], 200, 4)
        steps = sched.shard_steps
        assert steps == [sched.steps_for(s) for s in range(4)]
        assert sched.num_steps == max(steps)
        assert sched.total_windows == sum(steps)
        # the skew helper really skews the queue lengths
        assert steps[0] >= 3 * max(steps[1:])

    def test_shard_step_items_tile_the_shard(self):
        g = pl_graph(n=60, seed=9)
        part = partition_graph(g, 3)
        sched = ShardSchedule([sh.space for sh in part.shards], 100, 3)
        for s in range(3):
            total = 0
            for k in range(sched.steps_for(s)):
                sp, pv, num = sched.shard_step_items(s, k)
                assert sp.shape == (sched.chunk_shape,)
                total += num
            assert total == part.shards[s].items


# -------------------------------------------------------- bit-identity


class TestAsyncBitIdentity:
    @pytest.mark.parametrize("num_devices", [1, 2, 4, 8])
    @pytest.mark.parametrize("orient", ["none", "degree"])
    @pytest.mark.parametrize("emit", ["device", "host"])
    def test_async_equals_lockstep_and_reference(self, num_devices,
                                                 orient, emit):
        g = pl_graph(n=70, seed=5)
        want = census_batagelj_mrvar(g)
        got = {}
        for sched in ("async", "lockstep"):
            engine = CensusEngine(mesh=default_mesh(num_devices),
                                  backend="jnp", partition=True,
                                  emit=emit, schedule=sched)
            got[sched] = engine.run(g, max_items=120, orient=orient)
        np.testing.assert_array_equal(got["async"], want)
        np.testing.assert_array_equal(got["async"], got["lockstep"])

    @pytest.mark.parametrize("emit", ["device", "host"])
    def test_skewed_partition_bit_identical(self, emit):
        g = pl_graph(n=90, seed=11)
        want = census_batagelj_mrvar(g)
        part = skewed_partition(g, 4)
        for sched in ("async", "lockstep"):
            engine = CensusEngine(mesh=default_mesh(4), backend="jnp",
                                  partition=True, emit=emit,
                                  schedule=sched)
            got = engine.run(g, max_items=200, part=part)
            np.testing.assert_array_equal(got, want)
        # async dispatched only the real windows: Σ steps, not ndev×max
        st = engine.stats          # lockstep (last): padded idle steps
        assert st.idle_steps > 0

    def test_empty_shards_both_schedules(self):
        g = pl_graph(n=50, seed=13)
        want = census_batagelj_mrvar(g)
        space = pair_space(g)
        owner = np.zeros(space.num_pairs, np.int64)   # all pairs → 0
        part = partition_graph(num_shards=4, space=space, owner=owner)
        for sched in ("async", "lockstep"):
            engine = CensusEngine(mesh=default_mesh(4), backend="jnp",
                                  partition=True, schedule=sched)
            got = engine.run(g, max_items=150, part=part)
            np.testing.assert_array_equal(got, want)
            assert engine.stats.shard_steps[1:] == [0, 0, 0]

    @pytest.mark.parametrize("backend", ["pallas", "pallas-fused"])
    def test_async_backends(self, backend):
        g = pl_graph(n=40, deg=4, seed=8)
        want = census_batagelj_mrvar(g)
        engine = CensusEngine(mesh=default_mesh(4), backend=backend,
                              partition=True, schedule="async")
        np.testing.assert_array_equal(engine.run(g), want)
        np.testing.assert_array_equal(engine.run(g, max_items=80), want)

    def test_monolithic_schedule_async(self):
        """max_items=None still works: one window per shard."""
        g = pl_graph(n=60, seed=19)
        got = triad_census_graph(g, mesh=default_mesh(4),
                                 partition=True, schedule="async")
        np.testing.assert_array_equal(got, census_batagelj_mrvar(g))

    def test_schedule_validation(self):
        with pytest.raises(ValueError, match="schedule"):
            CensusEngine(mesh=default_mesh(2), partition=True,
                         schedule="bogus")
        engine = CensusEngine(mesh=default_mesh(2), partition=True)
        with pytest.raises(ValueError, match="schedule"):
            engine.run(pl_graph(n=20), schedule="bogus")

    def test_prebuilt_part_validation(self):
        g = pl_graph(n=30, seed=1)
        part = partition_graph(g, 2)
        with pytest.raises(ValueError, match="partition=True"):
            CensusEngine(mesh=default_mesh(2), backend="jnp").run(
                g, part=part)
        with pytest.raises(ValueError, match="shards"):
            CensusEngine(mesh=default_mesh(4), backend="jnp",
                         partition=True).run(g, part=part)


# ------------------------------------------------------ no-sync proof


class TestNoCrossShardSync:
    @pytest.mark.parametrize("emit", ["device", "host"])
    def test_async_never_enters_collective_step(self, emit, monkeypatch):
        """The lock-step path's collective primitives are the ONLY
        cross-shard synchronization points; poisoning them proves the
        async schedule never synchronizes shards between chunk steps."""
        import repro.core.engine as engine_mod

        def poison(*a, **k):
            raise AssertionError("async schedule entered the "
                                 "collective lock-step primitive")

        monkeypatch.setattr(engine_mod, "_part_desc_step", poison)
        monkeypatch.setattr(engine_mod, "_part_chunk_step", poison)
        g = pl_graph(n=70, seed=23)
        engine = CensusEngine(mesh=default_mesh(4), backend="jnp",
                              partition=True, emit=emit,
                              schedule="async")
        got = engine.run(g, max_items=150)
        np.testing.assert_array_equal(got, census_batagelj_mrvar(g))

    def test_lockstep_does_use_collective_step(self, monkeypatch):
        """Control for the poison test: the oracle path DOES go through
        the collective primitive."""
        import repro.core.engine as engine_mod
        calls = []
        real = engine_mod._part_desc_step

        def spy(*a, **k):
            calls.append(1)
            return real(*a, **k)

        monkeypatch.setattr(engine_mod, "_part_desc_step", spy)
        g = pl_graph(n=40, seed=23)
        engine = CensusEngine(mesh=default_mesh(4), backend="jnp",
                              partition=True, emit="device",
                              schedule="lockstep")
        engine.run(g, max_items=150)
        assert calls


# -------------------------------------------------------------- stats


class TestAsyncStats:
    def test_lockstep_vs_async_stats_regression(self):
        """Satellite: upload/step attribution under async.  Same census,
        same items, same per-shard step counts; both schedules attribute
        upload to REAL windows, with padding split into a separate
        counter (lock-step burns whole idle collective steps; async pads
        only ragged megabatch tails)."""
        g = pl_graph(n=90, seed=11)
        part = skewed_partition(g, 4)
        st = {}
        census = {}
        for sched in ("async", "lockstep"):
            engine = CensusEngine(mesh=default_mesh(4), backend="jnp",
                                  partition=True, emit="device",
                                  schedule=sched)
            census[sched] = engine.run(g, max_items=200, part=part)
            st[sched] = engine.stats
        a, l = st["async"], st["lockstep"]
        np.testing.assert_array_equal(census["async"],
                                      census["lockstep"])
        assert a.items == l.items > 0
        assert a.schedule == "async" and l.schedule == "lockstep"
        # identical queues, so identical per-shard step counts
        assert a.shard_steps == l.shard_steps
        sched_obj = ShardSchedule(
            [sh.space for sh in part.shards], 200, 4)
        assert a.shard_steps == sched_obj.shard_steps
        # async dispatches exactly the real windows; lock-step burns
        # whole collective steps on exhausted shards
        assert a.chunks == sum(a.shard_steps)
        assert a.idle_steps == 0
        assert l.idle_steps == 4 * max(l.shard_steps) \
            - sum(l.shard_steps) > 0
        # upload attribution: both schedules charge upload for REAL
        # windows only; lock-step's padded idle steps land in the pad
        # counter instead of inflating the upload total
        assert a.plan_upload_bytes_total == \
            a.plan_upload_bytes * sum(a.shard_steps)
        assert l.plan_upload_bytes_total == \
            l.plan_upload_bytes * sum(l.shard_steps)
        assert a.plan_upload_bytes_total == l.plan_upload_bytes_total
        assert l.plan_pad_bytes_total == \
            l.plan_upload_bytes * l.idle_steps > 0
        # async pad obeys the megabatch identity: cap × dispatches
        # minus real windows, all ragged-tail slots
        assert a.plan_pad_bytes_total == a.plan_upload_bytes * \
            (a.dispatch_batch_limit * a.dispatches_total
             - sum(a.shard_steps))
        # pipeline surface
        assert a.pipeline_depth == 2
        assert a.stall_steps >= 0
        assert "async" in a.summary() and "lockstep" in l.summary()
        # comparable lane footprint records
        assert a.peak_plan_bytes == l.peak_plan_bytes

    def test_async_compiles_once_per_device_not_per_step(self):
        """The stacked common-shape shard buffers mean one compiled step
        per DEVICE serves that shard's every window (jit keys on device
        placement, so the floor is ndev, never O(steps))."""
        g = pl_graph(n=90, seed=21)
        engine = CensusEngine(mesh=default_mesh(4), backend="jnp",
                              partition=True, schedule="async")
        engine.run(g, max_items=64)
        assert engine.stats.chunks >= 8
        assert engine.stats.step_compiles <= 4

    def test_host_emit_skips_fully_pruned_windows(self):
        """Host emission never dispatches a zero-valid window: chunks
        counts only real dispatches."""
        g = pl_graph(n=60, seed=29)
        engine = CensusEngine(mesh=default_mesh(4), backend="jnp",
                              partition=True, emit="host",
                              schedule="async")
        engine.run(g, max_items=100)
        st = engine.stats
        assert st.chunks == len(st.chunk_items)
        assert all(n > 0 for n in st.chunk_items)
