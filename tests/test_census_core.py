"""Core triad-census correctness: oracles, JAX path, distributed path."""

import numpy as np
import pytest

import networkx as nx

from repro.core import (
    from_edges, to_dense, build_plan, triad_census,
    triad_census_distributed, census_bruteforce, census_batagelj_mrvar,
    census_dict, erdos_renyi_digraph, scale_free_digraph, TRIAD_NAMES,
    TRICODE_TO_CLASS,
)


def random_digraph(rng, n, p):
    a = rng.random((n, n)) < p
    np.fill_diagonal(a, False)
    src, dst = np.nonzero(a)
    return from_edges(src, dst, n=n), a


def nx_census(a):
    n = a.shape[0]
    G = nx.DiGraph()
    G.add_nodes_from(range(n))
    G.add_edges_from(zip(*np.nonzero(a)))
    return nx.triadic_census(G)


class TestLUT:
    def test_partition_complete(self):
        assert TRICODE_TO_CLASS.shape == (64,)
        assert set(TRICODE_TO_CLASS.tolist()) == set(range(16))

    def test_null_and_full(self):
        assert TRIAD_NAMES[TRICODE_TO_CLASS[0]] == "003"
        assert TRIAD_NAMES[TRICODE_TO_CLASS[63]] == "300"


class TestOracles:
    @pytest.mark.parametrize("seed", range(5))
    def test_bruteforce_matches_networkx(self, seed):
        rng = np.random.default_rng(seed)
        n = int(rng.integers(3, 35))
        g, a = random_digraph(rng, n, float(rng.uniform(0.05, 0.5)))
        mine = census_dict(census_bruteforce(a))
        theirs = nx_census(a)
        assert mine == {k: int(v) for k, v in theirs.items()}

    @pytest.mark.parametrize("seed", range(5))
    def test_bm_matches_bruteforce(self, seed):
        rng = np.random.default_rng(100 + seed)
        n = int(rng.integers(3, 50))
        g, a = random_digraph(rng, n, float(rng.uniform(0.02, 0.4)))
        assert (census_batagelj_mrvar(g) == census_bruteforce(a)).all()

    def test_empty_graph(self):
        g = from_edges([], [], n=10)
        c = census_batagelj_mrvar(g)
        assert c[0] == 120 and c[1:].sum() == 0

    def test_tiny(self):
        # single mutual dyad among 4 nodes -> 2 triads of type 102
        g = from_edges([0, 1], [1, 0], n=4)
        c = census_batagelj_mrvar(g)
        assert census_dict(c)["102"] == 2
        assert c.sum() == 4


class TestJaxCensus:
    @pytest.mark.parametrize("seed", range(6))
    def test_matches_oracle(self, seed):
        rng = np.random.default_rng(200 + seed)
        n = int(rng.integers(3, 60))
        g, a = random_digraph(rng, n, float(rng.uniform(0.02, 0.35)))
        plan = build_plan(g)
        assert (triad_census(plan) == census_bruteforce(a)).all()

    def test_total_is_choose3(self):
        g = scale_free_digraph(n=500, avg_degree=6, exponent=2.2, seed=1)
        plan = build_plan(g)
        c = triad_census(plan)
        assert c.sum() == 500 * 499 * 498 // 6
        assert (c >= 0).all()

    def test_scale_free_matches_bm(self):
        g = scale_free_digraph(n=800, avg_degree=8, exponent=2.1,
                               mutual_p=0.4, seed=3)
        plan = build_plan(g)
        assert (triad_census(plan) == census_batagelj_mrvar(g)).all()

    def test_star_hub(self):
        # hub -> all others: C(n-1, 2) triads of type 021D
        n = 30
        src = np.zeros(n - 1, dtype=int)
        dst = np.arange(1, n)
        g = from_edges(src, dst, n=n)
        c = census_dict(triad_census(build_plan(g)))
        assert c["021D"] == (n - 1) * (n - 2) // 2

    def test_cycle_triangle(self):
        g = from_edges([0, 1, 2], [1, 2, 0], n=3)
        c = census_dict(triad_census(build_plan(g)))
        assert c["030C"] == 1


class TestDistributed:
    def test_matches_single_device(self):
        g = scale_free_digraph(n=600, avg_degree=7, exponent=2.3,
                               mutual_p=0.3, seed=7)
        import jax
        ndev = len(jax.devices())
        plan = build_plan(g, pad_to=ndev)
        serial = census_batagelj_mrvar(g)
        dist = triad_census_distributed(plan)
        assert (dist == serial).all()

    def test_pad_requirement(self):
        g = erdos_renyi_digraph(20, 0.3, seed=0)
        plan = build_plan(g, pad_to=1)
        import jax
        if len(jax.devices()) > 1:
            with pytest.raises(ValueError):
                triad_census_distributed(plan)


class TestPlanner:
    def test_balance_stats(self):
        g = scale_free_digraph(n=2000, avg_degree=10, exponent=1.8, seed=2)
        plan = build_plan(g, pad_to=64)
        stats = plan.balance_stats(64)
        assert stats["flat_max_over_mean"] <= 1.01
        # the flat plan must beat pair-granular partitioning on power law
        assert stats["pair_max_over_mean"] >= stats["flat_max_over_mean"]

    def test_item_count(self):
        g = erdos_renyi_digraph(50, 0.2, seed=1)
        plan = build_plan(g, prune_self=False)
        deg = g.degrees
        expect = sum(int(deg[u] + deg[v])
                     for u, v in zip(plan.pair_u, plan.pair_v))
        assert plan.num_items == expect
        # self-item pruning removes exactly 2 items per pair
        pruned = build_plan(g, prune_self=True)
        assert pruned.num_items == expect - 2 * plan.num_pairs

    def test_prune_self_same_census(self):
        g = scale_free_digraph(n=400, avg_degree=8, exponent=2.2,
                               mutual_p=0.4, seed=9)
        c1 = triad_census(build_plan(g, prune_self=False))
        c2 = triad_census(build_plan(g, prune_self=True))
        assert (c1 == c2).all()
