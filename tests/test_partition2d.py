"""2D pair×vertex decomposition: shard the halos, not just the pairs.

Central properties:

* **Range-count exactness** — the pre/post-prune closed forms restricted
  to a witness range ``[lo, hi)`` sum over any slice partition of
  ``[0, n)`` to the global counts, for both orients and both
  ``prune_self`` settings.  This is what makes per-tile item sub-ranges
  additive bases for the streaming planner.
* **Item-space partition** — the union over a shard's V tiles of the
  emitted items, mapped back to global ``(pair, side, witness)``
  coordinates, equals the 1D shard's item set exactly.  No item is lost,
  duplicated, or moved across pair-shard boundaries.
* **Mesh invariance** — censuses are bit-identical across 2D mesh
  shapes, the 1D path, and the Batagelj–Mrvar reference, for both
  orients, both emit modes and both schedules, full runs and
  incremental sessions.
* **Halo sharding** — the per-device resident adjacency entries (the
  halo the decomposition targets) shrink vs 1D at the same device count.
"""

import time

import jax
import numpy as np
import pytest

from repro.core import (
    CensusEngine, apply_delta, census_batagelj_mrvar, default_mesh,
    extract_shard, from_edges, lpt_assign, lpt_assign_heap, pair_space,
    partition_graph, partition_graph_2d, scale_free_digraph, shard_report,
    triad_census_graph, vertex_slices)
from repro.core.partition import GraphPartition2D, slice_pair_terms
from repro.core.plan_stream import ShardStreamPipeline
from repro.core.planner import (
    emit_items, global_bases, postprune_pair_counts,
    range_postprune_pair_counts, range_preprune_pair_counts)


@pytest.fixture(scope="module", autouse=True)
def _shed_compile_cache():
    """Drop compiled executables around this module.  The mesh-shape ×
    orient × emit × schedule sweeps below compile many distinct
    multi-device programs; stacked on the rest of the suite's cache in
    one process, the XLA CPU backend can segfault in a later
    ``backend_compile`` (jaxlib 0.4.x).  Clearing before and after keeps
    the per-process executable population bounded — per-test "compiled
    at most once" assertions elsewhere are per-engine-session and
    unaffected."""
    jax.clear_caches()
    yield
    jax.clear_caches()


def pl_graph(n=100, deg=5, seed=7, mutual_p=0.3):
    return scale_free_digraph(n=n, avg_degree=deg, exponent=2.2,
                              mutual_p=mutual_p, seed=seed)


def hub_graph(n=40, hub_out=24, extra=60, seed=0):
    """Graph with one dominant hub vertex (vertex 0)."""
    rng = np.random.default_rng(seed)
    src = [0] * hub_out + list(rng.integers(0, n, extra))
    dst = list(range(1, hub_out + 1)) + list(rng.integers(0, n, extra))
    return from_edges(src, dst, n=max(n, hub_out + 1))


def random_bounds(n, v, rng):
    """Random monotone slice bounds covering [0, n), possibly with empty
    slices."""
    cuts = np.sort(rng.integers(0, n + 1, size=v - 1))
    return np.concatenate([[0], cuts, [n]]).astype(np.int64)


# ------------------------------------------------- range closed forms


class TestRangeCounts:
    @pytest.mark.parametrize("orient", ["none", "degree"])
    @pytest.mark.parametrize("prune_self", [True, False])
    def test_partition_additivity(self, orient, prune_self):
        """Sliced pre/post counts sum to the global closed forms over ANY
        partition of the witness range — including empty slices."""
        rng = np.random.default_rng(0)
        for seed in range(3):
            g = pl_graph(n=80, deg=5, seed=seed)
            sp = pair_space(g, orient=orient, prune_self=prune_self)
            pre_g = sp.counts
            post_g = postprune_pair_counts(sp)
            for v in (1, 2, 3, 5):
                b = random_bounds(g.n, v, rng)
                pre = sum(range_preprune_pair_counts(sp, b[j], b[j + 1])
                          for j in range(v))
                post = sum(range_postprune_pair_counts(sp, b[j], b[j + 1])
                           for j in range(v))
                np.testing.assert_array_equal(pre, pre_g)
                np.testing.assert_array_equal(post, post_g)

    def test_full_range_is_global(self):
        sp = pair_space(pl_graph(seed=3), orient="degree")
        np.testing.assert_array_equal(
            range_postprune_pair_counts(sp, 0, sp.n),
            postprune_pair_counts(sp))

    def test_validation(self):
        sp = pair_space(pl_graph(seed=1))
        with pytest.raises(ValueError):
            range_preprune_pair_counts(sp, -1, 5)
        with pytest.raises(ValueError):
            range_postprune_pair_counts(sp, 7, 3)

    @pytest.mark.parametrize("orient", ["none", "degree"])
    def test_designated_terms_sum_to_global(self, orient):
        """slice_pair_terms credits each pair's dyadic base term to
        exactly one slice, so per-tile bases stay additive."""
        g = pl_graph(n=90, deg=4, seed=5)
        sp = pair_space(g, orient=orient)
        bounds = vertex_slices(sp, 3)
        terms = slice_pair_terms(sp, bounds)
        np.testing.assert_array_equal(sum(terms), sp.pair_term)
        # each pair designated exactly once (terms can be zero, so check
        # via the designation predicate, not the term value)
        pre = np.stack([range_preprune_pair_counts(sp, bounds[j],
                                                   bounds[j + 1]) > 0
                        for j in range(3)])
        assert (pre.sum(axis=0) >= 1).all()


# ------------------------------------------------------ vertex slices


class TestVertexSlices:
    def test_bounds_cover_and_monotone(self):
        sp = pair_space(pl_graph(n=120, deg=6, seed=2))
        for v in (1, 2, 4, 7):
            b = vertex_slices(sp, v)
            assert b.shape == (v + 1,)
            assert b[0] == 0 and b[-1] == sp.n
            assert (np.diff(b) >= 0).all()

    def test_entry_mass_balanced(self):
        """Each slice's CSR entry mass stays near the ideal share (up to
        one hub's granularity)."""
        sp = pair_space(pl_graph(n=400, deg=6, seed=3))
        mass = np.bincount(sp.nbr, minlength=sp.n)
        b = vertex_slices(sp, 4)
        per = np.array([mass[b[j]:b[j + 1]].sum() for j in range(4)])
        assert per.sum() == mass.sum()
        assert per.max() <= mass.sum() / 4 + mass.max()

    def test_empty_graph_even_split(self):
        g = from_edges([], [], n=12)
        b = vertex_slices(pair_space(g), 3)
        np.testing.assert_array_equal(b, [0, 4, 8, 12])


# ------------------------------------------- tile item-space partition


def tile_item_tuples(tile):
    """Emit a tile's surviving items as global (pair, side, witness)."""
    sp = tile.space
    pair, slot, side = emit_items(sp, 0, sp.num_items_preprune)
    gpair = tile.pair_ids[pair]
    gwit = tile.verts[tile.graph.packed[slot] >> 2]
    return set(zip(gpair.tolist(), side.tolist(), gwit.tolist()))


class TestTilePartition:
    @pytest.mark.parametrize("orient", ["none", "degree"])
    def test_tiles_partition_shard_items(self, orient):
        """Union of a shard's V tile item sets == the 1D shard's item
        set, and tiles are pairwise disjoint."""
        g = pl_graph(n=70, deg=5, seed=9)
        sp = pair_space(g, orient=orient)
        p1 = partition_graph(space=sp, num_shards=2)
        p2 = partition_graph_2d(space=sp, mesh_shape=(2, 3),
                                owner=p1.owner)
        for s in range(2):
            ref = tile_item_tuples(p1.shards[s])
            tiles = [p2.tile(s, j) for j in range(3)]
            sets = [tile_item_tuples(t) for t in tiles]
            union = set().union(*sets)
            assert union == ref
            assert sum(len(x) for x in sets) == len(ref)  # disjoint
            assert sum(t.items for t in tiles) == p1.shards[s].items

    def test_tile_items_field_matches_emitted(self):
        g = hub_graph()
        sp = pair_space(g, orient="degree")
        p2 = partition_graph_2d(space=sp, mesh_shape=(2, 2))
        for t in p2.shards:
            assert t.items == len(tile_item_tuples(t))

    def test_bases_additive_across_tiles(self):
        """Designated-slice pair terms make per-tile closed-form bases
        sum to the global bases."""
        g = pl_graph(n=60, deg=4, seed=13)
        for orient in ("none", "degree"):
            sp = pair_space(g, orient=orient)
            p2 = partition_graph_2d(space=sp, mesh_shape=(2, 2))
            tot = sum(np.asarray(global_bases(t.space)) for t in p2.shards)
            np.testing.assert_array_equal(tot, np.asarray(global_bases(sp)))


# ------------------------------------------ slice-aware extract_shard


class TestExtractShardSlices:
    def test_isolated_vertices(self):
        """Vertices with no arcs never enter any tile's vertex table and
        the census still matches the oracle (isolated triads come from
        the closed-form base, not items)."""
        src = [0, 1, 2, 3]
        dst = [1, 2, 3, 0]
        g = from_edges(src, dst, n=12)  # vertices 4..11 isolated
        part = partition_graph_2d(g, mesh_shape=(2, 2))
        iso = np.flatnonzero(np.diff(np.asarray(g.indptr)) == 0)
        for t in part.shards:
            assert not np.isin(t.verts, iso).any()
        c = triad_census_graph(g, mesh=default_mesh(4), partition_2d=(2, 2))
        np.testing.assert_array_equal(c, census_batagelj_mrvar(g))

    def test_one_hub_shard(self):
        """A shard dominated by one hub slices the hub's row across V
        tiles: tile row degrees sum to the full row."""
        g = hub_graph(n=30, hub_out=24, extra=10, seed=4)
        sp = pair_space(g)
        part = partition_graph_2d(space=sp, mesh_shape=(1, 4))
        deg = np.diff(np.asarray(g.indptr))
        hub = int(np.argmax(deg))
        got = 0
        for t in part.shards:
            loc = np.searchsorted(t.verts, hub)
            if loc < t.verts.shape[0] and t.verts[loc] == hub:
                ld = int(t.graph.indptr[loc + 1] - t.graph.indptr[loc])
                lo, hi = t.vertex_range
                nbrs = np.asarray(g.packed[g.indptr[hub]:g.indptr[hub + 1]]
                                  ) >> 2
                assert ld == int(((nbrs >= lo) & (nbrs < hi)).sum())
                got += ld
        assert got == deg[hub]
        c = triad_census_graph(g, mesh=default_mesh(4), partition_2d=(1, 4))
        np.testing.assert_array_equal(c, census_batagelj_mrvar(g))

    def test_pair_with_empty_slice_range_dropped(self):
        """A pair whose witness range has no pre-prune items in a slice
        is dropped from that tile (the pre-filter), yet survives in its
        designated slice even when ALL its post-prune items prune away
        there."""
        # two mutual dyads: pair (0,1) has only self-witness items
        g = from_edges([0, 1, 2, 3], [1, 0, 3, 2], n=4)
        sp = pair_space(g)
        assert (postprune_pair_counts(sp) == 0).all()
        part = partition_graph_2d(space=sp, mesh_shape=(1, 2))
        # every pair still present in exactly its designated slice(s)
        held = sum(t.num_pairs for t in part.shards)
        assert held >= sp.num_pairs
        c = triad_census_graph(g, mesh=default_mesh(2), partition_2d=(1, 2))
        np.testing.assert_array_equal(c, census_batagelj_mrvar(g))

    def test_vertex_range_recorded(self):
        g = pl_graph(n=50, seed=21)
        part = partition_graph_2d(g, mesh_shape=(2, 2))
        for s in range(2):
            for j in range(2):
                t = part.tile(s, j)
                assert t.vertex_range == (int(part.vertex_bounds[j]),
                                          int(part.vertex_bounds[j + 1]))
        # 1D extraction keeps vertex_range unset
        sp = pair_space(g)
        sh = extract_shard(sp, np.arange(min(5, sp.num_pairs)))
        assert sh.vertex_range is None


# ------------------------------------------------- partition_graph_2d


class TestPartition2D:
    def test_flat_tile_layout(self):
        part = partition_graph_2d(pl_graph(seed=2), mesh_shape=(3, 2))
        assert isinstance(part, GraphPartition2D)
        assert part.num_shards == 6
        assert part.pair_shards == 3 and part.num_vertex_slices == 2
        for s in range(3):
            for j in range(2):
                assert part.tile(s, j) is part.shards[s * 2 + j]

    def test_degenerate_meshes_match_1d(self):
        """(P, 1) is exactly the 1D partition; (1, V) holds every pair
        on one shard with sliced rows."""
        g = pl_graph(n=60, deg=4, seed=6)
        sp = pair_space(g)
        p1 = partition_graph(space=sp, num_shards=4)
        p2 = partition_graph_2d(space=sp, mesh_shape=(4, 1),
                                owner=p1.owner)
        for a, b in zip(p1.shards, p2.shards):
            np.testing.assert_array_equal(a.pair_ids, b.pair_ids)
            np.testing.assert_array_equal(a.verts, b.verts)
            np.testing.assert_array_equal(a.graph.packed, b.graph.packed)
            assert a.items == b.items

    def test_halo_shrinks_vs_1d(self):
        """The tentpole: per-device resident adjacency entries at
        (P, V) sit at the 1D level for P shards — strictly below the 1D
        level at P*V shards once replication bites."""
        g = pl_graph(n=400, deg=8, seed=3)
        sp = pair_space(g)
        p1 = partition_graph(space=sp, num_shards=8)
        p2 = partition_graph_2d(space=sp, mesh_shape=(4, 2))
        assert max(p2.stats.shard_entries) < max(p1.stats.shard_entries)
        assert p2.stats.entry_replication < p1.stats.entry_replication

    def test_stats_report_2d(self):
        part = partition_graph_2d(pl_graph(seed=8), mesh_shape=(2, 2))
        rep = shard_report(part)
        assert "mesh=2x2" in rep and "1,1" in rep
        assert "replication" in rep
        assert part.stats.mesh_shape == (2, 2)

    def test_validation(self):
        g = pl_graph(seed=1)
        with pytest.raises(ValueError):
            partition_graph_2d(g, mesh_shape=(0, 2))
        sp = pair_space(g)
        with pytest.raises(ValueError):
            partition_graph_2d(space=sp, mesh_shape=(2, 2),
                               vertex_bounds=np.array([0, 5, 4, g.n]))
        with pytest.raises(ValueError):
            partition_graph_2d(space=sp, mesh_shape=(2, 2),
                               owner=np.full(sp.num_pairs, 7))


# -------------------------------------------------- mesh invariance


MESHES_8 = [(8, 1), (4, 2), (2, 4), (1, 8)]


class TestMeshInvariance:
    @pytest.mark.parametrize("mesh_shape", MESHES_8)
    def test_bit_identical_across_shapes(self, mesh_shape):
        g = pl_graph(n=120, deg=5, seed=17)
        ref = census_batagelj_mrvar(g)
        c = triad_census_graph(g, mesh=default_mesh(8),
                               partition_2d=mesh_shape)
        np.testing.assert_array_equal(c, ref)

    @pytest.mark.parametrize("orient", ["none", "degree"])
    @pytest.mark.parametrize("emit", ["device", "host"])
    def test_orient_emit_sweep(self, orient, emit):
        g = pl_graph(n=90, deg=4, seed=19)
        ref = census_batagelj_mrvar(g)
        c = triad_census_graph(g, mesh=default_mesh(4), orient=orient,
                               emit=emit, partition_2d=(2, 2))
        np.testing.assert_array_equal(c, ref)

    @pytest.mark.parametrize("schedule", ["async", "lockstep"])
    def test_schedules_and_streaming(self, schedule):
        """The async/lock-step/megastep machinery runs unmodified over
        the 2D tile queue set."""
        g = pl_graph(n=110, deg=5, seed=23)
        ref = census_batagelj_mrvar(g)
        eng = CensusEngine(mesh=default_mesh(8), partition_2d=(4, 2),
                           schedule=schedule)
        c = eng.run(g, max_items=500)
        np.testing.assert_array_equal(c, ref)
        assert eng.stats.partition_shape == (4, 2)

    def test_matches_1d_partition_exactly(self):
        g = pl_graph(n=100, deg=5, seed=29)
        m = default_mesh(8)
        c1 = triad_census_graph(g, mesh=m, partition=True)
        c2 = triad_census_graph(g, mesh=m, partition_2d=(4, 2))
        np.testing.assert_array_equal(c1, c2)

    def test_engine_validates_mesh_shape(self):
        with pytest.raises(ValueError):
            CensusEngine(mesh=default_mesh(8), partition_2d=(3, 2))
        with pytest.raises(ValueError):
            CensusEngine(mesh=default_mesh(4), partition_2d=(4, 0))


# ------------------------------------------------------- 2D sessions


class TestSession2D:
    def test_update_parity_with_reference(self):
        rng = np.random.default_rng(31)
        g = pl_graph(n=80, deg=4, seed=31)
        eng = CensusEngine(mesh=default_mesh(8), partition_2d=(4, 2))
        sess = eng.session(g)
        np.testing.assert_array_equal(sess.census(), census_batagelj_mrvar(g))
        for _ in range(3):
            add_s = rng.integers(0, g.n, 3)
            add_d = (add_s + 1 + rng.integers(0, g.n - 1, 3)) % g.n
            g, _ = apply_delta(g, add_src=add_s, add_dst=add_d)
            c = sess.update(add_src=add_s, add_dst=add_d)
            np.testing.assert_array_equal(c, census_batagelj_mrvar(g))

    def test_rebalance_preserves_census(self):
        g = pl_graph(n=70, deg=4, seed=37)
        eng = CensusEngine(mesh=default_mesh(4), partition_2d=(2, 2))
        sess = eng.session(g)
        c0 = sess.census()
        sess.rebalance()
        np.testing.assert_array_equal(sess.census(), c0)


# ----------------------------------------------- satellite regressions


class TestLPTZeroCosts:
    def test_all_zero_costs_balanced_and_valid(self):
        """Regression: all-zero costs used to pile every exact-head pair
        onto shard 0 while the tail round-robined — now the degenerate
        case short-circuits to the (trivially balanced) all-zeros
        assignment, matching the heap oracle."""
        for size in (10, 4096, 10_000):
            owner = lpt_assign(np.zeros(size, np.int64), 8)
            assert owner.shape == (size,)
            np.testing.assert_array_equal(
                owner, lpt_assign_heap(np.zeros(size, np.int64), 8))

    def test_empty_costs(self):
        for ns in (1, 4):
            assert lpt_assign(np.zeros(0, np.int64), ns).shape == (0,)


class TestPipelineExceptionCleanup:
    def test_close_reaps_raising_producer_with_full_queue(self):
        """Regression: a producer that raised while its bounded queue
        was full (consumer gone) blocked forever in ``q.put(exc)`` and
        leaked a daemon thread past close().  The exception/done paths
        now use a stop-aware offer and close() drains every queue before
        joining."""
        def poisoned():
            yield "w0"  # fills the depth-1 queue; never consumed
            raise RuntimeError("injected planner failure")

        pipe = ShardStreamPipeline([poisoned()], depth=1)
        # wait until the producer is parked trying to deliver the
        # exception into the already-full queue (the old deadlock state)
        deadline = time.monotonic() + 2.0
        while time.monotonic() < deadline and pipe._queues[0].qsize() == 0:
            time.sleep(0.01)
        time.sleep(0.1)
        pipe.close()
        for t in pipe._threads:
            t.join(timeout=2.0)
        assert not any(t.is_alive() for t in pipe._threads), \
            "producer thread leaked past close()"

    def test_exception_propagates_then_close_joins(self):
        """A raising source surfaces in the consumer; close() afterwards
        reaps both the failed and the still-backlogged producer."""
        def poisoned():
            yield 1
            raise RuntimeError("injected planner failure")

        pipe = ShardStreamPipeline([poisoned(), iter(range(64))], depth=1)
        with pytest.raises(RuntimeError, match="injected"):
            for _ in pipe:
                pass
        pipe.close()
        assert not any(t.is_alive() for t in pipe._threads)

    def test_close_idempotent_after_normal_drain(self):
        pipe = ShardStreamPipeline([iter(range(3)), iter(range(2))],
                                   depth=2)
        got = sorted(w for _, w in pipe)
        assert got == [0, 0, 1, 1, 2]
        pipe.close()
        pipe.close()
        assert not any(t.is_alive() for t in pipe._threads)
