"""Sharding rules: divisibility fallbacks, uniqueness, batch combos,
cache specs — on a small (2, 2)-mesh stand-in for (data, model)."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.parallel.sharding import (
    activation_spec, batch_axes, cache_leaf_spec, spec_for_axes,
    tree_shardings)


@pytest.fixture(scope="module")
def mesh():
    n = len(jax.devices())
    if n < 4:
        pytest.skip("needs >= 4 devices")
    return jax.make_mesh((2, 2), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)


class TestSpecForAxes:
    def test_ffn_weight(self, mesh):
        s = spec_for_axes(("embed", "ffn"), (896, 4864), mesh)
        assert s == P("data", "model")

    def test_divisibility_fallback(self, mesh):
        # 7 heads cannot shard over a 2-way model axis
        s = spec_for_axes(("embed", "heads", "head_dim"),
                          (896, 7, 64), mesh)
        assert s == P("data", None, None)

    def test_mesh_axis_used_once(self, mesh):
        # experts takes model; ffn (also model-preferring) must fall back
        s = spec_for_axes(("experts", "embed", "ffn"), (64, 896, 512),
                          mesh)
        assert s == P("model", "data", None)

    def test_batch_combo(self, mesh):
        assert batch_axes(mesh, 256) == "data"
        s = spec_for_axes(("batch", None), (256, 128), mesh)
        assert s == P("data", None)

    def test_batch_of_one_replicated(self, mesh):
        assert batch_axes(mesh, 1) is None

    def test_pod_combo(self):
        n = len(jax.devices())
        if n < 8:
            pytest.skip("needs >= 8 devices")
        m3 = jax.make_mesh((2, 2, 2), ("pod", "data", "model"),
                           axis_types=(jax.sharding.AxisType.Auto,) * 3)
        assert batch_axes(m3, 8) == ("pod", "data")
        assert batch_axes(m3, 2) == "data"


class TestActivationAndCacheSpecs:
    def test_activation_seq_shard(self, mesh):
        s = activation_spec(mesh, 256, 4096)
        assert s == P("data", "model", None)

    def test_activation_odd_seq_falls_back(self, mesh):
        s = activation_spec(mesh, 256, 4097)
        assert s == P("data", None, None)

    def test_kv_cache_spec(self, mesh):
        s = cache_leaf_spec(("layers", "0", "k"), (128, 32768, 8, 64),
                            mesh, 128)
        assert s == P("data", "model", None, None)

    def test_mlstm_state_spec(self, mesh):
        s = cache_leaf_spec(("layers", "3", "c"), (1, 4, 1024, 1024),
                            mesh, 1)
        assert s == P(None, None, "model", None)

    def test_scalar_spec(self, mesh):
        assert cache_leaf_spec(("pos",), (), mesh, 128) == P()


class TestEndToEndParamShardings:
    def test_all_archs_produce_valid_shardings(self, mesh):
        """Every param of every arch gets a spec whose sharded dims all
        divide evenly — the invariant that makes the dry-run compile."""
        from repro.configs import all_configs
        from repro.models.model import make_abstract_params, params_axes
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        for arch in all_configs():
            absp = make_abstract_params(
                __import__("repro.configs", fromlist=["get_config"]
                           ).get_config(arch))
            axes = params_axes(
                __import__("repro.configs", fromlist=["get_config"]
                           ).get_config(arch))
            shardings = tree_shardings(axes, absp, mesh)

            def check(sh, ab):
                spec = sh.spec
                for dim, part in enumerate(spec):
                    if part is None:
                        continue
                    parts = part if isinstance(part, tuple) else (part,)
                    total = int(np.prod([sizes[a] for a in parts]))
                    assert ab.shape[dim] % total == 0, (arch, ab.shape,
                                                        spec)
            jax.tree.map(check, shardings, absp)
