"""Streaming census engine: chunk-boundary correctness, zero-item plans,
compile-once chunk steps, chunker invariants, vectorized digraph helpers.

The central property: for ANY ``max_items`` — including budgets smaller
than a single hub pair's item count, which force intra-pair chunk splits —
the streamed census is bit-identical to the monolithic oracle, for every
backend, both orient modes, and both drivers (single-device and mesh)."""

import numpy as np
import pytest

from repro.core import (
    CensusEngine, PlanChunker, build_plan, census_batagelj_mrvar,
    default_mesh, from_edges, iter_plan_chunks, scale_free_digraph,
    to_dense, triad_census, triad_census_distributed, triad_census_graph,
    unpack_items)
from repro.core.digraph import CompactDigraph
from repro.core.planner import emit_items, global_bases, pair_space


def hub_graph(n=24, hub_out=16, extra=40, seed=0):
    """Graph with a guaranteed hub: pair (hub, v) costs > hub_out items,
    so any max_items < hub_out forces intra-pair chunk splits."""
    rng = np.random.default_rng(seed)
    src = [0] * hub_out + list(rng.integers(0, n, extra))
    dst = list(range(1, hub_out + 1)) + list(rng.integers(0, n, extra))
    return from_edges(src, dst, n=max(n, hub_out + 1))


# ------------------------------------------------------------- chunker


class TestPlanChunker:
    @pytest.mark.parametrize("orient", ["none", "degree"])
    @pytest.mark.parametrize("max_items", [1, 3, 17, 101, 10**6])
    def test_chunks_partition_the_monolithic_plan(self, orient, max_items):
        g = hub_graph()
        plan = build_plan(g, orient=orient)
        chunks = list(iter_plan_chunks(g, max_items, orient=orient))
        # concatenated valid chunk items == the monolithic plan's items
        sp = np.concatenate([c.item_sp[:0] if c.num_items == 0 else
                             c.item_sp[np.asarray(
                                 (c.item_pv & 1) == 1)] for c in chunks])
        pv = np.concatenate([c.item_pv[np.asarray(
            (c.item_pv & 1) == 1)] for c in chunks])
        valid = plan.item_valid
        np.testing.assert_array_equal(sp, plan.item_sp[valid])
        np.testing.assert_array_equal(pv, plan.item_pv[valid])
        assert sum(c.num_items for c in chunks) == plan.num_items

    @pytest.mark.parametrize("orient", ["none", "degree"])
    def test_bases_are_additive(self, orient):
        g = hub_graph(seed=3)
        plan = build_plan(g, orient=orient)
        for max_items in (1, 7, 50):
            chunks = list(iter_plan_chunks(g, max_items, orient=orient))
            assert sum(c.base_asym for c in chunks) == plan.base_asym
            assert sum(c.base_mut for c in chunks) == plan.base_mut

    def test_budget_and_fixed_shape(self):
        g = hub_graph(seed=1)
        ck = PlanChunker(g, max_items=8, pad_to=8)
        assert ck.chunk_shape % 8 == 0
        for c in ck:
            assert c.num_items <= 8
            assert c.item_sp.shape == (ck.chunk_shape,)
            assert c.item_pv.shape == (ck.chunk_shape,)
            # padding is all-invalid
            _, _, _, valid = unpack_items(c.item_sp, c.item_pv)
            assert valid[:c.num_items].all()
            assert not valid[c.num_items:].any()

    def test_intra_pair_split_occurs(self):
        """With max_items below the hub pair's cost, some pair must span
        two consecutive chunks — the boundary case this PR exists for."""
        g = hub_graph()
        chunks = list(iter_plan_chunks(g, max_items=4))
        last_pair_per_chunk = []
        first_pair_per_chunk = []
        for c in chunks:
            _, _, pair, valid = unpack_items(c.item_sp, c.item_pv)
            if valid.any():
                first_pair_per_chunk.append(pair[valid][0])
                last_pair_per_chunk.append(pair[valid][-1])
        crossing = any(a == b for a, b in zip(last_pair_per_chunk,
                                              first_pair_per_chunk[1:]))
        assert crossing, "no pair spanned a chunk boundary"

    def test_rejects_bad_budget(self):
        g = hub_graph()
        with pytest.raises(ValueError):
            PlanChunker(g, max_items=0)
        with pytest.raises(ValueError):
            PlanChunker(g, max_items=8, pad_to=0)

    def test_empty_graph_has_no_chunks(self):
        ck = PlanChunker(from_edges([], [], n=6), max_items=8)
        assert len(ck) == 0 and list(ck) == []

    def test_emit_items_rejects_bad_slice(self):
        sp = pair_space(hub_graph())
        with pytest.raises(ValueError):
            emit_items(sp, -1, 5)
        with pytest.raises(ValueError):
            emit_items(sp, 0, sp.num_items_preprune + 1)


# ------------------------------------------------------------- parity

#: fast sweep on the pure-XLA backend; the Pallas backends run per-chunk
#: interpret-mode kernels on CPU, so they sweep a reduced budget set that
#: still includes an intra-pair-splitting budget (hub pair cost > 8)
SWEEP = {"jnp": (1, 3, 17, 101), "pallas": (8, 64),
         "pallas-fused": (8, 64)}


class TestStreamedEqualsMonolithic:
    @pytest.mark.parametrize("backend", ["jnp", "pallas", "pallas-fused"])
    @pytest.mark.parametrize("orient", ["none", "degree"])
    def test_single_device(self, backend, orient):
        g = hub_graph(seed=5)
        want = triad_census(build_plan(g, orient=orient), backend=backend)
        np.testing.assert_array_equal(
            want, census_batagelj_mrvar(g))   # monolithic oracle anchor
        engine = CensusEngine(backend=backend)
        for max_items in SWEEP[backend]:
            got = engine.run(g, max_items=max_items, orient=orient)
            np.testing.assert_array_equal(
                got, want, err_msg=f"max_items={max_items}")

    @pytest.mark.parametrize("backend", ["jnp", "pallas", "pallas-fused"])
    @pytest.mark.parametrize("orient", ["none", "degree"])
    def test_mesh_driver(self, backend, orient):
        g = hub_graph(seed=6)
        mesh = default_mesh()
        want = census_batagelj_mrvar(g)
        max_items = 13 if backend == "jnp" else 64
        got = triad_census_graph(g, mesh=mesh, backend=backend,
                                 orient=orient, max_items=max_items)
        np.testing.assert_array_equal(got, want)

    def test_scale_free_sweep(self):
        g = scale_free_digraph(n=250, avg_degree=9, exponent=2.0,
                               mutual_p=0.35, seed=11)
        want = census_batagelj_mrvar(g)
        engine = CensusEngine(backend="jnp")
        for max_items in (29, 500, 4096):
            for orient in ("none", "degree"):
                got = engine.run(g, max_items=max_items, orient=orient)
                np.testing.assert_array_equal(
                    got, want, err_msg=f"{max_items}/{orient}")

    @pytest.mark.parametrize("seed", range(4))
    def test_random_graphs_random_budgets(self, seed):
        rng = np.random.default_rng(300 + seed)
        n = int(rng.integers(3, 40))
        a = rng.random((n, n)) < float(rng.uniform(0.05, 0.4))
        np.fill_diagonal(a, False)
        g = from_edges(*np.nonzero(a), n=n)
        want = census_batagelj_mrvar(g)
        engine = CensusEngine(backend="jnp")
        for max_items in (1, int(rng.integers(2, 50)), 10**6):
            got = engine.run(g, max_items=max_items)
            np.testing.assert_array_equal(got, want)


# ------------------------------------------------------------- zero work


class TestZeroItemPlans:
    """A mutual dyad's only work items are self-items: pairs exist but the
    pruned plan is empty.  Regression for the phantom padded chunk."""

    @pytest.mark.parametrize("pad_to", [1, 8])
    def test_plan_is_zero_length(self, pad_to):
        g = from_edges([0, 1], [1, 0], n=4)
        plan = build_plan(g, pad_to=pad_to)
        assert plan.num_pairs == 1 and plan.num_items == 0
        assert plan.item_sp.shape == (0,) and plan.item_pv.shape == (0,)

    def test_single_device_driver(self):
        g = from_edges([0, 1], [1, 0], n=4)
        c = triad_census(build_plan(g))
        np.testing.assert_array_equal(c, census_batagelj_mrvar(g))
        assert c[2] == 2          # two 102 triads from the closed form

    def test_distributed_driver(self):
        g = from_edges([0, 1], [1, 0], n=4)
        mesh = default_mesh()
        plan = build_plan(g, pad_to=int(np.prod(mesh.devices.shape)))
        c = triad_census_distributed(plan, mesh=mesh)
        np.testing.assert_array_equal(c, census_batagelj_mrvar(g))

    def test_streamed(self):
        g = from_edges([0, 1], [1, 0], n=4)
        engine = CensusEngine(backend="jnp")
        c = engine.run(g, max_items=4)
        np.testing.assert_array_equal(c, census_batagelj_mrvar(g))

    def test_empty_graph_all_paths(self):
        g = from_edges([], [], n=10)
        want = census_batagelj_mrvar(g)
        np.testing.assert_array_equal(triad_census(build_plan(g)), want)
        np.testing.assert_array_equal(
            triad_census_graph(g, max_items=8), want)


# ------------------------------------------------------------- engine


class TestEngineMechanics:
    def test_step_compiles_once_across_chunks(self):
        g = scale_free_digraph(n=200, avg_degree=8, exponent=2.1,
                               mutual_p=0.3, seed=4)
        engine = CensusEngine(backend="jnp")
        engine.run(g, max_items=97)
        st = engine.stats
        assert st.chunks > 4
        # fixed chunk shape → at most one fresh compilation for the whole
        # stream (0 if an earlier test already compiled this shape)
        assert st.step_compiles <= 1, st.step_compiles
        assert st.streamed and st.chunk_shape >= 97 >= max(st.chunk_items)

    def test_stats_match_plan(self):
        g = scale_free_digraph(n=150, avg_degree=6, exponent=2.2,
                               mutual_p=0.3, seed=9)
        plan = build_plan(g)
        engine = CensusEngine(backend="jnp")
        engine.run(g, max_items=64)
        st = engine.stats
        assert st.items == plan.num_items
        assert sum(st.chunk_items) == plan.num_items
        assert st.peak_plan_bytes == 8 * st.chunk_shape
        assert st.monolithic_plan_bytes >= 8 * plan.num_items
        assert st.chunk_max_over_mean >= 1.0
        assert "streamed" in st.summary()

    def test_balance_stats_reports_streamed_schedule(self):
        g = scale_free_digraph(n=150, avg_degree=6, exponent=2.2,
                               mutual_p=0.3, seed=9)
        plan = build_plan(g)
        engine = CensusEngine(backend="jnp")
        engine.run(g, max_items=64)
        st = plan.balance_stats(8, max_items=64)
        # the planner's predicted chunk schedule is the engine's actual one
        assert st["chunks"] == engine.stats.chunks
        assert st["chunk_items"] == engine.stats.chunk_items
        assert st["chunk_max_over_mean"] == pytest.approx(
            engine.stats.chunk_max_over_mean)

    def test_progress_hook(self):
        g = hub_graph(seed=2)
        seen = []
        engine = CensusEngine(backend="jnp")
        engine.run(g, max_items=50,
                   progress=lambda k, total, items: seen.append(
                       (k, total, items)))
        assert len(seen) == engine.stats.chunks
        assert [k for k, _, _ in seen] == list(range(len(seen)))
        assert all(t == len(seen) for _, t, _ in seen)
        assert [i for _, _, i in seen] == engine.stats.chunk_items

    def test_report_streaming_section(self):
        from repro.analysis.report import streaming_section
        g = scale_free_digraph(n=120, avg_degree=6, exponent=2.2,
                               mutual_p=0.3, seed=12)
        engine = CensusEngine(backend="jnp")
        engine.run(g, max_items=200)
        md = streaming_section(engine.stats)
        assert "§Streaming schedule" in md
        assert f"{engine.stats.chunks} chunks" in md
        for items in engine.stats.chunk_items[:3]:
            assert f"| {items} |" in md
        assert "max-over-mean" in md
        # long schedules elide the middle instead of exploding the table
        engine.run(g, max_items=20)
        md = streaming_section(engine.stats)
        assert engine.stats.chunks > 16 and "| … | … | … |" in md

    def test_monolithic_run_records_stats(self):
        g = hub_graph(seed=7)
        engine = CensusEngine(backend="jnp")
        want = census_batagelj_mrvar(g)
        np.testing.assert_array_equal(engine.run(g), want)
        st = engine.stats
        assert not st.streamed and st.chunks == 1
        assert st.items == build_plan(g).num_items

    def test_rejects_unknown_backend(self):
        with pytest.raises(ValueError):
            CensusEngine(backend="cuda")

    def test_rejects_unpadded_plan_on_mesh(self):
        import jax
        if len(jax.devices()) <= 1:
            pytest.skip("single device")
        g = hub_graph(seed=8)
        plan = build_plan(g, pad_to=1)
        if plan.item_sp.shape[0] % len(jax.devices()) == 0:
            pytest.skip("accidentally aligned")
        with pytest.raises(ValueError):
            CensusEngine(mesh=default_mesh()).run_plan(plan)


# ------------------------------------------------------- vectorized digraph


def _to_dense_loop(g: CompactDigraph) -> np.ndarray:
    """The original O(n)-Python-loop implementation, kept as the oracle."""
    a = np.zeros((g.n, g.n), dtype=bool)
    for u in range(g.n):
        nb, cd = g.neighbors(u), g.codes(u)
        a[u, nb[(cd & 1) != 0]] = True
        a[nb[(cd & 2) != 0], u] = True
    return a


class TestVectorizedDigraph:
    @pytest.mark.parametrize("seed", range(8))
    def test_to_dense_matches_loop_oracle(self, seed):
        rng = np.random.default_rng(seed)
        n = int(rng.integers(1, 60))
        a = rng.random((n, n)) < float(rng.uniform(0.0, 0.5))
        np.fill_diagonal(a, False)
        g = from_edges(*np.nonzero(a), n=n)
        np.testing.assert_array_equal(to_dense(g), _to_dense_loop(g))
        np.testing.assert_array_equal(to_dense(g), a)

    def test_validate_accepts_valid_graphs(self):
        for g in (from_edges([], [], n=5),          # empty
                  from_edges([0], [4], n=9),        # isolated vertices
                  hub_graph(),                       # hub + empty rows
                  scale_free_digraph(n=300, avg_degree=7, exponent=2.1,
                                     mutual_p=0.3, seed=1)):
            g.validate()

    def test_validate_catches_unsorted_row(self):
        g = from_edges([0, 0, 1], [1, 2, 2], n=3)
        bad = CompactDigraph(n=g.n, indptr=g.indptr,
                             packed=g.packed[::-1].copy(),
                             num_arcs=g.num_arcs)
        with pytest.raises(AssertionError, match="not strictly sorted"):
            bad.validate()

    def test_validate_catches_zero_dir_code(self):
        g = from_edges([0, 1], [1, 2], n=3)
        packed = g.packed.copy()
        packed[0] &= ~np.int32(3)
        bad = CompactDigraph(n=g.n, indptr=g.indptr, packed=packed,
                             num_arcs=g.num_arcs)
        with pytest.raises(AssertionError, match="zero dir code"):
            bad.validate()

    def test_to_dense_empty(self):
        assert to_dense(from_edges([], [], n=4)).sum() == 0
