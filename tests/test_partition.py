"""Partitioned multi-device engine: shard the graph, not just the items.

Central properties:

* **Shard-count invariance** — censuses are bit-identical across
  1/2/4/8-device meshes, both orients, both emit modes, streamed and
  monolithic schedules, full runs and incremental sessions (the vertex
  relabeling is order-preserving and the pair partition is exact, so no
  per-item decision can differ).
* **Minimality** — each device holds only the CSR rows its pair shard's
  endpoints own (plus empty halo rows), so per-device resident graph
  bytes shrink vs the replicated baseline.
* **Routing** — an incremental update whose delta is confined to one
  shard dispatches NOTHING on the other devices.
"""

import numpy as np
import pytest

from repro.core import (
    CensusEngine, TriadMonitor, apply_delta, census_batagelj_mrvar,
    default_mesh, extract_shard, from_edges, lpt_assign, pair_space,
    partition_graph, replicated_graph_bytes, scale_free_digraph,
    shard_report, to_dense, triad_census_graph)
from repro.core.planner import emit_items_for_pairs, postprune_pair_counts


def pl_graph(n=100, deg=5, seed=7, mutual_p=0.3):
    return scale_free_digraph(n=n, avg_degree=deg, exponent=2.2,
                              mutual_p=mutual_p, seed=seed)


def hub_graph(n=40, hub_out=24, extra=60, seed=0):
    """Graph with one dominant hub vertex (vertex 0)."""
    rng = np.random.default_rng(seed)
    src = [0] * hub_out + list(rng.integers(0, n, extra))
    dst = list(range(1, hub_out + 1)) + list(rng.integers(0, n, extra))
    return from_edges(src, dst, n=max(n, hub_out + 1))


# ---------------------------------------------------------------- LPT


class TestLPT:
    def test_assignment_covers_all_pairs(self):
        space = pair_space(pl_graph())
        owner = lpt_assign(postprune_pair_counts(space), 4)
        assert owner.shape == (space.num_pairs,)
        assert owner.min() >= 0 and owner.max() < 4

    def test_balance_below_target_on_power_law(self):
        """The acceptance target: max/mean item imbalance ≤ 1.2 on a
        power-law graph at 8 shards."""
        part = partition_graph(pl_graph(n=400, deg=6, seed=3), 8)
        assert part.stats.max_over_mean <= 1.2
        assert sum(part.stats.shard_items) == part.stats.total_items

    def test_deterministic(self):
        costs = postprune_pair_counts(pair_space(pl_graph(seed=11)))
        a = lpt_assign(costs, 8)
        b = lpt_assign(costs, 8)
        np.testing.assert_array_equal(a, b)

    def test_single_shard_and_validation(self):
        costs = np.array([5, 3, 2], dtype=np.int64)
        np.testing.assert_array_equal(lpt_assign(costs, 1), [0, 0, 0])
        with pytest.raises(ValueError, match="num_shards"):
            lpt_assign(costs, 0)

    def test_small_inputs_match_heap_exactly(self):
        """≤ the exact-head cutoff the vectorized path delegates to the
        heap outright — bit-identical assignments, so every historical
        small-graph partition is preserved."""
        from repro.core import lpt_assign_heap
        rng = np.random.default_rng(5)
        for ns in (1, 2, 4, 8):
            costs = rng.integers(0, 100, size=700).astype(np.int64)
            np.testing.assert_array_equal(lpt_assign(costs, ns),
                                          lpt_assign_heap(costs, ns))

    def test_large_input_balance_matches_heap(self):
        """Above the cutoff the bucketed waterfill takes over; the
        assignment may differ from the heap but the achieved balance
        must match the heap oracle to within a hair."""
        from repro.core import lpt_assign_heap
        rng = np.random.default_rng(6)
        costs = np.minimum(rng.zipf(1.7, size=30_000), 50_000
                           ).astype(np.int64)
        for ns in (2, 4, 8):
            v = lpt_assign(costs, ns)
            assert v.shape == costs.shape
            assert v.min() >= 0 and v.max() < ns
            lv = np.bincount(v, weights=costs, minlength=ns)
            lh = np.bincount(lpt_assign_heap(costs, ns), weights=costs,
                             minlength=ns)
            assert lv.max() <= lh.max() * 1.01 + 1
            np.testing.assert_array_equal(lpt_assign(costs, ns), v)

    def test_large_input_is_vectorized_fast(self):
        """The point of the rewrite: millions of pairs assign in seconds
        where the python heap took minutes (loose bound — CI boxes)."""
        import time
        rng = np.random.default_rng(7)
        costs = np.minimum(rng.zipf(1.8, size=2_000_000), 10 ** 6
                           ).astype(np.int64)
        t0 = time.perf_counter()
        owner = lpt_assign(costs, 8)
        dt = time.perf_counter() - t0
        assert owner.shape == costs.shape
        loads = np.bincount(owner, weights=costs, minlength=8)
        assert loads.max() <= 1.05 * loads.sum() / 8
        assert dt < 10.0

    def test_zero_and_empty_costs(self):
        assert lpt_assign(np.zeros(0, np.int64), 4).shape == (0,)
        owner = lpt_assign(np.zeros(10_000, np.int64), 4)
        assert owner.min() >= 0 and owner.max() < 4

    def test_explicit_owner_override(self):
        """partition_graph(owner=...) takes ANY assignment — the skew
        hook — and validates shape + range."""
        g = pl_graph(n=50, seed=2)
        space = pair_space(g)
        owner = np.arange(space.num_pairs, dtype=np.int64) % 3
        part = partition_graph(g, num_shards=3, owner=owner)
        np.testing.assert_array_equal(part.owner, owner)
        with pytest.raises(ValueError, match="owner has"):
            partition_graph(g, num_shards=3, owner=owner[:-1])
        with pytest.raises(ValueError, match="outside"):
            partition_graph(g, num_shards=2, owner=owner)


# ----------------------------------------------------------- extraction


class TestExtractShard:
    def test_local_subgraph_invariants(self):
        g = pl_graph(seed=5)
        part = partition_graph(g, 4)
        all_ids = np.concatenate([sh.pair_ids for sh in part.shards])
        # shards tile the pair space exactly
        np.testing.assert_array_equal(np.sort(all_ids),
                                      np.arange(part.space.num_pairs))
        for sh in part.shards:
            # relabel table sorted (order-preserving) and consistent
            assert (np.diff(sh.verts) > 0).all()
            sh.graph.validate()
            # every local pair endpoint's row is the full global row,
            # relabeled
            for j in range(min(sh.num_pairs, 10)):
                gu = part.space.pair_u[sh.pair_ids[j]]
                lu = sh.space.pair_u[j]
                assert sh.verts[lu] == gu
                glob_row = part.space.nbr[
                    part.space.indptr[gu]:part.space.indptr[gu + 1]]
                loc_row = sh.graph.neighbors(lu)
                np.testing.assert_array_equal(sh.verts[loc_row], glob_row)
                np.testing.assert_array_equal(
                    sh.graph.codes(lu),
                    part.space.packed[part.space.indptr[gu]:
                                      part.space.indptr[gu + 1]] & 3)

    @pytest.mark.parametrize("orient", ["none", "degree"])
    def test_local_items_match_global_subset(self, orient):
        """The shard's local item emission is the global subset emission
        relabeled — same pair order, same slots' neighbor identities."""
        g = pl_graph(n=60, seed=9)
        space = pair_space(g, orient=orient)
        part = partition_graph(space=space, num_shards=3)
        for sh in part.shards:
            lp, ls, lside = emit_items_for_pairs(
                sh.space, np.arange(sh.num_pairs))
            gp, gs, gside = emit_items_for_pairs(space, sh.pair_ids)
            np.testing.assert_array_equal(lside, gside)
            # item pair ids map local -> global
            np.testing.assert_array_equal(sh.pair_ids[lp], gp)
            # gathered neighbor ids map through the relabel table
            np.testing.assert_array_equal(
                sh.verts[sh.space.nbr[ls]], space.nbr[gs])
            # post-prune per-shard items match the stats record
            assert lp.shape[0] == sh.items

    def test_resident_bytes_shrink(self):
        g = pl_graph(n=400, deg=6, seed=3)
        part = partition_graph(g, 8)
        rep = replicated_graph_bytes(part.space)
        assert part.stats.replicated_bytes == rep
        assert part.stats.max_shard_bytes * 2 <= rep
        assert part.stats.byte_reduction >= 2.0
        assert "reduction" in shard_report(part)

    def test_empty_and_tiny_shards(self):
        g = from_edges([0, 1], [1, 2], n=5)     # 2 pairs, 8 shards
        part = partition_graph(g, 8)
        empty = [sh for sh in part.shards if sh.num_pairs == 0]
        assert len(empty) == 6
        for sh in empty:
            assert sh.graph.n == 0 and sh.items == 0

    def test_bad_pair_ids_rejected(self):
        space = pair_space(pl_graph())
        with pytest.raises(ValueError, match="pair id"):
            extract_shard(space, [space.num_pairs])


# ------------------------------------------------- shard-count invariance


class TestShardCountInvariance:
    """Satellite: census bit-identical across 1/2/4/8 devices × both
    orients × emit host/device."""

    @pytest.mark.parametrize("num_devices", [1, 2, 4, 8])
    @pytest.mark.parametrize("orient", ["none", "degree"])
    @pytest.mark.parametrize("emit", ["device", "host"])
    def test_invariance(self, num_devices, orient, emit):
        g = pl_graph(n=70, seed=5)
        want = census_batagelj_mrvar(g)
        engine = CensusEngine(mesh=default_mesh(num_devices),
                              backend="jnp", partition=True, emit=emit)
        for max_items in (None, 120):
            got = engine.run(g, max_items=max_items, orient=orient)
            np.testing.assert_array_equal(got, want)
        st = engine.stats
        assert st.partitioned and len(st.shard_items) == num_devices
        assert st.emit == emit

    @pytest.mark.parametrize("backend", ["pallas", "pallas-fused"])
    def test_backends(self, backend):
        g = pl_graph(n=40, deg=4, seed=8)
        want = census_batagelj_mrvar(g)
        engine = CensusEngine(mesh=default_mesh(4), backend=backend,
                              partition=True)
        np.testing.assert_array_equal(engine.run(g), want)
        np.testing.assert_array_equal(engine.run(g, max_items=80), want)

    def test_hub_pairs_straddle_three_shards(self):
        """A hub vertex's pairs must straddle ≥ 3 shards (LPT scatters
        the heavy pairs) and the census must stay bit-identical."""
        g = hub_graph()
        part = partition_graph(g, 4)
        hub_owner = np.unique(part.owner[
            (part.space.pair_u == 0) | (part.space.pair_v == 0)])
        assert hub_owner.size >= 3
        want = census_batagelj_mrvar(g)
        got = triad_census_graph(g, mesh=default_mesh(4), partition=True)
        np.testing.assert_array_equal(got, want)

    def test_compile_once_across_steps(self):
        g = pl_graph(n=90, seed=21)
        engine = CensusEngine(mesh=default_mesh(4), backend="jnp",
                              partition=True, schedule="lockstep")
        engine.run(g, max_items=64)        # many lock-step windows
        assert engine.stats.chunks >= 4
        assert engine.stats.step_compiles <= 1

    def test_graph_bytes_reported(self):
        g = pl_graph(n=300, deg=6, seed=3)
        engine = CensusEngine(mesh=default_mesh(8), backend="jnp",
                              partition=True)
        engine.run(g)
        st = engine.stats
        assert st.graph_replicated_bytes >= 2 * st.graph_resident_bytes
        assert st.shard_max_over_mean <= 1.2
        assert "partitioned" in st.summary()

    def test_partition_requires_mesh(self):
        with pytest.raises(ValueError, match="mesh"):
            CensusEngine(partition=True)

    def test_run_plan_rejected(self):
        from repro.core import build_plan
        engine = CensusEngine(mesh=default_mesh(2), partition=True)
        with pytest.raises(ValueError, match="partitioned"):
            engine.run_plan(build_plan(pl_graph()))

    def test_empty_graph(self):
        g = from_edges(np.zeros(0, np.int64), np.zeros(0, np.int64), n=7)
        engine = CensusEngine(mesh=default_mesh(4), partition=True)
        got = engine.run(g)
        want = np.zeros(16, np.int64)
        want[0] = 7 * 6 * 5 // 6
        np.testing.assert_array_equal(got, want)


# ------------------------------------------------------------- sessions


def random_arcs(rng, n, k):
    return rng.integers(0, n, k), rng.integers(0, n, k)


class TestPartitionedSession:
    @pytest.mark.parametrize("emit", ["device", "host"])
    @pytest.mark.parametrize("orient", ["none", "degree"])
    def test_updates_match_oracle(self, emit, orient):
        rng = np.random.default_rng(13)
        g = pl_graph(n=40, deg=4, seed=13)
        session = CensusEngine(mesh=default_mesh(4), backend="jnp",
                               partition=True, emit=emit).session(
            g, orient=orient, max_items=256)
        np.testing.assert_array_equal(session.census(),
                                      census_batagelj_mrvar(g))
        for _ in range(3):
            add, rem = random_arcs(rng, g.n, 6), random_arcs(rng, g.n, 6)
            got = session.update(*add, *rem)
            g, _ = apply_delta(g, *add, *rem)
            np.testing.assert_array_equal(got, census_batagelj_mrvar(g))
        assert session.stats.partitioned

    def test_matches_unpartitioned_session(self):
        rng = np.random.default_rng(17)
        g = pl_graph(n=60, seed=17)
        add, rem = random_arcs(rng, g.n, 10), random_arcs(rng, g.n, 10)
        out = {}
        for partition in (False, True):
            s = CensusEngine(mesh=default_mesh(4), backend="jnp",
                             partition=partition).session(g, max_items=512)
            out[partition] = (s.census(), s.update(*add, *rem),
                              s.stats.items, s.stats.full_items)
        np.testing.assert_array_equal(out[False][0], out[True][0])
        np.testing.assert_array_equal(out[False][1], out[True][1])
        assert out[False][2] == out[True][2]     # same recount schedule
        assert out[False][3] == out[True][3]

    def test_one_shard_delta_other_shards_dispatch_nothing(
            self, monkeypatch):
        """A delta confined to one shard's pairs must upload and dispatch
        on that shard's device ONLY (monkeypatch counts every descriptor
        dispatch and records which device it ran on)."""
        import repro.core.engine as engine_mod
        # main component on 0..29; vertices 30..33 isolated
        base = pl_graph(n=30, deg=3, seed=3)
        a = to_dense(base)
        s, d = np.nonzero(a)
        g = from_edges(s, d, n=34)
        session = CensusEngine(mesh=default_mesh(4), backend="jnp",
                               partition=True).session(g)
        session.census()
        # update 1: a fresh 3-vertex component — all of its pairs are
        # assigned to ONE shard (locality-first assignment)
        got = session.update([30, 30, 31], [31, 32, 32])
        g, _ = apply_delta(g, [30, 30, 31], [31, 32, 32])
        np.testing.assert_array_equal(got, census_batagelj_mrvar(g))
        new_keys = [30 * 34 + 31, 30 * 34 + 32, 31 * 34 + 32]
        owners = {s for s in range(4)
                  if np.isin(new_keys, session._keys[s]).any()}
        assert len(owners) == 1
        (owner,) = owners
        owner_dev = session._devices[owner].id
        # update 2: flip one arc inside the component — every affected
        # pair lives on `owner`; no other device may see a dispatch
        calls = []
        real_step = engine_mod._desc_step

        def spy(*args, **kw):
            calls.append(list(args[0].devices())[0].id)
            return real_step(*args, **kw)

        monkeypatch.setattr(engine_mod, "_desc_step", spy)
        got = session.update([32], [30])
        monkeypatch.setattr(engine_mod, "_desc_step", real_step)
        g, _ = apply_delta(g, [32], [30])
        np.testing.assert_array_equal(got, census_batagelj_mrvar(g))
        assert calls, "expected the owning shard to dispatch"
        assert set(calls) == {owner_dev}
        nz = [i for i, x in enumerate(session.stats.shard_items) if x]
        assert nz == [owner] and session.stats.items > 0

    def test_empty_delta_no_dispatch(self, monkeypatch):
        import repro.core.engine as engine_mod
        g = from_edges([0, 1, 2], [1, 2, 3], n=5)
        session = CensusEngine(mesh=default_mesh(2), backend="jnp",
                               partition=True).session(g)
        c0 = session.census()
        calls = []
        monkeypatch.setattr(
            engine_mod, "_desc_step",
            lambda *a, **k: calls.append(1))
        got = session.update([0], [1])        # arc already present
        np.testing.assert_array_equal(got, c0)
        assert calls == []
        assert session.stats.chunks == 0

    def test_set_graph_repartitions(self):
        g1 = pl_graph(n=50, seed=1)
        g2 = pl_graph(n=50, seed=2)
        session = CensusEngine(mesh=default_mesh(4), backend="jnp",
                               partition=True).session(g1)
        np.testing.assert_array_equal(session.census(),
                                      census_batagelj_mrvar(g1))
        session.set_graph(g2)
        assert session.counts is None
        np.testing.assert_array_equal(session.census(),
                                      census_batagelj_mrvar(g2))
        with pytest.raises(ValueError, match="pinned"):
            session.set_graph(pl_graph(n=51, seed=2))

    def test_churn_keeps_ownership_balanced(self):
        """Sustained arc churn must not concentrate the pair space onto
        one shard (locality-capped assignment + lightest-shard spill)."""
        rng = np.random.default_rng(23)
        g = pl_graph(n=60, deg=5, seed=23)
        session = CensusEngine(mesh=default_mesh(4), backend="jnp",
                               partition=True).session(g, max_items=2048)
        session.census()
        for _ in range(12):
            add = random_arcs(rng, g.n, 25)
            rem = random_arcs(rng, g.n, 25)
            session.update(*add, *rem)
            g, _ = apply_delta(g, *add, *rem)
        np.testing.assert_array_equal(session.counts,
                                      census_batagelj_mrvar(g))
        loads = [sh.items for sh in session.shards]
        assert max(loads) <= 1.6 * (sum(loads) / len(loads))

    def test_explicit_rebalance_restores_lpt_balance(self):
        """Satellite: rebalance() re-runs the LPT over the churned pair
        space, recovers ≤ 1.1 imbalance, and the census stays exact."""
        rng = np.random.default_rng(31)
        g = pl_graph(n=60, deg=5, seed=31)
        session = CensusEngine(mesh=default_mesh(4), backend="jnp",
                               partition=True).session(g, max_items=2048)
        session.census()
        for _ in range(10):
            add = random_arcs(rng, g.n, 30)
            rem = random_arcs(rng, g.n, 30)
            session.update(*add, *rem)
            g, _ = apply_delta(g, *add, *rem)
        session.rebalance()
        assert session.rebalances == 1
        assert session.load_max_over_mean <= 1.1
        # census after rebalance is still exact, and further updates work
        np.testing.assert_array_equal(session.census(),
                                      census_batagelj_mrvar(g))
        add = random_arcs(rng, g.n, 10)
        got = session.update(*add, [], [])
        g, _ = apply_delta(g, *add, [], [])
        np.testing.assert_array_equal(got, census_batagelj_mrvar(g))

    def test_auto_rebalance_threshold(self):
        """Churn past the threshold triggers rebalance inside update();
        the returned census is still the exact post-delta census."""
        rng = np.random.default_rng(37)
        g = pl_graph(n=60, deg=5, seed=37)
        session = CensusEngine(mesh=default_mesh(4), backend="jnp",
                               partition=True).session(
            g, max_items=2048, auto_rebalance_threshold=1.1)
        session.census()
        for _ in range(12):
            add = random_arcs(rng, g.n, 35)
            rem = random_arcs(rng, g.n, 35)
            got = session.update(*add, *rem)
            g, _ = apply_delta(g, *add, *rem)
            np.testing.assert_array_equal(got, census_batagelj_mrvar(g))
        assert session.rebalances >= 1
        assert session.load_max_over_mean <= 1.1

    def test_auto_rebalance_threshold_validation(self):
        eng = CensusEngine(mesh=default_mesh(2), backend="jnp",
                           partition=True)
        with pytest.raises(ValueError, match="threshold"):
            eng.session(pl_graph(n=20), auto_rebalance_threshold=0.5)
        with pytest.raises(ValueError, match="partition"):
            CensusEngine(backend="jnp").session(
                pl_graph(n=20), auto_rebalance_threshold=1.2)


# -------------------------------------------------------------- monitor


class TestPartitionedMonitor:
    def test_monitor_bit_identical(self):
        rng = np.random.default_rng(29)
        src = rng.integers(0, 60, 1500)
        dst = rng.integers(0, 60, 1500)
        mons = {
            False: TriadMonitor(60, window=300, stride=100, history=2,
                                max_items=1024),
            True: TriadMonitor(60, window=300, stride=100, history=2,
                               max_items=1024, mesh=default_mesh(4),
                               partition=True),
        }
        for m in mons.values():
            m.observe(src, dst)
        np.testing.assert_array_equal(mons[False].censuses,
                                      mons[True].censuses)
        assert all(s.partitioned for s in mons[True].window_stats)
        assert all(len(s.shard_items) == 4
                   for s in mons[True].window_stats)


# ---------------------------------------------------------------- stats


class TestPhysicalStats:
    def test_host_emit_upload_bytes_are_per_device(self):
        """Satellite fix: under a mesh the packed item arrays are SHARDED,
        so the physical per-device upload is chunk bytes / ndev."""
        from repro.core.engine import ITEM_BYTES
        g = pl_graph(n=80, seed=31)
        single = CensusEngine(backend="jnp", emit="host")
        meshy = CensusEngine(mesh=default_mesh(8), backend="jnp",
                             emit="host")
        single.run(g, max_items=400)
        meshy.run(g, max_items=400)
        assert single.stats.plan_upload_bytes == \
            ITEM_BYTES * single.stats.chunk_shape
        assert meshy.stats.plan_upload_bytes == \
            ITEM_BYTES * meshy.stats.chunk_shape // 8
        # graph bytes: replicated path reports the full footprint on
        # every device
        assert meshy.stats.graph_resident_bytes == \
            meshy.stats.graph_replicated_bytes == \
            replicated_graph_bytes(pair_space(g))

    def test_partitioned_upload_is_private_window(self):
        from repro.core.planner import num_desc_anchors
        g = pl_graph(n=80, seed=31)
        part = CensusEngine(mesh=default_mesh(4), backend="jnp",
                            partition=True, emit="device",
                            schedule="lockstep")
        part.run(g, max_items=400)
        st = part.stats
        per_dev = st.chunk_shape // 4    # lock-step records global lanes
        assert st.plan_upload_bytes == 4 * (
            1 + 3 * st.desc_shape + num_desc_anchors(per_dev))
        # async stats record the per-dispatch (single-device) window:
        # same per-device upload unit, chunk_shape already per-device
        part = CensusEngine(mesh=default_mesh(4), backend="jnp",
                            partition=True, emit="device")
        part.run(g, max_items=400)
        st = part.stats
        assert st.schedule == "async"
        assert st.plan_upload_bytes == 4 * (
            1 + 3 * st.desc_shape + num_desc_anchors(st.chunk_shape))
