"""Property-based tests (hypothesis) for system invariants.

Census invariants: total = C(n,3); node-relabeling invariance; edge
reversal swaps the D/U type pairs; distributed == serial.
Model invariants: causality (future tokens cannot affect past logits);
mLSTM chunkwise == sequential recurrence; RG-LRU associative scan ==
step-by-step recurrence; GQA == MHA when kv == heads.
"""

import numpy as np
import pytest

# the container image does not bake in hypothesis; skip (don't fail) there
hypothesis = pytest.importorskip(
    "hypothesis", reason="hypothesis not installed in this environment")
from hypothesis import given, settings, strategies as st  # noqa: E402

import jax
import jax.numpy as jnp

from repro.core import (
    TRIAD_NAMES, build_plan, census_bruteforce, from_edges, to_dense,
    triad_census)

# ------------------------------------------------------------- strategies


@st.composite
def digraphs(draw, max_n=16):
    n = draw(st.integers(min_value=3, max_value=max_n))
    density = draw(st.floats(min_value=0.0, max_value=0.6))
    seed = draw(st.integers(min_value=0, max_value=2**31 - 1))
    rng = np.random.default_rng(seed)
    a = rng.random((n, n)) < density
    np.fill_diagonal(a, False)
    return a


REV_SWAP = {"021D": "021U", "021U": "021D", "111D": "111U",
            "111U": "111D", "120D": "120U", "120U": "120D"}


class TestCensusProperties:
    @settings(max_examples=25, deadline=None)
    @given(digraphs())
    def test_total_and_match_bruteforce(self, a):
        n = a.shape[0]
        src, dst = np.nonzero(a)
        g = from_edges(src, dst, n=n)
        c = triad_census(build_plan(g))
        assert c.sum() == n * (n - 1) * (n - 2) // 6
        assert (c == census_bruteforce(a)).all()

    @settings(max_examples=15, deadline=None)
    @given(digraphs(), st.integers(min_value=0, max_value=10**6))
    def test_relabeling_invariance(self, a, seed):
        n = a.shape[0]
        perm = np.random.default_rng(seed).permutation(n)
        ap = a[np.ix_(perm, perm)]
        c1 = triad_census(build_plan(from_edges(*np.nonzero(a), n=n)))
        c2 = triad_census(build_plan(from_edges(*np.nonzero(ap), n=n)))
        assert (c1 == c2).all()

    @settings(max_examples=15, deadline=None)
    @given(digraphs())
    def test_edge_reversal_swaps_du(self, a):
        n = a.shape[0]
        c_fwd = triad_census(build_plan(from_edges(*np.nonzero(a), n=n)))
        c_rev = triad_census(build_plan(from_edges(*np.nonzero(a.T), n=n)))
        for i, name in enumerate(TRIAD_NAMES):
            j = TRIAD_NAMES.index(REV_SWAP.get(name, name))
            assert c_fwd[i] == c_rev[j], (name,)

    @settings(max_examples=10, deadline=None)
    @given(digraphs(max_n=12))
    def test_roundtrip_dense(self, a):
        g = from_edges(*np.nonzero(a), n=a.shape[0])
        assert (to_dense(g) == a).all()


# ------------------------------------------------------------- models

def _mk_cfg(name):
    from repro.configs import get_config
    return get_config(name).reduced()


class TestModelProperties:
    @pytest.mark.parametrize("arch", ["qwen2-0.5b", "recurrentgemma-2b",
                                      "xlstm-1.3b", "deepseek-moe-16b"])
    def test_causality(self, arch):
        """Changing tokens after position t must not change logits <= t."""
        from repro.models.model import forward, make_params
        cfg = _mk_cfg(arch)
        rng = np.random.default_rng(0)
        params = make_params(cfg, seed=0)
        b, s, t = 1, 24, 11
        toks = rng.integers(0, cfg.vocab_size, (b, s))
        toks2 = toks.copy()
        toks2[:, t + 1:] = rng.integers(0, cfg.vocab_size, (b, s - t - 1))
        outs = []
        for tk in (toks, toks2):
            batch = {"tokens": jnp.asarray(tk, jnp.int32)}
            x, _, _ = forward(cfg, params, batch, q_chunk=8, rec_chunk=4)
            outs.append(np.asarray(x[:, :t + 1].astype(jnp.float32)))
        np.testing.assert_array_equal(outs[0], outs[1])

    def test_mlstm_chunkwise_equals_sequential(self):
        from repro.models.common import init_params
        from repro.models.recurrent import (
            mlstm_chunkwise, mlstm_decode_step, mlstm_schema)
        from repro.configs import get_config
        cfg = get_config("xlstm-1.3b").reduced()
        schema = mlstm_schema(cfg)
        p = init_params(schema, jax.random.PRNGKey(0))
        b, s, di = 2, 13, 2 * cfg.d_model
        x = jnp.asarray(np.random.default_rng(1).normal(size=(b, s, di)),
                        jnp.float32) * 0.3
        y_par, _ = mlstm_chunkwise(p, x, cfg.num_heads, chunk=4)
        # sequential reference via the decode step
        state = None
        ys = []
        from repro.models.recurrent import mlstm_init_state
        state = mlstm_init_state(cfg, b)
        for t in range(s):
            yt, state = mlstm_decode_step(p, x[:, t:t + 1], state,
                                          cfg.num_heads)
            ys.append(yt)
        y_seq = jnp.concatenate(ys, axis=1)
        np.testing.assert_allclose(np.asarray(y_par, np.float32),
                                   np.asarray(y_seq, np.float32),
                                   rtol=2e-4, atol=2e-5)

    def test_rglru_scan_equals_sequential(self):
        from repro.models.common import init_params
        from repro.models.recurrent import (
            rglru_block, rglru_init_state, rglru_schema)
        from repro.configs import get_config
        cfg = get_config("recurrentgemma-2b").reduced()
        p = init_params(rglru_schema(cfg), jax.random.PRNGKey(2))
        b, s = 2, 9
        x = jnp.asarray(np.random.default_rng(3).normal(
            size=(b, s, cfg.d_model)), jnp.float32) * 0.5
        y_par, _ = rglru_block(cfg, p, x)
        state = rglru_init_state(cfg, b)
        ys = []
        for t in range(s):
            yt, state = rglru_block(cfg, p, x[:, t:t + 1], state=state,
                                    decode=True)
            ys.append(yt)
        y_seq = jnp.concatenate(ys, axis=1)
        np.testing.assert_allclose(np.asarray(y_par, np.float32),
                                   np.asarray(y_seq, np.float32),
                                   rtol=2e-4, atol=2e-5)

    def test_gqa_equals_mha_when_kv_equals_heads(self):
        """GQA with kv == q heads is plain MHA: grouping must be a no-op."""
        import dataclasses
        from repro.models.attention import attention, attn_schema
        from repro.models.common import init_params
        from repro.configs import get_config
        cfg = dataclasses.replace(_mk_cfg("qwen2-0.5b"), num_heads=4,
                                  num_kv_heads=4)
        p = init_params(attn_schema(cfg), jax.random.PRNGKey(4))
        x = jnp.asarray(np.random.default_rng(5).normal(
            size=(2, 16, cfg.d_model)), jnp.float32) * 0.3
        pos = jnp.broadcast_to(jnp.arange(16, dtype=jnp.int32), (2, 16))
        y1 = attention(cfg, p, x, positions=pos, q_chunk=16)
        y2 = attention(cfg, p, x, positions=pos, q_chunk=4)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                                   rtol=1e-5, atol=1e-5)

    def test_local_window_matches_masked_full(self):
        """Banded chunked local attention == full attention with band mask."""
        import dataclasses
        from repro.models.attention import attention, attn_schema
        from repro.models.common import init_params
        from repro.configs import get_config
        cfg = dataclasses.replace(_mk_cfg("recurrentgemma-2b"), window=6)
        p = init_params(attn_schema(cfg), jax.random.PRNGKey(6))
        s = 20
        x = jnp.asarray(np.random.default_rng(7).normal(
            size=(1, s, cfg.d_model)), jnp.float32) * 0.3
        pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (1, s))
        y_local = attention(cfg, p, x, positions=pos, layer_window=6,
                            q_chunk=4)
        # reference: dense scores with band mask
        from repro.models import attention as am
        q, k, v = am._project_qkv(cfg, p, x, x)
        q = am._rope(cfg, q, pos)
        k = am._rope(cfg, k, pos)
        hkv = cfg.num_kv_heads
        g = cfg.num_heads // hkv
        qg = q.reshape(1, s, hkv, g, cfg.head_dim)
        sc = jnp.einsum("bqkgd,bskd->bkgqs", qg, k) / np.sqrt(cfg.head_dim)
        i, j = np.arange(s)[:, None], np.arange(s)[None, :]
        band = (j <= i) & (j > i - 6)
        sc = jnp.where(jnp.asarray(band)[None, None, None], sc, am.NEG_INF)
        pr = jax.nn.softmax(sc.astype(jnp.float32), -1).astype(v.dtype)
        o = jnp.einsum("bkgqs,bskd->bqkgd", pr, v).reshape(
            1, s, cfg.num_heads, cfg.head_dim)
        y_ref = jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(o.dtype))
        np.testing.assert_allclose(np.asarray(y_local), np.asarray(y_ref),
                                   rtol=2e-4, atol=2e-4)
