"""Pipeline parallelism (GPipe over a stage axis) and gradient
compression: numerical parity with the unpipelined / uncompressed paths."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax import shard_map
from jax.sharding import PartitionSpec as P

from repro.parallel.compression import quantized_psum, quantized_tree_psum
from repro.parallel.pipeline import pipeline_apply, split_stages


def _mesh(axis="pod"):
    n = len(jax.devices())
    if n < 2:
        pytest.skip("needs >= 2 devices")
    return jax.make_mesh((n,), (axis,),
                         axis_types=(jax.sharding.AxisType.Auto,))


class TestPipeline:
    def test_matches_sequential(self):
        mesh = _mesh()
        s = len(jax.devices())
        d = 8
        rng = np.random.default_rng(0)
        # per-stage linear+tanh layers
        layers = [{"w": jnp.asarray(rng.normal(size=(d, d)) * 0.3,
                                    jnp.float32)} for _ in range(s)]
        stage_params = split_stages(layers, s)

        def stage_fn(p, x):
            # p: layers-per-stage stacked (1 here)
            def body(xc, wl):
                return jnp.tanh(xc @ wl["w"]), None
            y, _ = jax.lax.scan(body, x, p)
            return y

        m = 4
        mbs = jnp.asarray(rng.normal(size=(m, 3, d)), jnp.float32)
        piped = pipeline_apply(stage_fn, mesh)
        out = piped(stage_params, mbs)

        # sequential reference
        ref = mbs
        for l in layers:
            ref = jnp.tanh(ref @ l["w"])
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)

    def test_grad_flows_through_pipeline(self):
        mesh = _mesh()
        s = len(jax.devices())
        d = 4
        rng = np.random.default_rng(1)
        layers = [{"w": jnp.asarray(rng.normal(size=(d, d)) * 0.3,
                                    jnp.float32)} for _ in range(s)]
        stage_params = split_stages(layers, s)

        def stage_fn(p, x):
            y, _ = jax.lax.scan(lambda xc, wl: (jnp.tanh(xc @ wl["w"]),
                                                None), x, p)
            return y

        mbs = jnp.asarray(rng.normal(size=(2, 2, d)), jnp.float32)
        piped = pipeline_apply(stage_fn, mesh)

        def loss_piped(sp):
            return jnp.sum(piped(sp, mbs) ** 2)

        def loss_seq(ls):
            x = mbs
            for l in ls:
                x = jnp.tanh(x @ l["w"])
            return jnp.sum(x ** 2)

        g_p = jax.grad(loss_piped)(stage_params)
        g_s = jax.grad(loss_seq)(layers)
        g_s_stacked = split_stages(
            [jax.tree.map(lambda a: a, l) for l in g_s],
            s)
        np.testing.assert_allclose(
            np.asarray(g_p["w"]), np.asarray(g_s_stacked["w"]),
            rtol=1e-4, atol=1e-5)


class TestCompression:
    def test_quantized_psum_close_to_exact(self):
        mesh = _mesh("data")
        n = len(jax.devices())
        rng = np.random.default_rng(2)
        xs = jnp.asarray(rng.normal(size=(n, 64)), jnp.float32)

        def f(x):
            return quantized_psum(x, "data", bits=8)

        out = shard_map(f, mesh=mesh, in_specs=P("data"),
                        out_specs=P("data"))(xs.reshape(n, 1, 64)
                                             ).reshape(n, 64)
        exact = np.asarray(xs).sum(axis=0)
        scale = np.abs(xs).max()
        # error bounded by n * scale / 127
        err = np.abs(np.asarray(out[0]) - exact).max()
        assert err <= n * float(scale) / 127 + 1e-5

    def test_bits16_tighter_than_bits4(self):
        mesh = _mesh("data")
        n = len(jax.devices())
        rng = np.random.default_rng(3)
        xs = jnp.asarray(rng.normal(size=(n, 1, 256)), jnp.float32)
        exact = np.asarray(xs).sum(axis=0)[0]

        def err_for(bits):
            out = shard_map(
                lambda x: quantized_psum(x, "data", bits=bits),
                mesh=mesh, in_specs=P("data"), out_specs=P("data"))(xs)
            return np.abs(np.asarray(out[0, 0]) - exact).mean()

        assert err_for(16) < err_for(4)

    def test_error_feedback_residual_shapes(self):
        mesh = _mesh("data")
        n = len(jax.devices())
        tree = {"a": jnp.ones((n, 1, 8)), "b": jnp.zeros((n, 1, 4))}

        def f(t):
            red, res = quantized_tree_psum(t, "data", bits=8)
            return red, res

        red, res = shard_map(f, mesh=mesh, in_specs=(P("data"),),
                             out_specs=(P("data"), P("data")))(tree)
        assert red["a"].shape == (n, 1, 8)
        np.testing.assert_allclose(np.asarray(red["a"][0, 0]),
                                   np.full(8, n, np.float32), rtol=1e-6)
