"""Benchmark harness — one entry per paper table/figure (census half) plus
LM substrate micro-benchmarks. Prints ``name,us_per_call,derived`` CSV;
``--json PATH`` additionally writes the rows as machine-readable JSON so
the perf trajectory is tracked across PRs.

Run: ``PYTHONPATH=src python -m benchmarks.run [--quick]``
"""

from __future__ import annotations

import argparse
import json
import os
import sys


#: every bench workload seeds its generators from this; recorded per
#: JSON row so cross-PR comparisons only match rows with identical
#: inputs
BENCH_SEED = 0


def write_json(path: str, rows: list) -> None:
    """Persist the benchmark rows as a ``BENCH_*.json``-style file: one
    object per row (name, us_per_call, derived, backend, jax_version,
    seed)."""
    import jax
    backend = jax.default_backend()
    payload = [
        {"name": name, "us_per_call": round(us, 3), "derived": derived,
         "backend": backend, "jax_version": jax.__version__,
         "seed": BENCH_SEED}
        for name, us, derived in rows
    ]
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
        f.write("\n")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="census benchmarks only")
    ap.add_argument("--smoke", action="store_true",
                    help="fast census smoke subset (CI regression gate)")
    ap.add_argument("--streaming-smoke", action="store_true",
                    help="streamed-vs-monolithic parity gate: tiny graph, "
                         "a max_items budget forcing >= 4 chunks")
    ap.add_argument("--temporal-smoke", action="store_true",
                    help="incremental-vs-full sliding-window gate: "
                         "bit-identity plus >= 2x item reduction at a "
                         "10%% stride")
    ap.add_argument("--emit-smoke", action="store_true",
                    help="device-vs-host emission gate: bit-identical "
                         "censuses (full + incremental) with >= 4x fewer "
                         "host-to-device plan bytes per chunk")
    ap.add_argument("--partition-smoke", action="store_true",
                    help="partitioned-execution gate: bit-identity vs "
                         "the single-device path on an 8-virtual-host "
                         "mesh, shard imbalance <= 1.2, >= 2x per-device "
                         "graph-byte reduction")
    ap.add_argument("--2d-smoke", dest="twod_smoke", action="store_true",
                    help="2D pair×vertex decomposition gate: bit-"
                         "identity 1D vs 2D vs reference on an 8-"
                         "virtual-device mesh ((4,2) and (2,4), both "
                         "emits, both orients, async + lockstep, "
                         "incremental session), >= 1.5x further halo "
                         "(resident adjacency entry) cut over 1D at "
                         "(4,2) and >= 2x at (2,4) on the power-law "
                         "workload")
    ap.add_argument("--mega-smoke", action="store_true",
                    help="megastep gate: in the tiny-window dispatch-"
                         "bound regime, K-window batched dispatches "
                         "must stay bit-identical, issue >= 2x fewer "
                         "dispatches than one-window async, and hold "
                         "within 1.15x of lock-step walltime")
    ap.add_argument("--fault-smoke", action="store_true",
                    help="fault-tolerance gate: a seeded plan (producer "
                         "error + transient dispatch error + one device "
                         "retirement) on an 8-virtual-device mesh must "
                         "finish bit-identical with >= 1 recorded "
                         "failover; an armed-but-idle engine must stay "
                         "within 1.05x of plain async; a run killed "
                         "mid-stream must checkpoint-resume to the "
                         "exact same census")
    ap.add_argument("--incr-host-smoke", action="store_true",
                    help="delta-incremental host-planner gate: warm "
                         "sliding-window updates with the persistent "
                         "pair-space index must be bit-identical to the "
                         "per-window rebuild oracle (censuses AND "
                         "post-prune item totals), >= 1.5x faster in "
                         "walltime and >= 1.3x in the pair-space host "
                         "phase at a 5%% stride on the backbone-"
                         "dominated degree-oriented workload")
    ap.add_argument("--async-smoke", action="store_true",
                    help="async-schedule gate: on a synthetic 4x-skewed "
                         "8-shard partition, async per-shard streams "
                         "must be bit-identical to the lock-step "
                         "oracle, >= 1.5x faster, and within 1.25x of "
                         "the balanced mean-shard ideal")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also write the rows as machine-readable JSON "
                         "(name, us_per_call, derived, backend), e.g. "
                         "BENCH_census.json")
    args = ap.parse_args()

    # the partition rows (part_shard{1,4,8} and --partition-smoke) need a
    # multi-device mesh; force 8 virtual host devices BEFORE the first
    # jax import, exactly like tests/conftest.py (single-device rows
    # still execute on one device — the virtual split only adds
    # addressable devices)
    if "jax" not in sys.modules:
        flags = os.environ.get("XLA_FLAGS", "")
        if "xla_force_host_platform_device_count" not in flags:
            os.environ["XLA_FLAGS"] = (
                flags + " --xla_force_host_platform_device_count=8"
            ).strip()

    rows: list = []
    from benchmarks import census_bench
    if args.fault_smoke:
        census_bench.fault_smoke(rows)
    elif args.twod_smoke:
        census_bench.twod_smoke(rows)
    elif args.mega_smoke:
        census_bench.mega_smoke(rows)
    elif args.incr_host_smoke:
        census_bench.incr_host_smoke(rows)
    elif args.async_smoke:
        census_bench.async_smoke(rows)
    elif args.partition_smoke:
        census_bench.partition_smoke(rows)
    elif args.emit_smoke:
        census_bench.emit_smoke(rows)
    elif args.temporal_smoke:
        census_bench.temporal_smoke(rows)
    elif args.streaming_smoke:
        census_bench.streaming_smoke(rows)
    elif args.smoke:
        census_bench.run_smoke(rows)
    else:
        census_bench.run(rows)
        if not args.quick:
            from benchmarks import lm_bench
            lm_bench.run(rows)

    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.3f},{derived}")
    sys.stdout.flush()
    if args.json:
        write_json(args.json, rows)


if __name__ == "__main__":
    main()
