"""Benchmark harness — one entry per paper table/figure (census half) plus
LM substrate micro-benchmarks. Prints ``name,us_per_call,derived`` CSV.

Run: ``PYTHONPATH=src python -m benchmarks.run [--quick]``
"""

from __future__ import annotations

import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="census benchmarks only")
    ap.add_argument("--smoke", action="store_true",
                    help="fast census smoke subset (CI regression gate)")
    ap.add_argument("--streaming-smoke", action="store_true",
                    help="streamed-vs-monolithic parity gate: tiny graph, "
                         "a max_items budget forcing >= 4 chunks")
    ap.add_argument("--temporal-smoke", action="store_true",
                    help="incremental-vs-full sliding-window gate: "
                         "bit-identity plus >= 2x item reduction at a "
                         "10%% stride")
    args = ap.parse_args()

    rows: list = []
    from benchmarks import census_bench
    if args.temporal_smoke:
        census_bench.temporal_smoke(rows)
    elif args.streaming_smoke:
        census_bench.streaming_smoke(rows)
    elif args.smoke:
        census_bench.run_smoke(rows)
    else:
        census_bench.run(rows)
        if not args.quick:
            from benchmarks import lm_bench
            lm_bench.run(rows)

    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.3f},{derived}")
    sys.stdout.flush()


if __name__ == "__main__":
    main()
