"""LM substrate micro-benchmarks (reduced configs on CPU): train-step and
decode-step latency per architecture family."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models.model import (
    decode_step, init_cache, loss_fn, make_params)
from repro.parallel.inputs import make_concrete_batch

FAMILIES = ["qwen2-0.5b", "deepseek-moe-16b", "xlstm-1.3b",
            "recurrentgemma-2b"]


def run(rows: list):
    for arch in FAMILIES:
        cfg = get_config(arch).reduced()
        params = make_params(cfg, seed=0)
        batch = make_concrete_batch(cfg, 2, 32)

        grad_fn = jax.jit(jax.grad(
            lambda p, b: loss_fn(cfg, p, b, q_chunk=16, rec_chunk=8)[0]))
        g = grad_fn(params, batch)
        jax.block_until_ready(g)
        t0 = time.perf_counter()
        jax.block_until_ready(grad_fn(params, batch))
        dt = time.perf_counter() - t0
        tokens = batch["tokens"].size
        rows.append((f"lm_train_step_{arch}", dt * 1e6,
                     f"{tokens / dt:.3g} tok/s (reduced cfg)"))

        cache = init_cache(cfg, batch=2, seq_len=32,
                           src_len=16 if cfg.is_encdec else 0)
        dec = jax.jit(lambda p, t, c: decode_step(cfg, p, t, c))
        tok = jnp.zeros((2, 1), jnp.int32)
        out, cache2 = dec(params, tok, cache)
        jax.block_until_ready(out)
        t0 = time.perf_counter()
        jax.block_until_ready(dec(params, tok, cache)[0])
        dt = time.perf_counter() - t0
        rows.append((f"lm_decode_step_{arch}", dt * 1e6,
                     f"{2 / dt:.3g} tok/s (reduced cfg)"))
