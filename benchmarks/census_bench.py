"""Census benchmarks mapping to the paper's tables/figures.

* fig6  — outdegree power-law distributions of the three re-synthesized
          workloads (patents / orkut / webgraph analogues).
* fig9  — utilization analogue: work-balance of the flat plan vs a naive
          pair-partitioned plan (the paper's CPU-utilization story).
* fig10/11/13 — strong-scaling analogue per workload: measured single-
          device throughput + modeled speedup from per-shard work shares
          (exact for a bandwidth-bound vector workload), up to 512 shards.
* table_census — exact 16-type censuses, validated against serial
          Batagelj-Mrvar.

CPU-host caveat (documented in EXPERIMENTS.md): this container has one
physical core, so wall-clock multi-device speedups are not observable;
the scaling columns report the work-partition model the paper's speedup
figures measure on real hardware, plus measured items/second throughput.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import (
    PAPER_WORKLOADS, build_plan, census_batagelj_mrvar, census_dict,
    paper_workload, triad_census)
from repro.core.generators import measured_exponent

#: scaled-down workload sizes (nodes, avg outdegree) — shaped like the
#: paper's patents (sparse, steep tail) / orkut (dense social) / webgraph
WORKLOAD_SIZES = {
    "patents": (30_000, 3.0),     # W ~  77M work items
    "orkut": (5_000, 40.0),       # W ~ 100M
    "webgraph": (15_000, 15.0),   # W ~ 118M
}


def _timeit(fn, *args, reps=3, **kw):
    fn(*args, **kw)                      # warmup / compile
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        ts.append(time.perf_counter() - t0)
    return min(ts), out


def fig6_degree_distributions(rows: list):
    for name in PAPER_WORKLOADS:
        n, deg = WORKLOAD_SIZES[name]
        g = paper_workload(name, n=n, avg_degree=deg, seed=0)
        exp = measured_exponent(g)
        rows.append((f"fig6_{name}_exponent", exp * 1e6,
                     f"target={PAPER_WORKLOADS[name]['exponent']}"))


def fig9_balance(rows: list):
    g = paper_workload("orkut", *WORKLOAD_SIZES["orkut"], seed=1)
    plan = build_plan(g, pad_to=64)
    st = plan.balance_stats(64)
    rows.append(("fig9_flat_max_over_mean",
                 st["flat_max_over_mean"] * 1e6, "flat plan, 64 shards"))
    rows.append(("fig9_pair_max_over_mean",
                 st["pair_max_over_mean"] * 1e6,
                 "naive pair partitioning"))


def scaling_fig(rows: list, name: str, fig: str):
    n, deg = WORKLOAD_SIZES[name]
    g = paper_workload(name, n=n, avg_degree=deg, seed=0)
    plan = build_plan(g)
    dt, census = _timeit(triad_census, plan)
    items_per_s = plan.num_items / dt
    rows.append((f"{fig}_{name}_census", dt * 1e6,
                 f"items={plan.num_items};items_per_s={items_per_s:.3g}"))
    # modeled strong scaling from per-shard work shares (paper's speedup)
    for shards in (8, 64, 256, 512):
        p = build_plan(g, pad_to=shards)
        st = p.balance_stats(shards)
        speedup = shards / st["flat_max_over_mean"]
        rows.append((f"{fig}_{name}_speedup_{shards}",
                     speedup * 1e6, "modeled from work shares"))


def table_census(rows: list):
    """Exact censuses; the (slow, serial-python) Batagelj-Mrvar oracle
    runs on a reduced graph of the same family — full-size equality is
    covered by the JAX-vs-oracle test suite."""
    for name in PAPER_WORKLOADS:
        n, deg = WORKLOAD_SIZES[name]
        g_small = paper_workload(name, n=min(n, 2000),
                                 avg_degree=min(deg, 10.0), seed=0)
        assert (triad_census(build_plan(g_small)) ==
                census_batagelj_mrvar(g_small)).all(), name
        g = paper_workload(name, n=n, avg_degree=deg, seed=0)
        c = triad_census(build_plan(g))
        d = census_dict(c)
        top = sorted(d.items(), key=lambda kv: -kv[1])[1:4]
        rows.append((f"table_census_{name}_ok", 1.0,
                     ";".join(f"{k}={v}" for k, v in top)))


def om_scaling(rows: list):
    """Batagelj–Mrvar's O(m) claim: census time ~ linear in work items
    (Σ deg(u)+deg(v) over edges) at fixed degree structure."""
    from repro.core import scale_free_digraph
    pts = []
    for n in (10_000, 20_000, 40_000, 80_000):
        g = scale_free_digraph(n=n, avg_degree=6, exponent=2.3,
                               mutual_p=0.3, preferential=False, seed=0)
        plan = build_plan(g)
        dt, _ = _timeit(triad_census, plan)
        pts.append((plan.num_items, dt))
        rows.append((f"fig_om_n{n}", dt * 1e6,
                     f"items={plan.num_items};"
                     f"ns_per_item={dt / plan.num_items * 1e9:.1f}"))
    # linearity check: per-item time ratio largest/smallest graph
    per = [t / w for w, t in pts]
    rows.append(("fig_om_linearity_ratio",
                 max(per) / min(per) * 1e6,
                 "~1.0 == linear in work items"))


def kernel_throughput(rows: list):
    import jax.numpy as jnp
    from repro.kernels import tricode_histogram_ref
    rng = np.random.default_rng(0)
    w = 1 << 20
    from repro.kernels.tricode_hist import tricode_histogram_kernel
    tri = jnp.asarray(rng.integers(0, 64, w), jnp.int32)
    mask = jnp.ones(w, bool)
    # hoist the jnp.where masking out of BOTH timed paths: each consumes
    # the identical pre-masked array (w is already a BLOCK_ITEMS multiple),
    # so neither side smuggles masking/padding cost into its timing
    masked = jnp.where(mask, tri, 64).block_until_ready()
    dt_ref, _ = _timeit(
        lambda: tricode_histogram_ref(masked).block_until_ready())
    dt_k, _ = _timeit(lambda: tricode_histogram_kernel(
        masked, interpret=True).block_until_ready())
    rows.append(("kernel_tricode_hist_jnp", dt_ref * 1e6,
                 f"{w / dt_ref:.3g} items/s"))
    rows.append(("kernel_tricode_hist_pallas_interp", dt_k * 1e6,
                 "interpret-mode (CPU correctness harness)"))


#: reduced sizes for the fused-kernel columns: interpret mode re-simulates
#: every grid step on the CPU host, so the full WORKLOAD_SIZES are too slow
FUSED_SIZES = {
    "patents": (3_000, 3.0),
    "orkut": (800, 20.0),
    "webgraph": (1_500, 8.0),
}


def fused_vs_reference(rows: list):
    """Fused single-pass kernel vs the jnp reference path, plus the
    degree-oriented planning work reduction (see EXPERIMENTS.md)."""
    for name in PAPER_WORKLOADS:
        n, deg = FUSED_SIZES[name]
        g = paper_workload(name, n=n, avg_degree=deg, seed=0)
        plan = build_plan(g)
        plan_deg = build_plan(g, orient="degree")
        dt_ref, c_ref = _timeit(triad_census, plan, backend="jnp")
        dt_fused, c_fused = _timeit(triad_census, plan,
                                    backend="pallas-fused")
        # explicit raise (not assert): this parity check is the regression
        # gate benchmarks/check.sh relies on, and must survive python -O
        if not (c_ref == c_fused).all():
            raise AssertionError(f"fused census mismatch on {name}")
        rows.append((f"fused_{name}_jnp", dt_ref * 1e6,
                     f"items_per_s={plan.num_items / dt_ref:.3g}"))
        rows.append((f"fused_{name}_pallas_fused_interp", dt_fused * 1e6,
                     f"items_per_s={plan.num_items / dt_fused:.3g};"
                     "interpret-mode (CPU correctness harness)"))
        # degree-oriented planning: same census, fewer work items
        dt_deg, c_deg = _timeit(triad_census, plan_deg,
                                backend="pallas-fused")
        if not (c_ref == c_deg).all():
            raise AssertionError(
                f"degree-oriented census mismatch on {name}")
        rows.append((f"fused_{name}_degree_oriented", dt_deg * 1e6,
                     f"items={plan_deg.num_items} vs {plan.num_items} "
                     f"({plan_deg.num_items / plan.num_items:.2%} of "
                     "default plan)"))


def streaming_vs_monolithic(rows: list):
    """Tentpole rows: streamed (chunked out-of-core) vs monolithic census.

    The monolithic path materializes the whole O(W) plan and ships it in
    one dispatch; streaming caps the per-dispatch packed-item bytes at
    ``8 * max_items`` and accumulates per-chunk partials.  The sweep
    includes a budget the monolithic plan exceeds by >= 8x, and asserts
    bit-identical censuses plus a compile-once chunk step.
    """
    from repro.core import CensusEngine, pair_space

    g = paper_workload("webgraph", n=6_000, avg_degree=10.0, seed=0)
    w_pre = pair_space(g).num_items_preprune
    mono = CensusEngine(backend="jnp")
    dt_mono, c_mono = _timeit(mono.run, g)
    rows.append(("stream_monolithic", dt_mono * 1e6,
                 f"plan_bytes={mono.stats.peak_plan_bytes};"
                 f"items={mono.stats.items}"))
    for frac in (8, 32):
        engine = CensusEngine(backend="jnp")
        max_items = -(-w_pre // frac)
        # compile-once gate on the FIRST (un-warmed) run: a per-chunk
        # recompilation regression compiles one entry per chunk here,
        # before _timeit's warmup can mask it in the cache
        c = engine.run(g, max_items=max_items)
        compiles_first = engine.stats.step_compiles
        if compiles_first > 1:
            raise AssertionError(
                f"per-chunk recompilation: {compiles_first} "
                f"compiles for {engine.stats.chunks} chunks")
        dt, c = _timeit(engine.run, g, max_items=max_items)
        st = engine.stats
        if not (c == c_mono).all():
            raise AssertionError(f"streamed census mismatch at 1/{frac}")
        # the 1/32 budget demonstrates a workload whose monolithic plan
        # is >= 8x the chunk budget (pruning keeps the 1/8 run near ~7x)
        if frac >= 32 and st.monolithic_plan_bytes < 8 * st.peak_plan_bytes:
            raise AssertionError(
                f"budget not demonstrated: monolithic "
                f"{st.monolithic_plan_bytes} < 8x peak "
                f"{st.peak_plan_bytes}")
        rows.append((f"stream_budget_1_{frac}", dt * 1e6,
                     f"chunks={st.chunks};"
                     f"peak_plan_bytes={st.peak_plan_bytes};"
                     f"monolithic_bytes={st.monolithic_plan_bytes};"
                     f"chunk_max_over_mean={st.chunk_max_over_mean:.3f};"
                     f"step_compiles={compiles_first}"))


def streaming_smoke(rows: list):
    """CI gate (benchmarks/check.sh): tiny graph, a max_items budget that
    forces >= 4 chunks (with intra-pair splits), parity-checked against
    the monolithic census on the jnp and pallas-fused backends."""
    from repro.core import CensusEngine, pair_space

    g = paper_workload("orkut", n=400, avg_degree=12.0, seed=0)
    want = triad_census(build_plan(g))
    w_pre = pair_space(g).num_items_preprune
    max_items = max(w_pre // 6, 1)
    for backend in ("jnp", "pallas-fused"):
        engine = CensusEngine(backend=backend)
        # first run is un-warmed: per-chunk recompilation shows up here
        got = engine.run(g, max_items=max_items)
        compiles_first = engine.stats.step_compiles
        if compiles_first > 1:
            raise AssertionError(
                f"per-chunk recompilation on {backend}: "
                f"{compiles_first} compiles for "
                f"{engine.stats.chunks} chunks")
        dt, got = _timeit(engine.run, g, max_items=max_items)
        st = engine.stats
        if not (got == want).all():
            raise AssertionError(f"streamed {backend} != monolithic")
        if st.chunks < 4:
            raise AssertionError(f"smoke too coarse: {st.chunks} chunks")
        rows.append((f"stream_smoke_{backend}", dt * 1e6,
                     f"chunks={st.chunks};items={st.items};"
                     f"peak_plan_bytes={st.peak_plan_bytes};"
                     f"step_compiles={compiles_first};parity=ok"))


def device_emission(rows: list):
    """Tentpole rows: host vs device work-item emission.

    ``emit="host"`` (the PR 3 baseline) materializes, packs and uploads
    every O(W) work item per chunk; ``emit="device"`` ships O(pairs)
    descriptors and expands pairs→items in-kernel.  Same chunk schedule,
    bit-identical censuses (asserted in-row), and the per-chunk
    host→device plan bytes shrink by the mean items-per-pair factor.
    """
    from repro.core import CensusEngine, pair_space

    g = paper_workload("webgraph", n=6_000, avg_degree=10.0, seed=0)
    w_pre = pair_space(g).num_items_preprune
    max_items = -(-w_pre // 32)
    res = {}
    for emit in ("host", "device"):
        engine = CensusEngine(backend="jnp", emit=emit)
        dt, c = _timeit(engine.run, g, max_items=max_items)
        res[emit] = (dt, c, engine.stats)
        st = engine.stats
        rows.append((f"emit_stream_{emit}", dt * 1e6,
                     f"chunks={st.chunks};items={st.items};"
                     f"plan_upload_bytes_per_chunk={st.plan_upload_bytes}"))
    if not (res["host"][1] == res["device"][1]).all():
        raise AssertionError("device-emit census != host-emit census")
    ratio = (res["host"][2].plan_upload_bytes
             / res["device"][2].plan_upload_bytes)
    rows.append(("emit_upload_reduction", ratio * 1e6,
                 "host/device plan bytes per chunk (same schedule)"))

    # warm incremental-update walltime: resident sessions on the
    # monitoring workload, timed over a fixed reciprocal delta after
    # warmup — the row the device-emission path must improve
    rng = np.random.default_rng(0)
    window = 4000
    src, dst, n = _monitor_stream(rng, 80, 3000, 800, 2 * window)
    from repro.core import from_edges
    g = from_edges(src[:window], dst[:window], n=n)
    # reciprocal delta: arcs of the NEXT window absent from g (so add
    # followed by delete restores g exactly — set semantics)
    base = src[:window] * n + dst[:window]
    cand_s, cand_d = src[window:], dst[window:]
    fresh = ~np.isin(cand_s * n + cand_d, base) & (cand_s != cand_d)
    d_src, d_dst = cand_s[fresh][:400], cand_d[fresh][:400]
    dts = {}
    for emit in ("host", "device"):
        session = CensusEngine(backend="jnp", emit=emit).session(
            g, max_items=4096)
        want = session.census()

        def cycle():
            session.update(d_src, d_dst)
            return session.update(del_src=d_src, del_dst=d_dst)

        dt, back = _timeit(cycle)
        dts[emit] = dt / 2                 # one update per half-cycle
        if not (back == want).all():
            raise AssertionError(f"emit={emit}: reciprocal updates "
                                 "did not restore the census")
        st = session.stats
        rows.append((f"emit_incr_update_{emit}", dts[emit] * 1e6,
                     f"affected_pairs={st.affected_pairs};"
                     f"items={st.items};"
                     f"plan_upload_bytes_per_chunk={st.plan_upload_bytes}"))
    rows.append(("emit_incr_update_speedup",
                 dts["host"] / max(dts["device"], 1e-9) * 1e6,
                 "host-emission walltime / device-emission walltime, "
                 "warm incremental update"))


def emit_smoke(rows: list):
    """CI gate (benchmarks/check.sh --emit-smoke): device-emission
    censuses must be bit-identical to host emission on the jnp and
    pallas-fused backends — full streamed runs (>= 4 chunks, matching
    per-chunk valid-item counts) and incremental session updates — with
    >= 4x fewer host→device plan bytes per chunk on both paths."""
    from repro.core import CensusEngine, pair_space

    g = paper_workload("orkut", n=400, avg_degree=12.0, seed=0)
    w_pre = pair_space(g).num_items_preprune
    max_items = max(w_pre // 6, 1)
    rng = np.random.default_rng(1)
    add = (rng.integers(0, 400, 60), rng.integers(0, 400, 60))
    rem = (rng.integers(0, 400, 60), rng.integers(0, 400, 60))
    for backend in ("jnp", "pallas-fused"):
        orients = ("none", "degree") if backend == "jnp" else ("none",)
        for orient in orients:
            t0 = time.perf_counter()
            # full streamed parity + per-chunk upload reduction
            eng = {}
            census = {}
            for emit in ("host", "device"):
                eng[emit] = CensusEngine(backend=backend, emit=emit)
                census[emit] = eng[emit].run(g, max_items=max_items,
                                             orient=orient)
            if not (census["host"] == census["device"]).all():
                raise AssertionError(
                    f"{backend}/{orient}: device-emit != host-emit")
            st_h, st_d = eng["host"].stats, eng["device"].stats
            if st_h.chunks < 4:
                raise AssertionError(f"smoke too coarse: {st_h.chunks}")
            if st_d.chunk_items != st_h.chunk_items:
                raise AssertionError(
                    f"{backend}/{orient}: device-counted valid items "
                    f"diverge from the host plan")
            if st_h.plan_upload_bytes < 4 * st_d.plan_upload_bytes:
                raise AssertionError(
                    f"{backend}/{orient}: full-run upload reduction "
                    f"{st_h.plan_upload_bytes}/{st_d.plan_upload_bytes} "
                    "< 4x")
            # incremental session parity + upload reduction
            ses = {e: CensusEngine(backend=backend, emit=e).session(
                g, orient=orient, max_items=max_items)
                for e in ("host", "device")}
            if not (ses["host"].census() == ses["device"].census()).all():
                raise AssertionError(
                    f"{backend}/{orient}: session census diverges")
            got_h = ses["host"].update(*add, *rem)
            got_d = ses["device"].update(*add, *rem)
            if not (got_h == got_d).all():
                raise AssertionError(
                    f"{backend}/{orient}: incremental update diverges")
            ib_h = ses["host"].stats.plan_upload_bytes
            ib_d = ses["device"].stats.plan_upload_bytes
            if ib_h < 4 * ib_d:
                raise AssertionError(
                    f"{backend}/{orient}: incremental upload reduction "
                    f"{ib_h}/{ib_d} < 4x")
            dt = time.perf_counter() - t0
            rows.append((f"emit_smoke_{backend}_{orient}", dt * 1e6,
                         f"chunks={st_h.chunks};"
                         f"full_bytes={st_h.plan_upload_bytes}v"
                         f"{st_d.plan_upload_bytes};"
                         f"incr_bytes={ib_h}v{ib_d};parity=ok"))


def partitioned_scaling(rows: list):
    """Tentpole rows ``part_shard{1,4,8}``: partitioned multi-device
    execution of the power-law workload — each device holds only its pair
    shard's local subgraph and walks its own descriptor stream — vs the
    replicated mesh baseline.  Asserts bit-identical censuses in-row and
    reports the per-device resident graph bytes, the byte reduction over
    replication, and the LPT shard imbalance (target ≤ 1.2)."""
    import jax

    from repro.core import CensusEngine, default_mesh

    if len(jax.devices()) < 8:
        rows.append(("part_shard_skipped", 0.0,
                     f"needs 8 devices, have {len(jax.devices())}"))
        return
    g = paper_workload("patents", n=20_000, avg_degree=3.0, seed=0)
    repl = CensusEngine(mesh=default_mesh(8), backend="jnp")
    dt_repl, want = _timeit(repl.run, g)
    rows.append(("part_replicated8", dt_repl * 1e6,
                 f"graph_bytes={repl.stats.graph_resident_bytes};"
                 f"items={repl.stats.items}"))
    for shards in (1, 4, 8):
        engine = CensusEngine(mesh=default_mesh(shards), backend="jnp",
                              partition=True, schedule="lockstep")
        got = engine.run(g)
        if not (got == want).all():
            raise AssertionError(
                f"partitioned census mismatch at {shards} shards")
        dt, _ = _timeit(engine.run, g)
        st = engine.stats
        rows.append((
            f"part_shard{shards}", dt * 1e6,
            f"graph_bytes={st.graph_resident_bytes};"
            f"replicated={st.graph_replicated_bytes};"
            f"reduction="
            f"{st.graph_replicated_bytes / max(st.graph_resident_bytes, 1):.2f}x;"
            f"shard_max_over_mean={st.shard_max_over_mean:.3f}"))
    # async per-shard streams on the same workload: no inter-shard
    # barrier, per-shard chunk queues drained independently.  Pinned to
    # one window per dispatch so the row stays comparable with its
    # pre-megastep history; part_mega_shard{4,8} below carries the
    # batched dispatches.
    for shards in (4, 8):
        engine = CensusEngine(mesh=default_mesh(shards), backend="jnp",
                              partition=True, schedule="async",
                              max_windows_per_dispatch=1)
        got = engine.run(g)
        if not (got == want).all():
            raise AssertionError(
                f"async partitioned census mismatch at {shards} shards")
        dt, _ = _timeit(engine.run, g)
        st = engine.stats
        rows.append((
            f"part_async_shard{shards}", dt * 1e6,
            f"windows={sum(st.shard_steps)};"
            f"stalls={st.stall_steps};"
            f"pipeline_depth={st.pipeline_depth};"
            f"upload_bytes={st.plan_upload_bytes_total};"
            f"shard_max_over_mean={st.shard_max_over_mean:.3f}"))
    # megastep: same async schedule, up to 8 windows scanned per
    # compiled dispatch — the Python dispatch cost is paid once per K.
    # Streamed (1M-item windows) so each shard has a multi-window queue
    # to batch; the unstreamed rows above have one window per shard,
    # where the engine clamps the batch capacity back to 1.
    for shards in (4, 8):
        engine = CensusEngine(mesh=default_mesh(shards), backend="jnp",
                              partition=True, schedule="async")
        got = engine.run(g, max_items=1_048_576)
        if not (got == want).all():
            raise AssertionError(
                f"megastep partitioned census mismatch at {shards} shards")
        dt, _ = _timeit(engine.run, g, max_items=1_048_576)
        st = engine.stats
        rows.append((
            f"part_mega_shard{shards}", dt * 1e6,
            f"windows={sum(st.shard_steps)};"
            f"dispatches={st.dispatches_total};"
            f"win_per_disp={st.windows_per_dispatch_mean:.2f}/"
            f"{st.windows_per_dispatch_max};"
            f"cap={st.dispatch_batch_limit};"
            f"pad_bytes={st.plan_pad_bytes_total};"
            f"stalls={st.stall_steps}"))
    # 2D pair×vertex meshes on the same workload: the pair axis keeps
    # the LPT assignment, the vertex axis slices each shard's adjacency
    # halo.  halo = max per-device resident adjacency entries (the
    # replicated CSR words the decomposition shards); 1D at 8 devices is
    # the reference point.
    from repro.core import partition_graph, partition_graph_2d
    halo_1d = max(partition_graph(g, num_shards=8).stats.shard_entries)
    for mesh_shape in ((4, 2), (2, 4)):
        p, v = mesh_shape
        engine = CensusEngine(mesh=default_mesh(8), backend="jnp",
                              partition_2d=mesh_shape, schedule="async")
        got = engine.run(g)
        if not (got == want).all():
            raise AssertionError(
                f"2D partitioned census mismatch at {mesh_shape}")
        dt, _ = _timeit(engine.run, g)
        st = engine.stats
        part2 = partition_graph_2d(g, mesh_shape=mesh_shape)
        halo = max(part2.stats.shard_entries)
        rows.append((
            f"part_2d_shard{p}x{v}", dt * 1e6,
            f"graph_bytes={st.graph_resident_bytes};"
            f"halo_entries={halo};"
            f"halo_cut_vs_1d8={halo_1d / max(halo, 1):.2f}x;"
            f"entry_replication={part2.stats.entry_replication:.2f};"
            f"shard_max_over_mean={st.shard_max_over_mean:.3f}"))


def _skewed_partition(space, num_shards: int, frac: float):
    """Deliberately imbalanced partition: shard 0 takes the heaviest
    pairs up to ``frac`` of the total pre-prune work (so its chunk queue
    is ``frac * num_shards``× the mean); the rest LPT-balance across the
    remaining shards."""
    from repro.core import lpt_assign_heap, partition_graph

    costs = space.counts.astype(np.int64)
    order = np.argsort(-costs, kind="stable")
    csum = np.cumsum(costs[order])
    k = int(np.searchsorted(csum, int(costs.sum() * frac))) + 1
    owner = np.empty(space.num_pairs, np.int64)
    owner[order[:k]] = 0
    rest = order[k:]
    owner[rest] = 1 + lpt_assign_heap(costs[rest], num_shards - 1)
    return partition_graph(num_shards=num_shards, space=space,
                           owner=owner)


def async_smoke(rows: list):
    """CI gate (benchmarks/check.sh --async-smoke): on a synthetic
    4×-skewed 8-shard partition (the heaviest shard's chunk queue ≥ 4×
    the mean) the async schedule must

    * stay bit-identical to the lock-step oracle AND the single-device
      census,
    * run ≥ 1.5× faster than lock-step (which burns ndev × max-shard
      collective steps, padded windows included), and
    * land within 1.25× of the mean-shard ideal — the same async engine
      on a balanced LPT partition of the same graph (same per-window
      dispatch cost, so the ratio isolates the skew penalty the barrier
      drop is supposed to erase).
    """
    import jax

    from repro.core import (CensusEngine, default_mesh, pair_space,
                            partition_graph, scale_free_digraph)
    from repro.core.plan_stream import ShardSchedule

    if len(jax.devices()) < 8:
        raise AssertionError(
            f"async smoke needs 8 devices, have {len(jax.devices())} "
            "(run via benchmarks/run.py, which forces them)")
    g = scale_free_digraph(1500, 8.0, 2.1, seed=0)
    space = pair_space(g)
    want = CensusEngine(backend="jnp").run(g)
    max_items = 16_384
    part_skew = _skewed_partition(space, 8, 0.52)
    part_bal = partition_graph(num_shards=8, space=space)
    sched = ShardSchedule([sh.space for sh in part_skew.shards],
                          max_items, 8)
    steps = sched.shard_steps
    skew = max(steps) / (sum(steps) / len(steps))
    if skew < 4.0:
        raise AssertionError(
            f"synthetic skew too mild: heaviest/mean {skew:.2f} < 4")
    mesh = default_mesh(8)

    def run_once(schedule, part):
        # pinned to one window per dispatch: this gate measures the PR 6
        # barrier drop (skew vs mean-shard pacing) and its thresholds
        # were calibrated there; the K-window megastep shifts the
        # critical path from dispatch to per-shard compute and has its
        # own gate (mega_smoke)
        engine = CensusEngine(mesh=mesh, backend="jnp",
                              partition=True, schedule=schedule,
                              max_windows_per_dispatch=1)
        dt, got = _timeit(engine.run, g, max_items=max_items, part=part,
                          reps=2)
        if not (got == want).all():
            raise AssertionError(
                f"{schedule} partitioned census != single-device")
        return dt, engine.stats

    t_async, st_a = run_once("async", part_skew)
    t_lock, st_l = run_once("lockstep", part_skew)
    t_ideal, st_i = run_once("async", part_bal)
    speedup = t_lock / t_async
    if speedup < 1.5:
        raise AssertionError(
            f"async only {speedup:.2f}x faster than lock-step on the "
            f"4x skew (need >= 1.5x)")
    if t_async > 1.25 * t_ideal:
        raise AssertionError(
            f"async on the skew is {t_async / t_ideal:.2f}x the "
            "balanced mean-shard ideal (need <= 1.25x)")
    rows.append(("async_smoke_skew", t_async * 1e6,
                 f"speedup_vs_lockstep={speedup:.2f}x;"
                 f"vs_mean_ideal={t_async / t_ideal:.2f}x;"
                 f"heaviest_over_mean={skew:.2f};"
                 f"windows={sum(st_a.shard_steps)};"
                 f"stalls={st_a.stall_steps};parity=ok"))
    rows.append(("async_smoke_lockstep", t_lock * 1e6,
                 f"collective_steps={max(st_l.shard_steps)};"
                 f"idle_steps={st_l.idle_steps};parity=ok"))
    rows.append(("async_smoke_ideal", t_ideal * 1e6,
                 f"windows={sum(st_i.shard_steps)};"
                 f"shard_max_over_mean="
                 f"{st_i.shard_max_over_mean:.3f};parity=ok"))


def dispatch_overhead(rows: list):
    """Microbench for the megastep's target regime: a small per-window
    item budget makes windows tiny and numerous, so per-dispatch Python
    overhead (trace-cache lookup, device_put, future bookkeeping)
    dominates device compute.  Rows compare async at one window per
    dispatch (PR 6), async with the 8-window megastep, and the
    lock-step oracle on the same 8-shard schedule."""
    import jax

    from repro.core import (CensusEngine, default_mesh,
                            scale_free_digraph)

    if len(jax.devices()) < 8:
        rows.append(("dispatch_overhead_skipped", 0.0,
                     f"needs 8 devices, have {len(jax.devices())}"))
        return
    g = scale_free_digraph(800, 6.0, 2.1, seed=3)
    max_items = 2_048          # tiny windows: dispatch-bound on purpose
    mesh = default_mesh(8)
    want = None
    for name, sched, cap in (("dispatch_async_k1", "async", 1),
                             ("dispatch_mega_k8", "async", 8),
                             ("dispatch_lockstep", "lockstep", 1)):
        engine = CensusEngine(mesh=mesh, backend="jnp", partition=True,
                              schedule=sched,
                              max_windows_per_dispatch=cap)
        got = engine.run(g, max_items=max_items)
        if want is None:
            want = got
        elif not (got == want).all():
            raise AssertionError(f"{name}: census mismatch")
        dt, _ = _timeit(engine.run, g, max_items=max_items)
        st = engine.stats
        rows.append((
            name, dt * 1e6,
            f"windows={sum(st.shard_steps)};"
            f"dispatches={st.dispatches_total};"
            f"win_per_disp={st.windows_per_dispatch_mean:.2f};"
            f"us_per_window={dt * 1e6 / max(sum(st.shard_steps), 1):.1f}"))


def mega_smoke(rows: list):
    """CI gate (benchmarks/check.sh --mega-smoke): in the tiny-window
    dispatch-bound regime on an 8-shard partition, the megastep must

    * stay bit-identical to the lock-step oracle AND the single-device
      census (per-window stacked partials + host int64 merge make the
      K-window scan indistinguishable from K single dispatches),
    * issue >= 2x fewer device dispatches than the one-window async
      schedule at an equal window budget, and
    * erase async's dispatch-overhead loss to lock-step: megastep
      walltime <= 1.15x lock-step on the same schedule (PR 6's
      one-window async pays ~windows× Python dispatch cost and loses
      this regime; amortizing K windows per dispatch is the fix).
    """
    import jax

    from repro.core import (CensusEngine, default_mesh,
                            scale_free_digraph)

    if len(jax.devices()) < 8:
        raise AssertionError(
            f"mega smoke needs 8 devices, have {len(jax.devices())} "
            "(run via benchmarks/run.py, which forces them)")
    g = scale_free_digraph(800, 6.0, 2.1, seed=3)
    max_items = 2_048
    want = CensusEngine(backend="jnp").run(g)
    mesh = default_mesh(8)

    def run_once(schedule, cap):
        engine = CensusEngine(mesh=mesh, backend="jnp",
                              partition=True, schedule=schedule,
                              max_windows_per_dispatch=cap)
        dt, got = _timeit(engine.run, g, max_items=max_items, reps=2)
        if not (got == want).all():
            raise AssertionError(
                f"{schedule}/cap={cap} census != single-device")
        return dt, engine.stats

    t_k1, st_k1 = run_once("async", 1)
    t_mega, st_mega = run_once("async", 8)
    t_lock, st_lock = run_once("lockstep", 1)
    if sum(st_mega.shard_steps) != sum(st_k1.shard_steps):
        raise AssertionError(
            "window budgets diverged: "
            f"{sum(st_mega.shard_steps)} != {sum(st_k1.shard_steps)}")
    if st_mega.dispatches_total * 2 > st_k1.dispatches_total:
        raise AssertionError(
            f"megastep dispatches {st_mega.dispatches_total} not >= 2x "
            f"fewer than one-window async {st_k1.dispatches_total}")
    if t_mega > 1.15 * t_lock:
        raise AssertionError(
            f"megastep is {t_mega / t_lock:.2f}x lock-step in the "
            "dispatch-bound regime (need <= 1.15x)")
    rows.append(("mega_smoke", t_mega * 1e6,
                 f"windows={sum(st_mega.shard_steps)};"
                 f"dispatches={st_mega.dispatches_total}v"
                 f"{st_k1.dispatches_total};"
                 f"win_per_disp={st_mega.windows_per_dispatch_mean:.2f}/"
                 f"{st_mega.windows_per_dispatch_max};"
                 f"vs_async_k1={t_mega / t_k1:.2f}x;"
                 f"vs_lockstep={t_mega / t_lock:.2f}x;parity=ok"))
    rows.append(("mega_smoke_async_k1", t_k1 * 1e6,
                 f"dispatches={st_k1.dispatches_total};parity=ok"))
    rows.append(("mega_smoke_lockstep", t_lock * 1e6,
                 f"collective_steps={st_lock.dispatches_total};"
                 f"idle_steps={st_lock.idle_steps};parity=ok"))


def fault_smoke(rows: list):
    """CI gate (benchmarks/check.sh --fault-smoke): the fault-tolerance
    layer on an 8-virtual-device mesh must

    * survive a seeded :class:`FaultPlan` carrying a producer plan-gen
      error, a transient dispatch error AND a device retirement —
      finishing bit-identical to the single-device census with >= 1
      recorded failover (the dead device's queue drained by survivors),
    * cost nothing when nothing fails: an armed engine (injection hooks
      threaded, watchdog set, empty fault plan) within 1.05x of the
      plain async walltime on the same workload, and
    * resume: a run killed mid-stream with ``checkpoint=`` journaling
      restores the landed windows and completes to the exact same
      census, with > 0 resumed (journal-skipped) windows.
    """
    import os
    import tempfile

    import jax

    from repro.core import (CensusEngine, FaultPlan, default_mesh,
                            scale_free_digraph)

    if len(jax.devices()) < 8:
        raise AssertionError(
            f"fault smoke needs 8 devices, have {len(jax.devices())} "
            "(run via benchmarks/run.py, which forces them)")
    g = scale_free_digraph(1500, 8.0, 2.1, seed=0)
    max_items = 16_384
    want = CensusEngine(backend="jnp").run(g)
    mesh = default_mesh(8)

    # plain async baseline (the PR 8 machinery, no fault layer armed)
    # vs armed-but-idle: injection hooks fire on every producer/upload/
    # dispatch event against an EMPTY plan, watchdog timers run — the
    # pure overhead of carrying the fault-tolerance layer.  Single runs
    # of this threaded pipeline jitter ~10% with host scheduling, so
    # the bound is checked on the MEDIAN of 8 back-to-back paired
    # ratios (pairing cancels load drift; the median sheds scheduler
    # outliers)
    plain = CensusEngine(mesh=mesh, backend="jnp", partition=True)
    armed = CensusEngine(mesh=mesh, backend="jnp", partition=True,
                         faults=FaultPlan(faults=[], seed=0),
                         watchdog_timeout=30.0)
    for eng, label in ((plain, "plain async"), (armed, "armed fault-free")):
        got = eng.run(g, max_items=max_items)        # warmup / compile
        if not (got == want).all():
            raise AssertionError(f"{label} census != single-device")
    ratios, ta = [], []
    for _ in range(8):
        t0 = time.perf_counter()
        plain.run(g, max_items=max_items)
        tp = time.perf_counter() - t0
        t0 = time.perf_counter()
        armed.run(g, max_items=max_items)
        ta.append(time.perf_counter() - t0)
        ratios.append(ta[-1] / tp)
    dt_armed = min(ta)
    overhead = float(np.median(ratios))
    if overhead > 1.05:
        raise AssertionError(
            f"fault-free overhead {overhead:.3f}x plain async "
            "(need <= 1.05x)")

    # adversarial: producer error + transient dispatch error + one
    # device retired mid-run — survivors drain its queue, merge order
    # doesn't matter, census must not move a bit
    adv = CensusEngine(mesh=mesh, backend="jnp", partition=True,
                       faults=FaultPlan.seeded(
                           7, 8, producer_errors=1, dispatch_errors=1,
                           retire_devices=1))
    dt_adv, got = _timeit(adv.run, g, max_items=max_items, reps=2)
    if not (got == want).all():
        raise AssertionError("faulted census != single-device")
    st = adv.stats
    if st.failovers < 1 or not st.retired_devices:
        raise AssertionError(
            f"seeded retirement did not fail over (failovers="
            f"{st.failovers}, retired={st.retired_devices})")
    if st.retries < 1:
        raise AssertionError("seeded transient faults were not retried")

    # checkpoint/resume: kill the run mid-stream, resume from the
    # journal, land the exact same census with > 0 skipped windows
    class _Killer:
        def __init__(self, after):
            self.after, self.calls = after, 0

        def __call__(self, done, total, num=None):
            self.calls += 1
            if self.calls == self.after:
                raise KeyboardInterrupt

    with tempfile.TemporaryDirectory() as td:
        ck = os.path.join(td, "census.ckpt")
        eng = CensusEngine(mesh=mesh, backend="jnp", partition=True)
        try:
            eng.run(g, max_items=max_items, checkpoint=ck,
                    progress=_Killer(8))
        except KeyboardInterrupt:
            pass
        t0 = time.perf_counter()
        got = eng.resume(g, ck, max_items=max_items)
        dt_resume = time.perf_counter() - t0
        if not (got == want).all():
            raise AssertionError("resumed census != uninterrupted")
        resumed = eng.stats.resumed_windows
        if resumed < 1:
            raise AssertionError(
                "resume did not skip any journaled windows")

    rows.append(("fault_smoke_adversarial", dt_adv * 1e6,
                 f"retries={st.retries};failovers={st.failovers};"
                 f"retired={sorted(st.retired_devices)};"
                 f"windows={sum(st.shard_steps)};parity=ok"))
    rows.append(("fault_smoke_overhead", dt_armed * 1e6,
                 f"vs_plain_async={overhead:.3f}x;parity=ok"))
    rows.append(("fault_smoke_resume", dt_resume * 1e6,
                 f"resumed_windows={resumed};parity=ok"))


def partition_smoke(rows: list):
    """CI gate (benchmarks/check.sh --partition-smoke): on an 8-virtual-
    host mesh, partitioned censuses must be bit-identical to the
    single-device path (jnp × both emits × both orients, monolithic +
    streamed, plus pallas-fused and an incremental partitioned session),
    with shard item imbalance ≤ 1.2 and ≥ 2x per-device graph-byte
    reduction on the power-law workload."""
    import jax

    from repro.core import CensusEngine, default_mesh, pair_space

    if len(jax.devices()) < 8:
        raise AssertionError(
            f"partition smoke needs 8 devices, have {len(jax.devices())} "
            "(run via benchmarks/run.py, which forces them)")
    g = paper_workload("patents", n=4_000, avg_degree=3.0, seed=0)
    want = CensusEngine(backend="jnp").run(g)
    w_pre = pair_space(g).num_items_preprune
    mesh = default_mesh(8)
    for backend, emits, orients in (
            ("jnp", ("device", "host"), ("none", "degree")),
            ("pallas-fused", ("device",), ("none",))):
        for emit in emits:
            for orient in orients:
                t0 = time.perf_counter()
                engine = CensusEngine(mesh=mesh, backend=backend,
                                      partition=True, emit=emit)
                for max_items in (None, max(w_pre // 4, 1)):
                    got = engine.run(g, max_items=max_items,
                                     orient=orient)
                    if not (got == want).all():
                        raise AssertionError(
                            f"{backend}/{emit}/{orient}: partitioned "
                            "census != single-device")
                st = engine.stats
                if st.shard_max_over_mean > 1.2:
                    raise AssertionError(
                        f"{backend}/{emit}/{orient}: shard imbalance "
                        f"{st.shard_max_over_mean:.3f} > 1.2")
                if st.graph_replicated_bytes < \
                        2 * st.graph_resident_bytes:
                    raise AssertionError(
                        f"{backend}/{emit}/{orient}: byte reduction "
                        f"{st.graph_replicated_bytes}/"
                        f"{st.graph_resident_bytes} < 2x")
                dt = time.perf_counter() - t0
                rows.append((
                    f"part_smoke_{backend}_{emit}_{orient}", dt * 1e6,
                    f"chunks={st.chunks};"
                    f"shard_max_over_mean={st.shard_max_over_mean:.3f};"
                    f"graph_bytes={st.graph_resident_bytes}v"
                    f"{st.graph_replicated_bytes};parity=ok"))
    # incremental partitioned session: delta updates must stay
    # bit-identical to the unpartitioned session's
    rng = np.random.default_rng(2)
    add = (rng.integers(0, 4_000, 80), rng.integers(0, 4_000, 80))
    rem = (rng.integers(0, 4_000, 80), rng.integers(0, 4_000, 80))
    t0 = time.perf_counter()
    ses = {p: CensusEngine(mesh=mesh, backend="jnp",
                           partition=p).session(g, max_items=w_pre)
           for p in (False, True)}
    if not (ses[False].census() == ses[True].census()).all():
        raise AssertionError("partitioned session census diverges")
    got_r = ses[False].update(*add, *rem)
    got_p = ses[True].update(*add, *rem)
    if not (got_r == got_p).all():
        raise AssertionError("partitioned incremental update diverges")
    st = ses[True].stats
    dt = time.perf_counter() - t0
    rows.append(("part_smoke_session", dt * 1e6,
                 f"affected_pairs={st.affected_pairs};items={st.items};"
                 f"dispatched_shards="
                 f"{sum(1 for x in st.shard_items if x)};parity=ok"))


def twod_smoke(rows: list):
    """CI gate (benchmarks/check.sh --2d-smoke): the 2D pair×vertex
    decomposition on an 8-virtual-host mesh.

    Bit-identity: 2D censuses at (4,2) and (2,4) must equal the 1D
    partitioned path and the single-device reference — both emits, both
    orients, monolithic + streamed, async + lockstep, plus an
    incremental 2D session.

    Halo gate: on the power-law workload, the max per-device resident
    adjacency entries (the halo — the replicated CSR words the vertex
    axis shards; pair descriptors scale with owned work, not graph
    size, and entries are structurally 2x the pair count, so total
    bytes are pair-bound) must shrink ≥ 1.5x further than 1D at 8
    devices on the (4,2) mesh and ≥ 2x on the (2,4) mesh, with total
    per-device resident bytes no worse than 1D."""
    import jax

    from repro.core import (CensusEngine, default_mesh, pair_space,
                            partition_graph, partition_graph_2d)

    if len(jax.devices()) < 8:
        raise AssertionError(
            f"2d smoke needs 8 devices, have {len(jax.devices())} "
            "(run via benchmarks/run.py, which forces them)")
    g = paper_workload("patents", n=4_000, avg_degree=3.0, seed=0)
    want = CensusEngine(backend="jnp").run(g)
    w_pre = pair_space(g).num_items_preprune
    mesh = default_mesh(8)
    c1 = CensusEngine(mesh=mesh, backend="jnp", partition=True).run(g)
    if not (c1 == want).all():
        raise AssertionError("1D partitioned census != single-device")
    for mesh_shape in ((4, 2), (2, 4)):
        for emit in ("device", "host"):
            for orient in ("none", "degree"):
                t0 = time.perf_counter()
                for schedule in ("async", "lockstep"):
                    engine = CensusEngine(mesh=mesh, backend="jnp",
                                          partition_2d=mesh_shape,
                                          emit=emit, schedule=schedule)
                    for max_items in (None, max(w_pre // 4, 1)):
                        got = engine.run(g, max_items=max_items,
                                         orient=orient)
                        if not (got == want).all():
                            raise AssertionError(
                                f"{mesh_shape}/{emit}/{orient}/"
                                f"{schedule}: 2D census != reference")
                st = engine.stats
                dt = time.perf_counter() - t0
                rows.append((
                    f"twod_smoke_{mesh_shape[0]}x{mesh_shape[1]}"
                    f"_{emit}_{orient}", dt * 1e6,
                    f"chunks={st.chunks};"
                    f"mesh={st.partition_shape};parity=ok"))
    # incremental 2D session: delta updates bit-identical to the
    # unpartitioned session's
    rng = np.random.default_rng(2)
    add = (rng.integers(0, 4_000, 80), rng.integers(0, 4_000, 80))
    rem = (rng.integers(0, 4_000, 80), rng.integers(0, 4_000, 80))
    t0 = time.perf_counter()
    ses_r = CensusEngine(mesh=mesh, backend="jnp").session(g)
    ses_2 = CensusEngine(mesh=mesh, backend="jnp",
                         partition_2d=(4, 2)).session(g)
    if not (ses_r.census() == ses_2.census()).all():
        raise AssertionError("2D session census diverges")
    if not (ses_r.update(*add, *rem) == ses_2.update(*add, *rem)).all():
        raise AssertionError("2D incremental update diverges")
    dt = time.perf_counter() - t0
    rows.append(("twod_smoke_session", dt * 1e6,
                 f"affected_pairs={ses_2.stats.affected_pairs};"
                 f"items={ses_2.stats.items};parity=ok"))
    # halo gate on the power-law workload (host-side partition stats —
    # no device work, so full scale is cheap)
    gh = paper_workload("patents", n=20_000, avg_degree=8.0, seed=0)
    t0 = time.perf_counter()
    p1 = partition_graph(gh, num_shards=8)
    halo_1d = max(p1.stats.shard_entries)
    bytes_1d = p1.stats.max_shard_bytes
    for mesh_shape, need in (((4, 2), 1.5), ((2, 4), 2.0)):
        p2 = partition_graph_2d(gh, mesh_shape=mesh_shape)
        halo = max(p2.stats.shard_entries)
        cut = halo_1d / max(halo, 1)
        if cut < need:
            raise AssertionError(
                f"{mesh_shape}: halo cut {cut:.2f}x < {need}x "
                f"({halo_1d} -> {halo} resident entries)")
        if mesh_shape == (4, 2) and \
                p2.stats.max_shard_bytes > bytes_1d:
            raise AssertionError(
                f"{mesh_shape}: total resident bytes regressed "
                f"{bytes_1d} -> {p2.stats.max_shard_bytes}")
        rows.append((
            f"twod_smoke_halo_{mesh_shape[0]}x{mesh_shape[1]}",
            (time.perf_counter() - t0) * 1e6,
            f"halo_entries={halo_1d}v{halo};cut={cut:.2f}x;"
            f"bytes={bytes_1d}v{p2.stats.max_shard_bytes};"
            f"entry_replication={p1.stats.entry_replication:.2f}v"
            f"{p2.stats.entry_replication:.2f}"))


def _monitor_stream(rng, n_servers, n_peers, backbone_arcs, length,
                    backbone_every=2, eph_every=None):
    """Monitoring workload: a persistent service backbone (a fixed server
    mesh cycled through the stream, so it sits in every window and never
    churns) interleaved with ephemeral peer-to-peer flows that churn
    completely between windows — the regime where incremental window
    updates pay (arc deltas touch few rows).  ``backbone_every=k`` makes
    every k-th stream slot a backbone edge (fraction 1/k); ``eph_every=k``
    inverts the cadence — every k-th slot is EPHEMERAL and the rest are
    backbone (fraction (k-1)/k), the backbone-dominated regime where the
    pair space is large but the per-slide delta stays small."""
    n = n_servers + n_peers
    bs = rng.integers(0, n_servers, backbone_arcs)
    bd = (bs + 1 + rng.integers(0, n_servers - 1, backbone_arcs)) \
        % n_servers
    src = np.empty(length, np.int64)
    dst = np.empty(length, np.int64)
    slots = np.arange(length)
    if eph_every is not None:
        bb = slots % eph_every != 0
        idx = (np.cumsum(bb) - 1)[bb] % backbone_arcs
    else:
        bb = slots % backbone_every == 0
        idx = (slots[bb] // backbone_every) % backbone_arcs
    src[bb], dst[bb] = bs[idx], bd[idx]
    n_peer_slots = int((~bb).sum())
    src[~bb] = n_servers + rng.integers(0, n_peers, n_peer_slots)
    dst[~bb] = n_servers + rng.integers(0, n_peers, n_peer_slots)
    return src, dst, n


def _run_monitor(src, dst, n, window, stride, incremental,
                 backend="jnp", max_items=4096, index=True):
    from repro.core import TriadMonitor
    mon = TriadMonitor(n, window=window, stride=stride, history=5,
                       backend=backend, incremental=incremental,
                       max_items=max_items, index=index)
    t0 = time.perf_counter()
    mon.observe(src, dst)
    dt = time.perf_counter() - t0
    return mon, dt


def temporal_windows(rows: list):
    """Tentpole rows: full per-window recompute vs incremental delta
    updates of sliding windows, at 5% / 20% / 50% stride-to-window
    overlap ratios.  Asserts bit-identical censuses in-row and reports
    the items processed plus the affected-pair fraction per window."""
    rng = np.random.default_rng(0)
    window = 4000
    src, dst, n = _monitor_stream(rng, 80, 3000, 800, 11 * window)
    # warm the shared jitted chunk step (same static args / chunk shape
    # for every monitor below) so neither timed mode absorbs the compile
    warm = 2 * window
    _run_monitor(src[:warm], dst[:warm], n, window, window // 2,
                 incremental=True)
    for frac in (0.05, 0.20, 0.50):
        stride = max(1, int(window * frac))
        mon_full, dt_full = _run_monitor(src, dst, n, window, stride,
                                         incremental=False)
        mon_inc, dt_inc = _run_monitor(src, dst, n, window, stride,
                                       incremental=True)
        if not (mon_full.censuses == mon_inc.censuses).all():
            raise AssertionError(
                f"incremental != full at stride {frac:.0%}")
        slid = mon_inc.window_stats[1:]     # first window is always full
        items = sum(s.items for s in slid)
        full_items = sum(s.full_items for s in slid)
        aff = np.mean([s.affected_pairs for s in slid])
        tag = f"s{int(frac * 100):02d}"
        rows.append((f"temporal_full_{tag}", dt_full * 1e6,
                     f"windows={len(mon_full.window_stats)};"
                     f"items={sum(s.items for s in mon_full.window_stats)}"))
        rows.append((f"temporal_incr_{tag}", dt_inc * 1e6,
                     f"windows={len(mon_inc.window_stats)};items={items};"
                     f"item_reduction={full_items / max(items, 1):.2f}x;"
                     f"mean_affected_pairs={aff:.0f};"
                     f"speedup={dt_full / max(dt_inc, 1e-9):.2f}x"))


def temporal_smoke(rows: list):
    """CI gate (benchmarks/check.sh --temporal-smoke): sliding windows at
    a 10% stride, asserting (a) incremental censuses are bit-identical to
    full per-window recomputes and (b) the incremental path processes
    >= 2x fewer census items, on the jnp and pallas-fused backends."""
    rng = np.random.default_rng(0)
    window = 1500
    src, dst, n = _monitor_stream(rng, 40, 1500, 300, 5 * window)
    stride = window // 10
    for backend in ("jnp", "pallas-fused"):
        # warm the chunk step so the timed runs compare algorithms, not
        # jit-cache states
        _run_monitor(src[:2 * window], dst[:2 * window], n, window,
                     stride, incremental=True, backend=backend,
                     max_items=2048)
        mon_full, dt_full = _run_monitor(
            src, dst, n, window, stride, incremental=False,
            backend=backend, max_items=2048)
        mon_inc, dt_inc = _run_monitor(
            src, dst, n, window, stride, incremental=True,
            backend=backend, max_items=2048)
        if not (mon_full.censuses == mon_inc.censuses).all():
            raise AssertionError(f"incremental != full on {backend}")
        slid_inc = mon_inc.window_stats[1:]
        items = sum(s.items for s in slid_inc)
        full_items = sum(s.full_items for s in slid_inc)
        if full_items < 2 * items:
            raise AssertionError(
                f"{backend}: incremental processed {items} items vs "
                f"{full_items} full — less than the required 2x reduction")
        compiles = sum(s.step_compiles for s in mon_inc.window_stats)
        if compiles > 1:
            raise AssertionError(
                f"{backend}: session step recompiled ({compiles}) "
                f"across {len(mon_inc.window_stats)} windows")
        rows.append((f"temporal_smoke_{backend}", dt_inc * 1e6,
                     f"windows={len(mon_inc.window_stats)};"
                     f"items={items};full_items={full_items};"
                     f"item_reduction={full_items / max(items, 1):.2f}x;"
                     f"step_compiles={compiles};parity=ok"))


def incr_host_smoke(rows: list):
    """CI gate (benchmarks/check.sh --incr-host-smoke): the
    delta-incremental host planner.  Warm sliding-window updates with the
    persistent pair-space index must be (a) bit-identical to the
    rebuild-from-scratch oracle (``index=False``), (b) >= 1.5x faster
    end-to-end in walltime at a 5% stride, and (c) >= 1.3x faster in the
    pair-space host phase alone.

    The workload is the backbone-dominated monitoring regime the index
    targets: a large stable service backbone (the pair space stays at
    P ~ 150k) with a small ephemeral churn fraction (1 slot in 50), under
    the degree-oriented planner — per slide the oracle rebuilds the O(P)
    pair space and repays the O(m + P log m) post-prune closed form,
    while the index edits both in O(delta log P + affected).
    """
    from repro.core import TriadMonitor
    rng = np.random.default_rng(0)
    window = 200_000
    n_slides = {0.05: 8, 0.20: 4}
    length = window + int(max(f * s for f, s in n_slides.items())
                          * window)
    src, dst, n = _monitor_stream(rng, 20000, 50000, 150000, length,
                                  eph_every=50)
    for frac, gates in ((0.05, (1.5, 1.3)), (0.20, None)):
        stride = int(window * frac)
        end = window + n_slides[frac] * stride
        runs = {}
        for index in (True, False):
            mon = TriadMonitor(n, window=window, stride=stride,
                               history=5, backend="jnp", orient="degree",
                               incremental=True, max_items=16384,
                               index=index)
            # first window: full census — session open + jit warm for
            # both modes, so the timed region is pure warm updates
            mon.observe(src[:window], dst[:window])
            t0 = time.perf_counter()
            mon.observe(src[window:end], dst[window:end])
            runs[index] = (mon, time.perf_counter() - t0)
        mon_on, dt_on = runs[True]
        mon_off, dt_off = runs[False]
        if not (mon_on.censuses == mon_off.censuses).all():
            raise AssertionError(
                f"indexed censuses != rebuild oracle at stride "
                f"{frac:.0%}")
        slid = [s for s in mon_on.window_stats[1:] if s is not None]
        slid_off = [s for s in mon_off.window_stats[1:] if s is not None]
        if [s.full_items for s in slid] != \
                [s.full_items for s in slid_off]:
            raise AssertionError(
                "maintained post-prune item totals != oracle recompute")
        speedup = dt_off / max(dt_on, 1e-9)
        pair_on = sum(s.host_pair_seconds for s in slid)
        pair_off = sum(s.host_pair_seconds for s in slid_off)
        pair_speedup = pair_off / max(pair_on, 1e-9)
        if gates is not None:
            wall_gate, pair_gate = gates
            if speedup < wall_gate:
                raise AssertionError(
                    f"indexed warm updates only {speedup:.2f}x faster "
                    f"than the per-window rebuild at stride {frac:.0%} "
                    f"(gate {wall_gate}x)")
            if pair_speedup < pair_gate:
                raise AssertionError(
                    f"indexed pair-space phase only {pair_speedup:.2f}x "
                    f"faster than the rebuild at stride {frac:.0%} "
                    f"(gate {pair_gate}x)")
        host_on = sum(s.plan_host_seconds for s in slid)
        host_off = sum(s.plan_host_seconds for s in slid_off)
        tag = f"s{int(frac * 100):02d}"
        rows.append((
            f"incr_host_{tag}", dt_on / max(len(slid), 1) * 1e6,
            f"windows={len(slid)};walltime_speedup={speedup:.2f}x;"
            f"pair_speedup={pair_speedup:.2f}x;"
            f"host_s={host_on:.3f}/{host_off:.3f};"
            f"host_pair_s={pair_on:.3f};"
            f"host_merge_s={sum(s.host_merge_seconds for s in slid):.3f};"
            f"host_emit_s={sum(s.host_emit_seconds for s in slid):.3f};"
            f"parity=ok"))


def run(rows: list):
    fig6_degree_distributions(rows)
    fig9_balance(rows)
    scaling_fig(rows, "patents", "fig10")
    scaling_fig(rows, "orkut", "fig11")
    scaling_fig(rows, "webgraph", "fig13")
    table_census(rows)
    om_scaling(rows)
    kernel_throughput(rows)
    fused_vs_reference(rows)
    streaming_vs_monolithic(rows)
    device_emission(rows)
    partitioned_scaling(rows)
    dispatch_overhead(rows)
    temporal_windows(rows)
    incr_host_smoke(rows)


def run_smoke(rows: list):
    """Fast subset for CI (benchmarks/check.sh): kernel throughput plus
    the fused-vs-reference parity/latency columns on reduced workloads."""
    kernel_throughput(rows)
    fused_vs_reference(rows)
