#!/usr/bin/env bash
# CI regression gate: tier-1 tests + a fast census benchmark smoke subset
# + a streaming-execution smoke.
#
# The smoke subset (benchmarks/run.py --smoke) runs the tricode-histogram
# kernel throughput comparison and the fused-vs-reference census columns on
# reduced workloads; the fused path asserts bit-identical censuses against
# the jnp backend, so a correctness regression in the fused kernel or the
# degree-oriented planner fails this script without the full benchmark.
#
# The streaming smoke (benchmarks/run.py --streaming-smoke) runs the
# chunked out-of-core engine on a small graph with a max_items budget
# forcing >= 4 chunks (including intra-pair splits) and asserts the
# streamed census is bit-identical to the monolithic dispatch on both the
# jnp and pallas-fused backends, with the per-chunk step compiled at most
# once — so the chunked path can never silently rot.
#
# The temporal smoke (benchmarks/run.py --temporal-smoke) runs the
# incremental sliding-window monitor at a 10% stride and asserts the
# delta-updated censuses are bit-identical to full per-window recomputes
# AND process >= 2x fewer census items, on the jnp and pallas-fused
# backends, with the resident session's step compiled at most once.
#
# The emit smoke (benchmarks/run.py --emit-smoke) asserts device-side
# work-item emission (descriptor upload + in-kernel pair→item expansion)
# is bit-identical to host emission on the jnp and pallas-fused backends
# — full streamed runs and incremental session updates — while shipping
# >= 4x fewer host→device plan bytes per chunk on both paths.
#
# The async smoke (benchmarks/run.py --async-smoke) runs the partitioned
# engine's async per-shard schedule on a synthetic 4x-skewed 8-shard
# partition (heaviest shard's chunk queue >= 4x the mean) and asserts
# bit-identity vs both the lock-step oracle and the single-device census,
# >= 1.5x walltime speedup over lock-step, and walltime within 1.25x of
# the balanced mean-shard ideal — so dropping the inter-shard barrier
# keeps paying for itself and can never silently regress to max-shard
# pacing.
#
# The mega smoke (benchmarks/run.py --mega-smoke) runs the async
# schedule's K-window megastep in the tiny-window dispatch-bound regime
# (many small windows, Python dispatch cost dominating device compute)
# and asserts bit-identity vs the lock-step oracle and the
# single-device census, >= 2x fewer device dispatches than one-window
# async at an equal window budget, and walltime within 1.15x of
# lock-step — so batching K windows per compiled dispatch keeps erasing
# the per-window round-trip and can never silently regress.
#
# The partition smoke (benchmarks/run.py --partition-smoke) runs the
# partitioned engine — each device of an 8-virtual-host mesh holds only
# its pair shard's relabeled local subgraph and walks its own descriptor
# stream — and asserts bit-identical censuses vs the single-device path
# (jnp × both emits × both orients, monolithic + streamed, pallas-fused,
# and an incremental partitioned session), shard item imbalance <= 1.2,
# and >= 2x per-device graph-byte reduction on the power-law workload.
#
# The 2D smoke (benchmarks/run.py --2d-smoke) runs the 2D pair×vertex
# decomposition on an 8-virtual-host mesh — the pair axis keeps the 1D
# LPT assignment, the vertex axis slices each shard's adjacency halo —
# and asserts bit-identical censuses vs the 1D partitioned path and the
# single-device reference ((4,2) and (2,4) meshes × both emits × both
# orients × async + lockstep, monolithic + streamed, plus an
# incremental 2D session), a >= 1.5x further cut in max per-device
# resident adjacency entries over 1D at 8 devices on the (4,2) mesh
# (>= 2x at (2,4)) on the power-law workload, and no total resident-
# byte regression at (4,2).
#
# The incr-host smoke (benchmarks/run.py --incr-host-smoke) runs warm
# sliding-window updates on the backbone-dominated monitoring workload
# (P ~ 150k pair space, 1-in-50 ephemeral churn, degree-oriented
# planner) with the persistent delta-incremental pair-space index
# (sessions' default) against the rebuild-from-scratch oracle
# (index=False) and asserts bit-identical censuses AND post-prune item
# totals, >= 1.5x warm-update walltime and >= 1.3x pair-space host
# phase at a 5% stride — so the O(delta log P + affected) host planner
# can never silently regress to the O(P) per-window rebuild + closed-
# form rescan it replaced.
#
# The fault smoke (benchmarks/run.py --fault-smoke) arms the fault-
# tolerance layer on an 8-virtual-device mesh and asserts three things:
# a seeded FaultPlan carrying a producer plan-gen error, a transient
# dispatch error and a device retirement finishes bit-identical to the
# single-device census with >= 1 recorded failover (the dead device's
# window queue drained by the survivors through their already-compiled
# steps); an armed-but-idle engine (injection hooks threaded, watchdog
# set, empty plan) stays within 1.05x of the plain async walltime; and
# a run killed mid-stream with checkpoint journaling resumes to the
# exact same census while skipping > 0 journaled windows.
#
# Usage: bash benchmarks/check.sh   (from the repo root)
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 tests =="
python -m pytest -x -q

echo "== census benchmark smoke subset =="
python -m benchmarks.run --smoke

echo "== streaming census smoke (chunked == monolithic) =="
python -m benchmarks.run --streaming-smoke

echo "== temporal census smoke (incremental == full, >= 2x item cut) =="
python -m benchmarks.run --temporal-smoke

echo "== emit smoke (device == host emission, >= 4x fewer plan bytes) =="
python -m benchmarks.run --emit-smoke

echo "== partition smoke (sharded graph == single device, >= 2x fewer graph bytes) =="
python -m benchmarks.run --partition-smoke

echo "== async smoke (per-shard streams == lock-step, >= 1.5x on 4x skew) =="
python -m benchmarks.run --async-smoke

echo "== mega smoke (K-window megastep == lock-step, >= 2x fewer dispatches) =="
python -m benchmarks.run --mega-smoke

echo "== 2d smoke (pair×vertex mesh == 1D == reference, >= 1.5x further halo cut) =="
python -m benchmarks.run --2d-smoke

echo "== incr-host smoke (indexed planner == rebuild oracle, >= 1.5x warm updates, >= 1.3x pair phase) =="
python -m benchmarks.run --incr-host-smoke

echo "== fault smoke (inject + retry + fail over + resume, still bit-identical) =="
python -m benchmarks.run --fault-smoke
