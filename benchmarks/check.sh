#!/usr/bin/env bash
# CI regression gate: tier-1 tests + a fast census benchmark smoke subset.
#
# The smoke subset (benchmarks/run.py --smoke) runs the tricode-histogram
# kernel throughput comparison and the fused-vs-reference census columns on
# reduced workloads; the fused path asserts bit-identical censuses against
# the jnp backend, so a correctness regression in the fused kernel or the
# degree-oriented planner fails this script without the full benchmark.
#
# Usage: bash benchmarks/check.sh   (from the repo root)
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 tests =="
python -m pytest -x -q

echo "== census benchmark smoke subset =="
python -m benchmarks.run --smoke
