"""GQA attention: RoPE/M-RoPE, QKV bias, local windows, chunked compute,
KV-cache decode with sequence-parallel partial-softmax merge (via GSPMD).

Memory policy: prefill/train attention is computed in *unrolled* query
chunks (python loop, static slices) so (a) peak score memory is bounded by
``q_chunk`` and (b) XLA's cost analysis counts every chunk — a deliberate
choice over ``lax.scan``, whose body is cost-counted once (DESIGN.md §4).
For local attention the chunking also bounds FLOPs: each query chunk only
attends to its static ``[start - window, end)`` key slice, making the
compute genuinely sub-quadratic.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import ParamDef, apply_mrope, apply_rope

NEG_INF = -2.0e38


def attn_schema(cfg, cross: bool = False) -> dict:
    d, hd = cfg.d_model, cfg.head_dim
    hq, hkv = cfg.num_heads, cfg.num_kv_heads
    s = {
        "wq": ParamDef((d, hq, hd), ("embed", "heads", "head_dim")),
        "wk": ParamDef((d, hkv, hd), ("embed", "kv_heads", "head_dim")),
        "wv": ParamDef((d, hkv, hd), ("embed", "kv_heads", "head_dim")),
        "wo": ParamDef((hq, hd, d), ("heads", "head_dim", "embed")),
    }
    if cfg.qkv_bias:
        s["bq"] = ParamDef((hq, hd), ("heads", "head_dim"), "zeros")
        s["bk"] = ParamDef((hkv, hd), ("kv_heads", "head_dim"), "zeros")
        s["bv"] = ParamDef((hkv, hd), ("kv_heads", "head_dim"), "zeros")
    return s


def _project_qkv(cfg, p, xq, xkv):
    q = jnp.einsum("bsd,dhk->bshk", xq, p["wq"].astype(xq.dtype))
    k = jnp.einsum("bsd,dhk->bshk", xkv, p["wk"].astype(xkv.dtype))
    v = jnp.einsum("bsd,dhk->bshk", xkv, p["wv"].astype(xkv.dtype))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(q.dtype)
        k = k + p["bk"].astype(k.dtype)
        v = v + p["bv"].astype(v.dtype)
    return q, k, v


def _positions(cfg, batch, cache=None):
    if cache is not None:
        pos = cache["pos"]
        return jnp.full((batch, 1), pos, jnp.int32)
    return None  # caller provides train positions


def _rope(cfg, x, positions):
    if cfg.rope_variant == "none" or positions is None:
        return x
    if cfg.rope_variant == "mrope":
        return apply_mrope(x, positions, cfg.rope_theta)
    return apply_rope(x, positions, cfg.rope_theta)


def _iota_mask(lo: int, hi: int, k_lo: int, k_hi: int, causal: bool,
               window: int = 0):
    """Mask via broadcasted_iota — NEVER a concrete numpy constant (a
    32k×32k bool constant embedded in the IR costs 1 GB of host RAM at
    trace time; iota costs nothing)."""
    rows, cols = hi - lo, k_hi - k_lo
    qpos = jax.lax.broadcasted_iota(jnp.int32, (rows, cols), 0) + lo
    kpos = jax.lax.broadcasted_iota(jnp.int32, (rows, cols), 1) + k_lo
    if not causal:
        return jnp.ones((rows, cols), bool)
    m = kpos <= qpos
    if window:
        m = m & (kpos > qpos - window)
    return m


def _chunked_scores_softmax(q, k, v, causal: bool, q_chunk: int):
    """Unrolled-chunk softmax attention.

    q: (B, Sq, Hkv, G, D); k, v: (B, Skv, Hkv, D).
    """
    b, sq, hkv, g, d = q.shape
    skv = k.shape[1]
    scale = 1.0 / np.sqrt(d)
    outs = []
    for lo in range(0, sq, q_chunk):
        hi = min(lo + q_chunk, sq)
        qc = q[:, lo:hi]
        scores = jnp.einsum("bqkgd,bskd->bkgqs", qc, k) * scale
        m = _iota_mask(lo, hi, 0, skv, causal)
        scores = jnp.where(m[None, None, None], scores, NEG_INF)
        p = jax.nn.softmax(scores.astype(jnp.float32), axis=-1)
        p = p.astype(v.dtype)
        outs.append(jnp.einsum("bkgqs,bskd->bqkgd", p, v))
    return jnp.concatenate(outs, axis=1)


def _local_chunked(q, k, v, window: int, q_chunk: int):
    """Banded local attention: each q chunk sees a static key slice of
    length (window + chunk); compute is O(S·window), not O(S²)."""
    b, sq, hkv, g, d = q.shape
    skv = k.shape[1]
    scale = 1.0 / np.sqrt(d)
    outs = []
    for lo in range(0, sq, q_chunk):
        hi = min(lo + q_chunk, sq)
        k_lo = max(0, hi - q_chunk - window + 1)
        kc = k[:, k_lo:hi]
        vc = v[:, k_lo:hi]
        qc = q[:, lo:hi]
        scores = jnp.einsum("bqkgd,bskd->bkgqs", qc, kc) * scale
        m = _iota_mask(lo, hi, k_lo, hi, True, window)
        scores = jnp.where(m[None, None, None], scores, NEG_INF)
        p = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(vc.dtype)
        outs.append(jnp.einsum("bkgqs,bskd->bqkgd", p, vc))
    return jnp.concatenate(outs, axis=1)


def attention(cfg, p, x, *, positions=None, layer_window: int = 0,
              causal: bool = True, xkv=None, q_chunk: int = 512,
              kv_positions=None):
    """Full-sequence (train / prefill / encoder) attention."""
    b, s, _ = x.shape
    xkv = x if xkv is None else xkv
    skv = xkv.shape[1]
    q, k, v = _project_qkv(cfg, p, x, xkv)
    q = _rope(cfg, q, positions)
    k = _rope(cfg, k, kv_positions if kv_positions is not None else
              (positions if xkv is x else None))
    hkv = cfg.num_kv_heads
    g = cfg.num_heads // hkv
    q = q.reshape(b, s, hkv, g, cfg.head_dim)

    if layer_window and causal:
        o = _local_chunked(q, k, v, layer_window, min(q_chunk, s))
    else:
        o = _chunked_scores_softmax(q, k, v, causal, min(q_chunk, s))
    o = o.reshape(b, s, cfg.num_heads, cfg.head_dim)
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(o.dtype))


def init_kv_cache(cfg, batch: int, seq_len: int, layer_window: int,
                  dtype=jnp.bfloat16, kv_quant: bool = False) -> dict:
    """Abstract/concrete KV cache for one attention layer.

    Local-attention layers keep only a ring buffer of ``window`` keys —
    this is what makes the long_500k cell feasible for recurrentgemma.
    ``kv_quant`` stores K/V as int8 with per-(position, head) f32 scales
    (2.1x smaller; the §Perf memory-term optimization for decode).
    """
    s = min(seq_len, layer_window) if layer_window else seq_len
    shp = (batch, s, cfg.num_kv_heads, cfg.head_dim)
    if kv_quant:
        return {"k": jnp.zeros(shp, jnp.int8),
                "v": jnp.zeros(shp, jnp.int8),
                "k_scale": jnp.zeros(shp[:3], jnp.float32),
                "v_scale": jnp.zeros(shp[:3], jnp.float32)}
    return {"k": jnp.zeros(shp, dtype), "v": jnp.zeros(shp, dtype)}


def _quantize_kv(x):
    """(B, 1, H, D) -> int8 values + (B, 1, H) scale."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale


def decode_attention(cfg, p, x, cache, pos, *, layer_window: int = 0,
                     cross_kv=None, q_chunk: int = 0):
    """Single-token decode. x: (B, 1, d). cache: {"k","v"} (B, S, Hkv, D).

    Returns (out, new_cache). With the cache's sequence dim sharded over
    the ``model`` mesh axis, GSPMD turns the softmax reductions into the
    flash-decoding partial-max/sum merge across shards.
    """
    b = x.shape[0]
    if cross_kv is not None:
        k, v = cross_kv["k"], cross_kv["v"]
        q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
        if cfg.qkv_bias:
            q = q + p["bq"].astype(q.dtype)
        new_cache = cache
        kv_len = k.shape[1]
        valid = jnp.ones((kv_len,), bool)
    else:
        posv = jnp.full((b, 1), pos, jnp.int32)
        q, k_new, v_new = _project_qkv(cfg, p, x, x)
        q = _rope(cfg, q, posv if cfg.rope_variant != "mrope" else
                  jnp.broadcast_to(posv, (3, b, 1)))
        k_new = _rope(cfg, k_new, posv if cfg.rope_variant != "mrope" else
                      jnp.broadcast_to(posv, (3, b, 1)))
        s_cache = cache["k"].shape[1]
        slot = pos % s_cache if layer_window else pos
        quant = cache["k"].dtype == jnp.int8
        if quant:
            kq, ks = _quantize_kv(k_new)
            vq, vs = _quantize_kv(v_new)
            new_cache = {
                "k": jax.lax.dynamic_update_slice(
                    cache["k"], kq, (0, slot, 0, 0)),
                "v": jax.lax.dynamic_update_slice(
                    cache["v"], vq, (0, slot, 0, 0)),
                "k_scale": jax.lax.dynamic_update_slice(
                    cache["k_scale"], ks, (0, slot, 0)),
                "v_scale": jax.lax.dynamic_update_slice(
                    cache["v_scale"], vs, (0, slot, 0)),
            }
            k = (new_cache["k"].astype(jnp.float32) *
                 new_cache["k_scale"][..., None]).astype(x.dtype)
            v = (new_cache["v"].astype(jnp.float32) *
                 new_cache["v_scale"][..., None]).astype(x.dtype)
        else:
            k = jax.lax.dynamic_update_slice(
                cache["k"], k_new.astype(cache["k"].dtype),
                (0, slot, 0, 0))
            v = jax.lax.dynamic_update_slice(
                cache["v"], v_new.astype(cache["v"].dtype),
                (0, slot, 0, 0))
            new_cache = {"k": k, "v": v}
        idx = jnp.arange(s_cache)
        if layer_window:
            valid = (idx <= slot) | (pos >= s_cache)   # ring buffer
        else:
            valid = idx <= pos
    hkv = cfg.num_kv_heads
    g = cfg.num_heads // hkv
    q = q.reshape(b, 1, hkv, g, cfg.head_dim)
    scale = 1.0 / np.sqrt(cfg.head_dim)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", q, k.astype(q.dtype)) * scale
    scores = jnp.where(valid[None, None, None, None, :], scores, NEG_INF)
    pr = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(x.dtype)
    o = jnp.einsum("bkgqs,bskd->bqkgd", pr, v.astype(x.dtype))
    o = o.reshape(b, 1, cfg.num_heads, cfg.head_dim)
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(o.dtype))
    return out, new_cache
