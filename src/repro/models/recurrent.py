"""Recurrent sequence mixers: mLSTM, sLSTM (xLSTM) and RG-LRU (Griffin /
RecurrentGemma).

* mLSTM — matrix-memory LSTM with exponential gating. Trained with the
  chunkwise-parallel form (quadratic within a chunk, (C, n, m) state scan
  across chunks); decoded with the O(1) recurrent step. The two forms are
  asserted equivalent in the property tests.
* sLSTM — scalar-memory LSTM with recurrent weights; strictly sequential
  (``lax.scan`` over time), per the xLSTM paper.
* RG-LRU — elementwise gated linear recurrence, computed with
  ``jax.lax.associative_scan`` (log-depth, fully parallel — and, unlike a
  scan, fully visible to XLA's cost analysis).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import ParamDef

F32 = jnp.float32


# ================================================================= mLSTM

#: xLSTM qkv_proj_blocksize: q/k/v are block-diagonal with 4x4 blocks
#: (near-diagonal), which is what puts the 48L/2048d config at ~1.3B.
QKV_BLOCK = 4


def mlstm_schema(cfg) -> dict:
    d, h = cfg.d_model, cfg.num_heads
    di = 2 * d                      # xLSTM mLSTM projection factor 2
    nb = di // QKV_BLOCK
    return {
        "w_in": ParamDef((d, di), ("embed", "ffn")),
        "w_gate": ParamDef((d, di), ("embed", "ffn")),
        "wq": ParamDef((nb, QKV_BLOCK, QKV_BLOCK), ("ffn", None, None)),
        "wk": ParamDef((nb, QKV_BLOCK, QKV_BLOCK), ("ffn", None, None)),
        "wv": ParamDef((nb, QKV_BLOCK, QKV_BLOCK), ("ffn", None, None)),
        "w_if": ParamDef((di, 2 * h), ("ffn", None)),   # i, f gate heads
        "b_if": ParamDef((2 * h,), (None,), "zeros"),
        "ln_scale": ParamDef((di,), ("ffn",), "ones"),
        "w_out": ParamDef((di, d), ("ffn", "embed")),
    }


def _headwise_proj(x, w):
    """Block-diagonal projection: x (..., di), w (nb, bs, bs)."""
    nb, bs, _ = w.shape
    xs = x.reshape(*x.shape[:-1], nb, bs)
    y = jnp.einsum("...nk,nkj->...nj", xs, w.astype(x.dtype))
    return y.reshape(x.shape)


def _mlstm_gates(p, xi, h):
    gf = jnp.einsum("btd,dg->btg", xi, p["w_if"].astype(xi.dtype))
    gf = gf.astype(F32) + p["b_if"].astype(F32)
    log_i = gf[..., :h]                          # i = exp(raw)
    log_f = -jax.nn.softplus(-gf[..., h:])       # f = sigmoid(raw)
    return log_i, log_f


def mlstm_chunkwise(p, x, h: int, chunk: int = 256, state=None,
                    unroll: bool = False):
    """x: (B, S, d_in). Returns (y, final_state).

    state = (C (B,H,K,K), n (B,H,K), m (B,H)) with K = d_in // H.
    """
    b, s, di = x.shape
    k_dim = di // h
    xs = x
    log_i, log_f = _mlstm_gates(p, xs, h)                     # (B,S,H)

    q = _headwise_proj(xs, p["wq"])
    k = _headwise_proj(xs, p["wk"])
    v = _headwise_proj(xs, p["wv"])
    split = lambda z: z.reshape(b, s, h, k_dim)
    q, k, v = split(q), split(k), split(v)
    q = q * (1.0 / np.sqrt(k_dim))

    if state is None:
        c0 = jnp.zeros((b, h, k_dim, k_dim), F32)
        n0 = jnp.zeros((b, h, k_dim), F32)
        m0 = jnp.full((b, h), -1e30, F32)
        state = (c0, n0, m0)

    nchunks = -(-s // chunk)
    pad = nchunks * chunk - s
    if pad:
        zpad = lambda z: jnp.pad(z, ((0, 0), (0, pad)) + ((0, 0),) *
                                 (z.ndim - 2))
        q, k, v = zpad(q), zpad(k), zpad(v)
        log_i = jnp.pad(log_i, ((0, 0), (0, pad), (0, 0)))
        log_f = jnp.pad(log_f, ((0, 0), (0, pad), (0, 0)))
    w = chunk
    resh = lambda z: z.reshape(b, nchunks, w, *z.shape[2:]).swapaxes(0, 1)
    qc, kc, vc = resh(q), resh(k), resh(v)
    lic, lfc = resh(log_i), resh(log_f)

    def step(state, inputs):
        c0, n0, m0 = state
        q, k, v, li, lf = inputs                  # (B,W,H,K)/(B,W,H)
        cf = jnp.cumsum(lf, axis=1)               # F_t  (B,W,H)
        # intra-chunk decay matrix: D[t, s] = F_t - F_s + log_i_s, s <= t
        dmat = cf[:, :, None, :] - cf[:, None, :, :] + li[:, None, :, :]
        tidx = np.arange(w)
        causal = jnp.asarray(tidx[:, None] >= tidx[None, :])
        dmat = jnp.where(causal[None, :, :, None], dmat, -jnp.inf)
        a_inter = cf + m0[:, None, :]             # (B,W,H) decay of carry
        m_t = jnp.maximum(jnp.max(dmat, axis=2), a_inter)
        m_t = jnp.maximum(m_t, -1e30)
        dexp = jnp.exp(dmat - m_t[:, :, None, :])             # (B,W,W,H)
        inter_w = jnp.exp(a_inter - m_t)                      # (B,W,H)

        scores = jnp.einsum("bthk,bshk->btsh", q.astype(F32),
                            k.astype(F32)) * dexp
        num_intra = jnp.einsum("btsh,bshV->bthV", scores, v.astype(F32))
        num_inter = jnp.einsum("bthk,bhkV->bthV", q.astype(F32), c0)
        num = num_intra + num_inter * inter_w[..., None]
        den_intra = jnp.sum(scores, axis=2)                   # (B,W,H)
        den_inter = jnp.einsum("bthk,bhk->bth", q.astype(F32), n0)
        den = den_intra + den_inter * inter_w
        denom = jnp.maximum(jnp.abs(den), jnp.exp(-m_t))
        y = num / denom[..., None]

        # carry to next chunk
        ftot = cf[:, -1]                                      # (B,H)
        m_next = jnp.maximum(ftot + m0,
                             jnp.max(ftot[:, None] - cf + li, axis=1))
        wts = jnp.exp(ftot[:, None] - cf + li - m_next[:, None])  # (B,W,H)
        c_next = (jnp.exp(ftot + m0 - m_next)[..., None, None] * c0 +
                  jnp.einsum("bwh,bwhk,bwhV->bhkV", wts,
                             k.astype(F32), v.astype(F32)))
        n_next = (jnp.exp(ftot + m0 - m_next)[..., None] * n0 +
                  jnp.einsum("bwh,bwhk->bhk", wts, k.astype(F32)))
        return (c_next, n_next, m_next), y

    state, ys = jax.lax.scan(step, state, (qc, kc, vc, lic, lfc),
                             unroll=nchunks if unroll else 1)
    y = ys.swapaxes(0, 1).reshape(b, nchunks * w, h, k_dim)[:, :s]
    return y.reshape(b, s, di).astype(x.dtype), state


def mlstm_decode_step(p, x, state, h: int):
    """x: (B, 1, d_in); O(1) recurrent update (the sequential form)."""
    b, _, di = x.shape
    k_dim = di // h
    log_i, log_f = _mlstm_gates(p, x, h)                      # (B,1,H)
    log_i, log_f = log_i[:, 0], log_f[:, 0]
    q = _headwise_proj(x, p["wq"])[:, 0]
    k = _headwise_proj(x, p["wk"])[:, 0]
    v = _headwise_proj(x, p["wv"])[:, 0]
    q = q.reshape(b, h, k_dim).astype(F32) * (1.0 / np.sqrt(k_dim))
    k = k.reshape(b, h, k_dim).astype(F32)
    v = v.reshape(b, h, k_dim).astype(F32)
    c0, n0, m0 = state
    m1 = jnp.maximum(log_f + m0, log_i)
    fw = jnp.exp(log_f + m0 - m1)
    iw = jnp.exp(log_i - m1)
    c1 = fw[..., None, None] * c0 + iw[..., None, None] * (
        k[..., :, None] * v[..., None, :])
    n1 = fw[..., None] * n0 + iw[..., None] * k
    num = jnp.einsum("bhk,bhkV->bhV", q, c1)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", q, n1)),
                      jnp.exp(-m1))
    y = (num / den[..., None]).reshape(b, 1, di)
    return y.astype(x.dtype), (c1, n1, m1)


def mlstm_block(cfg, p, x, *, chunk: int = 256, state=None, decode=False,
                unroll: bool = False):
    """Full mLSTM block: up-proj, mixer, gate, down-proj."""
    xi = jnp.einsum("bsd,de->bse", x, p["w_in"].astype(x.dtype))
    gate = jnp.einsum("bsd,de->bse", x, p["w_gate"].astype(x.dtype))
    if decode:
        y, state = mlstm_decode_step(p, xi, state, cfg.num_heads)
    else:
        y, state = mlstm_chunkwise(p, xi, cfg.num_heads, chunk, state,
                                   unroll=unroll)
    # per-head group norm (RMS over head dim)
    b, s, di = y.shape
    hd = di // cfg.num_heads
    yh = y.reshape(b, s, cfg.num_heads, hd).astype(F32)
    yh = yh * jax.lax.rsqrt(jnp.mean(yh * yh, axis=-1, keepdims=True) + 1e-6)
    y = yh.reshape(b, s, di) * p["ln_scale"].astype(F32)
    y = y.astype(x.dtype) * jax.nn.silu(gate)
    out = jnp.einsum("bse,ed->bsd", y, p["w_out"].astype(y.dtype))
    return out, state


def mlstm_init_state(cfg, batch: int, dtype=F32):
    di = 2 * cfg.d_model
    h = cfg.num_heads
    k = di // h
    return (jnp.zeros((batch, h, k, k), F32),
            jnp.zeros((batch, h, k), F32),
            jnp.full((batch, h), -1e30, F32))


# ================================================================= sLSTM

def slstm_schema(cfg) -> dict:
    d = cfg.d_model
    h = cfg.num_heads
    hd = d // h
    return {
        "w_gates": ParamDef((d, 4 * d), ("embed", "ffn")),   # z, i, f, o
        "r_gates": ParamDef((h, hd, 4 * hd), ("heads", None, None)),
        "b_gates": ParamDef((4 * d,), ("ffn",), "zeros"),
        "ln_scale": ParamDef((d,), ("embed",), "ones"),
        "w_up": ParamDef((d, 4 * d // 3), ("embed", "ffn")),
        "w_up_gate": ParamDef((d, 4 * d // 3), ("embed", "ffn")),
        "w_down": ParamDef((4 * d // 3, d), ("ffn", "embed")),
    }


def slstm_scan(cfg, p, x, state=None):
    """Strictly sequential sLSTM over time. x: (B, S, d)."""
    b, s, d = x.shape
    h = cfg.num_heads
    hd = d // h
    wx = jnp.einsum("bsd,dg->bsg", x, p["w_gates"].astype(x.dtype))
    wx = wx.astype(F32) + p["b_gates"].astype(F32)            # (B,S,4d)
    wx = wx.reshape(b, s, h, 4 * hd)
    if state is None:
        state = slstm_init_state(cfg, b)

    r = p["r_gates"].astype(F32)

    def step(carry, wx_t):
        c, n, hprev, m = carry                                # (B,H,hd)...
        rec = jnp.einsum("bhk,hkg->bhg", hprev, r)            # (B,H,4hd)
        g = wx_t.astype(F32) + rec
        z = jnp.tanh(g[..., :hd])
        log_i = g[..., hd:2 * hd]
        log_f = -jax.nn.softplus(-g[..., 2 * hd:3 * hd])
        o = jax.nn.sigmoid(g[..., 3 * hd:])
        m1 = jnp.maximum(log_f + m, log_i)
        fw, iw = jnp.exp(log_f + m - m1), jnp.exp(log_i - m1)
        c1 = fw * c + iw * z
        n1 = jnp.maximum(fw * n + iw, jnp.exp(-m1))
        h1 = o * c1 / n1
        return (c1, n1, h1, m1), h1

    wx_t = wx.swapaxes(0, 1)                                  # (S,B,H,4hd)
    state, ys = jax.lax.scan(step, state, wx_t)
    y = ys.swapaxes(0, 1).reshape(b, s, d).astype(x.dtype)
    return y, state


def slstm_init_state(cfg, batch: int):
    h = cfg.num_heads
    hd = cfg.d_model // h
    z = jnp.zeros((batch, h, hd), F32)
    return (z, z + 1e-6, z, jnp.full((batch, h, hd), -1e30, F32))


def slstm_block(cfg, p, x, *, state=None, decode=False):
    y, state = slstm_scan(cfg, p, x, state)
    b, s, d = y.shape
    h = cfg.num_heads
    yh = y.reshape(b, s, h, d // h).astype(F32)
    yh = yh * jax.lax.rsqrt(jnp.mean(yh * yh, axis=-1, keepdims=True) + 1e-6)
    y = (yh.reshape(b, s, d) * p["ln_scale"].astype(F32)).astype(x.dtype)
    up = jnp.einsum("bsd,df->bsf", y, p["w_up"].astype(y.dtype))
    gate = jnp.einsum("bsd,df->bsf", y, p["w_up_gate"].astype(y.dtype))
    out = jnp.einsum("bsf,fd->bsd", jax.nn.gelu(gate) * up,
                     p["w_down"].astype(up.dtype))
    return out, state


# ================================================================= RG-LRU

def rglru_schema(cfg) -> dict:
    d, w = cfg.d_model, cfg.lru_width
    cw = cfg.conv1d_width
    return {
        "w_x": ParamDef((d, w), ("embed", "lru")),
        "w_gate_branch": ParamDef((d, w), ("embed", "lru")),
        "conv_w": ParamDef((cw, w), (None, "lru"), "normal"),
        "conv_b": ParamDef((w,), ("lru",), "zeros"),
        "w_rec_gate": ParamDef((w, w), ("lru", "lru")),
        "w_in_gate": ParamDef((w, w), ("lru", "lru")),
        "lam": ParamDef((w,), ("lru",), "normal"),
        "w_out": ParamDef((w, d), ("lru", "embed")),
    }

_C_RGLRU = 8.0


def _rglru_core(p, u, h0=None):
    """u: (B, S, W) post-conv activations; gated linear recurrence."""
    r = jax.nn.sigmoid(jnp.einsum(
        "bsw,wv->bsv", u, p["w_rec_gate"].astype(u.dtype)).astype(F32))
    i = jax.nn.sigmoid(jnp.einsum(
        "bsw,wv->bsv", u, p["w_in_gate"].astype(u.dtype)).astype(F32))
    log_a0 = -jax.nn.softplus(-p["lam"].astype(F32))          # log sigmoid
    log_a = _C_RGLRU * r * log_a0[None, None, :]              # (B,S,W)
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    b_t = gated * i * u.astype(F32)
    if h0 is not None:
        # fold the carried state into the first step
        b_t = b_t.at[:, 0].add(a[:, 0] * h0)
    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2
    _, h = jax.lax.associative_scan(combine, (a, b_t), axis=1)
    return h.astype(u.dtype), h[:, -1].astype(F32)


def rglru_block(cfg, p, x, *, state=None, decode=False):
    """Griffin recurrent block: proj -> causal conv -> RG-LRU -> gate."""
    u = jnp.einsum("bsd,dw->bsw", x, p["w_x"].astype(x.dtype))
    gate = jax.nn.gelu(jnp.einsum(
        "bsd,dw->bsw", x, p["w_gate_branch"].astype(x.dtype)))
    cw = cfg.conv1d_width
    if decode:
        conv_buf, h0 = state                       # (B, cw-1, W), (B, W)
        seq = jnp.concatenate([conv_buf, u.astype(conv_buf.dtype)], axis=1)
        conv_in = seq[:, -cw:]                     # (B, cw, W)
        u_c = jnp.einsum("bcw,cw->bw", conv_in,
                         p["conv_w"].astype(conv_in.dtype))
        u_c = (u_c + p["conv_b"].astype(u_c.dtype))[:, None]
        r = jax.nn.sigmoid(jnp.einsum(
            "bsw,wv->bsv", u_c, p["w_rec_gate"].astype(u_c.dtype)
        ).astype(F32))[:, 0]
        i = jax.nn.sigmoid(jnp.einsum(
            "bsw,wv->bsv", u_c, p["w_in_gate"].astype(u_c.dtype)
        ).astype(F32))[:, 0]
        log_a0 = -jax.nn.softplus(-p["lam"].astype(F32))
        log_a = _C_RGLRU * r * log_a0[None, :]
        a = jnp.exp(log_a)
        gmul = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
        h1 = a * h0 + gmul * i * u_c[:, 0].astype(F32)
        y = h1[:, None].astype(x.dtype)
        new_state = (seq[:, -(cw - 1):], h1)
    else:
        # causal depthwise conv via static shifts (width is tiny)
        acc = jnp.zeros_like(u, dtype=F32)
        for j in range(cw):
            shifted = jnp.pad(u, ((0, 0), (cw - 1 - j, 0), (0, 0))
                              )[:, :u.shape[1]]
            acc = acc + shifted.astype(F32) * p["conv_w"][j].astype(F32)
        u_c = (acc + p["conv_b"].astype(F32)).astype(x.dtype)
        h0 = state[1] if state is not None else None
        y, h_last = _rglru_core(p, u_c, h0)
        buf_src = jnp.concatenate(
            [jnp.zeros((u.shape[0], cw - 1, u.shape[2]), u.dtype), u], 1)
        new_state = (buf_src[:, -(cw - 1):].astype(F32), h_last)
    out = jnp.einsum("bsw,wd->bsd", y * gate.astype(y.dtype),
                     p["w_out"].astype(y.dtype))
    return out, new_state


def rglru_init_state(cfg, batch: int):
    return (jnp.zeros((batch, cfg.conv1d_width - 1, cfg.lru_width), F32),
            jnp.zeros((batch, cfg.lru_width), F32))
