"""Shared model machinery: parameter schema, norms, rotary embeddings.

Parameters are described by a *schema tree* of :class:`ParamDef` leaves —
a single source of truth from which we derive (a) materialized arrays for
real runs, (b) ``ShapeDtypeStruct`` stand-ins for the dry-run, and (c)
logical-axis PartitionSpecs for the sharding rules. Keeping these three
views in one place is what lets every (arch × shape × mesh) cell lower
without allocation.
"""

from __future__ import annotations

import hashlib
from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class ParamDef:
    """One parameter: shape + logical axes + init recipe."""
    shape: tuple
    axes: tuple                  # logical axis name (or None) per dim
    init: str = "fan_in"         # fan_in | zeros | ones | normal | embed
    dtype: Any = jnp.float32

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def is_def(x) -> bool:
    return isinstance(x, ParamDef)


def tree_paths(tree, prefix=()):
    """Yield (path, leaf) for a nested dict tree of ParamDefs."""
    if is_def(tree):
        yield prefix, tree
        return
    for k in sorted(tree):
        yield from tree_paths(tree[k], prefix + (k,))


def _leaf_key(root_key, path):
    h = int.from_bytes(
        hashlib.md5("/".join(map(str, path)).encode()).digest()[:4], "big")
    return jax.random.fold_in(root_key, h)


def _materialize(d: ParamDef, key) -> jax.Array:
    if d.init == "zeros":
        return jnp.zeros(d.shape, d.dtype)
    if d.init == "ones":
        return jnp.ones(d.shape, d.dtype)
    fan_in = d.shape[0] if len(d.shape) >= 2 else max(d.shape[-1], 1)
    if d.init == "embed":
        std = 1.0
    elif d.init == "normal":
        std = 0.02
    else:  # fan_in (lecun normal)
        std = float(np.sqrt(1.0 / fan_in))
    return (jax.random.normal(key, d.shape, jnp.float32) * std).astype(d.dtype)


def init_params(schema, key):
    """Materialize a schema tree into concrete parameter arrays."""
    def walk(node, path):
        if is_def(node):
            return _materialize(node, _leaf_key(key, path))
        return {k: walk(v, path + (k,)) for k, v in node.items()}
    return walk(schema, ())


def abstract_params(schema):
    """ShapeDtypeStruct tree (no allocation) — the dry-run's param view."""
    def walk(node):
        if is_def(node):
            return jax.ShapeDtypeStruct(node.shape, node.dtype)
        return {k: walk(v) for k, v in node.items()}
    return walk(schema)


def schema_axes(schema):
    """Tree of logical-axis tuples mirroring the schema."""
    def walk(node):
        if is_def(node):
            return node.axes
        return {k: walk(v) for k, v in node.items()}
    return walk(schema)


def count_schema_params(schema) -> int:
    return sum(int(np.prod(d.shape)) for _, d in tree_paths(schema))


# ---------------------------------------------------------------- norms

def rms_norm(x, scale, eps=1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
            ).astype(x.dtype)


def layer_norm(x, scale, bias=None, eps=1e-5):
    x32 = x.astype(jnp.float32)
    mu = x32.mean(axis=-1, keepdims=True)
    var = ((x32 - mu) ** 2).mean(axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
    if bias is not None:
        y = y + bias.astype(jnp.float32)
    return y.astype(x.dtype)


def norm_schema(cfg) -> dict:
    d = {"scale": ParamDef((cfg.d_model,), ("embed",), "ones")}
    if cfg.norm == "layernorm":
        d["bias"] = ParamDef((cfg.d_model,), ("embed",), "zeros")
    return d


def apply_norm(cfg, p, x):
    if cfg.norm == "layernorm":
        return layer_norm(x, p["scale"], p.get("bias"))
    return rms_norm(x, p["scale"])


# ---------------------------------------------------------------- rope

def rope_freqs(head_dim: int, theta: float) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, head_dim, 2) / head_dim))


def apply_rope(x, positions, theta: float):
    """Rotate-half RoPE. x: (B, S, H, D); positions: (B, S) int32."""
    d = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(d, theta), jnp.float32)
    ang = positions[..., None].astype(jnp.float32) * freqs    # (B,S,D/2)
    sin, cos = jnp.sin(ang)[:, :, None, :], jnp.cos(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


def apply_mrope(x, positions3, theta: float,
                sections: tuple | None = None):
    """Multimodal RoPE (Qwen2-VL): 3 position streams (t, h, w) drive
    disjoint frequency sections of the half-dim. positions3: (3, B, S)."""
    d = x.shape[-1]
    half = d // 2
    if sections is None:
        s_h = half // 4
        sections = (half - 2 * s_h, s_h, s_h)
    assert sum(sections) == half, (sections, half)
    freqs = jnp.asarray(rope_freqs(d, theta), jnp.float32)    # (half,)
    # select the position stream per frequency slot -> (half, B, S)
    sec_id = jnp.asarray(
        np.repeat(np.arange(3), np.asarray(sections)), jnp.int32)  # (half,)
    p3 = positions3.astype(jnp.float32)                        # (3,B,S)
    pos = p3[sec_id]                                           # (half,B,S)
    ang = jnp.moveaxis(pos, 0, -1) * freqs                     # (B,S,half)
    sin, cos = jnp.sin(ang)[:, :, None, :], jnp.cos(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


def pad_vocab(v: int, multiple: int = 256) -> int:
    """Internal vocab padding (logical vocab unchanged; masked in loss)."""
    return -(-v // multiple) * multiple
