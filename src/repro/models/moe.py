"""Mixture-of-Experts: top-k router + capacity-based scatter dispatch.

The dispatch path deliberately reuses the paper's parallelization patterns
(DESIGN.md §5):

* the (token × expert-slot) assignment is flattened into a dense work-item
  array — the census planner's "manhattan collapse" applied to routing;
* per-device router/load statistics are privatized partial sums combined
  with one ``psum`` (the paper's 64 local census vectors);
* tokens land in a static (E, C, d) buffer via scatter-add (no atomics, no
  ragged loops), experts run as one batched einsum sharded over the
  ``experts`` logical axis (EP).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import ParamDef
from repro.models.ffn import GATED


def moe_schema(cfg) -> dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    s = {
        "router": ParamDef((d, e), ("embed", "experts"), "normal"),
        "w_up": ParamDef((e, d, f), ("experts", "embed", "ffn")),
        "w_down": ParamDef((e, f, d), ("experts", "ffn", "embed")),
    }
    if cfg.ffn_activation in GATED:
        s["w_gate"] = ParamDef((e, d, f), ("experts", "embed", "ffn"))
    if cfg.num_shared_experts:
        fs = f * cfg.num_shared_experts
        s["shared_up"] = ParamDef((d, fs), ("embed", "ffn"))
        s["shared_down"] = ParamDef((fs, d), ("ffn", "embed"))
        if cfg.ffn_activation in GATED:
            s["shared_gate"] = ParamDef((d, fs), ("embed", "ffn"))
    return s


def _expert_ffn(cfg, p, xe):
    """xe: (E, C, d) -> (E, C, d), batched over experts."""
    up = jnp.einsum("ecd,edf->ecf", xe, p["w_up"].astype(xe.dtype))
    if cfg.ffn_activation in GATED:
        gate = jnp.einsum("ecd,edf->ecf", xe, p["w_gate"].astype(xe.dtype))
        h = (jax.nn.silu(gate) if cfg.ffn_activation == "swiglu"
             else jax.nn.gelu(gate)) * up
    elif cfg.ffn_activation == "sq_relu":
        r = jax.nn.relu(up)
        h = r * r
    else:
        h = jax.nn.gelu(up)
    return jnp.einsum("ecf,efd->ecd", h, p["w_down"].astype(h.dtype))


def apply_moe(cfg, p, x, capacity_factor: float = 1.25, groups: int = 1,
              ep_sharder=None, group_sharder=None):
    """x: (B, S, d) -> (out, aux_metrics).

    Capacity-based dispatch with **per-group capacity**: tokens are split
    into ``groups`` contiguous groups (aligned with the batch/data
    sharding, like GShard's per-device groups), each with capacity
    C = ceil(T_g·k/E · cf). This keeps every dispatch tensor — the one-hot
    position cumsum, the (G, E·C+1, d) scatter buffer — leading-dim
    sharded; the (G,...) -> (E,...) transpose before the expert einsum is
    the canonical MoE all-to-all. ``ep_sharder`` re-constrains the expert
    batch (EP over the ``model`` axis when E divides it).
    """
    b, s, d = x.shape
    t = b * s
    e, k = cfg.num_experts, cfg.top_k
    g = groups if t % max(groups, 1) == 0 else 1
    tl = t // g
    gsh = group_sharder or (lambda a: a)
    xg = gsh(x.reshape(g, tl, d))                              # (G, Tl, d)
    xt = xg.reshape(t, d)

    logits = jnp.einsum("gtd,de->gte", xg, p["router"].astype(xg.dtype))
    logits32 = gsh(logits.astype(jnp.float32))
    probs = jax.nn.softmax(logits32, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)        # (G, Tl, k)

    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    cap = max(int(np.ceil(tl * k / e * capacity_factor)), 4)

    # flat work items per group: the manhattan collapse of routing
    i_items = tl * k
    ge = gsh(expert_idx.reshape(g, i_items))                   # (G, I)
    gg = gsh(gate_vals.reshape(g, i_items))
    local_t = jax.lax.broadcasted_iota(jnp.int32, (g, i_items), 1) // k

    onehot = gsh(jax.nn.one_hot(ge, e, dtype=jnp.int32))       # (G, I, E)
    pos_in_e = jnp.cumsum(onehot, axis=1) - onehot             # exclusive
    pos = jnp.take_along_axis(
        pos_in_e, ge[..., None], axis=2)[..., 0]               # (G, I)
    keep = pos < cap
    slot = jnp.where(keep, ge * cap + pos, e * cap)            # (G, I)

    # scatter tokens into (G, E*C+1, d); last row per group = drop bin
    items_in = jnp.take_along_axis(xg, local_t[..., None], axis=1)
    g_idx = jax.lax.broadcasted_iota(jnp.int32, (g, i_items), 0)
    buf = gsh(jnp.zeros((g, e * cap + 1, d), xt.dtype))
    buf = gsh(buf.at[g_idx, slot].add(items_in))

    # (G, E, C, d) -> (E, G*C, d): the MoE all-to-all
    xe = buf[:, :-1].reshape(g, e, cap, d).transpose(1, 0, 2, 3)
    xe = xe.reshape(e, g * cap, d)
    if ep_sharder is not None:
        xe = ep_sharder(xe)
    ye = _expert_ffn(cfg, p, xe)
    if ep_sharder is not None:
        ye = ep_sharder(ye)
    ye = ye.reshape(e, g, cap, d).transpose(1, 0, 2, 3)        # (G,E,C,d)
    ye = gsh(ye.reshape(g, e * cap, d))
    ye = jnp.concatenate([ye, jnp.zeros((g, 1, d), ye.dtype)], axis=1)

    # combine: gather back per group, weighted by gates
    out_items = jnp.take_along_axis(ye, slot[..., None], axis=1)
    out_items = out_items * gg[..., None].astype(ye.dtype)
    out = gsh(jnp.zeros((g, tl, d), ye.dtype))
    out = gsh(out.at[g_idx, local_t].add(out_items))
    out = out.reshape(t, d)

    if cfg.num_shared_experts:
        sp = {"w_up": p["shared_up"], "w_down": p["shared_down"]}
        if "shared_gate" in p:
            sp["w_gate"] = p["shared_gate"]
        from repro.models.ffn import apply_ffn
        out = out + apply_ffn(cfg, sp, xt[None]).reshape(t, d)

    # auxiliary losses + privatized load stats (paper pattern: per-shard
    # partials, one reduction)
    me = probs.mean(axis=(0, 1))                               # (E,)
    load = onehot.sum(axis=(0, 1))                             # (E,) int32
    ce = load.astype(jnp.float32) / max(t * k, 1)
    aux_loss = e * jnp.sum(me * ce)
    z_loss = jnp.mean(jax.nn.logsumexp(logits32, axis=-1) ** 2)
    dropped = jnp.sum(1 - keep.astype(jnp.int32))
    metrics = {"moe_aux_loss": aux_loss, "moe_z_loss": z_loss,
               "expert_load": load, "dropped_tokens": dropped}
    return out.reshape(b, s, d), metrics
