"""Unified model assembly for all 10 assigned architectures.

Layers are grouped into *supergroups* of identical signature and executed
with ``lax.scan`` over stacked parameters (fast compiles at 64 layers);
heterogeneous patterns (xLSTM 1:7, Griffin 2:1) scan over their repeating
period. The decode path is fully unrolled instead — decode steps are small
and unrolling keeps XLA's cost analysis exact (DESIGN.md §4).

``num_layer_override`` exists solely for the dry-run's cost accounting:
lowering the same program with 0 layers isolates the non-loop "outer" cost
so the roofline can reconstruct ``outer + L × body``.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import attention as attn_mod
from repro.models import ffn as ffn_mod
from repro.models import moe as moe_mod
from repro.models import recurrent as rec_mod
from repro.models.common import (
    ParamDef, abstract_params, apply_norm, count_schema_params, init_params,
    norm_schema, pad_vocab, schema_axes)

Sig = tuple  # (mixer_kind, ffn_kind)


# ---------------------------------------------------------- layer grouping

def layer_sigs(cfg: ArchConfig, num_layers: int | None = None) -> list[Sig]:
    n = cfg.num_layers if num_layers is None else num_layers
    kinds = cfg.pattern_for(n)
    sigs = []
    for i, kind in enumerate(kinds):
        if cfg.d_ff == 0:
            ffn_kind = "none"
        elif cfg.is_moe:
            ffn_kind = ("dense_first" if (cfg.first_layer_dense and i == 0)
                        else "moe")
        else:
            ffn_kind = "dense"
        sigs.append((kind, ffn_kind))
    return sigs


def layer_groups(cfg: ArchConfig,
                 num_layers: int | None = None) -> list[tuple[list, int]]:
    """[(sig_chunk, repeats)] — scan over ``repeats`` stacked copies."""
    sigs = layer_sigs(cfg, num_layers)
    groups, i = [], 0
    if sigs and cfg.first_layer_dense:
        groups.append(([sigs[0]], 1))
        i = 1
    k = len(cfg.block_pattern)
    rem = len(sigs) - i
    reps = rem // k
    if reps > 0 and all(sigs[i + j * k: i + (j + 1) * k] == sigs[i:i + k]
                        for j in range(reps)):
        groups.append((sigs[i:i + k], reps))
        i += reps * k
    while i < len(sigs):                        # run-length the remainder
        j = i
        while j < len(sigs) and sigs[j] == sigs[i]:
            j += 1
        groups.append(([sigs[i]], j - i))
        i = j
    return groups


# ---------------------------------------------------------- schemas

def _mixer_schema(cfg, kind, cross=False):
    if kind in ("attn", "local_attn"):
        return attn_mod.attn_schema(cfg)
    if kind == "mlstm":
        return rec_mod.mlstm_schema(cfg)
    if kind == "slstm":
        return rec_mod.slstm_schema(cfg)
    if kind == "rglru":
        return rec_mod.rglru_schema(cfg)
    raise ValueError(kind)


def block_schema(cfg, sig: Sig, cross: bool = False) -> dict:
    kind, ffn_kind = sig
    s = {"norm1": norm_schema(cfg), "mixer": _mixer_schema(cfg, kind)}
    if cross:
        s["norm_cross"] = norm_schema(cfg)
        s["cross_attn"] = attn_mod.attn_schema(cfg, cross=True)
    if ffn_kind != "none":
        s["norm2"] = norm_schema(cfg)
        if ffn_kind == "moe":
            s["moe"] = moe_mod.moe_schema(cfg)
        elif ffn_kind == "dense_first":
            s["ffn"] = ffn_mod.ffn_schema(cfg, d_ff=cfg.dense_d_ff)
        else:
            s["ffn"] = ffn_mod.ffn_schema(cfg)
    return s


def _stack_defs(schema, n: int):
    def walk(node):
        if isinstance(node, ParamDef):
            return ParamDef((n,) + node.shape, ("layers",) + node.axes,
                            node.init, node.dtype)
        return {k: walk(v) for k, v in node.items()}
    return walk(schema)


def param_schema(cfg: ArchConfig, num_layers: int | None = None) -> dict:
    vp = pad_vocab(cfg.vocab_size)
    s = {
        "embed": ParamDef((vp, cfg.d_model), ("vocab", "embed"), "embed"),
        "out_norm": norm_schema(cfg),
    }
    if not cfg.tie_embeddings:
        s["lm_head"] = ParamDef((cfg.d_model, vp), ("embed", "vocab"))
    for gi, (chunk, reps) in enumerate(layer_groups(cfg, num_layers)):
        g = {f"b{bi}": block_schema(cfg, sig, cross=cfg.is_encdec)
             for bi, sig in enumerate(chunk)}
        s[f"g{gi}"] = _stack_defs(g, reps) if reps > 1 else g
    if cfg.is_encdec:
        enc_sigs = layer_sigs(cfg, cfg.encoder_layers)
        enc = {"out_norm": norm_schema(cfg)}
        chunk = [enc_sigs[0]]
        enc_g = {"b0": block_schema(cfg, enc_sigs[0], cross=False)}
        enc["g0"] = _stack_defs(enc_g, cfg.encoder_layers)
        s["encoder"] = enc
    return s


def count_params(cfg: ArchConfig, active_only: bool = False) -> int:
    schema = param_schema(cfg)
    total = 0
    from repro.models.common import tree_paths
    for path, d in tree_paths(schema):
        size = int(np.prod(d.shape))
        if active_only and "moe" in path and path[-1] in (
                "w_up", "w_down", "w_gate"):
            size = size * cfg.top_k // max(cfg.num_experts, 1)
        if active_only and path[-1] in ("embed", "lm_head"):
            continue
        total += size
    return total


# ---------------------------------------------------------- block forward

def apply_block(cfg, sig: Sig, p, x, ctx):
    """One block, full-sequence mode. Returns (x, aux)."""
    kind, ffn_kind = sig
    metrics = {}
    cache = {}
    h = apply_norm(cfg, p["norm1"], x)
    if kind in ("attn", "local_attn"):
        window = cfg.window if kind == "local_attn" else 0
        y = attn_mod.attention(
            cfg, p["mixer"], h, positions=ctx["positions"],
            layer_window=window, causal=ctx["causal"],
            q_chunk=ctx["q_chunk"])
        if ctx["want_cache"]:
            # recompute k/v for the cache (cheap relative to attention)
            _, k, v = attn_mod._project_qkv(cfg, p["mixer"], h, h)
            k = attn_mod._rope(cfg, k, ctx["positions"])
            cache = {"k": k, "v": v}
    elif kind == "mlstm":
        y, state = rec_mod.mlstm_block(cfg, p["mixer"], h,
                                       chunk=ctx["rec_chunk"],
                                       unroll=ctx.get("rec_unroll", False))
        cache = {"state": state} if ctx["want_cache"] else {}
    elif kind == "slstm":
        y, state = rec_mod.slstm_block(cfg, p["mixer"], h)
        cache = {"state": state} if ctx["want_cache"] else {}
    elif kind == "rglru":
        y, state = rec_mod.rglru_block(cfg, p["mixer"], h)
        cache = {"state": state} if ctx["want_cache"] else {}
    else:
        raise ValueError(kind)
    x = x + y
    if "cross_attn" in p and ctx.get("enc_out") is not None:
        hc = apply_norm(cfg, p["norm_cross"], x)
        yc = attn_mod.attention(
            cfg, p["cross_attn"], hc, positions=ctx["positions"],
            causal=False, xkv=ctx["enc_out"], q_chunk=ctx["q_chunk"],
            kv_positions=ctx.get("enc_positions"))
        x = x + yc
    if ffn_kind != "none":
        h2 = apply_norm(cfg, p["norm2"], x)
        if ffn_kind == "moe":
            if ctx.get("moe_fn") is not None:
                y2, moe_metrics = ctx["moe_fn"](p["moe"], h2)
            else:
                y2, moe_metrics = moe_mod.apply_moe(
                    cfg, p["moe"], h2, groups=ctx.get("moe_groups", 1),
                    ep_sharder=ctx.get("ep_sharder"),
                    group_sharder=ctx.get("moe_group_sharder"))
            metrics.update(moe_metrics)
        else:
            y2 = ffn_mod.apply_ffn(cfg, p["ffn"], h2)
        x = x + y2
    return x, {"metrics": metrics, "cache": cache}


def _zero_metrics(cfg):
    z = {}
    if cfg.is_moe:
        z = {"moe_aux_loss": jnp.zeros((), jnp.float32),
             "moe_z_loss": jnp.zeros((), jnp.float32),
             "expert_load": jnp.zeros((cfg.num_experts,), jnp.int32),
             "dropped_tokens": jnp.zeros((), jnp.int32)}
    return z


def _merge_metrics(acc, new):
    for k, v in new.items():
        acc[k] = acc.get(k, 0) + v
    return acc


# ---------------------------------------------------------- full forward

def embed_tokens(cfg, params, batch):
    emb = params["embed"]
    x = emb[batch["tokens"]].astype(jnp.bfloat16)
    if cfg.modality == "vlm" and "vision_embeds" in batch:
        x = jnp.where(batch["vision_mask"][..., None],
                      batch["vision_embeds"].astype(x.dtype), x)
    return x


def _positions_for(cfg, batch, b, s):
    if cfg.rope_variant == "mrope":
        if "positions3" in batch:
            return batch["positions3"]
        pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
        return jnp.broadcast_to(pos[None], (3, b, s))
    return jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))


def run_stack(cfg, params, x, ctx, groups, prefix: str):
    """Apply all layer groups; scan over stacked repeats.

    ``ctx["sharder"]`` re-constrains the residual stream at block
    boundaries (sequence-parallel layout); ``ctx["remat"]`` wraps the scan
    body in ``jax.checkpoint`` so the backward pass recomputes each layer
    from its carried input instead of storing activations.
    """
    metrics = _zero_metrics(cfg)
    caches = []
    sharder = ctx.get("sharder") or (lambda t: t)
    for gi, (chunk, reps) in enumerate(groups):
        gp = params[f"{prefix}g{gi}"]

        def body(xc, pl):
            m = _zero_metrics(cfg)
            entry = []
            for bi, sig in enumerate(chunk):
                xc, aux = apply_block(cfg, sig, pl[f"b{bi}"], xc, ctx)
                xc = sharder(xc)
                m = _merge_metrics(m, aux["metrics"])
                entry.append(aux["cache"])
            return xc, (m, entry)

        if ctx.get("remat"):
            body = jax.checkpoint(body, prevent_cse=False)
        if reps == 1:
            x, (m, entry) = body(x, gp)
            metrics = _merge_metrics(metrics, m)
            caches.append(entry)
        elif not ctx.get("scan_layers", True):
            # unrolled (exact XLA cost accounting; dry-run cost variants)
            entries = []
            for r in range(reps):
                x, (m, entry) = body(x, jax.tree.map(lambda a: a[r], gp))
                metrics = _merge_metrics(metrics, m)
                entries.append(entry)
            caches.append(entries)
        else:
            x, (ms, entries) = jax.lax.scan(body, x, gp)
            metrics = _merge_metrics(
                metrics, jax.tree.map(lambda a: a.sum(0), ms))
            caches.append(entries)   # leaves stacked over reps
    return x, metrics, caches


def forward(cfg: ArchConfig, params, batch, *, q_chunk: int = 512,
            rec_chunk: int = 256, want_cache: bool = False,
            num_layers: int | None = None, sharder=None,
            remat: bool = False, scan_layers: bool = True,
            rec_unroll: bool = False, moe_groups: int = 1,
            ep_sharder=None, moe_group_sharder=None, moe_fn=None):
    """Full-sequence forward -> (final hidden states, metrics, caches)."""
    x = embed_tokens(cfg, params, batch)
    if sharder is not None:
        x = sharder(x)
    b, s, _ = x.shape
    ctx = dict(positions=_positions_for(cfg, batch, b, s), causal=True,
               q_chunk=q_chunk, rec_chunk=rec_chunk, want_cache=want_cache,
               enc_out=None, sharder=sharder, remat=remat,
               scan_layers=scan_layers, rec_unroll=rec_unroll,
               moe_groups=moe_groups, ep_sharder=ep_sharder,
               moe_group_sharder=moe_group_sharder, moe_fn=moe_fn)
    if cfg.is_encdec:
        src = batch["src_embeds"].astype(jnp.bfloat16)
        bs, ss, _ = src.shape
        enc_ctx = dict(positions=jnp.broadcast_to(
            jnp.arange(ss, dtype=jnp.int32), (bs, ss)),
            causal=False, q_chunk=q_chunk, rec_chunk=rec_chunk,
            want_cache=False, enc_out=None, sharder=sharder, remat=remat,
            scan_layers=scan_layers, rec_unroll=rec_unroll)
        enc_groups = [([layer_sigs(cfg, 1)[0]], cfg.encoder_layers)]
        enc_x, _, _ = run_stack(cfg, params["encoder"], src, enc_ctx,
                                enc_groups, prefix="")
        enc_x = apply_norm(cfg, params["encoder"]["out_norm"], enc_x)
        ctx["enc_out"] = enc_x
        ctx["enc_positions"] = enc_ctx["positions"]
    groups = layer_groups(cfg, num_layers)
    x, metrics, caches = run_stack(cfg, params, x, ctx, groups, prefix="")
    x = apply_norm(cfg, params["out_norm"], x)
    return x, metrics, caches


def logits_from_hidden(cfg, params, x):
    head = (params["embed"].T if cfg.tie_embeddings
            else params["lm_head"])
    return jnp.einsum("bsd,dv->bsv", x, head.astype(x.dtype))


def loss_fn(cfg: ArchConfig, params, batch, *, q_chunk: int = 512,
            rec_chunk: int = 256, num_layers: int | None = None,
            sharder=None, logits_sharder=None, remat: bool = False,
            scan_layers: bool = True, rec_unroll: bool = False,
            moe_groups: int = 1, ep_sharder=None,
            moe_group_sharder=None, moe_fn=None):
    """Cross-entropy + MoE aux losses. labels < 0 are masked.

    The logits tensor stays fully sharded (batch over ``data``, vocab over
    ``model``); the label pick uses a one-hot masked reduction instead of
    ``take_along_axis`` so GSPMD never all-gathers the vocab dim.
    """
    x, metrics, _ = forward(cfg, params, batch, q_chunk=q_chunk,
                            rec_chunk=rec_chunk, num_layers=num_layers,
                            sharder=sharder, remat=remat,
                            scan_layers=scan_layers, rec_unroll=rec_unroll,
                            moe_groups=moe_groups, ep_sharder=ep_sharder,
                            moe_group_sharder=moe_group_sharder,
                            moe_fn=moe_fn)
    b, s, d = x.shape
    labels = batch["labels"]
    vp = pad_vocab(cfg.vocab_size)
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = jnp.einsum("bsd,dv->bsv", x, head.astype(x.dtype))
    if logits_sharder is not None:
        logits = logits_sharder(logits)
    vocab_ids = jax.lax.broadcasted_iota(jnp.int32, (1, 1, vp), 2)
    logits = jnp.where(vocab_ids >= cfg.vocab_size, attn_mod.NEG_INF,
                       logits).astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)                      # (b, s)
    pick = (vocab_ids == jnp.maximum(labels, 0)[..., None])
    ll = jnp.sum(jnp.where(pick, logits, 0.0), axis=-1)          # (b, s)
    msk = (labels >= 0).astype(jnp.float32)
    tot_nll = jnp.sum((lse - ll) * msk)
    tot_cnt = jnp.sum(msk)
    loss = tot_nll / jnp.maximum(tot_cnt, 1.0)
    metrics = dict(metrics)
    if cfg.is_moe:
        loss = loss + cfg.router_aux_coef * metrics["moe_aux_loss"] \
            + 1e-3 * metrics["moe_z_loss"]
    metrics["nll"] = tot_nll / jnp.maximum(tot_cnt, 1.0)
    return loss, metrics


def serve_prefill(cfg: ArchConfig, params, batch, *, q_chunk: int = 512,
                  rec_chunk: int = 256, num_layers: int | None = None,
                  sharder=None, scan_layers: bool = True,
                  rec_unroll: bool = False, moe_groups: int = 1,
                  ep_sharder=None, moe_group_sharder=None, moe_fn=None):
    """Prefill: full forward returning last-position logits + layer caches."""
    x, _, caches = forward(cfg, params, batch, q_chunk=q_chunk,
                           rec_chunk=rec_chunk, want_cache=True,
                           num_layers=num_layers, sharder=sharder,
                           scan_layers=scan_layers, rec_unroll=rec_unroll,
                           moe_groups=moe_groups, ep_sharder=ep_sharder,
                           moe_group_sharder=moe_group_sharder,
                           moe_fn=moe_fn)
    logits = logits_from_hidden(cfg, params, x[:, -1:])
    vp = pad_vocab(cfg.vocab_size)
    neg = jnp.asarray(np.arange(vp) >= cfg.vocab_size)
    logits = jnp.where(neg[None, None, :], attn_mod.NEG_INF, logits)
    return logits, caches


# ---------------------------------------------------------- decode path

def init_cache(cfg: ArchConfig, batch: int, seq_len: int,
               src_len: int = 0, dtype=jnp.bfloat16,
               kv_quant: bool = False) -> dict:
    """Decode cache pytree (unrolled per layer)."""
    sigs = layer_sigs(cfg)
    layers = []
    for kind, _ in sigs:
        if kind in ("attn", "local_attn"):
            window = cfg.window if kind == "local_attn" else 0
            entry = attn_mod.init_kv_cache(cfg, batch, seq_len, window,
                                           dtype, kv_quant=kv_quant)
            if cfg.is_encdec:
                entry["cross_k"] = jnp.zeros(
                    (batch, src_len, cfg.num_kv_heads, cfg.head_dim), dtype)
                entry["cross_v"] = jnp.zeros_like(entry["cross_k"])
        elif kind == "mlstm":
            c, n, m = rec_mod.mlstm_init_state(cfg, batch)
            entry = {"c": c, "n": n, "m": m}
        elif kind == "slstm":
            c, n, h, m = rec_mod.slstm_init_state(cfg, batch)
            entry = {"c": c, "n": n, "h": h, "m": m}
        elif kind == "rglru":
            buf, h = rec_mod.rglru_init_state(cfg, batch)
            entry = {"conv": buf, "h": h}
        layers.append(entry)
    return {"pos": jnp.zeros((), jnp.int32), "layers": layers}


def _group_layer_params(cfg, params, num_layers: int | None = None):
    """Flatten grouped/stacked params back to a per-layer list."""
    out = []
    for gi, (chunk, reps) in enumerate(layer_groups(cfg, num_layers)):
        gp = params[f"g{gi}"]
        for r in range(reps):
            for bi, _ in enumerate(chunk):
                bp = gp[f"b{bi}"]
                out.append(jax.tree.map(lambda a: a[r], bp)
                           if reps > 1 else bp)
    return out


def decode_step(cfg: ArchConfig, params, token, cache,
                num_layers: int | None = None):
    """One-token decode. token: (B, 1) int32. Returns (logits, cache)."""
    pos = cache["pos"]
    b = token.shape[0]
    x = params["embed"][token].astype(jnp.bfloat16)
    sigs = layer_sigs(cfg, num_layers)
    layer_params = _group_layer_params(cfg, params, num_layers)
    new_layers = []
    for (kind, ffn_kind), p, entry in zip(sigs, layer_params,
                                          cache["layers"]):
        h = apply_norm(cfg, p["norm1"], x)
        if kind in ("attn", "local_attn"):
            window = cfg.window if kind == "local_attn" else 0
            y, new_entry = attn_mod.decode_attention(
                cfg, p["mixer"], h, entry, pos, layer_window=window)
            if cfg.is_encdec:
                new_entry = dict(new_entry)
                new_entry["cross_k"] = entry["cross_k"]
                new_entry["cross_v"] = entry["cross_v"]
        elif kind == "mlstm":
            xi = jnp.einsum("bsd,de->bse", h,
                            p["mixer"]["w_in"].astype(h.dtype))
            gate = jnp.einsum("bsd,de->bse", h,
                              p["mixer"]["w_gate"].astype(h.dtype))
            yq, st = rec_mod.mlstm_decode_step(
                p["mixer"], xi, (entry["c"], entry["n"], entry["m"]),
                cfg.num_heads)
            di = yq.shape[-1]
            hd = di // cfg.num_heads
            yh = yq.reshape(b, 1, cfg.num_heads, hd).astype(jnp.float32)
            yh = yh * jax.lax.rsqrt(
                jnp.mean(yh * yh, axis=-1, keepdims=True) + 1e-6)
            yq = yh.reshape(b, 1, di) * p["mixer"]["ln_scale"].astype(
                jnp.float32)
            yq = yq.astype(h.dtype) * jax.nn.silu(gate)
            y = jnp.einsum("bse,ed->bsd", yq,
                           p["mixer"]["w_out"].astype(yq.dtype))
            new_entry = {"c": st[0], "n": st[1], "m": st[2]}
        elif kind == "slstm":
            y, st = rec_mod.slstm_block(
                cfg, p["mixer"], h,
                state=(entry["c"], entry["n"], entry["h"], entry["m"]))
            new_entry = {"c": st[0], "n": st[1], "h": st[2], "m": st[3]}
        elif kind == "rglru":
            y, st = rec_mod.rglru_block(
                cfg, p["mixer"], h, state=(entry["conv"], entry["h"]),
                decode=True)
            new_entry = {"conv": st[0], "h": st[1]}
        x = x + y
        if "cross_attn" in p:
            hc = apply_norm(cfg, p["norm_cross"], x)
            yc, _ = attn_mod.decode_attention(
                cfg, p["cross_attn"], hc, entry, pos,
                cross_kv={"k": entry["cross_k"], "v": entry["cross_v"]})
            x = x + yc
        if ffn_kind != "none":
            h2 = apply_norm(cfg, p["norm2"], x)
            if ffn_kind == "moe":
                y2, _ = moe_mod.apply_moe(cfg, p["moe"], h2)
            else:
                y2 = ffn_mod.apply_ffn(cfg, p["ffn"], h2)
            x = x + y2
        new_layers.append(new_entry)
    x = apply_norm(cfg, params["out_norm"], x)
    logits = logits_from_hidden(cfg, params, x)
    vp = pad_vocab(cfg.vocab_size)
    neg = jnp.asarray(np.arange(vp) >= cfg.vocab_size)
    logits = jnp.where(neg[None, None, :], attn_mod.NEG_INF, logits)
    return logits, {"pos": pos + 1, "layers": new_layers}


# ---------------------------------------------------------- entry points

def make_params(cfg: ArchConfig, seed: int = 0,
                num_layers: int | None = None):
    return init_params(param_schema(cfg, num_layers),
                       jax.random.PRNGKey(seed))


def make_abstract_params(cfg: ArchConfig, num_layers: int | None = None):
    return abstract_params(param_schema(cfg, num_layers))


def params_axes(cfg: ArchConfig, num_layers: int | None = None):
    return schema_axes(param_schema(cfg, num_layers))
