"""Explicit shard_map MoE dispatch — the beyond-paper optimization for the
MoE cells (§Perf), and the purest expression of the paper's patterns:

* each device's tokens form exactly one dispatch group (the manhattan-
  collapsed routing loop, privatized per device — zero cross-device
  traffic for the route/position/capacity logic);
* expert exchange is ONE ``all_to_all`` over the ``model`` axis each way
  (vs. the GSPMD baseline's inferred all-gather/permute storm);
* router/load statistics are per-device partials merged with a single
  ``psum`` — the paper's 64 privatized census vectors, verbatim;
* FSDP weight gathers are explicit ``all_gather`` (transpose:
  reduce-scatter), so the collective schedule is exactly what you read.

Used by the hillclimb variants via ``build_train_step(..., moe_impl=
"shard_map")``; numerics match the grouped GSPMD path (same per-group
capacity semantics), asserted in tests.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map

from repro.models.ffn import GATED


def _gather_weight(w, spec: P, skip: tuple = ()):
    """Explicit FSDP: all-gather a weight along every sharded dim.

    Axes in ``skip`` stay sharded (for EP, the expert dim's ``model``
    sharding IS the expert assignment — each shard keeps its experts)."""
    for dim, part in enumerate(spec):
        if part is None:
            continue
        for ax in (part if isinstance(part, tuple) else (part,)):
            if ax in skip:
                continue
            w = jax.lax.all_gather(w, ax, axis=dim, tiled=True)
    return w


def _local_dispatch(xt, logits32, e: int, k: int, cap: int):
    """Per-device routing + scatter (no collectives at all)."""
    tl, d = xt.shape
    probs = jax.nn.softmax(logits32, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)           # (Tl, k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)
    ge = expert_idx.reshape(tl * k)
    gg = gate_vals.reshape(tl * k)
    local_t = jax.lax.broadcasted_iota(jnp.int32, (tl * k,), 0) // k
    onehot = jax.nn.one_hot(ge, e, dtype=jnp.int32)
    pos = jnp.take_along_axis(
        jnp.cumsum(onehot, axis=0) - onehot, ge[:, None], 1)[:, 0]
    keep = pos < cap
    slot = jnp.where(keep, ge * cap + pos, e * cap)
    buf = jnp.zeros((e * cap + 1, d), xt.dtype).at[slot].add(xt[local_t])
    stats = (probs, onehot, keep)
    return buf[:-1].reshape(e, cap, d), (slot, gg, local_t), stats


def _expert_ffn_local(cfg, ws, xe):
    up = jnp.einsum("ecd,edf->ecf", xe, ws["w_up"].astype(xe.dtype))
    if cfg.ffn_activation in GATED:
        gate = jnp.einsum("ecd,edf->ecf", xe,
                          ws["w_gate"].astype(xe.dtype))
        h = (jax.nn.silu(gate) if cfg.ffn_activation == "swiglu"
             else jax.nn.gelu(gate)) * up
    elif cfg.ffn_activation == "sq_relu":
        r = jax.nn.relu(up)
        h = r * r
    else:
        h = jax.nn.gelu(up)
    return jnp.einsum("ecf,efd->ecd", h, ws["w_down"].astype(h.dtype))


def make_sharded_moe(cfg, mesh: Mesh, batch_axes_, expert_specs: dict,
                     capacity_factor: float = 1.25):
    """Build apply(p, x) -> (y, metrics) running the dispatch in
    shard_map. ``expert_specs`` are the actual param PartitionSpecs
    (from the sharding rules) so in_specs match storage exactly."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    nm = sizes.get("model", 1)
    e, k = cfg.num_experts, cfg.top_k
    ep = e % nm == 0 and nm > 1
    all_axes = tuple(mesh.axis_names)
    b_axes = (batch_axes_ if isinstance(batch_axes_, tuple)
              else ((batch_axes_,) if batch_axes_ else ()))

    def inner(p, x):
        b_l, s_l, d = x.shape
        tl = b_l * s_l
        xt = x.reshape(tl, d)
        wr = p["router"].astype(xt.dtype)                 # replicated
        logits32 = jnp.einsum("td,de->te", xt, wr).astype(jnp.float32)
        cap = max(int(np.ceil(tl * k / e * capacity_factor)), 4)
        xe, (slot, gg, local_t), (probs, onehot, keep) = _local_dispatch(
            xt, logits32, e, k, cap)

        # EP: weights stay model-sharded on the expert dim (that sharding
        # IS the expert->shard assignment); FSDP dims are gathered.
        skip = ("model",) if ep else ()
        ws = {key: _gather_weight(p[key], expert_specs[key], skip=skip)
              for key in ("w_up", "w_down", "w_gate") if key in p}
        if ep:
            # ONE all-to-all each way over `model`: (E, C, d) -> (E/nm,
            # nm*C, d) gathers each owner's expert buffers from its row
            xe = jax.lax.all_to_all(xe, "model", split_axis=0,
                                    concat_axis=1, tiled=True)
            ye = _expert_ffn_local(cfg, ws, xe)
            ye = jax.lax.all_to_all(ye, "model", split_axis=1,
                                    concat_axis=0, tiled=True)
        else:
            ye = _expert_ffn_local(cfg, ws, xe)
        ye = ye.reshape(e * cap, d)
        ye = jnp.concatenate([ye, jnp.zeros((1, d), ye.dtype)])
        items = ye[slot] * gg[:, None].astype(ye.dtype)
        out = jnp.zeros((tl, d), ye.dtype).at[local_t].add(items)

        if cfg.num_shared_experts:
            su = _gather_weight(p["shared_up"],
                                expert_specs["shared_up"])
            sd = _gather_weight(p["shared_down"],
                                expert_specs["shared_down"])
            h = jnp.einsum("td,df->tf", xt, su.astype(xt.dtype))
            if "shared_gate" in p:
                sg = _gather_weight(p["shared_gate"],
                                    expert_specs["shared_gate"])
                h = jax.nn.silu(jnp.einsum(
                    "td,df->tf", xt, sg.astype(xt.dtype))) * h
            else:
                h = jax.nn.gelu(h)
            out = out + jnp.einsum("tf,fd->td", h, sd.astype(h.dtype))

        # privatized stats -> ONE reduction (the paper's census pattern)
        me = jax.lax.pmean(probs.mean(axis=0), all_axes)
        load = jax.lax.psum(onehot.sum(axis=0), all_axes)
        tk = jax.lax.psum(jnp.asarray(tl * k, jnp.float32), all_axes)
        ce = load.astype(jnp.float32) / tk
        aux_loss = e * jnp.sum(me * ce)
        z_loss = jax.lax.pmean(
            jnp.mean(jax.nn.logsumexp(logits32, axis=-1) ** 2), all_axes)
        dropped = jax.lax.psum(jnp.sum(1 - keep.astype(jnp.int32)),
                               all_axes)
        metrics = {"moe_aux_loss": aux_loss, "moe_z_loss": z_loss,
                   "expert_load": load, "dropped_tokens": dropped}
        return out.reshape(b_l, s_l, d), metrics

    x_spec = P(b_axes if len(b_axes) > 1 else
               (b_axes[0] if b_axes else None), "model", None)
    p_specs = dict(expert_specs)
    p_specs["router"] = P(None, None)
    in_specs = ({k: p_specs[k] for k in p_specs}, x_spec)
    out_specs = (x_spec, P())

    fn = shard_map(inner, mesh=mesh, in_specs=in_specs,
                   out_specs=out_specs, check_vma=False)

    def apply(p, x):
        pp = {k: p[k] for k in p_specs if k in p}
        return fn(pp, x)

    return apply
