"""Dense FFN variants: SwiGLU / GeGLU / GELU / squared-ReLU."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import ParamDef

GATED = {"swiglu", "geglu"}


def ffn_schema(cfg, d_ff: int | None = None) -> dict:
    d = cfg.d_model
    f = cfg.d_ff if d_ff is None else d_ff
    s = {
        "w_up": ParamDef((d, f), ("embed", "ffn")),
        "w_down": ParamDef((f, d), ("ffn", "embed")),
    }
    if cfg.ffn_activation in GATED:
        s["w_gate"] = ParamDef((d, f), ("embed", "ffn"))
    return s


def apply_ffn(cfg, p, x):
    up = jnp.einsum("bsd,df->bsf", x, p["w_up"].astype(x.dtype))
    act = cfg.ffn_activation
    if act == "swiglu":
        gate = jnp.einsum("bsd,df->bsf", x, p["w_gate"].astype(x.dtype))
        h = jax.nn.silu(gate) * up
    elif act == "geglu":
        gate = jnp.einsum("bsd,df->bsf", x, p["w_gate"].astype(x.dtype))
        h = jax.nn.gelu(gate) * up
    elif act == "gelu":
        h = jax.nn.gelu(up)
    elif act == "sq_relu":
        r = jax.nn.relu(up)
        h = r * r
    else:
        raise ValueError(f"unknown activation {act}")
    return jnp.einsum("bsf,fd->bsd", h, p["w_down"].astype(h.dtype))
