"""Train-step construction: loss + grad + optimizer, sharding-aware.

``build_train_step`` returns the pure step function plus the sharding
trees for params / optimizer state / batch, ready for ``jax.jit`` —
used identically by the real training loop and the dry-run lowering.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeSpec
from repro.models.model import (
    loss_fn, make_abstract_params, params_axes)
from repro.parallel.sharding import (
    batch_axes, make_activation_sharder, moe_dispatch_plan,
    tree_shardings)
from repro.train.optimizer import OptConfig, apply_update, init_state


def build_train_step(cfg: ArchConfig, mesh: Mesh, shape: ShapeSpec,
                     opt_cfg: OptConfig | None = None, *,
                     q_chunk: int = 512, rec_chunk: int = 256,
                     remat: bool = True,
                     grad_accum: int = 1, seq_shard: bool = True,
                     num_layers: int | None = None, rules=None,
                     scan_layers: bool = True, rec_unroll: bool = False,
                     moe_impl: str = "gspmd",
                     moe_capacity_factor: float = 1.25):
    """Returns (train_step, shardings dict, abstract state dict)."""
    opt_cfg = opt_cfg or OptConfig()
    sharder = make_activation_sharder(
        mesh, shape.global_batch, shape.seq_len, seq_shard=seq_shard)
    b_ax = batch_axes(mesh, shape.global_batch)
    logits_sh = NamedSharding(mesh, P(b_ax, None, "model"))
    logits_sharder = lambda t: jax.lax.with_sharding_constraint(t, logits_sh)
    moe_groups, moe_gsh, ep_sharder = moe_dispatch_plan(
        cfg, mesh, shape.global_batch, shape.seq_len, seq_shard)
    moe_fn = None
    if cfg.is_moe and moe_impl == "shard_map":
        from repro.models.moe import moe_schema
        from repro.models.moe_shard import make_sharded_moe
        from repro.parallel.sharding import spec_for_axes
        schema = moe_schema(cfg)
        specs = {k: spec_for_axes(d.axes, d.shape, mesh)
                 for k, d in schema.items()}
        moe_fn = make_sharded_moe(cfg, mesh, b_ax, specs,
                                  capacity_factor=moe_capacity_factor)

    def compute_loss(params, batch):
        return loss_fn(cfg, params, batch, q_chunk=q_chunk,
                       rec_chunk=rec_chunk,
                       num_layers=num_layers, sharder=sharder,
                       logits_sharder=logits_sharder, remat=remat,
                       scan_layers=scan_layers, rec_unroll=rec_unroll,
                       moe_groups=moe_groups, ep_sharder=ep_sharder,
                       moe_group_sharder=moe_gsh, moe_fn=moe_fn)

    def train_step(params, opt_state, batch):
        if grad_accum > 1:
            def micro(carry, mb):
                gsum, lsum = carry
                (loss, _), grads = jax.value_and_grad(
                    compute_loss, has_aux=True)(params, mb)
                gsum = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32), gsum, grads)
                return (gsum, lsum + loss), None
            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            mbs = jax.tree.map(
                lambda a: a.reshape(grad_accum, a.shape[0] // grad_accum,
                                    *a.shape[1:]), batch)
            (grads, loss), _ = jax.lax.scan(micro, (zeros, 0.0), mbs)
            grads = jax.tree.map(lambda g: g / grad_accum, grads)
            loss = loss / grad_accum
            metrics = {}
        else:
            (loss, metrics), grads = jax.value_and_grad(
                compute_loss, has_aux=True)(params, batch)
        new_params, new_opt, opt_metrics = apply_update(
            opt_cfg, params, grads, opt_state)
        out_metrics = {"loss": loss, **opt_metrics}
        for k in ("nll", "moe_aux_loss", "dropped_tokens"):
            if k in metrics:
                out_metrics[k] = metrics[k]
        return new_params, new_opt, out_metrics

    abs_params = make_abstract_params(cfg, num_layers)
    axes = params_axes(cfg, num_layers)
    p_shard = tree_shardings(axes, abs_params, mesh, rules)
    abs_opt = jax.eval_shape(init_state, abs_params)
    # moments share the param specs (f32); step is replicated
    o_shard = {"mu": p_shard, "nu": p_shard,
               "step": NamedSharding(mesh, P())}
    shardings = {"params": p_shard, "opt": o_shard}
    return train_step, shardings, {"params": abs_params, "opt": abs_opt}
