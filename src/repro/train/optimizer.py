"""Optimizers built in-repo (no optax): AdamW + Lion, global-norm clipping,
cosine schedule with warmup.

Optimizer moments inherit the parameter PartitionSpecs, so under the
default FSDP(``data``) × TP(``model``) layout the state is fully sharded —
ZeRO-style — with no extra code.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    kind: str = "adamw"            # adamw | lion


def schedule(cfg: OptConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps) /
                 jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0., 1.)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
    floor = cfg.min_lr_ratio
    return cfg.lr * warm * (floor + (1 - floor) * cos)


def init_state(params) -> dict:
    zeros = lambda: jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return {"mu": zeros(), "nu": zeros(),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jax.Array:
    sq = jax.tree.map(lambda g: jnp.sum(g.astype(jnp.float32) ** 2), tree)
    return jnp.sqrt(sum(jax.tree.leaves(sq)))


def apply_update(cfg: OptConfig, params, grads, state):
    """One optimizer step -> (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)
    step = state["step"] + 1
    lr = schedule(cfg, step)

    if cfg.kind == "lion":
        flat_p, tdef = jax.tree.flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_mu = tdef.flatten_up_to(state["mu"])
        new_p, new_mu = [], []
        for p, g, mu in zip(flat_p, flat_g, flat_mu):
            d = jnp.sign(cfg.b1 * mu + (1 - cfg.b1) * g)
            new_p.append((p.astype(jnp.float32) - lr *
                          (d + cfg.weight_decay * p.astype(jnp.float32))
                          ).astype(p.dtype))
            new_mu.append(cfg.b2 * mu + (1 - cfg.b2) * g)
        return (tdef.unflatten(new_p),
                {"mu": tdef.unflatten(new_mu), "nu": state["nu"],
                 "step": step},
                {"grad_norm": gnorm, "lr": lr})

    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        mu = b1 * mu + (1 - b1) * g
        nu = b2 * nu + (1 - b2) * g * g
        upd_ = (mu / bc1) / (jnp.sqrt(nu / bc2) + cfg.eps)
        newp = p.astype(jnp.float32) - lr * (upd_ + cfg.weight_decay *
                                             p.astype(jnp.float32))
        return newp.astype(p.dtype), mu, nu

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_mu = tdef.flatten_up_to(state["mu"])
    flat_nu = tdef.flatten_up_to(state["nu"])
    new_p, new_mu, new_nu = [], [], []
    for p, g, mu, nu in zip(flat_p, flat_g, flat_mu, flat_nu):
        a, b, c = upd(p, g, mu, nu)
        new_p.append(a)
        new_mu.append(b)
        new_nu.append(c)
    return (tdef.unflatten(new_p),
            {"mu": tdef.unflatten(new_mu), "nu": tdef.unflatten(new_nu),
             "step": step},
            {"grad_norm": gnorm, "lr": lr})
