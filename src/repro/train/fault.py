"""Fault tolerance: heartbeat watchdog, checkpoint/restart coordinator,
straggler detection.

On a real pod the failure signals come from the runtime (ICI timeouts,
host heartbeats); here the coordinator wraps the step function so the
control-plane logic — detect, restore, replay, mitigate — is real and unit
tested, with failures injected by the tests.

Design points mirroring production systems:
* steps are pure state -> state, so replay-from-checkpoint is exact;
* the data pipeline is addressed by step index (deterministic batches), so
  restarts do not skew the data distribution;
* straggler mitigation is a callback: on TPU pods the usual action is to
  re-shard around the slow host or preemptively checkpoint.
"""

from __future__ import annotations

import threading
import time
from collections import deque
from dataclasses import dataclass, field


class StepTimeoutError(RuntimeError):
    pass


@dataclass
class Watchdog:
    """Heartbeat monitor: flags a hang if no beat within ``timeout_s``."""
    timeout_s: float = 300.0
    _last: float = field(default_factory=time.monotonic)
    _stop: threading.Event = field(default_factory=threading.Event)
    _fired: threading.Event = field(default_factory=threading.Event)

    def beat(self):
        self._last = time.monotonic()

    def start(self):
        def loop():
            while not self._stop.wait(min(self.timeout_s / 4, 1.0)):
                if time.monotonic() - self._last > self.timeout_s:
                    self._fired.set()
                    return
        self._thread = threading.Thread(target=loop, daemon=True)
        self._thread.start()

    def stop(self):
        self._stop.set()

    @property
    def fired(self) -> bool:
        return self._fired.is_set()


@dataclass
class StragglerDetector:
    """Flags steps slower than ``factor`` × trailing median."""
    window: int = 50
    factor: float = 3.0
    _durations: deque = field(default_factory=lambda: deque(maxlen=50))
    events: list = field(default_factory=list)

    def observe(self, step: int, seconds: float) -> bool:
        hist = sorted(self._durations)
        self._durations.append(seconds)
        if len(hist) < 10:
            return False
        median = hist[len(hist) // 2]
        if seconds > self.factor * median:
            self.events.append({"step": step, "seconds": seconds,
                                "median": median})
            return True
        return False


class Coordinator:
    """Run a training loop with checkpoint/restart on failure.

    ``step_fn(state, batch) -> (state, metrics)`` must be pure.
    ``batch_fn(step) -> batch`` must be deterministic in ``step``.
    """

    def __init__(self, step_fn, batch_fn, ckpt_manager, *,
                 ckpt_every: int = 100, max_failures: int = 3,
                 straggler: StragglerDetector | None = None,
                 on_straggler=None, watchdog: Watchdog | None = None):
        self.step_fn = step_fn
        self.batch_fn = batch_fn
        self.ckpt = ckpt_manager
        self.ckpt_every = ckpt_every
        self.max_failures = max_failures
        self.straggler = straggler or StragglerDetector()
        self.on_straggler = on_straggler
        self.watchdog = watchdog
        self.failures = 0
        self.restarts = []

    def run(self, state, start_step: int, num_steps: int):
        """Returns (final_state, last_step, history)."""
        step = start_step
        history = []
        if self.watchdog:
            self.watchdog.start()
        while step < start_step + num_steps:
            try:
                t0 = time.monotonic()
                batch = self.batch_fn(step)
                state, metrics = self.step_fn(state, batch)
                dt = time.monotonic() - t0
                if self.watchdog:
                    self.watchdog.beat()
                    if self.watchdog.fired:
                        raise StepTimeoutError(f"hang at step {step}")
                if self.straggler.observe(step, dt) and self.on_straggler:
                    self.on_straggler(step, dt)
                history.append({"step": step, **{
                    k: float(v) for k, v in (metrics or {}).items()
                    if hasattr(v, "__float__") or isinstance(v, float)}})
                step += 1
                if step % self.ckpt_every == 0:
                    self.ckpt.save_async(step, {"state": state,
                                                "step": step})
            except Exception as e:  # noqa: BLE001 — recovery path
                self.failures += 1
                self.restarts.append({"step": step, "error": repr(e)})
                if self.failures > self.max_failures:
                    raise
                self.ckpt.wait()
                latest = self.ckpt.latest_step()
                if latest is not None:
                    restored, _ = self.ckpt.restore(
                        {"state": state, "step": 0})
                    state = restored["state"]
                    step = int(restored["step"])
                # else: replay from start_step with current state
        if self.watchdog:
            self.watchdog.stop()
        self.ckpt.wait()
        return state, step, history
