"""Sharded checkpointing with cross-topology restore (elastic scaling).

Leaves are stored as individual ``.npy`` files keyed by their tree path,
plus a JSON manifest. Restore takes a *target* mesh + sharding tree and
``device_put``s each leaf into the new layout — a checkpoint written on a
16×16 mesh restores onto 2×16×16 (or a single CPU device) unchanged, which
is the elastic-scaling contract.

Writes are atomic (tmp dir + rename) and optionally asynchronous (the
train loop overlaps the device→host gather + disk write with subsequent
steps). A retention policy keeps the newest K checkpoints.
"""

from __future__ import annotations

import json
import shutil
import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor
from pathlib import Path

import jax
import numpy as np


def _flatten(tree, prefix=()):
    if isinstance(tree, dict):
        for k in sorted(tree):
            yield from _flatten(tree[k], prefix + (str(k),))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            yield from _flatten(v, prefix + (str(i),))
    else:
        yield prefix, tree


def _unflatten_into(skeleton, flat: dict):
    def walk(node, path):
        if isinstance(node, dict):
            return {k: walk(v, path + (str(k),)) for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            out = [walk(v, path + (str(i),)) for i, v in enumerate(node)]
            return type(node)(out)
        return flat["/".join(path)]
    return walk(skeleton, ())


class CheckpointManager:
    def __init__(self, directory, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._pool = ThreadPoolExecutor(max_workers=1)
        self._lock = threading.Lock()

    # ---------------------------------------------------------- save

    def save(self, step: int, state: dict) -> Path:
        """Blocking save of a pytree state dict."""
        host_state = jax.tree.map(lambda a: np.asarray(jax.device_get(a)),
                                  state)
        return self._write(step, host_state)

    def save_async(self, step: int, state: dict) -> Future:
        """Gather to host now, write on a background thread."""
        host_state = jax.tree.map(lambda a: np.asarray(jax.device_get(a)),
                                  state)
        return self._pool.submit(self._write, step, host_state)

    def _write(self, step: int, host_state) -> Path:
        with self._lock:
            final = self.dir / f"step_{step:010d}"
            tmp = self.dir / f".tmp_step_{step:010d}"
            if tmp.exists():
                shutil.rmtree(tmp)
            tmp.mkdir(parents=True)
            manifest = {"step": step, "time": time.time(), "leaves": {}}
            for path, leaf in _flatten(host_state):
                key = "/".join(path)
                fname = key.replace("/", "__") + ".npy"
                np.save(tmp / fname, np.asarray(leaf), allow_pickle=False)
                manifest["leaves"][key] = {
                    "file": fname,
                    "shape": list(np.shape(leaf)),
                    "dtype": str(np.asarray(leaf).dtype),
                }
            (tmp / "manifest.json").write_text(json.dumps(manifest))
            if final.exists():
                shutil.rmtree(final)
            tmp.rename(final)
            self._gc()
            return final

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(self.dir / f"step_{s:010d}", ignore_errors=True)

    # ---------------------------------------------------------- restore

    def all_steps(self) -> list[int]:
        return sorted(int(p.name.split("_")[1])
                      for p in self.dir.glob("step_*"))

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, skeleton, step: int | None = None,
                shardings=None):
        """Restore into the structure of ``skeleton``; optionally place
        each leaf with the given sharding tree (any mesh/topology)."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        d = self.dir / f"step_{step:010d}"
        manifest = json.loads((d / "manifest.json").read_text())
        flat = {}
        for key, info in manifest["leaves"].items():
            flat[key] = np.load(d / info["file"], allow_pickle=False)
        state = _unflatten_into(skeleton, flat)
        if shardings is not None:
            state = jax.tree.map(
                lambda a, s: jax.device_put(a, s), state, shardings)
        return state, step

    def wait(self):
        self._pool.shutdown(wait=True)
        self._pool = ThreadPoolExecutor(max_workers=1)
