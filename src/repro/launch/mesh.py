"""Production mesh definitions (functions, never module-level constants —
importing this module must not touch jax device state).

Single pod: (16, 16) = 256 chips, axes (data, model).
Multi-pod:  (2, 16, 16) = 512 chips, axes (pod, data, model); the default
placement runs DP over ``pod`` (one cross-pod gradient all-reduce per
step); the GPipe pipeline over ``pod`` is available as a feature
(repro.parallel.pipeline).
"""

from __future__ import annotations

import jax

from repro import compat  # noqa: F401  (installs AxisType/make_mesh shims)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh(shape=None, axes=None):
    """Small CPU mesh for tests/examples (uses however many local devices
    exist)."""
    n = len(jax.devices())
    if shape is None:
        shape, axes = (n,), ("data",)
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
