"""Production training launcher.

On a real pod this runs under the TPU runtime (one process per host,
``jax.distributed.initialize`` from the environment); on CPU it runs the
same code over host devices. Wires together: config system, mesh,
sharded train step, deterministic data pipeline, async checkpointing and
the fault coordinator.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src \
        python -m repro.launch.train --arch qwen2-0.5b --steps 50 \
        --reduced --batch 8 --seq 64
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--reduced", action="store_true",
                    help="use the reduced (CPU-sized) config")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--remat", action="store_true")
    ap.add_argument("--multi-pod", action="store_true",
                    help="use the 2x16x16 production mesh (needs 512 "
                    "devices)")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    from repro.configs import get_config
    from repro.configs.base import ShapeSpec
    from repro.data.pipeline import DataConfig, TokenPipeline
    from repro.launch.mesh import make_host_mesh, make_production_mesh
    from repro.models.model import count_params, make_params
    from repro.train.checkpoint import CheckpointManager
    from repro.train.fault import Coordinator, StragglerDetector
    from repro.train.optimizer import OptConfig, init_state
    from repro.train.train_loop import build_train_step

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    n = len(jax.devices())
    if args.multi_pod or n >= 256:
        mesh = make_production_mesh(multi_pod=args.multi_pod)
    else:
        mesh = jax.make_mesh(
            (n, 1), ("data", "model"),
            axis_types=(jax.sharding.AxisType.Auto,) * 2)
    shape = ShapeSpec("cli", "train", args.seq, args.batch)
    opt_cfg = OptConfig(lr=args.lr, total_steps=args.steps,
                        warmup_steps=min(100, args.steps // 10 + 1))
    step_fn, shardings, _ = build_train_step(
        cfg, mesh, shape, opt_cfg, q_chunk=min(512, args.seq),
        remat=args.remat, grad_accum=args.grad_accum)
    jstep = jax.jit(step_fn, donate_argnums=(0, 1))

    params = make_params(cfg, seed=0)
    opt = init_state(params)
    print(f"{args.arch}: {count_params(cfg)/1e6:.1f}M params on "
          f"{n} devices, mesh {dict(zip(mesh.axis_names, mesh.devices.shape))}")

    pipe = TokenPipeline(DataConfig(vocab_size=cfg.vocab_size,
                                    batch=args.batch, seq_len=args.seq))
    mgr = CheckpointManager(args.ckpt_dir, keep=3)
    state = {"params": params, "opt": opt, "step": np.int64(0)}
    if args.resume and mgr.latest_step() is not None:
        state, s0 = mgr.restore(state)
        print(f"resumed from step {s0}")

    def wrapped(st, batch):
        p, o, m = jstep(st["params"], st["opt"], batch)
        return {"params": p, "opt": o, "step": st["step"] + 1}, m

    def batch_fn(s):
        return {k: jax.numpy.asarray(v)
                for k, v in pipe.batch_at(s).items()}

    coord = Coordinator(wrapped, batch_fn, mgr,
                        ckpt_every=args.ckpt_every,
                        straggler=StragglerDetector())
    t0 = time.time()
    state, last, hist = coord.run(state, int(state["step"]), args.steps)
    dt = time.time() - t0
    losses = [h["loss"] for h in hist if "loss" in h]
    print(f"{last} steps in {dt:.1f}s; loss {losses[0]:.3f} -> "
          f"{np.mean(losses[-5:]):.3f}; "
          f"{args.steps * args.batch * args.seq / dt:.0f} tok/s")
    mgr.save(last, state)


if __name__ == "__main__":
    main()
