import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this produces:
* the main compile (scanned layers) — proves the sharding config is
  coherent, records ``memory_analysis`` (fits-per-device) and the
  trip-count-corrected collective schedule;
* a cost reconstruction — XLA counts scan bodies once, so HLO FLOPs/bytes
  are rebuilt either from a fully unrolled variant (small archs, exact) or
  from outer/period compiles: ``outer + reps × (period − outer)``;
* a JSON record per cell under ``experiments/dryrun/`` consumed by the
  roofline analysis (EXPERIMENTS.md §Dry-run / §Roofline).

Usage::

    PYTHONPATH=src python -m repro.launch.dryrun --all
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2.5-32b \
        --shape train_4k --mesh both
"""

import argparse
import dataclasses
import json
import time
import traceback
from pathlib import Path

import jax
import numpy as np

from repro.analysis.hlo import collective_summary
from repro.configs import SHAPES, all_configs, get_config, shapes_for
from repro.launch.mesh import make_production_mesh
from repro.models.model import (
    count_params, decode_step, serve_prefill, make_abstract_params,
    params_axes)
from repro.parallel.inputs import decode_inputs, train_batch_specs
from repro.parallel.sharding import (
    make_activation_sharder, moe_dispatch_plan, tree_shardings)
from repro.train.optimizer import OptConfig, init_state
from repro.train.train_loop import build_train_step

OUT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def _mem_dict(ma):
    return {
        "argument_bytes": ma.argument_size_in_bytes,
        "output_bytes": ma.output_size_in_bytes,
        "temp_bytes": ma.temp_size_in_bytes,
        "generated_code_bytes": ma.generated_code_size_in_bytes,
    }


def _cost_dict(ca):
    if ca is None:
        return {}
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes_accessed": float(ca.get("bytes accessed", 0.0))}


def _compile(step_fn, in_shardings, args, lower_only: bool = False):
    t0 = time.time()
    jitted = jax.jit(step_fn, in_shardings=in_shardings)
    lowered = jitted.lower(*args)
    if lower_only:
        return lowered, time.time() - t0
    compiled = lowered.compile()
    return compiled, time.time() - t0


def _lower_train(cfg, shape, mesh, *, num_layers=None, scan_layers=True,
                 rec_unroll=False, q_chunk=512, seq_shard=True, rules=None,
                 remat=True, lower_only=False, grad_accum=1,
                 moe_impl="gspmd"):
    step, shardings, abstract = build_train_step(
        cfg, mesh, shape, OptConfig(), num_layers=num_layers,
        scan_layers=scan_layers, rec_unroll=rec_unroll, q_chunk=q_chunk,
        seq_shard=seq_shard, rules=rules, remat=remat,
        grad_accum=grad_accum, moe_impl=moe_impl)
    batch_abs, batch_shard = train_batch_specs(cfg, shape, mesh)
    return _compile(
        step, (shardings["params"], shardings["opt"], batch_shard),
        (abstract["params"], abstract["opt"], batch_abs),
        lower_only=lower_only)


def _lower_prefill(cfg, shape, mesh, *, num_layers=None, scan_layers=True,
                   rec_unroll=False, q_chunk=512, seq_shard=True,
                   rules=None, remat=True, lower_only=False,
                   moe_impl="gspmd"):
    sharder = make_activation_sharder(mesh, shape.global_batch,
                                      shape.seq_len, seq_shard=seq_shard)
    moe_groups, moe_gsh, ep_sharder = moe_dispatch_plan(
        cfg, mesh, shape.global_batch, shape.seq_len, seq_shard)
    moe_fn = None
    if cfg.is_moe and moe_impl == "shard_map":
        from repro.models.moe import moe_schema
        from repro.models.moe_shard import make_sharded_moe
        from repro.parallel.sharding import batch_axes, spec_for_axes
        schema = moe_schema(cfg)
        specs = {k: spec_for_axes(d.axes, d.shape, mesh)
                 for k, d in schema.items()}
        moe_fn = make_sharded_moe(
            cfg, mesh, batch_axes(mesh, shape.global_batch), specs)

    def step(params, batch):
        return serve_prefill(cfg, params, batch, q_chunk=q_chunk,
                             num_layers=num_layers, sharder=sharder,
                             scan_layers=scan_layers,
                             rec_unroll=rec_unroll,
                             moe_groups=moe_groups,
                             ep_sharder=ep_sharder,
                             moe_group_sharder=moe_gsh,
                             moe_fn=moe_fn)

    abs_params = make_abstract_params(cfg, num_layers)
    p_shard = tree_shardings(params_axes(cfg, num_layers), abs_params,
                             mesh, rules)
    batch_abs, batch_shard = train_batch_specs(cfg, shape, mesh)
    batch_abs.pop("labels")
    batch_shard.pop("labels")
    return _compile(step, (p_shard, batch_shard), (abs_params, batch_abs),
                    lower_only=lower_only)


def _lower_decode(cfg, shape, mesh, *, num_layers=None, rules=None,
                  lower_only=False, kv_quant=False, **_ignored):
    def step(params, token, cache):
        return decode_step(cfg, params, token, cache,
                           num_layers=num_layers)

    abs_params = make_abstract_params(cfg, num_layers)
    p_shard = tree_shardings(params_axes(cfg, num_layers), abs_params,
                             mesh, rules)
    token, cache, sh = decode_inputs(cfg, shape, mesh, kv_quant=kv_quant)
    return _compile(step, (p_shard, sh["token"], sh["cache"]),
                    (abs_params, token, cache), lower_only=lower_only)


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             *, q_chunk: int = 512, seq_shard: bool = True,
             rules=None, variant: str = "baseline",
             overrides: dict | None = None) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    ndev = int(np.prod(mesh.devices.shape))
    kind = shape.kind
    rec = {
        "arch": arch, "shape": shape_name, "kind": kind,
        "mesh": "2x16x16" if multi_pod else "16x16", "devices": ndev,
        "variant": variant,
        "params": count_params(cfg),
        "active_params": count_params(cfg, active_only=True),
        "timestamp": time.time(),
    }

    lower_map = {
        "train": _lower_train, "prefill": _lower_prefill,
        "decode": _lower_decode,
    }
    # long sequences: bigger q chunks keep the unrolled-chunk count (and
    # hence compile time) bounded; memory stays sharded per-device
    q_main = 2048 if shape.seq_len >= 32_768 else q_chunk
    # MoE: keep the token layout purely data-sharded so dispatch groups
    # align with device shards (no GSPMD relayout of the scatter chain)
    if cfg.is_moe:
        seq_shard = False
    kwargs = {} if kind == "decode" else dict(
        q_chunk=q_main, seq_shard=seq_shard)
    main_kwargs = dict(kwargs)
    if cfg.is_moe and kind == "train":
        # microbatch the dispatch transients under the 16 GB budget
        main_kwargs["grad_accum"] = 4
    if overrides:
        rec["overrides"] = {k: str(v) for k, v in overrides.items()}
        if "seq_shard" in overrides and kind != "decode":
            main_kwargs["seq_shard"] = overrides["seq_shard"]
            kwargs["seq_shard"] = overrides["seq_shard"]
        for key in ("grad_accum", "moe_impl", "kv_quant", "q_chunk"):
            if key in overrides:
                main_kwargs[key] = overrides[key]
    compiled, dt = lower_map[kind](cfg, shape, mesh, rules=rules,
                                   **main_kwargs)
    rec["compile_seconds"] = round(dt, 1)
    rec["memory"] = _mem_dict(compiled.memory_analysis())
    rec["cost_raw"] = _cost_dict(compiled.cost_analysis())
    coll = collective_summary(compiled.as_text())
    rec["collectives"] = coll

    # ---- cost reconstruction (scan bodies are cost-counted once by XLA)
    if kind == "decode":
        # decode path is fully unrolled -> compiled cost already exact
        rec["cost_corrected"] = dict(rec["cost_raw"],
                                     collective_bytes=coll["total_bytes"])
        rec["cost_method"] = "compiled-unrolled(decode)"
        rec["cost_scope"] = "per_device"
    else:
        # exact algorithmic cost: fully unrolled, remat off, LOWER ONLY
        # (pre-partitioning HLO -> global flops/bytes; no expensive
        # compile). Attention FLOPs are invariant to q chunking, so the
        # cost trace uses one full-sequence chunk to stay small.
        kwargs_cost = dict(kwargs, q_chunk=shape.seq_len)
        lowered, dt2 = lower_map[kind](
            cfg, shape, mesh, rules=rules, scan_layers=False,
            rec_unroll=True, remat=False, lower_only=True, **kwargs_cost)
        cc = _cost_dict(lowered.cost_analysis())
        cc["collective_bytes"] = coll["total_bytes"] * ndev  # global-ize
        rec["cost_corrected"] = cc
        rec["cost_method"] = "lowered-unrolled"
        rec["cost_scope"] = "global"
        rec["lower_seconds_cost"] = round(dt2, 1)
    return rec


def cell_list(archs=None):
    cells = []
    for arch, cfg in sorted(all_configs().items()):
        if archs and arch not in archs:
            continue
        for shape in shapes_for(cfg):
            cells.append((arch, shape.name))
    return cells


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", action="append", default=None)
    ap.add_argument("--shape", action="append", default=None)
    ap.add_argument("--mesh", choices=["pod", "multipod", "both"],
                    default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", default=str(OUT_DIR))
    args = ap.parse_args()

    out = Path(args.out)
    out.mkdir(parents=True, exist_ok=True)
    meshes = {"pod": [False], "multipod": [True],
              "both": [False, True]}[args.mesh]
    cells = cell_list(args.arch)
    if args.shape:
        cells = [c for c in cells if c[1] in args.shape]

    results = []
    for arch, shape in cells:
        for mp in meshes:
            tag = f"{arch}__{shape}__{'2x16x16' if mp else '16x16'}"
            path = out / f"{tag}.json"
            if path.exists() and not args.force:
                print(f"[skip] {tag}")
                continue
            print(f"[run ] {tag}", flush=True)
            t0 = time.time()
            try:
                rec = run_cell(arch, shape, mp)
                rec["status"] = "ok"
            except Exception as e:  # noqa: BLE001 — record the failure
                rec = {"arch": arch, "shape": shape,
                       "mesh": "2x16x16" if mp else "16x16",
                       "status": "error", "error": repr(e),
                       "traceback": traceback.format_exc()[-4000:]}
            rec["wall_seconds"] = round(time.time() - t0, 1)
            path.write_text(json.dumps(rec, indent=2, default=str))
            print(f"       {rec['status']} in {rec['wall_seconds']}s",
                  flush=True)
            results.append(rec)
    ok = sum(r["status"] == "ok" for r in results)
    print(f"done: {ok}/{len(results)} cells ok")


if __name__ == "__main__":
    main()
