"""Production serving launcher: batched generation via ServeEngine.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b \
        --reduced --batch 4 --new-tokens 16
"""

from __future__ import annotations

import argparse
import time

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--requests", type=int, default=3,
                    help="number of batched request rounds")
    args = ap.parse_args()

    from repro.configs import get_config
    from repro.models.model import make_params
    from repro.serve.engine import ServeEngine

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    params = make_params(cfg, seed=0)
    eng = ServeEngine(cfg, params,
                      max_seq_len=args.prompt_len + args.new_tokens + 8,
                      q_chunk=16)
    rng = np.random.default_rng(0)
    total, t0 = 0, time.time()
    for r in range(args.requests):
        prompts = rng.integers(
            0, cfg.vocab_size,
            (args.batch, args.prompt_len)).astype(np.int32)
        src = (rng.normal(size=(args.batch, args.prompt_len, cfg.d_model))
               .astype(np.float32) if cfg.is_encdec else None)
        out = eng.generate(prompts, max_new_tokens=args.new_tokens,
                           temperature=args.temperature, seed=r,
                           src_embeds=src)
        total += out[:, args.prompt_len:].size
        print(f"request {r}: generated {out.shape} "
              f"(first row tail: {out[0, -8:].tolist()})")
    dt = time.time() - t0
    print(f"{total} tokens in {dt:.1f}s = {total / dt:.1f} tok/s "
          f"(incl. compile)")


if __name__ == "__main__":
    main()
