import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""§Perf hillclimbing driver: re-run selected cells with optimization
variants and record before/after roofline terms.

    PYTHONPATH=src python -m repro.launch.hillclimb --cell moe
    PYTHONPATH=src python -m repro.launch.hillclimb --all
"""

import argparse
import json
import time
import traceback
from pathlib import Path

OUT = Path(__file__).resolve().parents[3] / "experiments" / "variants"

#: (name, arch, shape, multi_pod, overrides) — hypotheses in §Perf log
VARIANTS = {
    "moe": [
        ("shardmap_dispatch", "deepseek-moe-16b", "train_4k", False,
         {"moe_impl": "shard_map", "seq_shard": True, "grad_accum": 4}),
        ("shardmap_noaccum", "deepseek-moe-16b", "train_4k", False,
         {"moe_impl": "shard_map", "seq_shard": True, "grad_accum": 1}),
        ("shardmap_dispatch", "granite-moe-3b-a800m", "train_4k", False,
         {"moe_impl": "shard_map", "seq_shard": True, "grad_accum": 4}),
        ("shardmap_prefill", "granite-moe-3b-a800m", "prefill_32k", False,
         {"moe_impl": "shard_map", "seq_shard": True}),
        ("shardmap_prefill", "deepseek-moe-16b", "prefill_32k", False,
         {"moe_impl": "shard_map", "seq_shard": True}),
    ],
    "decode": [
        ("kv_int8", "qwen2.5-32b", "decode_32k", False,
         {"kv_quant": True}),
        ("kv_int8_long", "recurrentgemma-2b", "long_500k", False,
         {"kv_quant": True}),
    ],
    "dense": [
        # H1: drop SP, classic Megatron TP (1 AR/block) + microbatching
        ("tp_classic_accum4", "qwen2.5-32b", "train_4k", False,
         {"seq_shard": False, "grad_accum": 4}),
        # control: microbatching alone (memory fit, same layout)
        ("accum4", "qwen2.5-32b", "train_4k", False,
         {"grad_accum": 4}),
    ],
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", action="append", default=None,
                    choices=list(VARIANTS) + [None])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    groups = args.cell or (list(VARIANTS) if args.all else [])

    from repro.launch.dryrun import run_cell
    OUT.mkdir(parents=True, exist_ok=True)
    for group in groups:
        for name, arch, shape, mp, overrides in VARIANTS[group]:
            tag = (f"{arch}__{shape}__{'2x16x16' if mp else '16x16'}"
                   f"__{name}")
            path = OUT / f"{tag}.json"
            if path.exists() and not args.force:
                print(f"[skip] {tag}")
                continue
            print(f"[run ] {tag}", flush=True)
            t0 = time.time()
            try:
                rec = run_cell(arch, shape, mp, variant=name,
                               overrides=overrides)
                rec["status"] = "ok"
            except Exception as e:  # noqa: BLE001
                rec = {"arch": arch, "shape": shape, "variant": name,
                       "status": "error", "error": repr(e),
                       "traceback": traceback.format_exc()[-3000:]}
            rec["wall_seconds"] = round(time.time() - t0, 1)
            path.write_text(json.dumps(rec, indent=2, default=str))
            print(f"       {rec['status']} in {rec['wall_seconds']}s",
                  flush=True)


if __name__ == "__main__":
    main()
