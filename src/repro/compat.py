"""JAX version compatibility shim.

The codebase is written against the modern public API (``jax.shard_map``,
``jax.sharding.AxisType``, ``jax.make_mesh(..., axis_types=...)``).  Older
jax releases (<= 0.4.x, as baked into this container) ship ``shard_map``
under ``jax.experimental`` and have neither ``AxisType`` nor the
``axis_types`` kwarg.  Importing this module installs forward-compatible
aliases onto ``jax`` itself so both the library and the test-suite idiom
work unchanged on either version.

Usage: ``from repro import compat`` (idempotent, side-effecting import) or
use the re-exported :func:`shard_map` / :func:`make_mesh` directly.
"""

from __future__ import annotations

import functools
import inspect

import jax

# --- shard_map -------------------------------------------------------------
if hasattr(jax, "shard_map"):
    shard_map = jax.shard_map
else:  # pragma: no cover - exercised only on old jax
    from jax.experimental.shard_map import shard_map as _exp_shard_map

    @functools.wraps(_exp_shard_map)
    def shard_map(f=None, /, *, mesh=None, in_specs=None, out_specs=None,
                  **kw):
        # modern jax.shard_map is keyword-only and curries when f is None
        if f is None:
            return lambda g: shard_map(g, mesh=mesh, in_specs=in_specs,
                                       out_specs=out_specs, **kw)
        kw.pop("axis_names", None)  # not in the old signature
        if "check_vma" in kw:       # renamed from check_rep in newer jax
            kw["check_rep"] = kw.pop("check_vma")
        return _exp_shard_map(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, **kw)

    jax.shard_map = shard_map

# --- sharding.AxisType -----------------------------------------------------
if not hasattr(jax.sharding, "AxisType"):  # pragma: no cover
    class _AxisType:
        """Stand-in for jax.sharding.AxisType (values are ignored by the
        make_mesh shim below — old jax has no explicit/auto axis modes)."""

        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"

    jax.sharding.AxisType = _AxisType

# --- make_mesh(axis_types=...) --------------------------------------------
_raw_make_mesh = getattr(jax, "make_mesh", None)
_HAS_AXIS_TYPES = (_raw_make_mesh is not None and "axis_types"
                   in inspect.signature(_raw_make_mesh).parameters)


def make_mesh(axis_shapes, axis_names, *args, **kwargs):
    """``jax.make_mesh`` accepting (and dropping, if unsupported) the
    ``axis_types`` keyword of newer jax; falls back to the raw ``Mesh``
    constructor on releases that predate ``jax.make_mesh`` itself."""
    if not _HAS_AXIS_TYPES:
        kwargs.pop("axis_types", None)
    if _raw_make_mesh is None:  # pragma: no cover - pre-0.4.35 jax only
        import math
        devices = kwargs.pop("devices", None)
        if devices is None:
            devices = jax.devices()[:math.prod(axis_shapes)]
        import numpy as _np
        return jax.sharding.Mesh(
            _np.asarray(devices).reshape(axis_shapes), axis_names)
    return _raw_make_mesh(axis_shapes, axis_names, *args, **kwargs)


# only monkeypatch where the shim actually differs (old jax); on modern
# jax the public jax.make_mesh is left untouched
if not _HAS_AXIS_TYPES and not getattr(jax, "_repro_compat_mesh", False):
    jax._repro_compat_mesh = True
    jax.make_mesh = make_mesh

__all__ = ["shard_map", "make_mesh"]
