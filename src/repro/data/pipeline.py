"""Deterministic, resumable data pipeline.

Batches are a pure function of (seed, step) — the property the fault
coordinator relies on for exact replay after restart. A background
prefetch thread keeps a bounded queue of upcoming batches; the iterator
can be fast-forwarded to any step for resume.

Sources: synthetic Zipf token streams (matching the scale-free flavor of
the paper's workloads) or a binary token file (memmapped).
"""

from __future__ import annotations

import queue
import threading
from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    batch: int
    seq_len: int
    seed: int = 0
    source: str = "synthetic"        # synthetic | file
    path: str = ""
    zipf_a: float = 1.3


class TokenPipeline:
    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        self._tokens = None
        if cfg.source == "file":
            self._tokens = np.memmap(cfg.path, dtype=np.uint16, mode="r")

    def batch_at(self, step: int) -> dict:
        """Pure function of step -> {tokens, labels}."""
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed << 20) ^ step)
        if self._tokens is None:
            toks = rng.zipf(cfg.zipf_a, size=(cfg.batch, cfg.seq_len + 1))
            toks = (toks - 1) % cfg.vocab_size
        else:
            n = self._tokens.shape[0] - cfg.seq_len - 1
            starts = rng.integers(0, n, size=cfg.batch)
            toks = np.stack([
                np.asarray(self._tokens[s:s + cfg.seq_len + 1])
                for s in starts]).astype(np.int64) % cfg.vocab_size
        return {
            "tokens": toks[:, :-1].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32),
        }

    def iterate(self, start_step: int = 0, prefetch: int = 2):
        """Prefetching iterator, resumable at any step."""
        q: queue.Queue = queue.Queue(maxsize=prefetch)
        stop = threading.Event()

        def producer():
            step = start_step
            while not stop.is_set():
                try:
                    q.put((step, self.batch_at(step)), timeout=0.25)
                    step += 1
                except queue.Full:
                    continue

        t = threading.Thread(target=producer, daemon=True)
        t.start()
        try:
            while True:
                yield q.get()
        finally:
            stop.set()


def host_shard(batch: dict, process_index: int, process_count: int) -> dict:
    """Per-host slice of the global batch (multi-host data loading)."""
    def sl(a):
        n = a.shape[0]
        chunk = n // process_count
        return a[process_index * chunk:(process_index + 1) * chunk]
    return {k: sl(v) for k, v in batch.items()}
