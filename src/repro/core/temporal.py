"""Temporal triadic monitoring (the paper's security application, Figs 3-4).

Computes the triad census of a dynamic edge stream over fixed time windows,
tracks the proportion of each triad type relative to its trailing history,
and flags windows where monitored patterns deviate beyond a z-score
threshold — the paper's anomaly/threat monitor.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.digraph import from_edges
from repro.core.planner import build_plan
from repro.core.census import triad_census
from repro.core.tricode import TRIAD_NAMES

#: Paper Fig 3: triad patterns relevant to computer-network monitoring.
SECURITY_PATTERNS = {
    "scanning": ("021D",),            # one source fanning out
    "ddos": ("021U",),                # many sources converging
    "relay": ("021C", "030T"),        # stepping-stone chains
    "p2p_exfil": ("102", "201", "300"),  # unusual mutual cliques
}


@dataclass
class TriadMonitor:
    """Sliding-window census tracker with z-score anomaly detection."""

    n_nodes: int
    window: int = 1000               #: edges per census window
    history: int = 20                #: trailing windows for the baseline
    threshold: float = 3.0           #: z-score alarm threshold
    _censuses: list = field(default_factory=list)

    def observe(self, src: np.ndarray, dst: np.ndarray) -> np.ndarray:
        """Ingest one window of edges; returns its 16-type census."""
        g = from_edges(src, dst, n=self.n_nodes)
        plan = build_plan(g)
        census = triad_census(plan)
        self._censuses.append(census)
        return census

    def proportions(self) -> np.ndarray:
        """(windows, 16) census proportions over non-null triads."""
        cs = np.asarray(self._censuses, dtype=np.float64)
        denom = np.maximum(cs[:, 1:].sum(axis=1, keepdims=True), 1.0)
        return cs / denom

    def alarms(self) -> list[dict]:
        """Windows whose monitored patterns deviate from trailing history.

        Uses robust statistics (median + MAD) so that an ongoing attack
        does not poison its own detection baseline.
        """
        props = self.proportions()
        out = []
        for t in range(self.history, props.shape[0]):
            base = props[max(0, t - self.history):t]
            mu = np.median(base, axis=0)
            mad = np.median(np.abs(base - mu), axis=0)
            sd = 1.4826 * mad + 1e-6
            z = (props[t] - mu) / sd
            for pattern, types in SECURITY_PATTERNS.items():
                idx = [TRIAD_NAMES.index(ty) for ty in types]
                score = float(np.max(np.abs(z[idx])))
                if score > self.threshold:
                    out.append({"window": t, "pattern": pattern,
                                "zscore": score})
        return out
