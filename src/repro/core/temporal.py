"""Temporal triadic monitoring (the paper's security application, Figs 3-4).

Computes the triad census of a dynamic edge stream over sliding windows,
tracks the proportion of each triad type relative to its trailing history,
and flags windows where monitored patterns deviate beyond a z-score
threshold — the paper's anomaly/threat monitor, rebuilt on the engine
subsystem instead of per-window from-scratch host censuses.

Windowing model
---------------
The monitor ingests an ordered stream of directed edges in arbitrary
batches (:meth:`TriadMonitor.observe`).  A census is emitted for every
window of the last ``window`` stream edges, advancing by ``stride`` edges;
``stride == window`` (the default) recovers the legacy tumbling behavior,
``stride < window`` gives overlapping sliding windows.  Each window's
graph is the *set* of its arcs (duplicates collapse, self-loops drop),
exactly as :func:`repro.core.digraph.from_edges` would build it.

Delta-update contract
---------------------
All censuses run through one resident
:class:`repro.core.engine.EngineSession` on the monitor's backend/mesh, so
graph + pair arrays upload once per window and the jitted chunk step
compiles once for the whole stream.  When consecutive windows overlap
(``stride < window``) and ``incremental=True``, window k+1's census is
computed as the delta update

    C_{k+1} = C_k + contrib(affected, G_{k+1}) − contrib(affected, G_k)

re-counting only the pairs with an endpoint whose row the arc delta
changed (:mod:`repro.core.incremental`).  This is **bit-identical** to a
from-scratch census of window k+1 on every backend and orient mode — the
incremental path changes the work done, never the counts — and processes
O(affected) work items instead of the window's full O(W)
(`tests/test_temporal.py`, `benchmarks/check.sh --temporal-smoke`).

With ``partition=True`` (and a mesh) the session shards each window's
graph itself: every device holds only its pair shard's local subgraph,
and a sliding-window delta dispatches only the shards owning affected
pairs — the other devices' buffers are never touched
(:mod:`repro.core.partition`).

Anomaly detection uses robust statistics (median + MAD over the trailing
``history`` windows) so an ongoing attack does not poison its own
baseline; per-window proportions and alarm verdicts are cached
incrementally as windows are observed, so :meth:`TriadMonitor.alarms` is
O(new windows), not a quadratic rescan of the history.
"""

from __future__ import annotations

import numpy as np

from repro.core.engine import (
    CensusEngine, EMIT_MODES, EngineStats, MAX_WINDOWS_PER_DISPATCH,
    PIPELINE_DEPTH)
from repro.core.faults import FaultError
from repro.core.tricode import TRIAD_NAMES

#: Paper Fig 3: triad patterns relevant to computer-network monitoring.
SECURITY_PATTERNS = {
    "scanning": ("021D",),            # one source fanning out
    "ddos": ("021U",),                # many sources converging
    "relay": ("021C", "030T"),        # stepping-stone chains
    "p2p_exfil": ("102", "201", "300"),  # unusual mutual cliques
}

def _indices_for(types: tuple) -> np.ndarray:
    """Census indices for a pattern's triad-type tuple, memoized by the
    tuple itself — so the per-window alarm loop never calls
    ``TRIAD_NAMES.index``, while patterns added to (or edited in) the
    public ``SECURITY_PATTERNS`` dict at runtime are still honored."""
    got = _PATTERN_INDEX_CACHE.get(types)
    if got is None:
        got = _PATTERN_INDEX_CACHE[types] = np.array(
            [TRIAD_NAMES.index(t) for t in types], dtype=np.int64)
    return got


_PATTERN_INDEX_CACHE: dict[tuple, np.ndarray] = {}

#: Precomputed census indices for the stock patterns (satellite fix: no
#: ``TRIAD_NAMES.index`` calls inside the per-window alarm loop).
SECURITY_PATTERN_INDICES = {
    pattern: _indices_for(types)
    for pattern, types in SECURITY_PATTERNS.items()
}


class TriadMonitor:
    """Sliding-window census tracker with z-score anomaly detection.

    Parameters
    ----------
    n_nodes : fixed vertex-id space of the stream.
    window : edges per census window.
    history : trailing windows forming the robust alarm baseline.
    threshold : z-score alarm threshold (a live attribute — retuning it
        re-filters past windows too).
    stride : keyword-only; edges between consecutive windows (default
        ``window`` — tumbling).  Must satisfy ``1 <= stride <= window``.
    backend / mesh / orient / max_items : engine routing — every window's
        census runs on this backend (optionally sharded over ``mesh``)
        through one resident :class:`~repro.core.engine.EngineSession`.
    partition : shard each window's GRAPH across the mesh instead of
        replicating it — every device holds only its pair shard's local
        subgraph, sliding-window deltas dispatch only the owning shards
        (:class:`~repro.core.engine.PartitionedEngineSession`), and the
        per-window :class:`~repro.core.engine.EngineStats` carry the
        shard balance/residency report.  Requires ``mesh``; censuses are
        bit-identical either way.
    schedule : partitioned full-run execution discipline (``"async"``
        per-shard streams by default, ``"lockstep"`` the collective
        oracle); forwarded to the engine, bit-identical either way.
    pipeline_depth : per-shard produced-window queue depth of the async
        host pipeline (default 2 — double-buffering); forwarded to the
        engine and surfaced in each window's
        ``EngineStats.pipeline_depth``.
    max_windows_per_dispatch : cap K on the descriptor windows one
        async megastep dispatch may scan (default 8); forwarded to the
        engine, bit-identical for any K.
    auto_rebalance_threshold : partitioned only — re-shard the resident
        session with a fresh LPT whenever sliding-window churn pushes
        the shard load max/mean past this value (see
        :meth:`~repro.core.engine.PartitionedEngineSession.rebalance`).
    incremental : delta-update overlapping windows instead of recomputing
        them from scratch (bit-identical either way).
    emit : work-item emission mode for every window census and delta
        update (``None`` — the engine default, ``"device"`` — stream
        O(affected pairs) descriptors and expand in-kernel, ``"host"`` —
        materialize items in numpy; bit-identical either way).
    index : bool
        keep a persistent :class:`~repro.core.pair_index.PairSpaceIndex`
        in the resident session so each slide edits the pair space by
        the delta instead of rebuilding it (default True; False is the
        rebuild-from-scratch parity oracle).
    faults / max_retries / retry_backoff / watchdog_timeout : forwarded
        to the :class:`~repro.core.engine.CensusEngine` fault-tolerance
        layer.  A window whose census still fails after the retry budget
        does NOT kill the monitor: the window is recorded as *degraded*
        (:attr:`degraded` — the previous census is carried forward so
        the alarm baseline stays aligned) and the next window forces a
        full recompute, re-syncing the resident session.
    """

    def __init__(self, n_nodes: int, window: int = 1000,
                 history: int = 20, threshold: float = 3.0, *,
                 stride: int | None = None, backend: str = "jnp",
                 mesh=None, orient: str = "none",
                 incremental: bool = True,
                 max_items: int | None = None,
                 emit: str | None = None,
                 partition: bool = False,
                 schedule: str = "async",
                 pipeline_depth: int = PIPELINE_DEPTH,
                 max_windows_per_dispatch: int =
                 MAX_WINDOWS_PER_DISPATCH,
                 auto_rebalance_threshold: float | None = None,
                 index: bool = True,
                 faults=None, max_retries: int = 2,
                 retry_backoff: float = 0.01,
                 watchdog_timeout: float | None = None):
        if n_nodes < 1:
            raise ValueError(f"n_nodes must be >= 1, got {n_nodes}")
        if window < 1:
            raise ValueError(f"window must be >= 1, got {window}")
        if history < 1:
            raise ValueError(f"history must be >= 1, got {history}")
        stride = window if stride is None else int(stride)
        if not 1 <= stride <= window:
            raise ValueError(
                f"stride must be in [1, window={window}], got {stride}")
        self.n_nodes = int(n_nodes)
        self.window = int(window)
        self.stride = stride
        self.history = int(history)
        self.threshold = float(threshold)
        if emit is not None and emit not in EMIT_MODES:
            raise ValueError(
                f"unknown emit mode {emit!r}; one of {EMIT_MODES}")
        self.incremental = bool(incremental)
        self.orient = orient
        self.max_items = max_items
        self.emit = emit
        if auto_rebalance_threshold is not None and not partition:
            raise ValueError(
                "auto_rebalance_threshold requires partition=True")
        self.auto_rebalance_threshold = auto_rebalance_threshold
        self.index = bool(index)
        self.engine = CensusEngine(
            mesh=mesh, backend=backend, partition=partition,
            schedule=schedule, pipeline_depth=pipeline_depth,
            max_windows_per_dispatch=max_windows_per_dispatch,
            faults=faults, max_retries=max_retries,
            retry_backoff=retry_backoff,
            watchdog_timeout=watchdog_timeout)
        self._session = None
        self._buf = np.zeros(0, dtype=np.int64)     # pending eid tail
        self._arcset: np.ndarray | None = None      # current window's arcs
        #: multiplicity of each ``_arcset`` arc in the current window —
        #: maintained incrementally so a slide diffs the window by its
        #: O(stride) boundary batches instead of re-sorting all W edges
        self._arcmult: np.ndarray | None = None
        self._censuses: list[np.ndarray] = []
        self._props: list[np.ndarray] = []
        self.window_stats: list[EngineStats] = []
        self._alarm_cache: list[dict] = []
        self._next_alarm_t = self.history
        #: windows whose census failed past the retry budget and were
        #: recorded by carrying the previous census forward
        self.degraded: list[dict] = []
        self._force_full = False
        self.last_t: float | None = None

    # ------------------------------------------------------------ ingest
    def _validate(self, src, dst) -> np.ndarray:
        """Ravel + validate one batch the way ``from_edges`` does, plus
        explicit errors for the failure modes that used to surface deep
        inside CSR edits: empty batches, ragged (object-dtype) arrays,
        non-finite float ids, and out-of-range vertices."""
        src = np.asarray(src)
        dst = np.asarray(dst)
        if src.dtype == object or dst.dtype == object:
            raise ValueError(
                "ragged edge batch: src/dst must be rectangular numeric "
                "arrays (got object dtype — rows of unequal length?)")
        for name, a in (("src", src), ("dst", dst)):
            if np.issubdtype(a.dtype, np.floating) \
                    and not np.isfinite(a).all():
                raise ValueError(
                    f"non-finite vertex id (NaN/inf) in {name}")
        src = src.astype(np.int64).ravel()
        dst = dst.astype(np.int64).ravel()
        if src.shape != dst.shape:
            raise ValueError(
                f"src/dst length mismatch: {src.shape[0]} != "
                f"{dst.shape[0]}")
        if src.size == 0:
            raise ValueError(
                "empty edge batch: a census window cannot be empty")
        if (src.min() < 0 or dst.min() < 0
                or max(src.max(), dst.max()) >= self.n_nodes):
            raise ValueError(
                f"vertex id out of range [0, {self.n_nodes})")
        return src * self.n_nodes + dst

    def _validate_times(self, t, count: int) -> None:
        t = np.asarray(t, dtype=np.float64).ravel()
        if t.shape[0] != count:
            raise ValueError(
                f"timestamps/edges length mismatch: {t.shape[0]} != "
                f"{count}")
        if np.isnan(t).any():
            raise ValueError("NaN timestamp in edge batch")
        if (t < 0).any():
            raise ValueError(
                f"negative timestamp in edge batch (min {t.min()})")
        if self.last_t is not None and t.size and t[0] < self.last_t:
            raise ValueError(
                f"timestamps regressed: batch starts at {t[0]} but the "
                f"stream is already at {self.last_t}")
        if t.size:
            self.last_t = float(t[-1])

    def observe(self, src, dst, t=None) -> np.ndarray:
        """Ingest a batch of stream edges; returns the ``(k, 16)`` censuses
        of the windows this batch completed (possibly empty).

        Feeding exactly ``window`` edges per call with the default
        tumbling stride emits exactly one census per call — the legacy
        one-batch-one-window usage.  ``t`` (optional per-edge timestamps)
        is validated — NaN, negative, or regressing values are rejected
        at the edge — but does not affect windowing, which is count-based.
        """
        eids = self._validate(src, dst)
        if t is not None:
            self._validate_times(t, eids.shape[0])
        self._buf = np.concatenate([self._buf, eids])
        out = []
        w, s = self.window, self.stride
        while True:
            if self._arcset is None:
                if self._buf.shape[0] < w:
                    break
                out.append(self._guarded(self._emit_full, self._buf[:w]))
            else:
                if self._buf.shape[0] < w + s:
                    break
                out.append(self._guarded(self._emit_slide,
                                         self._buf[s:s + w]))
                self._buf = self._buf[s:]
        return (np.stack(out) if out
                else np.zeros((0, len(TRIAD_NAMES)), dtype=np.int64))

    def _guarded(self, emit, win: np.ndarray) -> np.ndarray:
        """Run one window emission under the monitor's degradation
        contract: a census that fails past the engine's retry budget is
        recorded as a *degraded* window carrying the previous census
        forward (the alarm baseline stays aligned with the stream), and
        the next window forces a full recompute to re-sync the resident
        session.  Only the very first window — with no census to carry —
        re-raises."""
        try:
            census = emit(win)
        except FaultError as exc:
            if not self._censuses:
                raise
            self.degraded.append(
                {"window": len(self._censuses), "error": str(exc)})
            self._force_full = True
            self.window_stats.append(None)   # keeps lengths aligned
            return self._record(self._censuses[-1].copy())
        self._force_full = False
        return census

    def _emit_full(self, win: np.ndarray) -> np.ndarray:
        """Full census of a window (first window, tumbling slides, or
        incremental disabled)."""
        from repro.core.digraph import from_edges
        arcs, mult = np.unique(win, return_counts=True)
        n = self.n_nodes
        g = from_edges(arcs // n, arcs % n, n=n)
        if self._session is None:
            kw = {}
            if self.auto_rebalance_threshold is not None:
                kw["auto_rebalance_threshold"] = \
                    self.auto_rebalance_threshold
            self._session = self.engine.session(
                g, orient=self.orient, max_items=self.max_items,
                emit=self.emit, index=self.index, **kw)
        else:
            self._session.set_graph(g)
        census = self._session.census()
        self._arcset = arcs
        self._arcmult = mult
        self.window_stats.append(self._session.stats)
        return self._record(census)

    def _slide_diff(self) -> tuple:
        """Arc add/remove sets of the next slide plus the slid window's
        (arcset, multiplicity) arrays, computed from the O(stride)
        boundary batches — the ``stride`` edges leaving the window and
        the ``stride`` edges entering it — instead of re-sorting all W
        window edges (``np.unique`` + two ``setdiff1d``).  The window's
        arc multiset is maintained in ``_arcset``/``_arcmult``; an arc
        is removed only when its multiplicity drains to zero, added only
        when it appears from zero — exactly the sets the old full diff
        produced."""
        w, s = self.window, self.stride
        eids, mult = self._arcset, self._arcmult.copy()
        lv, lc = np.unique(self._buf[:s], return_counts=True)
        ev, ec = np.unique(self._buf[w:w + s], return_counts=True)
        mult[np.searchsorted(eids, lv)] -= lc
        pos = np.searchsorted(eids, ev)
        safe = np.minimum(pos, eids.shape[0] - 1)
        hit = (pos < eids.shape[0]) & (eids[safe] == ev)
        mult[pos[hit]] += ec[hit]
        add, add_mult = ev[~hit], ec[~hit]
        dead = mult == 0
        rem = eids[dead]
        if dead.any() or add.size:
            # splice out the drained arcs, splice in the new ones (same
            # positional arithmetic as PairSpaceIndex.apply)
            del_pos = np.nonzero(dead)[0]
            ins_raw = pos[~hit]
            ipos = ins_raw - np.searchsorted(del_pos, ins_raw)
            keep = ~dead
            j = np.arange(eids.shape[0] - del_pos.shape[0])
            dest_surv = j + np.searchsorted(ipos, j, side="right")
            dest_ins = ipos + np.arange(ipos.shape[0])
            out_e = np.empty(j.shape[0] + ipos.shape[0], dtype=eids.dtype)
            out_m = np.empty_like(out_e)
            out_e[dest_surv] = eids[keep]
            out_e[dest_ins] = add
            out_m[dest_surv] = mult[keep]
            out_m[dest_ins] = add_mult
            eids, mult = out_e, out_m
        return add, rem, eids, mult

    def _emit_slide(self, win: np.ndarray) -> np.ndarray:
        """Census of the next window, delta-updated when it overlaps the
        previous one and ``incremental`` is on (or from scratch after a
        degraded window — the resident session must re-sync)."""
        if self._force_full or not self.incremental \
                or self.stride >= self.window:
            return self._emit_full(win)
        add, rem, arcs, mult = self._slide_diff()
        n = self.n_nodes
        census = self._session.update(add // n, add % n,
                                      rem // n, rem % n)
        self._arcset = arcs
        self._arcmult = mult
        self.window_stats.append(self._session.stats)
        return self._record(census)

    def _record(self, census: np.ndarray) -> np.ndarray:
        """Append a window census + its cached proportion row.  Engine
        stats are appended by the observe-driven emit paths only, so a
        replayed census never duplicates a stale stats entry."""
        census = np.asarray(census, dtype=np.int64)
        self._censuses.append(census)
        denom = max(float(census[1:].sum()), 1.0)
        self._props.append(census / denom)
        return census

    record = _record      # public alias: inject precomputed censuses

    # ------------------------------------------------------------ state
    @property
    def censuses(self) -> np.ndarray:
        """(windows, 16) emitted window censuses."""
        return (np.stack(self._censuses) if self._censuses
                else np.zeros((0, len(TRIAD_NAMES)), dtype=np.int64))

    def proportions(self) -> np.ndarray:
        """(windows, 16) census proportions over non-null triads
        (cached incrementally as windows are observed)."""
        return (np.stack(self._props) if self._props
                else np.zeros((0, len(TRIAD_NAMES))))

    # ------------------------------------------------------------ alarms
    def alarms(self) -> list[dict]:
        """Windows whose monitored patterns *exceed* their trailing
        history (one-sided: a pattern draining away is not a threat).

        Uses robust statistics (median + MAD) so that an ongoing attack
        does not poison its own detection baseline; the robust sd is
        floored at a small fraction of the median plus an absolute 1e-3
        proportion, so neither a freakishly stable baseline (tiny MAD)
        nor a rare triad type absent from the whole history (MAD = 0)
        can turn one noise triad into a huge z-score.  Scores are cached
        threshold-free — each call only evaluates windows observed since
        the last one (a window's trailing baseline is immutable once it
        exists) and filters by the *current* ``threshold``, so retuning
        the attribute re-screens the whole history for free.
        """
        props = self._props
        for t in range(self._next_alarm_t, len(props)):
            base = np.stack(props[t - self.history:t])
            mu = np.median(base, axis=0)
            mad = np.median(np.abs(base - mu), axis=0)
            sd = np.maximum(1.4826 * mad, 0.05 * mu) + 1e-3
            z = (props[t] - mu) / sd
            for pattern, types in SECURITY_PATTERNS.items():
                idx = _indices_for(tuple(types))
                self._alarm_cache.append(
                    {"window": t, "pattern": pattern,
                     "zscore": float(np.max(z[idx]))})
        self._next_alarm_t = max(self._next_alarm_t, len(props))
        return [dict(a) for a in self._alarm_cache
                if a["zscore"] > self.threshold]
