"""Distributed triad census: thin wrappers over the streaming engine.

The actual dispatch — shard_map over a device mesh, privatized 64-bin
tricode histograms + 2-bin intersection counters per device, one ``psum``
at the end (the paper's 64 hashed local census vectors mapped onto a pod's
memory hierarchy) — lives in :class:`repro.core.engine.CensusEngine`,
shared with the single-device driver.  What remains here is the public
distributed API:

* :func:`triad_census_distributed` — exact census of a prebuilt
  (monolithic) plan across every device of a mesh.
* :func:`triad_census_graph` — plan + count in one call; pass
  ``max_items`` to stream the plan as bounded chunks instead of one
  O(W) dispatch (see :mod:`repro.core.plan_stream`), with per-chunk
  uploads sharded over the mesh and partials accumulated on the host.

Work items travel as the planner's two packed int32 words per item
(``item_sp``/``item_pv``), halving the host→device transfer and the sharded
HBM footprint relative to the four legacy streams.  ``backend`` selects the
same per-shard paths as :func:`repro.core.census.triad_census`, including
``"pallas-fused"`` (the whole per-item pipeline in one kernel per shard).
"""

from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh

from repro.core.planner import CensusPlan
from repro.core.digraph import CompactDigraph


def default_mesh() -> Mesh:
    """Flat mesh over all local devices."""
    n = len(jax.devices())
    return jax.make_mesh((n,), ("devices",),
                         axis_types=(jax.sharding.AxisType.Auto,))


def triad_census_distributed(plan: CensusPlan, mesh: Mesh | None = None,
                             backend: str = "jnp") -> np.ndarray:
    """Exact 16-type census computed across all devices of ``mesh``."""
    from repro.core.engine import CensusEngine
    if mesh is None:
        mesh = default_mesh()
    return CensusEngine(mesh=mesh, backend=backend).run_plan(plan)


def triad_census_graph(g: CompactDigraph, mesh: Mesh | None = None,
                       backend: str = "jnp", orient: str = "none",
                       max_items: int | None = None,
                       progress=None,
                       emit: str | None = None) -> np.ndarray:
    """Convenience: plan + distribute + count in one call.

    ``max_items=None`` reproduces the historical one-dispatch schedule;
    an integer budget streams the plan in O(max_items) host memory.
    ``emit`` picks the work-item path (default ``"device"``: descriptor
    upload + in-kernel pair→item expansion; ``"host"``: packed-item
    upload) — bit-identical either way.
    """
    from repro.core.engine import CensusEngine
    if mesh is None:
        mesh = default_mesh()
    engine = CensusEngine(mesh=mesh, backend=backend)
    return engine.run(g, max_items=max_items, orient=orient,
                      progress=progress, emit=emit)
