"""Distributed triad census: shard_map over a device mesh.

The flat work plan is split into equal chunks across every device of the
mesh (all axes flattened); each device computes its privatized 64-bin
tricode histogram + 2-bin intersection counters, and a single ``psum``
combines them — the paper's 64 hashed local census vectors, mapped onto the
memory hierarchy of a pod: device-local partials in HBM/VMEM, one collective
at the end.

Work items travel as the planner's two packed int32 words per item
(``item_sp``/``item_pv``), halving the host→device transfer and the sharded
HBM footprint relative to the four legacy streams.  ``backend`` selects the
same per-shard paths as :func:`repro.core.census.triad_census`, including
``"pallas-fused"`` (the whole per-item pipeline in one kernel per shard).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import shard_map
from repro.core.census import BACKENDS, assemble_census, partials_fn
from repro.core.planner import CensusPlan, build_plan
from repro.core.digraph import CompactDigraph


def default_mesh() -> Mesh:
    """Flat mesh over all local devices."""
    n = len(jax.devices())
    return jax.make_mesh((n,), ("devices",),
                         axis_types=(jax.sharding.AxisType.Auto,))


@functools.partial(jax.jit,
                   static_argnames=("mesh", "search_iters", "backend"))
def _sharded_census(indptr, packed, pair_u, pair_v, pair_code,
                    item_sp, item_pv, mesh, search_iters, backend):
    axes = mesh.axis_names
    partials = partials_fn(backend, search_iters)

    def shard_fn(ip, pk, pu, pv, pc, wsp, wpv):
        hist64, inter = partials(ip, pk, pu, pv, pc, wsp, wpv)
        hist64 = jax.lax.psum(hist64, axes)
        inter = jax.lax.psum(inter, axes)
        return hist64, inter

    item_spec = P(axes)       # work items sharded over every mesh axis
    rep = P()                 # graph + pair arrays replicated
    fn = shard_map(
        shard_fn, mesh=mesh,
        in_specs=(rep, rep, rep, rep, rep, item_spec, item_spec),
        out_specs=(rep, rep),
        # pallas_call has no replication rule; keep the check on the
        # pure-XLA path where it still can catch a missing psum
        check_vma=(backend == "jnp"))
    return fn(indptr, packed, pair_u, pair_v, pair_code, item_sp, item_pv)


def triad_census_distributed(plan: CensusPlan, mesh: Mesh | None = None,
                             backend: str = "jnp") -> np.ndarray:
    """Exact 16-type census computed across all devices of ``mesh``."""
    if backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; one of {BACKENDS}")
    if mesh is None:
        mesh = default_mesh()
    ndev = int(np.prod(mesh.devices.shape))
    if plan.item_sp.shape[0] % ndev != 0:
        raise ValueError(
            f"plan padded to {plan.item_sp.shape[0]} items, not a "
            f"multiple of {ndev} devices; build with pad_to=num_devices")
    if plan.num_pairs == 0:
        n = plan.n
        out = np.zeros(16, dtype=np.int64)
        out[0] = n * (n - 1) * (n - 2) // 6
        return out
    sharding = NamedSharding(mesh, P(mesh.axis_names))
    rep = NamedSharding(mesh, P())
    dev = lambda a, s: jax.device_put(jnp.asarray(a), s)
    hist64, inter = _sharded_census(
        dev(plan.indptr, rep), dev(plan.packed, rep),
        dev(plan.pair_u, rep), dev(plan.pair_v, rep),
        dev(plan.pair_code, rep),
        dev(plan.item_sp, sharding), dev(plan.item_pv, sharding),
        mesh, plan.search_iters, backend)
    return assemble_census(plan, np.asarray(hist64), np.asarray(inter))


def triad_census_graph(g: CompactDigraph, mesh: Mesh | None = None,
                       backend: str = "jnp",
                       orient: str = "none") -> np.ndarray:
    """Convenience: plan + distribute + count in one call."""
    if mesh is None:
        mesh = default_mesh()
    ndev = int(np.prod(mesh.devices.shape))
    plan = build_plan(g, pad_to=ndev, orient=orient)
    return triad_census_distributed(plan, mesh=mesh, backend=backend)
