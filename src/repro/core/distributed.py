"""Distributed triad census: shard_map over a device mesh.

The flat work plan is split into equal chunks across every device of the
mesh (all axes flattened); each device computes its privatized 64-bin
tricode histogram + 2-bin intersection counters, and a single ``psum``
combines them — the paper's 64 hashed local census vectors, mapped onto the
memory hierarchy of a pod: device-local partials in HBM/VMEM, one collective
at the end.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax import shard_map

from repro.core.census import assemble_census, census_partials
from repro.core.planner import CensusPlan, build_plan
from repro.core.digraph import CompactDigraph


def default_mesh() -> Mesh:
    """Flat mesh over all local devices."""
    n = len(jax.devices())
    return jax.make_mesh((n,), ("devices",),
                         axis_types=(jax.sharding.AxisType.Auto,))


@functools.partial(jax.jit,
                   static_argnames=("mesh", "search_iters", "backend"))
def _sharded_census(indptr, packed, pair_u, pair_v, pair_code,
                    item_pair, item_slot, item_side, item_valid,
                    mesh, search_iters, backend):
    axes = mesh.axis_names
    histogram_fn = None
    if backend == "pallas":
        from repro.kernels import ops as kops
        histogram_fn = kops.tricode_histogram

    def shard_fn(ip, pk, pu, pv, pc, wpair, wslot, wside, wvalid):
        hist64, inter = census_partials(
            ip, pk, pu, pv, pc, wpair, wslot, wside, wvalid,
            search_iters, histogram_fn=histogram_fn)
        hist64 = jax.lax.psum(hist64, axes)
        inter = jax.lax.psum(inter, axes)
        return hist64, inter

    item_spec = P(axes)       # work items sharded over every mesh axis
    rep = P()                 # graph + pair arrays replicated
    fn = shard_map(
        shard_fn, mesh=mesh,
        in_specs=(rep, rep, rep, rep, rep,
                  item_spec, item_spec, item_spec, item_spec),
        out_specs=(rep, rep))
    return fn(indptr, packed, pair_u, pair_v, pair_code,
              item_pair, item_slot, item_side, item_valid)


def triad_census_distributed(plan: CensusPlan, mesh: Mesh | None = None,
                             backend: str = "jnp") -> np.ndarray:
    """Exact 16-type census computed across all devices of ``mesh``."""
    if mesh is None:
        mesh = default_mesh()
    ndev = int(np.prod(mesh.devices.shape))
    if plan.item_valid.shape[0] % ndev != 0:
        raise ValueError(
            f"plan padded to {plan.item_valid.shape[0]} items, not a "
            f"multiple of {ndev} devices; build with pad_to=num_devices")
    if plan.num_pairs == 0:
        n = plan.n
        out = np.zeros(16, dtype=np.int64)
        out[0] = n * (n - 1) * (n - 2) // 6
        return out
    sharding = NamedSharding(mesh, P(mesh.axis_names))
    rep = NamedSharding(mesh, P())
    dev = lambda a, s: jax.device_put(jnp.asarray(a), s)
    hist64, inter = _sharded_census(
        dev(plan.indptr, rep), dev(plan.packed, rep),
        dev(plan.pair_u, rep), dev(plan.pair_v, rep),
        dev(plan.pair_code, rep),
        dev(plan.item_pair, sharding), dev(plan.item_slot, sharding),
        dev(plan.item_side, sharding), dev(plan.item_valid, sharding),
        mesh, plan.search_iters, backend)
    return assemble_census(plan, np.asarray(hist64), np.asarray(inter))


def triad_census_graph(g: CompactDigraph, mesh: Mesh | None = None,
                       backend: str = "jnp") -> np.ndarray:
    """Convenience: plan + distribute + count in one call."""
    if mesh is None:
        mesh = default_mesh()
    ndev = int(np.prod(mesh.devices.shape))
    plan = build_plan(g, pad_to=ndev)
    return triad_census_distributed(plan, mesh=mesh, backend=backend)
