"""Distributed triad census: the public partition + mesh API.

Two distribution regimes, both ending in the paper's single merge of
per-processor private census vectors:

* **Replicated** (the default): every device holds the whole CSR and the
  flat work items are sharded across the mesh.  Right for graphs that fit
  one device's memory anyway — zero partitioning overhead, perfect item
  balance by construction.
* **Partitioned** (``partition=True`` / :func:`partition_graph`): the
  pair space is LPT-split into one private shard per device
  (:mod:`repro.core.partition`), each device holds only its shard's
  relabeled local subgraph — O(E_shard + halo) resident bytes instead of
  O(E) — and walks its own descriptor stream inside the compile-once
  collective step; the private histograms meet in one ``psum``.
  Bit-identical to the replicated and single-device paths for every
  backend, orient and emit mode.
* **2D partitioned** (``partition_2d=(P, V)`` /
  :func:`partition_graph_2d`): the mesh is read as ``pair_shards ×
  vertex_slices``.  The pair axis keeps the 1D LPT assignment; each pair
  shard's witness range is then split across ``V`` contiguous vertex
  slices, so tile ``(s, j)`` holds only the slice of each endpoint row
  whose neighbor ids fall in its vertex range — the *halo* (replicated
  adjacency entries) shrinks from the 1D level at ``P·V`` shards to the
  1D level at ``P`` shards, spread over ``V`` devices.  Per-tile item
  sub-ranges partition each pair's global item space exactly, so the
  merged census stays bit-identical to the 1D and reference paths.

What lives here is the public surface:

* :func:`partition_graph` / :class:`GraphPartition` /
  :class:`PartitionStats` / :func:`shard_report` — the partition layer,
  usable standalone (inspect balance and residency before committing to
  a mesh shape).
* :func:`default_mesh` — flat mesh over all (or the first ``k``) local
  devices.
* :func:`triad_census_distributed` — exact census of a prebuilt
  (monolithic, replicated) plan across a mesh.
* :func:`triad_census_graph` — plan + count in one call, streaming
  (``max_items``), emission (``emit``) and partitioning (``partition``)
  knobs included.

Dispatch lives in :class:`repro.core.engine.CensusEngine`, shared with
the single-device driver.
"""

from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh

from repro.core.digraph import CompactDigraph
from repro.core.partition import (
    GraphPartition, GraphPartition2D, LocalShard, PartitionStats,
    extract_shard, graph_bytes, lpt_assign, lpt_assign_heap,
    partition_graph, partition_graph_2d, replicated_graph_bytes,
    vertex_slices)
from repro.core.planner import CensusPlan

__all__ = [
    "GraphPartition", "GraphPartition2D", "LocalShard", "PartitionStats",
    "default_mesh", "extract_shard", "graph_bytes", "lpt_assign",
    "lpt_assign_heap", "partition_graph", "partition_graph_2d",
    "replicated_graph_bytes", "shard_report", "triad_census_distributed",
    "triad_census_graph", "vertex_slices",
]


def default_mesh(num_devices: int | None = None) -> Mesh:
    """Flat mesh over all local devices, or the first ``num_devices`` of
    them (sub-meshes are how the shard-count invariance suites sweep
    1/2/4/8 shards on one host)."""
    devs = jax.devices()
    if num_devices is None:
        n = len(devs)
        return jax.make_mesh((n,), ("devices",),
                             axis_types=(jax.sharding.AxisType.Auto,))
    k = int(num_devices)
    if not 1 <= k <= len(devs):
        raise ValueError(
            f"num_devices must be in [1, {len(devs)}], got {k}")
    return Mesh(np.asarray(devs[:k]), ("devices",))


def shard_report(part: GraphPartition | GraphPartition2D,
                 stats=None) -> str:
    """Human-readable per-shard balance + residency table of a
    :func:`partition_graph` or :func:`partition_graph_2d` result (2D
    partitions label each row with its ``(pair_shard, vertex_slice)``
    tile coordinate and add a resident-entry replication line).

    Pass the run's :class:`~repro.core.engine.EngineStats` as ``stats``
    to append a fault-tolerance section when anything went wrong:
    retried windows, producer watchdog restarts, retired devices whose
    queues failed over to the survivors, and checkpoint-resumed windows.
    """
    text = part.stats.report()
    if stats is None:
        return text
    fired = (getattr(stats, "retries", 0)
             or getattr(stats, "failovers", 0)
             or getattr(stats, "watchdog_fires", 0)
             or getattr(stats, "retired_devices", [])
             or getattr(stats, "resumed_windows", 0))
    if not fired:
        return text
    lines = ["", "fault tolerance:"]
    if stats.retired_devices:
        lines.append(f"  retired devices : {sorted(stats.retired_devices)}"
                     " (queues drained by survivors)")
    lines.append(f"  retries         : {stats.retries}")
    lines.append(f"  failovers       : {stats.failovers}")
    lines.append(f"  watchdog fires  : {stats.watchdog_fires}")
    if stats.resumed_windows:
        lines.append(f"  resumed windows : {stats.resumed_windows}"
                     " (skipped via checkpoint)")
    return text + "\n".join(lines)


def triad_census_distributed(plan: CensusPlan, mesh: Mesh | None = None,
                             backend: str = "jnp") -> np.ndarray:
    """Exact 16-type census computed across all devices of ``mesh``
    (replicated graph, sharded items — prebuilt plans carry global
    coordinates; partition from the graph via :func:`triad_census_graph`
    instead)."""
    from repro.core.engine import CensusEngine
    if mesh is None:
        mesh = default_mesh()
    return CensusEngine(mesh=mesh, backend=backend).run_plan(plan)


def triad_census_graph(g: CompactDigraph, mesh: Mesh | None = None,
                       backend: str = "jnp", orient: str = "none",
                       max_items: int | None = None,
                       progress=None,
                       emit: str | None = None,
                       partition: bool = False,
                       partition_2d: tuple[int, int] | None = None,
                       schedule: str = "async") -> np.ndarray:
    """Convenience: plan + distribute + count in one call.

    ``max_items=None`` reproduces the historical one-dispatch schedule;
    an integer budget streams the plan in O(max_items) host memory.
    ``emit`` picks the work-item path (default ``"device"``: descriptor
    upload + in-kernel pair→item expansion; ``"host"``: packed-item
    upload).  ``partition=True`` shards the GRAPH across the mesh — each
    device holds only its pair shard's local subgraph and walks its own
    stream (:mod:`repro.core.partition`); ``schedule`` then picks the
    execution discipline (``"async"``: private per-shard streams, no
    inter-shard barrier; ``"lockstep"``: the collective oracle).
    ``partition_2d=(P, V)`` upgrades the partitioned path to the 2D
    pair×vertex decomposition — ``P·V`` must equal the mesh's device
    count — sharding each pair shard's adjacency halo across ``V``
    vertex slices.  Bit-identical on every combination.
    """
    from repro.core.engine import CensusEngine
    if mesh is None:
        mesh = default_mesh()
    engine = CensusEngine(mesh=mesh, backend=backend,
                          partition=partition, partition_2d=partition_2d,
                          schedule=schedule)
    return engine.run(g, max_items=max_items, orient=orient,
                      progress=progress, emit=emit)
