"""Deterministic fault injection for the partitioned shard streams.

The async machinery (PR 6-8) proved the host int64 merge is
order-invariant and windows are independent, so any window can be
retried or re-routed to any device without changing the census.  This
module supplies the *adversary* for exercising that property: a seeded
:class:`FaultPlan` describing exactly which producer plan-generations,
host->device uploads, and device dispatches fail (and how), plus the
:class:`FaultInjector` runtime the engine threads the plan through.

Fault sites
-----------
``producer``
    the background plan-generation thread of one shard
    (:class:`~repro.core.plan_stream.ShardStreamPipeline` producer).
``upload``
    the ``device_put`` of a window's plan buffer onto its device.
``dispatch``
    the compiled ``_desc_megastep`` / ``_part_desc_step`` /
    ``_part_chunk_step`` call boundary (covers both the synchronous
    trace/launch and the asynchronous materialization of the result).

Fault kinds
-----------
``error``
    raise :class:`InjectedFault` (a transient failure; retried).
``delay``
    sleep ``seconds`` before proceeding (exercises the watchdog and
    slow-device paths without breaking anything).
``poison``
    corrupt the fetched result so landing-time validation must catch
    it and re-dispatch.

A fault with ``persistent=True`` at the ``upload``/``dispatch`` sites
models a *dead device*: every subsequent operation on that device
fails, forcing the engine to retire it and fail its queue over to the
survivors.  Persistence is keyed by device, so re-routed work succeeds
elsewhere.

All plans are deterministic: :meth:`FaultPlan.seeded` draws from
``numpy.random.default_rng(seed)`` and two runs with the same seed and
topology inject identically.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field

import numpy as np


class FaultError(RuntimeError):
    """Base class for failures raised by the fault-tolerance layer."""


class InjectedFault(FaultError):
    """A deliberately injected failure (transient unless the underlying
    :class:`Fault` is ``persistent``)."""

    def __init__(self, fault: "Fault", site: str, key: tuple):
        self.fault = fault
        self.site = site
        self.key = key
        super().__init__(
            f"injected {fault.kind} fault at {site} (shard={fault.shard}, "
            f"device={fault.device}, occurrence={fault.occurrence}, "
            f"persistent={fault.persistent})"
        )


SITES = ("producer", "upload", "dispatch")
KINDS = ("error", "delay", "poison")


@dataclass(frozen=True)
class Fault:
    """One planned failure.

    ``site``/``kind`` select where and how it fires; ``shard`` and/or
    ``device`` select which stream it hits (``None`` matches any);
    ``occurrence`` is the zero-based index among the matching events at
    that site (the 3rd dispatch on device 2, say).  ``persistent``
    turns an ``upload``/``dispatch`` error into a device retirement:
    the matched device fails this and every later operation.
    """

    site: str
    kind: str = "error"
    shard: int | None = None
    device: int | None = None
    occurrence: int = 0
    seconds: float = 0.05
    persistent: bool = False

    def __post_init__(self):
        if self.site not in SITES:
            raise ValueError(f"unknown fault site {self.site!r}; one of {SITES}")
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; one of {KINDS}")
        if self.persistent and self.site == "producer":
            raise ValueError("persistent faults model dead devices; "
                             "use site='upload' or 'dispatch'")


@dataclass
class FaultPlan:
    """An ordered collection of :class:`Fault` specs, shared by every
    component of one engine run via a single :class:`FaultInjector`."""

    faults: list = field(default_factory=list)
    seed: int | None = None

    @classmethod
    def seeded(cls, seed: int, num_shards: int, *, producer_errors: int = 1,
               dispatch_errors: int = 1, retire_devices: int = 0,
               delays: int = 0, poisons: int = 0,
               delay_seconds: float = 0.05) -> "FaultPlan":
        """Draw a deterministic plan: which shards/devices fail and on
        which occurrence is decided by ``default_rng(seed)``."""
        rng = np.random.default_rng(seed)
        faults = []
        for _ in range(producer_errors):
            faults.append(Fault("producer", "error",
                                shard=int(rng.integers(num_shards)),
                                occurrence=int(rng.integers(2))))
        for _ in range(dispatch_errors):
            faults.append(Fault("dispatch", "error",
                                device=int(rng.integers(num_shards)),
                                occurrence=int(rng.integers(2))))
        for _ in range(poisons):
            faults.append(Fault("dispatch", "poison",
                                device=int(rng.integers(num_shards)),
                                occurrence=int(rng.integers(2))))
        for _ in range(delays):
            faults.append(Fault("dispatch", "delay",
                                device=int(rng.integers(num_shards)),
                                occurrence=int(rng.integers(2)),
                                seconds=delay_seconds))
        # retire distinct devices, and never device 0 when there are
        # survivors to take the work (keeps the plan always completable)
        if retire_devices:
            lo = 1 if num_shards > 1 else 0
            pool = rng.permutation(np.arange(lo, num_shards))
            for d in pool[:retire_devices]:
                faults.append(Fault("dispatch", "error", device=int(d),
                                    occurrence=int(rng.integers(2)),
                                    persistent=True))
        return cls(faults=faults, seed=seed)

    def injector(self) -> "FaultInjector":
        return FaultInjector(self)


class FaultInjector:
    """Runtime for one engine run: counts matching events per
    ``(site, shard, device)`` stream and fires the planned faults.

    Thread-safe by construction for the engine's actual topology
    (producers hit only their own ``(site, shard)`` counter; the
    consumer thread owns all upload/dispatch counters), so no lock is
    needed on the hot path.
    """

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self._counts: dict = {}
        self._dead: set = set()
        self.fired: list = []

    def device_is_dead(self, device: int) -> bool:
        return device in self._dead

    def _matches(self, f: Fault, site: str, shard, device) -> bool:
        if f.site != site:
            return False
        if f.shard is not None and f.shard != shard:
            return False
        if f.device is not None and f.device != device:
            return False
        return True

    def fire(self, site: str, *, shard: int | None = None,
             device: int | None = None) -> None:
        """Record one event at ``site`` for the given stream and raise /
        sleep if a planned fault matches.  Call *before* the real work
        (producer plan-gen, upload, dispatch)."""
        if device is not None and device in self._dead:
            raise InjectedFault(
                Fault(site, "error", device=device, persistent=True),
                site, (site, shard, device))
        key = (site, shard, device)
        n = self._counts.get(key, 0)
        self._counts[key] = n + 1
        # every fault matching THIS event fires (two faults planned on
        # the same stream + occurrence must both take effect — e.g. a
        # transient error colliding with a device retirement); among
        # matched errors the persistent one wins the raise, so the
        # retirement is never shadowed by a transient
        err = None
        for f in self.plan.faults:
            if not self._matches(f, site, shard, device):
                continue
            if f.occurrence != n:
                continue
            self.fired.append((f, key))
            if f.kind == "delay":
                time.sleep(f.seconds)
            elif f.kind == "poison":
                # the caller checks take_poison() after fetching
                self._poison = key
            else:
                if f.persistent and device is not None:
                    self._dead.add(device)
                if err is None or (f.persistent and not err.persistent):
                    err = f
        if err is not None:
            raise InjectedFault(err, site, key)

    _poison: tuple | None = None

    def take_poison(self) -> bool:
        """True exactly once after a matching ``poison`` fault fired at
        the most recent :meth:`fire`; the caller corrupts the fetched
        result so landing-time validation must reject it."""
        if self._poison is not None:
            self._poison = None
            return True
        return False


def poison_result(hist: np.ndarray, inter: np.ndarray):
    """Corrupt a fetched (hist, inter) partial the way a flaky device
    would: negate the histogram lanes.  Landing-time validation rejects
    negative counts, forcing a re-dispatch."""
    return -hist - 1, inter


__all__ = [
    "Fault",
    "FaultError",
    "FaultInjector",
    "FaultPlan",
    "InjectedFault",
    "poison_result",
]
