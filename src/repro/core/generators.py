"""Scale-free directed graph generators for the paper's three workloads.

The paper evaluates on US patents (outdeg power-law exponent 3.126), Orkut
(2.127) and a .uk webgraph (1.516).  We re-synthesize statistically similar
graphs at configurable scale: bounded-Zipf out-degree sequences with either
uniform or preferential target attachment, plus a direction mix so all 16
triad types occur.
"""

from __future__ import annotations

import numpy as np

from repro.core.digraph import CompactDigraph, from_edges

#: The paper's workloads: (outdegree power-law exponent, mutual-edge rate).
PAPER_WORKLOADS = {
    "patents": {"exponent": 3.126, "mutual_p": 0.0},   # citations: acyclic-ish
    "orkut": {"exponent": 2.127, "mutual_p": 0.5},     # social: many mutual
    "webgraph": {"exponent": 1.516, "mutual_p": 0.25}, # hyperlinks
}


def powerlaw_outdegrees(n: int, exponent: float, avg_degree: float,
                        rng: np.random.Generator,
                        max_degree: int | None = None) -> np.ndarray:
    """Bounded discrete power-law sample scaled to the target average."""
    if max_degree is None:
        max_degree = max(4, int(np.sqrt(n) * 4))
    ks = np.arange(1, max_degree + 1, dtype=np.float64)
    pmf = ks ** (-exponent)
    pmf /= pmf.sum()
    deg = rng.choice(ks.astype(np.int64), size=n, p=pmf)
    # rescale to the requested average (keeps the tail shape)
    scale = avg_degree / max(deg.mean(), 1e-9)
    deg = np.maximum(0, np.round(deg * scale)).astype(np.int64)
    return np.minimum(deg, n - 1)


def scale_free_digraph(n: int, avg_degree: float, exponent: float,
                       mutual_p: float = 0.2, preferential: bool = True,
                       seed: int = 0) -> CompactDigraph:
    """Directed scale-free graph with a power-law outdegree distribution.

    Targets are sampled preferentially (proportional to 1 + indegree-weight
    approximated by a static Zipf weight) or uniformly. ``mutual_p`` is the
    probability that an edge gets a reciprocal partner, controlling the
    mutual-dyad density (social nets high, citation nets ~0).
    """
    rng = np.random.default_rng(seed)
    outdeg = powerlaw_outdegrees(n, exponent, avg_degree, rng)
    m = int(outdeg.sum())
    src = np.repeat(np.arange(n, dtype=np.int64), outdeg)
    if preferential:
        # static preferential weights ~ Zipf over a random permutation
        perm = rng.permutation(n)
        w = 1.0 / (1.0 + np.argsort(perm))
        w /= w.sum()
        dst = rng.choice(n, size=m, p=w)
    else:
        dst = rng.integers(0, n, size=m)
    # reciprocal edges
    flip = rng.random(m) < mutual_p
    rs, rd = dst[flip], src[flip]
    src = np.concatenate([src, rs])
    dst = np.concatenate([dst, rd])
    return from_edges(src, dst, n=n)


def paper_workload(name: str, n: int, avg_degree: float,
                   seed: int = 0) -> CompactDigraph:
    """Scaled-down analogue of one of the paper's three graphs."""
    cfg = PAPER_WORKLOADS[name]
    return scale_free_digraph(n=n, avg_degree=avg_degree,
                              exponent=cfg["exponent"],
                              mutual_p=cfg["mutual_p"], seed=seed)


def erdos_renyi_digraph(n: int, p: float, seed: int = 0) -> CompactDigraph:
    rng = np.random.default_rng(seed)
    a = rng.random((n, n)) < p
    np.fill_diagonal(a, False)
    src, dst = np.nonzero(a)
    return from_edges(src, dst, n=n)


def measured_exponent(g: CompactDigraph) -> float:
    """Crude MLE of the outdegree power-law exponent (for fig6 checks)."""
    out = np.zeros(g.n, dtype=np.int64)
    code = g.packed & 3
    nbr = g.packed >> 2
    rows = np.repeat(np.arange(g.n), g.degrees)
    np.add.at(out, rows, (code & 1).astype(np.int64))
    d = out[out >= 1].astype(np.float64)
    if d.size < 10:
        return float("nan")
    dmin = 1.0
    return 1.0 + d.size / np.log(d / dmin + 1e-12).sum()
