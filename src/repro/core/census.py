"""Vectorized triad census — the device half of the algorithm.

Each flat work item (pair p=(u,v), neighbor slot) is processed
independently: decode w and its direction code from the packed entry,
binary-search w in the *other* endpoint's sorted row (the TPU-native
replacement for the paper's pointer merge), classify the triad in situ from
the 2-bit codes, and accumulate a 64-bin tricode histogram with
``segment``-style reductions — no atomics, which is the structural version
of the paper's privatized census vectors.

Backends:

* ``jnp``          — pure XLA; the oracle for everything below.
* ``pallas``       — classification in XLA, the 64-bin histogram hot loop
                     in the Pallas :mod:`repro.kernels.tricode_hist` kernel.
* ``pallas-fused`` — the whole per-item pipeline (gather, binary search,
                     classification, histogram) in one Pallas kernel; the
                     per-item tricode array never materializes in HBM
                     (:mod:`repro.kernels.census_fused`).

Returned per device/shard: ``hist64`` (connected-triad tricode histogram)
and ``inter`` (2-bin count of N(u)∩N(v) elements split by pair mutuality),
from which the host assembles the exact 16-type census.

Dispatch lives in :class:`repro.core.engine.CensusEngine`, which runs these
partials either as one monolithic plan dispatch or as a stream of bounded
fixed-shape chunks accumulated on the host (the partials are integer sums,
so any chunking of the work items yields bit-identical censuses).
:func:`triad_census` below is the thin single-device wrapper.

Work items reach a dispatch in one of two forms: pre-packed item words
(:func:`census_partials` — host emission) or pair descriptors that the
device expands back into items itself (:func:`census_partials_desc`, via
:func:`expand_work_items` — device emission, no host-side item
materialization).  Both feed the same :func:`classify_items`, and every
item the host-side planner would have pruned is provably a zero
contribution of the classification masks, which is why the two forms are
bit-identical on every backend and orient mode.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.planner import CensusPlan
from repro.core.tricode import FOLD_64_TO_16

BACKENDS = ("jnp", "pallas", "pallas-fused")


def segment_searchsorted(keys, lo, hi, q, iters: int):
    """First index i in [lo, hi) with keys[i] >= q, per element (batched).

    ``iters`` must be >= ceil(log2(max segment length + 1)); it is a static
    plan property so the loop unrolls to a fixed depth.
    """
    size = keys.shape[0]
    def body(_, state):
        lo, hi = state
        mid = (lo + hi) >> 1
        km = keys[jnp.clip(mid, 0, size - 1)]
        go_right = km < q
        return jnp.where(go_right, mid + 1, lo), jnp.where(go_right, hi, mid)
    lo, hi = jax.lax.fori_loop(0, iters, body, (lo, hi), unroll=True)
    return lo


def classify_items(indptr, packed, pair_u, pair_v, pair_code,
                   item_pair, item_slot, item_side, item_valid,
                   search_iters: int):
    """Per-item triad classification. Returns (tricode, count_mask, inter_mask, is_mut).

    tricode is in [0, 64); count_mask marks items contributing a connected
    triad under the canonical-selection predicate; inter_mask marks items
    witnessing an element of N(u) ∩ N(v) on the pair's designated witness
    side (bit 2 of ``pair_code``; 0 unless the plan is degree-oriented).
    """
    nbr_ids = packed >> 2
    w_packed = packed[item_slot]
    w = w_packed >> 2
    c_side = w_packed & 3

    u = pair_u[item_pair]
    v = pair_v[item_pair]
    pc = pair_code[item_pair]
    c_uv = pc & 3
    inter_side = (pc >> 2) & 1

    other = jnp.where(item_side == 0, v, u)
    lo = indptr[other]
    hi = indptr[other + 1]
    pos = segment_searchsorted(nbr_ids, lo, hi, w, search_iters)
    hit = packed[jnp.clip(pos, 0, packed.shape[0] - 1)]
    found = (pos < hi) & ((hit >> 2) == w)
    c_other = jnp.where(found, hit & 3, 0)

    c_uw = jnp.where(item_side == 0, c_side, c_other)
    c_vw = jnp.where(item_side == 0, c_other, c_side)

    not_self = (w != u) & (w != v)
    dedup = ~(found & (item_side == 1))      # union duplicates count once
    canonical = (v < w) | ((u < w) & (w < v) & (c_uw == 0))
    count_mask = item_valid & not_self & dedup & canonical
    inter_mask = item_valid & not_self & found & (item_side == inter_side)

    tricode = c_uv * 16 + c_uw * 4 + c_vw
    return tricode, count_mask, inter_mask, c_uv == 3


def expand_work_items(indptr, pair_u, pair_v, desc_pair, desc_cum,
                      desc_within0, anchors, num_valid, idx,
                      desc_iters: int):
    """Map flat item indices back to ``(pair, slot, side, valid)`` from a
    per-pair descriptor window — the device-resident inverse of the host
    planner's ``emit_items``.

    ``desc_cum`` is the window-local cumulative-offset table (padded with
    :data:`repro.core.planner.DESC_CUM_PAD`, which is larger than any
    real index, so the lower-bound search can never land on padding).
    ``anchors`` pre-resolves each :data:`DESC_ANCHOR_STRIDE`-item span to
    its first descriptor, so the per-lane search covers at most
    ``stride + 1`` candidates (every descriptor spans >= 1 pre-prune
    item — 2D vertex-sliced tiles keep pairs with a single in-slice
    item, so the old ``stride/2 + 1`` bound under the global >= 2
    items-per-pair invariant no longer holds) and ``desc_iters`` is the
    constant :data:`repro.core.planner.DESC_SEARCH_ITERS` — extra
    iterations are harmless (the converged lower bound is a fixed point
    of the search body, and the result is clamped into the anchored
    range).
    ``num_valid`` is a traced scalar: lanes past it are padding and come
    out clamped to safe (pair 0, slot 0) coordinates.
    """
    from repro.core.planner import DESC_ANCHOR_STRIDE
    num_descs = desc_cum.shape[0]
    a = jnp.clip(idx // DESC_ANCHOR_STRIDE, 0, anchors.shape[0] - 1)
    lo_d = anchors[a]
    hi_d = jnp.minimum(lo_d + DESC_ANCHOR_STRIDE + 1, num_descs)
    d = segment_searchsorted(desc_cum, lo_d, hi_d, idx + 1,
                             desc_iters) - 1
    d = jnp.minimum(jnp.clip(d, 0, num_descs - 1), hi_d - 1)
    pair = desc_pair[d]
    within = desc_within0[d] + idx - desc_cum[d]
    u = pair_u[pair]
    v = pair_v[pair]
    row_u = indptr[u]
    deg_u = indptr[u + 1] - row_u
    side = (within >= deg_u).astype(jnp.int32)
    slot = jnp.where(side == 0, row_u + within, indptr[v] + within - deg_u)
    valid = idx < num_valid
    return (jnp.where(valid, pair, 0), jnp.where(valid, slot, 0),
            jnp.where(valid, side, 0), valid)


def prune_keep_mask(packed, pair_u, pair_v, pair_code,
                    item_pair, item_slot, item_side, item_valid,
                    orient: str, prune_self: bool):
    """Device-side mirror of the planner's plan-time pruning predicate
    (:func:`repro.core.planner.prune_items`): which expanded items a host
    plan would have shipped.  Pruned items already contribute zero to
    every census counter (their count/inter masks are provably false), so
    this mask only feeds the valid-item statistics — dropping it can never
    change a census."""
    w_ids = packed[item_slot] >> 2
    u_of = pair_u[item_pair]
    v_of = pair_v[item_pair]
    not_self = (w_ids != u_of) & (w_ids != v_of)
    if orient == "degree":
        inter_side = (pair_code[item_pair] >> 2) & 1
        can_count = jnp.where(item_side == 0, w_ids > v_of, w_ids > u_of)
        return item_valid & not_self & (
            (item_side == inter_side) | can_count)
    if prune_self:
        return item_valid & not_self
    return item_valid


def _partials_reduce(tricode, count_mask, inter_mask, is_mut,
                     histogram_fn=None, keep_mask=None):
    """Shared reduction tail: fold per-item classifications into the
    ``hist64`` histogram and the intersection counters (plus a valid-item
    count when ``keep_mask`` is given — the device-emission stats lane)."""
    if histogram_fn is None:
        hist64 = jnp.zeros(64, jnp.int32).at[
            jnp.where(count_mask, tricode, 0)
        ].add(count_mask.astype(jnp.int32))
    else:
        hist64 = histogram_fn(tricode, count_mask)
    lanes = [
        jnp.sum((inter_mask & ~is_mut).astype(jnp.int32)),
        jnp.sum((inter_mask & is_mut).astype(jnp.int32)),
    ]
    if keep_mask is not None:
        lanes.append(jnp.sum(keep_mask.astype(jnp.int32)))
    return hist64, jnp.stack(lanes)


def census_partials(indptr, packed, pair_u, pair_v, pair_code,
                    item_sp, item_pv, search_iters: int, histogram_fn=None):
    """Shard-local partials from packed work items: (hist64, inter2) int32."""
    item_slot = item_sp >> 1
    item_side = item_sp & 1
    item_pair = item_pv >> 1
    item_valid = (item_pv & 1) == 1
    tricode, count_mask, inter_mask, is_mut = classify_items(
        indptr, packed, pair_u, pair_v, pair_code,
        item_pair, item_slot, item_side, item_valid, search_iters)
    return _partials_reduce(tricode, count_mask, inter_mask, is_mut,
                            histogram_fn)


def census_partials_desc(indptr, packed, pair_u, pair_v, pair_code,
                         desc_pair, desc_cum, desc_within0, anchors,
                         num_valid, idx, search_iters: int,
                         desc_iters: int, orient: str, prune_self: bool,
                         histogram_fn=None):
    """Shard-local partials from *pair descriptors*: ``(hist64, inter3)``.

    The device expands each flat index in ``idx`` back to its work item
    (:func:`expand_work_items`) and classifies it in place — no host-side
    item materialization, no O(W) item upload.  ``inter3`` carries the two
    intersection counters plus the count of items the plan-time pruning
    predicate would have kept (:func:`prune_keep_mask`) so the engine's
    valid-item statistics stay comparable with host emission.
    """
    item_pair, item_slot, item_side, item_valid = expand_work_items(
        indptr, pair_u, pair_v, desc_pair, desc_cum, desc_within0,
        anchors, num_valid, idx, desc_iters)
    tricode, count_mask, inter_mask, is_mut = classify_items(
        indptr, packed, pair_u, pair_v, pair_code,
        item_pair, item_slot, item_side, item_valid, search_iters)
    keep = prune_keep_mask(packed, pair_u, pair_v, pair_code,
                           item_pair, item_slot, item_side, item_valid,
                           orient, prune_self)
    return _partials_reduce(tricode, count_mask, inter_mask, is_mut,
                            histogram_fn, keep_mask=keep)


def census_partials_desc_batch(indptr, packed, pair_u, pair_v, pair_code,
                               words_batch, idx, search_iters: int,
                               desc_iters: int, orient: str,
                               prune_self: bool, backend: str = "jnp"):
    """Multi-window megastep partials: ``lax.scan`` over K stacked
    descriptor windows inside ONE compiled dispatch.

    ``words_batch`` is a fixed-shape ``(K, words)`` int32 buffer of
    stacked :meth:`repro.core.planner.DescriptorWindow.device_words`
    rows — the megabatch a
    :class:`repro.core.plan_stream.WindowBatcher` coalesces so Python
    dispatch cost is paid once per K windows instead of once per window.
    Rows past the batch's real window count are all-zero padding: their
    leading ``num_preprune`` word is 0, every lane of
    :func:`expand_work_items` comes out invalid, and the masked window
    contributes EXACT ZEROS — which is why any (real, padding) split of
    the batch is bit-identical to K separate single-window dispatches.
    A ``lax.cond`` on that word additionally skips the padded rows'
    compute, so a partially-filled batch costs only its real windows.

    Returns the per-window partials STACKED, ``(hist64s (K, 64),
    inter3s (K, 3))`` int32, rather than device-reduced: the engine
    merges them on the host in int64 exactly like the single-window
    async path (jax's default int32 lattice cannot hold a K-window sum
    without x64 mode, and the tiny (K, 67) transfer keeps the
    per-window ``chunk_items`` stats lane intact).
    """
    from repro.core.planner import num_desc_anchors
    num_anchors = num_desc_anchors(idx.shape[0])
    num_descs = (words_batch.shape[1] - 1 - num_anchors) // 3
    partials = desc_partials_fn(backend, search_iters, desc_iters,
                                orient, prune_self)

    def one(words):
        nv = words[:1]
        dp = words[1:1 + num_descs]
        dc = words[1 + num_descs:1 + 2 * num_descs]
        dw = words[1 + 2 * num_descs:1 + 3 * num_descs]
        an = words[1 + 3 * num_descs:]
        return partials(indptr, packed, pair_u, pair_v, pair_code,
                        dp, dc, dw, an, nv, idx)

    def zeros(_words):
        return jnp.zeros(64, jnp.int32), jnp.zeros(3, jnp.int32)

    def body(carry, words):
        return carry, jax.lax.cond(words[0] > 0, one, zeros, words)

    _, (hist64s, inter3s) = jax.lax.scan(body, None, words_batch)
    return hist64s, inter3s


def assemble_counts(n: int, base_asym: int, base_mut: int,
                    hist64: np.ndarray, inter: np.ndarray) -> np.ndarray:
    """Combine (accumulated) device partials with the closed-form bases
    into the 16 counts — the plan-free core of :func:`assemble_census`,
    used by the streaming engine where the bases arrive as per-chunk
    additive shares."""
    hist64 = np.asarray(hist64, dtype=np.int64)
    inter = np.asarray(inter, dtype=np.int64)
    census = FOLD_64_TO_16 @ hist64
    census[1] += base_asym + int(inter[0])   # 012
    census[2] += base_mut + int(inter[1])    # 102
    total = n * (n - 1) * (n - 2) // 6
    census[0] = total - census[1:].sum()
    return census


def assemble_census(plan: CensusPlan, hist64: np.ndarray,
                    inter: np.ndarray) -> np.ndarray:
    """Combine device partials with host closed forms into the 16 counts."""
    return assemble_counts(plan.n, plan.base_asym, plan.base_mut,
                           hist64, inter)


def partials_fn(backend: str, search_iters: int):
    """Per-shard partials callable for ``backend`` — the single dispatch
    point shared by the single-device and distributed drivers.  The
    returned function maps the 7 device arrays (graph + pairs + packed
    items) to ``(hist64, inter)``."""
    if backend == "pallas-fused":
        from repro.kernels import ops as kops
        return functools.partial(kops.fused_census_partials,
                                 search_iters=search_iters)
    histogram_fn = None
    if backend == "pallas":
        from repro.kernels import ops as kops
        histogram_fn = kops.tricode_histogram
    return functools.partial(census_partials, search_iters=search_iters,
                             histogram_fn=histogram_fn)


def desc_partials_fn(backend: str, search_iters: int, desc_iters: int,
                     orient: str, prune_self: bool):
    """Descriptor-expansion counterpart of :func:`partials_fn`: maps the
    9 device arrays (graph + pairs + descriptor window + valid count) and
    the resident flat-index array to ``(hist64, inter3)``."""
    if backend == "pallas-fused":
        from repro.kernels import ops as kops
        return functools.partial(kops.fused_census_desc_partials,
                                 search_iters=search_iters,
                                 desc_iters=desc_iters, orient=orient,
                                 prune_self=prune_self)
    histogram_fn = None
    if backend == "pallas":
        from repro.kernels import ops as kops
        histogram_fn = kops.tricode_histogram
    return functools.partial(census_partials_desc,
                             search_iters=search_iters,
                             desc_iters=desc_iters, orient=orient,
                             prune_self=prune_self,
                             histogram_fn=histogram_fn)


def triad_census(plan: CensusPlan, backend: str = "jnp") -> np.ndarray:
    """Single-device exact 16-type triad census from a plan.

    Thin wrapper over :class:`repro.core.engine.CensusEngine` (mesh-less,
    monolithic).  ``backend='pallas'`` routes the histogram hot loop
    through the Pallas kernel; ``backend='pallas-fused'`` runs the whole
    per-item pipeline in one Pallas kernel (both interpret mode on CPU).
    """
    from repro.core.engine import CensusEngine
    return CensusEngine(mesh=None, backend=backend).run_plan(plan)
