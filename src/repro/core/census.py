"""Vectorized triad census — the device half of the algorithm.

Each flat work item (pair p=(u,v), neighbor slot) is processed
independently: decode w and its direction code from the packed entry,
binary-search w in the *other* endpoint's sorted row (the TPU-native
replacement for the paper's pointer merge), classify the triad in situ from
the 2-bit codes, and accumulate a 64-bin tricode histogram with
``segment``-style reductions — no atomics, which is the structural version
of the paper's privatized census vectors.

Backends:

* ``jnp``          — pure XLA; the oracle for everything below.
* ``pallas``       — classification in XLA, the 64-bin histogram hot loop
                     in the Pallas :mod:`repro.kernels.tricode_hist` kernel.
* ``pallas-fused`` — the whole per-item pipeline (gather, binary search,
                     classification, histogram) in one Pallas kernel; the
                     per-item tricode array never materializes in HBM
                     (:mod:`repro.kernels.census_fused`).

Returned per device/shard: ``hist64`` (connected-triad tricode histogram)
and ``inter`` (2-bin count of N(u)∩N(v) elements split by pair mutuality),
from which the host assembles the exact 16-type census.

Dispatch lives in :class:`repro.core.engine.CensusEngine`, which runs these
partials either as one monolithic plan dispatch or as a stream of bounded
fixed-shape chunks accumulated on the host (the partials are integer sums,
so any chunking of the work items yields bit-identical censuses).
:func:`triad_census` below is the thin single-device wrapper.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.planner import CensusPlan
from repro.core.tricode import FOLD_64_TO_16

BACKENDS = ("jnp", "pallas", "pallas-fused")


def segment_searchsorted(keys, lo, hi, q, iters: int):
    """First index i in [lo, hi) with keys[i] >= q, per element (batched).

    ``iters`` must be >= ceil(log2(max segment length + 1)); it is a static
    plan property so the loop unrolls to a fixed depth.
    """
    size = keys.shape[0]
    def body(_, state):
        lo, hi = state
        mid = (lo + hi) >> 1
        km = keys[jnp.clip(mid, 0, size - 1)]
        go_right = km < q
        return jnp.where(go_right, mid + 1, lo), jnp.where(go_right, hi, mid)
    lo, hi = jax.lax.fori_loop(0, iters, body, (lo, hi), unroll=True)
    return lo


def classify_items(indptr, packed, pair_u, pair_v, pair_code,
                   item_pair, item_slot, item_side, item_valid,
                   search_iters: int):
    """Per-item triad classification. Returns (tricode, count_mask, inter_mask, is_mut).

    tricode is in [0, 64); count_mask marks items contributing a connected
    triad under the canonical-selection predicate; inter_mask marks items
    witnessing an element of N(u) ∩ N(v) on the pair's designated witness
    side (bit 2 of ``pair_code``; 0 unless the plan is degree-oriented).
    """
    nbr_ids = packed >> 2
    w_packed = packed[item_slot]
    w = w_packed >> 2
    c_side = w_packed & 3

    u = pair_u[item_pair]
    v = pair_v[item_pair]
    pc = pair_code[item_pair]
    c_uv = pc & 3
    inter_side = (pc >> 2) & 1

    other = jnp.where(item_side == 0, v, u)
    lo = indptr[other]
    hi = indptr[other + 1]
    pos = segment_searchsorted(nbr_ids, lo, hi, w, search_iters)
    hit = packed[jnp.clip(pos, 0, packed.shape[0] - 1)]
    found = (pos < hi) & ((hit >> 2) == w)
    c_other = jnp.where(found, hit & 3, 0)

    c_uw = jnp.where(item_side == 0, c_side, c_other)
    c_vw = jnp.where(item_side == 0, c_other, c_side)

    not_self = (w != u) & (w != v)
    dedup = ~(found & (item_side == 1))      # union duplicates count once
    canonical = (v < w) | ((u < w) & (w < v) & (c_uw == 0))
    count_mask = item_valid & not_self & dedup & canonical
    inter_mask = item_valid & not_self & found & (item_side == inter_side)

    tricode = c_uv * 16 + c_uw * 4 + c_vw
    return tricode, count_mask, inter_mask, c_uv == 3


def census_partials(indptr, packed, pair_u, pair_v, pair_code,
                    item_sp, item_pv, search_iters: int, histogram_fn=None):
    """Shard-local partials from packed work items: (hist64, inter2) int32."""
    item_slot = item_sp >> 1
    item_side = item_sp & 1
    item_pair = item_pv >> 1
    item_valid = (item_pv & 1) == 1
    tricode, count_mask, inter_mask, is_mut = classify_items(
        indptr, packed, pair_u, pair_v, pair_code,
        item_pair, item_slot, item_side, item_valid, search_iters)
    if histogram_fn is None:
        hist64 = jnp.zeros(64, jnp.int32).at[
            jnp.where(count_mask, tricode, 0)
        ].add(count_mask.astype(jnp.int32))
    else:
        hist64 = histogram_fn(tricode, count_mask)
    inter = jnp.stack([
        jnp.sum((inter_mask & ~is_mut).astype(jnp.int32)),
        jnp.sum((inter_mask & is_mut).astype(jnp.int32)),
    ])
    return hist64, inter


def assemble_counts(n: int, base_asym: int, base_mut: int,
                    hist64: np.ndarray, inter: np.ndarray) -> np.ndarray:
    """Combine (accumulated) device partials with the closed-form bases
    into the 16 counts — the plan-free core of :func:`assemble_census`,
    used by the streaming engine where the bases arrive as per-chunk
    additive shares."""
    hist64 = np.asarray(hist64, dtype=np.int64)
    inter = np.asarray(inter, dtype=np.int64)
    census = FOLD_64_TO_16 @ hist64
    census[1] += base_asym + int(inter[0])   # 012
    census[2] += base_mut + int(inter[1])    # 102
    total = n * (n - 1) * (n - 2) // 6
    census[0] = total - census[1:].sum()
    return census


def assemble_census(plan: CensusPlan, hist64: np.ndarray,
                    inter: np.ndarray) -> np.ndarray:
    """Combine device partials with host closed forms into the 16 counts."""
    return assemble_counts(plan.n, plan.base_asym, plan.base_mut,
                           hist64, inter)


def partials_fn(backend: str, search_iters: int):
    """Per-shard partials callable for ``backend`` — the single dispatch
    point shared by the single-device and distributed drivers.  The
    returned function maps the 7 device arrays (graph + pairs + packed
    items) to ``(hist64, inter)``."""
    if backend == "pallas-fused":
        from repro.kernels import ops as kops
        return functools.partial(kops.fused_census_partials,
                                 search_iters=search_iters)
    histogram_fn = None
    if backend == "pallas":
        from repro.kernels import ops as kops
        histogram_fn = kops.tricode_histogram
    return functools.partial(census_partials, search_iters=search_iters,
                             histogram_fn=histogram_fn)


def triad_census(plan: CensusPlan, backend: str = "jnp") -> np.ndarray:
    """Single-device exact 16-type triad census from a plan.

    Thin wrapper over :class:`repro.core.engine.CensusEngine` (mesh-less,
    monolithic).  ``backend='pallas'`` routes the histogram hot loop
    through the Pallas kernel; ``backend='pallas-fused'`` runs the whole
    per-item pipeline in one Pallas kernel (both interpret mode on CPU).
    """
    from repro.core.engine import CensusEngine
    return CensusEngine(mesh=None, backend=backend).run_plan(plan)
