"""Degree-aware static graph partitioning — shard the graph, not the items.

The mesh path used to replicate the *entire* CSR and pair space on every
device and shard only the flat work items, so per-device memory stayed
O(graph) and the "distributed" engine could not outgrow one device's HBM.
This module makes partitioning a first-class layer, following the
per-processor subgraph + surrogate approach of Arifuzzaman et al.
("Distributed-Memory Parallel Algorithms for Counting and Listing
Triangles in Big Graphs") and the work-decomposition discipline of Tom &
Karypis ("A 2D Parallel Triangle Counting Algorithm for Distributed-Memory
Architectures"):

* :func:`lpt_assign` splits the canonical pair space into per-device
  shards by greedy LPT (longest-processing-time) over the exact per-pair
  post-prune item counts (:func:`repro.core.planner.postprune_pair_counts`)
  — the classic 4/3-approximate makespan bound, which on power-law pair
  costs with P >> shards lands far below the ≤ 1.2 max/mean target.
* :func:`extract_shard` cuts the minimal local subgraph a shard's pairs
  can touch: the CSR rows of the shard's pair *endpoints* (a pair (u, v)
  reads exactly rows N(u) and N(v) — gathers, slots, and the binary
  search all stay inside them) plus an **order-preserving vertex
  relabeling** over endpoints ∪ their neighbors (the halo).  Because the
  relabeling is monotone, every id comparison the census makes
  (`w != u`, `v < w`, row sortedness, the canonical-selection predicate)
  is preserved verbatim, so per-item classifications — and therefore the
  merged census — are **bit-identical** to the single-device path.
* :func:`partition_graph` composes the two into a :class:`GraphPartition`
  whose :class:`PartitionStats` report per-shard items, balance and
  resident graph bytes vs the replicated baseline.

Resident bytes per device shrink from O(E) to O(E_shard + halo): each
shard holds only its endpoints' rows (hub rows still replicate into every
shard that owns one of their pairs — the halo term), and the pair arrays
shard perfectly.  Device dispatch of the shards lives in
:class:`repro.core.engine.CensusEngine` (``partition=True``) and
``PartitionedEngineSession``; the public API is re-exported by
:mod:`repro.core.distributed`.
"""

from __future__ import annotations

import heapq
from dataclasses import dataclass

import numpy as np

from repro.core.digraph import CompactDigraph
from repro.core.planner import (
    PairSpace, make_pair_space, pair_space, postprune_pair_counts,
    range_postprune_pair_counts, range_preprune_pair_counts)
from repro.core.planner import _entry_keys as planner_entry_keys


def graph_bytes(indptr_len: int, entries: int, pairs: int) -> int:
    """Device bytes of the 5 int32 resident graph + pair arrays
    (indptr, packed, pair_u, pair_v, pair_code)."""
    return 4 * (int(indptr_len) + int(entries) + 3 * int(pairs))


def replicated_graph_bytes(space: PairSpace) -> int:
    """Per-device resident graph bytes of the replicated (un-partitioned)
    mesh path — the baseline the partitioner's byte reduction is measured
    against."""
    return graph_bytes(space.indptr.shape[0], space.packed.shape[0],
                      space.num_pairs)


def lpt_assign_heap(costs, num_shards: int) -> np.ndarray:
    """Exact greedy LPT over per-pair costs: (P,) shard owner per pair.

    Pairs are visited in descending cost (ties by pair id, so the
    assignment is deterministic) and each lands on the currently lightest
    shard — the longest-processing-time heuristic, whose makespan is
    within 4/3 − 1/(3m) of optimal.  One heap operation per pair makes
    this O(P log P) *Python-loop* work — fine up to ~10^5 pairs, far too
    slow for the 10M-pair spaces the streaming engine handles, which is
    why :func:`lpt_assign` (the production entry point) only delegates
    here for small inputs and the tests keep this as the oracle.
    """
    costs = np.asarray(costs, dtype=np.int64).ravel()
    owner = np.zeros(costs.shape[0], dtype=np.int64)
    if num_shards < 1:
        raise ValueError(f"num_shards must be >= 1, got {num_shards}")
    if num_shards == 1 or costs.size == 0:
        return owner
    order = np.argsort(-costs, kind="stable")
    loads = np.zeros(num_shards, dtype=np.int64)
    _greedy_assign(costs[order], order, owner, loads)
    return owner


def _greedy_assign(costs_desc: np.ndarray, ids: np.ndarray,
                   owner: np.ndarray, loads: np.ndarray) -> None:
    """Exact greedy LPT of ``ids`` (costs already descending) onto the
    running ``loads``, writing ``owner`` and ``loads`` in place."""
    heap = [(int(l), s) for s, l in enumerate(loads)]
    heapq.heapify(heap)
    for i, c in zip(ids.tolist(), costs_desc.tolist()):
        load, s = heapq.heappop(heap)
        owner[i] = s
        heapq.heappush(heap, (load + c, s))
    for load, s in heap:
        loads[s] = load


def _waterfill(levels: np.ndarray, total: int) -> np.ndarray:
    """Distribute ``total`` units over shards with ascending load
    ``levels`` so the lightest rise toward one common level (the exact
    continuous-LPT fill): returns the per-shard amounts, summing to
    ``total``, zero for shards already above the waterline."""
    ns = int(levels.shape[0])
    want = np.zeros(ns, dtype=np.int64)
    if ns == 1:
        want[0] = total
        return want
    pre = np.cumsum(levels)
    k = np.arange(1, ns, dtype=np.int64)
    # cost of raising the k lightest shards up to level ``levels[k]``
    need = k * levels[1:] - pre[:-1]
    m = int(np.searchsorted(need, total, side="right")) + 1
    q, r = divmod(int(total) + int(pre[m - 1]), m)
    want[:m] = q - levels[:m]
    want[:r] += 1
    return want


#: head size of the bucketed assigner that still runs the exact heap LPT
#: (a constant-bounded Python loop); the heavy hub pairs that dominate
#: makespan are all inside it
_LPT_EXACT_HEAD = 4096


def lpt_assign(costs, num_shards: int) -> np.ndarray:
    """Bucketed numpy LPT over per-pair costs: (P,) shard owner per pair.

    Semantics match :func:`lpt_assign_heap` (descending-cost greedy onto
    the lightest shard; deterministic), but the per-pair Python heap loop
    is replaced by vectorized passes so 10M-pair spaces assign in well
    under a second instead of tens of seconds:

    * pairs are grouped into log2 cost buckets and ordered by an O(P)
      int16 **radix** argsort of the bucket keys (numpy's ``stable`` kind
      radix-sorts small integer dtypes) — descending bucket, ascending
      pair id within a bucket, so the assignment stays deterministic;
    * the top ``_LPT_EXACT_HEAD`` pairs — the hub pairs that actually
      decide the makespan — still run the exact heap LPT (a bounded
      loop);
    * each remaining bucket slab is split by *cumulative cost* into
      contiguous segments sized by an exact waterfill against the
      current shard loads (lightest shards drink first), so the tail
      back-fills the load gaps just like the greedy loop, with per-slab
      boundary error at most one item's cost.

    Inputs small enough for the exact loop (``<= _LPT_EXACT_HEAD``)
    delegate to it outright, so small-graph assignments are *identical*
    to the historical heap results.
    """
    costs = np.asarray(costs, dtype=np.int64).ravel()
    if num_shards < 1:
        raise ValueError(f"num_shards must be >= 1, got {num_shards}")
    owner = np.zeros(costs.shape[0], dtype=np.int64)
    if num_shards == 1 or costs.size == 0:
        return owner
    if costs.size and int(costs.max()) == 0:
        # all-zero costs (empty pair space after pruning, fully-pruned
        # shard): every assignment has zero makespan — return the
        # all-zeros owner the heap oracle produces instead of feeding
        # degenerate buckets to the radix path
        return owner
    if costs.shape[0] <= _LPT_EXACT_HEAD:
        return lpt_assign_heap(costs, num_shards)
    ns = int(num_shards)
    # log2 cost buckets via the float32 exponent (exact for bucketing:
    # off-by-one rounding at a power-of-two boundary only moves a pair
    # between adjacent buckets, deterministically)
    expo = np.frexp(costs.astype(np.float32))[1].astype(np.int16)
    order = np.argsort(np.int16(64) - expo, kind="stable")
    loads = np.zeros(ns, dtype=np.int64)
    head = order[:_LPT_EXACT_HEAD]
    _greedy_assign(costs[head], head, owner, loads)
    tail = order[_LPT_EXACT_HEAD:]
    key_tail = expo[tail]
    cut = np.flatnonzero(np.diff(key_tail)) + 1
    bounds = np.concatenate([[0], cut, [tail.shape[0]]])
    for lo, hi in zip(bounds[:-1].tolist(), bounds[1:].tolist()):
        ids = tail[lo:hi]
        c = costs[ids]
        total = int(c.sum())
        if total == 0:
            # zero-cost pairs carry no work — spread them round-robin so
            # no shard concentrates their pair-array bytes
            owner[ids] = np.arange(ids.shape[0], dtype=np.int64) % ns
            continue
        rank = np.argsort(loads, kind="stable")        # light -> heavy
        targets = np.cumsum(_waterfill(loads[rank], total))
        seg = np.minimum(np.searchsorted(targets, np.cumsum(c),
                                         side="left"), ns - 1)
        owner[ids] = rank[seg]
        loads += np.bincount(rank[seg], weights=c,
                             minlength=ns).astype(np.int64)
    return owner


def vertex_slices(space: PairSpace, num_slices: int) -> np.ndarray:
    """Entry-mass-balanced vertex slice bounds, (V+1,) int64.

    Slice ``j`` owns witness ids ``[bounds[j], bounds[j+1])``.  Bounds
    are chosen so each slice receives ~equal CSR *entry mass* (how many
    adjacency entries point into it — exactly the halo bytes the 2D
    decomposition shards), via quantiles of the cumulative in-mass.
    Granularity is one vertex: a single hub id's mass cannot split, so a
    slice holding it may exceed the ideal share by that hub's in-degree.
    """
    if num_slices < 1:
        raise ValueError(f"num_slices must be >= 1, got {num_slices}")
    n = space.n
    bounds = np.zeros(num_slices + 1, dtype=np.int64)
    bounds[-1] = n
    if num_slices == 1 or n == 0:
        return bounds
    mass = np.bincount(space.nbr, minlength=n).astype(np.int64)
    cmass = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(mass, out=cmass[1:])
    total = int(cmass[-1])
    if total == 0:
        bounds[:] = np.round(
            np.linspace(0, n, num_slices + 1)).astype(np.int64)
        return bounds
    targets = (np.arange(1, num_slices, dtype=np.int64) * total
               ) // num_slices
    cuts = np.searchsorted(cmass, targets, side="left")
    bounds[1:-1] = np.minimum(np.maximum.accumulate(cuts), n)
    return bounds


def slice_pair_terms(space: PairSpace, vertex_bounds: np.ndarray
                     ) -> list[np.ndarray]:
    """Designated-slice split of ``space.pair_term``: V arrays of shape
    (P,) summing elementwise to the global terms.

    Each pair's full closed-form dyadic term is credited to the *first*
    vertex slice holding any of its pre-prune items (every pair has at
    least ``deg_u + deg_v >= 2`` items, so a designated slice always
    exists) and zeroed elsewhere — the tile that keeps the pair in that
    slice carries the term, so :func:`repro.core.planner.base_for_pairs`
    sums exactly across a shard's tiles.
    """
    bounds = np.asarray(vertex_bounds, dtype=np.int64).ravel()
    num_slices = bounds.shape[0] - 1
    if num_slices == 1:
        return [space.pair_term.copy()]
    pre = np.stack([range_preprune_pair_counts(
        space, int(bounds[j]), int(bounds[j + 1])) > 0
        for j in range(num_slices)])
    first = np.argmax(pre, axis=0) if space.num_pairs else np.zeros(
        0, dtype=np.int64)
    return [np.where(first == j, space.pair_term, 0)
            for j in range(num_slices)]


@dataclass(frozen=True)
class LocalShard:
    """One device's private slice of the census: the pairs it owns and the
    minimal relabeled subgraph those pairs can touch.

    ``verts`` is the relabeling table (local id -> global id, sorted
    ascending so the relabeling preserves every id comparison);
    ``graph``'s rows are the *full* global rows of the shard's pair
    endpoints (halo vertices — neighbors that are not endpoints — exist as
    empty rows, present only so ids resolve).  ``space`` is the shard's
    local pair space: the owned pairs in local coordinates, with the
    closed-form ``pair_term`` copied from the global space so per-shard
    bases stay additive to the global ones.
    """

    index: int
    pair_ids: np.ndarray       #: (P_s,) sorted global pair indices
    keys: np.ndarray           #: (P_s,) sorted global pair keys lo*n+hi
    verts: np.ndarray          #: (n_loc,) sorted global vertex ids
    graph: CompactDigraph      #: relabeled local CSR
    space: PairSpace           #: local pair space over ``graph``
    items: int                 #: post-prune work items owned
    vertex_range: tuple | None = None  #: (lo, hi) witness slice, 2D only

    @property
    def num_pairs(self) -> int:
        return int(self.pair_ids.shape[0])

    @property
    def resident_bytes(self) -> int:
        """Device bytes of this shard's resident graph + pair arrays."""
        return graph_bytes(self.graph.indptr.shape[0],
                           self.graph.packed.shape[0], self.num_pairs)


def extract_shard(space: PairSpace, pair_ids, index: int = 0,
                  costs: np.ndarray | None = None, *,
                  vertex_range: tuple | None = None,
                  pair_term: np.ndarray | None = None) -> LocalShard:
    """Extract the minimal local subgraph of a pair subset of ``space``.

    ``pair_ids`` (any order; sorted internally) index the global space's
    canonical pairs.  The local vertex id space is ``endpoints ∪ their
    neighbors`` sorted ascending — an order-preserving relabeling, which
    is the whole correctness argument: the census only ever *compares*
    vertex ids, so a monotone injection changes no per-item decision.
    ``costs`` (the global :func:`postprune_pair_counts`) avoids an
    O(P log m) recount per shard when the caller already has it.

    ``vertex_range=(lo, hi)`` is the **slice-aware variant** behind the
    2D decomposition: endpoint rows are restricted to their neighbor
    entries with ids in ``[lo, hi)`` (rows are sorted, so each restriction
    is one contiguous run), and pairs with *no* pre-prune item in the
    range are dropped, so pair-array bytes shard with the vertex axis
    too.  Restricting a sorted row to an id range keeps it sorted and —
    because every item's witness lies in the range — keeps the kernel's
    binary search of the co-endpoint row exact (``w ∈ sliced row ⟺
    w ∈ global row`` for in-range ``w``), so per-item decisions, and the
    union of the tiles' item spaces over a slicing of ``[0, n)``, are
    bit-identical to the unsliced shard.  When slicing, ``costs`` must be
    the matching :func:`range_postprune_pair_counts` (computed here when
    omitted), and ``pair_term`` may override the global per-pair base
    terms with a designated-slice split (:func:`slice_pair_terms`) so
    per-tile bases stay additive across the vertex axis.
    """
    ids = np.sort(np.asarray(pair_ids, dtype=np.int64).ravel())
    if ids.size and (ids[0] < 0 or ids[-1] >= space.num_pairs):
        raise ValueError(f"pair id outside [0, {space.num_pairs})")
    deg = space.deg.astype(np.int64)
    if vertex_range is None:
        if costs is None:
            costs = postprune_pair_counts(space)
        pu, pv = space.pair_u[ids], space.pair_v[ids]
        ends = (np.unique(np.concatenate([pu, pv])) if ids.size
                else np.zeros(0, dtype=np.int64))
        row_start = space.indptr[ends].astype(np.int64)
        row_deg = deg[ends]
    else:
        lo_v, hi_v = int(vertex_range[0]), int(vertex_range[1])
        if not 0 <= lo_v <= hi_v <= space.n:
            raise ValueError(
                f"vertex range [{lo_v}, {hi_v}) outside [0, {space.n}]")
        vertex_range = (lo_v, hi_v)
        if costs is None:
            costs = range_postprune_pair_counts(space, lo_v, hi_v)
        key = planner_entry_keys(space)
        n64 = int(space.n)

        def cnt(rows, a, b):
            return (np.searchsorted(key, rows * n64 + b)
                    - np.searchsorted(key, rows * n64 + a))

        pu = space.pair_u[ids].astype(np.int64)
        pv = space.pair_v[ids].astype(np.int64)
        # a pair with zero pre-prune items in the slice contributes
        # nothing here (its items live in other slices) — drop it so the
        # pair arrays shard along the vertex axis as well
        keep = (cnt(pu, lo_v, hi_v) + cnt(pv, lo_v, hi_v)) > 0
        ids = ids[keep]
        pu, pv = pu[keep], pv[keep]
        ends = (np.unique(np.concatenate([pu, pv])) if ids.size
                else np.zeros(0, dtype=np.int64))
        below = np.searchsorted(key, ends * n64 + lo_v) - space.indptr[ends]
        row_deg = cnt(ends, lo_v, hi_v).astype(np.int64)
        row_start = (space.indptr[ends] + below).astype(np.int64)
    keys = pu * space.n + pv
    items = int(costs[ids].sum()) if ids.size else 0

    total = int(row_deg.sum())
    loc_off = np.zeros(ends.shape[0] + 1, dtype=np.int64)
    np.cumsum(row_deg, out=loc_off[1:])
    # slots of the endpoints' (possibly range-restricted) rows, in
    # (endpoint asc, within-row asc) order — exactly local CSR order
    # after relabeling
    slot = (np.repeat(row_start - loc_off[:-1], row_deg)
            + np.arange(total, dtype=np.int64))
    rows_packed = space.packed[slot].astype(np.int64)
    nbrs = rows_packed >> 2

    verts = np.union1d(ends, nbrs)
    n_loc = int(verts.shape[0])
    ends_loc = np.searchsorted(verts, ends)
    deg_loc = np.zeros(n_loc, dtype=np.int64)
    deg_loc[ends_loc] = row_deg
    indptr_loc = np.zeros(n_loc + 1, dtype=np.int64)
    np.cumsum(deg_loc, out=indptr_loc[1:])
    nbr_loc = np.searchsorted(verts, nbrs)
    packed_loc = ((nbr_loc << 2) | (rows_packed & 3)).astype(np.int32)
    g_loc = CompactDigraph(
        n=n_loc, indptr=indptr_loc, packed=packed_loc,
        # row-side outgoing entries; arcs whose both endpoints are shard
        # endpoints appear from each side (informational only)
        num_arcs=int(((rows_packed & 1) != 0).sum()))

    term_src = (space.pair_term if pair_term is None
                else np.asarray(pair_term, dtype=np.int64).ravel())
    space_loc = make_pair_space(
        g_loc, np.searchsorted(verts, pu), np.searchsorted(verts, pv),
        space.pair_code[ids].copy(), orient=space.orient,
        prune_self=space.prune_self,
        pair_term=term_src[ids].copy())
    return LocalShard(index=index, pair_ids=ids, keys=keys, verts=verts,
                      graph=g_loc, space=space_loc, items=items,
                      vertex_range=vertex_range)


@dataclass(frozen=True)
class PartitionStats:
    """Balance + residency record of one :func:`partition_graph` call."""

    num_shards: int
    total_items: int
    shard_items: tuple         #: per-shard post-prune work items
    shard_pairs: tuple         #: per-shard owned pair counts
    shard_bytes: tuple         #: per-shard resident graph bytes
    replicated_bytes: int      #: per-device bytes of the replicated path
    mesh_shape: tuple | None = None  #: (pair_shards, vertex_slices); 2D only
    shard_entries: tuple = ()  #: per-shard resident packed CSR entries
    total_entries: int = 0     #: global packed CSR entries (halo denom)

    @property
    def entry_replication(self) -> float:
        """Halo blow-up: total resident CSR entry copies across shards /
        global entries (1.0 == no replication; the 2D vertex axis exists
        to pull this down)."""
        if not self.shard_entries or not self.total_entries:
            return 1.0
        return sum(self.shard_entries) / self.total_entries

    @property
    def max_over_mean(self) -> float:
        """Shard item imbalance (1.0 == perfect; target ≤ 1.2)."""
        if not self.shard_items or not self.total_items:
            return 1.0
        mean = self.total_items / self.num_shards
        return max(self.shard_items) / mean

    @property
    def max_shard_bytes(self) -> int:
        return max(self.shard_bytes) if self.shard_bytes else 0

    @property
    def byte_reduction(self) -> float:
        """Replicated / max-per-shard resident graph bytes (the ≥ 2x
        acceptance metric)."""
        return self.replicated_bytes / max(self.max_shard_bytes, 1)

    def report(self) -> str:
        """Human-readable shard table + balance/residency summary; tiles
        of a 2D partition are labeled by their (pair shard, vertex slice)
        mesh coordinates."""
        two_d = self.mesh_shape is not None
        head = f"{'tile':>7}" if two_d else f"{'shard':>5}"
        lines = [f"{head} {'pairs':>9} {'items':>11} {'graph_bytes':>12}"]
        for s in range(self.num_shards):
            label = (f"{s // self.mesh_shape[1]:>3},{s % self.mesh_shape[1]}"
                     if two_d else f"{s:>5}")
            lines.append(f"{label:>7} {self.shard_pairs[s]:>9} "
                         f"{self.shard_items[s]:>11} "
                         f"{self.shard_bytes[s]:>12}"
                         if two_d else
                         f"{label} {self.shard_pairs[s]:>9} "
                         f"{self.shard_items[s]:>11} "
                         f"{self.shard_bytes[s]:>12}")
        if two_d:
            lines.append(f"mesh={self.mesh_shape[0]}x{self.mesh_shape[1]} "
                         f"(pair shards x vertex slices)")
        if self.shard_entries and self.total_entries:
            lines.append(
                f"halo: resident entries={sum(self.shard_entries)} "
                f"global={self.total_entries} "
                f"(replication {self.entry_replication:.2f}x)")
        lines.append(
            f"items max/mean={self.max_over_mean:.3f} "
            f"resident_bytes max={self.max_shard_bytes} "
            f"replicated={self.replicated_bytes} "
            f"({self.byte_reduction:.2f}x reduction)")
        return "\n".join(lines)


@dataclass(frozen=True)
class GraphPartition:
    """A graph statically partitioned into per-device local shards."""

    space: PairSpace           #: the global pair space
    shards: list               #: list[LocalShard], one per device
    owner: np.ndarray          #: (P,) shard owning each global pair
    stats: PartitionStats

    @property
    def num_shards(self) -> int:
        return len(self.shards)


def partition_graph(g: CompactDigraph | None = None, num_shards: int = 1,
                    orient: str = "none", prune_self: bool = True, *,
                    space: PairSpace | None = None,
                    owner: np.ndarray | None = None,
                    costs: np.ndarray | None = None) -> GraphPartition:
    """Partition a graph's census work into ``num_shards`` private slices.

    Greedy LPT over the exact per-pair post-prune item counts, then
    per-shard minimal-subgraph extraction (:func:`extract_shard`).  Pass
    ``space`` to reuse an existing pair decomposition (``g`` is then
    ignored); ``orient``/``prune_self`` match
    :func:`repro.core.planner.build_plan`.  ``owner`` overrides the LPT
    with an explicit (P,) pair→shard assignment — the hook the skewed
    -schedule tests and benchmarks use to build deliberately imbalanced
    partitions (the census is exact for ANY assignment; only balance
    changes).  ``costs`` supplies a precomputed (P,)
    :func:`postprune_pair_counts` of ``space`` — the hook a maintained
    :class:`~repro.core.pair_index.PairSpaceIndex` uses to skip the
    O(P log m) recount on warm repartitions.
    """
    if space is None:
        if g is None:
            raise ValueError("need a graph or a prebuilt pair space")
        space = pair_space(g, orient=orient, prune_self=prune_self)
    if costs is None:
        costs = postprune_pair_counts(space)
    else:
        costs = np.asarray(costs, dtype=np.int64).ravel()
        if costs.shape[0] != space.num_pairs:
            raise ValueError(
                f"costs has {costs.shape[0]} entries for "
                f"{space.num_pairs} pairs")
    if owner is None:
        owner = lpt_assign(costs, num_shards)
    else:
        owner = np.asarray(owner, dtype=np.int64).ravel()
        if owner.shape[0] != space.num_pairs:
            raise ValueError(
                f"owner has {owner.shape[0]} entries for "
                f"{space.num_pairs} pairs")
        if owner.size and (owner.min() < 0 or owner.max() >= num_shards):
            raise ValueError(f"owner shard outside [0, {num_shards})")
    shards = [extract_shard(space, np.nonzero(owner == s)[0], index=s,
                            costs=costs)
              for s in range(num_shards)]
    stats = PartitionStats(
        num_shards=num_shards, total_items=int(costs.sum()),
        shard_items=tuple(sh.items for sh in shards),
        shard_pairs=tuple(sh.num_pairs for sh in shards),
        shard_bytes=tuple(sh.resident_bytes for sh in shards),
        replicated_bytes=replicated_graph_bytes(space),
        shard_entries=tuple(sh.graph.packed.shape[0] for sh in shards),
        total_entries=int(space.packed.shape[0]))
    return GraphPartition(space=space, shards=shards, owner=owner,
                          stats=stats)


@dataclass(frozen=True)
class GraphPartition2D:
    """A graph partitioned over a ``(pair_shards, vertex_slices)`` mesh.

    ``shards`` is the **flat** tile list — tile ``(s, j)`` (pair shard
    ``s``, vertex slice ``j``) sits at index ``s * V + j`` — so every
    consumer of the 1D partition's shard list (``ShardSchedule``,
    ``stacked_device_arrays``, the async/lock-step/megastep dispatch
    paths) runs unmodified over the 2D tile set; only ownership
    bookkeeping (one pair shard owns a pair, its V tiles split the
    pair's witness range) knows about the second axis.
    """

    space: PairSpace           #: the global pair space
    mesh_shape: tuple          #: (P, V) = (pair shards, vertex slices)
    vertex_bounds: np.ndarray  #: (V+1,) slice boundaries over [0, n)
    shards: list               #: list[LocalShard], P*V tiles, flat s*V+j
    owner: np.ndarray          #: (P,) pair shard owning each global pair
    stats: PartitionStats

    @property
    def num_shards(self) -> int:
        return len(self.shards)

    @property
    def pair_shards(self) -> int:
        return int(self.mesh_shape[0])

    @property
    def num_vertex_slices(self) -> int:
        return int(self.mesh_shape[1])

    def tile(self, shard: int, vslice: int) -> LocalShard:
        """The tile of pair shard ``shard`` × vertex slice ``vslice``."""
        return self.shards[shard * self.num_vertex_slices + vslice]


def partition_graph_2d(g: CompactDigraph | None = None,
                       mesh_shape: tuple = (1, 1),
                       orient: str = "none", prune_self: bool = True, *,
                       space: PairSpace | None = None,
                       owner: np.ndarray | None = None,
                       vertex_bounds: np.ndarray | None = None
                       ) -> GraphPartition2D:
    """Partition census work over a ``(pair_shards, vertex_slices)`` mesh.

    The pair axis reuses the 1D machinery verbatim: greedy LPT over the
    exact global post-prune costs assigns each pair one owner shard.  The
    vertex axis then splits every shard's *item space*: tile ``(s, j)``
    extracts shard ``s``'s pairs restricted to witness ids in slice
    ``j``'s range (:func:`extract_shard` with ``vertex_range``), so hub
    halo rows — which the 1D split replicates into every shard owning one
    of their pairs — are themselves sliced ``V`` ways.  Per-pair dyadic
    base terms are credited to one designated tile per pair
    (:func:`slice_pair_terms`) so per-tile bases stay additive.  ``owner``
    overrides the LPT with an explicit (P,) pair→shard assignment and
    ``vertex_bounds`` overrides the entry-mass-balanced slice boundaries
    (:func:`vertex_slices`); the census is exact for any choice of both —
    only balance and residency change.
    """
    num_pair_shards, num_slices = int(mesh_shape[0]), int(mesh_shape[1])
    if num_pair_shards < 1 or num_slices < 1:
        raise ValueError(f"mesh_shape must be >= (1, 1), got {mesh_shape}")
    if space is None:
        if g is None:
            raise ValueError("need a graph or a prebuilt pair space")
        space = pair_space(g, orient=orient, prune_self=prune_self)
    costs = postprune_pair_counts(space)
    if owner is None:
        owner = lpt_assign(costs, num_pair_shards)
    else:
        owner = np.asarray(owner, dtype=np.int64).ravel()
        if owner.shape[0] != space.num_pairs:
            raise ValueError(
                f"owner has {owner.shape[0]} entries for "
                f"{space.num_pairs} pairs")
        if owner.size and (owner.min() < 0
                           or owner.max() >= num_pair_shards):
            raise ValueError(
                f"owner shard outside [0, {num_pair_shards})")
    if vertex_bounds is None:
        vertex_bounds = vertex_slices(space, num_slices)
    else:
        vertex_bounds = np.asarray(vertex_bounds, dtype=np.int64).ravel()
        if (vertex_bounds.shape[0] != num_slices + 1
                or vertex_bounds[0] != 0 or vertex_bounds[-1] != space.n
                or (np.diff(vertex_bounds) < 0).any()):
            raise ValueError(
                f"vertex_bounds must be a monotone ({num_slices + 1},) "
                f"cover of [0, {space.n}]")
    terms = slice_pair_terms(space, vertex_bounds)
    slice_costs = [range_postprune_pair_counts(
        space, int(vertex_bounds[j]), int(vertex_bounds[j + 1]))
        for j in range(num_slices)]
    tiles = []
    for s in range(num_pair_shards):
        sids = np.nonzero(owner == s)[0]
        for j in range(num_slices):
            tiles.append(extract_shard(
                space, sids, index=s * num_slices + j,
                costs=slice_costs[j],
                vertex_range=(int(vertex_bounds[j]),
                              int(vertex_bounds[j + 1])),
                pair_term=terms[j]))
    stats = PartitionStats(
        num_shards=len(tiles), total_items=int(costs.sum()),
        shard_items=tuple(t.items for t in tiles),
        shard_pairs=tuple(t.num_pairs for t in tiles),
        shard_bytes=tuple(t.resident_bytes for t in tiles),
        replicated_bytes=replicated_graph_bytes(space),
        mesh_shape=(num_pair_shards, num_slices),
        shard_entries=tuple(t.graph.packed.shape[0] for t in tiles),
        total_entries=int(space.packed.shape[0]))
    return GraphPartition2D(
        space=space, mesh_shape=(num_pair_shards, num_slices),
        vertex_bounds=vertex_bounds, shards=tiles, owner=owner,
        stats=stats)


def stacked_device_arrays(shards) -> tuple[np.ndarray, ...]:
    """The per-shard graph + pair arrays stacked to (num_shards, ·) int32
    — the *sharded* inputs of the partitioned collective step (each device
    receives exactly its own row).

    Rows are padded to common lengths so they stack: ``indptr`` with its
    own final value (phantom empty rows past ``n_loc``), ``packed`` and
    the pair arrays with zeros (inert — no live row or descriptor ever
    points at them, and invalid lanes clamp to pair/slot 0, which the
    padding keeps in-bounds).
    """
    li = max(max(sh.graph.indptr.shape[0] for sh in shards), 2)
    le = max(max(sh.graph.packed.shape[0] for sh in shards), 1)
    lp = max(max(sh.num_pairs for sh in shards), 1)
    ns = len(shards)
    indptr = np.zeros((ns, li), dtype=np.int32)
    packed = np.zeros((ns, le), dtype=np.int32)
    pu = np.zeros((ns, lp), dtype=np.int32)
    pv = np.zeros((ns, lp), dtype=np.int32)
    pc = np.zeros((ns, lp), dtype=np.int32)
    for s, sh in enumerate(shards):
        ip = sh.graph.indptr
        indptr[s, :ip.shape[0]] = ip
        indptr[s, ip.shape[0]:] = ip[-1]
        packed[s, :sh.graph.packed.shape[0]] = sh.graph.packed
        sp = sh.space
        pu[s, :sh.num_pairs] = sp.pair_u
        pv[s, :sh.num_pairs] = sp.pair_v
        pc[s, :sh.num_pairs] = sp.pair_code
    return indptr, packed, pu, pv, pc
