"""Degree-aware static graph partitioning — shard the graph, not the items.

The mesh path used to replicate the *entire* CSR and pair space on every
device and shard only the flat work items, so per-device memory stayed
O(graph) and the "distributed" engine could not outgrow one device's HBM.
This module makes partitioning a first-class layer, following the
per-processor subgraph + surrogate approach of Arifuzzaman et al.
("Distributed-Memory Parallel Algorithms for Counting and Listing
Triangles in Big Graphs") and the work-decomposition discipline of Tom &
Karypis ("A 2D Parallel Triangle Counting Algorithm for Distributed-Memory
Architectures"):

* :func:`lpt_assign` splits the canonical pair space into per-device
  shards by greedy LPT (longest-processing-time) over the exact per-pair
  post-prune item counts (:func:`repro.core.planner.postprune_pair_counts`)
  — the classic 4/3-approximate makespan bound, which on power-law pair
  costs with P >> shards lands far below the ≤ 1.2 max/mean target.
* :func:`extract_shard` cuts the minimal local subgraph a shard's pairs
  can touch: the CSR rows of the shard's pair *endpoints* (a pair (u, v)
  reads exactly rows N(u) and N(v) — gathers, slots, and the binary
  search all stay inside them) plus an **order-preserving vertex
  relabeling** over endpoints ∪ their neighbors (the halo).  Because the
  relabeling is monotone, every id comparison the census makes
  (`w != u`, `v < w`, row sortedness, the canonical-selection predicate)
  is preserved verbatim, so per-item classifications — and therefore the
  merged census — are **bit-identical** to the single-device path.
* :func:`partition_graph` composes the two into a :class:`GraphPartition`
  whose :class:`PartitionStats` report per-shard items, balance and
  resident graph bytes vs the replicated baseline.

Resident bytes per device shrink from O(E) to O(E_shard + halo): each
shard holds only its endpoints' rows (hub rows still replicate into every
shard that owns one of their pairs — the halo term), and the pair arrays
shard perfectly.  Device dispatch of the shards lives in
:class:`repro.core.engine.CensusEngine` (``partition=True``) and
``PartitionedEngineSession``; the public API is re-exported by
:mod:`repro.core.distributed`.
"""

from __future__ import annotations

import heapq
from dataclasses import dataclass

import numpy as np

from repro.core.digraph import CompactDigraph
from repro.core.planner import (
    PairSpace, make_pair_space, pair_space, postprune_pair_counts)


def graph_bytes(indptr_len: int, entries: int, pairs: int) -> int:
    """Device bytes of the 5 int32 resident graph + pair arrays
    (indptr, packed, pair_u, pair_v, pair_code)."""
    return 4 * (int(indptr_len) + int(entries) + 3 * int(pairs))


def replicated_graph_bytes(space: PairSpace) -> int:
    """Per-device resident graph bytes of the replicated (un-partitioned)
    mesh path — the baseline the partitioner's byte reduction is measured
    against."""
    return graph_bytes(space.indptr.shape[0], space.packed.shape[0],
                      space.num_pairs)


def lpt_assign(costs, num_shards: int) -> np.ndarray:
    """Greedy LPT over per-pair costs: (P,) shard owner per pair.

    Pairs are visited in descending cost (ties by pair id, so the
    assignment is deterministic) and each lands on the currently lightest
    shard — the longest-processing-time heuristic, whose makespan is
    within 4/3 − 1/(3m) of optimal.  Hub pairs therefore scatter across
    shards while the cheap tail back-fills the load gaps.
    """
    costs = np.asarray(costs, dtype=np.int64).ravel()
    owner = np.zeros(costs.shape[0], dtype=np.int64)
    if num_shards < 1:
        raise ValueError(f"num_shards must be >= 1, got {num_shards}")
    if num_shards == 1 or costs.size == 0:
        return owner
    order = np.argsort(-costs, kind="stable")
    heap = [(0, s) for s in range(num_shards)]   # (load, shard), pre-heaped
    for i in order.tolist():
        load, s = heapq.heappop(heap)
        owner[i] = s
        heapq.heappush(heap, (load + int(costs[i]), s))
    return owner


@dataclass(frozen=True)
class LocalShard:
    """One device's private slice of the census: the pairs it owns and the
    minimal relabeled subgraph those pairs can touch.

    ``verts`` is the relabeling table (local id -> global id, sorted
    ascending so the relabeling preserves every id comparison);
    ``graph``'s rows are the *full* global rows of the shard's pair
    endpoints (halo vertices — neighbors that are not endpoints — exist as
    empty rows, present only so ids resolve).  ``space`` is the shard's
    local pair space: the owned pairs in local coordinates, with the
    closed-form ``pair_term`` copied from the global space so per-shard
    bases stay additive to the global ones.
    """

    index: int
    pair_ids: np.ndarray       #: (P_s,) sorted global pair indices
    keys: np.ndarray           #: (P_s,) sorted global pair keys lo*n+hi
    verts: np.ndarray          #: (n_loc,) sorted global vertex ids
    graph: CompactDigraph      #: relabeled local CSR
    space: PairSpace           #: local pair space over ``graph``
    items: int                 #: post-prune work items owned

    @property
    def num_pairs(self) -> int:
        return int(self.pair_ids.shape[0])

    @property
    def resident_bytes(self) -> int:
        """Device bytes of this shard's resident graph + pair arrays."""
        return graph_bytes(self.graph.indptr.shape[0],
                           self.graph.packed.shape[0], self.num_pairs)


def extract_shard(space: PairSpace, pair_ids, index: int = 0,
                  costs: np.ndarray | None = None) -> LocalShard:
    """Extract the minimal local subgraph of a pair subset of ``space``.

    ``pair_ids`` (any order; sorted internally) index the global space's
    canonical pairs.  The local vertex id space is ``endpoints ∪ their
    neighbors`` sorted ascending — an order-preserving relabeling, which
    is the whole correctness argument: the census only ever *compares*
    vertex ids, so a monotone injection changes no per-item decision.
    ``costs`` (the global :func:`postprune_pair_counts`) avoids an
    O(P log m) recount per shard when the caller already has it.
    """
    ids = np.sort(np.asarray(pair_ids, dtype=np.int64).ravel())
    if ids.size and (ids[0] < 0 or ids[-1] >= space.num_pairs):
        raise ValueError(f"pair id outside [0, {space.num_pairs})")
    pu, pv = space.pair_u[ids], space.pair_v[ids]
    keys = pu * space.n + pv
    if costs is None:
        costs = postprune_pair_counts(space)
    items = int(costs[ids].sum()) if ids.size else 0

    deg = space.deg.astype(np.int64)
    ends = (np.unique(np.concatenate([pu, pv])) if ids.size
            else np.zeros(0, dtype=np.int64))
    row_deg = deg[ends]
    total = int(row_deg.sum())
    loc_off = np.zeros(ends.shape[0] + 1, dtype=np.int64)
    np.cumsum(row_deg, out=loc_off[1:])
    # slots of the endpoints' rows, in (endpoint asc, within-row asc)
    # order — exactly local CSR order after relabeling
    slot = (np.repeat(space.indptr[ends] - loc_off[:-1], row_deg)
            + np.arange(total, dtype=np.int64))
    rows_packed = space.packed[slot].astype(np.int64)
    nbrs = rows_packed >> 2

    verts = np.union1d(ends, nbrs)
    n_loc = int(verts.shape[0])
    ends_loc = np.searchsorted(verts, ends)
    deg_loc = np.zeros(n_loc, dtype=np.int64)
    deg_loc[ends_loc] = row_deg
    indptr_loc = np.zeros(n_loc + 1, dtype=np.int64)
    np.cumsum(deg_loc, out=indptr_loc[1:])
    nbr_loc = np.searchsorted(verts, nbrs)
    packed_loc = ((nbr_loc << 2) | (rows_packed & 3)).astype(np.int32)
    g_loc = CompactDigraph(
        n=n_loc, indptr=indptr_loc, packed=packed_loc,
        # row-side outgoing entries; arcs whose both endpoints are shard
        # endpoints appear from each side (informational only)
        num_arcs=int(((rows_packed & 1) != 0).sum()))

    space_loc = make_pair_space(
        g_loc, np.searchsorted(verts, pu), np.searchsorted(verts, pv),
        space.pair_code[ids].copy(), orient=space.orient,
        prune_self=space.prune_self,
        pair_term=space.pair_term[ids].copy())
    return LocalShard(index=index, pair_ids=ids, keys=keys, verts=verts,
                      graph=g_loc, space=space_loc, items=items)


@dataclass(frozen=True)
class PartitionStats:
    """Balance + residency record of one :func:`partition_graph` call."""

    num_shards: int
    total_items: int
    shard_items: tuple         #: per-shard post-prune work items
    shard_pairs: tuple         #: per-shard owned pair counts
    shard_bytes: tuple         #: per-shard resident graph bytes
    replicated_bytes: int      #: per-device bytes of the replicated path

    @property
    def max_over_mean(self) -> float:
        """Shard item imbalance (1.0 == perfect; target ≤ 1.2)."""
        if not self.shard_items or not self.total_items:
            return 1.0
        mean = self.total_items / self.num_shards
        return max(self.shard_items) / mean

    @property
    def max_shard_bytes(self) -> int:
        return max(self.shard_bytes) if self.shard_bytes else 0

    @property
    def byte_reduction(self) -> float:
        """Replicated / max-per-shard resident graph bytes (the ≥ 2x
        acceptance metric)."""
        return self.replicated_bytes / max(self.max_shard_bytes, 1)

    def report(self) -> str:
        """Human-readable shard table + balance/residency summary."""
        lines = [f"{'shard':>5} {'pairs':>9} {'items':>11} "
                 f"{'graph_bytes':>12}"]
        for s in range(self.num_shards):
            lines.append(f"{s:>5} {self.shard_pairs[s]:>9} "
                         f"{self.shard_items[s]:>11} "
                         f"{self.shard_bytes[s]:>12}")
        lines.append(
            f"items max/mean={self.max_over_mean:.3f} "
            f"resident_bytes max={self.max_shard_bytes} "
            f"replicated={self.replicated_bytes} "
            f"({self.byte_reduction:.2f}x reduction)")
        return "\n".join(lines)


@dataclass(frozen=True)
class GraphPartition:
    """A graph statically partitioned into per-device local shards."""

    space: PairSpace           #: the global pair space
    shards: list               #: list[LocalShard], one per device
    owner: np.ndarray          #: (P,) shard owning each global pair
    stats: PartitionStats

    @property
    def num_shards(self) -> int:
        return len(self.shards)


def partition_graph(g: CompactDigraph | None = None, num_shards: int = 1,
                    orient: str = "none", prune_self: bool = True, *,
                    space: PairSpace | None = None) -> GraphPartition:
    """Partition a graph's census work into ``num_shards`` private slices.

    Greedy LPT over the exact per-pair post-prune item counts, then
    per-shard minimal-subgraph extraction (:func:`extract_shard`).  Pass
    ``space`` to reuse an existing pair decomposition (``g`` is then
    ignored); ``orient``/``prune_self`` match
    :func:`repro.core.planner.build_plan`.
    """
    if space is None:
        if g is None:
            raise ValueError("need a graph or a prebuilt pair space")
        space = pair_space(g, orient=orient, prune_self=prune_self)
    costs = postprune_pair_counts(space)
    owner = lpt_assign(costs, num_shards)
    shards = [extract_shard(space, np.nonzero(owner == s)[0], index=s,
                            costs=costs)
              for s in range(num_shards)]
    stats = PartitionStats(
        num_shards=num_shards, total_items=int(costs.sum()),
        shard_items=tuple(sh.items for sh in shards),
        shard_pairs=tuple(sh.num_pairs for sh in shards),
        shard_bytes=tuple(sh.resident_bytes for sh in shards),
        replicated_bytes=replicated_graph_bytes(space))
    return GraphPartition(space=space, shards=shards, owner=owner,
                          stats=stats)


def stacked_device_arrays(shards) -> tuple[np.ndarray, ...]:
    """The per-shard graph + pair arrays stacked to (num_shards, ·) int32
    — the *sharded* inputs of the partitioned collective step (each device
    receives exactly its own row).

    Rows are padded to common lengths so they stack: ``indptr`` with its
    own final value (phantom empty rows past ``n_loc``), ``packed`` and
    the pair arrays with zeros (inert — no live row or descriptor ever
    points at them, and invalid lanes clamp to pair/slot 0, which the
    padding keeps in-bounds).
    """
    li = max(max(sh.graph.indptr.shape[0] for sh in shards), 2)
    le = max(max(sh.graph.packed.shape[0] for sh in shards), 1)
    lp = max(max(sh.num_pairs for sh in shards), 1)
    ns = len(shards)
    indptr = np.zeros((ns, li), dtype=np.int32)
    packed = np.zeros((ns, le), dtype=np.int32)
    pu = np.zeros((ns, lp), dtype=np.int32)
    pv = np.zeros((ns, lp), dtype=np.int32)
    pc = np.zeros((ns, lp), dtype=np.int32)
    for s, sh in enumerate(shards):
        ip = sh.graph.indptr
        indptr[s, :ip.shape[0]] = ip
        indptr[s, ip.shape[0]:] = ip[-1]
        packed[s, :sh.graph.packed.shape[0]] = sh.graph.packed
        sp = sh.space
        pu[s, :sh.num_pairs] = sp.pair_u
        pv[s, :sh.num_pairs] = sp.pair_v
        pc[s, :sh.num_pairs] = sp.pair_code
    return indptr, packed, pu, pv, pc
