"""Parallel triad census — the paper's contribution, TPU-native in JAX.

Public API::

    g = from_edges(src, dst, n)                 # paper Fig 7 structure
    plan = build_plan(g, pad_to=num_devices)    # manhattan-collapse plan
    census = triad_census(plan)                 # single device
    census = triad_census_distributed(plan, mesh)   # sharded + psum
"""

from repro.core.digraph import CompactDigraph, from_edges, from_dense, to_dense
from repro.core.planner import (
    CensusPlan, build_plan, pack_items, unpack_items)
from repro.core.census import triad_census, assemble_census
from repro.core.distributed import (
    triad_census_distributed, triad_census_graph, default_mesh)
from repro.core.census_ref import (
    census_bruteforce, census_batagelj_mrvar, census_dict)
from repro.core.tricode import (
    TRIAD_NAMES, TRICODE_TO_CLASS, FOLD_64_TO_16, NUM_CLASSES)
from repro.core.generators import (
    scale_free_digraph, paper_workload, erdos_renyi_digraph, PAPER_WORKLOADS)
from repro.core.temporal import TriadMonitor, SECURITY_PATTERNS

__all__ = [
    "CompactDigraph", "from_edges", "from_dense", "to_dense",
    "CensusPlan", "build_plan", "pack_items", "unpack_items",
    "triad_census", "assemble_census",
    "triad_census_distributed", "triad_census_graph", "default_mesh",
    "census_bruteforce", "census_batagelj_mrvar", "census_dict",
    "TRIAD_NAMES", "TRICODE_TO_CLASS", "FOLD_64_TO_16", "NUM_CLASSES",
    "scale_free_digraph", "paper_workload", "erdos_renyi_digraph",
    "PAPER_WORKLOADS", "TriadMonitor", "SECURITY_PATTERNS",
]
