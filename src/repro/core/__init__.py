"""Parallel triad census — the paper's contribution, TPU-native in JAX.

Public API::

    g = from_edges(src, dst, n)                 # paper Fig 7 structure
    plan = build_plan(g, pad_to=num_devices)    # manhattan-collapse plan
    census = triad_census(plan)                 # single device
    census = triad_census_distributed(plan, mesh)   # sharded + psum

    # out-of-core: never materialize the O(W) plan — stream bounded chunks
    engine = CensusEngine(mesh, backend="pallas-fused")
    census = engine.run(g, max_items=10_000_000)
    engine.stats.summary()                      # chunks, peak plan bytes

    # resident sliding-window session: upload once, recount by edge delta
    session = engine.session(g)
    c0 = session.census()
    c1 = session.update(add_src, add_dst, del_src, del_dst)

    # partitioned: shard the GRAPH, not just the items — each device
    # holds only its pair shard's local subgraph (O(E_shard + halo))
    part = partition_graph(g, num_shards=8); print(shard_report(part))
    engine = CensusEngine(mesh, backend="pallas-fused", partition=True)
    census = engine.run(g)            # bit-identical, private shards
    session = engine.session(g)       # deltas dispatch owning shards only

    # partitioned runs drain per-shard streams asynchronously (no
    # inter-shard barrier; walltime tracks the MEAN shard, not the max);
    # schedule="lockstep" keeps the collective barrier as the oracle
    census = engine.run(g, schedule="lockstep")

    # 2D pair×vertex: keep the LPT pair axis, slice each shard's
    # witness range across V vertex slices — the adjacency halo shards
    # too, not just the pairs
    part = partition_graph_2d(g, mesh_shape=(4, 2))
    engine = CensusEngine(mesh, backend="pallas-fused", partition_2d=(4, 2))
    census = engine.run(g)            # still bit-identical
"""

from repro.core.digraph import (
    CompactDigraph, GraphDelta, apply_delta, canonical_pairs, from_edges,
    from_dense, from_pairs, to_dense)
from repro.core.planner import (
    CensusPlan, DescriptorWindow, PairSpace, base_for_pairs, build_plan,
    descriptor_window, emit_items, emit_items_for_pairs,
    iter_descriptor_windows, pack_items, pair_space, unpack_items)
from repro.core.plan_stream import (
    PlanChunk, PlanChunker, ProducerStalledError, ShardSchedule,
    ShardStreamPipeline, WindowBatcher, iter_plan_chunks)
from repro.core.faults import (
    Fault, FaultError, FaultInjector, FaultPlan, InjectedFault)
from repro.core.planner import PlanOverflowError
from repro.core.census import (
    triad_census, assemble_census, census_partials_desc_batch)
from repro.core.engine import (
    CensusEngine, EMIT_MODES, SCHEDULES, EngineSession, EngineStats,
    PartitionedEngineSession, PartitionedEngineSession2D)
from repro.core.incremental import (
    affected_pair_ids, subset_contribution, subset_descriptor_windows,
    verify_delta_closure)
from repro.core.pair_index import IndexCorruptionError, PairSpaceIndex
from repro.core.partition import (
    GraphPartition, GraphPartition2D, LocalShard, PartitionStats,
    extract_shard, lpt_assign, lpt_assign_heap, partition_graph,
    partition_graph_2d, replicated_graph_bytes, vertex_slices)
from repro.core.distributed import (
    shard_report, triad_census_distributed, triad_census_graph,
    default_mesh)
from repro.core.census_ref import (
    census_bruteforce, census_batagelj_mrvar, census_dict)
from repro.core.tricode import (
    TRIAD_NAMES, TRICODE_TO_CLASS, FOLD_64_TO_16, NUM_CLASSES)
from repro.core.generators import (
    scale_free_digraph, paper_workload, erdos_renyi_digraph, PAPER_WORKLOADS)
from repro.core.temporal import (
    TriadMonitor, SECURITY_PATTERNS, SECURITY_PATTERN_INDICES)

__all__ = [
    "CompactDigraph", "GraphDelta", "apply_delta", "canonical_pairs",
    "from_edges", "from_dense", "from_pairs", "to_dense",
    "CensusPlan", "DescriptorWindow", "PairSpace", "base_for_pairs",
    "build_plan", "descriptor_window", "emit_items",
    "emit_items_for_pairs", "iter_descriptor_windows", "pack_items",
    "pair_space", "unpack_items",
    "PlanChunk", "PlanChunker", "ProducerStalledError", "ShardSchedule",
    "ShardStreamPipeline", "WindowBatcher", "iter_plan_chunks",
    "Fault", "FaultError", "FaultInjector", "FaultPlan", "InjectedFault",
    "PlanOverflowError",
    "CensusEngine", "EMIT_MODES", "SCHEDULES", "EngineSession",
    "EngineStats", "PartitionedEngineSession",
    "PartitionedEngineSession2D",
    "affected_pair_ids", "subset_contribution",
    "subset_descriptor_windows", "verify_delta_closure",
    "IndexCorruptionError", "PairSpaceIndex",
    "GraphPartition", "GraphPartition2D", "LocalShard", "PartitionStats",
    "extract_shard", "lpt_assign", "lpt_assign_heap", "partition_graph",
    "partition_graph_2d", "replicated_graph_bytes", "vertex_slices",
    "shard_report",
    "triad_census", "assemble_census", "census_partials_desc_batch",
    "triad_census_distributed", "triad_census_graph", "default_mesh",
    "census_bruteforce", "census_batagelj_mrvar", "census_dict",
    "TRIAD_NAMES", "TRICODE_TO_CLASS", "FOLD_64_TO_16", "NUM_CLASSES",
    "scale_free_digraph", "paper_workload", "erdos_renyi_digraph",
    "PAPER_WORKLOADS", "TriadMonitor", "SECURITY_PATTERNS",
    "SECURITY_PATTERN_INDICES",
]
