"""Parallel triad census — the paper's contribution, TPU-native in JAX.

Public API::

    g = from_edges(src, dst, n)                 # paper Fig 7 structure
    plan = build_plan(g, pad_to=num_devices)    # manhattan-collapse plan
    census = triad_census(plan)                 # single device
    census = triad_census_distributed(plan, mesh)   # sharded + psum

    # out-of-core: never materialize the O(W) plan — stream bounded chunks
    engine = CensusEngine(mesh, backend="pallas-fused")
    census = engine.run(g, max_items=10_000_000)
    engine.stats.summary()                      # chunks, peak plan bytes
"""

from repro.core.digraph import CompactDigraph, from_edges, from_dense, to_dense
from repro.core.planner import (
    CensusPlan, PairSpace, build_plan, emit_items, pack_items, pair_space,
    unpack_items)
from repro.core.plan_stream import PlanChunk, PlanChunker, iter_plan_chunks
from repro.core.census import triad_census, assemble_census
from repro.core.engine import CensusEngine, EngineStats
from repro.core.distributed import (
    triad_census_distributed, triad_census_graph, default_mesh)
from repro.core.census_ref import (
    census_bruteforce, census_batagelj_mrvar, census_dict)
from repro.core.tricode import (
    TRIAD_NAMES, TRICODE_TO_CLASS, FOLD_64_TO_16, NUM_CLASSES)
from repro.core.generators import (
    scale_free_digraph, paper_workload, erdos_renyi_digraph, PAPER_WORKLOADS)
from repro.core.temporal import TriadMonitor, SECURITY_PATTERNS

__all__ = [
    "CompactDigraph", "from_edges", "from_dense", "to_dense",
    "CensusPlan", "PairSpace", "build_plan", "emit_items", "pack_items",
    "pair_space", "unpack_items",
    "PlanChunk", "PlanChunker", "iter_plan_chunks",
    "CensusEngine", "EngineStats",
    "triad_census", "assemble_census",
    "triad_census_distributed", "triad_census_graph", "default_mesh",
    "census_bruteforce", "census_batagelj_mrvar", "census_dict",
    "TRIAD_NAMES", "TRICODE_TO_CLASS", "FOLD_64_TO_16", "NUM_CLASSES",
    "scale_free_digraph", "paper_workload", "erdos_renyi_digraph",
    "PAPER_WORKLOADS", "TriadMonitor", "SECURITY_PATTERNS",
]
