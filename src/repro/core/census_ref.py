"""Reference triad-census oracles (host-side, exact integer arithmetic).

Two independent implementations used to validate the JAX / Pallas paths:

* :func:`census_bruteforce` — O(n^3) enumeration of every node triple.
* :func:`census_batagelj_mrvar` — a direct serial transcription of the
  paper's Fig 5 pseudocode (Batagelj & Mrvar 2001) over the compact
  structure, including the pointer-merge union walk of Fig 8.
"""

from __future__ import annotations

import numpy as np

from repro.core.digraph import CompactDigraph, to_dense
from repro.core.tricode import NUM_CLASSES, TRICODE_TO_CLASS, TRIAD_NAMES


def _pair_code(a: np.ndarray, i: int, j: int) -> int:
    return int(a[i, j]) | (int(a[j, i]) << 1)


def census_bruteforce(g: CompactDigraph | np.ndarray) -> np.ndarray:
    """Exact 16-bin census by enumerating all C(n,3) triples."""
    a = g if isinstance(g, np.ndarray) else to_dense(g)
    n = a.shape[0]
    out = np.zeros(NUM_CLASSES, dtype=np.int64)
    for u in range(n):
        for v in range(u + 1, n):
            c_uv = _pair_code(a, u, v)
            for w in range(v + 1, n):
                t = c_uv * 16 + _pair_code(a, u, w) * 4 + _pair_code(a, v, w)
                out[TRICODE_TO_CLASS[t]] += 1
    return out


def census_batagelj_mrvar(g: CompactDigraph) -> np.ndarray:
    """Serial Batagelj–Mrvar census (paper Fig 5, with the Fig 8 merge)."""
    n = g.n
    census = np.zeros(NUM_CLASSES, dtype=np.int64)
    indptr, packed = g.indptr, g.packed
    nbr, code = packed >> 2, packed & 3

    for u in range(n):
        for iu in range(indptr[u], indptr[u + 1]):
            v, c_uv = int(nbr[iu]), int(code[iu])
            if not u < v:
                continue
            # dyadic triads: n - |S| - 2 third nodes see neither u nor v
            tritype = 2 if c_uv == 3 else 1          # 102 : 012 (0-based)
            # pointer-merge union walk over N(u), N(v)  (paper Fig 8)
            pu, pv = indptr[u], indptr[v]
            eu, ev = indptr[u + 1], indptr[v + 1]
            union_size = 0
            while pu < eu or pv < ev:
                wu = int(nbr[pu]) if pu < eu else n
                wv = int(nbr[pv]) if pv < ev else n
                if wu < wv:
                    w, c_uw, c_vw = wu, int(code[pu]), 0
                    u_adj_w = True
                    pu += 1
                elif wv < wu:
                    w, c_uw, c_vw = wv, 0, int(code[pv])
                    u_adj_w = False
                    pv += 1
                else:
                    w, c_uw, c_vw = wu, int(code[pu]), int(code[pv])
                    u_adj_w = True
                    pu += 1
                    pv += 1
                if w == u or w == v:
                    continue
                union_size += 1
                # canonical-selection predicate (step 2.1.4)
                if v < w or (u < w < v and not u_adj_w):
                    t = c_uv * 16 + c_uw * 4 + c_vw
                    census[TRICODE_TO_CLASS[t]] += 1
            census[tritype] += n - union_size - 2
    total = n * (n - 1) * (n - 2) // 6
    census[0] = total - census[1:].sum()
    return census


def census_dict(census: np.ndarray) -> dict[str, int]:
    return {name: int(census[i]) for i, name in enumerate(TRIAD_NAMES)}
