"""Host-side work planner — the paper's "manhattan collapse", reified.

The imperfectly nested loops ``for u in V / for v in N(u), u < v / for w in
N(u) ∪ N(v)`` are flattened into dense arrays of *work items*, one item per
(canonical pair, neighbor slot).  Equal-sized chunks of this flat plan give
the perfect static load balance the paper obtained from OpenMP ``dynamic``
scheduling / the XMT's thread virtualization — except here the balance is
exact by construction and measurable ahead of time.

Two beyond-paper refinements live here:

* **Packed item encoding** — each work item is two int32 words instead of
  four streams: ``item_sp = slot << 1 | side`` and ``item_pv = pair << 1 |
  valid``.  This halves plan HBM residency and host→device transfer, and is
  what the fused Pallas kernel (:mod:`repro.kernels.census_fused`) consumes
  directly.  The legacy per-field views remain available as properties.
* **Degree-oriented planning** (``orient="degree"``) — the standard
  work-reduction trick from degree-aware triangle counting, adapted to the
  census: per pair, the *lower-degree* endpoint's row is designated to
  witness N(u)∩N(v) (cost min(deg) instead of always deg(u)), and items on
  the other side that can never satisfy the canonical counting predicate
  (``w <= v`` for N(u)-side items, ``w <= u`` for N(v)-side items — both
  decidable at plan time) are dropped entirely.  This shrinks W itself,
  typically by ~40-50% on the power-law workloads, with bit-identical
  censuses.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.digraph import CompactDigraph

#: bit 2 of ``pair_code`` in a degree-oriented plan: which side of the pair
#: (0 = N(u), 1 = N(v)) witnesses the intersection count for the dyadic
#: closed forms.  Default plans leave it 0 == the historical behavior.
INTER_SIDE_BIT = 2


def pack_items(item_slot: np.ndarray, item_side: np.ndarray,
               item_pair: np.ndarray, item_valid: np.ndarray
               ) -> tuple[np.ndarray, np.ndarray]:
    """Fold (slot, side) and (pair, valid) into two int32 words per item.

    Requires ``slot < 2**30`` and ``pair < 2**30`` (enforced by
    :func:`build_plan`'s int32 guard).
    """
    item_sp = ((item_slot.astype(np.int64) << 1)
               | item_side.astype(np.int64)).astype(np.int32)
    item_pv = ((item_pair.astype(np.int64) << 1)
               | item_valid.astype(np.int64)).astype(np.int32)
    return item_sp, item_pv


def unpack_items(item_sp: np.ndarray, item_pv: np.ndarray):
    """Inverse of :func:`pack_items`: (slot, side, pair, valid)."""
    item_sp = np.asarray(item_sp)
    item_pv = np.asarray(item_pv)
    return (item_sp >> 1, (item_sp & 1).astype(np.int32),
            item_pv >> 1, (item_pv & 1).astype(bool))


@dataclass(frozen=True)
class CensusPlan:
    """Flattened iteration space + exact host-side closed-form terms."""

    n: int
    num_pairs: int
    num_items: int             #: pre-padding work-item count W
    max_degree: int
    search_iters: int          #: binary-search depth = ceil(log2(max_deg+1))
    orient: str                #: "none" or "degree"

    # device arrays (int32): graph
    indptr: np.ndarray         #: (n+1,)
    packed: np.ndarray         #: (2*pairs,)
    # canonical pairs
    pair_u: np.ndarray         #: (P,)
    pair_v: np.ndarray         #: (P,)
    pair_code: np.ndarray      #: (P,) dyad code in {1,2,3} | inter_side << 2
    # flat work items (padded to `pad_to`), packed two-words-per-item
    item_sp: np.ndarray        #: (Wp,) ``slot << 1 | side``
    item_pv: np.ndarray        #: (Wp,) ``pair << 1 | valid``

    # exact int64 host terms for the dyadic (012/102) closed forms:
    # census[t] = base_t + (# intersections found on device for pairs of t)
    base_asym: int
    base_mut: int

    # --- legacy per-field views (decoded on access; device code should
    # --- ship the packed words and decode in-graph) -----------------------
    @property
    def item_slot(self) -> np.ndarray:
        return self.item_sp >> 1

    @property
    def item_side(self) -> np.ndarray:
        return (self.item_sp & 1).astype(np.int32)

    @property
    def item_pair(self) -> np.ndarray:
        return self.item_pv >> 1

    @property
    def item_valid(self) -> np.ndarray:
        return (self.item_pv & 1).astype(bool)

    def balance_stats(self, num_shards: int) -> dict[str, float]:
        """Work-imbalance metrics (paper Fig 9 utilization analogue).

        Compares the flat plan against pair-granular partitioning (what a
        naive parallel-for over pairs would give on a power-law graph).
        """
        wp = self.item_pv.shape[0]
        flat_max = -(-wp // num_shards)
        flat_mean = wp / num_shards
        # pair-granular: contiguous pair blocks, shard work = sum of costs
        # (single O(W) decode instead of one per property access)
        _, _, item_pair, item_valid = unpack_items(self.item_sp,
                                                   self.item_pv)
        cost = np.bincount(item_pair[item_valid],
                           minlength=self.num_pairs).astype(np.int64)
        bounds = np.linspace(0, self.num_pairs, num_shards + 1).astype(int)
        per = np.add.reduceat(cost, bounds[:-1]) if self.num_pairs else \
            np.zeros(num_shards)
        return {
            "flat_max_over_mean": flat_max / max(flat_mean, 1e-9),
            "pair_max_over_mean": float(per.max() / max(per.mean(), 1e-9))
            if self.num_pairs else 1.0,
            "items": int(self.num_items),
            "pairs": int(self.num_pairs),
        }


def build_plan(g: CompactDigraph, pad_to: int = 1,
               prune_self: bool = True, orient: str = "none") -> CensusPlan:
    """Construct the flat census plan for a compact graph.

    ``prune_self`` drops the two guaranteed no-op items per pair (the
    slot where N(u) contains v itself and vice versa) at plan time — a
    beyond-paper optimization worth 2·P of the W work items (§Perf).

    ``orient="degree"`` additionally (a) assigns intersection-witness duty
    to each pair's lower-degree endpoint and (b) drops every item that can
    neither witness the intersection nor satisfy the canonical counting
    predicate (see module docstring).  Implies ``prune_self`` semantics.
    The resulting plan is accepted by every backend and yields bit-identical
    censuses.
    """
    if orient not in ("none", "degree"):
        raise ValueError(f"unknown orient mode {orient!r}")
    n = g.n
    indptr, packed = g.indptr, g.packed
    nbr = packed >> 2
    deg = g.degrees

    # canonical pairs: CSR entries with nbr > row
    rows = np.repeat(np.arange(n, dtype=np.int64), deg)
    canon = nbr > rows
    pair_u = rows[canon]
    pair_v = nbr[canon].astype(np.int64)
    pair_code = (packed[canon] & 3).astype(np.int32)
    num_pairs = pair_u.shape[0]

    deg_u, deg_v = deg[pair_u], deg[pair_v]
    counts = deg_u + deg_v
    num_items = int(counts.sum())

    offsets = np.zeros(num_pairs + 1, dtype=np.int64)
    np.cumsum(counts, out=offsets[1:])
    item_pair = np.repeat(np.arange(num_pairs, dtype=np.int64), counts)
    within = np.arange(num_items, dtype=np.int64) - offsets[item_pair]
    item_side = (within >= deg_u[item_pair]).astype(np.int8)
    item_slot = np.where(
        item_side == 0,
        indptr[pair_u[item_pair]] + within,
        indptr[pair_v[item_pair]] + within - deg_u[item_pair])

    if orient == "degree" and num_items:
        inter_side = (deg_v < deg_u).astype(np.int32)
        pair_code = pair_code | (inter_side << INTER_SIDE_BIT)
        w_ids = nbr[item_slot]
        u_of, v_of = pair_u[item_pair], pair_v[item_pair]
        on_inter = item_side == inter_side[item_pair]
        not_self = (w_ids != u_of) & (w_ids != v_of)
        # non-inter-side items survive only if the canonical predicate can
        # hold: N(u)-side needs w > v; N(v)-side needs w > u (plan-time
        # facts — see census.classify_items for the device-side predicate)
        can_count = np.where(item_side == 0, w_ids > v_of, w_ids > u_of)
        keep = not_self & (on_inter | can_count)
        item_pair, item_slot, item_side = (
            item_pair[keep], item_slot[keep], item_side[keep])
        num_items = int(item_pair.shape[0])
    elif prune_self and num_items:
        w_ids = nbr[item_slot]
        keep = ~(((item_side == 0) & (w_ids == pair_v[item_pair])) |
                 ((item_side == 1) & (w_ids == pair_u[item_pair])))
        item_pair = item_pair[keep]
        item_slot = item_slot[keep]
        item_side = item_side[keep]
        num_items = int(item_pair.shape[0])

    # pad the flat plan to a multiple of the shard count
    wp = -(-max(num_items, 1) // pad_to) * pad_to
    pad = wp - num_items
    item_pair = np.concatenate([item_pair, np.zeros(pad, np.int64)])
    item_slot = np.concatenate([item_slot, np.zeros(pad, np.int64)])
    item_side = np.concatenate([item_side, np.zeros(pad, np.int8)])
    item_valid = np.concatenate(
        [np.ones(num_items, bool), np.zeros(pad, bool)])

    # closed-form dyadic bases: sum over pairs of (n - deg_u - deg_v)
    term = (n - deg_u - deg_v).astype(np.int64)
    mut = (pair_code & 3) == 3
    base_mut = int(term[mut].sum())
    base_asym = int(term[~mut].sum())

    max_deg = int(deg.max()) if n else 0
    # slot/pair gain a packed flag bit, so they must fit in 30 value bits
    if wp >= 2**31 or packed.shape[0] >= 2**30:
        raise ValueError("plan exceeds int32 packed-item indexing "
                         "(need slots < 2**30); shard the graph first")
    item_sp, item_pv = pack_items(item_slot, item_side, item_pair,
                                  item_valid)
    return CensusPlan(
        n=n, num_pairs=num_pairs, num_items=num_items, max_degree=max_deg,
        search_iters=max(1, int(np.ceil(np.log2(max_deg + 1)))),
        orient=orient,
        indptr=indptr.astype(np.int32), packed=packed,
        pair_u=pair_u.astype(np.int32), pair_v=pair_v.astype(np.int32),
        pair_code=pair_code,
        item_sp=item_sp, item_pv=item_pv,
        base_asym=base_asym, base_mut=base_mut)
