"""Host-side work planner — the paper's "manhattan collapse", reified.

The imperfectly nested loops ``for u in V / for v in N(u), u < v / for w in
N(u) ∪ N(v)`` are flattened into dense arrays of *work items*, one item per
(canonical pair, neighbor slot).  Equal-sized chunks of this flat plan give
the perfect static load balance the paper obtained from OpenMP ``dynamic``
scheduling / the XMT's thread virtualization — except here the balance is
exact by construction and measurable ahead of time.

The planner is factored in two stages so the flat plan never *has* to be
materialized at once:

* :func:`pair_space` builds the O(pairs) canonical-pair decomposition —
  per-pair item counts, prefix offsets into the conceptual pre-prune item
  space, and the per-pair closed-form dyadic terms.
* :func:`emit_items` materializes any contiguous slice ``[lo, hi)`` of
  that item space (with pruning/orientation applied) in O(hi - lo) memory.
* :func:`emit_items_for_pairs` materializes the items of an *arbitrary
  pair subset* (with the same pruning/orientation), and
  :func:`base_for_pairs` gives the matching subset-additive closed-form
  bases — the pieces the incremental census
  (:mod:`repro.core.incremental`) diffs affected pairs with.
* :func:`make_pair_space` assembles a :class:`PairSpace` from an explicit
  pair sequence over any CSR — the shard-aware slicing hook the graph
  partitioner (:mod:`repro.core.partition`) builds per-device local
  spaces with — and :func:`postprune_pair_counts` gives the exact
  per-pair work-item costs its LPT balances.
* :func:`descriptor_window` compresses any window of the item space into
  O(pairs) *descriptors* (:class:`DescriptorWindow`) from which the
  device expands items itself
  (:func:`repro.core.census.expand_work_items`) — the ``emit="device"``
  path that avoids materializing items on the host at all.

:func:`build_plan` is the one-slice special case (``[0, W)``);
:mod:`repro.core.plan_stream` iterates bounded slices for out-of-core
streaming execution.

Two beyond-paper refinements live here:

* **Packed item encoding** — each work item is two int32 words instead of
  four streams: ``item_sp = slot << 1 | side`` and ``item_pv = pair << 1 |
  valid``.  This halves plan HBM residency and host→device transfer, and is
  what the fused Pallas kernel (:mod:`repro.kernels.census_fused`) consumes
  directly.  The legacy per-field views remain available as properties.
* **Degree-oriented planning** (``orient="degree"``) — the standard
  work-reduction trick from degree-aware triangle counting, adapted to the
  census: per pair, the *lower-degree* endpoint's row is designated to
  witness N(u)∩N(v) (cost min(deg) instead of always deg(u)), and items on
  the other side that can never satisfy the canonical counting predicate
  (``w <= v`` for N(u)-side items, ``w <= u`` for N(v)-side items — both
  decidable at plan time) are dropped entirely.  This shrinks W itself,
  typically by ~40-50% on the power-law workloads, with bit-identical
  censuses.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.digraph import CompactDigraph, canonical_pairs

#: bit 2 of ``pair_code`` in a degree-oriented plan: which side of the pair
#: (0 = N(u), 1 = N(v)) witnesses the intersection count for the dyadic
#: closed forms.  Default plans leave it 0 == the historical behavior.
INTER_SIDE_BIT = 2


class PlanOverflowError(ValueError):
    """A plan (or one window of a streamed plan) would exceed the int32
    packed-item indexing / per-window int32 accumulator lanes.

    Raised at *plan time* wherever an item count could reach ``2**31``,
    so the failure is a clear actionable message instead of a silent
    int32 wraparound deep inside a compiled step.  Subclasses
    :class:`ValueError` for backward compatibility with callers that
    caught the old generic guard.
    """


def pack_items(item_slot: np.ndarray, item_side: np.ndarray,
               item_pair: np.ndarray, item_valid: np.ndarray
               ) -> tuple[np.ndarray, np.ndarray]:
    """Fold (slot, side) and (pair, valid) into two int32 words per item.

    Requires ``slot < 2**30`` and ``pair < 2**30`` (enforced by
    :func:`pair_space`'s int32 guard).
    """
    item_sp = ((item_slot.astype(np.int64) << 1)
               | item_side.astype(np.int64)).astype(np.int32)
    item_pv = ((item_pair.astype(np.int64) << 1)
               | item_valid.astype(np.int64)).astype(np.int32)
    return item_sp, item_pv


def unpack_items(item_sp: np.ndarray, item_pv: np.ndarray):
    """Inverse of :func:`pack_items`: (slot, side, pair, valid)."""
    item_sp = np.asarray(item_sp)
    item_pv = np.asarray(item_pv)
    return (item_sp >> 1, (item_sp & 1).astype(np.int32),
            item_pv >> 1, (item_pv & 1).astype(bool))


@dataclass(frozen=True)
class PairSpace:
    """Canonical-pair decomposition of the census iteration space.

    Everything needed to (a) emit any contiguous slice of the *pre-prune*
    flat item space on demand and (b) split the closed-form dyadic bases
    additively across such slices — in O(n + edges + pairs) host memory,
    independent of the total work-item count W.
    """

    n: int
    orient: str                #: "none" or "degree"
    prune_self: bool
    max_degree: int
    search_iters: int

    indptr: np.ndarray         #: (n+1,) int64 CSR row offsets
    packed: np.ndarray         #: (2*pairs,) int32 ``(nbr << 2) | code``
    nbr: np.ndarray            #: (2*pairs,) ``packed >> 2`` (precomputed)
    deg: np.ndarray            #: (n,) row degrees

    pair_u: np.ndarray         #: (P,) int64
    pair_v: np.ndarray         #: (P,) int64
    pair_code: np.ndarray      #: (P,) int32, incl. inter-side bit if oriented

    counts: np.ndarray         #: (P,) pre-prune items per pair (deg_u+deg_v)
    offsets: np.ndarray        #: (P+1,) int64 prefix sum of ``counts``
    pair_term: np.ndarray      #: (P,) int64 closed-form term n-deg_u-deg_v
    pair_mut: np.ndarray       #: (P,) bool — pair dyad is mutual

    @property
    def num_pairs(self) -> int:
        return self.pair_u.shape[0]

    @property
    def num_items_preprune(self) -> int:
        """Size W₀ of the pre-prune flat item space (Σ deg_u + deg_v)."""
        return int(self.offsets[-1])

    def num_items_postprune(self) -> int:
        """Exact post-prune work-item count W without emitting any items
        (the sum of :func:`postprune_pair_counts`)."""
        if self.num_pairs == 0:
            return 0
        return int(postprune_pair_counts(self).sum())

    def base_slices(self, starts: np.ndarray) -> tuple[np.ndarray,
                                                       np.ndarray]:
        """Additive (base_asym, base_mut) shares for the slices delimited by
        pre-prune item positions ``starts`` (ascending, covering [0, W₀)).

        Each pair's term is credited to the slice containing the pair's
        first pre-prune item, so the shares sum exactly to the global bases
        regardless of where slice boundaries fall (including mid-pair).
        """
        starts = np.asarray(starts, dtype=np.int64)
        nchunks = starts.shape[0]
        which = np.searchsorted(starts, self.offsets[:-1], side="right") - 1
        which = np.clip(which, 0, max(nchunks - 1, 0))
        asym = np.zeros(nchunks, dtype=np.int64)
        mut = np.zeros(nchunks, dtype=np.int64)
        np.add.at(asym, which[~self.pair_mut], self.pair_term[~self.pair_mut])
        np.add.at(mut, which[self.pair_mut], self.pair_term[self.pair_mut])
        return asym, mut


def make_pair_space(g: CompactDigraph, pair_u: np.ndarray,
                    pair_v: np.ndarray, pair_code: np.ndarray, *,
                    orient: str, prune_self: bool = True,
                    pair_term: np.ndarray | None = None) -> PairSpace:
    """Assemble a :class:`PairSpace` over ``g`` from an explicit canonical
    -pair sequence — the shard-aware constructor behind :func:`pair_space`
    (which passes the full canonical decomposition) and
    :mod:`repro.core.partition` (which passes one shard's pairs over its
    relabeled local subgraph).

    ``pair_code`` is taken as given — including any degree-orientation
    inter-side bits already stamped on it — so a pair sliced out of a
    larger space keeps the exact plan policy it had there.  ``pair_term``
    overrides the closed-form dyadic terms; a shard passes the *global*
    ``n - deg_u - deg_v`` values so per-shard bases stay additive to the
    global ones (the local ``n`` would be wrong for the complement).
    """
    if orient not in ("none", "degree"):
        raise ValueError(f"unknown orient mode {orient!r}")
    indptr, packed = g.indptr, g.packed
    deg = g.degrees
    pair_u = np.asarray(pair_u, dtype=np.int64)
    pair_v = np.asarray(pair_v, dtype=np.int64)
    pair_code = np.asarray(pair_code, dtype=np.int32)
    num_pairs = pair_u.shape[0]

    deg_u, deg_v = deg[pair_u], deg[pair_v]
    counts = (deg_u + deg_v).astype(np.int64)
    offsets = np.zeros(num_pairs + 1, dtype=np.int64)
    np.cumsum(counts, out=offsets[1:])

    # slot/pair gain a packed flag bit, so they must fit in 30 value bits
    if packed.shape[0] >= 2**30:
        raise ValueError("graph exceeds int32 packed-item indexing "
                         "(need slots < 2**30); shard the graph first")

    if pair_term is None:
        pair_term = (g.n - deg_u - deg_v).astype(np.int64)
    max_deg = int(deg.max()) if g.n else 0
    return PairSpace(
        n=g.n, orient=orient, prune_self=prune_self, max_degree=max_deg,
        search_iters=max(1, int(np.ceil(np.log2(max_deg + 1)))),
        indptr=indptr, packed=packed, nbr=packed >> 2, deg=deg,
        pair_u=pair_u, pair_v=pair_v, pair_code=pair_code,
        counts=counts, offsets=offsets,
        pair_term=np.asarray(pair_term, dtype=np.int64),
        pair_mut=(pair_code & 3) == 3)


def pair_space(g: CompactDigraph, orient: str = "none",
               prune_self: bool = True) -> PairSpace:
    """Build the O(pairs) pair decomposition for ``g`` (no items yet)."""
    if orient not in ("none", "degree"):
        raise ValueError(f"unknown orient mode {orient!r}")
    # canonical pairs: CSR entries with nbr > row
    pair_u, pair_v, pair_code = canonical_pairs(g)
    pair_code = pair_code.astype(np.int32)
    if orient == "degree" and pair_u.shape[0]:
        deg = g.degrees
        inter_side = (deg[pair_v] < deg[pair_u]).astype(np.int32)
        pair_code = pair_code | (inter_side << INTER_SIDE_BIT)
    return make_pair_space(g, pair_u, pair_v, pair_code, orient=orient,
                           prune_self=prune_self)


def postprune_pair_counts(space: PairSpace,
                          pair_ids: np.ndarray | None = None,
                          entry_key: np.ndarray | None = None
                          ) -> np.ndarray:
    """Exact post-prune work items per pair, (P,) int64, without emitting.

    The closed form per pair: with self-pruning each pair loses its two
    guaranteed self-items; with degree orientation the witness side keeps
    its ``deg - 1`` non-self items while the other side keeps only the
    entries past the co-endpoint in its sorted row (the plan-time
    canonical predicate) — both countable from the CSR in O(P log m) via
    the globally sorted entry keys.  This is both the exact-W closed form
    (:meth:`PairSpace.num_items_postprune`) and the per-pair cost vector
    the partitioner's LPT balances (:mod:`repro.core.partition`).

    ``pair_ids`` restricts the computation to a pair subset (result
    aligned with ``pair_ids``), the hook the delta-incremental
    :class:`~repro.core.pair_index.PairSpaceIndex` recounts only affected
    pairs with — O(|subset| log m) searches instead of O(P log m); pass
    the CSR's cached ``entry_key``
    (:func:`repro.core.digraph.entry_keys`) to also skip the O(m) key
    materialization the degree branch otherwise pays.
    """
    if space.num_pairs == 0:
        return np.zeros(0 if pair_ids is None else len(pair_ids),
                        dtype=np.int64)
    counts = space.counts if pair_ids is None else space.counts[pair_ids]
    if space.orient != "degree":
        return counts - (2 if space.prune_self else 0)
    pu = space.pair_u if pair_ids is None else space.pair_u[pair_ids]
    pv = space.pair_v if pair_ids is None else space.pair_v[pair_ids]
    code = (space.pair_code if pair_ids is None
            else space.pair_code[pair_ids])
    if entry_key is None:
        rows = np.repeat(np.arange(space.n, dtype=np.int64),
                         space.deg.astype(np.int64))
        entry_key = rows * space.n + space.nbr.astype(np.int64)
    pos_v_in_u = (np.searchsorted(entry_key, pu * space.n + pv)
                  - space.indptr[pu])
    pos_u_in_v = (np.searchsorted(entry_key, pv * space.n + pu)
                  - space.indptr[pv])
    deg_u = space.deg[pu].astype(np.int64)
    deg_v = space.deg[pv].astype(np.int64)
    inter = (code >> INTER_SIDE_BIT) & 1
    side0 = np.where(inter == 0, deg_u - 1, deg_u - pos_v_in_u - 1)
    side1 = np.where(inter == 1, deg_v - 1, deg_v - pos_u_in_v - 1)
    return side0 + side1


def _entry_keys(space: PairSpace) -> np.ndarray:
    """Globally sorted ``row * n + nbr`` keys of every CSR entry — the
    O(1)-per-query index behind the range-restricted pair counts."""
    rows = np.repeat(np.arange(space.n, dtype=np.int64),
                     space.deg.astype(np.int64))
    return rows * space.n + space.nbr.astype(np.int64)


def range_preprune_pair_counts(space: PairSpace, lo: int, hi: int
                               ) -> np.ndarray:
    """Pre-prune items per pair whose witness id lies in ``[lo, hi)``.

    The per-slice analogue of ``space.counts``: for each pair (u, v) it
    counts the entries of N(u) and N(v) inside the vertex range — the
    item population a 2D vertex slice owns *before* pruning.  Over a
    partition of ``[0, n)`` into slices these sum to ``space.counts``
    exactly, which is what makes the 2D tile item spaces a partition of
    each pair's global item space.
    """
    if not 0 <= lo <= hi <= space.n:
        raise ValueError(f"vertex range [{lo}, {hi}) outside [0, {space.n}]")
    if space.num_pairs == 0:
        return np.zeros(0, dtype=np.int64)
    key = _entry_keys(space)
    n = space.n

    def cnt(rows, a, b):
        return (np.searchsorted(key, rows * n + b)
                - np.searchsorted(key, rows * n + a))

    return cnt(space.pair_u, lo, hi) + cnt(space.pair_v, lo, hi)


def range_postprune_pair_counts(space: PairSpace, lo: int, hi: int
                                ) -> np.ndarray:
    """Exact post-prune items per pair restricted to witnesses in
    ``[lo, hi)`` — the per-slice cost closed form of the 2D decomposition.

    Mirrors :func:`postprune_pair_counts` with every row count replaced
    by its range restriction and every co-endpoint ``- 1`` replaced by a
    membership test (in a sliced row the co-endpoint may fall *outside*
    the range, so the unconditional subtraction of the global closed form
    would undercount).  Over a partition of ``[0, n)`` into slices these
    sum to :func:`postprune_pair_counts` exactly — the additivity the 2D
    engine's per-tile partials rely on.
    """
    if not 0 <= lo <= hi <= space.n:
        raise ValueError(f"vertex range [{lo}, {hi}) outside [0, {space.n}]")
    if space.num_pairs == 0:
        return np.zeros(0, dtype=np.int64)
    key = _entry_keys(space)
    n = space.n
    pu = space.pair_u
    pv = space.pair_v

    def cnt(rows, a, b):
        return (np.searchsorted(key, rows * n + b)
                - np.searchsorted(key, rows * n + a))

    c_u = cnt(pu, lo, hi)
    c_v = cnt(pv, lo, hi)

    def in_range(x):
        return ((x >= lo) & (x < hi)).astype(np.int64)

    if space.orient != "degree":
        if not space.prune_self:
            return c_u + c_v
        return c_u + c_v - in_range(pv) - in_range(pu)
    inter = (space.pair_code >> INTER_SIDE_BIT) & 1
    # witness side keeps its in-range non-self entries; the other side
    # keeps only in-range entries past the co-endpoint (prune_items'
    # ``can_count`` predicate, range-restricted)
    side0 = np.where(inter == 0, c_u - in_range(pv),
                     cnt(pu, np.clip(pv + 1, lo, hi), hi))
    side1 = np.where(inter == 1, c_v - in_range(pu),
                     cnt(pv, np.clip(pu + 1, lo, hi), hi))
    return side0 + side1


def emit_items(space: PairSpace, lo: int, hi: int
               ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Materialize pre-prune item range ``[lo, hi)`` with pruning applied.

    Returns ``(item_pair, item_slot, item_side)`` for the surviving items,
    in pre-prune order, using O(hi - lo) memory.  Slices may start or end
    mid-pair (intra-pair splits for hub pairs are exactly this).
    """
    offsets = space.offsets
    lo, hi = int(lo), int(hi)
    if not (0 <= lo <= hi <= space.num_items_preprune):
        raise ValueError(f"slice [{lo}, {hi}) outside item space "
                         f"[0, {space.num_items_preprune})")
    empty = np.zeros(0, np.int64)
    if hi == lo:
        return empty, empty, empty.astype(np.int8)

    p0 = int(np.searchsorted(offsets, lo, side="right") - 1)
    p1 = int(np.searchsorted(offsets, hi, side="left"))
    ids = np.arange(p0, p1, dtype=np.int64)
    overlap = (np.minimum(offsets[ids + 1], hi)
               - np.maximum(offsets[ids], lo))
    item_pair = np.repeat(ids, overlap)
    within = np.arange(lo, hi, dtype=np.int64) - offsets[item_pair]
    return _materialize_items(space, item_pair, within)


def _materialize_items(space: PairSpace, item_pair: np.ndarray,
                       within: np.ndarray
                       ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Turn (pair, within-pair position) coordinates into concrete pruned
    ``(pair, slot, side)`` items — the tail shared by :func:`emit_items`
    and :func:`emit_items_for_pairs`, so the contiguous-slice and
    pair-subset paths can never diverge."""
    deg_u = space.deg[space.pair_u[item_pair]]
    item_side = (within >= deg_u).astype(np.int8)
    item_slot = np.where(
        item_side == 0,
        space.indptr[space.pair_u[item_pair]] + within,
        space.indptr[space.pair_v[item_pair]] + within - deg_u)
    return prune_items(space, item_pair, item_slot, item_side)


def prune_items(space: PairSpace, item_pair: np.ndarray,
                item_slot: np.ndarray, item_side: np.ndarray
                ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Apply the space's pruning/orientation policy to raw items — the
    shared tail of :func:`emit_items` and :func:`emit_items_for_pairs`."""
    if space.orient == "degree":
        inter_side = (space.pair_code[item_pair] >> INTER_SIDE_BIT) & 1
        w_ids = space.nbr[item_slot]
        u_of = space.pair_u[item_pair]
        v_of = space.pair_v[item_pair]
        on_inter = item_side == inter_side
        not_self = (w_ids != u_of) & (w_ids != v_of)
        # non-inter-side items survive only if the canonical predicate can
        # hold: N(u)-side needs w > v; N(v)-side needs w > u (plan-time
        # facts — see census.classify_items for the device-side predicate)
        can_count = np.where(item_side == 0, w_ids > v_of, w_ids > u_of)
        keep = not_self & (on_inter | can_count)
        return item_pair[keep], item_slot[keep], item_side[keep]
    if space.prune_self:
        w_ids = space.nbr[item_slot]
        keep = ~(((item_side == 0) & (w_ids == space.pair_v[item_pair])) |
                 ((item_side == 1) & (w_ids == space.pair_u[item_pair])))
        return item_pair[keep], item_slot[keep], item_side[keep]
    return item_pair, item_slot, item_side


def emit_items_for_pairs(space: PairSpace, pair_ids
                         ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Materialize the (pruned) work items of an arbitrary pair subset.

    ``pair_ids`` indexes the space's canonical pair arrays; items come out
    grouped by pair in the given order, in O(Σ counts[pair_ids]) memory.
    The union over a partition of all pairs reproduces exactly the items
    of :func:`emit_items` over ``[0, W₀)`` (possibly permuted — census
    partials are order-invariant integer sums), which is what makes
    per-subset census contributions additive.
    """
    ids = np.asarray(pair_ids, dtype=np.int64).ravel()
    empty = np.zeros(0, np.int64)
    if ids.size == 0:
        return empty, empty, empty.astype(np.int8)
    if ids.min() < 0 or ids.max() >= space.num_pairs:
        raise ValueError(f"pair id outside [0, {space.num_pairs})")
    counts = space.counts[ids]
    total = int(counts.sum())
    item_pair = np.repeat(ids, counts)
    starts = np.cumsum(counts) - counts
    within = np.arange(total, dtype=np.int64) - np.repeat(starts, counts)
    return _materialize_items(space, item_pair, within)


#: bytes per pair descriptor shipped by the device-emission path: three
#: int32 words (pair id, window-local cumulative offset, within-pair start)
DESC_BYTES = 12

#: padding value for ``desc_cum`` — larger than any window-local item
#: index, so the in-kernel lower-bound search never lands on a padding
#: descriptor (mirrors census_fused.PACKED_PAD for the CSR array)
DESC_CUM_PAD = 2**31 - 1

#: anchor-table stride for the in-kernel item→descriptor lookup: one
#: precomputed anchor per ``DESC_ANCHOR_STRIDE`` flat items narrows the
#: per-lane lower-bound search from the whole descriptor table to the
#: <= stride + 1 descriptors that can overlap one stride span (every
#: descriptor spans >= 1 pre-prune item — 2D vertex-sliced tiles keep
#: pairs whose in-slice item count is exactly 1, so the tighter
#: stride/2 + 1 bound of the global >= 2 items-per-pair invariant does
#: not apply), making the unrolled search depth a small CONSTANT
#: independent of the window's pair count
DESC_ANCHOR_STRIDE = 16

#: unrolled lower-bound depth sufficient for any anchored search range
DESC_SEARCH_ITERS = int(np.ceil(np.log2(DESC_ANCHOR_STRIDE + 2)))


def num_desc_anchors(chunk_shape: int) -> int:
    """Fixed anchor-table length for a ``chunk_shape``-lane window (the
    +2 covers the partial trailing stride and the closing bound)."""
    return int(chunk_shape) // DESC_ANCHOR_STRIDE + 2


def max_pairs_per_window(offsets: np.ndarray, window: int) -> int:
    """Widest pair span of any chunk in the equal-``window`` slicing of
    an item space — the one boundary convention (searchsorted right/left
    over the prefix ``offsets``) shared by every descriptor-shape sizing
    decision, so producers and :func:`descriptor_window` can never
    disagree about how many descriptors a window may need."""
    offsets = np.asarray(offsets, dtype=np.int64)
    total = int(offsets[-1])
    if total == 0 or offsets.shape[0] <= 1:
        return 1
    starts = np.arange(0, total, int(window), dtype=np.int64)
    stops = np.minimum(starts + int(window), total)
    p0 = np.searchsorted(offsets, starts, side="right") - 1
    p1 = np.searchsorted(offsets, stops, side="left")
    return max(int((p1 - p0).max()), 1)


@dataclass(frozen=True)
class DescriptorWindow:
    """Compact per-pair descriptors for one window of an item space.

    This is what the device-emission path ships instead of materialized
    work items: O(pairs-in-window) descriptors from which the device
    expands every flat item index ``i`` in ``[0, num_preprune)`` back to
    its ``(pair, slot, side)`` coordinates arithmetically
    (:func:`repro.core.census.expand_work_items`).  ``desc_cum[j]`` is the
    window-local index of descriptor j's first item (a cumulative-offset
    table the kernel binary-searches); ``desc_within0[j]`` is the
    within-pair position of that first item — non-zero only when the
    window starts mid-pair (an intra-pair split expressed as an offset,
    never as materialized items).  Arrays are padded to a fixed
    ``desc_shape`` so the jitted device step compiles once.
    """

    start: int                 #: window [start, stop) in its item space
    stop: int
    num_preprune: int          #: stop - start (valid expansion lanes)
    num_descs: int             #: live descriptors before padding
    desc_pair: np.ndarray      #: (desc_shape,) int32 pair ids, pad 0
    desc_cum: np.ndarray       #: (desc_shape,) int32, pad DESC_CUM_PAD
    desc_within0: np.ndarray   #: (desc_shape,) int32, pad 0
    anchors: np.ndarray        #: (num_anchors,) int32 item→desc anchors

    @property
    def upload_bytes(self) -> int:
        """Host→device plan bytes this window ships (padded descriptor
        arrays + anchor table + the 4-byte valid-lane count)."""
        return (DESC_BYTES * int(self.desc_pair.shape[0])
                + 4 * int(self.anchors.shape[0]) + 4)

    def device_words(self) -> np.ndarray:
        """The window as ONE int32 buffer — ``[num_preprune, desc_pair…,
        desc_cum…, desc_within0…, anchors…]`` — so each chunk costs a
        single host→device upload; the jitted step slices the fields back
        apart (their lengths are static, recoverable from the buffer and
        item-lane counts)."""
        return np.concatenate([
            np.array([self.num_preprune], dtype=np.int32),
            self.desc_pair, self.desc_cum, self.desc_within0,
            self.anchors])


def descriptor_window(offsets: np.ndarray, lo: int, hi: int,
                      desc_shape: int, num_anchors: int,
                      pair_ids=None) -> DescriptorWindow:
    """Build the descriptors of item window ``[lo, hi)``.

    ``offsets`` is the (K+1,) pre-prune prefix over a pair sequence —
    :attr:`PairSpace.offsets` for the global space (``pair_ids=None``:
    descriptor j's pair id is its absolute index), or a subset prefix with
    ``pair_ids`` giving the actual pair ids (the incremental path).
    ``num_anchors`` fixes the anchor-table shape
    (:func:`num_desc_anchors` of the dispatch lane count).
    O(pairs-in-window + num_anchors) time and memory; boundaries may fall
    mid-pair.
    """
    offsets = np.asarray(offsets, dtype=np.int64)
    lo, hi = int(lo), int(hi)
    if not (0 <= lo <= hi <= int(offsets[-1])):
        raise ValueError(f"window [{lo}, {hi}) outside item space "
                         f"[0, {int(offsets[-1])})")
    j0 = int(np.searchsorted(offsets, lo, side="right") - 1) if hi > lo \
        else 0
    j1 = int(np.searchsorted(offsets, hi, side="left")) if hi > lo else 0
    nd = j1 - j0
    if nd > desc_shape:
        raise ValueError(f"window [{lo}, {hi}) spans {nd} pairs "
                         f"> desc_shape {desc_shape}")
    dp = np.zeros(desc_shape, dtype=np.int32)
    dc = np.full(desc_shape, DESC_CUM_PAD, dtype=np.int32)
    dw = np.zeros(desc_shape, dtype=np.int32)
    anchors = np.zeros(num_anchors, dtype=np.int32)
    if nd:
        ids = (np.arange(j0, j1, dtype=np.int64) if pair_ids is None
               else np.asarray(pair_ids, dtype=np.int64)[j0:j1])
        starts = offsets[j0:j1]
        dp[:nd] = ids
        cum = np.maximum(starts - lo, 0)
        dc[:nd] = cum
        dw[:nd] = np.maximum(lo - starts, 0)
        grid = (np.arange(num_anchors, dtype=np.int64)
                * DESC_ANCHOR_STRIDE)
        anchors[:] = np.clip(
            np.searchsorted(cum, grid, side="right") - 1, 0, nd - 1)
    return DescriptorWindow(start=lo, stop=hi, num_preprune=hi - lo,
                            num_descs=nd, desc_pair=dp, desc_cum=dc,
                            desc_within0=dw, anchors=anchors)


def iter_descriptor_windows(offsets: np.ndarray, max_items: int,
                            desc_shape: int, num_anchors: int,
                            pair_ids=None):
    """Cover an item space with descriptor windows of at most ``max_items``
    items AND at most ``desc_shape`` pairs each (a window over many small
    pairs shrinks its item span instead of overflowing the fixed-shape
    descriptor buffers — compile-once without capacity growth)."""
    offsets = np.asarray(offsets, dtype=np.int64)
    total = int(offsets[-1])
    num_pairs = offsets.shape[0] - 1
    lo = 0
    while lo < total:
        j0 = int(np.searchsorted(offsets, lo, side="right") - 1)
        hi = min(lo + int(max_items), total,
                 int(offsets[min(j0 + int(desc_shape), num_pairs)]))
        yield descriptor_window(offsets, lo, hi, desc_shape, num_anchors,
                                pair_ids=pair_ids)
        lo = hi


def base_for_pairs(space: PairSpace, pair_ids) -> tuple[int, int]:
    """Subset-additive ``(base_asym, base_mut)`` closed-form shares for an
    arbitrary pair subset; over a partition of all pairs these sum exactly
    to :func:`global_bases`."""
    ids = np.asarray(pair_ids, dtype=np.int64).ravel()
    mut = space.pair_mut[ids]
    term = space.pair_term[ids]
    return int(term[~mut].sum()), int(term[mut].sum())


def pad_and_pack(item_pair: np.ndarray, item_slot: np.ndarray,
                 item_side: np.ndarray, length: int
                 ) -> tuple[np.ndarray, np.ndarray]:
    """Pad emitted items with invalid (all-zero) entries to ``length`` and
    fold them into the two packed int32 words — the one padding/packing
    convention shared by the monolithic plan and every streamed chunk."""
    num_items = item_pair.shape[0]
    pad = length - num_items
    item_pair = np.concatenate([item_pair, np.zeros(pad, np.int64)])
    item_slot = np.concatenate([item_slot, np.zeros(pad, np.int64)])
    item_side = np.concatenate([item_side, np.zeros(pad, np.int8)])
    item_valid = np.concatenate(
        [np.ones(num_items, bool), np.zeros(pad, bool)])
    return pack_items(item_slot, item_side, item_pair, item_valid)


@dataclass(frozen=True)
class CensusPlan:
    """Flattened iteration space + exact host-side closed-form terms."""

    n: int
    num_pairs: int
    num_items: int             #: pre-padding work-item count W
    max_degree: int
    search_iters: int          #: binary-search depth = ceil(log2(max_deg+1))
    orient: str                #: "none" or "degree"

    # device arrays (int32): graph
    indptr: np.ndarray         #: (n+1,)
    packed: np.ndarray         #: (2*pairs,)
    # canonical pairs
    pair_u: np.ndarray         #: (P,)
    pair_v: np.ndarray         #: (P,)
    pair_code: np.ndarray      #: (P,) dyad code in {1,2,3} | inter_side << 2
    # flat work items (padded to `pad_to`), packed two-words-per-item
    item_sp: np.ndarray        #: (Wp,) ``slot << 1 | side``
    item_pv: np.ndarray        #: (Wp,) ``pair << 1 | valid``

    # exact int64 host terms for the dyadic (012/102) closed forms:
    # census[t] = base_t + (# intersections found on device for pairs of t)
    base_asym: int
    base_mut: int

    # --- legacy per-field views (decoded on access; device code should
    # --- ship the packed words and decode in-graph) -----------------------
    @property
    def item_slot(self) -> np.ndarray:
        return self.item_sp >> 1

    @property
    def item_side(self) -> np.ndarray:
        return (self.item_sp & 1).astype(np.int32)

    @property
    def item_pair(self) -> np.ndarray:
        return self.item_pv >> 1

    @property
    def item_valid(self) -> np.ndarray:
        return (self.item_pv & 1).astype(bool)

    def preprune_index(self) -> np.ndarray:
        """Map each (padded) plan item to its pre-prune flat index.

        This is the coordinate system :mod:`repro.core.plan_stream` chunks
        over, recovered from the packed words alone; padding items map to
        index 0 (they are invalid and never counted).
        """
        item_slot, item_side, item_pair, item_valid = unpack_items(
            self.item_sp, self.item_pv)
        deg = np.diff(self.indptr).astype(np.int64)
        u = self.pair_u.astype(np.int64)[item_pair]
        v = self.pair_v.astype(np.int64)[item_pair]
        within = np.where(
            item_side == 0,
            item_slot - self.indptr[u],
            deg[u] + item_slot - self.indptr[v])
        counts = deg[self.pair_u.astype(np.int64)] + \
            deg[self.pair_v.astype(np.int64)]
        offsets = np.zeros(self.num_pairs + 1, dtype=np.int64)
        np.cumsum(counts, out=offsets[1:])
        return np.where(item_valid, offsets[item_pair] + within, 0)

    def balance_stats(self, num_shards: int,
                      max_items: int | None = None) -> dict[str, float]:
        """Work-imbalance metrics (paper Fig 9 utilization analogue).

        Compares the flat plan against pair-granular partitioning (what a
        naive parallel-for over pairs would give on a power-law graph).

        With ``max_items`` set, additionally reports the *streamed*
        schedule that :class:`repro.core.engine.CensusEngine` would run:
        per-chunk valid-item counts and their max-over-mean imbalance
        (chunks are equal slices of the pre-prune item space, so post-prune
        counts per chunk wobble with the local prune rate).
        """
        wp = self.item_pv.shape[0]
        flat_max = -(-wp // num_shards) if wp else 0
        flat_mean = wp / num_shards
        # pair-granular: contiguous pair blocks, shard work = sum of costs
        # (single O(W) decode instead of one per property access)
        _, _, item_pair, item_valid = unpack_items(self.item_sp,
                                                   self.item_pv)
        cost = np.bincount(item_pair[item_valid],
                           minlength=self.num_pairs).astype(np.int64)
        bounds = np.linspace(0, self.num_pairs, num_shards + 1).astype(int)
        per = np.add.reduceat(cost, bounds[:-1]) if self.num_pairs else \
            np.zeros(num_shards)
        stats = {
            "flat_max_over_mean":
                flat_max / max(flat_mean, 1e-9) if wp else 1.0,
            "pair_max_over_mean": float(per.max() / max(per.mean(), 1e-9))
            if self.num_pairs else 1.0,
            "items": int(self.num_items),
            "pairs": int(self.num_pairs),
        }
        if max_items is not None:
            stats.update(self.chunk_stats(max_items))
        return stats

    def chunk_stats(self, max_items: int) -> dict:
        """Streamed-schedule stats for a ``max_items`` chunk budget:
        number of chunks, per-chunk valid item counts, and the
        max-over-mean chunk imbalance (1.0 == perfectly even chunks)."""
        if max_items < 1:
            raise ValueError("max_items must be >= 1")
        pre = self.preprune_index()
        valid = self.item_valid
        deg = np.diff(self.indptr).astype(np.int64)
        w_pre = int((deg[self.pair_u.astype(np.int64)]
                     + deg[self.pair_v.astype(np.int64)]).sum())
        num_chunks = max(-(-w_pre // max_items), 1) if w_pre else 0
        chunk_items = np.bincount(pre[valid] // max_items,
                                  minlength=max(num_chunks, 1))[
            :max(num_chunks, 1)] if num_chunks else np.zeros(0, np.int64)
        mean = chunk_items.mean() if num_chunks else 0.0
        return {
            "chunks": int(num_chunks),
            "chunk_items": chunk_items.astype(int).tolist(),
            "chunk_max_over_mean":
                float(chunk_items.max() / max(mean, 1e-9))
                if num_chunks else 1.0,
        }


def build_plan(g: CompactDigraph, pad_to: int = 1,
               prune_self: bool = True, orient: str = "none") -> CensusPlan:
    """Construct the flat census plan for a compact graph.

    This is the one-chunk special case of the streaming planner: the whole
    pre-prune item space is emitted as a single :func:`emit_items` slice,
    so host memory is O(W).  For graphs whose W outgrows host RAM use
    :class:`repro.core.engine.CensusEngine` with a ``max_items`` budget,
    which never materializes more than one chunk.

    ``prune_self`` drops the two guaranteed no-op items per pair (the
    slot where N(u) contains v itself and vice versa) at plan time — a
    beyond-paper optimization worth 2·P of the W work items (§Perf).

    ``orient="degree"`` additionally (a) assigns intersection-witness duty
    to each pair's lower-degree endpoint and (b) drops every item that can
    neither witness the intersection nor satisfy the canonical counting
    predicate (see module docstring).  Implies ``prune_self`` semantics.
    The resulting plan is accepted by every backend and yields bit-identical
    censuses.

    A plan with zero work items (possible with pairs present — e.g. a
    single mutual dyad, whose only items are self-items) has zero-length
    item arrays; both census drivers resolve such plans entirely from the
    closed-form bases without a device dispatch.
    """
    space = pair_space(g, orient=orient, prune_self=prune_self)
    item_pair, item_slot, item_side = emit_items(
        space, 0, space.num_items_preprune)
    num_items = int(item_pair.shape[0])

    # pad the flat plan to a multiple of the shard count (a zero-item plan
    # stays zero-length — no phantom padded items)
    wp = -(-num_items // pad_to) * pad_to
    if wp >= 2**31:
        raise PlanOverflowError(
            "plan exceeds int32 packed-item indexing; "
            "stream it in chunks (CensusEngine max_items) "
            "or shard the graph first")
    item_sp, item_pv = pad_and_pack(item_pair, item_slot, item_side, wp)
    base_asym, base_mut = global_bases(space)
    return CensusPlan(
        n=space.n, num_pairs=space.num_pairs, num_items=num_items,
        max_degree=space.max_degree, search_iters=space.search_iters,
        orient=orient,
        indptr=space.indptr.astype(np.int32), packed=space.packed,
        pair_u=space.pair_u.astype(np.int32),
        pair_v=space.pair_v.astype(np.int32),
        pair_code=space.pair_code,
        item_sp=item_sp, item_pv=item_pv,
        base_asym=base_asym, base_mut=base_mut)


def global_bases(space: PairSpace) -> tuple[int, int]:
    """Exact closed-form dyadic bases summed over all pairs."""
    base_mut = int(space.pair_term[space.pair_mut].sum())
    base_asym = int(space.pair_term[~space.pair_mut].sum())
    return base_asym, base_mut
