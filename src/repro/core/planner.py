"""Host-side work planner — the paper's "manhattan collapse", reified.

The imperfectly nested loops ``for u in V / for v in N(u), u < v / for w in
N(u) ∪ N(v)`` are flattened into dense arrays of *work items*, one item per
(canonical pair, neighbor slot).  Equal-sized chunks of this flat plan give
the perfect static load balance the paper obtained from OpenMP ``dynamic``
scheduling / the XMT's thread virtualization — except here the balance is
exact by construction and measurable ahead of time.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.digraph import CompactDigraph


@dataclass(frozen=True)
class CensusPlan:
    """Flattened iteration space + exact host-side closed-form terms."""

    n: int
    num_pairs: int
    num_items: int             #: pre-padding work-item count W
    max_degree: int
    search_iters: int          #: binary-search depth = ceil(log2(max_deg+1))

    # device arrays (int32): graph
    indptr: np.ndarray         #: (n+1,)
    packed: np.ndarray         #: (2*pairs,)
    # canonical pairs
    pair_u: np.ndarray         #: (P,)
    pair_v: np.ndarray         #: (P,)
    pair_code: np.ndarray      #: (P,) dyad code of (u, v) in {1,2,3}
    # flat work items (padded to `pad_to`)
    item_pair: np.ndarray      #: (Wp,) index into pair arrays
    item_slot: np.ndarray      #: (Wp,) index into `packed`
    item_side: np.ndarray      #: (Wp,) 0 = slot from N(u), 1 = from N(v)
    item_valid: np.ndarray     #: (Wp,) bool padding mask

    # exact int64 host terms for the dyadic (012/102) closed forms:
    # census[t] = base_t + (# intersections found on device for pairs of t)
    base_asym: int
    base_mut: int

    def balance_stats(self, num_shards: int) -> dict[str, float]:
        """Work-imbalance metrics (paper Fig 9 utilization analogue).

        Compares the flat plan against pair-granular partitioning (what a
        naive parallel-for over pairs would give on a power-law graph).
        """
        wp = self.item_valid.shape[0]
        flat_max = -(-wp // num_shards)
        flat_mean = wp / num_shards
        # pair-granular: contiguous pair blocks, shard work = sum of costs
        cost = np.bincount(self.item_pair[self.item_valid],
                           minlength=self.num_pairs).astype(np.int64)
        bounds = np.linspace(0, self.num_pairs, num_shards + 1).astype(int)
        per = np.add.reduceat(cost, bounds[:-1]) if self.num_pairs else \
            np.zeros(num_shards)
        return {
            "flat_max_over_mean": flat_max / max(flat_mean, 1e-9),
            "pair_max_over_mean": float(per.max() / max(per.mean(), 1e-9))
            if self.num_pairs else 1.0,
            "items": int(self.num_items),
            "pairs": int(self.num_pairs),
        }


def build_plan(g: CompactDigraph, pad_to: int = 1,
               prune_self: bool = True) -> CensusPlan:
    """Construct the flat census plan for a compact graph.

    ``prune_self`` drops the two guaranteed no-op items per pair (the
    slot where N(u) contains v itself and vice versa) at plan time — a
    beyond-paper optimization worth 2·P of the W work items (§Perf).
    """
    n = g.n
    indptr, packed = g.indptr, g.packed
    nbr = packed >> 2
    deg = g.degrees

    # canonical pairs: CSR entries with nbr > row
    rows = np.repeat(np.arange(n, dtype=np.int64), deg)
    canon = nbr > rows
    pair_u = rows[canon]
    pair_v = nbr[canon].astype(np.int64)
    pair_code = (packed[canon] & 3).astype(np.int32)
    num_pairs = pair_u.shape[0]

    deg_u, deg_v = deg[pair_u], deg[pair_v]
    counts = deg_u + deg_v
    num_items = int(counts.sum())

    offsets = np.zeros(num_pairs + 1, dtype=np.int64)
    np.cumsum(counts, out=offsets[1:])
    item_pair = np.repeat(np.arange(num_pairs, dtype=np.int64), counts)
    within = np.arange(num_items, dtype=np.int64) - offsets[item_pair]
    item_side = (within >= deg_u[item_pair]).astype(np.int8)
    item_slot = np.where(
        item_side == 0,
        indptr[pair_u[item_pair]] + within,
        indptr[pair_v[item_pair]] + within - deg_u[item_pair])

    if prune_self and num_items:
        w_ids = nbr[item_slot]
        keep = ~(((item_side == 0) & (w_ids == pair_v[item_pair])) |
                 ((item_side == 1) & (w_ids == pair_u[item_pair])))
        item_pair = item_pair[keep]
        item_slot = item_slot[keep]
        item_side = item_side[keep]
        num_items = int(item_pair.shape[0])

    # pad the flat plan to a multiple of the shard count
    wp = -(-max(num_items, 1) // pad_to) * pad_to
    pad = wp - num_items
    item_pair = np.concatenate([item_pair, np.zeros(pad, np.int64)])
    item_slot = np.concatenate([item_slot, np.zeros(pad, np.int64)])
    item_side = np.concatenate([item_side, np.zeros(pad, np.int8)])
    item_valid = np.concatenate(
        [np.ones(num_items, bool), np.zeros(pad, bool)])

    # closed-form dyadic bases: sum over pairs of (n - deg_u - deg_v)
    term = (n - deg_u - deg_v).astype(np.int64)
    mut = pair_code == 3
    base_mut = int(term[mut].sum())
    base_asym = int(term[~mut].sum())

    max_deg = int(deg.max()) if n else 0
    if wp >= 2**31 or packed.shape[0] >= 2**31:
        raise ValueError("plan exceeds int32 indexing; shard the graph first")
    return CensusPlan(
        n=n, num_pairs=num_pairs, num_items=num_items, max_degree=max_deg,
        search_iters=max(1, int(np.ceil(np.log2(max_deg + 1)))),
        indptr=indptr.astype(np.int32), packed=packed,
        pair_u=pair_u.astype(np.int32), pair_v=pair_v.astype(np.int32),
        pair_code=pair_code,
        item_pair=item_pair.astype(np.int32),
        item_slot=item_slot.astype(np.int32),
        item_side=item_side.astype(np.int32),
        item_valid=item_valid,
        base_asym=base_asym, base_mut=base_mut)
