"""Persistent delta-incremental pair-space index.

:func:`~repro.core.planner.pair_space` rebuilds the full O(P) canonical
pair decomposition from scratch — canonical-pair extraction, per-pair
counts, prefix offsets, closed-form terms — which is fine for a one-shot
census but dominates the host side of a *warm* sliding-window update,
where the delta touches a handful of rows and the device work is already
delta-sized (EXPERIMENTS.md "Incremental monitoring").

:class:`PairSpaceIndex` keeps the decomposition alive between updates and
edits it in place of rebuilding:

* the sorted canonical pair keys ``u * n + v`` are cached, so a
  :class:`~repro.core.digraph.GraphDelta` maps onto the pair arrays with
  O(delta · log P) binary searches;
* structural changes (pairs appearing/vanishing) are array splices at
  those searched positions — vectorized memmoves, no re-sort;
* per-pair counts, closed-form terms, orientation bits and post-prune
  costs are recomputed only for the *affected* pairs (those with a
  touched endpoint), found by walking just the touched CSR rows —
  the CSR itself is the vertex→pair reverse index;
* :meth:`affected_pair_ids` answers the incremental census's discovery
  query from the same touched-row walk instead of the O(P) mask scan of
  :func:`repro.core.incremental.affected_pair_ids`.

The produced :class:`~repro.core.planner.PairSpace` is **bit-identical**
(array for array, dtype for dtype) to ``pair_space(g_new, ...)`` — the
full rebuild stays available as the parity oracle (sessions expose it as
``index=False``) and the test suite asserts the equivalence under
randomized delta streams.

Every ``apply`` cross-checks the delta's ``old_code`` against the codes
the index is tracking; a mismatch means the index has drifted from the
graph it claims to mirror (stale handle, external mutation, bit rot) and
raises :class:`IndexCorruptionError` instead of silently producing a
wrong plan.  :meth:`verify` runs the full fingerprint check on demand.
"""

from __future__ import annotations

import zlib

import numpy as np

from repro.core.digraph import CompactDigraph, GraphDelta, SplicePlan
from repro.core.planner import (
    INTER_SIDE_BIT, PairSpace, pair_space, postprune_pair_counts)


class IndexCorruptionError(ValueError):
    """The persistent pair-space index no longer matches the graph it
    claims to track (fingerprint / pair-code mismatch)."""


def _touched_pair_keys(indptr: np.ndarray, nbr: np.ndarray, n: int,
                       touched: np.ndarray) -> np.ndarray:
    """Canonical pair keys ``lo * n + hi`` of every pair with an endpoint
    in ``touched``, read off the touched CSR rows (sorted, deduplicated).

    O(Σ deg(touched)) — the CSR is its own vertex→pair reverse index:
    vertex u's adjacent pairs are exactly {canonical(u, w) : w ∈ N(u)}.
    """
    if touched.size == 0 or indptr[-1] == 0:
        return np.zeros(0, dtype=np.int64)
    starts = indptr[touched]
    degs = (indptr[touched + 1] - starts).astype(np.int64)
    total = int(degs.sum())
    if total == 0:
        return np.zeros(0, dtype=np.int64)
    off = np.zeros(touched.shape[0], dtype=np.int64)
    np.cumsum(degs[:-1], out=off[1:])
    sel = np.repeat(starts - off, degs) + np.arange(total, dtype=np.int64)
    nb = nbr[sel].astype(np.int64)
    rw = np.repeat(touched.astype(np.int64), degs)
    keys = np.where(nb > rw, rw * n + nb, nb * n + rw)
    return np.unique(keys)


class PairSpaceIndex:
    """Live pair-space over one graph, editable by :class:`GraphDelta`.

    Parameters mirror :func:`~repro.core.planner.pair_space`; the initial
    build IS a full ``pair_space`` call (the open of a session is O(P)
    either way) — the index earns its keep on every update after it.
    """

    def __init__(self, g: CompactDigraph, orient: str = "none",
                 prune_self: bool = True, *,
                 space: PairSpace | None = None,
                 track_costs: bool = True):
        if space is None:
            space = pair_space(g, orient=orient, prune_self=prune_self)
        elif space.orient != orient or space.prune_self != prune_self:
            raise ValueError("prebuilt space disagrees with orient/prune")
        self._space = space
        self._keys = space.pair_u * space.n + space.pair_v
        #: maintained post-prune cost vector; only the partitioned
        #: sessions route on it, so plain sessions opt out
        #: (``track_costs=False``) and skip its splice + subset recount
        self._costs = postprune_pair_counts(space) if track_costs else None
        self._crc: int | None = zlib.crc32(space.packed)
        #: (touched, affected ids) of the last ``apply`` — re-served to
        #: the session's post-apply discovery query without re-walking
        self._aff_cache: tuple | None = None

    def _packed_crc(self) -> int:
        """The tracked CSR's crc, computed lazily after an ``apply``
        (which re-anchors the fingerprint on the new graph instead of
        hashing O(E) bytes on the hot path)."""
        if self._crc is None:
            self._crc = zlib.crc32(self._space.packed)
        return self._crc

    # ------------------------------------------------------------ views
    @property
    def space(self) -> PairSpace:
        """The tracked :class:`PairSpace` (bit-identical to a rebuild)."""
        return self._space

    @property
    def keys(self) -> np.ndarray:
        """(P,) sorted canonical pair keys ``pair_u * n + pair_v``."""
        return self._keys

    @property
    def costs(self) -> np.ndarray:
        """(P,) maintained :func:`postprune_pair_counts` of the space —
        the per-pair cost vector partition owner routing balances on.
        With ``track_costs=False`` this falls back to a full recount."""
        if self._costs is None:
            return postprune_pair_counts(self._space)
        return self._costs

    @property
    def fingerprint(self) -> dict:
        """Identity of the tracked graph + plan policy."""
        return {"n": self._space.n, "orient": self._space.orient,
                "prune_self": self._space.prune_self,
                "pairs": self._space.num_pairs,
                "packed_crc": self._packed_crc()}

    # ------------------------------------------------------- validation
    def verify(self, g: CompactDigraph | None = None) -> None:
        """Full consistency check; raises :class:`IndexCorruptionError`.

        Confirms the cached keys still mirror the pair arrays, the packed
        CSR still hashes to the recorded fingerprint, and (when ``g`` is
        given) that the index is tracking *that* graph.
        """
        sp = self._space
        crc = self._packed_crc()
        if zlib.crc32(sp.packed) != crc:
            raise IndexCorruptionError(
                "pair-space index fingerprint mismatch: tracked CSR no "
                f"longer hashes to {crc} — the graph was mutated "
                "behind the index")
        keys = sp.pair_u * sp.n + sp.pair_v
        if not np.array_equal(keys, self._keys):
            raise IndexCorruptionError(
                "pair-space index key cache disagrees with the pair "
                "arrays — index state is corrupted")
        if keys.size > 1 and not (np.diff(keys) > 0).all():
            raise IndexCorruptionError(
                "pair-space index keys are not strictly ascending")
        if g is not None and zlib.crc32(g.packed) != self._packed_crc():
            raise IndexCorruptionError(
                "pair-space index tracks a different graph than the one "
                "passed (packed CSR fingerprints differ)")

    # --------------------------------------------------------- queries
    def affected_pair_ids(self, touched: np.ndarray) -> np.ndarray:
        """Ids (into the tracked space) of every pair with an endpoint in
        ``touched`` — O(Σ deg(touched) · log P) via the touched-row walk,
        equal to :func:`repro.core.incremental.affected_pair_ids`'s O(P)
        scan of the same space.
        """
        if self._aff_cache is not None and self._aff_cache[0] is touched:
            return self._aff_cache[1]
        sp = self._space
        touched = np.asarray(touched, dtype=np.int64)
        keys = _touched_pair_keys(sp.indptr, sp.nbr, sp.n, touched)
        return np.searchsorted(self._keys, keys)

    # ----------------------------------------------------------- apply
    def apply(self, delta: GraphDelta, g_new: CompactDigraph) -> PairSpace:
        """Edit the tracked space into the pair space of ``g_new``.

        ``(g_new, delta)`` must come from
        :func:`~repro.core.digraph.apply_delta` on the tracked graph.
        Host cost: O(delta · log P) searches + O(affected · log m)
        recounts + the vectorized memmoves of the splice; no sorting, no
        full recount.  Returns the new space (also ``self.space``).
        """
        sp = self._space
        n = sp.n
        if delta.n != n or g_new.n != n:
            raise ValueError(f"delta/graph vertex count != index n={n}")
        if delta.num_changed == 0:
            return sp

        dkeys = delta.pair_lo * n + delta.pair_hi
        old_code, new_code = delta.old_code, delta.new_code
        if dkeys.size > 1 and not (np.diff(dkeys) > 0).all():
            order = np.argsort(dkeys, kind="stable")
            dkeys = dkeys[order]
            old_code, new_code = old_code[order], new_code[order]

        # the delta's old codes must be the codes the index is tracking —
        # anything else means the index drifted from its graph
        num = self._keys.shape[0]
        pos = np.searchsorted(self._keys, dkeys)
        if num:
            safe = np.minimum(pos, num - 1)
            found = (pos < num) & (self._keys[safe] == dkeys)
            here = np.where(found,
                            (sp.pair_code[safe] & 3).astype(np.int64), 0)
        else:
            here = np.zeros(dkeys.shape[0], dtype=np.int64)
        if not np.array_equal(here, old_code):
            raise IndexCorruptionError(
                "delta old codes disagree with the tracked pair codes — "
                "the index is stale or corrupted (expected fingerprint "
                f"{self.fingerprint})")
        if g_new.packed.shape[0] >= 2**30:
            raise ValueError("graph exceeds int32 packed-item indexing "
                             "(need slots < 2**30); shard the graph first")

        vanish = new_code == 0
        appear = old_code == 0
        recode = ~vanish & ~appear
        new32 = new_code.astype(np.int32)

        if vanish.any() or appear.any():
            # one shared :class:`~repro.core.digraph.SplicePlan` edits
            # every pair array with a single fancy gather plus a
            # delta-sized store — np.delete + np.insert semantics
            # without their per-array masking passes
            plan = SplicePlan(num, pos[vanish], pos[appear])
            keys = plan.splice(self._keys, dkeys[appear])
            pair_u = plan.splice(sp.pair_u, dkeys[appear] // n)
            pair_v = plan.splice(sp.pair_v, dkeys[appear] % n)
            pair_code = plan.splice(sp.pair_code, new32[appear])
            if recode.any():
                # recoded pairs survive; re-address them post-splice
                pair_code[plan.readdress(pos[recode])] = new32[recode]
            counts = plan.splice(sp.counts, 0)   # recounted below
            pair_term = plan.splice(sp.pair_term, 0)
            costs = (None if self._costs is None
                     else plan.splice(self._costs, 0))
        else:
            keys = self._keys
            pair_u, pair_v = sp.pair_u, sp.pair_v
            pair_code = sp.pair_code.copy()
            if num:
                pair_code[pos[recode]] = new32[recode]
            counts = sp.counts.copy()
            pair_term = sp.pair_term.copy()
            costs = None if self._costs is None else self._costs.copy()

        # recount exactly the pairs with a touched endpoint — degrees,
        # closed-form terms, orientation side and post-prune costs of
        # every other pair are untouched by construction
        deg = g_new.degrees
        nbr = g_new.packed >> 2
        aff_keys = _touched_pair_keys(g_new.indptr, nbr, n, delta.touched)
        aff = np.searchsorted(keys, aff_keys)
        deg_u = deg[pair_u[aff]]
        deg_v = deg[pair_v[aff]]
        counts[aff] = deg_u + deg_v
        pair_term[aff] = n - deg_u - deg_v
        if sp.orient == "degree" and aff.size:
            inter = (deg_v < deg_u).astype(np.int32)
            pair_code[aff] = ((pair_code[aff] & 3)
                              | (inter << INTER_SIDE_BIT))

        offsets = np.zeros(keys.shape[0] + 1, dtype=np.int64)
        np.cumsum(counts, out=offsets[1:])
        max_deg = int(deg.max()) if n else 0
        space_new = PairSpace(
            n=n, orient=sp.orient, prune_self=sp.prune_self,
            max_degree=max_deg,
            search_iters=max(1, int(np.ceil(np.log2(max_deg + 1)))),
            indptr=g_new.indptr, packed=g_new.packed, nbr=nbr, deg=deg,
            pair_u=pair_u, pair_v=pair_v, pair_code=pair_code,
            counts=counts, offsets=offsets, pair_term=pair_term,
            pair_mut=(pair_code & 3) == 3)
        if costs is not None:
            costs[aff] = postprune_pair_counts(
                space_new, aff, entry_key=g_new.ekey_cache)

        self._space = space_new
        self._keys = keys
        self._costs = costs
        self._crc = None                 # re-anchored lazily on g_new
        self._aff_cache = (delta.touched, aff)
        return space_new
