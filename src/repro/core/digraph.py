"""Compact directed-graph structure (paper Fig 7).

Compressed sparse row over the *symmetrized* adjacency: each unordered
adjacent pair {u, w} contributes one entry to u's row and one to w's row.
An entry packs ``(neighbor_id << 2) | dir_code`` where the 2-bit dir code is
relative to the row owner ``u``::

    bit 0: u -> w  ("01" unidirectional current -> neighbor)
    bit 1: w -> u  ("10" unidirectional neighbor -> current)
    "11": bidirectional

Rows are sorted by neighbor id (packing preserves order: id occupies the
high bits), enabling binary search — exactly the paper's layout.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.tricode import swap_code


@dataclass(frozen=True)
class CompactDigraph:
    """CSR-with-direction-bits graph container (host-side, numpy)."""

    n: int                     #: number of vertices
    indptr: np.ndarray         #: (n+1,) int64 row offsets
    packed: np.ndarray         #: (2*pairs,) int32 ``(nbr << 2) | code``
    num_arcs: int              #: directed edge count (after dedup)
    #: lazily built sorted ``row * n + nbr`` entry keys
    #: (:func:`entry_keys`); :func:`apply_delta` splices the cache
    #: forward so warm updates skip the O(m) rebuild
    ekey_cache: np.ndarray | None = field(
        default=None, repr=False, compare=False)

    @property
    def degrees(self) -> np.ndarray:
        return np.diff(self.indptr)

    @property
    def num_pairs(self) -> int:
        """Number of unordered adjacent pairs (undirected edges)."""
        return self.packed.shape[0] // 2

    def neighbors(self, u: int) -> np.ndarray:
        return self.packed[self.indptr[u]:self.indptr[u + 1]] >> 2

    def codes(self, u: int) -> np.ndarray:
        return self.packed[self.indptr[u]:self.indptr[u + 1]] & 3

    def validate(self) -> None:
        deg = self.degrees
        assert (deg >= 0).all() and self.indptr[-1] == self.packed.shape[0]
        nbr = self.packed >> 2
        # rows sorted strictly (no duplicate neighbors within a row) —
        # vectorized: every adjacent CSR entry must increase unless the
        # boundary between two rows falls there
        if nbr.shape[0] > 1:
            rising = np.diff(nbr) > 0
            crossing = np.zeros(nbr.shape[0] - 1, dtype=bool)
            bounds = np.asarray(self.indptr[1:-1], dtype=np.int64)
            bounds = bounds[(bounds > 0) & (bounds < nbr.shape[0])]
            crossing[bounds - 1] = True
            bad = ~(rising | crossing)
            if bad.any():
                at = np.nonzero(bad)[0][0]
                u = int(np.searchsorted(self.indptr, at, side="right") - 1)
                raise AssertionError(f"row {u} not strictly sorted")
        assert ((self.packed & 3) != 0).all(), "zero dir code"


def clean_arcs(src, dst, n: int | None = None
               ) -> tuple[np.ndarray, np.ndarray, int]:
    """Validate, ravel and dedupe a directed edge list.

    Self-loops are dropped and duplicate directed edges deduplicated,
    matching the paper's preprocessing of the raw edge lists.  Returns
    ``(src, dst, n)`` with arcs sorted by ``src * n + dst``.
    """
    src = np.asarray(src)
    dst = np.asarray(dst)
    if src.dtype == object or dst.dtype == object:
        raise ValueError(
            "ragged edge arrays: src/dst must be rectangular numeric "
            "arrays (got object dtype — rows of unequal length?)")
    for name, a in (("src", src), ("dst", dst)):
        if np.issubdtype(a.dtype, np.floating) \
                and not np.isfinite(a).all():
            raise ValueError(f"non-finite vertex id (NaN/inf) in {name}")
    src = src.astype(np.int64).ravel()
    dst = dst.astype(np.int64).ravel()
    if src.shape != dst.shape:
        raise ValueError(
            f"src/dst length mismatch: {src.shape[0]} != {dst.shape[0]}")
    if n is None:
        n = int(max(src.max(initial=-1), dst.max(initial=-1))) + 1
    if src.size and (src.min() < 0 or dst.min() < 0
                     or max(src.max(), dst.max()) >= n):
        bad = int(min(src.min(), dst.min()))
        if bad >= 0:
            bad = int(max(src.max(), dst.max()))
        raise ValueError(
            f"vertex id {bad} out of range [0, {n}) — ids must index "
            f"the fixed n={n} vertex space")
    keep = src != dst
    src, dst = src[keep], dst[keep]
    eid = np.unique(src * n + dst)
    return eid // n, eid % n, int(n)


def arcs_to_pairs(src, dst, n: int
                  ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Aggregate clean arcs into canonical unordered pairs.

    Returns ``(plo, phi, code)`` with ``plo < phi`` ascending by pair key
    and 2-bit codes (1: lo->hi, 2: hi->lo, 3: mutual) — the pair
    decomposition shared by :func:`from_edges` and :func:`apply_delta`.
    """
    lo, hi = np.minimum(src, dst), np.maximum(src, dst)
    pkey = lo * n + hi
    bit = np.where(src < dst, 1, 2).astype(np.int64)   # 1: lo->hi, 2: hi->lo
    order = np.argsort(pkey, kind="stable")
    pkey, bit = pkey[order], bit[order]
    uniq, start = np.unique(pkey, return_index=True)
    # OR the bits per pair (bits are distinct per directed edge after dedup)
    code = np.bitwise_or.reduceat(bit, start) if uniq.size else bit[:0]
    return uniq // n, uniq % n, code


def from_pairs(n: int, plo: np.ndarray, phi: np.ndarray, code: np.ndarray,
               num_arcs: int | None = None) -> CompactDigraph:
    """Build the CSR structure from canonical pairs (``plo < phi``, codes
    in {1, 2, 3}) — the second half of :func:`from_edges`, reusable by the
    incremental :func:`apply_delta` edit path."""
    plo = np.asarray(plo, dtype=np.int64)
    phi = np.asarray(phi, dtype=np.int64)
    code = np.asarray(code, dtype=np.int64)
    if num_arcs is None:
        num_arcs = int(((code & 1) != 0).sum() + ((code & 2) != 0).sum())

    # each pair emits two CSR entries: (plo: phi, code) and (phi: plo, swap)
    rows = np.concatenate([plo, phi])
    nbrs = np.concatenate([phi, plo])
    codes = np.concatenate([code, swap_code(code)])

    deg = np.bincount(rows, minlength=n).astype(np.int64)
    indptr = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(deg, out=indptr[1:])
    order = np.lexsort((nbrs, rows))
    packed = ((nbrs[order] << 2) | codes[order]).astype(np.int64)
    if packed.size and packed.max() >= 2**31:
        raise ValueError("graph too large for int32 packing; need n < 2^29")
    return CompactDigraph(n=int(n), indptr=indptr,
                          packed=packed.astype(np.int32),
                          num_arcs=int(num_arcs))


def from_edges(src, dst, n: int | None = None) -> CompactDigraph:
    """Build the compact structure from directed edge arrays.

    Self-loops are dropped and duplicate directed edges deduplicated,
    matching the paper's preprocessing of the raw edge lists.  Composed
    from the exposed stages :func:`clean_arcs` → :func:`arcs_to_pairs` →
    :func:`from_pairs`.
    """
    src, dst, n = clean_arcs(src, dst, n)
    plo, phi, code = arcs_to_pairs(src, dst, n)
    return from_pairs(n, plo, phi, code, num_arcs=src.shape[0])


def canonical_pairs(g: CompactDigraph
                    ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Extract the canonical pair decomposition ``(pu, pv, code)`` from a
    CSR graph: one entry per unordered adjacent pair with ``pu < pv``,
    ascending by pair key, code relative to (pu, pv)."""
    rows = np.repeat(np.arange(g.n, dtype=np.int64), g.degrees)
    nbr = (g.packed >> 2).astype(np.int64)
    canon = nbr > rows
    return rows[canon], nbr[canon], (g.packed[canon] & 3).astype(np.int64)


@dataclass(frozen=True)
class GraphDelta:
    """Record of the pairs perturbed by one :func:`apply_delta` edit.

    ``old_code == 0`` marks a pair that appeared, ``new_code == 0`` one
    that disappeared; every listed pair satisfies ``old != new``.
    ``touched`` is the set of vertices whose CSR row changed — exactly the
    endpoints of the changed pairs — which is what the incremental census
    (:mod:`repro.core.incremental`) keys its affected-pair discovery on.
    """

    n: int
    pair_lo: np.ndarray        #: (C,) int64, lo < hi
    pair_hi: np.ndarray        #: (C,) int64
    old_code: np.ndarray       #: (C,) int64 dyad code in g_old (0 absent)
    new_code: np.ndarray       #: (C,) int64 dyad code in g_new (0 absent)
    touched: np.ndarray = field(default=None)  #: vertices with changed rows

    def __post_init__(self):
        if self.touched is None:
            object.__setattr__(self, "touched", np.unique(
                np.concatenate([self.pair_lo, self.pair_hi])))

    @property
    def num_changed(self) -> int:
        return self.pair_lo.shape[0]


def _lookup_pair_codes(g: CompactDigraph, keys: np.ndarray,
                       entry_key: np.ndarray | None = None) -> np.ndarray:
    """Dyad code of each canonical pair key ``lo * n + hi`` in ``g``
    (0 where the pair is not adjacent).  O(|keys| log m) via the globally
    sorted CSR entry keys (pass a precomputed ``entry_key`` to skip the
    O(m) key materialization)."""
    if g.packed.size == 0 or keys.size == 0:
        return np.zeros(keys.shape[0], dtype=np.int64)
    if entry_key is None:
        entry_key = entry_keys(g)
    pos = np.searchsorted(entry_key, keys)
    safe = np.minimum(pos, entry_key.shape[0] - 1)
    hit = (pos < entry_key.shape[0]) & (entry_key[safe] == keys)
    return np.where(hit, (g.packed[safe] & 3).astype(np.int64), 0)


def entry_keys(g: CompactDigraph) -> np.ndarray:
    """Strictly ascending ``row * n + nbr`` key of every CSR entry — the
    binary-searchable global address space of the adjacency structure.
    Cached on the graph; :func:`apply_delta` keeps the cache alive by
    splicing it into the edited graph's."""
    if g.ekey_cache is not None:
        return g.ekey_cache
    rows = np.repeat(np.arange(g.n, dtype=np.int64), g.degrees)
    ek = rows * g.n + (g.packed >> 2)
    object.__setattr__(g, "ekey_cache", ek)
    return ek


class SplicePlan:
    """Vectorized delete-and-insert plan over a length-``num`` sorted
    array family (``np.delete`` + ``np.insert`` semantics in one pass).

    ``del_pos`` (sorted, distinct) are positions to drop; ``ins_pos``
    (sorted, possibly duplicated) are *pre-deletion* insertion points.
    The plan precomputes one shared source permutation: survivor slots
    shift right by the insertions at or before them, insertion points
    shift left by the deletions preceding them — both monotone step
    functions materialized with O(num) repeats, no per-array masking
    and no O(num log delta) searches.  :meth:`splice` then edits any
    number of parallel arrays with a single fancy gather plus a
    delta-sized store each; :meth:`readdress` maps a surviving
    position to its post-splice slot.
    """

    __slots__ = ("del_pos", "ipos", "src", "dest_ins", "n_surv", "n_new")

    def __init__(self, num: int, del_pos: np.ndarray,
                 ins_pos: np.ndarray):
        self.del_pos = del_pos
        ipos = ins_pos - np.searchsorted(del_pos, ins_pos)
        self.ipos = ipos
        n_ins = ipos.shape[0]
        self.n_surv = num - del_pos.shape[0]
        self.n_new = self.n_surv + n_ins
        seg = np.diff(np.concatenate((
            np.zeros(1, dtype=np.int64), ipos,
            np.full(1, self.n_surv, dtype=np.int64))))
        shift = np.repeat(np.arange(n_ins + 1, dtype=np.int64), seg)
        keep = np.ones(num, dtype=bool)
        keep[del_pos] = False
        src = np.zeros(self.n_new, dtype=np.int64)
        src[np.arange(self.n_surv, dtype=np.int64) + shift] = \
            np.flatnonzero(keep)
        self.src = src
        self.dest_ins = ipos + np.arange(n_ins, dtype=np.int64)

    def splice(self, arr: np.ndarray, vals) -> np.ndarray:
        out = (arr[self.src] if self.n_surv
               else np.empty(self.n_new, dtype=arr.dtype))
        out[self.dest_ins] = vals
        return out

    def readdress(self, p: np.ndarray) -> np.ndarray:
        """Post-splice position of the surviving pre-splice position
        ``p`` (must not be in ``del_pos``)."""
        p = p - np.searchsorted(self.del_pos, p)
        return p + np.searchsorted(self.ipos, p, side="right")


def apply_delta(g: CompactDigraph, add_src=None, add_dst=None,
                del_src=None, del_dst=None
                ) -> tuple[CompactDigraph, GraphDelta]:
    """Insert and expire arcs without a full :func:`from_edges` rebuild.

    Set semantics on directed arcs: removals apply first, then insertions
    (an arc both deleted and added ends up present); inserting an existing
    arc and deleting an absent one are no-ops; self-loops are dropped.
    Works at pair granularity — only the pairs containing a delta arc are
    re-coded, and the CSR is edited by splicing exactly the touched rows
    (rewrite / delete / insert at binary-searched positions in the
    globally sorted entry keys) — no re-sort, no re-deduplication, no
    O(P) pair-decomposition merge.  Host cost is O(delta log m) searches
    plus the O(m) memmoves of the splice itself.

    Returns the edited graph and the :class:`GraphDelta` describing every
    pair whose dyad code changed (the input to incremental censuses).
    """
    empty = np.zeros(0, dtype=np.int64)

    def pair_bits(src, dst):
        if src is None:
            return empty, empty
        src, dst, _ = clean_arcs(src, dst, g.n)
        plo, phi, code = arcs_to_pairs(src, dst, g.n)
        return plo * g.n + phi, code

    dkey, dbits = pair_bits(del_src, del_dst)
    akey, abits = pair_bits(add_src, add_dst)

    keys = np.union1d(dkey, akey)
    if keys.size == 0:
        return g, GraphDelta(n=g.n, pair_lo=empty, pair_hi=empty,
                             old_code=empty, new_code=empty)
    dfull = np.zeros(keys.shape[0], dtype=np.int64)
    afull = np.zeros(keys.shape[0], dtype=np.int64)
    dfull[np.searchsorted(keys, dkey)] = dbits
    afull[np.searchsorted(keys, akey)] = abits

    entry_key = entry_keys(g) if g.packed.size else None
    old = _lookup_pair_codes(g, keys, entry_key)
    new = (old & ~dfull) | afull
    changed = new != old
    keys, old, new = keys[changed], old[changed], new[changed]
    delta = GraphDelta(n=g.n, pair_lo=keys // g.n, pair_hi=keys % g.n,
                       old_code=old, new_code=new)
    if keys.size == 0:
        return g, delta

    # CSR splice: each changed pair perturbs exactly two rows (lo's entry
    # for hi and hi's entry for lo).  Rows stay neighbor-sorted, so every
    # edit is a rewrite / delete / insert at a binary-searched position in
    # the globally sorted entry keys ``row * n + nbr``.
    lo, hi = keys // g.n, keys % g.n
    erow = np.concatenate([lo, hi])
    enbr = np.concatenate([hi, lo])
    eold = np.concatenate([old, swap_code(old)])
    enew = np.concatenate([new, swap_code(new)])
    ekey = erow * g.n + enbr
    order = np.argsort(ekey)               # 2C entries, C = changed pairs
    erow, enbr = erow[order], enbr[order]
    eold, enew, ekey = eold[order], enew[order], ekey[order]

    pos = (np.searchsorted(entry_key, ekey) if entry_key is not None
           else np.zeros(ekey.shape[0], dtype=np.int64))

    rew = (eold > 0) & (enew > 0)              # recoded in place
    rvals = ((enbr[rew] << 2) | enew[rew]).astype(np.int32)
    dele = enew == 0                           # entry vanishes
    insm = eold == 0                           # entry appears
    if dele.any() or insm.any():
        vals = (enbr[insm] << 2) | enew[insm]
        if vals.size and vals.max() >= 2**31:
            raise ValueError(
                "graph too large for int32 packing; need n < 2^29")
        plan = SplicePlan(g.packed.shape[0], pos[dele], pos[insm])
        packed = plan.splice(g.packed, vals.astype(np.int32))
        # rewrites keep their key (same row, same neighbor), so the
        # edited entry-key cache is one more splice of the same plan —
        # the next delta never rebuilds it
        ekey_new = (plan.splice(entry_key, ekey[insm])
                    if entry_key is not None else None)
        if rew.any():
            packed[plan.readdress(pos[rew])] = rvals
    else:
        packed = g.packed.copy()
        packed[pos[rew]] = rvals
        ekey_new = entry_key

    ddeg = np.zeros(g.n, dtype=np.int64)
    np.add.at(ddeg, erow[dele], -1)
    np.add.at(ddeg, erow[insm], 1)
    indptr = g.indptr.copy()
    indptr[1:] += np.cumsum(ddeg)

    def _narcs(c):
        return int(((c & 1) != 0).sum() + ((c & 2) != 0).sum())

    g_new = CompactDigraph(
        n=g.n, indptr=indptr, packed=packed,
        num_arcs=g.num_arcs + _narcs(new) - _narcs(old),
        ekey_cache=ekey_new)
    return g_new, delta


def from_dense(a: np.ndarray) -> CompactDigraph:
    """Build from a dense boolean adjacency matrix (tests / tiny graphs)."""
    a = np.asarray(a, dtype=bool).copy()
    np.fill_diagonal(a, False)
    src, dst = np.nonzero(a)
    return from_edges(src, dst, n=a.shape[0])


def to_dense(g: CompactDigraph) -> np.ndarray:
    a = np.zeros((g.n, g.n), dtype=bool)
    if g.packed.size:
        rows = np.repeat(np.arange(g.n, dtype=np.int64), g.degrees)
        nbr = g.packed >> 2
        code = g.packed & 3
        out = (code & 1) != 0
        a[rows[out], nbr[out]] = True
        inc = (code & 2) != 0
        a[nbr[inc], rows[inc]] = True
    return a
