"""Compact directed-graph structure (paper Fig 7).

Compressed sparse row over the *symmetrized* adjacency: each unordered
adjacent pair {u, w} contributes one entry to u's row and one to w's row.
An entry packs ``(neighbor_id << 2) | dir_code`` where the 2-bit dir code is
relative to the row owner ``u``::

    bit 0: u -> w  ("01" unidirectional current -> neighbor)
    bit 1: w -> u  ("10" unidirectional neighbor -> current)
    "11": bidirectional

Rows are sorted by neighbor id (packing preserves order: id occupies the
high bits), enabling binary search — exactly the paper's layout.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.tricode import swap_code


@dataclass(frozen=True)
class CompactDigraph:
    """CSR-with-direction-bits graph container (host-side, numpy)."""

    n: int                     #: number of vertices
    indptr: np.ndarray         #: (n+1,) int64 row offsets
    packed: np.ndarray         #: (2*pairs,) int32 ``(nbr << 2) | code``
    num_arcs: int              #: directed edge count (after dedup)

    @property
    def degrees(self) -> np.ndarray:
        return np.diff(self.indptr)

    @property
    def num_pairs(self) -> int:
        """Number of unordered adjacent pairs (undirected edges)."""
        return self.packed.shape[0] // 2

    def neighbors(self, u: int) -> np.ndarray:
        return self.packed[self.indptr[u]:self.indptr[u + 1]] >> 2

    def codes(self, u: int) -> np.ndarray:
        return self.packed[self.indptr[u]:self.indptr[u + 1]] & 3

    def validate(self) -> None:
        deg = self.degrees
        assert (deg >= 0).all() and self.indptr[-1] == self.packed.shape[0]
        nbr = self.packed >> 2
        # rows sorted strictly (no duplicate neighbors within a row) —
        # vectorized: every adjacent CSR entry must increase unless the
        # boundary between two rows falls there
        if nbr.shape[0] > 1:
            rising = np.diff(nbr) > 0
            crossing = np.zeros(nbr.shape[0] - 1, dtype=bool)
            bounds = np.asarray(self.indptr[1:-1], dtype=np.int64)
            bounds = bounds[(bounds > 0) & (bounds < nbr.shape[0])]
            crossing[bounds - 1] = True
            bad = ~(rising | crossing)
            if bad.any():
                at = np.nonzero(bad)[0][0]
                u = int(np.searchsorted(self.indptr, at, side="right") - 1)
                raise AssertionError(f"row {u} not strictly sorted")
        assert ((self.packed & 3) != 0).all(), "zero dir code"


def from_edges(src, dst, n: int | None = None) -> CompactDigraph:
    """Build the compact structure from directed edge arrays.

    Self-loops are dropped and duplicate directed edges deduplicated,
    matching the paper's preprocessing of the raw edge lists.
    """
    src = np.asarray(src, dtype=np.int64).ravel()
    dst = np.asarray(dst, dtype=np.int64).ravel()
    if src.shape != dst.shape:
        raise ValueError("src/dst length mismatch")
    if n is None:
        n = int(max(src.max(initial=-1), dst.max(initial=-1))) + 1
    if src.size and (src.min() < 0 or dst.min() < 0
                     or max(src.max(), dst.max()) >= n):
        raise ValueError("vertex id out of range")

    keep = src != dst
    src, dst = src[keep], dst[keep]
    # dedupe directed edges
    eid = src * n + dst
    eid = np.unique(eid)
    src, dst = eid // n, eid % n
    num_arcs = src.shape[0]

    # unordered pair key + the bit this arc sets on the (lo, hi) pair code
    lo, hi = np.minimum(src, dst), np.maximum(src, dst)
    pkey = lo * n + hi
    bit = np.where(src < dst, 1, 2).astype(np.int64)   # 1: lo->hi, 2: hi->lo
    order = np.argsort(pkey, kind="stable")
    pkey, bit = pkey[order], bit[order]
    uniq, start = np.unique(pkey, return_index=True)
    # OR the bits per pair (bits are distinct per directed edge after dedup)
    code = np.bitwise_or.reduceat(bit, start) if uniq.size else bit[:0]
    plo, phi = uniq // n, uniq % n

    # each pair emits two CSR entries: (plo: phi, code) and (phi: plo, swap)
    rows = np.concatenate([plo, phi])
    nbrs = np.concatenate([phi, plo])
    codes = np.concatenate([code, swap_code(code)])

    deg = np.bincount(rows, minlength=n).astype(np.int64)
    indptr = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(deg, out=indptr[1:])
    order = np.lexsort((nbrs, rows))
    packed = ((nbrs[order] << 2) | codes[order]).astype(np.int64)
    if packed.size and packed.max() >= 2**31:
        raise ValueError("graph too large for int32 packing; need n < 2^29")
    return CompactDigraph(n=int(n), indptr=indptr,
                          packed=packed.astype(np.int32),
                          num_arcs=int(num_arcs))


def from_dense(a: np.ndarray) -> CompactDigraph:
    """Build from a dense boolean adjacency matrix (tests / tiny graphs)."""
    a = np.asarray(a, dtype=bool).copy()
    np.fill_diagonal(a, False)
    src, dst = np.nonzero(a)
    return from_edges(src, dst, n=a.shape[0])


def to_dense(g: CompactDigraph) -> np.ndarray:
    a = np.zeros((g.n, g.n), dtype=bool)
    if g.packed.size:
        rows = np.repeat(np.arange(g.n, dtype=np.int64), g.degrees)
        nbr = g.packed >> 2
        code = g.packed & 3
        out = (code & 1) != 0
        a[rows[out], nbr[out]] = True
        inc = (code & 2) != 0
        a[nbr[inc], rows[inc]] = True
    return a
