"""Streaming census engine: unified multi-chunk execution, all backends.

:class:`CensusEngine` is the single owner of device dispatch for the triad
census.  It subsumes what used to be two parallel drivers (the
single-device path in :mod:`repro.core.census` and the sharded path in
:mod:`repro.core.distributed` — both are now thin wrappers over it) and
adds the out-of-core mode that the monolithic drivers could not express:

* **Monolithic** (``max_items=None``): one plan, one dispatch — exactly
  the historical behavior, for plans that fit.
* **Streamed** (``max_items=N``): the plan is never materialized whole.
  :class:`repro.core.plan_stream.PlanChunker` slices the pre-prune item
  space into bounded chunks; the engine uploads the chunk-invariant graph
  and pair arrays once, runs one jitted fixed-shape partials step per
  chunk (every chunk is padded to the same ``chunk_shape``, so the step
  compiles exactly once; item buffers are donated for HBM reuse), overlaps
  the host-side generation + upload of chunk k+1 with the device compute
  of chunk k, and accumulates the ``hist64``/``inter`` partials in int64
  on the host.  Peak plan memory is O(max_items) instead of O(W).

Orthogonally, ``emit`` picks how chunks reach the device:

* ``emit="device"`` (default): the host ships each chunk as ONE packed
  buffer of O(pairs) descriptors + anchors
  (:class:`repro.core.planner.DescriptorWindow`); the device step maps
  every flat item index back to its pair via an anchored constant-depth
  lower-bound search, derives slot/side arithmetically against the
  resident CSR, and applies the pruning predicate in-kernel — no item is
  ever materialized on the host, and per-chunk host→device plan traffic
  drops from O(max_items) to O(pairs-per-chunk)
  (``EngineStats.plan_upload_bytes``).
* ``emit="host"``: the original path — emit, prune, pack and upload the
  O(W) item words in numpy.  Kept as the oracle (bit-identical censuses
  by construction: every plan-pruned item is provably a zero
  contribution of the classification masks) and for prebuilt plans.

Partials are perfectly mergeable across chunks (integer histogram sums and
additive closed-form bases), so the streamed census is bit-identical to
the monolithic dispatch for every backend (``jnp``, ``pallas``,
``pallas-fused``), both orient modes, and any chunk size — enforced by
``tests/test_streaming.py``.

For *repeated* censuses of an evolving graph (the temporal monitor's
sliding windows), :meth:`CensusEngine.session` opens a resident-graph
:class:`EngineSession`: the CSR + pair arrays live on device in
fixed-capacity buffers, every dispatch reuses one jitted fixed-shape chunk
step (search depth pinned to ``ceil(log2 n)`` so no graph revision ever
recompiles it), and edge deltas are applied incrementally — only the
*affected pairs* (endpoint row changed) are re-counted, old partials
subtracted and new ones added, bit-identical to a from-scratch census
(:mod:`repro.core.incremental`).
"""

from __future__ import annotations

import functools
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import shard_map
from repro.core.census import (
    BACKENDS, assemble_census, assemble_counts, desc_partials_fn,
    partials_fn)
from repro.core.digraph import CompactDigraph, GraphDelta, apply_delta
from repro.core.incremental import (
    affected_pair_ids, combine, contribution_counts,
    subset_descriptor_windows)
from repro.core.planner import (
    DESC_BYTES, DESC_SEARCH_ITERS, CensusPlan, base_for_pairs,
    build_plan, emit_items, emit_items_for_pairs, global_bases,
    iter_descriptor_windows, max_pairs_per_window, num_desc_anchors,
    pad_and_pack, pair_space)
from repro.core.plan_stream import PlanChunker

#: work-item emission modes: ``device`` streams O(pairs) descriptors and
#: expands pairs→items in-kernel (the default); ``host`` materializes and
#: uploads every packed item in numpy (the original path, kept as the
#: oracle and for prebuilt monolithic plans)
EMIT_MODES = ("device", "host")


def _chunk_step_impl(indptr, packed, pair_u, pair_v, pair_code,
                     item_sp, item_pv, mesh, search_iters, backend):
    """One fixed-shape partials dispatch: ``(hist64, inter)`` int32.

    ``mesh=None`` runs single-device; otherwise the items are shard_mapped
    over every mesh axis with replicated graph/pair arrays and a final
    psum — the paper's privatized census vectors, one collective at the
    end.
    """
    partials = partials_fn(backend, search_iters)
    if mesh is None:
        return partials(indptr, packed, pair_u, pair_v, pair_code,
                        item_sp, item_pv)

    axes = mesh.axis_names

    def shard_fn(ip, pk, pu, pv, pc, wsp, wpv):
        hist64, inter = partials(ip, pk, pu, pv, pc, wsp, wpv)
        return jax.lax.psum(hist64, axes), jax.lax.psum(inter, axes)

    item_spec = P(axes)       # work items sharded over every mesh axis
    rep = P()                 # graph + pair arrays replicated
    fn = shard_map(
        shard_fn, mesh=mesh,
        in_specs=(rep, rep, rep, rep, rep, item_spec, item_spec),
        out_specs=(rep, rep),
        # pallas_call has no replication rule; keep the check on the
        # pure-XLA path where it still can catch a missing psum
        check_vma=(backend == "jnp"))
    return fn(indptr, packed, pair_u, pair_v, pair_code, item_sp, item_pv)


_STATIC = ("mesh", "search_iters", "backend")
#: donated variant: each chunk's packed item buffers hand their HBM to the
#: next upload (accelerators only — XLA:CPU cannot alias donated inputs,
#: so the plain variant avoids a per-chunk "unusable donation" warning)
_chunk_step_donated = functools.partial(
    jax.jit, static_argnames=_STATIC,
    donate_argnames=("item_sp", "item_pv"))(_chunk_step_impl)
_chunk_step_plain = functools.partial(
    jax.jit, static_argnames=_STATIC)(_chunk_step_impl)


def _chunk_step(mesh=None):
    """The per-chunk jitted step for the platform the work runs on —
    the mesh's device platform when sharded, the default backend when
    single-device."""
    platform = (mesh.devices.flat[0].platform if mesh is not None
                else jax.default_backend())
    return _chunk_step_plain if platform == "cpu" else _chunk_step_donated


def _desc_step_impl(indptr, packed, pair_u, pair_v, pair_code,
                    desc_words, idx, mesh, search_iters, desc_iters,
                    backend, orient, prune_self):
    """One fixed-shape device-emission dispatch: ``(hist64, inter3)``.

    ``desc_words`` is the window's single packed int32 buffer
    (:meth:`repro.core.planner.DescriptorWindow.device_words` — one
    upload per chunk instead of four); ``idx`` is the resident flat
    item-index array (created on device once per run/session, sharded
    over the mesh when distributed) — everything else is replicated.  No
    buffers are donated: the per-chunk upload is the O(pairs) descriptor
    buffer, small enough that HBM aliasing buys nothing.
    """
    num_anchors = num_desc_anchors(idx.shape[0])
    num_descs = (desc_words.shape[0] - 1 - num_anchors) // 3
    partials = desc_partials_fn(backend, search_iters, desc_iters,
                                orient, prune_self)

    def run(ip, pk, pu, pv, pc, words, ix):
        nv = words[:1]
        dp = words[1:1 + num_descs]
        dc = words[1 + num_descs:1 + 2 * num_descs]
        dw = words[1 + 2 * num_descs:1 + 3 * num_descs]
        an = words[1 + 3 * num_descs:]
        return partials(ip, pk, pu, pv, pc, dp, dc, dw, an, nv, ix)

    if mesh is None:
        return run(indptr, packed, pair_u, pair_v, pair_code,
                   desc_words, idx)

    axes = mesh.axis_names

    def shard_fn(*args):
        hist64, inter = run(*args)
        return jax.lax.psum(hist64, axes), jax.lax.psum(inter, axes)

    rep = P()                 # graph + pair + descriptor arrays replicated
    fn = shard_map(
        shard_fn, mesh=mesh,
        in_specs=(rep, rep, rep, rep, rep, rep,
                  P(axes)),   # only the item-index space is sharded
        out_specs=(rep, rep),
        check_vma=(backend == "jnp"))
    return fn(indptr, packed, pair_u, pair_v, pair_code, desc_words, idx)


_desc_step = functools.partial(
    jax.jit, static_argnames=(
        "mesh", "search_iters", "desc_iters", "backend", "orient",
        "prune_self"))(_desc_step_impl)


def _jit_cache_size(step) -> int:
    """Compile counter via jax's private ``_cache_size`` — if a jax
    upgrade drops it, only the ``step_compiles`` stat degrades (to 0),
    never the census itself."""
    return getattr(step, "_cache_size", lambda: 0)()


#: bytes per packed work item (two int32 words)
ITEM_BYTES = 8


def _land_desc_partials(fut, hist_acc: np.ndarray, inter_acc: np.ndarray,
                        chunk_items: list) -> int:
    """Accumulate one descriptor-step result in place — hist64 into
    ``hist_acc``, the two intersection lanes into ``inter_acc`` — and
    record/return lane 2, the chunk's device-counted valid items (the
    one place that knows the ``inter3`` layout)."""
    hist_acc += np.asarray(fut[0], dtype=np.int64)
    inter3 = np.asarray(fut[1], dtype=np.int64)
    inter_acc += inter3[:2]
    num = int(inter3[2])
    chunk_items.append(num)
    return num


@dataclass
class EngineStats:
    """Execution stats of the last :class:`CensusEngine` run.

    ``peak_plan_bytes`` is the per-dispatch item-lane footprint at packed
    -item width (``ITEM_BYTES * chunk_shape`` — the streaming ceiling the
    ``max_items`` knob tunes, comparable across emit modes; under
    ``emit="device"`` nothing item-shaped is HOST-resident, and the bytes
    actually uploaded per chunk are ``plan_upload_bytes``);
    ``monolithic_plan_bytes`` is what a single dispatch of the same work
    would have shipped.  ``step_compiles`` counts fresh compilations of
    the per-chunk step during the run — 0 or 1 for a streamed run, never
    one per chunk (fixed chunk shapes).
    """

    backend: str
    ndev: int
    orient: str
    streamed: bool
    max_items: int | None
    chunks: int
    chunk_shape: int           #: padded items per dispatch
    items: int                 #: total valid work items processed
    chunk_items: list[int] = field(default_factory=list)
    peak_plan_bytes: int = 0
    monolithic_plan_bytes: int = 0
    step_compiles: int = 0
    #: session-mode extras: valid items a full recompute of the current
    #: graph would process (== ``items`` for non-incremental runs), and
    #: the number of affected pairs an incremental update re-counted
    full_items: int = 0
    affected_pairs: int = 0
    #: work-item emission mode of the run ("host" or "device")
    emit: str = "host"
    #: fixed per-dispatch descriptor-array length (device emission only)
    desc_shape: int = 0
    #: host→device *plan* bytes shipped per dispatch: the packed item
    #: words under host emission, the descriptor window (+ 4-byte valid
    #: count) under device emission — the traffic the emit knob trades
    plan_upload_bytes: int = 0
    #: jitted-step compilations forced by session capacity growth (graph
    #: buffers regrown past their padded device shapes), counted apart
    #: from ``step_compiles`` so the compile-once contract stays auditable
    capacity_recompiles: int = 0

    @property
    def chunk_max_over_mean(self) -> float:
        """Streamed-schedule imbalance (1.0 == perfectly even chunks)."""
        if not self.chunk_items or not sum(self.chunk_items):
            return 1.0
        mean = sum(self.chunk_items) / len(self.chunk_items)
        return max(self.chunk_items) / mean

    def summary(self) -> str:
        mode = (f"streamed max_items={self.max_items}" if self.streamed
                else "monolithic")
        return (f"{self.backend} [{mode} emit={self.emit}] "
                f"chunks={self.chunks} items={self.items} "
                f"peak_plan_bytes={self.peak_plan_bytes} "
                f"(monolithic {self.monolithic_plan_bytes}) "
                f"plan_upload_bytes={self.plan_upload_bytes} "
                f"chunk_max_over_mean={self.chunk_max_over_mean:.3f} "
                f"step_compiles={self.step_compiles}")


class CensusEngine:
    """Owns mesh + backend dispatch for monolithic and streamed censuses.

    ``mesh=None`` executes on the default device; a :class:`Mesh` shards
    every chunk's items across all mesh axes.  After each ``run`` /
    ``run_plan`` the execution record is available as :attr:`stats`.
    """

    def __init__(self, mesh: Mesh | None = None, backend: str = "jnp",
                 emit: str = "device"):
        if backend not in BACKENDS:
            raise ValueError(
                f"unknown backend {backend!r}; one of {BACKENDS}")
        if emit not in EMIT_MODES:
            raise ValueError(
                f"unknown emit mode {emit!r}; one of {EMIT_MODES}")
        self.mesh = mesh
        self.backend = backend
        self.emit = emit
        self.stats: EngineStats | None = None

    @property
    def ndev(self) -> int:
        return 1 if self.mesh is None else int(
            np.prod(self.mesh.devices.shape))

    # ------------------------------------------------------------- helpers
    def _shardings(self):
        """(replicated, item-sharded) NamedShardings, or (None, None)."""
        if self.mesh is None:
            return None, None
        return (NamedSharding(self.mesh, P()),
                NamedSharding(self.mesh, P(self.mesh.axis_names)))

    def _put(self, a, sharding):
        arr = jnp.asarray(a)
        return arr if sharding is None else jax.device_put(arr, sharding)

    def _mono_stats(self, plan: CensusPlan,
                    max_items: int | None = None) -> EngineStats:
        wp = int(plan.item_sp.shape[0])
        return EngineStats(
            backend=self.backend, ndev=self.ndev, orient=plan.orient,
            streamed=False, max_items=max_items,
            chunks=1 if plan.num_items else 0, chunk_shape=wp,
            items=plan.num_items,
            chunk_items=[plan.num_items] if plan.num_items else [],
            peak_plan_bytes=ITEM_BYTES * wp,
            monolithic_plan_bytes=ITEM_BYTES * wp,
            emit="host", plan_upload_bytes=ITEM_BYTES * wp)

    # ------------------------------------------------------------- running
    def run_plan(self, plan: CensusPlan) -> np.ndarray:
        """Exact 16-type census from a prebuilt (monolithic) plan."""
        wp = int(plan.item_sp.shape[0])
        if self.mesh is not None and wp % self.ndev != 0:
            raise ValueError(
                f"plan padded to {wp} items, not a multiple of "
                f"{self.ndev} devices; build with pad_to=num_devices")
        self.stats = self._mono_stats(plan)
        if plan.num_pairs == 0 or plan.num_items == 0:
            # zero-work plans (incl. pairs whose items were all pruned)
            # resolve entirely from the host closed forms — the device is
            # never dispatched on zero-length item arrays
            return assemble_census(plan, np.zeros(64, np.int64),
                                   np.zeros(2, np.int64))
        rep, item_sh = self._shardings()
        step = _chunk_step(self.mesh)
        cache0 = _jit_cache_size(step)
        hist64, inter = step(
            self._put(plan.indptr, rep), self._put(plan.packed, rep),
            self._put(plan.pair_u, rep), self._put(plan.pair_v, rep),
            self._put(plan.pair_code, rep),
            self._put(plan.item_sp, item_sh),
            self._put(plan.item_pv, item_sh),
            self.mesh, plan.search_iters, self.backend)
        census = assemble_census(plan, np.asarray(hist64),
                                 np.asarray(inter))
        self.stats.step_compiles = _jit_cache_size(step) - cache0
        return census

    def run(self, g: CompactDigraph, *, max_items: int | None = None,
            orient: str = "none", prune_self: bool = True,
            progress=None, emit: str | None = None) -> np.ndarray:
        """Plan + count ``g`` end to end.

        ``max_items=None`` covers the whole item space in one dispatch;
        an integer budget streams bounded chunks instead (O(max_items)).
        ``emit`` (default: the engine's mode) picks the work-item path:
        ``"device"`` ships O(pairs) descriptors per chunk and expands
        pairs→items in-kernel; ``"host"`` materializes, packs and uploads
        every O(W) item in numpy (the oracle).  Both are bit-identical on
        every backend and orient mode.
        ``progress(chunk_index, num_chunks, chunk_valid_items)`` is called
        per chunk — at dispatch under host emission, when the chunk's
        device-counted valid items land under device emission.
        """
        emit = self.emit if emit is None else emit
        if emit not in EMIT_MODES:
            raise ValueError(
                f"unknown emit mode {emit!r}; one of {EMIT_MODES}")
        if emit == "device":
            chunker = PlanChunker(g, max_items, orient=orient,
                                  pad_to=self.ndev, prune_self=prune_self)
            return self._run_stream_desc(chunker, progress,
                                         max_items=max_items)
        if max_items is None:
            plan = build_plan(g, pad_to=self.ndev, orient=orient,
                              prune_self=prune_self)
            return self.run_plan(plan)
        chunker = PlanChunker(g, max_items, orient=orient,
                              pad_to=self.ndev, prune_self=prune_self)
        return self._run_stream(chunker, progress)

    def session(self, g: CompactDigraph, *, orient: str = "none",
                prune_self: bool = True, max_items: int | None = None,
                emit: str | None = None) -> "EngineSession":
        """Open a resident-graph session on ``g`` for repeated / sliding-
        window censuses (see :class:`EngineSession`)."""
        return EngineSession(self, g, orient=orient, prune_self=prune_self,
                             max_items=max_items, emit=emit)

    def _run_stream(self, chunker: PlanChunker, progress) -> np.ndarray:
        space = chunker.space
        self.stats = EngineStats(
            backend=self.backend, ndev=self.ndev, orient=space.orient,
            streamed=True, max_items=chunker.max_items,
            chunks=chunker.num_chunks, chunk_shape=chunker.chunk_shape,
            items=0, peak_plan_bytes=ITEM_BYTES * chunker.chunk_shape,
            emit="host",
            plan_upload_bytes=ITEM_BYTES * chunker.chunk_shape)
        if chunker.num_chunks == 0:
            return assemble_counts(space.n, 0, 0, np.zeros(64, np.int64),
                                   np.zeros(2, np.int64))

        rep, item_sh = self._shardings()
        # chunk-invariant graph + pair arrays: uploaded once, reused by
        # every chunk step (replicated across the mesh when sharded)
        graph_dev = tuple(self._put(a, rep)
                          for a in chunker.device_arrays())

        hist_acc = np.zeros(64, np.int64)
        inter_acc = np.zeros(2, np.int64)
        base_asym = base_mut = 0
        chunk_items: list[int] = []
        step = _chunk_step(self.mesh)
        cache0 = _jit_cache_size(step)
        pending = None
        for chunk in chunker:
            base_asym += chunk.base_asym
            base_mut += chunk.base_mut
            chunk_items.append(chunk.num_items)
            if progress is not None:
                progress(chunk.index, chunker.num_chunks, chunk.num_items)
            if chunk.num_items == 0:
                # fully-pruned chunk: its bases are credited above, the
                # all-invalid items contribute nothing — skip the dispatch
                # (mirrors the monolithic zero-work short-circuit)
                continue
            # upload + dispatch chunk k while chunk k-1 still computes
            # (dispatch is async; we only block when accumulating k-1)
            sp_dev = self._put(chunk.item_sp, item_sh)
            pv_dev = self._put(chunk.item_pv, item_sh)
            fut = step(*graph_dev, sp_dev, pv_dev,
                       self.mesh, space.search_iters, self.backend)
            if pending is not None:
                hist_acc += np.asarray(pending[0], dtype=np.int64)
                inter_acc += np.asarray(pending[1], dtype=np.int64)
            pending = fut
        if pending is not None:
            hist_acc += np.asarray(pending[0], dtype=np.int64)
            inter_acc += np.asarray(pending[1], dtype=np.int64)

        st = self.stats
        st.step_compiles = _jit_cache_size(step) - cache0
        st.chunk_items = chunk_items
        st.items = int(sum(chunk_items))
        mono_wp = -(-st.items // self.ndev) * self.ndev
        st.monolithic_plan_bytes = ITEM_BYTES * mono_wp
        return assemble_counts(space.n, base_asym, base_mut,
                               hist_acc, inter_acc)

    def _run_stream_desc(self, chunker: PlanChunker, progress,
                         max_items: int | None) -> np.ndarray:
        """Device-emission stream: per chunk the host ships the O(pairs)
        descriptor window; the device expands pairs→items in-kernel
        against the resident flat-index array.  Bit-identical to
        :meth:`_run_stream` — the expanded pre-prune items carry the
        plan-time pruning as an in-kernel mask, and every masked item is
        provably a zero contribution (see
        :func:`repro.core.census.prune_keep_mask`)."""
        space = chunker.space
        upload = (DESC_BYTES * chunker.desc_shape
                  + 4 * chunker.num_anchors + 4)
        self.stats = EngineStats(
            backend=self.backend, ndev=self.ndev, orient=space.orient,
            streamed=max_items is not None, max_items=max_items,
            chunks=chunker.num_chunks, chunk_shape=chunker.chunk_shape,
            items=0, peak_plan_bytes=ITEM_BYTES * chunker.chunk_shape,
            emit="device", desc_shape=chunker.desc_shape,
            plan_upload_bytes=upload)
        if chunker.num_chunks == 0:
            return assemble_counts(space.n, 0, 0, np.zeros(64, np.int64),
                                   np.zeros(2, np.int64))

        rep, item_sh = self._shardings()
        graph_dev = tuple(self._put(a, rep)
                          for a in chunker.device_arrays())
        # the flat item-index space: created on device once, reused by
        # every chunk (this is the array the mesh shards — there are no
        # item arrays left to shard)
        idx_dev = self._put(jnp.arange(chunker.chunk_shape, dtype=jnp.int32),
                            item_sh)

        hist_acc = np.zeros(64, np.int64)
        inter_acc = np.zeros(2, np.int64)
        base_asym = base_mut = 0
        chunk_items: list[int] = []
        cache0 = _jit_cache_size(_desc_step)
        pending = None

        def land(fut, k):
            num = _land_desc_partials(fut, hist_acc, inter_acc,
                                      chunk_items)
            if progress is not None:
                progress(k, chunker.num_chunks, num)

        for k in range(chunker.num_chunks):
            ba, bm = chunker.bases(k)
            base_asym += ba
            base_mut += bm
            win = chunker.descriptors(k)
            words = self._put(win.device_words(), rep)
            fut = _desc_step(*graph_dev, words, idx_dev,
                             self.mesh, space.search_iters,
                             chunker.desc_iters, self.backend,
                             space.orient, space.prune_self)
            if pending is not None:
                land(pending, k - 1)
            pending = fut
        if pending is not None:
            land(pending, chunker.num_chunks - 1)

        st = self.stats
        st.step_compiles = _jit_cache_size(_desc_step) - cache0
        st.chunk_items = chunk_items
        st.items = int(sum(chunk_items))
        mono_wp = -(-st.items // self.ndev) * self.ndev
        st.monolithic_plan_bytes = ITEM_BYTES * mono_wp
        return assemble_counts(space.n, base_asym, base_mut,
                               hist_acc, inter_acc)


def _pad_i32(a: np.ndarray, cap: int) -> np.ndarray:
    """Zero-pad an int32 array to a fixed capacity (device shape)."""
    out = np.zeros(cap, dtype=np.int32)
    out[:a.shape[0]] = a
    return out


class EngineSession:
    """Resident-graph census session: upload once, recount by delta.

    The graph-shaped device arrays (CSR ``indptr``/``packed`` + pair
    arrays) are uploaded once per graph revision into fixed-capacity
    zero-padded buffers (grown geometrically, so revisions of similar size
    reuse the same compiled step), items are dispatched in fixed
    ``chunk_shape`` slices through the engine's compile-once chunk step,
    and the binary-search depth is pinned to ``ceil(log2 n)`` — an upper
    bound for every possible row — so no future window can force a
    recompilation.  The padding is inert by construction: items only
    reference real slots/pairs, and the search stays inside real row
    bounds.

    Two ways to move the session forward:

    * :meth:`set_graph` + :meth:`census` — full recompute of a new graph
      (the tumbling-window path; still benefits from the resident arrays
      and the compile-once step).
    * :meth:`update` — apply an edge delta via
      :func:`repro.core.digraph.apply_delta` and recount only the
      *affected pairs* (see :mod:`repro.core.incremental`):
      ``C_new = C_old + contrib(A, G_new) − contrib(A, G_old)``,
      bit-identical to a from-scratch census of the edited graph.

    ``max_items`` bounds the padded items per dispatch (device-memory
    knob, default: one chunk sized to the initial graph's pre-prune item
    space); full censuses emit per-slice so host plan memory is
    O(chunk_shape), and subset recounts are O(subset items).  After every
    operation :attr:`stats` (also mirrored to ``engine.stats``) records
    the dispatch schedule, including ``full_items`` — what a from-scratch
    recompute would have processed — and ``affected_pairs``.

    Under ``emit="device"`` (the default) nothing above changes
    semantically, but per dispatch the host uploads ONE packed
    descriptor buffer (O(pairs-in-window) words) instead of the packed
    items, and a delta update uploads only the touched pairs'
    descriptors.  The descriptor capacity and anchor geometry are fixed
    at session open — windows that would overflow shrink their item span
    instead — so device emission adds no recompile vector;
    graph-capacity growth remains the only one and is counted apart as
    ``stats.capacity_recompiles``.
    """

    def __init__(self, engine: CensusEngine, g: CompactDigraph, *,
                 orient: str = "none", prune_self: bool = True,
                 max_items: int | None = None, emit: str | None = None):
        if max_items is not None and max_items < 1:
            raise ValueError(f"max_items must be >= 1, got {max_items}")
        emit = engine.emit if emit is None else emit
        if emit not in EMIT_MODES:
            raise ValueError(
                f"unknown emit mode {emit!r}; one of {EMIT_MODES}")
        self.engine = engine
        self.orient = orient
        self.prune_self = prune_self
        self.emit = emit
        self.n = g.n
        self.max_items = max_items
        #: pinned unrolled-search depth: any row has < n entries, so this
        #: upper bound keeps the jitted step valid for every graph revision
        self.search_iters = max(1, int(np.ceil(np.log2(max(g.n, 2)))))
        self._rep, self._item_sh = engine._shardings()
        self._step = _chunk_step(engine.mesh)
        self._cap_entries = 0
        self._cap_pairs = 0
        self._capacity_grew = False
        self.chunk_shape: int | None = None
        self.desc_shape: int | None = None
        self._census: np.ndarray | None = None
        self.last_delta: GraphDelta | None = None
        self.stats: EngineStats | None = None
        self._install(g)
        if self.emit == "device":
            self._init_device_emission()

    # ------------------------------------------------------------ state
    @property
    def graph(self) -> CompactDigraph:
        return self._g

    @property
    def space(self):
        return self._space

    @property
    def counts(self) -> np.ndarray | None:
        """The session's running census C_k (None until :meth:`census`)."""
        return None if self._census is None else self._census.copy()

    @staticmethod
    def _grown(cap: int, need: int) -> int:
        cap = max(cap, 256)
        while cap < need:
            cap *= 2
        return cap

    def _init_device_emission(self) -> None:
        """Fix the session's descriptor geometry: a per-dispatch
        descriptor capacity sized to the initial graph's schedule (with
        2x headroom for sparser affected-pair subsets, capped at the
        structural bound of chunk_shape/2 + 1 pairs per window — every
        pair spans >= 2 pre-prune items), the matching pinned lower-bound
        depth, and the resident flat-index array the windows expand
        against.  Windows that would overflow the capacity shrink their
        item span instead (:func:`repro.core.planner
        .iter_descriptor_windows`), so no graph revision or delta can
        ever force a descriptor-shape recompile."""
        space = self._space
        cs = self.chunk_shape
        need = max_pairs_per_window(space.offsets, cs)
        self.desc_shape = min(cs // 2 + 1, max(64, 2 * need))
        self.desc_iters = DESC_SEARCH_ITERS
        self.num_anchors = num_desc_anchors(cs)
        self._idx = self.engine._put(
            jnp.arange(cs, dtype=jnp.int32), self._item_sh)

    def _install(self, g: CompactDigraph) -> None:
        """Make ``g`` the resident graph: rebuild the pair space and
        (re)upload the padded device arrays."""
        self._g = g
        space = pair_space(g, orient=self.orient,
                           prune_self=self.prune_self)
        self._space = space
        self._full_items: int | None = None   # lazy per-install stat
        if self.chunk_shape is None:
            budget = (self.max_items if self.max_items is not None
                      else max(space.num_items_preprune, 1))
            self.chunk_shape = -(-max(int(budget), 1)
                                 // self.engine.ndev) * self.engine.ndev
            if self.chunk_shape >= 2**31:
                raise ValueError(
                    "chunk exceeds int32 item indexing; pass a smaller "
                    "max_items budget")
        prev_caps = (self._cap_entries, self._cap_pairs)
        self._cap_entries = self._grown(self._cap_entries,
                                        space.packed.shape[0])
        self._cap_pairs = self._grown(self._cap_pairs, space.num_pairs)
        if prev_caps != (0, 0) and \
                prev_caps != (self._cap_entries, self._cap_pairs):
            # the padded device shapes changed: the next dispatch's fresh
            # compile (if any) is a capacity recompile, not a step compile
            self._capacity_grew = True
        put = self.engine._put
        self._dev = (
            put(space.indptr.astype(np.int32), self._rep),
            put(_pad_i32(space.packed, self._cap_entries), self._rep),
            put(_pad_i32(space.pair_u.astype(np.int32),
                         self._cap_pairs), self._rep),
            put(_pad_i32(space.pair_v.astype(np.int32),
                         self._cap_pairs), self._rep),
            put(_pad_i32(space.pair_code, self._cap_pairs), self._rep),
        )

    def set_graph(self, g: CompactDigraph) -> None:
        """Replace the resident graph wholesale (no delta bookkeeping).
        Invalidates the running census until :meth:`census` recomputes."""
        if g.n != self.n:
            raise ValueError(f"session is pinned to n={self.n}, got {g.n}")
        self._install(g)
        self._census = None
        self.last_delta = None

    # ---------------------------------------------------------- running
    def _run_batches(self, batches
                     ) -> tuple[np.ndarray, np.ndarray, list[int]]:
        """Dispatch item batches (each with at most ``chunk_shape``
        items) in fixed-shape chunks against the resident device graph;
        accumulate int64 partials on the host, overlapping batch k+1's
        emission + upload with batch k's compute.  Fully-pruned batches
        are skipped without a dispatch."""
        hist_acc = np.zeros(64, np.int64)
        inter_acc = np.zeros(2, np.int64)
        chunk_items: list[int] = []
        pending = None
        for item_pair, item_slot, item_side in batches:
            num = int(item_pair.shape[0])
            if num == 0:
                continue
            item_sp, item_pv = pad_and_pack(
                item_pair, item_slot, item_side, self.chunk_shape)
            sp_dev = self.engine._put(item_sp, self._item_sh)
            pv_dev = self.engine._put(item_pv, self._item_sh)
            fut = self._step(*self._dev, sp_dev, pv_dev, self.engine.mesh,
                             self.search_iters, self.engine.backend)
            if pending is not None:
                hist_acc += np.asarray(pending[0], dtype=np.int64)
                inter_acc += np.asarray(pending[1], dtype=np.int64)
            pending = fut
            chunk_items.append(num)
        if pending is not None:
            hist_acc += np.asarray(pending[0], dtype=np.int64)
            inter_acc += np.asarray(pending[1], dtype=np.int64)
        return hist_acc, inter_acc, chunk_items

    def _run_desc_batches(self, windows
                          ) -> tuple[np.ndarray, np.ndarray, list[int]]:
        """Device-emission twin of :meth:`_run_batches`: dispatch
        descriptor windows against the resident graph + flat-index
        arrays, overlapping window k+1's (tiny) descriptor build + upload
        with window k's compute.  Valid-item counts come back from the
        device (``inter`` lane 2), so the stats stay comparable with host
        emission without materializing a single item."""
        hist_acc = np.zeros(64, np.int64)
        inter_acc = np.zeros(2, np.int64)
        chunk_items: list[int] = []
        put = self.engine._put
        pending = None
        for win in windows:
            if win.num_preprune == 0:
                continue
            words = put(win.device_words(), self._rep)
            fut = _desc_step(*self._dev, words, self._idx,
                             self.engine.mesh, self.search_iters,
                             self.desc_iters, self.engine.backend,
                             self.orient, self.prune_self)
            if pending is not None:
                _land_desc_partials(pending, hist_acc, inter_acc,
                                    chunk_items)
            pending = fut
        if pending is not None:
            _land_desc_partials(pending, hist_acc, inter_acc,
                                chunk_items)
        return hist_acc, inter_acc, chunk_items

    def _slices(self, item_pair, item_slot, item_side):
        """Yield materialized items in ``chunk_shape``-sized batches."""
        cs = self.chunk_shape
        for lo in range(0, int(item_pair.shape[0]), cs):
            yield (item_pair[lo:lo + cs], item_slot[lo:lo + cs],
                   item_side[lo:lo + cs])

    def _subset(self, pair_ids: np.ndarray
                ) -> tuple[np.ndarray, int, list[int]]:
        """Contribution of a pair subset of the RESIDENT graph.  Host
        memory is O(subset items) under host emission and O(subset pairs)
        under device emission — bounded by the affected neighborhoods in
        the incremental path, not by the graph's full W."""
        base_asym, base_mut = base_for_pairs(self._space, pair_ids)
        if self.emit == "device":
            ids = np.asarray(pair_ids, dtype=np.int64).ravel()
            hist, inter, chunk_items = self._run_desc_batches(
                subset_descriptor_windows(self._space, ids,
                                          self.chunk_shape,
                                          self.desc_shape,
                                          self.num_anchors))
            return (contribution_counts(base_asym, base_mut, hist, inter),
                    int(sum(chunk_items)), chunk_items)
        items = emit_items_for_pairs(self._space, pair_ids)
        num_items = int(items[0].shape[0])
        if num_items == 0:
            return (contribution_counts(base_asym, base_mut,
                                        np.zeros(64, np.int64),
                                        np.zeros(2, np.int64)), 0, [])
        hist, inter, chunk_items = self._run_batches(self._slices(*items))
        return (contribution_counts(base_asym, base_mut, hist, inter),
                num_items, chunk_items)

    def _postprune_items(self) -> int:
        """Full-recompute item count of the resident graph, computed at
        most once per graph revision (the degree-orient closed form costs
        an O(m + P log m) scan — stats only, never the hot path)."""
        if self._full_items is None:
            self._full_items = self._space.num_items_postprune()
        return self._full_items

    def _cache_size(self) -> int:
        """Compile counter of the jitted step this session dispatches
        through (the descriptor step under device emission)."""
        return _jit_cache_size(
            _desc_step if self.emit == "device" else self._step)

    def _set_stats(self, chunk_items: list[int], items: int,
                   full_items: int, affected_pairs: int,
                   compiles: int) -> None:
        ndev = self.engine.ndev
        capacity_recompiles = 0
        if self._capacity_grew and chunk_items:
            # first dispatches on the regrown buffers: any fresh compile
            # they forced is the capacity's fault, not the step's
            capacity_recompiles, compiles = compiles, 0
            self._capacity_grew = False
        self.stats = EngineStats(
            backend=self.engine.backend, ndev=ndev, orient=self.orient,
            streamed=True, max_items=self.max_items,
            chunks=len(chunk_items), chunk_shape=self.chunk_shape,
            items=items, chunk_items=chunk_items,
            peak_plan_bytes=ITEM_BYTES * self.chunk_shape,
            monolithic_plan_bytes=ITEM_BYTES
            * (-(-full_items // ndev) * ndev),
            step_compiles=compiles,
            full_items=full_items, affected_pairs=affected_pairs,
            emit=self.emit,
            desc_shape=self.desc_shape or 0,
            plan_upload_bytes=(
                DESC_BYTES * self.desc_shape + 4 * self.num_anchors + 4
                if self.emit == "device"
                else ITEM_BYTES * self.chunk_shape),
            capacity_recompiles=capacity_recompiles)
        self.engine.stats = self.stats

    def census(self) -> np.ndarray:
        """Full census of the resident graph; (re)bases the session's
        running C_k that :meth:`update` moves forward.  Under host
        emission items are emitted per pre-prune slice of ``chunk_shape``
        (host plan memory O(chunk_shape), never O(W)); under device
        emission only descriptor windows are built — O(pairs-per-window)
        host memory and upload."""
        space = self._space
        cache0 = self._cache_size()
        w0 = space.num_items_preprune
        cs = self.chunk_shape
        if self.emit == "device":
            hist, inter, chunk_items = self._run_desc_batches(
                iter_descriptor_windows(space.offsets, cs,
                                        self.desc_shape,
                                        self.num_anchors))
        else:
            batches = (emit_items(space, lo, min(lo + cs, w0))
                       for lo in range(0, w0, cs))
            hist, inter, chunk_items = self._run_batches(batches)
        base_asym, base_mut = global_bases(space)
        self._census = assemble_counts(self.n, base_asym, base_mut,
                                       hist, inter)
        num_items = int(sum(chunk_items))
        self._full_items = num_items      # the full census just counted it
        self._set_stats(chunk_items, num_items, num_items,
                        space.num_pairs,
                        self._cache_size() - cache0)
        return self._census.copy()

    def update(self, add_src=None, add_dst=None,
               del_src=None, del_dst=None) -> np.ndarray:
        """Apply an edge delta and return the edited graph's census,
        recounting only the affected pairs — bit-identical to a
        from-scratch census of the new graph on any backend."""
        if self._census is None:
            raise RuntimeError(
                "no baseline census: call census() before update()")
        cache0 = self._cache_size()
        g_new, delta = apply_delta(self._g, add_src, add_dst,
                                   del_src, del_dst)
        self.last_delta = delta
        if delta.num_changed == 0:
            # nothing changed: no recount, no descriptor/item upload, no
            # device dispatch — the running census is already the answer
            self._set_stats([], 0, self._postprune_items(), 0,
                            self._cache_size() - cache0)
            return self._census.copy()

        aff_old = affected_pair_ids(self._space, delta.touched)
        contrib_old, items_old, chunks_old = self._subset(aff_old)
        self._install(g_new)
        aff_new = affected_pair_ids(self._space, delta.touched)
        contrib_new, items_new, chunks_new = self._subset(aff_new)
        self._census = combine(self._census, contrib_old, contrib_new,
                               self.n)
        self._set_stats(chunks_old + chunks_new, items_old + items_new,
                        self._postprune_items(),
                        int(aff_old.shape[0] + aff_new.shape[0]),
                        self._cache_size() - cache0)
        return self._census.copy()
