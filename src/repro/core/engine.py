"""Streaming census engine: unified multi-chunk execution, all backends.

:class:`CensusEngine` is the single owner of device dispatch for the triad
census.  It subsumes what used to be two parallel drivers (the
single-device path in :mod:`repro.core.census` and the sharded path in
:mod:`repro.core.distributed` — both are now thin wrappers over it) and
adds the out-of-core mode that the monolithic drivers could not express:

* **Monolithic** (``max_items=None``): one plan, one dispatch — exactly
  the historical behavior, for plans that fit.
* **Streamed** (``max_items=N``): the plan is never materialized whole.
  :class:`repro.core.plan_stream.PlanChunker` slices the pre-prune item
  space into bounded chunks; the engine uploads the chunk-invariant graph
  and pair arrays once, runs one jitted fixed-shape partials step per
  chunk (every chunk is padded to the same ``chunk_shape``, so the step
  compiles exactly once; item buffers are donated for HBM reuse), overlaps
  the host-side generation + upload of chunk k+1 with the device compute
  of chunk k, and accumulates the ``hist64``/``inter`` partials in int64
  on the host.  Peak plan memory is O(max_items) instead of O(W).

Orthogonally, ``emit`` picks how chunks reach the device:

* ``emit="device"`` (default): the host ships each chunk as ONE packed
  buffer of O(pairs) descriptors + anchors
  (:class:`repro.core.planner.DescriptorWindow`); the device step maps
  every flat item index back to its pair via an anchored constant-depth
  lower-bound search, derives slot/side arithmetically against the
  resident CSR, and applies the pruning predicate in-kernel — no item is
  ever materialized on the host, and per-chunk host→device plan traffic
  drops from O(max_items) to O(pairs-per-chunk)
  (``EngineStats.plan_upload_bytes``).
* ``emit="host"``: the original path — emit, prune, pack and upload the
  O(W) item words in numpy.  Kept as the oracle (bit-identical censuses
  by construction: every plan-pruned item is provably a zero
  contribution of the classification masks) and for prebuilt plans.

Partials are perfectly mergeable across chunks (integer histogram sums and
additive closed-form bases), so the streamed census is bit-identical to
the monolithic dispatch for every backend (``jnp``, ``pallas``,
``pallas-fused``), both orient modes, and any chunk size — enforced by
``tests/test_streaming.py``.

For *repeated* censuses of an evolving graph (the temporal monitor's
sliding windows), :meth:`CensusEngine.session` opens a resident-graph
:class:`EngineSession`: the CSR + pair arrays live on device in
fixed-capacity buffers, every dispatch reuses one jitted fixed-shape chunk
step (search depth pinned to ``ceil(log2 n)`` so no graph revision ever
recompiles it), and edge deltas are applied incrementally — only the
*affected pairs* (endpoint row changed) are re-counted, old partials
subtracted and new ones added, bit-identical to a from-scratch census
(:mod:`repro.core.incremental`).

Orthogonally to all of the above, ``partition=True`` shards the GRAPH
instead of replicating it (:mod:`repro.core.partition`): the pair space
is LPT-split into one private shard per mesh device, each device holds
only its shard's order-preservingly relabeled local subgraph
(O(E_shard + halo) resident bytes instead of O(E)) and walks its own
descriptor/item stream — through the partitioned collective steps for
full runs (`_part_chunk_step` / `_part_desc_step`: graph arrays are
sharded inputs with a leading device axis, one closing psum) and through
per-device committed dispatches for :class:`PartitionedEngineSession`,
whose delta updates touch only the shards owning affected pairs.
"""

from __future__ import annotations

import functools
import json
import os
import time
import zlib
from collections import deque
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import shard_map
from repro.core.census import (
    BACKENDS, assemble_census, assemble_counts,
    census_partials_desc_batch, desc_partials_fn, partials_fn)
from repro.core.digraph import CompactDigraph, GraphDelta, apply_delta
from repro.core.faults import FaultError, FaultPlan, poison_result
from repro.core.incremental import (
    affected_pair_ids, combine, contribution_counts,
    subset_descriptor_windows)
from repro.core.pair_index import PairSpaceIndex
from repro.core.partition import (
    extract_shard, partition_graph, partition_graph_2d,
    range_postprune_pair_counts, slice_pair_terms,
    replicated_graph_bytes,
    stacked_device_arrays)
from repro.core.planner import (
    DESC_BYTES, DESC_SEARCH_ITERS, CensusPlan, PlanOverflowError,
    base_for_pairs,
    build_plan, emit_items, emit_items_for_pairs, global_bases,
    iter_descriptor_windows, max_pairs_per_window, num_desc_anchors,
    pad_and_pack, pair_space, postprune_pair_counts)
from repro.core.plan_stream import (
    PlanChunker, ShardSchedule, ShardStreamPipeline, WindowBatcher)

#: work-item emission modes: ``device`` streams O(pairs) descriptors and
#: expands pairs→items in-kernel (the default); ``host`` materializes and
#: uploads every packed item in numpy (the original path, kept as the
#: oracle and for prebuilt monolithic plans)
EMIT_MODES = ("device", "host")

#: partitioned execution disciplines: ``async`` (the default) walks each
#: shard's private chunk queue independently — per-device dispatches, no
#: inter-shard barrier, background per-shard window producers — so
#: walltime tracks the MEAN shard cost; ``lockstep`` advances every
#: shard's queue together through one collective dispatch per step (the
#: slowest shard gates each step) and is kept as the bit-identity oracle
SCHEDULES = ("async", "lockstep")

#: per-shard produced-window queue depth of the async host pipeline
#: (2 == double-buffering: one window in flight, one pre-built behind it)
PIPELINE_DEPTH = 2

#: default cap K on the descriptor windows one async megastep dispatch
#: consumes (``lax.scan`` over the stacked window batch): Python dispatch
#: cost is paid once per up-to-K windows; the live batch size adapts
#: between 1 and this cap from stall/backlog feedback
#: (:class:`repro.core.plan_stream.WindowBatcher`)
MAX_WINDOWS_PER_DISPATCH = 8


def _chunk_step_impl(indptr, packed, pair_u, pair_v, pair_code,
                     item_sp, item_pv, mesh, search_iters, backend):
    """One fixed-shape partials dispatch: ``(hist64, inter)`` int32.

    ``mesh=None`` runs single-device; otherwise the items are shard_mapped
    over every mesh axis with replicated graph/pair arrays and a final
    psum — the paper's privatized census vectors, one collective at the
    end.
    """
    partials = partials_fn(backend, search_iters)
    if mesh is None:
        return partials(indptr, packed, pair_u, pair_v, pair_code,
                        item_sp, item_pv)

    axes = mesh.axis_names

    def shard_fn(ip, pk, pu, pv, pc, wsp, wpv):
        hist64, inter = partials(ip, pk, pu, pv, pc, wsp, wpv)
        return jax.lax.psum(hist64, axes), jax.lax.psum(inter, axes)

    item_spec = P(axes)       # work items sharded over every mesh axis
    rep = P()                 # graph + pair arrays replicated
    fn = shard_map(
        shard_fn, mesh=mesh,
        in_specs=(rep, rep, rep, rep, rep, item_spec, item_spec),
        out_specs=(rep, rep),
        # pallas_call has no replication rule; keep the check on the
        # pure-XLA path where it still can catch a missing psum
        check_vma=(backend == "jnp"))
    return fn(indptr, packed, pair_u, pair_v, pair_code, item_sp, item_pv)


_STATIC = ("mesh", "search_iters", "backend")
#: donated variant: each chunk's packed item buffers hand their HBM to the
#: next upload (accelerators only — XLA:CPU cannot alias donated inputs,
#: so the plain variant avoids a per-chunk "unusable donation" warning)
_chunk_step_donated = functools.partial(
    jax.jit, static_argnames=_STATIC,
    donate_argnames=("item_sp", "item_pv"))(_chunk_step_impl)
_chunk_step_plain = functools.partial(
    jax.jit, static_argnames=_STATIC)(_chunk_step_impl)


def _chunk_step(mesh=None):
    """The per-chunk jitted step for the platform the work runs on —
    the mesh's device platform when sharded, the default backend when
    single-device."""
    platform = (mesh.devices.flat[0].platform if mesh is not None
                else jax.default_backend())
    return _chunk_step_plain if platform == "cpu" else _chunk_step_donated


def _desc_step_impl(indptr, packed, pair_u, pair_v, pair_code,
                    desc_words, idx, mesh, search_iters, desc_iters,
                    backend, orient, prune_self):
    """One fixed-shape device-emission dispatch: ``(hist64, inter3)``.

    ``desc_words`` is the window's single packed int32 buffer
    (:meth:`repro.core.planner.DescriptorWindow.device_words` — one
    upload per chunk instead of four); ``idx`` is the resident flat
    item-index array (created on device once per run/session, sharded
    over the mesh when distributed) — everything else is replicated.  No
    buffers are donated: the per-chunk upload is the O(pairs) descriptor
    buffer, small enough that HBM aliasing buys nothing.
    """
    num_anchors = num_desc_anchors(idx.shape[0])
    num_descs = (desc_words.shape[0] - 1 - num_anchors) // 3
    partials = desc_partials_fn(backend, search_iters, desc_iters,
                                orient, prune_self)

    def run(ip, pk, pu, pv, pc, words, ix):
        nv = words[:1]
        dp = words[1:1 + num_descs]
        dc = words[1 + num_descs:1 + 2 * num_descs]
        dw = words[1 + 2 * num_descs:1 + 3 * num_descs]
        an = words[1 + 3 * num_descs:]
        return partials(ip, pk, pu, pv, pc, dp, dc, dw, an, nv, ix)

    if mesh is None:
        return run(indptr, packed, pair_u, pair_v, pair_code,
                   desc_words, idx)

    axes = mesh.axis_names

    def shard_fn(*args):
        hist64, inter = run(*args)
        return jax.lax.psum(hist64, axes), jax.lax.psum(inter, axes)

    rep = P()                 # graph + pair + descriptor arrays replicated
    fn = shard_map(
        shard_fn, mesh=mesh,
        in_specs=(rep, rep, rep, rep, rep, rep,
                  P(axes)),   # only the item-index space is sharded
        out_specs=(rep, rep),
        check_vma=(backend == "jnp"))
    return fn(indptr, packed, pair_u, pair_v, pair_code, desc_words, idx)


_desc_step = functools.partial(
    jax.jit, static_argnames=(
        "mesh", "search_iters", "desc_iters", "backend", "orient",
        "prune_self"))(_desc_step_impl)


def _desc_megastep_impl(indptr, packed, pair_u, pair_v, pair_code,
                        words_batch, idx, search_iters, desc_iters,
                        backend, orient, prune_self):
    """K-window async megastep: one single-device dispatch scans a
    fixed-shape ``(K, words)`` batch of stacked descriptor windows
    (:func:`repro.core.census.census_partials_desc_batch`) and returns
    the per-window partials stacked — ``(hist64s (K, 64),
    inter3s (K, 3))`` int32, merged on the host in int64.  The batch
    shape is the ``max_windows_per_dispatch`` cap regardless of how many
    real windows landed (padding rows mask to exact zeros), so the step
    compiles once per device no matter how the adaptive K schedule
    moves."""
    return census_partials_desc_batch(
        indptr, packed, pair_u, pair_v, pair_code, words_batch, idx,
        search_iters, desc_iters, orient, prune_self, backend=backend)


_MEGA_STATIC = ("search_iters", "desc_iters", "backend", "orient",
                "prune_self")
_desc_megastep_donated = functools.partial(
    jax.jit, static_argnames=_MEGA_STATIC,
    donate_argnames=("words_batch",))(_desc_megastep_impl)
_desc_megastep_plain = functools.partial(
    jax.jit, static_argnames=_MEGA_STATIC)(_desc_megastep_impl)


def _desc_megastep(mesh=None):
    """The async megastep for the platform the work runs on: the window
    ring buffers are donated on accelerators (each upload's HBM is
    reused by the next double-buffered batch), plain on CPU (no
    donation support)."""
    platform = (mesh.devices.flat[0].platform if mesh is not None
                else jax.default_backend())
    return (_desc_megastep_plain if platform == "cpu"
            else _desc_megastep_donated)


def _part_chunk_step_impl(indptr, packed, pair_u, pair_v, pair_code,
                          item_sp, item_pv, mesh, search_iters, backend):
    """Partitioned twin of :func:`_chunk_step_impl`: every array carries a
    leading device axis and is SHARDED over the mesh — each device
    consumes its own local-CSR row and its own packed item window (graph
    arrays are sharded inputs, not replicated closures) — and the private
    histograms meet in the single closing psum.
    """
    partials = partials_fn(backend, search_iters)
    axes = mesh.axis_names

    def shard_fn(ip, pk, pu, pv, pc, wsp, wpv):
        hist64, inter = partials(
            ip.reshape(-1), pk.reshape(-1), pu.reshape(-1),
            pv.reshape(-1), pc.reshape(-1), wsp.reshape(-1),
            wpv.reshape(-1))
        return jax.lax.psum(hist64, axes), jax.lax.psum(inter, axes)

    sh = P(axes)
    fn = shard_map(
        shard_fn, mesh=mesh, in_specs=(sh,) * 7, out_specs=(P(), P()),
        check_vma=(backend == "jnp"))
    return fn(indptr, packed, pair_u, pair_v, pair_code, item_sp, item_pv)


_part_chunk_step = functools.partial(
    jax.jit, static_argnames=_STATIC)(_part_chunk_step_impl)


def _part_desc_step_impl(indptr, packed, pair_u, pair_v, pair_code,
                         desc_words, idx, mesh, search_iters, desc_iters,
                         backend, orient, prune_self):
    """Partitioned twin of :func:`_desc_step_impl`: per-device descriptor
    windows against per-device local-CSR buffers.  Every graph/pair/word
    array is (ndev, ·) sharded over the mesh — each device expands and
    classifies ITS OWN window of its own shard's stream — while the flat
    item-index array stays replicated (every device walks lanes
    ``[0, chunk_shape)`` of its private window).  One psum merges the
    private histograms.
    """
    num_anchors = num_desc_anchors(idx.shape[0])
    num_descs = (desc_words.shape[1] - 1 - num_anchors) // 3
    partials = desc_partials_fn(backend, search_iters, desc_iters,
                                orient, prune_self)
    axes = mesh.axis_names

    def shard_fn(ip, pk, pu, pv, pc, words, ix):
        words = words.reshape(-1)
        nv = words[:1]
        dp = words[1:1 + num_descs]
        dc = words[1 + num_descs:1 + 2 * num_descs]
        dw = words[1 + 2 * num_descs:1 + 3 * num_descs]
        an = words[1 + 3 * num_descs:]
        hist64, inter = partials(
            ip.reshape(-1), pk.reshape(-1), pu.reshape(-1),
            pv.reshape(-1), pc.reshape(-1), dp, dc, dw, an, nv, ix)
        return jax.lax.psum(hist64, axes), jax.lax.psum(inter, axes)

    sh = P(axes)
    fn = shard_map(
        shard_fn, mesh=mesh,
        in_specs=(sh, sh, sh, sh, sh, sh, P()), out_specs=(P(), P()),
        check_vma=(backend == "jnp"))
    return fn(indptr, packed, pair_u, pair_v, pair_code, desc_words, idx)


_part_desc_step = functools.partial(
    jax.jit, static_argnames=(
        "mesh", "search_iters", "desc_iters", "backend", "orient",
        "prune_self"))(_part_desc_step_impl)


def _jit_cache_size(step) -> int:
    """Compile counter via jax's private ``_cache_size`` — if a jax
    upgrade drops it, only the ``step_compiles`` stat degrades (to 0),
    never the census itself."""
    return getattr(step, "_cache_size", lambda: 0)()


#: bytes per packed work item (two int32 words)
ITEM_BYTES = 8


def _desc_capacity(chunk_shape: int, need: int) -> int:
    """Session descriptor capacity for a ``chunk_shape``-lane dispatch:
    2x headroom over the densest full-stream window (sparser
    affected-pair subsets span more pairs per item), capped at the
    structural bound of ``chunk_shape/2 + 1`` pairs per window — every
    pair spans >= 2 pre-prune items.  Overflowing windows shrink their
    item span instead (:func:`repro.core.planner
    .iter_descriptor_windows`), so this is never a recompile vector."""
    return min(chunk_shape // 2 + 1, max(64, 2 * need))


def _guard_chunk_shape(chunk_shape: int) -> int:
    if chunk_shape >= 2**31:
        raise PlanOverflowError(
            f"chunk_shape {chunk_shape} exceeds int32 item indexing and "
            f"would silently wrap the per-window int32 accumulator "
            f"lanes; pass a smaller max_items budget (< 2**31)")
    return chunk_shape


def _validate_partials(hist, inter) -> None:
    """Landing-time sanity check on fetched device partials: census
    histogram and intersection lanes are counts and can never go
    negative.  A corrupted (poisoned) result fails here, turning silent
    wrong answers into a retryable :class:`FaultError`."""
    if (hist < 0).any() or (inter < 0).any():
        raise FaultError(
            "device returned corrupted census partials (negative "
            "counts); retrying the window")


def _land_desc_partials(fut, hist_acc: np.ndarray, inter_acc: np.ndarray,
                        chunk_items: list) -> int:
    """Accumulate one descriptor-step result in place — hist64 into
    ``hist_acc``, the two intersection lanes into ``inter_acc`` — and
    record/return lane 2, the chunk's device-counted valid items (the
    one place that knows the ``inter3`` layout)."""
    hist_acc += np.asarray(fut[0], dtype=np.int64)
    inter3 = np.asarray(fut[1], dtype=np.int64)
    inter_acc += inter3[:2]
    num = int(inter3[2])
    chunk_items.append(num)
    return num


@dataclass
class EngineStats:
    """Execution stats of the last :class:`CensusEngine` run.

    ``peak_plan_bytes`` is the per-dispatch item-lane footprint at packed
    -item width (``ITEM_BYTES * chunk_shape`` — the streaming ceiling the
    ``max_items`` knob tunes, comparable across emit modes; under
    ``emit="device"`` nothing item-shaped is HOST-resident, and the bytes
    actually uploaded per chunk are ``plan_upload_bytes``);
    ``monolithic_plan_bytes`` is what a single dispatch of the same work
    would have shipped.  ``step_compiles`` counts fresh compilations of
    the per-chunk step during the run — 0 or 1 for a streamed run, never
    one per chunk (fixed chunk shapes).
    """

    backend: str
    ndev: int
    orient: str
    streamed: bool
    max_items: int | None
    chunks: int
    chunk_shape: int           #: padded items per dispatch
    items: int                 #: total valid work items processed
    chunk_items: list[int] = field(default_factory=list)
    peak_plan_bytes: int = 0
    monolithic_plan_bytes: int = 0
    step_compiles: int = 0
    #: session-mode extras: valid items a full recompute of the current
    #: graph would process (== ``items`` for non-incremental runs), and
    #: the number of affected pairs an incremental update re-counted
    full_items: int = 0
    affected_pairs: int = 0
    #: work-item emission mode of the run ("host" or "device")
    emit: str = "host"
    #: fixed per-dispatch descriptor-array length (device emission only)
    desc_shape: int = 0
    #: *physical per-device* host→device plan bytes shipped per dispatch:
    #: the packed item words under host emission (divided across the mesh
    #: when the item arrays are sharded), the descriptor window (+ 4-byte
    #: valid count) under device emission (replicated on every device
    #: un-partitioned, one private window per device partitioned) — the
    #: traffic the emit knob trades
    plan_upload_bytes: int = 0
    #: jitted-step compilations forced by session capacity growth (graph
    #: buffers regrown past their padded device shapes), counted apart
    #: from ``step_compiles`` so the compile-once contract stays auditable
    capacity_recompiles: int = 0
    #: True when the run sharded the GRAPH (each device held only its
    #: pair shard's local subgraph), not just the work items
    partitioned: bool = False
    #: (pair_shards, vertex_slices) of a 2D-partitioned run; None when
    #: un-partitioned or 1D (device d serves tile (d // V, d % V))
    partition_shape: tuple | None = None
    #: per-shard post-prune work items owned (partitioned runs: the LPT
    #: balance record; per-update dispatch record for sessions)
    shard_items: list[int] = field(default_factory=list)
    #: per-device resident graph + pair bytes: the max shard footprint
    #: when partitioned, the full replicated footprint otherwise
    graph_resident_bytes: int = 0
    #: what replication would have made ``graph_resident_bytes`` — equal
    #: to it on un-partitioned runs, ≥ it (the byte-reduction numerator)
    #: on partitioned ones
    graph_replicated_bytes: int = 0
    #: partitioned execution discipline ("async" or "lockstep"; "" when
    #: not partitioned)
    schedule: str = ""
    #: per-shard REAL dispatch steps (windows carrying pre-prune items) —
    #: identical between schedules; what differs is ``idle_steps``
    shard_steps: list[int] = field(default_factory=list)
    #: empty padded window lanes the lock-step barrier still dispatched
    #: (``num_steps * ndev − Σ shard_steps``); structurally 0 under async
    idle_steps: int = 0
    #: async consumer stalls: moments every produced-window queue was
    #: empty and the host had to wait on a producer (pipeline-bound)
    stall_steps: int = 0
    #: per-shard produced-window queue depth of the async host pipeline
    pipeline_depth: int = 0
    #: TOTAL host→device plan bytes attributed to REAL windows over the
    #: whole run, summed across devices and dispatches
    #: (``plan_upload_bytes`` is the per-window unit).  Padding that was
    #: physically shipped but masked — megabatch rows past the real
    #: window count under async, empty padded window lanes under
    #: lock-step — is reported separately as ``plan_pad_bytes_total``
    #: instead of silently inflating the per-shard numbers
    plan_upload_bytes_total: int = 0
    #: masked-padding plan bytes physically shipped (see above); the
    #: run's physical upload is the sum of both totals
    plan_pad_bytes_total: int = 0
    #: device dispatches issued for the run's windows: under the async
    #: megastep one dispatch consumes up to ``dispatch_batch_limit``
    #: windows, under lock-step one collective dispatch advances every
    #: shard's lane one step
    dispatches_total: int = 0
    #: real windows per dispatch, mean and max over the run — the
    #: dispatch-amortization record (async megastep: adapts toward
    #: ``dispatch_batch_limit``; lock-step: the live-lane count)
    windows_per_dispatch_mean: float = 0.0
    windows_per_dispatch_max: int = 0
    #: the megabatch cap K in effect (``max_windows_per_dispatch``;
    #: 1 == no window batching, 0 == not an async/partitioned run)
    dispatch_batch_limit: int = 0
    #: fault-tolerance record: window dispatches re-attempted after a
    #: transient failure (injected or real), devices retired to the
    #: survivors, watchdog-restarted producers, and the retired device
    #: ids — all zero/empty on a fault-free run
    retries: int = 0
    failovers: int = 0
    watchdog_fires: int = 0
    retired_devices: list = field(default_factory=list)
    #: windows restored from a checkpoint journal instead of re-executed
    resumed_windows: int = 0
    #: host planning walltime of the run, split by phase: pair-space
    #: maintenance (full ``pair_space`` rebuild, or the delta-incremental
    #: index edit + affected-pair discovery when ``indexed``), the
    #: ``apply_delta`` CSR/pair-code diff, and host-side work emission
    #: (item materialization / descriptor-window construction, measured
    #: inside the dispatch loop so device wait time is excluded)
    host_pair_seconds: float = 0.0
    host_merge_seconds: float = 0.0
    host_emit_seconds: float = 0.0
    #: True when the run's pair space came from the session's persistent
    #: :class:`~repro.core.pair_index.PairSpaceIndex` instead of a full
    #: O(P) rebuild
    indexed: bool = False

    @property
    def plan_host_seconds(self) -> float:
        """Total host planning walltime (sum of the three phase buckets)."""
        return (self.host_pair_seconds + self.host_merge_seconds
                + self.host_emit_seconds)

    @property
    def shard_max_over_mean(self) -> float:
        """Shard work imbalance (1.0 == perfectly balanced shards)."""
        if not self.shard_items or not sum(self.shard_items):
            return 1.0
        mean = sum(self.shard_items) / len(self.shard_items)
        return max(self.shard_items) / mean

    @property
    def chunk_max_over_mean(self) -> float:
        """Streamed-schedule imbalance (1.0 == perfectly even chunks)."""
        if not self.chunk_items or not sum(self.chunk_items):
            return 1.0
        mean = sum(self.chunk_items) / len(self.chunk_items)
        return max(self.chunk_items) / mean

    def summary(self) -> str:
        mode = (f"streamed max_items={self.max_items}" if self.streamed
                else "monolithic")
        part = ""
        if self.partitioned:
            mesh2d = (f" mesh={self.partition_shape[0]}"
                      f"x{self.partition_shape[1]}"
                      if self.partition_shape else "")
            part = (f" partitioned[{self.schedule}]{mesh2d} "
                    f"shards={len(self.shard_items)} "
                    f"shard_max_over_mean={self.shard_max_over_mean:.3f} "
                    f"graph_bytes={self.graph_resident_bytes}"
                    f"/{self.graph_replicated_bytes}")
            if self.schedule == "async":
                part += (f" stalls={self.stall_steps} "
                         f"depth={self.pipeline_depth} "
                         f"dispatches={self.dispatches_total} "
                         f"win/disp={self.windows_per_dispatch_mean:.2f}"
                         f"/{self.windows_per_dispatch_max}"
                         f"(cap {self.dispatch_batch_limit})")
            else:
                part += f" idle_steps={self.idle_steps}"
        if (self.retries or self.failovers or self.watchdog_fires
                or self.resumed_windows):
            part += (f" faults[retries={self.retries} "
                     f"failovers={self.failovers} "
                     f"retired={self.retired_devices} "
                     f"watchdog_fires={self.watchdog_fires} "
                     f"resumed={self.resumed_windows}]")
        if self.plan_host_seconds:
            part += (f" host[pair={self.host_pair_seconds * 1e3:.2f}ms"
                     f" merge={self.host_merge_seconds * 1e3:.2f}ms"
                     f" emit={self.host_emit_seconds * 1e3:.2f}ms"
                     f"{' indexed' if self.indexed else ''}]")
        return (f"{self.backend} [{mode} emit={self.emit}] "
                f"chunks={self.chunks} items={self.items} "
                f"peak_plan_bytes={self.peak_plan_bytes} "
                f"(monolithic {self.monolithic_plan_bytes}) "
                f"plan_upload_bytes={self.plan_upload_bytes} "
                f"chunk_max_over_mean={self.chunk_max_over_mean:.3f} "
                f"step_compiles={self.step_compiles}" + part)


class _CheckpointJournal:
    """JSONL window journal for :meth:`CensusEngine.run(checkpoint=)`.

    Line 0 is the run fingerprint (graph + schedule identity); every
    further line records one landed dispatch: the shard, the explicit
    window ids it covered, the dispatch's summed int64 partials, and
    the per-window valid item counts.  Landings are flushed
    line-by-line, so a run killed mid-stream leaves a valid prefix.

    Resume correctness rests on the property the async machinery already
    proved: the host merge is an integer sum over independent windows,
    so restoring the journaled partials and *skipping exactly the
    journaled window ids* reproduces the uninterrupted census
    bit-identically — regardless of the order landings happened to
    reach the journal (retried windows can land out of per-shard
    order, hence explicit ids instead of prefix counts).
    """

    VERSION = 1

    def __init__(self, path: str, fingerprint: dict, ndev: int):
        self.path = path
        self.fingerprint = fingerprint
        #: per-shard set of yielded-window ids already landed
        self.done: list = [set() for _ in range(ndev)]
        self.hist = np.zeros(64, np.int64)
        self.inter = np.zeros(2, np.int64)
        self.chunk_items: list = []
        self.shard_items = [0] * ndev
        self.windows = 0
        self._f = None
        if os.path.exists(path):
            self._load(ndev)
        self._f = open(path, "a" if self.windows or self._header_ok
                       else "w")
        if not self._header_ok:
            self._f.write(json.dumps({"v": self.VERSION,
                                      **fingerprint}) + "\n")
            self._f.flush()

    _header_ok = False

    @staticmethod
    def graph_fingerprint(space, *, emit: str, ndev: int,
                          max_items) -> dict:
        return {
            "n": int(space.n), "pairs": int(space.num_pairs),
            "preprune": int(space.num_items_preprune),
            "packed_crc": int(zlib.crc32(
                np.ascontiguousarray(space.packed).tobytes())),
            "orient": space.orient, "prune_self": bool(space.prune_self),
            "emit": emit, "ndev": int(ndev),
            "max_items": None if max_items is None else int(max_items),
        }

    def _load(self, ndev: int) -> None:
        with open(self.path) as f:
            lines = [ln for ln in f.read().splitlines() if ln.strip()]
        if not lines:
            return
        head = json.loads(lines[0])
        want = {"v": self.VERSION, **self.fingerprint}
        if head != want:
            raise FaultError(
                f"checkpoint {self.path!r} was written by a different "
                f"run (header {head} != {want}); delete it or pass a "
                f"fresh path")
        self._header_ok = True
        for ln in lines[1:]:
            try:
                rec = json.loads(ln)
            except json.JSONDecodeError:
                break                      # torn final line from a kill
            s = int(rec["s"])
            ids = {int(x) for x in rec["ids"]}
            if ids & self.done[s]:
                continue                   # duplicate landing — ignore
            self.done[s] |= ids
            self.hist += np.asarray(rec["hist"], dtype=np.int64)
            self.inter += np.asarray(rec["inter"], dtype=np.int64)
            self.chunk_items.extend(int(x) for x in rec["items"])
            self.shard_items[s] += int(sum(rec["items"]))
            self.windows += len(ids)

    def record(self, s: int, ids, hist, inter, items) -> None:
        self._f.write(json.dumps({
            "s": int(s), "ids": [int(x) for x in ids],
            "hist": [int(x) for x in hist],
            "inter": [int(x) for x in inter],
            "items": [int(x) for x in items]}) + "\n")
        self._f.flush()

    def close(self) -> None:
        if self._f is not None:
            self._f.close()
            self._f = None


class CensusEngine:
    """Owns mesh + backend dispatch for monolithic and streamed censuses.

    ``mesh=None`` executes on the default device; a :class:`Mesh` shards
    every chunk's items across all mesh axes.  ``partition=True``
    additionally shards the GRAPH: the pair space is LPT-split into one
    private shard per mesh device (:mod:`repro.core.partition`), each
    device holds only its shard's relabeled local subgraph and walks its
    own descriptor/item stream inside the compile-once collective step,
    and the private histograms merge in a single psum — per-device
    resident graph bytes drop from O(E) to O(E_shard + halo), with
    bit-identical censuses.  Replication (the default) remains right for
    graphs small enough to fit every device anyway — partitioning spends
    host-side extraction work to shrink device residency.  After each
    ``run`` / ``run_plan`` the execution record is available as
    :attr:`stats`.
    """

    def __init__(self, mesh: Mesh | None = None, backend: str = "jnp",
                 emit: str = "device", partition: bool = False,
                 schedule: str = "async",
                 pipeline_depth: int = PIPELINE_DEPTH,
                 max_windows_per_dispatch: int =
                 MAX_WINDOWS_PER_DISPATCH,
                 partition_2d: tuple | None = None,
                 max_retries: int = 2, retry_backoff: float = 0.01,
                 watchdog_timeout: float | None = None,
                 faults: FaultPlan | None = None):
        if backend not in BACKENDS:
            raise ValueError(
                f"unknown backend {backend!r}; one of {BACKENDS}")
        if emit not in EMIT_MODES:
            raise ValueError(
                f"unknown emit mode {emit!r}; one of {EMIT_MODES}")
        if schedule not in SCHEDULES:
            raise ValueError(
                f"unknown schedule {schedule!r}; one of {SCHEDULES}")
        if partition_2d is not None:
            partition = True          # a 2D mesh factorization implies it
            partition_2d = (int(partition_2d[0]), int(partition_2d[1]))
            if partition_2d[0] < 1 or partition_2d[1] < 1:
                raise ValueError(
                    f"partition_2d must be >= (1, 1), got {partition_2d}")
        if partition:
            if mesh is None:
                raise ValueError("partition=True requires a mesh")
            if mesh.devices.ndim != 1:
                raise ValueError(
                    "partitioned execution shards over a 1-D mesh; got "
                    f"shape {mesh.devices.shape}")
            ndev = int(np.prod(mesh.devices.shape))
            if (partition_2d is not None
                    and partition_2d[0] * partition_2d[1] != ndev):
                raise ValueError(
                    f"partition_2d {partition_2d} needs "
                    f"{partition_2d[0] * partition_2d[1]} devices; the "
                    f"mesh has {ndev}")
        if pipeline_depth < 1:
            raise ValueError(
                f"pipeline_depth must be >= 1, got {pipeline_depth}")
        if max_windows_per_dispatch < 1:
            raise ValueError(
                "max_windows_per_dispatch must be >= 1, got "
                f"{max_windows_per_dispatch}")
        if max_retries < 0:
            raise ValueError(
                f"max_retries must be >= 0, got {max_retries}")
        if retry_backoff < 0:
            raise ValueError(
                f"retry_backoff must be >= 0, got {retry_backoff}")
        if watchdog_timeout is not None and watchdog_timeout <= 0:
            raise ValueError(
                f"watchdog_timeout must be > 0, got {watchdog_timeout}")
        self.mesh = mesh
        self.backend = backend
        self.emit = emit
        self.partition = partition
        #: (pair_shards, vertex_slices) factorization of the 1-D mesh;
        #: device d serves tile (d // V, d % V).  None == 1D partition.
        self.partition_2d = partition_2d
        self.schedule = schedule
        #: per-shard produced-window queue depth of the async host
        #: pipeline (:class:`repro.core.plan_stream.ShardStreamPipeline`)
        self.pipeline_depth = int(pipeline_depth)
        #: cap K on the windows one async megastep dispatch may consume
        self.max_windows_per_dispatch = int(max_windows_per_dispatch)
        #: fault-tolerance knobs: per-window re-dispatch budget with
        #: exponential ``retry_backoff`` sleeps, producer-stall watchdog
        #: (None == off), and an optional deterministic
        #: :class:`repro.core.faults.FaultPlan` to inject against
        self.max_retries = int(max_retries)
        self.retry_backoff = float(retry_backoff)
        self.watchdog_timeout = (None if watchdog_timeout is None
                                 else float(watchdog_timeout))
        self.faults = faults
        self.stats: EngineStats | None = None

    @property
    def ndev(self) -> int:
        return 1 if self.mesh is None else int(
            np.prod(self.mesh.devices.shape))

    # ------------------------------------------------------------- helpers
    def _shardings(self):
        """(replicated, item-sharded) NamedShardings, or (None, None)."""
        if self.mesh is None:
            return None, None
        return (NamedSharding(self.mesh, P()),
                NamedSharding(self.mesh, P(self.mesh.axis_names)))

    def _put(self, a, sharding):
        arr = jnp.asarray(a)
        return arr if sharding is None else jax.device_put(arr, sharding)

    def _mono_stats(self, plan: CensusPlan,
                    max_items: int | None = None) -> EngineStats:
        wp = int(plan.item_sp.shape[0])
        gbytes = 4 * (plan.indptr.shape[0] + plan.packed.shape[0]
                      + 3 * plan.num_pairs)
        return EngineStats(
            backend=self.backend, ndev=self.ndev, orient=plan.orient,
            streamed=False, max_items=max_items,
            chunks=1 if plan.num_items else 0, chunk_shape=wp,
            items=plan.num_items,
            chunk_items=[plan.num_items] if plan.num_items else [],
            peak_plan_bytes=ITEM_BYTES * wp,
            monolithic_plan_bytes=ITEM_BYTES * wp,
            emit="host",
            # items are sharded over the mesh: physical per-device bytes
            plan_upload_bytes=ITEM_BYTES * wp // self.ndev,
            graph_resident_bytes=gbytes, graph_replicated_bytes=gbytes)

    # ------------------------------------------------------------- running
    def run_plan(self, plan: CensusPlan) -> np.ndarray:
        """Exact 16-type census from a prebuilt (monolithic) plan."""
        if self.partition:
            raise ValueError(
                "prebuilt plans are replicated; partitioned execution "
                "plans from the graph — use run()/session()")
        wp = int(plan.item_sp.shape[0])
        if self.mesh is not None and wp % self.ndev != 0:
            raise ValueError(
                f"plan padded to {wp} items, not a multiple of "
                f"{self.ndev} devices; build with pad_to=num_devices")
        self.stats = self._mono_stats(plan)
        if plan.num_pairs == 0 or plan.num_items == 0:
            # zero-work plans (incl. pairs whose items were all pruned)
            # resolve entirely from the host closed forms — the device is
            # never dispatched on zero-length item arrays
            return assemble_census(plan, np.zeros(64, np.int64),
                                   np.zeros(2, np.int64))
        rep, item_sh = self._shardings()
        step = _chunk_step(self.mesh)
        cache0 = _jit_cache_size(step)
        hist64, inter = step(
            self._put(plan.indptr, rep), self._put(plan.packed, rep),
            self._put(plan.pair_u, rep), self._put(plan.pair_v, rep),
            self._put(plan.pair_code, rep),
            self._put(plan.item_sp, item_sh),
            self._put(plan.item_pv, item_sh),
            self.mesh, plan.search_iters, self.backend)
        census = assemble_census(plan, np.asarray(hist64),
                                 np.asarray(inter))
        self.stats.step_compiles = _jit_cache_size(step) - cache0
        return census

    def run(self, g: CompactDigraph, *, max_items: int | None = None,
            orient: str = "none", prune_self: bool = True,
            progress=None, emit: str | None = None,
            schedule: str | None = None, part=None,
            checkpoint: str | None = None) -> np.ndarray:
        """Plan + count ``g`` end to end.

        ``max_items=None`` covers the whole item space in one dispatch;
        an integer budget streams bounded chunks instead (O(max_items)).
        ``emit`` (default: the engine's mode) picks the work-item path:
        ``"device"`` ships O(pairs) descriptors per chunk and expands
        pairs→items in-kernel; ``"host"`` materializes, packs and uploads
        every O(W) item in numpy (the oracle).  Both are bit-identical on
        every backend and orient mode.
        ``progress(chunk_index, num_chunks, chunk_valid_items)`` is called
        per chunk — at dispatch under host emission, when the chunk's
        device-counted valid items land under device emission.

        Partitioned engines additionally accept ``schedule`` (default:
        the engine's; ``"async"`` walks per-shard private queues with no
        inter-shard barrier, ``"lockstep"`` is the collective oracle) and
        ``part`` — a prebuilt :class:`repro.core.partition.GraphPartition`
        over ``num_shards == ndev`` shards, overriding the internal LPT
        (``orient``/``prune_self`` are then taken from its space).

        ``checkpoint`` (partitioned async runs only) journals every
        landed window to the given JSONL path; a later ``run`` (or
        :meth:`resume`) against an existing journal restores the
        journaled partials, skips the completed windows, and reproduces
        the uninterrupted census bit-identically.
        """
        emit = self.emit if emit is None else emit
        if emit not in EMIT_MODES:
            raise ValueError(
                f"unknown emit mode {emit!r}; one of {EMIT_MODES}")
        schedule = self.schedule if schedule is None else schedule
        if schedule not in SCHEDULES:
            raise ValueError(
                f"unknown schedule {schedule!r}; one of {SCHEDULES}")
        if part is not None and not self.partition:
            raise ValueError(
                "a prebuilt partition requires partition=True")
        if checkpoint is not None and not (
                self.partition and schedule == "async"):
            raise ValueError(
                "checkpoint/resume is supported on partitioned async "
                "runs (partition=True, schedule='async')")
        if self.partition:
            return self._run_partitioned(g, max_items=max_items,
                                         orient=orient,
                                         prune_self=prune_self,
                                         progress=progress, emit=emit,
                                         schedule=schedule, part=part,
                                         checkpoint=checkpoint)
        if emit == "device":
            chunker = PlanChunker(g, max_items, orient=orient,
                                  pad_to=self.ndev, prune_self=prune_self)
            return self._run_stream_desc(chunker, progress,
                                         max_items=max_items)
        if max_items is None:
            plan = build_plan(g, pad_to=self.ndev, orient=orient,
                              prune_self=prune_self)
            return self.run_plan(plan)
        chunker = PlanChunker(g, max_items, orient=orient,
                              pad_to=self.ndev, prune_self=prune_self)
        return self._run_stream(chunker, progress)

    def resume(self, g: CompactDigraph, checkpoint: str,
               **kwargs) -> np.ndarray:
        """Resume a checkpointed partitioned async run: requires the
        journal to exist (use :meth:`run` with ``checkpoint=`` to start
        one), restores its landed windows, and completes the rest —
        bit-identical to the uninterrupted run."""
        if not os.path.exists(checkpoint):
            raise FileNotFoundError(
                f"no checkpoint journal at {checkpoint!r}; start the "
                f"run with run(..., checkpoint=path) first")
        return self.run(g, checkpoint=checkpoint, **kwargs)

    @staticmethod
    def compact_checkpoint(checkpoint: str) -> dict:
        """Fold an append-only checkpoint journal into its minimal form.

        A long checkpointed run appends one JSONL record per landed
        dispatch, so the journal grows with the window count even though
        resume only needs the *sums*.  Compaction rewrites the file as
        the fingerprint header plus ONE merged record per shard (summed
        partials, unioned window ids, concatenated per-window item
        counts) — the landing merge is an integer sum over independent
        windows, so :meth:`resume` restores the compacted journal to the
        exact state the full journal would have produced, and keeps
        appending new landings after it (``_load`` is additive per
        record; both forms read identically).

        Duplicate landings and a torn final line are dropped the same
        way loading drops them.  The rewrite is atomic (temp file +
        ``os.replace``), so a kill mid-compaction leaves the original
        journal intact.  Returns ``{"records", "compacted", "bytes",
        "compacted_bytes"}``.
        """
        if not os.path.exists(checkpoint):
            raise FileNotFoundError(
                f"no checkpoint journal at {checkpoint!r}")
        old_bytes = os.path.getsize(checkpoint)
        with open(checkpoint) as f:
            lines = [ln for ln in f.read().splitlines() if ln.strip()]
        if not lines:
            raise FaultError(
                f"checkpoint {checkpoint!r} is empty — nothing to "
                f"compact")
        head = json.loads(lines[0])
        if head.get("v") != _CheckpointJournal.VERSION:
            raise FaultError(
                f"checkpoint {checkpoint!r} has unknown version "
                f"{head.get('v')!r}")
        # replay the records exactly the way _load does (skip duplicate
        # landings and the torn tail), but keep the sums per shard
        merged: dict = {}
        records = 0
        for ln in lines[1:]:
            try:
                rec = json.loads(ln)
            except json.JSONDecodeError:
                break
            records += 1
            s = int(rec["s"])
            m = merged.setdefault(s, {
                "ids": set(), "hist": np.zeros(64, np.int64),
                "inter": np.zeros(2, np.int64), "items": []})
            ids = {int(x) for x in rec["ids"]}
            if ids & m["ids"]:
                continue
            m["ids"] |= ids
            m["hist"] += np.asarray(rec["hist"], dtype=np.int64)
            m["inter"] += np.asarray(rec["inter"], dtype=np.int64)
            m["items"].extend(int(x) for x in rec["items"])
        tmp = checkpoint + ".compact.tmp"
        with open(tmp, "w") as f:
            f.write(json.dumps(head) + "\n")
            for s in sorted(merged):
                m = merged[s]
                f.write(json.dumps({
                    "s": s, "ids": sorted(m["ids"]),
                    "hist": [int(x) for x in m["hist"]],
                    "inter": [int(x) for x in m["inter"]],
                    "items": m["items"]}) + "\n")
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, checkpoint)
        return {"records": records, "compacted": len(merged),
                "bytes": old_bytes,
                "compacted_bytes": os.path.getsize(checkpoint)}

    def session(self, g: CompactDigraph, *, orient: str = "none",
                prune_self: bool = True, max_items: int | None = None,
                emit: str | None = None,
                auto_rebalance_threshold: float | None = None,
                index: bool = True):
        """Open a resident-graph session on ``g`` for repeated / sliding-
        window censuses (see :class:`EngineSession`; a partitioned engine
        opens a :class:`PartitionedEngineSession`, whose delta updates
        dispatch only the shards owning touched pairs).
        ``auto_rebalance_threshold`` (partitioned only) re-shards the
        session with a fresh LPT whenever churn pushes the load
        ``max/mean`` past it (see
        :meth:`PartitionedEngineSession.rebalance`).  ``index`` keeps a
        persistent :class:`~repro.core.pair_index.PairSpaceIndex` so
        warm ``update()`` calls edit the pair space in O(delta · log P)
        instead of rebuilding it in O(P); ``index=False`` is the
        rebuild-from-scratch oracle path (bit-identical either way)."""
        if self.partition:
            if self.partition_2d is not None:
                return PartitionedEngineSession2D(
                    self, g, mesh_shape=self.partition_2d,
                    orient=orient, prune_self=prune_self,
                    max_items=max_items, emit=emit,
                    auto_rebalance_threshold=auto_rebalance_threshold,
                    index=index)
            return PartitionedEngineSession(
                self, g, orient=orient, prune_self=prune_self,
                max_items=max_items, emit=emit,
                auto_rebalance_threshold=auto_rebalance_threshold,
                index=index)
        if auto_rebalance_threshold is not None:
            raise ValueError(
                "auto_rebalance_threshold requires partition=True")
        return EngineSession(self, g, orient=orient,
                             prune_self=prune_self,
                             max_items=max_items, emit=emit, index=index)

    def _run_stream(self, chunker: PlanChunker, progress) -> np.ndarray:
        space = chunker.space
        gbytes = replicated_graph_bytes(space)
        self.stats = EngineStats(
            backend=self.backend, ndev=self.ndev, orient=space.orient,
            streamed=True, max_items=chunker.max_items,
            chunks=chunker.num_chunks, chunk_shape=chunker.chunk_shape,
            items=0, peak_plan_bytes=ITEM_BYTES * chunker.chunk_shape,
            emit="host",
            # item arrays are sharded over the mesh (chunk_shape is a
            # multiple of ndev): physical per-device upload bytes
            plan_upload_bytes=ITEM_BYTES * chunker.chunk_shape
            // self.ndev,
            graph_resident_bytes=gbytes, graph_replicated_bytes=gbytes)
        if chunker.num_chunks == 0:
            return assemble_counts(space.n, 0, 0, np.zeros(64, np.int64),
                                   np.zeros(2, np.int64))

        rep, item_sh = self._shardings()
        # chunk-invariant graph + pair arrays: uploaded once, reused by
        # every chunk step (replicated across the mesh when sharded)
        graph_dev = tuple(self._put(a, rep)
                          for a in chunker.device_arrays())

        hist_acc = np.zeros(64, np.int64)
        inter_acc = np.zeros(2, np.int64)
        base_asym = base_mut = 0
        chunk_items: list[int] = []
        step = _chunk_step(self.mesh)
        cache0 = _jit_cache_size(step)
        pending = None
        for chunk in chunker:
            base_asym += chunk.base_asym
            base_mut += chunk.base_mut
            chunk_items.append(chunk.num_items)
            if progress is not None:
                progress(chunk.index, chunker.num_chunks, chunk.num_items)
            if chunk.num_items == 0:
                # fully-pruned chunk: its bases are credited above, the
                # all-invalid items contribute nothing — skip the dispatch
                # (mirrors the monolithic zero-work short-circuit)
                continue
            # upload + dispatch chunk k while chunk k-1 still computes
            # (dispatch is async; we only block when accumulating k-1)
            sp_dev = self._put(chunk.item_sp, item_sh)
            pv_dev = self._put(chunk.item_pv, item_sh)
            fut = step(*graph_dev, sp_dev, pv_dev,
                       self.mesh, space.search_iters, self.backend)
            if pending is not None:
                hist_acc += np.asarray(pending[0], dtype=np.int64)
                inter_acc += np.asarray(pending[1], dtype=np.int64)
            pending = fut
        if pending is not None:
            hist_acc += np.asarray(pending[0], dtype=np.int64)
            inter_acc += np.asarray(pending[1], dtype=np.int64)

        st = self.stats
        st.step_compiles = _jit_cache_size(step) - cache0
        st.chunk_items = chunk_items
        st.items = int(sum(chunk_items))
        mono_wp = -(-st.items // self.ndev) * self.ndev
        st.monolithic_plan_bytes = ITEM_BYTES * mono_wp
        return assemble_counts(space.n, base_asym, base_mut,
                               hist_acc, inter_acc)

    def _run_stream_desc(self, chunker: PlanChunker, progress,
                         max_items: int | None) -> np.ndarray:
        """Device-emission stream: per chunk the host ships the O(pairs)
        descriptor window; the device expands pairs→items in-kernel
        against the resident flat-index array.  Bit-identical to
        :meth:`_run_stream` — the expanded pre-prune items carry the
        plan-time pruning as an in-kernel mask, and every masked item is
        provably a zero contribution (see
        :func:`repro.core.census.prune_keep_mask`)."""
        space = chunker.space
        # the descriptor buffer is replicated on every device: the padded
        # window IS the physical per-device upload
        upload = (DESC_BYTES * chunker.desc_shape
                  + 4 * chunker.num_anchors + 4)
        gbytes = replicated_graph_bytes(space)
        self.stats = EngineStats(
            backend=self.backend, ndev=self.ndev, orient=space.orient,
            streamed=max_items is not None, max_items=max_items,
            chunks=chunker.num_chunks, chunk_shape=chunker.chunk_shape,
            items=0, peak_plan_bytes=ITEM_BYTES * chunker.chunk_shape,
            emit="device", desc_shape=chunker.desc_shape,
            plan_upload_bytes=upload,
            graph_resident_bytes=gbytes, graph_replicated_bytes=gbytes)
        if chunker.num_chunks == 0:
            return assemble_counts(space.n, 0, 0, np.zeros(64, np.int64),
                                   np.zeros(2, np.int64))

        rep, item_sh = self._shardings()
        graph_dev = tuple(self._put(a, rep)
                          for a in chunker.device_arrays())
        # the flat item-index space: created on device once, reused by
        # every chunk (this is the array the mesh shards — there are no
        # item arrays left to shard)
        idx_dev = self._put(jnp.arange(chunker.chunk_shape, dtype=jnp.int32),
                            item_sh)

        hist_acc = np.zeros(64, np.int64)
        inter_acc = np.zeros(2, np.int64)
        base_asym = base_mut = 0
        chunk_items: list[int] = []
        cache0 = _jit_cache_size(_desc_step)
        pending = None

        def land(fut, k):
            num = _land_desc_partials(fut, hist_acc, inter_acc,
                                      chunk_items)
            if progress is not None:
                progress(k, chunker.num_chunks, num)

        for k in range(chunker.num_chunks):
            ba, bm = chunker.bases(k)
            base_asym += ba
            base_mut += bm
            win = chunker.descriptors(k)
            words = self._put(win.device_words(), rep)
            fut = _desc_step(*graph_dev, words, idx_dev,
                             self.mesh, space.search_iters,
                             chunker.desc_iters, self.backend,
                             space.orient, space.prune_self)
            if pending is not None:
                land(pending, k - 1)
            pending = fut
        if pending is not None:
            land(pending, chunker.num_chunks - 1)

        st = self.stats
        st.step_compiles = _jit_cache_size(_desc_step) - cache0
        st.chunk_items = chunk_items
        st.items = int(sum(chunk_items))
        mono_wp = -(-st.items // self.ndev) * self.ndev
        st.monolithic_plan_bytes = ITEM_BYTES * mono_wp
        return assemble_counts(space.n, base_asym, base_mut,
                               hist_acc, inter_acc)

    def _run_partitioned(self, g: CompactDigraph, *,
                         max_items: int | None, orient: str,
                         prune_self: bool, progress, emit: str,
                         schedule: str, part=None,
                         checkpoint: str | None = None) -> np.ndarray:
        """Partitioned plan + count: LPT-shard the pair space (or take a
        prebuilt ``part``), extract one local subgraph per mesh device,
        and walk every device's private chunk queue
        (:class:`repro.core.plan_stream.ShardSchedule`).  Each device
        holds only ITS shard's relabeled CSR + pair arrays and receives
        only its own descriptor windows (``emit="device"``) or packed
        item windows (``emit="host"``).  ``schedule="async"`` (default)
        drains the queues independently — per-device dispatches, host
        merge, no inter-shard barrier; ``"lockstep"`` advances them
        together through the collective step with a single closing psum
        (the oracle).  Bit-identical to the replicated and single-device
        paths for every backend, orient, emit and schedule (the
        relabeling is order-preserving, the pair partition is exact, and
        the partials are integer sums — merge order cannot matter)."""
        if part is None:
            space = pair_space(g, orient=orient, prune_self=prune_self)
            part = (partition_graph_2d(space=space,
                                       mesh_shape=self.partition_2d)
                    if self.partition_2d is not None
                    else partition_graph(num_shards=self.ndev,
                                         space=space))
        elif part.num_shards != self.ndev:
            raise ValueError(
                f"prebuilt partition has {part.num_shards} shards for "
                f"{self.ndev} devices")
        elif (self.partition_2d is not None
              and getattr(part, "mesh_shape", None) != self.partition_2d):
            raise ValueError(
                f"prebuilt partition mesh "
                f"{getattr(part, 'mesh_shape', None)} does not match "
                f"partition_2d={self.partition_2d}")
        space = part.space
        sched = ShardSchedule([sh.space for sh in part.shards],
                              max_items, self.ndev,
                              mesh_shape=getattr(part, "mesh_shape",
                                                 None))
        upload = (4 * (1 + 3 * sched.desc_shape + sched.num_anchors)
                  if emit == "device"
                  else ITEM_BYTES * sched.chunk_shape)
        if schedule == "async":
            return self._run_partitioned_async(part, sched, progress,
                                               emit, max_items, upload,
                                               checkpoint=checkpoint)
        self.stats = EngineStats(
            backend=self.backend, ndev=self.ndev, orient=space.orient,
            streamed=max_items is not None, max_items=max_items,
            chunks=sched.num_steps,
            chunk_shape=sched.chunk_shape * self.ndev,
            items=0,
            peak_plan_bytes=ITEM_BYTES * sched.chunk_shape * self.ndev,
            emit=emit,
            desc_shape=sched.desc_shape if emit == "device" else 0,
            plan_upload_bytes=upload, partitioned=True,
            partition_shape=getattr(part, "mesh_shape", None),
            shard_items=list(part.stats.shard_items),
            graph_resident_bytes=part.stats.max_shard_bytes,
            graph_replicated_bytes=part.stats.replicated_bytes,
            schedule="lockstep", shard_steps=sched.shard_steps,
            idle_steps=(sched.num_steps * self.ndev
                        - sched.total_windows),
            # real windows vs the empty padded lanes the barrier still
            # ships: the physical upload is the sum of both totals
            plan_upload_bytes_total=sched.total_windows * upload,
            plan_pad_bytes_total=(sched.num_steps * self.ndev
                                  - sched.total_windows) * upload,
            dispatches_total=sched.num_steps,
            windows_per_dispatch_mean=(
                sched.total_windows / sched.num_steps
                if sched.num_steps else 0.0),
            # live lanes per step never exceed step 0's (shards only
            # drain), so the max is the non-empty shard count
            windows_per_dispatch_max=sum(
                1 for t in sched.shard_steps if t > 0),
            dispatch_batch_limit=1)
        base_asym, base_mut = global_bases(space)
        if sched.num_steps == 0:
            return assemble_counts(space.n, base_asym, base_mut,
                                   np.zeros(64, np.int64),
                                   np.zeros(2, np.int64))

        rep, dev_sh = self._shardings()
        graph_dev = tuple(self._put(a, dev_sh)
                          for a in stacked_device_arrays(part.shards))
        hist_acc = np.zeros(64, np.int64)
        inter_acc = np.zeros(2, np.int64)
        chunk_items: list[int] = []
        pending = None
        if emit == "device":
            idx_dev = self._put(
                jnp.arange(sched.chunk_shape, dtype=jnp.int32), rep)
            step = _part_desc_step
            cache0 = _jit_cache_size(step)

            def land(fut, k):
                num = _land_desc_partials(fut, hist_acc, inter_acc,
                                          chunk_items)
                if progress is not None:
                    progress(k, sched.num_steps, num)

            for k in range(sched.num_steps):
                words = self._put(sched.step_words(k), dev_sh)
                fut = step(*graph_dev, words, idx_dev, self.mesh,
                           space.search_iters, sched.desc_iters,
                           self.backend, space.orient, space.prune_self)
                if pending is not None:
                    land(pending, k - 1)
                pending = fut
            if pending is not None:
                land(pending, sched.num_steps - 1)
        else:
            step = _part_chunk_step
            cache0 = _jit_cache_size(step)
            for k in range(sched.num_steps):
                item_sp, item_pv, nums = sched.step_items(k)
                chunk_items.append(int(sum(nums)))
                if progress is not None:
                    progress(k, sched.num_steps, chunk_items[-1])
                fut = step(graph_dev[0], graph_dev[1], graph_dev[2],
                           graph_dev[3], graph_dev[4],
                           self._put(item_sp, dev_sh),
                           self._put(item_pv, dev_sh),
                           self.mesh, space.search_iters, self.backend)
                if pending is not None:
                    hist_acc += np.asarray(pending[0], dtype=np.int64)
                    inter_acc += np.asarray(pending[1], dtype=np.int64)
                pending = fut
            if pending is not None:
                hist_acc += np.asarray(pending[0], dtype=np.int64)
                inter_acc += np.asarray(pending[1], dtype=np.int64)

        st = self.stats
        st.step_compiles = _jit_cache_size(step) - cache0
        st.chunk_items = chunk_items
        st.items = int(sum(chunk_items))
        mono_wp = -(-st.items // self.ndev) * self.ndev
        st.monolithic_plan_bytes = ITEM_BYTES * mono_wp
        return assemble_counts(space.n, base_asym, base_mut,
                               hist_acc, inter_acc)

    def _run_partitioned_async(self, part, sched: ShardSchedule,
                               progress, emit: str,
                               max_items: int | None,
                               upload: int,
                               checkpoint: str | None = None
                               ) -> np.ndarray:
        """Async per-shard streams: every device drains its PRIVATE chunk
        queue with no inter-shard barrier.

        Instead of one collective dispatch per lock step (where the
        longest shard's queue gates every device, and exhausted shards
        burn whole steps on empty padded windows), each shard's real
        windows are dispatched as independent single-device steps against
        per-device-committed shard buffers — the
        :class:`PartitionedEngineSession` dispatch discipline applied to
        the full run.  A shard with 3 chunks is done after 3 dispatches
        while a 12-chunk shard keeps going, so walltime tracks the MEAN
        shard cost, not the max.

        The host side is pipelined by a
        :class:`repro.core.plan_stream.ShardStreamPipeline`: one
        background producer per non-empty shard packs descriptor
        windows / emits item batches ``pipeline_depth`` windows ahead
        into its private queue, so window k+1's generation + upload
        overlaps window k's compute (zero-window shards never get a
        producer or a rotation slot); dispatches are async (futures)
        with a bounded in-flight deque of ``2 * ndev``, keeping host +
        device plan memory O(ndev · chunk_shape).  On accelerator
        platforms the uploaded buffers are donated (:func:`_chunk_step`
        / :func:`_desc_megastep`), so the double-buffered uploads reuse
        HBM.

        Under ``emit="device"`` each dispatch is a **megastep**: the
        producer coalesces up to K descriptor windows into one
        fixed-shape ``(cap, words)`` batch
        (:class:`repro.core.plan_stream.WindowBatcher`) and the device
        scans them inside one compiled step
        (:func:`_desc_megastep`), so Python dispatch cost — the async
        schedule's Achilles' heel on fast devices with tiny windows —
        is paid once per K windows.  K adapts live between 1 and
        ``max_windows_per_dispatch``: consumer stalls shrink it
        (producer-bound: smaller batches keep the pipeline full),
        producer backlog grows it (dispatch-bound: amortize more).
        ``emit="host"`` keeps the PR 6 one-window-per-dispatch path as
        the oracle.

        Partials merge on the host in int64 — integer sums, so the
        arbitrary landing order is bit-identical to the lock-step psum.

        **Fault tolerance** rides on the same property: windows are
        independent and the merge is order-invariant, so any window can
        be re-dispatched (after a transient error or a corrupted
        result) or re-routed to a surviving device (after its home
        device is retired) without changing a single census bit.  Every
        dispatch is retried up to ``max_retries`` with exponential
        backoff; a device that exhausts the budget (or hits a
        persistent injected fault) is retired and its shards' host
        arrays are re-uploaded to a survivor, whose already-compiled
        step drains the remaining queue; stalled producers are
        restarted by the pipeline watchdog; and ``checkpoint=`` journals
        every landed window so a killed run resumes to the exact same
        census.  An optional :class:`repro.core.faults.FaultPlan`
        injects deterministic failures at the producer / upload /
        dispatch boundaries to exercise all of it.
        """
        space = part.space
        ndev = self.ndev
        total_windows = sched.total_windows
        # effective megabatch capacity: never pad past the longest
        # shard's queue — a schedule whose every shard has s windows can
        # fill at most s rows per batch, so a larger buffer would only
        # upload dead zero rows (the scan already skips their compute)
        cap = (max(1, min(self.max_windows_per_dispatch,
                          max(sched.shard_steps, default=0)))
               if emit == "device" else 1)
        self.stats = EngineStats(
            backend=self.backend, ndev=ndev, orient=space.orient,
            streamed=max_items is not None, max_items=max_items,
            chunks=0, chunk_shape=sched.chunk_shape, items=0,
            # the schedule-wide lane footprint (all devices), comparable
            # with the lock-step record
            peak_plan_bytes=ITEM_BYTES * sched.chunk_shape * ndev,
            emit=emit,
            desc_shape=sched.desc_shape if emit == "device" else 0,
            plan_upload_bytes=upload, partitioned=True,
            partition_shape=getattr(part, "mesh_shape", None),
            shard_items=list(part.stats.shard_items),
            graph_resident_bytes=part.stats.max_shard_bytes,
            graph_replicated_bytes=part.stats.replicated_bytes,
            schedule="async", shard_steps=[0] * ndev,
            pipeline_depth=self.pipeline_depth,
            dispatch_batch_limit=cap)
        base_asym, base_mut = global_bases(space)
        if total_windows == 0:
            return assemble_counts(space.n, base_asym, base_mut,
                                   np.zeros(64, np.int64),
                                   np.zeros(2, np.int64))

        injector = (self.faults.injector()
                    if self.faults is not None else None)
        journal = None
        done = None
        if checkpoint is not None:
            fp = _CheckpointJournal.graph_fingerprint(
                space, emit=emit, ndev=ndev, max_items=max_items)
            journal = _CheckpointJournal(checkpoint, fp, ndev)
            done = journal.done

        devices = list(self.mesh.devices.flat)
        # per-device commit of each shard's padded local arrays (common
        # shapes across shards, so ONE compiled single-device step serves
        # every shard's every window); the host copies in ``arrs`` stay
        # alive as the failover re-upload source
        arrs = stacked_device_arrays(part.shards)
        dev = [tuple(jax.device_put(a[s], devices[s]) for a in arrs)
               for s in range(ndev)]
        #: shard → device currently serving it (failover re-routes)
        home = list(range(ndev))
        retired: set = set()
        # drained-shard short-circuit: a shard with zero windows never
        # gets a producer thread or a consumer rotation slot
        batcher = None
        if emit == "device":
            step = _desc_megastep(self.mesh)
            idx = [jax.device_put(
                np.arange(sched.chunk_shape, dtype=np.int32), d)
                for d in devices]
            batcher = WindowBatcher(
                cap, 1 + 3 * sched.desc_shape + sched.num_anchors)
            # remaining window ids per shard in yield order — lets the
            # consumer recover each pulled window's id (FIFO queues
            # preserve producer order) for the checkpoint journal
            order = [[k for k in range(sched.steps_for(s))
                      if done is None or k not in done[s]]
                     for s in range(ndev)]
            live = [s for s in range(ndev) if order[s]]

            def make_source(s, skip=0):
                def gen():
                    for j, k in enumerate(order[s]):
                        if j < skip:
                            continue
                        if injector is not None:
                            injector.fire("producer", shard=s)
                        yield sched.descriptors(s, k).device_words()
                return gen()
        else:
            step = _chunk_step(self.mesh)
            order = None
            live = [s for s in range(ndev) if sched.steps_for(s) > 0]

            def make_source(s, skip=0):
                def gen():
                    emitted = 0
                    for k in range(sched.steps_for(s)):
                        if done is not None and k in done[s]:
                            continue
                        sp, pv, num = sched.shard_step_items(s, k)
                        if num == 0:
                            # fully-pruned window: zero contribution by
                            # construction — never dispatched
                            continue
                        emitted += 1
                        if emitted <= skip:
                            continue
                        if injector is not None:
                            injector.fire("producer", shard=s)
                        yield k, sp, pv, num
                return gen()

        cache0 = _jit_cache_size(step)
        hist_acc = np.zeros(64, np.int64)
        inter_acc = np.zeros(2, np.int64)
        chunk_items: list[int] = []
        if journal is not None and journal.windows:
            np.add(hist_acc, journal.hist, out=hist_acc)
            np.add(inter_acc, journal.inter, out=inter_acc)
            chunk_items.extend(journal.chunk_items)
            self.stats.resumed_windows = journal.windows
        shard_steps = [0] * ndev
        pos = [0] * ndev
        dispatches = 0
        win_max = 0
        pad_windows = 0
        landed = [self.stats.resumed_windows]
        st = self.stats

        def retire(d_id: int, cause) -> None:
            """Fail device ``d_id`` over to the survivors: every shard
            homed on it is re-uploaded (from the host copies) onto a
            surviving device, whose already-compiled step drains the
            rest of the queue.  The merge is untouched, so the census
            stays bit-identical."""
            if d_id in retired:
                return
            retired.add(d_id)
            survivors = [x for x in range(ndev) if x not in retired]
            if not survivors:
                raise FaultError(
                    "every device has been retired; cannot complete "
                    "the census") from cause
            st.failovers += 1
            st.retired_devices.append(d_id)
            for s2 in range(ndev):
                if home[s2] == d_id:
                    r = survivors[s2 % len(survivors)]
                    home[s2] = r
                    dev[s2] = tuple(
                        jax.device_put(a[s2], devices[r]) for a in arrs)

        def do_dispatch(s: int, window):
            """One dispatch attempt of ``window`` on shard ``s``'s home
            device; returns (future, poisoned)."""
            d_id = home[s]
            d = devices[d_id]
            if injector is not None:
                injector.fire("upload", shard=s, device=d_id)
            if emit == "device":
                buf, _x = window
                buf_d = jax.device_put(buf, d)
                if injector is not None:
                    injector.fire("dispatch", shard=s, device=d_id)
                fut = step(*dev[s], buf_d, idx[d_id],
                           space.search_iters, sched.desc_iters,
                           self.backend, space.orient, space.prune_self)
            else:
                _wid, sp, pv, _num = window
                sp_d = jax.device_put(sp, d)
                pv_d = jax.device_put(pv, d)
                if injector is not None:
                    injector.fire("dispatch", shard=s, device=d_id)
                fut = step(*dev[s], sp_d, pv_d, None,
                           space.search_iters, self.backend)
            poisoned = (injector.take_poison()
                        if injector is not None else False)
            return fut, poisoned

        def dispatch_retrying(s: int, window, attempts: int = 0):
            """Dispatch with the retry/failover discipline: transient
            failures back off and retry on the same device up to
            ``max_retries``; a dead device (persistent fault) or an
            exhausted budget retires the device and re-routes."""
            while True:
                d_id = home[s]
                try:
                    fut, poisoned = do_dispatch(s, window)
                    return fut, poisoned, attempts
                except Exception as exc:
                    dead = ((injector is not None
                             and injector.device_is_dead(d_id))
                            or getattr(getattr(exc, "fault", None),
                                       "persistent", False))
                    if dead:
                        retire(d_id, exc)
                        attempts = 0
                        continue
                    attempts += 1
                    st.retries += 1
                    if attempts > self.max_retries:
                        # budget exhausted: treat the device as failed
                        # and drain its queue on the survivors
                        retire(d_id, exc)
                        attempts = 0
                        continue
                    time.sleep(self.retry_backoff
                               * 2 ** (attempts - 1))

        def land(job) -> None:
            s, window, ids, fut, x, attempts, poisoned = job
            while True:
                try:
                    if emit == "device":
                        # megastep: per-window int32 partials stacked
                        # (cap, ·); summing the first x rows through
                        # int64 is bit-identical to landing x
                        # single-window dispatches
                        hist64s = np.asarray(fut[0], dtype=np.int64)
                        inter3s = np.asarray(fut[1], dtype=np.int64)
                        if poisoned:
                            hist64s, inter3s = poison_result(hist64s,
                                                             inter3s)
                        _validate_partials(hist64s[:x], inter3s[:x])
                        hsum = hist64s[:x].sum(axis=0)
                        isum = inter3s[:x, :2].sum(axis=0)
                        nums = [int(inter3s[i, 2]) for i in range(x)]
                    else:
                        h = np.asarray(fut[0], dtype=np.int64)
                        it2 = np.asarray(fut[1], dtype=np.int64)
                        if poisoned:
                            h, it2 = poison_result(h, it2)
                        _validate_partials(h, it2)
                        hsum, isum = h, it2
                        nums = [x]
                    break
                except Exception as exc:
                    # fetch/validation failure: re-dispatch the SAME
                    # window (same-device retry, then failover) — the
                    # merge is order-invariant, so the late landing is
                    # bit-identical
                    attempts += 1
                    st.retries += 1
                    if attempts > self.max_retries:
                        retire(home[s], exc)
                        attempts = 0
                    else:
                        time.sleep(self.retry_backoff
                                   * 2 ** (attempts - 1))
                    fut, poisoned, attempts = dispatch_retrying(
                        s, window, attempts)
            np.add(hist_acc, hsum, out=hist_acc)
            np.add(inter_acc, isum, out=inter_acc)
            if journal is not None:
                journal.record(s, ids, hsum, isum, nums)
            for num in nums:
                chunk_items.append(num)
                if progress is not None:
                    progress(landed[0], total_windows, num)
                landed[0] += 1

        def restart(slot: int, skip: int):
            return make_source(live[slot], skip)

        pipeline = ShardStreamPipeline(
            [make_source(s) for s in live], depth=self.pipeline_depth,
            batch=batcher, restart=restart,
            watchdog=self.watchdog_timeout,
            max_retries=self.max_retries, backoff=self.retry_backoff)
        pending: deque = deque()
        limit = 2 * ndev
        try:
            with pipeline:
                for slot, window in pipeline:
                    s = live[slot]
                    if emit == "device":
                        _buf, x = window
                        ids = order[s][pos[s]:pos[s] + x]
                        pos[s] += x
                        shard_steps[s] += x
                        win_max = max(win_max, x)
                        pad_windows += cap - x
                    else:
                        wid, _sp, _pv, x = window
                        ids = [wid]
                        shard_steps[s] += 1
                        win_max = max(win_max, 1)
                    fut, poisoned, attempts = dispatch_retrying(s, window)
                    dispatches += 1
                    pending.append(
                        (s, window, ids, fut, x, attempts, poisoned))
                    if len(pending) > limit:
                        land(pending.popleft())
                while pending:
                    land(pending.popleft())
        finally:
            if journal is not None:
                journal.close()

        st.step_compiles = _jit_cache_size(step) - cache0
        st.chunk_items = chunk_items
        st.chunks = len(chunk_items)
        st.items = int(sum(chunk_items))
        st.shard_steps = shard_steps
        st.stall_steps = pipeline.stalls
        st.retries += pipeline.producer_retries
        st.watchdog_fires = pipeline.watchdog_fires
        st.dispatches_total = dispatches
        st.windows_per_dispatch_max = win_max
        st.windows_per_dispatch_mean = (
            sum(shard_steps) / dispatches if dispatches else 0.0)
        st.plan_upload_bytes_total = upload * sum(shard_steps)
        st.plan_pad_bytes_total = upload * pad_windows
        mono_wp = -(-st.items // ndev) * ndev
        st.monolithic_plan_bytes = ITEM_BYTES * mono_wp
        return assemble_counts(space.n, base_asym, base_mut,
                               hist_acc, inter_acc)


def _pad_i32(a: np.ndarray, cap: int) -> np.ndarray:
    """Zero-pad an int32 array to a fixed capacity (device shape)."""
    out = np.zeros(cap, dtype=np.int32)
    out[:a.shape[0]] = a
    return out


class _TimedIter:
    """Wrap an iterator, accumulating the walltime spent *inside*
    ``next()`` — the host-side plan/window construction cost of a lazy
    emission stream, excluding the consumer's device-wait time (the
    ``host_emit_seconds`` stats bucket)."""

    def __init__(self, it):
        self._it = iter(it)
        self.seconds = 0.0

    def __iter__(self):
        return self

    def __next__(self):
        t0 = time.perf_counter()
        try:
            return next(self._it)
        finally:
            self.seconds += time.perf_counter() - t0


def _split_capacity_compiles(session, chunk_items: list, compiles: int
                             ) -> tuple[int, int]:
    """(capacity_recompiles, step_compiles) attribution shared by both
    session kinds: the first dispatches after the resident buffers regrew
    charge any fresh compile to the capacity growth, not the step."""
    if session._capacity_grew and chunk_items:
        session._capacity_grew = False
        return compiles, 0
    return 0, compiles


def _dispatch_retrying_session(session, thunk):
    """Session-side dispatch retry: call ``thunk`` (upload + step launch,
    with the session's fault-injection hooks inside) under the engine's
    retry budget with exponential backoff.  Sessions retry on the same
    device only — failover is an engine-run discipline — so a persistent
    fault surfaces to the caller once the budget is spent (the temporal
    monitor turns that into a degraded window instead of dying)."""
    engine = session.engine
    attempts = 0
    while True:
        try:
            return thunk()
        except FaultError:
            if attempts >= engine.max_retries:
                raise
            attempts += 1
            session.retries += 1
            time.sleep(engine.retry_backoff * 2 ** (attempts - 1))


def _land_retrying_session(session, fut, poisoned, redo):
    """Session-side landing: fetch + validate one dispatch result,
    re-dispatching the same window via ``redo`` on failure (fetch error
    or corrupted partials), up to the engine's retry budget.  Returns
    the validated ``(hist64, inter)`` int64 arrays — the caller
    accumulates them, so nothing is ever double-counted."""
    engine = session.engine
    attempts = 0
    while True:
        try:
            hist = np.asarray(fut[0], dtype=np.int64)
            inter = np.asarray(fut[1], dtype=np.int64)
            if poisoned:
                hist, inter = poison_result(hist, inter)
            _validate_partials(hist, inter)
            return hist, inter
        except Exception:
            if redo is None or attempts >= engine.max_retries:
                raise
            attempts += 1
            session.retries += 1
            time.sleep(engine.retry_backoff * 2 ** (attempts - 1))
            fut, poisoned = redo()


def _session_graph_crc(g: CompactDigraph) -> int:
    return int(zlib.crc32(np.ascontiguousarray(g.packed).tobytes()))


def _save_session_checkpoint(session, path: str) -> None:
    """Persist a session's running census + graph fingerprint so a new
    session over the same graph can continue warm updates without
    recomputing the baseline (both session kinds share this format)."""
    if session._census is None:
        raise RuntimeError(
            "no census to checkpoint: call census() first")
    with open(path, "w") as f:
        json.dump({
            "v": 1, "kind": "session", "n": int(session.n),
            "orient": session.orient,
            "prune_self": bool(session.prune_self),
            "packed_crc": _session_graph_crc(session._g),
            "census": [int(x) for x in session._census]}, f)
        f.write("\n")


def _load_session_checkpoint(session, path: str) -> np.ndarray:
    """Restore a running census saved by :func:`_save_session_checkpoint`
    into a session whose RESIDENT graph matches the checkpoint's
    fingerprint; :meth:`update` then continues exactly where the saved
    session left off (bit-identical — the census never depended on which
    process computed it)."""
    with open(path) as f:
        rec = json.load(f)
    want = {"v": 1, "kind": "session", "n": int(session.n),
            "orient": session.orient,
            "prune_self": bool(session.prune_self),
            "packed_crc": _session_graph_crc(session._g)}
    got = {k: rec.get(k) for k in want}
    if got != want:
        raise FaultError(
            f"session checkpoint {path!r} does not match the resident "
            f"graph/session ({got} != {want})")
    session._census = np.asarray(rec["census"], dtype=np.int64)
    return session._census.copy()


class EngineSession:
    """Resident-graph census session: upload once, recount by delta.

    The graph-shaped device arrays (CSR ``indptr``/``packed`` + pair
    arrays) are uploaded once per graph revision into fixed-capacity
    zero-padded buffers (grown geometrically, so revisions of similar size
    reuse the same compiled step), items are dispatched in fixed
    ``chunk_shape`` slices through the engine's compile-once chunk step,
    and the binary-search depth is pinned to ``ceil(log2 n)`` — an upper
    bound for every possible row — so no future window can force a
    recompilation.  The padding is inert by construction: items only
    reference real slots/pairs, and the search stays inside real row
    bounds.

    Two ways to move the session forward:

    * :meth:`set_graph` + :meth:`census` — full recompute of a new graph
      (the tumbling-window path; still benefits from the resident arrays
      and the compile-once step).
    * :meth:`update` — apply an edge delta via
      :func:`repro.core.digraph.apply_delta` and recount only the
      *affected pairs* (see :mod:`repro.core.incremental`):
      ``C_new = C_old + contrib(A, G_new) − contrib(A, G_old)``,
      bit-identical to a from-scratch census of the edited graph.

    ``max_items`` bounds the padded items per dispatch (device-memory
    knob, default: one chunk sized to the initial graph's pre-prune item
    space); full censuses emit per-slice so host plan memory is
    O(chunk_shape), and subset recounts are O(subset items).  After every
    operation :attr:`stats` (also mirrored to ``engine.stats``) records
    the dispatch schedule, including ``full_items`` — what a from-scratch
    recompute would have processed — and ``affected_pairs``.

    Under ``emit="device"`` (the default) nothing above changes
    semantically, but per dispatch the host uploads ONE packed
    descriptor buffer (O(pairs-in-window) words) instead of the packed
    items, and a delta update uploads only the touched pairs'
    descriptors.  The descriptor capacity and anchor geometry are fixed
    at session open — windows that would overflow shrink their item span
    instead — so device emission adds no recompile vector;
    graph-capacity growth remains the only one and is counted apart as
    ``stats.capacity_recompiles``.
    """

    def __init__(self, engine: CensusEngine, g: CompactDigraph, *,
                 orient: str = "none", prune_self: bool = True,
                 max_items: int | None = None, emit: str | None = None,
                 index: bool = True):
        if max_items is not None and max_items < 1:
            raise ValueError(f"max_items must be >= 1, got {max_items}")
        emit = engine.emit if emit is None else emit
        if emit not in EMIT_MODES:
            raise ValueError(
                f"unknown emit mode {emit!r}; one of {EMIT_MODES}")
        self.engine = engine
        self.orient = orient
        self.prune_self = prune_self
        self.emit = emit
        self.n = g.n
        self.max_items = max_items
        #: delta-incremental host planning: keep a persistent
        #: :class:`PairSpaceIndex` and edit it per update instead of
        #: rebuilding the O(P) pair space (False == rebuild oracle)
        self.use_index = bool(index)
        self._pair_index: PairSpaceIndex | None = None
        self._t_pair = self._t_merge = self._t_emit = 0.0
        #: pinned unrolled-search depth: any row has < n entries, so this
        #: upper bound keeps the jitted step valid for every graph revision
        self.search_iters = max(1, int(np.ceil(np.log2(max(g.n, 2)))))
        self._rep, self._item_sh = engine._shardings()
        self._step = _chunk_step(engine.mesh)
        self._cap_entries = 0
        self._cap_pairs = 0
        self._capacity_grew = False
        self.chunk_shape: int | None = None
        self.desc_shape: int | None = None
        self._census: np.ndarray | None = None
        self.last_delta: GraphDelta | None = None
        self.stats: EngineStats | None = None
        #: injected-fault runtime shared across this session's dispatches
        #: (occurrence counters persist across census()/update() calls)
        self._injector = (engine.faults.injector()
                          if engine.faults is not None else None)
        #: dispatches re-attempted after a fault, across the session's life
        self.retries = 0
        self._closed = False
        self._install(g)
        if self.emit == "device":
            self._init_device_emission()

    # ---------------------------------------------------------- lifecycle
    def close(self) -> None:
        """Release the resident device buffers.  Idempotent; the session
        is unusable afterwards."""
        self._dev = None
        if hasattr(self, "_idx"):
            self._idx = None
        self._closed = True

    def __enter__(self) -> "EngineSession":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.close()

    def _check_open(self) -> None:
        if self._closed:
            raise RuntimeError("session is closed")

    # ------------------------------------------------------- checkpointing
    def save_checkpoint(self, path: str) -> None:
        """Persist the running census + graph fingerprint (JSON) so a new
        session over the same graph resumes warm updates via
        :meth:`load_checkpoint` without recomputing the baseline."""
        _save_session_checkpoint(self, path)

    def load_checkpoint(self, path: str) -> np.ndarray:
        """Adopt a census saved by :meth:`save_checkpoint`; the resident
        graph must match the checkpoint's fingerprint.  Returns the
        restored census; subsequent :meth:`update` calls continue
        bit-identically from it."""
        return _load_session_checkpoint(self, path)

    # ------------------------------------------------------------ state
    @property
    def graph(self) -> CompactDigraph:
        return self._g

    @property
    def space(self):
        return self._space

    @property
    def counts(self) -> np.ndarray | None:
        """The session's running census C_k (None until :meth:`census`)."""
        return None if self._census is None else self._census.copy()

    @staticmethod
    def _grown(cap: int, need: int) -> int:
        cap = max(cap, 256)
        while cap < need:
            cap *= 2
        return cap

    def _init_device_emission(self) -> None:
        """Fix the session's descriptor geometry: the per-dispatch
        descriptor capacity (:func:`_desc_capacity`), the matching pinned
        lower-bound depth, and the resident flat-index array the windows
        expand against — none of which any graph revision or delta can
        ever force to recompile."""
        space = self._space
        cs = self.chunk_shape
        self.desc_shape = _desc_capacity(
            cs, max_pairs_per_window(space.offsets, cs))
        self.desc_iters = DESC_SEARCH_ITERS
        self.num_anchors = num_desc_anchors(cs)
        self._idx = self.engine._put(
            jnp.arange(cs, dtype=jnp.int32), self._item_sh)

    def _install(self, g: CompactDigraph, space=None) -> None:
        """Make ``g`` the resident graph: rebuild the pair space (or
        adopt the prebuilt ``space`` an index edit produced) and
        (re)upload the padded device arrays."""
        self._g = g
        if space is None:
            t0 = time.perf_counter()
            if self.use_index:
                self._pair_index = PairSpaceIndex(
                    g, orient=self.orient, prune_self=self.prune_self)
                space = self._pair_index.space
            else:
                space = pair_space(g, orient=self.orient,
                                   prune_self=self.prune_self)
            self._t_pair += time.perf_counter() - t0
        self._space = space
        self._full_items: int | None = None   # lazy per-install stat
        if self.chunk_shape is None:
            budget = (self.max_items if self.max_items is not None
                      else max(space.num_items_preprune, 1))
            self.chunk_shape = _guard_chunk_shape(
                -(-max(int(budget), 1)
                  // self.engine.ndev) * self.engine.ndev)
        prev_caps = (self._cap_entries, self._cap_pairs)
        self._cap_entries = self._grown(self._cap_entries,
                                        space.packed.shape[0])
        self._cap_pairs = self._grown(self._cap_pairs, space.num_pairs)
        if prev_caps != (0, 0) and \
                prev_caps != (self._cap_entries, self._cap_pairs):
            # the padded device shapes changed: the next dispatch's fresh
            # compile (if any) is a capacity recompile, not a step compile
            self._capacity_grew = True
        put = self.engine._put
        self._dev = (
            put(space.indptr.astype(np.int32), self._rep),
            put(_pad_i32(space.packed, self._cap_entries), self._rep),
            put(_pad_i32(space.pair_u.astype(np.int32),
                         self._cap_pairs), self._rep),
            put(_pad_i32(space.pair_v.astype(np.int32),
                         self._cap_pairs), self._rep),
            put(_pad_i32(space.pair_code, self._cap_pairs), self._rep),
        )

    def set_graph(self, g: CompactDigraph) -> None:
        """Replace the resident graph wholesale (no delta bookkeeping).
        Invalidates the running census until :meth:`census` recomputes."""
        if g.n != self.n:
            raise ValueError(f"session is pinned to n={self.n}, got {g.n}")
        self._install(g)
        self._census = None
        self.last_delta = None

    # ---------------------------------------------------------- running
    def _run_batches(self, batches
                     ) -> tuple[np.ndarray, np.ndarray, list[int]]:
        """Dispatch item batches (each with at most ``chunk_shape``
        items) in fixed-shape chunks against the resident device graph;
        accumulate int64 partials on the host, overlapping batch k+1's
        emission + upload with batch k's compute.  Fully-pruned batches
        are skipped without a dispatch."""
        hist_acc = np.zeros(64, np.int64)
        inter_acc = np.zeros(2, np.int64)
        chunk_items: list[int] = []
        pending = None

        def land(job):
            fut, poisoned, dispatch = job
            hist, inter = _land_retrying_session(
                self, fut, poisoned,
                lambda: _dispatch_retrying_session(self, dispatch))
            np.add(hist_acc, hist, out=hist_acc)
            np.add(inter_acc, inter, out=inter_acc)

        for item_pair, item_slot, item_side in batches:
            num = int(item_pair.shape[0])
            if num == 0:
                continue
            item_sp, item_pv = pad_and_pack(
                item_pair, item_slot, item_side, self.chunk_shape)

            def dispatch(item_sp=item_sp, item_pv=item_pv):
                inj = self._injector
                if inj is not None:
                    inj.fire("upload", shard=0, device=0)
                sp_dev = self.engine._put(item_sp, self._item_sh)
                pv_dev = self.engine._put(item_pv, self._item_sh)
                if inj is not None:
                    inj.fire("dispatch", shard=0, device=0)
                fut = self._step(*self._dev, sp_dev, pv_dev,
                                 self.engine.mesh, self.search_iters,
                                 self.engine.backend)
                poisoned = inj.take_poison() if inj is not None else False
                return fut, poisoned

            fut, poisoned = _dispatch_retrying_session(self, dispatch)
            if pending is not None:
                land(pending)
            pending = (fut, poisoned, dispatch)
            chunk_items.append(num)
        if pending is not None:
            land(pending)
        return hist_acc, inter_acc, chunk_items

    def _run_desc_batches(self, windows
                          ) -> tuple[np.ndarray, np.ndarray, list[int]]:
        """Device-emission twin of :meth:`_run_batches`: dispatch
        descriptor windows against the resident graph + flat-index
        arrays, overlapping window k+1's (tiny) descriptor build + upload
        with window k's compute.  Valid-item counts come back from the
        device (``inter`` lane 2), so the stats stay comparable with host
        emission without materializing a single item."""
        hist_acc = np.zeros(64, np.int64)
        inter_acc = np.zeros(2, np.int64)
        chunk_items: list[int] = []
        put = self.engine._put
        pending = None

        def land(job):
            fut, poisoned, dispatch = job
            hist, inter3 = _land_retrying_session(
                self, fut, poisoned,
                lambda: _dispatch_retrying_session(self, dispatch))
            np.add(hist_acc, hist, out=hist_acc)
            np.add(inter_acc, inter3[:2], out=inter_acc)
            chunk_items.append(int(inter3[2]))

        for win in windows:
            if win.num_preprune == 0:
                continue

            def dispatch(win=win):
                inj = self._injector
                if inj is not None:
                    inj.fire("upload", shard=0, device=0)
                words = put(win.device_words(), self._rep)
                if inj is not None:
                    inj.fire("dispatch", shard=0, device=0)
                fut = _desc_step(*self._dev, words, self._idx,
                                 self.engine.mesh, self.search_iters,
                                 self.desc_iters, self.engine.backend,
                                 self.orient, self.prune_self)
                poisoned = inj.take_poison() if inj is not None else False
                return fut, poisoned

            fut, poisoned = _dispatch_retrying_session(self, dispatch)
            if pending is not None:
                land(pending)
            pending = (fut, poisoned, dispatch)
        if pending is not None:
            land(pending)
        return hist_acc, inter_acc, chunk_items

    def _slices(self, item_pair, item_slot, item_side):
        """Yield materialized items in ``chunk_shape``-sized batches."""
        cs = self.chunk_shape
        for lo in range(0, int(item_pair.shape[0]), cs):
            yield (item_pair[lo:lo + cs], item_slot[lo:lo + cs],
                   item_side[lo:lo + cs])

    def _subset(self, pair_ids: np.ndarray
                ) -> tuple[np.ndarray, int, list[int]]:
        """Contribution of a pair subset of the RESIDENT graph.  Host
        memory is O(subset items) under host emission and O(subset pairs)
        under device emission — bounded by the affected neighborhoods in
        the incremental path, not by the graph's full W."""
        base_asym, base_mut = base_for_pairs(self._space, pair_ids)
        if self.emit == "device":
            ids = np.asarray(pair_ids, dtype=np.int64).ravel()
            wins = _TimedIter(
                subset_descriptor_windows(self._space, ids,
                                          self.chunk_shape,
                                          self.desc_shape,
                                          self.num_anchors))
            hist, inter, chunk_items = self._run_desc_batches(wins)
            self._t_emit += wins.seconds
            return (contribution_counts(base_asym, base_mut, hist, inter),
                    int(sum(chunk_items)), chunk_items)
        t0 = time.perf_counter()
        items = emit_items_for_pairs(self._space, pair_ids)
        self._t_emit += time.perf_counter() - t0
        num_items = int(items[0].shape[0])
        if num_items == 0:
            return (contribution_counts(base_asym, base_mut,
                                        np.zeros(64, np.int64),
                                        np.zeros(2, np.int64)), 0, [])
        hist, inter, chunk_items = self._run_batches(self._slices(*items))
        return (contribution_counts(base_asym, base_mut, hist, inter),
                num_items, chunk_items)

    def _postprune_items(self) -> int:
        """Full-recompute item count of the resident graph, computed at
        most once per graph revision.  The index's maintained per-pair
        cost vector answers it with an O(P) sum; the rebuild oracle pays
        the O(m + P log m) degree-orient closed-form scan instead."""
        if self._full_items is None:
            if self.use_index and self._pair_index is not None:
                self._full_items = int(self._pair_index.costs.sum())
            else:
                self._full_items = self._space.num_items_postprune()
        return self._full_items

    def _cache_size(self) -> int:
        """Compile counter of the jitted step this session dispatches
        through (the descriptor step under device emission)."""
        return _jit_cache_size(
            _desc_step if self.emit == "device" else self._step)

    def _set_stats(self, chunk_items: list[int], items: int,
                   full_items: int, affected_pairs: int,
                   compiles: int) -> None:
        ndev = self.engine.ndev
        capacity_recompiles, compiles = _split_capacity_compiles(
            self, chunk_items, compiles)
        gbytes = replicated_graph_bytes(self._space)
        self.stats = EngineStats(
            backend=self.engine.backend, ndev=ndev, orient=self.orient,
            streamed=True, max_items=self.max_items,
            chunks=len(chunk_items), chunk_shape=self.chunk_shape,
            items=items, chunk_items=chunk_items,
            peak_plan_bytes=ITEM_BYTES * self.chunk_shape,
            monolithic_plan_bytes=ITEM_BYTES
            * (-(-full_items // ndev) * ndev),
            step_compiles=compiles,
            full_items=full_items, affected_pairs=affected_pairs,
            emit=self.emit,
            desc_shape=self.desc_shape or 0,
            # physical per-device plan bytes: descriptor windows are
            # replicated, item arrays sharded over the mesh
            plan_upload_bytes=(
                DESC_BYTES * self.desc_shape + 4 * self.num_anchors + 4
                if self.emit == "device"
                else ITEM_BYTES * self.chunk_shape // ndev),
            capacity_recompiles=capacity_recompiles,
            retries=self.retries,
            graph_resident_bytes=gbytes, graph_replicated_bytes=gbytes,
            host_pair_seconds=self._t_pair,
            host_merge_seconds=self._t_merge,
            host_emit_seconds=self._t_emit, indexed=self.use_index)
        self._t_pair = self._t_merge = self._t_emit = 0.0
        self.engine.stats = self.stats

    def census(self) -> np.ndarray:
        """Full census of the resident graph; (re)bases the session's
        running C_k that :meth:`update` moves forward.  Under host
        emission items are emitted per pre-prune slice of ``chunk_shape``
        (host plan memory O(chunk_shape), never O(W)); under device
        emission only descriptor windows are built — O(pairs-per-window)
        host memory and upload."""
        self._check_open()
        space = self._space
        cache0 = self._cache_size()
        w0 = space.num_items_preprune
        cs = self.chunk_shape
        if self.emit == "device":
            wins = _TimedIter(
                iter_descriptor_windows(space.offsets, cs,
                                        self.desc_shape,
                                        self.num_anchors))
            hist, inter, chunk_items = self._run_desc_batches(wins)
            self._t_emit += wins.seconds
        else:
            batches = _TimedIter(emit_items(space, lo, min(lo + cs, w0))
                                 for lo in range(0, w0, cs))
            hist, inter, chunk_items = self._run_batches(batches)
            self._t_emit += batches.seconds
        base_asym, base_mut = global_bases(space)
        self._census = assemble_counts(self.n, base_asym, base_mut,
                                       hist, inter)
        num_items = int(sum(chunk_items))
        self._full_items = num_items      # the full census just counted it
        self._set_stats(chunk_items, num_items, num_items,
                        space.num_pairs,
                        self._cache_size() - cache0)
        return self._census.copy()

    def update(self, add_src=None, add_dst=None,
               del_src=None, del_dst=None) -> np.ndarray:
        """Apply an edge delta and return the edited graph's census,
        recounting only the affected pairs — bit-identical to a
        from-scratch census of the new graph on any backend."""
        self._check_open()
        if self._census is None:
            raise RuntimeError(
                "no baseline census: call census() before update()")
        cache0 = self._cache_size()
        t0 = time.perf_counter()
        g_new, delta = apply_delta(self._g, add_src, add_dst,
                                   del_src, del_dst)
        self._t_merge += time.perf_counter() - t0
        self.last_delta = delta
        if delta.num_changed == 0:
            # nothing changed: no recount, no descriptor/item upload, no
            # device dispatch — the running census is already the answer
            self._set_stats([], 0, self._postprune_items(), 0,
                            self._cache_size() - cache0)
            return self._census.copy()

        t0 = time.perf_counter()
        aff_old = (self._pair_index.affected_pair_ids(delta.touched)
                   if self.use_index
                   else affected_pair_ids(self._space, delta.touched))
        self._t_pair += time.perf_counter() - t0
        contrib_old, items_old, chunks_old = self._subset(aff_old)
        if self.use_index:
            # edit the persistent index into the new graph's pair space
            # (O(delta · log P + affected)) instead of rebuilding O(P)
            t0 = time.perf_counter()
            space_new = self._pair_index.apply(delta, g_new)
            self._t_pair += time.perf_counter() - t0
            self._install(g_new, space=space_new)
        else:
            self._install(g_new)
        t0 = time.perf_counter()
        aff_new = (self._pair_index.affected_pair_ids(delta.touched)
                   if self.use_index
                   else affected_pair_ids(self._space, delta.touched))
        self._t_pair += time.perf_counter() - t0
        contrib_new, items_new, chunks_new = self._subset(aff_new)
        self._census = combine(self._census, contrib_old, contrib_new,
                               self.n)
        self._set_stats(chunks_old + chunks_new, items_old + items_new,
                        self._postprune_items(),
                        int(aff_old.shape[0] + aff_new.shape[0]),
                        self._cache_size() - cache0)
        return self._census.copy()


class PartitionedEngineSession:
    """Partition-resident census session: each shard lives on its device,
    delta updates dispatch only the shards owning touched pairs.

    On open the graph's pair space is LPT-split into one private shard
    per mesh device (:mod:`repro.core.partition`); each shard's relabeled
    local CSR + pair arrays are uploaded once into fixed-capacity buffers
    committed to THAT device (capacities are common across shards and
    grown geometrically, so one compiled single-device step serves every
    shard and every graph revision — the binary-search depth is pinned to
    ``ceil(log2 n)`` exactly like :class:`EngineSession`).  Per-shard
    dispatches are independent and asynchronous, so devices overlap
    naturally; partials are merged on the host (the paper's 64 private
    census vectors, merged once).

    :meth:`update` applies an edge delta and routes the recount by
    ownership: the *affected pairs* (endpoint row changed) are looked up
    in each shard's sorted key set, only the owning shards re-count their
    slices (old contribution against the still-resident arrays, then new
    contribution after only those shards re-extract + re-upload), and
    **untouched shards dispatch nothing** — no descriptor/item upload, no
    device work, their resident subgraphs provably unchanged.  Pairs that
    appear in the delta are assigned to a shard already owning one of
    their endpoints' pairs (locality), else to the lightest shard.
    Bit-identical to a from-scratch census of the edited graph on every
    backend, orient and emit mode.

    Sustained churn drifts the locality-routed loads away from the LPT
    optimum (the spill cap bounds the drift at ~1.25x mean, but never
    restores balance).  :meth:`rebalance` re-sharding — a fresh LPT over
    the CURRENT pair space with every shard re-extracted + re-uploaded,
    like :meth:`set_graph` but keeping the running census valid (counts
    never depend on ownership) — restores ≈LPT balance;
    ``auto_rebalance_threshold`` triggers it automatically at the end of
    any :meth:`update` that leaves ``load_max_over_mean`` above the
    threshold (``rebalances`` counts the triggers).
    """

    def __init__(self, engine: CensusEngine, g: CompactDigraph, *,
                 orient: str = "none", prune_self: bool = True,
                 max_items: int | None = None, emit: str | None = None,
                 auto_rebalance_threshold: float | None = None,
                 index: bool = True):
        if max_items is not None and max_items < 1:
            raise ValueError(f"max_items must be >= 1, got {max_items}")
        if auto_rebalance_threshold is not None \
                and auto_rebalance_threshold < 1.0:
            raise ValueError(
                "auto_rebalance_threshold must be >= 1.0, got "
                f"{auto_rebalance_threshold}")
        emit = engine.emit if emit is None else emit
        if emit not in EMIT_MODES:
            raise ValueError(
                f"unknown emit mode {emit!r}; one of {EMIT_MODES}")
        self.auto_rebalance_threshold = (
            None if auto_rebalance_threshold is None
            else float(auto_rebalance_threshold))
        self.rebalances = 0
        self.engine = engine
        self.orient = orient
        self.prune_self = prune_self
        self.emit = emit
        self.n = g.n
        self.max_items = max_items
        self.ndev = engine.ndev
        self._devices = list(engine.mesh.devices.flat)
        #: pinned unrolled-search depth (see :class:`EngineSession`)
        self.search_iters = max(1, int(np.ceil(np.log2(max(g.n, 2)))))
        self._step = _chunk_step(engine.mesh)
        self._cap_n = self._cap_entries = self._cap_pairs = 0
        self._capacity_grew = False
        self.chunk_shape: int | None = None
        self.desc_shape: int | None = None
        self._census: np.ndarray | None = None
        self.last_delta: GraphDelta | None = None
        self.stats: EngineStats | None = None
        #: injected-fault runtime shared across this session's dispatches
        self._injector = (engine.faults.injector()
                          if engine.faults is not None else None)
        #: dispatches re-attempted after a fault, across the session's life
        self.retries = 0
        self._closed = False
        #: delta-incremental host planning (see :class:`EngineSession`)
        self.use_index = bool(index)
        self._pair_index: PairSpaceIndex | None = None
        self._t_pair = self._t_merge = self._t_emit = 0.0
        self._install_full(g)

    # ---------------------------------------------------------- lifecycle
    def close(self) -> None:
        """Release every shard's resident device buffers.  Idempotent;
        the session is unusable afterwards."""
        self._dev = [None] * self.ndev
        if hasattr(self, "_idx"):
            self._idx = None
        self._closed = True

    def __enter__(self) -> "PartitionedEngineSession":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.close()

    def _check_open(self) -> None:
        if self._closed:
            raise RuntimeError("session is closed")

    # ------------------------------------------------------- checkpointing
    def save_checkpoint(self, path: str) -> None:
        """Persist the running census + graph fingerprint (JSON); a new
        session over the same graph warm-resumes updates via
        :meth:`load_checkpoint` without recomputing the baseline.  The
        census never depends on the partition, so the restoring session
        may shard (1D/2D) however it likes."""
        _save_session_checkpoint(self, path)

    def load_checkpoint(self, path: str) -> np.ndarray:
        """Adopt a census saved by :meth:`save_checkpoint` (the resident
        graph must match its fingerprint); :meth:`update` continues
        bit-identically from it."""
        return _load_session_checkpoint(self, path)

    # ------------------------------------------------------------ state
    @property
    def graph(self) -> CompactDigraph:
        return self._g

    @property
    def space(self):
        """The GLOBAL pair space of the resident graph."""
        return self._space

    @property
    def shards(self):
        return list(self._shards)

    @property
    def counts(self) -> np.ndarray | None:
        return None if self._census is None else self._census.copy()

    def _install_full(self, g: CompactDigraph) -> None:
        """(Re)partition ``g`` from scratch and make every shard
        device-resident (session open and :meth:`set_graph`)."""
        self._g = g
        t0 = time.perf_counter()
        if self.use_index:
            self._pair_index = PairSpaceIndex(
                g, orient=self.orient, prune_self=self.prune_self)
            space = self._pair_index.space
        else:
            space = pair_space(g, orient=self.orient,
                               prune_self=self.prune_self)
        self._t_pair += time.perf_counter() - t0
        self._space = space
        self._full_items: int | None = None
        part = self._make_partition(space)
        self._shards = list(part.shards)
        self._keys = [sh.keys for sh in self._shards]
        self._set_ownership(part)
        if self.chunk_shape is None:
            budget = (self.max_items if self.max_items is not None
                      else max(space.num_items_preprune, 1))
            self.chunk_shape = _guard_chunk_shape(
                -(-max(int(budget), 1) // self.ndev))
        if self.emit == "device" and self.desc_shape is None:
            cs = self.chunk_shape
            self.desc_shape = _desc_capacity(
                cs, max(max_pairs_per_window(sh.space.offsets, cs)
                        for sh in self._shards))
            self.desc_iters = DESC_SEARCH_ITERS
            self.num_anchors = num_desc_anchors(cs)
            self._idx = [
                jax.device_put(np.arange(cs, dtype=np.int32), d)
                for d in self._devices]
        self._dev: list = [None] * self.ndev
        self._upload_shards(range(self.ndev))

    # ----------------------------------------------- ownership hooks
    # The 2D session (:class:`PartitionedEngineSession2D`) overrides
    # these four: there a device holds a TILE (pair shard × vertex
    # slice) while ownership/load bookkeeping stays per pair shard.
    def _make_partition(self, space):
        """Partition ``space`` into the device-resident shard list."""
        return partition_graph(num_shards=self.ndev, space=space)

    def _set_ownership(self, part) -> None:
        """Record ownership/load bookkeeping from a fresh partition."""
        self._load = [sh.items for sh in self._shards]

    def _tile_shard(self, s: int) -> int:
        """Device/tile index → owning pair shard (identity in 1D)."""
        return s

    def _ownership(self) -> list:
        """Per pair shard sorted global key arrays (the reassignment
        target of :meth:`update`); the per-device dispatch key sets in
        1D, the per-shard sets distinct from ``_keys`` in 2D."""
        return self._keys

    def _upload_shards(self, shard_ids) -> None:
        """(Re)upload the listed shards' padded local arrays onto their
        devices; a capacity growth changes every shard's padded shapes,
        so it forces a full re-upload (and is accounted as a capacity
        recompile, never a step compile)."""
        need_n = max(max(sh.graph.indptr.shape[0]
                         for sh in self._shards), 2)
        need_e = max(max(sh.graph.packed.shape[0]
                         for sh in self._shards), 1)
        need_p = max(max(sh.num_pairs for sh in self._shards), 1)
        prev = (self._cap_n, self._cap_entries, self._cap_pairs)
        self._cap_n = EngineSession._grown(self._cap_n, need_n)
        self._cap_entries = EngineSession._grown(self._cap_entries,
                                                 need_e)
        self._cap_pairs = EngineSession._grown(self._cap_pairs, need_p)
        caps = (self._cap_n, self._cap_entries, self._cap_pairs)
        if prev != caps:
            if prev != (0, 0, 0):
                self._capacity_grew = True
            shard_ids = range(self.ndev)
        for s in shard_ids:
            sh = self._shards[s]
            ip = np.zeros(self._cap_n, dtype=np.int32)
            l = sh.graph.indptr.shape[0]
            ip[:l] = sh.graph.indptr
            ip[l:] = sh.graph.indptr[-1]      # phantom empty rows
            dev = self._devices[s]
            self._dev[s] = tuple(
                jax.device_put(a, dev) for a in (
                    ip, _pad_i32(sh.graph.packed, self._cap_entries),
                    _pad_i32(sh.space.pair_u.astype(np.int32),
                             self._cap_pairs),
                    _pad_i32(sh.space.pair_v.astype(np.int32),
                             self._cap_pairs),
                    _pad_i32(sh.space.pair_code, self._cap_pairs)))

    def set_graph(self, g: CompactDigraph) -> None:
        """Replace the resident graph wholesale: fresh LPT partition,
        every shard re-extracted + re-uploaded.  Invalidates the running
        census until :meth:`census` recomputes."""
        if g.n != self.n:
            raise ValueError(f"session is pinned to n={self.n}, got {g.n}")
        self._install_full(g)
        self._census = None
        self.last_delta = None

    @property
    def load_max_over_mean(self) -> float:
        """Current shard load imbalance (post-prune items; 1.0 ==
        perfectly balanced) — the quantity ``auto_rebalance_threshold``
        is compared against after every update."""
        total = sum(self._load)
        if not total:
            return 1.0
        return max(self._load) / (total / len(self._load))

    def rebalance(self) -> None:
        """Re-shard the CURRENT resident graph with a fresh LPT (the
        :meth:`set_graph` ownership reset without the graph change):
        every shard re-extracts + re-uploads, restoring ≈LPT balance
        after churn has drifted the locality-routed loads.  The running
        census — and the pair space — are untouched: the census never
        depends on which shard owns a pair, so no recount is needed and
        :meth:`update` continues bit-identically from here."""
        part = self._make_partition(self._space)
        self._shards = list(part.shards)
        self._keys = [sh.keys for sh in self._shards]
        self._set_ownership(part)
        self._upload_shards(range(self.ndev))
        self.rebalances += 1

    def _maybe_rebalance(self) -> None:
        if self.auto_rebalance_threshold is not None and \
                self.load_max_over_mean > self.auto_rebalance_threshold:
            self.rebalance()

    # ---------------------------------------------------------- running
    def _dispatch_desc(self, s: int, win):
        """One descriptor window against shard ``s``'s resident arrays,
        on shard ``s``'s device (single-device step, async).  Fires the
        session's fault-injection hooks around the upload and the step
        launch; returns ``(fut, poisoned)``."""
        inj = self._injector
        if inj is not None:
            inj.fire("upload", shard=s, device=s)
        words = jax.device_put(win.device_words(), self._devices[s])
        if inj is not None:
            inj.fire("dispatch", shard=s, device=s)
        fut = _desc_step(*self._dev[s], words, self._idx[s], None,
                         self.search_iters, self.desc_iters,
                         self.engine.backend, self.orient,
                         self.prune_self)
        return fut, (inj.take_poison() if inj is not None else False)

    def _dispatch_items(self, s: int, item_pair, item_slot, item_side):
        """One packed-item window against shard ``s``'s resident arrays
        (host emission), on shard ``s``'s device; returns
        ``(fut, poisoned)`` like :meth:`_dispatch_desc`."""
        item_sp, item_pv = pad_and_pack(item_pair, item_slot, item_side,
                                        self.chunk_shape)
        dev = self._devices[s]
        inj = self._injector
        if inj is not None:
            inj.fire("upload", shard=s, device=s)
        sp_dev = jax.device_put(item_sp, dev)
        pv_dev = jax.device_put(item_pv, dev)
        if inj is not None:
            inj.fire("dispatch", shard=s, device=s)
        fut = self._step(*self._dev[s], sp_dev, pv_dev,
                         None, self.search_iters, self.engine.backend)
        return fut, (inj.take_poison() if inj is not None else False)

    def _shard_jobs(self, s: int, pair_ids=None):
        """Yield shard ``s``'s dispatch jobs: its full stream
        (``pair_ids=None``) or an arbitrary local pair subset.  Each job
        is ``(fut, poisoned, redo, num_or_None)`` — ``redo`` re-dispatches
        the same window (the landing-side retry handle), ``num`` is the
        item count under host emission and ``None`` under device emission
        (counts come back from the device).  Dispatch-time faults are
        retried here under the engine's budget."""
        sp = self._shards[s].space
        cs = self.chunk_shape
        if self.emit == "device":
            wins = _TimedIter(
                iter_descriptor_windows(sp.offsets, cs,
                                        self.desc_shape,
                                        self.num_anchors)
                if pair_ids is None else
                subset_descriptor_windows(sp, pair_ids, cs,
                                          self.desc_shape,
                                          self.num_anchors))
            for win in wins:
                if win.num_preprune == 0:
                    continue

                def redo(win=win, s=s):
                    return _dispatch_retrying_session(
                        self, lambda: self._dispatch_desc(s, win))

                fut, poisoned = redo()
                yield fut, poisoned, redo, None
            self._t_emit += wins.seconds
            return
        if pair_ids is None:
            w0 = sp.num_items_preprune
            batches = _TimedIter(emit_items(sp, lo, min(lo + cs, w0))
                                 for lo in range(0, w0, cs))
        else:
            t0 = time.perf_counter()
            items = emit_items_for_pairs(sp, pair_ids)
            self._t_emit += time.perf_counter() - t0
            batches = _TimedIter(
                (items[0][lo:lo + cs], items[1][lo:lo + cs],
                 items[2][lo:lo + cs])
                for lo in range(0, max(int(items[0].shape[0]), 1), cs))
        for batch in batches:
            num = int(batch[0].shape[0])
            if num == 0:
                continue

            def redo(batch=batch, s=s):
                return _dispatch_retrying_session(
                    self, lambda: self._dispatch_items(s, *batch))

            fut, poisoned = redo()
            yield fut, poisoned, redo, num
        self._t_emit += batches.seconds

    def _job_stream(self, s: int, pair_ids=None):
        """Shard ``s``'s jobs tagged with their shard id (a bound helper,
        so per-shard generators never share a loop variable)."""
        for fut, poisoned, redo, num in self._shard_jobs(s, pair_ids):
            yield s, fut, poisoned, redo, num

    def _land(self, futs, hist_acc, inter_acc, chunk_items, shard_items):
        """Accumulate ``(shard, fut, poisoned, redo, num_or_None)``
        results, re-dispatching through ``redo`` on fetch failures or
        corrupted partials (the landing half of the session retry)."""
        for s, fut, poisoned, redo, num in futs:
            hist, inter = _land_retrying_session(self, fut, poisoned,
                                                 redo)
            if num is None:
                inter_acc += inter[:2]
                num = int(inter[2])
            else:
                inter_acc += inter
            hist_acc += hist
            chunk_items.append(num)
            shard_items[s] += num

    def _drain(self, streams, hist_acc, inter_acc, chunk_items,
               shard_items) -> None:
        """Pull per-shard job streams round-robin (every device gets fed
        each cycle) with a bounded in-flight window: at most
        ``2 * ndev`` dispatches — and their chunk-shaped buffers — are
        pending at once, so host and device memory stay
        O(ndev · chunk_shape), never O(W) (the memory contract
        ``max_items`` promises, matching :class:`EngineSession`'s
        depth-1 pipelining)."""
        limit = 2 * self.ndev
        pending: deque = deque()
        active = list(streams)
        while active:
            alive = []
            for it in active:
                job = next(it, None)
                if job is None:
                    continue
                alive.append(it)
                pending.append(job)
                if len(pending) > limit:
                    self._land([pending.popleft()], hist_acc, inter_acc,
                               chunk_items, shard_items)
            active = alive
        self._land(pending, hist_acc, inter_acc, chunk_items,
                   shard_items)

    def _cache_size(self) -> int:
        return _jit_cache_size(
            _desc_step if self.emit == "device" else self._step)

    def _postprune_items(self) -> int:
        if self._full_items is None:
            if self.use_index and self._pair_index is not None:
                self._full_items = int(self._pair_index.costs.sum())
            else:
                self._full_items = self._space.num_items_postprune()
        return self._full_items

    def _set_stats(self, chunk_items, shard_items, items, full_items,
                   affected_pairs, compiles) -> None:
        capacity_recompiles, compiles = _split_capacity_compiles(
            self, chunk_items, compiles)
        self.stats = EngineStats(
            backend=self.engine.backend, ndev=self.ndev,
            orient=self.orient, streamed=True, max_items=self.max_items,
            chunks=len(chunk_items), chunk_shape=self.chunk_shape,
            items=items, chunk_items=chunk_items,
            peak_plan_bytes=ITEM_BYTES * self.chunk_shape,
            monolithic_plan_bytes=ITEM_BYTES
            * (-(-full_items // self.ndev) * self.ndev),
            step_compiles=compiles,
            full_items=full_items, affected_pairs=affected_pairs,
            emit=self.emit, desc_shape=self.desc_shape or 0,
            plan_upload_bytes=(
                DESC_BYTES * self.desc_shape + 4 * self.num_anchors + 4
                if self.emit == "device"
                else ITEM_BYTES * self.chunk_shape),
            capacity_recompiles=capacity_recompiles,
            retries=self.retries,
            partitioned=True,
            partition_shape=getattr(self, "mesh_shape", None),
            shard_items=shard_items,
            graph_resident_bytes=max(sh.resident_bytes
                                     for sh in self._shards),
            graph_replicated_bytes=replicated_graph_bytes(self._space),
            host_pair_seconds=self._t_pair,
            host_merge_seconds=self._t_merge,
            host_emit_seconds=self._t_emit, indexed=self.use_index)
        self._t_pair = self._t_merge = self._t_emit = 0.0
        self.engine.stats = self.stats

    def census(self) -> np.ndarray:
        """Full census of the resident graph: every shard walks its own
        stream on its own device, partials merge on the host.  (Re)bases
        the running C_k that :meth:`update` moves forward."""
        self._check_open()
        cache0 = self._cache_size()
        hist_acc = np.zeros(64, np.int64)
        inter_acc = np.zeros(2, np.int64)
        chunk_items: list[int] = []
        shard_items = [0] * self.ndev
        self._drain([self._job_stream(s) for s in range(self.ndev)],
                    hist_acc, inter_acc, chunk_items, shard_items)
        base_asym, base_mut = global_bases(self._space)
        self._census = assemble_counts(self.n, base_asym, base_mut,
                                       hist_acc, inter_acc)
        items = int(sum(chunk_items))
        self._full_items = items
        self._set_stats(chunk_items, shard_items, items, items,
                        self._space.num_pairs,
                        self._cache_size() - cache0)
        return self._census.copy()

    def _recount(self, aff_keys, chunk_items, shard_items,
                 touched_owner=None, touched=None):
        """Contribution of the affected pairs, recounted shard by shard
        on the CURRENT resident arrays; shards owning none of them are
        never dispatched.  Returns (contribution, dirty shard ids)."""
        base_asym = base_mut = 0
        streams = []
        dirty = []
        for s in range(self.ndev):
            loc = np.nonzero(np.isin(self._keys[s], aff_keys,
                                     assume_unique=True))[0]
            if loc.size == 0:
                continue
            dirty.append(s)
            sh = self._shards[s]
            if touched_owner is not None:
                # remember which shard owns each touched vertex's pairs —
                # appeared pairs are assigned for locality from this map
                gids = sh.pair_ids[loc]
                for u in np.intersect1d(
                        np.concatenate([self._space.pair_u[gids],
                                        self._space.pair_v[gids]]),
                        touched).tolist():
                    touched_owner.setdefault(int(u),
                                             self._tile_shard(s))
            ba, bm = base_for_pairs(sh.space, loc)
            base_asym += ba
            base_mut += bm
            streams.append(self._job_stream(s, loc))
        hist = np.zeros(64, np.int64)
        inter = np.zeros(2, np.int64)
        self._drain(streams, hist, inter, chunk_items, shard_items)
        return contribution_counts(base_asym, base_mut, hist, inter), \
            dirty

    def _refresh_shards(self, dirty, space_new, key_all_new,
                        costs_new=None) -> None:
        """Re-extract + re-upload the dirty pair shards against the new
        space; untouched shards keep their device buffers verbatim.
        ``costs_new`` is the per-pair post-prune cost vector — the
        maintained one from the session's index when available, else one
        global scan shared by every dirty shard's refresh (extract_shard
        would otherwise recount it per shard)."""
        if costs_new is None:
            costs_new = postprune_pair_counts(space_new)
        for s in dirty:
            ids = np.searchsorted(key_all_new, self._keys[s])
            self._shards[s] = extract_shard(space_new, ids, index=s,
                                            costs=costs_new)
            self._load[s] = self._shards[s].items
        self._upload_shards(dirty)

    def update(self, add_src=None, add_dst=None,
               del_src=None, del_dst=None) -> np.ndarray:
        """Apply an edge delta and return the edited graph's census.

        Only the shards owning affected pairs recount (old contribution
        on their still-resident arrays, new contribution after refresh);
        every other shard keeps its device buffers untouched and
        dispatches nothing.  Bit-identical to a from-scratch census."""
        self._check_open()
        if self._census is None:
            raise RuntimeError(
                "no baseline census: call census() before update()")
        cache0 = self._cache_size()
        t0 = time.perf_counter()
        g_new, delta = apply_delta(self._g, add_src, add_dst,
                                   del_src, del_dst)
        self._t_merge += time.perf_counter() - t0
        self.last_delta = delta
        if delta.num_changed == 0:
            self._set_stats([], [0] * self.ndev, 0,
                            self._postprune_items(), 0,
                            self._cache_size() - cache0)
            return self._census.copy()

        n = self.n
        space_old = self._space
        t0 = time.perf_counter()
        if self.use_index:
            aff_old = self._pair_index.affected_pair_ids(delta.touched)
        else:
            aff_old = affected_pair_ids(space_old, delta.touched)
        aff_keys_old = (space_old.pair_u * n + space_old.pair_v)[aff_old]
        self._t_pair += time.perf_counter() - t0
        chunk_items: list[int] = []
        shard_items = [0] * self.ndev
        touched_owner: dict[int, int] = {}
        contrib_old, dirty_old = self._recount(
            aff_keys_old, chunk_items, shard_items,
            touched_owner=touched_owner, touched=delta.touched)

        # ---- reassign ownership and refresh only the dirty shards
        self._g = g_new
        t0 = time.perf_counter()
        if self.use_index:
            # edit the persistent index into the new pair space
            # (O(delta · log P + affected)) instead of rebuilding O(P);
            # its maintained keys/costs also feed the owner routing and
            # the dirty-shard refresh below
            space_new = self._pair_index.apply(delta, g_new)
            key_all_new = self._pair_index.keys
            costs_new = self._pair_index.costs
        else:
            space_new = pair_space(g_new, orient=self.orient,
                                   prune_self=self.prune_self)
            key_all_new = space_new.pair_u * n + space_new.pair_v
            costs_new = None
        self._t_pair += time.perf_counter() - t0
        self._space = space_new
        self._full_items = None
        dkeys = delta.pair_lo * n + delta.pair_hi
        vanished = dkeys[delta.new_code == 0]
        appeared = dkeys[delta.old_code == 0]
        okeys = self._ownership()
        # dirty is tracked per PAIR SHARD (== per device in 1D; a 2D
        # shard refreshes all of its vertex-slice tiles together so the
        # designated base-term slice stays consistent within the shard)
        dirty = {self._tile_shard(t) for t in dirty_old}
        if vanished.size:
            for s in sorted(dirty):  # vanished pairs were affected-old
                okeys[s] = np.setdiff1d(okeys[s], vanished,
                                        assume_unique=True)
        if appeared.size:
            pending: dict[int, list[int]] = {}
            # locality first — an appeared pair joins the shard already
            # owning its endpoints' pairs — but only while that shard is
            # within 1.25x of the mean load; past it, spill to the
            # lightest shard so sustained churn cannot concentrate the
            # whole pair space onto one device
            cap = 1.25 * (sum(self._load) / len(self._load)) + 1.0
            for k in appeared.tolist():
                u, v = divmod(k, n)
                s = touched_owner.get(u, touched_owner.get(v))
                if s is None or self._load[s] > cap:
                    s = int(np.argmin(self._load))
                touched_owner.setdefault(u, s)
                touched_owner.setdefault(v, s)
                idx = int(np.searchsorted(key_all_new, k))
                self._load[s] += int(space_new.counts[idx])
                pending.setdefault(s, []).append(k)
            for s, ks in pending.items():
                okeys[s] = np.union1d(okeys[s],
                                      np.asarray(ks, np.int64))
                dirty.add(s)
        self._refresh_shards(sorted(dirty), space_new, key_all_new,
                             costs_new)

        # ---- new-side recount (owners of every affected new pair are,
        # by construction, in the refreshed dirty set)
        t0 = time.perf_counter()
        if self.use_index:
            aff_new = self._pair_index.affected_pair_ids(delta.touched)
        else:
            aff_new = affected_pair_ids(space_new, delta.touched)
        aff_keys_new = key_all_new[aff_new]
        self._t_pair += time.perf_counter() - t0
        contrib_new, _ = self._recount(
            aff_keys_new, chunk_items, shard_items)
        self._census = combine(self._census, contrib_old, contrib_new,
                               self.n)
        self._set_stats(chunk_items, shard_items,
                        int(sum(chunk_items)),
                        self._postprune_items(),
                        int(aff_old.shape[0] + aff_new.shape[0]),
                        self._cache_size() - cache0)
        self._maybe_rebalance()
        return self._census.copy()


class PartitionedEngineSession2D(PartitionedEngineSession):
    """2D-partition-resident session: device = tile (pair shard × vertex
    slice), ownership = pair shard.

    Every device-facing mechanism of :class:`PartitionedEngineSession`
    — fixed-capacity grow-once buffers, async per-tile dispatch, the
    bounded-in-flight drain, host int64 merge — runs verbatim over the
    flat tile list (tile ``(s, j)`` at device ``s * V + j``).  What the
    second axis changes is *bookkeeping*: a pair belongs to one pair
    shard (``_ownership`` tracks per-shard key sets), its items split
    across that shard's ``V`` tiles by witness vertex range, and its
    closed-form base term is credited to one designated tile
    (:func:`repro.core.partition.slice_pair_terms`) so per-tile bases
    stay subset-additive.

    :meth:`update` routes deltas to ``(owner shard, touched slices)``:
    affected pairs recount only on the tiles whose vertex slice actually
    holds some of their items (a tile without them never appears in the
    key lookup, so it dispatches nothing), and a dirty shard re-extracts
    all of its slice tiles together against the session's pinned vertex
    bounds, keeping each pair's designated-slice term consistent within
    the shard.  Bit-identical to the 1D and unpartitioned sessions on
    every backend, orient and emit mode.
    """

    def __init__(self, engine: CensusEngine, g: CompactDigraph, *,
                 mesh_shape: tuple, **kwargs):
        mesh_shape = (int(mesh_shape[0]), int(mesh_shape[1]))
        if mesh_shape[0] * mesh_shape[1] != engine.ndev:
            raise ValueError(
                f"mesh_shape {mesh_shape} needs "
                f"{mesh_shape[0] * mesh_shape[1]} devices; the engine "
                f"has {engine.ndev}")
        self.mesh_shape = mesh_shape
        super().__init__(engine, g, **kwargs)

    def _make_partition(self, space):
        return partition_graph_2d(space=space,
                                  mesh_shape=self.mesh_shape)

    def _set_ownership(self, part) -> None:
        num_shards, num_slices = self.mesh_shape
        self._vertex_bounds = np.asarray(part.vertex_bounds,
                                         dtype=np.int64)
        space = part.space
        key_all = (space.pair_u.astype(np.int64) * space.n
                   + space.pair_v)
        self._shard_keys = [np.sort(key_all[part.owner == s])
                            for s in range(num_shards)]
        self._load = [sum(self._shards[s * num_slices + j].items
                          for j in range(num_slices))
                      for s in range(num_shards)]

    def _tile_shard(self, s: int) -> int:
        return s // self.mesh_shape[1]

    def _ownership(self) -> list:
        return self._shard_keys

    def _refresh_shards(self, dirty, space_new, key_all_new,
                        costs_new=None) -> None:
        """Re-extract every vertex-slice tile of each dirty pair shard
        against the session's pinned slice bounds (one shard's tiles are
        a unit: the designated base-term slice of any of its pairs must
        agree across them), then re-upload just those tiles.
        ``costs_new`` (the 1D session's maintained global cost vector) is
        ignored: tile costs are range-restricted per vertex slice, so
        they are recomputed here — the index still supplies the space
        itself, which is where the rebuild time went."""
        num_slices = self.mesh_shape[1]
        bounds = self._vertex_bounds
        terms = slice_pair_terms(space_new, bounds)
        slice_costs = [range_postprune_pair_counts(
            space_new, int(bounds[j]), int(bounds[j + 1]))
            for j in range(num_slices)]
        tiles = []
        for s in dirty:
            ids = np.searchsorted(key_all_new, self._shard_keys[s])
            load = 0
            for j in range(num_slices):
                t = s * num_slices + j
                sh = extract_shard(
                    space_new, ids, index=t, costs=slice_costs[j],
                    vertex_range=(int(bounds[j]), int(bounds[j + 1])),
                    pair_term=terms[j])
                self._shards[t] = sh
                self._keys[t] = sh.keys
                load += sh.items
                tiles.append(t)
            self._load[s] = load
        self._upload_shards(tiles)
