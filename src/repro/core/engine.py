"""Streaming census engine: unified multi-chunk execution, all backends.

:class:`CensusEngine` is the single owner of device dispatch for the triad
census.  It subsumes what used to be two parallel drivers (the
single-device path in :mod:`repro.core.census` and the sharded path in
:mod:`repro.core.distributed` — both are now thin wrappers over it) and
adds the out-of-core mode that the monolithic drivers could not express:

* **Monolithic** (``max_items=None``): one plan, one dispatch — exactly
  the historical behavior, for plans that fit.
* **Streamed** (``max_items=N``): the plan is never materialized whole.
  :class:`repro.core.plan_stream.PlanChunker` slices the pre-prune item
  space into bounded chunks; the engine uploads the chunk-invariant graph
  and pair arrays once, runs one jitted fixed-shape partials step per
  chunk (every chunk is padded to the same ``chunk_shape``, so the step
  compiles exactly once; item buffers are donated for HBM reuse), overlaps
  the host-side generation + upload of chunk k+1 with the device compute
  of chunk k, and accumulates the ``hist64``/``inter`` partials in int64
  on the host.  Peak plan memory is O(max_items) instead of O(W).

Partials are perfectly mergeable across chunks (integer histogram sums and
additive closed-form bases), so the streamed census is bit-identical to
the monolithic dispatch for every backend (``jnp``, ``pallas``,
``pallas-fused``), both orient modes, and any chunk size — enforced by
``tests/test_streaming.py``.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import shard_map
from repro.core.census import (
    BACKENDS, assemble_census, assemble_counts, partials_fn)
from repro.core.digraph import CompactDigraph
from repro.core.planner import CensusPlan, build_plan
from repro.core.plan_stream import PlanChunker


def _chunk_step_impl(indptr, packed, pair_u, pair_v, pair_code,
                     item_sp, item_pv, mesh, search_iters, backend):
    """One fixed-shape partials dispatch: ``(hist64, inter)`` int32.

    ``mesh=None`` runs single-device; otherwise the items are shard_mapped
    over every mesh axis with replicated graph/pair arrays and a final
    psum — the paper's privatized census vectors, one collective at the
    end.
    """
    partials = partials_fn(backend, search_iters)
    if mesh is None:
        return partials(indptr, packed, pair_u, pair_v, pair_code,
                        item_sp, item_pv)

    axes = mesh.axis_names

    def shard_fn(ip, pk, pu, pv, pc, wsp, wpv):
        hist64, inter = partials(ip, pk, pu, pv, pc, wsp, wpv)
        return jax.lax.psum(hist64, axes), jax.lax.psum(inter, axes)

    item_spec = P(axes)       # work items sharded over every mesh axis
    rep = P()                 # graph + pair arrays replicated
    fn = shard_map(
        shard_fn, mesh=mesh,
        in_specs=(rep, rep, rep, rep, rep, item_spec, item_spec),
        out_specs=(rep, rep),
        # pallas_call has no replication rule; keep the check on the
        # pure-XLA path where it still can catch a missing psum
        check_vma=(backend == "jnp"))
    return fn(indptr, packed, pair_u, pair_v, pair_code, item_sp, item_pv)


_STATIC = ("mesh", "search_iters", "backend")
#: donated variant: each chunk's packed item buffers hand their HBM to the
#: next upload (accelerators only — XLA:CPU cannot alias donated inputs,
#: so the plain variant avoids a per-chunk "unusable donation" warning)
_chunk_step_donated = functools.partial(
    jax.jit, static_argnames=_STATIC,
    donate_argnames=("item_sp", "item_pv"))(_chunk_step_impl)
_chunk_step_plain = functools.partial(
    jax.jit, static_argnames=_STATIC)(_chunk_step_impl)


def _chunk_step(mesh=None):
    """The per-chunk jitted step for the platform the work runs on —
    the mesh's device platform when sharded, the default backend when
    single-device."""
    platform = (mesh.devices.flat[0].platform if mesh is not None
                else jax.default_backend())
    return _chunk_step_plain if platform == "cpu" else _chunk_step_donated


def _jit_cache_size(step) -> int:
    """Compile counter via jax's private ``_cache_size`` — if a jax
    upgrade drops it, only the ``step_compiles`` stat degrades (to 0),
    never the census itself."""
    return getattr(step, "_cache_size", lambda: 0)()


#: bytes per packed work item (two int32 words)
ITEM_BYTES = 8


@dataclass
class EngineStats:
    """Execution stats of the last :class:`CensusEngine` run.

    ``peak_plan_bytes`` is the packed-item bytes resident per dispatch
    (the streaming memory ceiling the ``max_items`` knob tunes);
    ``monolithic_plan_bytes`` is what a single dispatch of the same work
    would have shipped.  ``step_compiles`` counts fresh compilations of
    the per-chunk step during the run — 0 or 1 for a streamed run, never
    one per chunk (fixed chunk shapes).
    """

    backend: str
    ndev: int
    orient: str
    streamed: bool
    max_items: int | None
    chunks: int
    chunk_shape: int           #: padded items per dispatch
    items: int                 #: total valid work items processed
    chunk_items: list[int] = field(default_factory=list)
    peak_plan_bytes: int = 0
    monolithic_plan_bytes: int = 0
    step_compiles: int = 0

    @property
    def chunk_max_over_mean(self) -> float:
        """Streamed-schedule imbalance (1.0 == perfectly even chunks)."""
        if not self.chunk_items or not sum(self.chunk_items):
            return 1.0
        mean = sum(self.chunk_items) / len(self.chunk_items)
        return max(self.chunk_items) / mean

    def summary(self) -> str:
        mode = (f"streamed max_items={self.max_items}" if self.streamed
                else "monolithic")
        return (f"{self.backend} [{mode}] chunks={self.chunks} "
                f"items={self.items} "
                f"peak_plan_bytes={self.peak_plan_bytes} "
                f"(monolithic {self.monolithic_plan_bytes}) "
                f"chunk_max_over_mean={self.chunk_max_over_mean:.3f} "
                f"step_compiles={self.step_compiles}")


class CensusEngine:
    """Owns mesh + backend dispatch for monolithic and streamed censuses.

    ``mesh=None`` executes on the default device; a :class:`Mesh` shards
    every chunk's items across all mesh axes.  After each ``run`` /
    ``run_plan`` the execution record is available as :attr:`stats`.
    """

    def __init__(self, mesh: Mesh | None = None, backend: str = "jnp"):
        if backend not in BACKENDS:
            raise ValueError(
                f"unknown backend {backend!r}; one of {BACKENDS}")
        self.mesh = mesh
        self.backend = backend
        self.stats: EngineStats | None = None

    @property
    def ndev(self) -> int:
        return 1 if self.mesh is None else int(
            np.prod(self.mesh.devices.shape))

    # ------------------------------------------------------------- helpers
    def _shardings(self):
        """(replicated, item-sharded) NamedShardings, or (None, None)."""
        if self.mesh is None:
            return None, None
        return (NamedSharding(self.mesh, P()),
                NamedSharding(self.mesh, P(self.mesh.axis_names)))

    def _put(self, a, sharding):
        arr = jnp.asarray(a)
        return arr if sharding is None else jax.device_put(arr, sharding)

    def _mono_stats(self, plan: CensusPlan,
                    max_items: int | None = None) -> EngineStats:
        wp = int(plan.item_sp.shape[0])
        return EngineStats(
            backend=self.backend, ndev=self.ndev, orient=plan.orient,
            streamed=False, max_items=max_items,
            chunks=1 if plan.num_items else 0, chunk_shape=wp,
            items=plan.num_items,
            chunk_items=[plan.num_items] if plan.num_items else [],
            peak_plan_bytes=ITEM_BYTES * wp,
            monolithic_plan_bytes=ITEM_BYTES * wp)

    # ------------------------------------------------------------- running
    def run_plan(self, plan: CensusPlan) -> np.ndarray:
        """Exact 16-type census from a prebuilt (monolithic) plan."""
        wp = int(plan.item_sp.shape[0])
        if self.mesh is not None and wp % self.ndev != 0:
            raise ValueError(
                f"plan padded to {wp} items, not a multiple of "
                f"{self.ndev} devices; build with pad_to=num_devices")
        self.stats = self._mono_stats(plan)
        if plan.num_pairs == 0 or plan.num_items == 0:
            # zero-work plans (incl. pairs whose items were all pruned)
            # resolve entirely from the host closed forms — the device is
            # never dispatched on zero-length item arrays
            return assemble_census(plan, np.zeros(64, np.int64),
                                   np.zeros(2, np.int64))
        rep, item_sh = self._shardings()
        step = _chunk_step(self.mesh)
        cache0 = _jit_cache_size(step)
        hist64, inter = step(
            self._put(plan.indptr, rep), self._put(plan.packed, rep),
            self._put(plan.pair_u, rep), self._put(plan.pair_v, rep),
            self._put(plan.pair_code, rep),
            self._put(plan.item_sp, item_sh),
            self._put(plan.item_pv, item_sh),
            self.mesh, plan.search_iters, self.backend)
        census = assemble_census(plan, np.asarray(hist64),
                                 np.asarray(inter))
        self.stats.step_compiles = _jit_cache_size(step) - cache0
        return census

    def run(self, g: CompactDigraph, *, max_items: int | None = None,
            orient: str = "none", prune_self: bool = True,
            progress=None) -> np.ndarray:
        """Plan + count ``g`` end to end.

        ``max_items=None`` builds one monolithic plan (O(W) host memory);
        an integer budget streams bounded chunks instead (O(max_items)).
        ``progress(chunk_index, num_chunks, chunk_valid_items)`` is called
        as each chunk is dispatched.
        """
        if max_items is None:
            plan = build_plan(g, pad_to=self.ndev, orient=orient,
                              prune_self=prune_self)
            return self.run_plan(plan)
        chunker = PlanChunker(g, max_items, orient=orient,
                              pad_to=self.ndev, prune_self=prune_self)
        return self._run_stream(chunker, progress)

    def _run_stream(self, chunker: PlanChunker, progress) -> np.ndarray:
        space = chunker.space
        self.stats = EngineStats(
            backend=self.backend, ndev=self.ndev, orient=space.orient,
            streamed=True, max_items=chunker.max_items,
            chunks=chunker.num_chunks, chunk_shape=chunker.chunk_shape,
            items=0, peak_plan_bytes=ITEM_BYTES * chunker.chunk_shape)
        if chunker.num_chunks == 0:
            return assemble_counts(space.n, 0, 0, np.zeros(64, np.int64),
                                   np.zeros(2, np.int64))

        rep, item_sh = self._shardings()
        # chunk-invariant graph + pair arrays: uploaded once, reused by
        # every chunk step (replicated across the mesh when sharded)
        graph_dev = tuple(self._put(a, rep)
                          for a in chunker.device_arrays())

        hist_acc = np.zeros(64, np.int64)
        inter_acc = np.zeros(2, np.int64)
        base_asym = base_mut = 0
        chunk_items: list[int] = []
        step = _chunk_step(self.mesh)
        cache0 = _jit_cache_size(step)
        pending = None
        for chunk in chunker:
            base_asym += chunk.base_asym
            base_mut += chunk.base_mut
            chunk_items.append(chunk.num_items)
            if progress is not None:
                progress(chunk.index, chunker.num_chunks, chunk.num_items)
            if chunk.num_items == 0:
                # fully-pruned chunk: its bases are credited above, the
                # all-invalid items contribute nothing — skip the dispatch
                # (mirrors the monolithic zero-work short-circuit)
                continue
            # upload + dispatch chunk k while chunk k-1 still computes
            # (dispatch is async; we only block when accumulating k-1)
            sp_dev = self._put(chunk.item_sp, item_sh)
            pv_dev = self._put(chunk.item_pv, item_sh)
            fut = step(*graph_dev, sp_dev, pv_dev,
                       self.mesh, space.search_iters, self.backend)
            if pending is not None:
                hist_acc += np.asarray(pending[0], dtype=np.int64)
                inter_acc += np.asarray(pending[1], dtype=np.int64)
            pending = fut
        if pending is not None:
            hist_acc += np.asarray(pending[0], dtype=np.int64)
            inter_acc += np.asarray(pending[1], dtype=np.int64)

        st = self.stats
        st.step_compiles = _jit_cache_size(step) - cache0
        st.chunk_items = chunk_items
        st.items = int(sum(chunk_items))
        mono_wp = -(-st.items // self.ndev) * self.ndev
        st.monolithic_plan_bytes = ITEM_BYTES * mono_wp
        return assemble_counts(space.n, base_asym, base_mut,
                               hist_acc, inter_acc)
