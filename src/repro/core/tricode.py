"""Triad isomorphism coding (the paper's ``IsoTricode`` lookup table).

A triad over nodes (u, v, w) is described by three *dyad codes*, one per
unordered node pair.  For an ordered pair (a, b) the code is::

    c_ab = (a->b ? 1 : 0) | (b->a ? 2 : 0)        # 2 bits, paper Fig 7

The *tricode* packs the three dyad codes of (u,v), (u,w), (v,w)::

    tricode = c_uv * 16 + c_uw * 4 + c_vw         # in [0, 64)

``TRICODE_TO_CLASS`` maps each of the 64 tricodes onto one of the 16
isomorphism classes (Holland-Leinhardt M-A-N types).  The table is *derived*
at import time by canonicalising every 6-arc configuration under the 6 node
permutations — not hand-copied — and is validated against networkx and a
brute-force oracle in the tests.
"""

from __future__ import annotations

import itertools

import numpy as np

#: Standard Holland-Leinhardt triad type names, index 0..15.
TRIAD_NAMES = (
    "003", "012", "102", "021D", "021U", "021C", "111D", "111U",
    "030T", "030C", "201", "120D", "120U", "120C", "210", "300",
)

NUM_CLASSES = 16


def _adj_from_tricode(t: int) -> np.ndarray:
    """3x3 directed adjacency matrix for a tricode."""
    c_uv, c_uw, c_vw = (t >> 4) & 3, (t >> 2) & 3, t & 3
    a = np.zeros((3, 3), dtype=bool)
    a[0, 1], a[1, 0] = bool(c_uv & 1), bool(c_uv & 2)
    a[0, 2], a[2, 0] = bool(c_uw & 1), bool(c_uw & 2)
    a[1, 2], a[2, 1] = bool(c_vw & 1), bool(c_vw & 2)
    return a


def _tricode_from_adj(a: np.ndarray) -> int:
    c_uv = int(a[0, 1]) | (int(a[1, 0]) << 1)
    c_uw = int(a[0, 2]) | (int(a[2, 0]) << 1)
    c_vw = int(a[1, 2]) | (int(a[2, 1]) << 1)
    return c_uv * 16 + c_uw * 4 + c_vw


def _classify(a: np.ndarray) -> str:
    """Name the M-A-N class of a 3-node digraph (canonical rules)."""
    codes = [
        int(a[0, 1]) | (int(a[1, 0]) << 1),
        int(a[0, 2]) | (int(a[2, 0]) << 1),
        int(a[1, 2]) | (int(a[2, 1]) << 1),
    ]
    m = sum(c == 3 for c in codes)
    asym = sum(c in (1, 2) for c in codes)
    n = sum(c == 0 for c in codes)
    arcs = [(i, j) for i in range(3) for j in range(3) if i != j and a[i, j]]
    if (m, asym, n) == (0, 0, 3):
        return "003"
    if (m, asym, n) == (0, 1, 2):
        return "012"
    if (m, asym, n) == (1, 0, 2):
        return "102"
    if (m, asym, n) == (0, 2, 1):
        (s0, t0), (s1, t1) = arcs
        if s0 == s1:
            return "021D"          # both arcs diverge from one sender
        if t0 == t1:
            return "021U"          # both arcs converge on one receiver
        return "021C"              # directed path
    if (m, asym, n) == (1, 1, 1):
        # the asymmetric arc either points INTO the mutual dyad or out of it
        mutual_pair = {i for i in range(3) for j in range(3)
                       if i != j and a[i, j] and a[j, i]}
        (s, t) = [e for e in arcs
                  if not (e[0] in mutual_pair and e[1] in mutual_pair)][0]
        # Holland-Leinhardt: 111D has the arc directed toward the dyad,
        # 111U has the arc directed away from it (validated vs networkx).
        return "111D" if t in mutual_pair else "111U"
    if (m, asym, n) == (0, 3, 0):
        outdeg = a.sum(axis=1)
        return "030C" if (outdeg == 1).all() else "030T"
    if (m, asym, n) == (2, 0, 1):
        return "201"
    if (m, asym, n) == (1, 2, 0):
        mutual_pair = {i for i in range(3) for j in range(3)
                       if i != j and a[i, j] and a[j, i]}
        asym_arcs = [e for e in arcs
                     if not (e[0] in mutual_pair and e[1] in mutual_pair)]
        (s0, t0), (s1, t1) = asym_arcs
        if s0 == s1:
            return "120D"
        if t0 == t1:
            return "120U"
        return "120C"
    if (m, asym, n) == (2, 1, 0):
        return "210"
    if (m, asym, n) == (3, 0, 0):
        return "300"
    raise AssertionError(f"unclassifiable triad {codes}")


def _build_table() -> np.ndarray:
    table = np.zeros(64, dtype=np.int32)
    perms = list(itertools.permutations(range(3)))
    for t in range(64):
        a = _adj_from_tricode(t)
        # canonical representative: classification is permutation-invariant
        names = {_classify(a[np.ix_(p, p)]) for p in perms}
        assert len(names) == 1, (t, names)
        table[t] = TRIAD_NAMES.index(names.pop())
    return table


#: 64-entry lookup: tricode -> isomorphism class index (0..15).
TRICODE_TO_CLASS = _build_table()

#: (16, 64) 0/1 fold matrix: hist16 = FOLD @ hist64.
FOLD_64_TO_16 = np.zeros((NUM_CLASSES, 64), dtype=np.int64)
FOLD_64_TO_16[TRICODE_TO_CLASS, np.arange(64)] = 1


def swap_code(c):
    """Dyad code of (b, a) given the code of (a, b): swaps the 2 bits."""
    return ((c & 1) << 1) | ((c & 2) >> 1)
