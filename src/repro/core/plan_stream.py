"""Chunked out-of-core planning: bounded slices of the census plan.

:func:`repro.core.planner.build_plan` materializes the whole O(W) flat work
plan at once — W is Σ (deg u + deg v) over adjacent pairs, which on a
10M-edge power-law graph already dwarfs host RAM and single-dispatch HBM.
This module slices the same canonical-pair iteration space into contiguous
*pre-prune item ranges* of at most ``max_items`` items each, so peak host
memory for the item arrays is O(max_items) regardless of W (the standard
bounded-batch strategy of the streaming triangle-counting literature,
e.g. arXiv:1308.2166).

Key properties:

* **Exact partition.**  Chunk items are exactly the monolithic plan's items,
  split by pre-prune index; histograms and intersection counters are
  integer sums, so accumulating per-chunk partials is bit-identical to the
  single dispatch.
* **Intra-pair splits.**  Boundaries fall at arbitrary item indices, so a
  hub pair whose item count exceeds ``max_items`` simply spans several
  chunks — no chunk can overflow the budget.
* **Additive bases.**  The closed-form dyadic bases (``base_asym`` /
  ``base_mut``) are credited to the chunk containing each pair's first
  pre-prune item and sum exactly to the global bases.
* **Fixed chunk shape.**  Every chunk's packed item arrays are padded to
  the same ``chunk_shape`` (``max_items`` rounded up to ``pad_to``), so the
  per-chunk device step compiles once (see
  :class:`repro.core.engine.CensusEngine`).
* **Per-shard chunking.**  A :class:`PlanChunker` can be opened on a
  prebuilt pair space (``space=``) — one graph shard's local space — and
  :class:`ShardSchedule` locks several such per-shard streams into one
  compile-once collective geometry for the partitioned engine
  (:mod:`repro.core.partition`).
"""

from __future__ import annotations

import queue
import threading
import time
from dataclasses import dataclass
from typing import Iterator

import numpy as np

from repro.core.digraph import CompactDigraph
from repro.core.faults import FaultError
from repro.core.planner import (
    DESC_SEARCH_ITERS, DescriptorWindow, PairSpace, PlanOverflowError,
    descriptor_window, emit_items, max_pairs_per_window, num_desc_anchors,
    pad_and_pack, pair_space)


class ProducerStalledError(FaultError):
    """A shard's window producer made no progress past the watchdog
    timeout and exhausted its restart budget."""


@dataclass(frozen=True)
class PlanChunk:
    """One bounded slice of the flat work plan.

    ``item_sp``/``item_pv`` are the planner's packed words, padded with
    invalid (all-zero) items to the chunker's fixed ``chunk_shape``.
    ``base_asym``/``base_mut`` are this chunk's additive share of the
    closed-form dyadic terms.
    """

    index: int                 #: chunk number, 0-based
    num_chunks: int
    start: int                 #: pre-prune item range [start, stop)
    stop: int
    num_items: int             #: valid (post-prune) items in this chunk
    item_sp: np.ndarray        #: (chunk_shape,) int32
    item_pv: np.ndarray        #: (chunk_shape,) int32
    base_asym: int
    base_mut: int


class PlanChunker:
    """Slices a graph's census iteration space into bounded chunks.

    ``max_items`` bounds the *pre-prune* items per chunk (so valid items
    per chunk are ≤ max_items); ``pad_to`` rounds the fixed chunk shape up
    to a shard-count multiple for the distributed engine.  ``orient`` /
    ``prune_self`` match :func:`repro.core.planner.build_plan`.
    """

    def __init__(self, g: CompactDigraph | None, max_items: int | None,
                 orient: str = "none", pad_to: int = 1,
                 prune_self: bool = True, *,
                 space: PairSpace | None = None):
        if max_items is not None and max_items < 1:
            raise ValueError(f"max_items must be >= 1, got {max_items}")
        if pad_to < 1:
            raise ValueError(f"pad_to must be >= 1, got {pad_to}")
        #: a prebuilt ``space`` (e.g. one shard's local pair space from
        #: :mod:`repro.core.partition`, or a live
        #: :class:`~repro.core.pair_index.PairSpaceIndex`, unwrapped here)
        #: bypasses the graph decomposition — the per-shard chunker;
        #: ``orient``/``prune_self`` are then the space's own
        space = getattr(space, "space", space)
        self.space: PairSpace = space if space is not None else \
            pair_space(g, orient=orient, prune_self=prune_self)
        w_pre = self.space.num_items_preprune
        #: ``max_items=None`` covers the whole item space as one chunk —
        #: the monolithic schedule expressed in chunker terms (used by the
        #: device-emission path, which has no separate monolithic driver)
        self.max_items = int(max_items) if max_items is not None \
            else max(w_pre, 1)
        self.pad_to = int(pad_to)
        self.num_chunks = -(-w_pre // self.max_items) if w_pre else 0
        #: fixed padded per-chunk item-array length (compile-once shape);
        #: clamped to the actual work when the budget exceeds it
        span = min(self.max_items, max(w_pre, 1))
        self.chunk_shape = -(-span // self.pad_to) * self.pad_to
        if self.chunk_shape >= 2**31:
            raise PlanOverflowError(
                f"chunk_shape {self.chunk_shape} exceeds int32 item "
                f"indexing and would silently wrap the per-window int32 "
                f"accumulator lanes; pass a smaller max_items budget "
                f"(< 2**31)")
        starts = np.arange(self.num_chunks, dtype=np.int64) * self.max_items
        self._starts = starts
        self._base_asym, self._base_mut = self.space.base_slices(starts)
        # descriptor-space view of the same schedule: the fixed desc_shape
        # is the widest per-chunk pair span, so every chunk's descriptor
        # arrays share one shape
        self.desc_shape = max_pairs_per_window(self.space.offsets,
                                               self.max_items)
        #: unrolled lower-bound depth per lane — a constant, thanks to
        #: the anchored search (see planner.DESC_ANCHOR_STRIDE)
        self.desc_iters = DESC_SEARCH_ITERS
        self.num_anchors = num_desc_anchors(self.chunk_shape)

    def __len__(self) -> int:
        return self.num_chunks

    @property
    def num_items_preprune(self) -> int:
        return self.space.num_items_preprune

    def device_arrays(self) -> tuple[np.ndarray, ...]:
        """The 5 chunk-invariant device arrays (graph + pairs), int32 —
        uploaded once by the engine and reused across every chunk."""
        s = self.space
        return (s.indptr.astype(np.int32), s.packed,
                s.pair_u.astype(np.int32), s.pair_v.astype(np.int32),
                s.pair_code)

    def chunk(self, k: int) -> PlanChunk:
        """Materialize chunk ``k`` (O(max_items) memory)."""
        if not 0 <= k < self.num_chunks:
            raise IndexError(f"chunk {k} out of range "
                             f"[0, {self.num_chunks})")
        lo = int(self._starts[k])
        hi = min(lo + self.max_items, self.space.num_items_preprune)
        item_pair, item_slot, item_side = emit_items(self.space, lo, hi)
        num_items = int(item_pair.shape[0])
        item_sp, item_pv = pad_and_pack(item_pair, item_slot, item_side,
                                        self.chunk_shape)
        return PlanChunk(
            index=k, num_chunks=self.num_chunks, start=lo, stop=hi,
            num_items=num_items, item_sp=item_sp, item_pv=item_pv,
            base_asym=int(self._base_asym[k]),
            base_mut=int(self._base_mut[k]))

    def descriptors(self, k: int) -> DescriptorWindow:
        """Chunk ``k`` as a pair-descriptor window (O(pairs-in-chunk)
        memory, no item materialization) — what the device-emission path
        ships instead of :meth:`chunk`'s packed items.  Intra-pair splits
        surface as the window's ``desc_within0`` offsets."""
        if not 0 <= k < self.num_chunks:
            raise IndexError(f"chunk {k} out of range "
                             f"[0, {self.num_chunks})")
        lo = int(self._starts[k])
        hi = min(lo + self.max_items, self.space.num_items_preprune)
        return descriptor_window(self.space.offsets, lo, hi,
                                 self.desc_shape, self.num_anchors)

    def bases(self, k: int) -> tuple[int, int]:
        """Chunk ``k``'s additive (base_asym, base_mut) share."""
        return int(self._base_asym[k]), int(self._base_mut[k])

    def __iter__(self) -> Iterator[PlanChunk]:
        for k in range(self.num_chunks):
            yield self.chunk(k)


class ShardSchedule:
    """Per-shard chunk schedules under one compile-once geometry.

    The partitioned engine gives every device a *private* stream: shard s
    walks its own item space in windows of ``chunk_shape`` pre-prune
    items.  This schedule locks the per-shard :class:`PlanChunker`
    geometries together — one common ``chunk_shape`` (the per-device slice
    of ``max_items``) and one common ``desc_shape`` (the widest pair span
    any shard's window can have) — so one fixed-shape jitted step serves
    every shard's every window and compiles exactly once.

    Two execution disciplines consume the same geometry:

    * **Lock-step** (``schedule="lockstep"``): one collective dispatch
      per step advances every device's queue together; ``num_steps`` is
      the longest shard's step count and shorter shards pad with empty
      windows (:meth:`step_words` / :meth:`step_items` stack all shards).
      The bit-identity oracle.
    * **Async** (``schedule="async"``, the default): each shard's private
      queue is walked independently — :meth:`steps_for` real windows per
      shard, no padding steps, no inter-shard barrier
      (:meth:`shard_step_items` / :meth:`descriptors` serve one shard's
      window at a time).  Walltime tracks the mean shard cost instead of
      the max.
    """

    def __init__(self, spaces, max_items: int | None, num_devices: int,
                 mesh_shape: tuple | None = None):
        if max_items is not None and max_items < 1:
            raise ValueError(f"max_items must be >= 1, got {max_items}")
        self.spaces = list(spaces)
        if mesh_shape is not None and (
                int(mesh_shape[0]) * int(mesh_shape[1]) != len(self.spaces)):
            raise ValueError(
                f"mesh_shape {tuple(mesh_shape)} does not cover "
                f"{len(self.spaces)} shard spaces")
        #: (pair_shards, vertex_slices) when the spaces are 2D tiles in
        #: flat s*V+j order; queue s then serves tile
        #: :meth:`tile_coords`(s) — geometry and dispatch are unchanged
        self.mesh_shape = (tuple(int(x) for x in mesh_shape)
                           if mesh_shape is not None else None)
        w_max = max((s.num_items_preprune for s in self.spaces), default=0)
        budget = (-(-int(max_items) // num_devices)
                  if max_items is not None else max(w_max, 1))
        self.max_items = max_items
        #: fixed per-DEVICE dispatch lanes (each device expands/processes
        #: its own ``chunk_shape`` item window per step)
        self.chunk_shape = max(min(budget, max(w_max, 1)), 1)
        if self.chunk_shape >= 2**31:
            raise PlanOverflowError(
                f"per-device chunk_shape {self.chunk_shape} exceeds int32 "
                f"item indexing and would silently wrap the per-window "
                f"int32 accumulator lanes; pass a smaller max_items "
                f"budget (< 2**31 per device)")
        self.num_steps = max(
            (-(-s.num_items_preprune // self.chunk_shape)
             for s in self.spaces), default=0)
        self.desc_shape = max(
            max_pairs_per_window(s.offsets, self.chunk_shape)
            for s in self.spaces) if self.spaces else 1
        self.desc_iters = DESC_SEARCH_ITERS
        self.num_anchors = num_desc_anchors(self.chunk_shape)

    @property
    def num_shards(self) -> int:
        return len(self.spaces)

    def tile_coords(self, s: int) -> tuple:
        """Shard index → (pair shard, vertex slice) mesh coordinates;
        identity-on-axis-0 for 1D schedules (slice 0)."""
        if self.mesh_shape is None:
            return (s, 0)
        return (s // self.mesh_shape[1], s % self.mesh_shape[1])

    def steps_for(self, s: int) -> int:
        """Shard ``s``'s REAL step count: the windows that actually carry
        pre-prune items (``num_steps`` minus this shard's lock-step
        padding)."""
        return -(-self.spaces[s].num_items_preprune // self.chunk_shape)

    @property
    def shard_steps(self) -> list:
        """Per-shard real step counts — the async schedule's work list
        and the lock-step schedule's idle accounting
        (``idle = num_steps * num_shards - sum(shard_steps)``)."""
        return [self.steps_for(s) for s in range(self.num_shards)]

    @property
    def total_windows(self) -> int:
        """Total real windows across every shard — the async path's
        dispatch count (lock-step dispatches
        ``num_steps * num_shards`` window lanes instead)."""
        return sum(self.shard_steps)

    def _bounds(self, s: int, k: int) -> tuple[int, int]:
        """Item window [lo, hi) of shard ``s`` at step ``k`` — empty (at
        the space's end) once the shard's own queue is exhausted."""
        total = self.spaces[s].num_items_preprune
        lo = min(k * self.chunk_shape, total)
        return lo, min(lo + self.chunk_shape, total)

    def descriptors(self, s: int, k: int) -> DescriptorWindow:
        """Shard ``s``'s descriptor window at step ``k`` (possibly empty)."""
        lo, hi = self._bounds(s, k)
        return descriptor_window(self.spaces[s].offsets, lo, hi,
                                 self.desc_shape, self.num_anchors)

    def step_words(self, k: int) -> np.ndarray:
        """All shards' step-``k`` windows as one (num_shards, words) int32
        buffer — the sharded per-step upload of the device-emission path."""
        return np.stack([self.descriptors(s, k).device_words()
                         for s in range(self.num_shards)])

    def shard_step_items(self, s: int, k: int
                         ) -> tuple[np.ndarray, np.ndarray, int]:
        """Shard ``s``'s step-``k`` packed item window
        ((chunk_shape,) sp/pv words + valid item count) — the per-shard
        unit the async path dispatches one at a time."""
        lo, hi = self._bounds(s, k)
        item_pair, item_slot, item_side = emit_items(self.spaces[s],
                                                     lo, hi)
        sp, pv = pad_and_pack(item_pair, item_slot, item_side,
                              self.chunk_shape)
        return sp, pv, int(item_pair.shape[0])

    def step_items(self, k: int
                   ) -> tuple[np.ndarray, np.ndarray, list[int]]:
        """All shards' step-``k`` packed item windows, stacked
        (num_shards, chunk_shape), plus per-shard valid item counts — the
        host-emission twin of :meth:`step_words`."""
        sps, pvs, nums = [], [], []
        for s in range(self.num_shards):
            sp, pv, num = self.shard_step_items(s, k)
            nums.append(num)
            sps.append(sp)
            pvs.append(pv)
        return np.stack(sps), np.stack(pvs), nums


#: end-of-stream sentinel of :class:`ShardStreamPipeline` producers
_STREAM_DONE = object()


class WindowBatcher:
    """Adaptive K-window megabatch coalescer for the async pipeline.

    :meth:`wrap` turns a per-shard descriptor-window source (a stream of
    ``DescriptorWindow.device_words()`` rows, all of one schedule-wide
    length ``words``) into a stream of fixed-shape megabatches: each
    yield is ``(buffer, real)`` where ``buffer`` is ``(cap, words)``
    int32 holding up to the CURRENT ``k`` stacked window rows and
    ``real`` counts them.  Rows past ``real`` stay all-zero — their
    leading ``num_preprune`` word is 0, so the megastep scan masks them
    to exact zeros (:func:`repro.core.census.census_partials_desc_batch`)
    — and the buffer shape never depends on ``k``, so the jitted
    megastep compiles once regardless of how many real windows land.

    ``k`` adapts in [1, cap] from live pipeline feedback, one monotone
    move per signal:

    * :meth:`shrink` (consumer stalled: every queue empty while batches
      remain — the producers are the bottleneck) halves ``k`` so
      smaller batches reach the device sooner and the pipeline stays
      full;
    * :meth:`grow` (producer backlogged: a put found its queue full —
      the consumer/device side is the bottleneck) doubles ``k`` toward
      ``cap`` to amortize more Python dispatch overhead per step.

    ``k`` starts at ``cap`` (greedy: in the dispatch-bound regime the
    batcher exists for, producers outrun the consumer and full batches
    are right from the first dispatch).  Reads/writes of the single
    ``k`` int are atomic under the GIL; a batch snapshots ``k`` when it
    starts filling, so adaptive moves apply from the next batch on.
    """

    def __init__(self, cap: int, words: int, start: int | None = None):
        if cap < 1:
            raise ValueError(f"cap must be >= 1, got {cap}")
        if words < 1:
            raise ValueError(f"words must be >= 1, got {words}")
        self.cap = int(cap)
        self.words = int(words)
        self.k = self.cap if start is None \
            else max(1, min(int(start), self.cap))

    def shrink(self) -> None:
        """Producer-starved signal: halve ``k`` (floor 1)."""
        self.k = max(1, self.k // 2)

    def grow(self) -> None:
        """Consumer-backlogged signal: double ``k`` (cap ``cap``)."""
        self.k = min(self.cap, self.k * 2)

    def wrap(self, source):
        """Generator coalescing ``source``'s window rows into
        ``(buffer (cap, words) int32, real)`` megabatches of at most
        the current ``k`` windows each."""
        it = iter(source)
        while True:
            take = self.k
            buf = np.zeros((self.cap, self.words), dtype=np.int32)
            real = 0
            for row in it:
                buf[real] = row
                real += 1
                if real >= take:
                    break
            if real == 0:
                return
            yield buf, real


class ShardStreamPipeline:
    """Background per-shard window producers feeding a round-robin
    consumer — the host half of the async partitioned pipeline.

    One daemon thread per shard runs that shard's ``source`` generator
    (descriptor-window packing or item emission — pure numpy host work)
    into a private bounded queue of ``depth`` windows, so window k+1's
    generation overlaps window k's upload + device compute and no shard's
    production ever waits on another's.  ``depth=2`` double-buffers: one
    window in flight to the device, one pre-built behind it.

    Iterating the pipeline yields ``(shard, window)`` in round-robin
    order over whichever shards have a window ready — a fast shard is
    never held back by a slow one (no barrier); drained shards (their
    ``_STREAM_DONE`` sentinel consumed) leave the rotation immediately
    and are never polled again, so exhausted or empty-shard streams
    cost the consumer nothing (the engine additionally never opens a
    stream for a shard with zero windows).  When *no* live shard has a
    window ready the consumer blocks on the first live queue and counts
    a **stall** (producer-bound moments, surfaced as
    ``EngineStats.stall_steps``).  Producer exceptions re-raise in the
    consumer; :meth:`close` unblocks and joins the threads (the engine
    closes in a ``finally``).

    ``batch`` (optional) is a :class:`WindowBatcher`: each source is
    wrapped so its producer thread coalesces up to the batcher's
    current ``k`` windows into one fixed-shape megabatch per queue
    item, and the pipeline feeds the batcher its adaptive signals —
    consumer stalls call :meth:`WindowBatcher.shrink` (only once
    something has been consumed, so startup latency is not mistaken for
    producer starvation) and producer backlog (a put finding its queue
    full) calls :meth:`WindowBatcher.grow`, once per blocked window.

    **Fault tolerance** (all optional, all off by default):

    * ``restart`` — a factory ``restart(slot, skip) -> source`` building
      a fresh window source for ``slot`` that skips its first ``skip``
      raw windows.  With it, a producer that *raises* retries in place:
      the thread rebuilds its source from the number of windows already
      landed on the queue (the authoritative progress record — windows
      put are never regenerated, windows lost mid-generation always
      are) and resumes, up to ``max_retries`` attempts with exponential
      ``backoff``; the budget exhausted, the exception surfaces to the
      consumer as before.  Regeneration is pure host numpy from the
      same immutable pair space, so a restarted stream is bit-identical
      to an uninterrupted one.
    * ``watchdog`` — a stall timeout in seconds.  A monitor thread
      watches every live producer; one whose queue is *empty* and whose
      put-count has not advanced for ``watchdog`` seconds is declared
      hung, its attempt is cancelled, and a fresh thread resumes from
      the same put-count (``watchdog_fires`` counts these).  Cancelled
      attempts can never land a late window: puts and cancellation are
      serialized under one lock, and a cancelled attempt re-checks its
      own cancel event under that lock before every put.

    The pipeline is a context manager; ``__exit__`` calls
    :meth:`close`, so producer threads are reaped on exceptions and
    KeyboardInterrupt, not just on the engine's explicit ``finally``.
    """

    _POLL = 0.05

    def __init__(self, sources, depth: int = 2, batch=None, *,
                 restart=None, watchdog: float | None = None,
                 max_retries: int = 2, backoff: float = 0.01):
        if depth < 1:
            raise ValueError(f"depth must be >= 1, got {depth}")
        if max_retries < 0:
            raise ValueError(f"max_retries must be >= 0, got {max_retries}")
        self.depth = int(depth)
        self.batch = batch
        self.stalls = 0
        self.producer_retries = 0
        self.watchdog_fires = 0
        self._consumed = 0
        self._stop = threading.Event()
        self._restart = restart
        self._watchdog = watchdog
        self._max_retries = int(max_retries)
        self._backoff = float(backoff)
        sources = list(sources)
        n = len(sources)
        self._live = set(range(n))
        self._queues = [queue.Queue(maxsize=self.depth) for _ in range(n)]
        #: serializes producer puts against watchdog cancellation so a
        #: cancelled attempt can never land a late (duplicate) window
        self._lock = threading.Lock()
        #: raw windows successfully landed per slot, across all attempts
        self._puts = [0] * n
        #: restart attempts consumed per slot (error + watchdog combined)
        self._attempts = [0] * n
        self._cancels: list = [threading.Event() for _ in range(n)]
        self._threads = []
        for s, src in enumerate(sources):
            self._spawn(s, src, self._cancels[s])
        if watchdog is not None:
            t = threading.Thread(target=self._watch, daemon=True)
            t.start()
            self._threads.append(t)

    def __enter__(self) -> "ShardStreamPipeline":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.close()

    def _spawn(self, slot: int, source, cancel) -> None:
        if self.batch is not None:
            source = self.batch.wrap(source)
        t = threading.Thread(target=self._produce,
                             args=(slot, self._queues[slot], source, cancel),
                             daemon=True)
        t.start()
        self._threads.append(t)

    def _make_source(self, slot: int, skip: int):
        src = self._restart(slot, skip)
        return self.batch.wrap(src) if self.batch is not None else src

    def _offer(self, q: queue.Queue, item) -> bool:
        """Stop-aware put: lands ``item`` or gives up once :meth:`close`
        has been called (the consumer is gone — nobody will ever drain a
        full queue, so an unconditional put would strand the thread)."""
        while not self._stop.is_set():
            try:
                q.put(item, timeout=self._POLL)
                return True
            except queue.Full:
                continue
        return False

    def _put_window(self, slot: int, q: queue.Queue, window,
                    cancel) -> bool:
        """Land one window under the put/cancel lock; ``False`` once this
        attempt is stopped or cancelled (the window is then discarded —
        its replacement attempt will regenerate it)."""
        count = window[1] if self.batch is not None else 1
        backlogged = False
        while not (self._stop.is_set() or cancel.is_set()):
            with self._lock:
                if cancel.is_set():
                    return False
                try:
                    q.put_nowait(window)
                    self._puts[slot] += count
                    return True
                except queue.Full:
                    pass
            if not backlogged and self.batch is not None:
                # consumer behind: one grow signal per blocked window,
                # not per retry
                self.batch.grow()
                backlogged = True
            time.sleep(0.002)
        return False

    def _produce(self, slot: int, q: queue.Queue, source, cancel) -> None:
        while True:
            try:
                for window in source:
                    if not self._put_window(slot, q, window, cancel):
                        return
            except BaseException as exc:
                if (self._restart is None or self._stop.is_set()
                        or cancel.is_set()
                        or self._attempts[slot] >= self._max_retries):
                    # out of budget (or no restart factory): surface to
                    # the consumer, as before
                    self._offer(q, exc)
                    return
                self._attempts[slot] += 1
                self.producer_retries += 1
                time.sleep(self._backoff * 2 ** (self._attempts[slot] - 1))
                source = self._make_source(slot, self._puts[slot])
                continue
            break
        self._offer(q, _STREAM_DONE)

    def _watch(self) -> None:
        """Watchdog: restart producers whose queue is empty and whose
        put-count is frozen past the timeout.  An empty queue rules out
        a producer blocked on a legitimately full queue (that is
        consumer-bound, not a stall), so a frozen count really means the
        generation itself is hung."""
        n = len(self._queues)
        seen = list(self._puts)
        since = [time.monotonic()] * n
        poll = min(self._watchdog / 4.0, self._POLL) or self._POLL
        while not self._stop.wait(poll):
            now = time.monotonic()
            for s in list(self._live):
                fresh = None
                with self._lock:
                    if self._puts[s] != seen[s] or not self._queues[s].empty():
                        seen[s] = self._puts[s]
                        since[s] = now
                        continue
                    if now - since[s] < self._watchdog:
                        continue
                    # hung: cancel this attempt under the lock (no put
                    # can interleave) and snapshot the resume point
                    self._cancels[s].set()
                    skip = self._puts[s]
                    since[s] = now
                    self.watchdog_fires += 1
                    if (self._restart is None
                            or self._attempts[s] >= self._max_retries):
                        fresh = False
                    else:
                        self._attempts[s] += 1
                        fresh = True
                if fresh is False:
                    self._offer(self._queues[s], ProducerStalledError(
                        f"shard {s} producer made no progress for "
                        f"{self._watchdog}s and exhausted its "
                        f"{self._max_retries} restarts"))
                elif fresh:
                    cancel = threading.Event()
                    self._cancels[s] = cancel
                    try:
                        src = self._restart(s, skip)
                    except BaseException as exc:
                        self._offer(self._queues[s], exc)
                        continue
                    self._spawn(s, src, cancel)

    def _resolve(self, item, s: int):
        if item is _STREAM_DONE:
            # drained: out of the rotation for good — never polled again
            self._live.discard(s)
            return None
        if isinstance(item, BaseException):
            raise item
        self._consumed += 1
        return (s, item)

    def __iter__(self):
        while self._live:
            progressed = False
            for s in sorted(self._live):
                try:
                    item = self._queues[s].get_nowait()
                except queue.Empty:
                    continue
                progressed = True
                got = self._resolve(item, s)
                if got is not None:
                    yield got
            if not progressed and self._live:
                # every live producer is mid-generation: block on the
                # lowest shard and record the stall
                self.stalls += 1
                if self.batch is not None and self._consumed:
                    self.batch.shrink()
                s = min(self._live)
                got = self._resolve(self._queues[s].get(), s)
                if got is not None:
                    yield got

    def close(self) -> None:
        """Stop the producers, drain the queues, and join the threads
        (idempotent); safe mid-iteration.

        Draining matters: a producer blocked on a full queue — including
        one trying to land its terminal exception or ``_STREAM_DONE``
        sentinel — frees up immediately instead of spinning out its stop
        timeout, and the join below then reaps every thread even when a
        producer raised after the consumer stopped iterating.
        """
        self._stop.set()
        for q in self._queues:
            while True:
                try:
                    q.get_nowait()
                except queue.Empty:
                    break
        for t in self._threads:
            t.join(timeout=1.0)


def iter_plan_chunks(g: CompactDigraph, max_items: int,
                     orient: str = "none", pad_to: int = 1,
                     prune_self: bool = True) -> Iterator[PlanChunk]:
    """Generator convenience over :class:`PlanChunker`."""
    yield from PlanChunker(g, max_items, orient=orient, pad_to=pad_to,
                           prune_self=prune_self)
