"""Delta algebra for incremental triad censuses.

The census decomposes over canonical pairs::

    C = complement(base_asym + base_mut + Σ_p partials(p))

where ``partials(p)`` for pair p = (u, v) depends *only* on the dyad code
c_uv, the two CSR rows N(u) and N(v) (contents + direction codes), and the
vertex ids — nothing else (see :func:`repro.core.census.classify_items`).
An edge delta Δ changes the rows of exactly the *touched* vertices
T = endpoints of pairs whose dyad code changed
(:class:`repro.core.digraph.GraphDelta`).  Hence any pair with both
endpoints outside T contributes bit-identical partials and closed-form
base terms in G_old and G_new, and with

    A(G) = pairs of G with an endpoint in T         (affected pairs)

the update

    C_new = C_old − contrib(A(G_old), G_old) + contrib(A(G_new), G_new)

is *exact* in integer arithmetic — bit-identical to a from-scratch census
of G_new, on every backend and orient mode (the streaming literature's
touched-neighborhood principle, arXiv:1308.2166, composed with the
per-partition additive recounts of arXiv:1706.05151).

This module owns the pure host-side algebra: affected-pair discovery,
subset contributions (via :func:`repro.core.planner.emit_items_for_pairs`
+ subset-additive bases), the combine step, and the exactness invariant
checker used by the tests.  Device dispatch of the subset items lives in
:class:`repro.core.engine.EngineSession`.
"""

from __future__ import annotations

from typing import Callable

import numpy as np

from repro.core.digraph import GraphDelta
from repro.core.planner import (
    PairSpace, base_for_pairs, emit_items_for_pairs,
    iter_descriptor_windows)
from repro.core.tricode import FOLD_64_TO_16

#: runner signature: (item_pair, item_slot, item_side) -> (hist64, inter)
ItemRunner = Callable[[np.ndarray, np.ndarray, np.ndarray],
                      tuple[np.ndarray, np.ndarray]]


def affected_pair_ids(space, touched) -> np.ndarray:
    """Indices of the pairs with an endpoint in ``touched`` — the pairs
    whose census contribution may differ after the delta (their item sets,
    item codes, or closed-form terms read a changed row/degree).

    ``space`` may be a :class:`PairSpace` (O(P) mask scan — the oracle)
    or a :class:`~repro.core.pair_index.PairSpaceIndex`, which answers
    the same query in O(Σ deg(touched) · log P) from its touched-row
    walk; results are identical."""
    if hasattr(space, "affected_pair_ids"):   # a PairSpaceIndex
        return space.affected_pair_ids(touched)
    touched = np.asarray(touched, dtype=np.int64).ravel()
    if touched.size == 0 or space.num_pairs == 0:
        return np.zeros(0, dtype=np.int64)
    mask = np.zeros(space.n, dtype=bool)
    mask[touched] = True
    return np.nonzero(mask[space.pair_u] | mask[space.pair_v])[0]


def contribution_counts(base_asym: int, base_mut: int, hist64, inter
                        ) -> np.ndarray:
    """Fold device partials + closed-form bases of a pair subset into its
    additive 16-type contribution.  Slot 0 (the 003 null triads) is left
    at zero — it is a global complement, restored by :func:`combine`."""
    hist64 = np.asarray(hist64, dtype=np.int64)
    inter = np.asarray(inter, dtype=np.int64)
    c = FOLD_64_TO_16 @ hist64
    c[1] += base_asym + int(inter[0])   # 012
    c[2] += base_mut + int(inter[1])    # 102
    c[0] = 0
    return c


def subset_contribution(space: PairSpace, pair_ids: np.ndarray,
                        run_items: ItemRunner
                        ) -> tuple[np.ndarray, int]:
    """16-type contribution of an arbitrary pair subset + its item count.

    ``run_items`` computes the ``(hist64, inter)`` partials of the emitted
    items on whatever backend/device the caller owns; zero-item subsets
    never dispatch.
    """
    ids = np.asarray(pair_ids, dtype=np.int64).ravel()
    base_asym, base_mut = base_for_pairs(space, ids)
    item_pair, item_slot, item_side = emit_items_for_pairs(space, ids)
    num_items = int(item_pair.shape[0])
    if num_items == 0:
        hist64 = np.zeros(64, np.int64)
        inter = np.zeros(2, np.int64)
    else:
        hist64, inter = run_items(item_pair, item_slot, item_side)
    return contribution_counts(base_asym, base_mut, hist64, inter), \
        num_items


def subset_descriptor_windows(space, pair_ids: np.ndarray,
                              max_items: int, desc_shape: int,
                              num_anchors: int):
    """Descriptor windows covering an arbitrary pair subset's item space —
    the device-emission counterpart of :func:`emit_items_for_pairs`.

    A delta update that routes its affected pairs through these windows
    uploads O(affected pairs) descriptor words per window instead of the
    subset's O(items) packed work items; the device expands and prunes in
    place (:func:`repro.core.census.census_partials_desc`), so the
    incremental path's host→device traffic shrinks with the same delta
    algebra and bit-identical results.

    ``space`` may be a :class:`PairSpace` or a
    :class:`~repro.core.pair_index.PairSpaceIndex` (its live space is
    used — the windows it yields are bit-identical either way).
    """
    space = getattr(space, "space", space)   # unwrap a PairSpaceIndex
    ids = np.asarray(pair_ids, dtype=np.int64).ravel()
    if ids.size and (ids.min() < 0 or ids.max() >= space.num_pairs):
        raise ValueError(f"pair id outside [0, {space.num_pairs})")
    offsets = np.zeros(ids.shape[0] + 1, dtype=np.int64)
    np.cumsum(space.counts[ids], out=offsets[1:])
    yield from iter_descriptor_windows(offsets, max_items, desc_shape,
                                       num_anchors, pair_ids=ids)


def combine(census_old: np.ndarray, contrib_old: np.ndarray,
            contrib_new: np.ndarray, n: int) -> np.ndarray:
    """Apply the affected-pair diff: ``C_new = C_old − old + new`` on the
    15 non-null types, with the 003 count restored as the complement of
    the fixed triad total ``C(n, 3)``."""
    out = np.asarray(census_old, dtype=np.int64).copy()
    out[1:] += contrib_new[1:] - contrib_old[1:]
    total = n * (n - 1) * (n - 2) // 6
    out[0] = total - out[1:].sum()
    return out


def host_runner(space: PairSpace, backend: str = "jnp",
                pad_to: int = 1) -> ItemRunner:
    """Non-resident reference runner: packs the items and dispatches the
    single-device partials for ``backend`` ad hoc (no session reuse).
    The exactness oracle for :class:`repro.core.engine.EngineSession` and
    the convenience path for standalone host-side incremental updates."""
    import jax.numpy as jnp

    from repro.core.census import partials_fn
    from repro.core.planner import pad_and_pack

    def run(item_pair, item_slot, item_side):
        length = -(-item_pair.shape[0] // pad_to) * pad_to
        item_sp, item_pv = pad_and_pack(item_pair, item_slot, item_side,
                                        length)
        fn = partials_fn(backend, space.search_iters)
        hist64, inter = fn(
            jnp.asarray(space.indptr.astype(np.int32)),
            jnp.asarray(space.packed),
            jnp.asarray(space.pair_u.astype(np.int32)),
            jnp.asarray(space.pair_v.astype(np.int32)),
            jnp.asarray(space.pair_code),
            jnp.asarray(item_sp), jnp.asarray(item_pv))
        return (np.asarray(hist64, dtype=np.int64),
                np.asarray(inter, dtype=np.int64))

    return run


def verify_delta_closure(space_old: PairSpace, space_new: PairSpace,
                         delta: GraphDelta) -> None:
    """Exactness invariant: every pair whose presence or dyad code differs
    between the two spaces must be inside BOTH affected sets (old and new),
    and the delta's recorded codes must match the graphs.  O(P) — used by
    the tests and debug paths, never on the hot path."""
    n = space_old.n
    assert space_new.n == n, "incremental updates require a fixed n"
    key_old = space_old.pair_u * n + space_old.pair_v
    key_new = space_new.pair_u * n + space_new.pair_v
    keys = np.union1d(key_old, key_new)

    def codes_on(space, key_side, keys):
        out = np.zeros(keys.shape[0], dtype=np.int64)
        if key_side.size:
            pos = np.searchsorted(key_side, keys)
            safe = np.minimum(pos, key_side.shape[0] - 1)
            hit = (pos < key_side.shape[0]) & (key_side[safe] == keys)
            out[hit] = (space.pair_code[safe[hit]] & 3)
        return out

    c_old = codes_on(space_old, key_old, keys)
    c_new = codes_on(space_new, key_new, keys)
    changed = keys[c_old != c_new]
    dkeys = delta.pair_lo * n + delta.pair_hi
    assert np.isin(changed, dkeys).all(), \
        "a changed pair escaped the recorded delta"
    rec_old = codes_on(space_old, key_old, dkeys)
    rec_new = codes_on(space_new, key_new, dkeys)
    assert np.array_equal(rec_old, delta.old_code & 3), "stale old codes"
    assert np.array_equal(rec_new, delta.new_code & 3), "stale new codes"

    for space, key_side in ((space_old, key_old), (space_new, key_new)):
        aff = affected_pair_ids(space, delta.touched)
        aff_keys = (space.pair_u[aff] * n + space.pair_v[aff]
                    if aff.size else np.zeros(0, np.int64))
        present_changed = changed[np.isin(changed, key_side)]
        assert np.isin(present_changed, aff_keys).all(), \
            "a changed pair is outside the affected set"
