"""Gradient compression for cross-pod all-reduce: int8 quantized psum.

At 512 chips the gradient all-reduce crosses the slow pod interconnect;
8-bit quantization cuts that volume 4× (vs f32 moments staying local).
Scheme: global max-abs scale (one scalar pmax), symmetric int8 quantize,
integer psum (exact — no accumulation error across 2..4096 shards since
|Σq| ≤ shards·127 « 2³¹), dequantize. Optional error feedback keeps the
residual locally for the next step (Seide et al., 1-bit SGD lineage).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def quantized_psum(x, axis_name: str, bits: int = 8):
    """All-reduce ``x`` with int-``bits`` quantization. Returns f32."""
    assert 2 <= bits <= 16
    qmax = float(2 ** (bits - 1) - 1)
    x32 = x.astype(jnp.float32)
    scale = jax.lax.pmax(jnp.max(jnp.abs(x32)), axis_name)
    scale = jnp.maximum(scale, 1e-30)
    q = jnp.clip(jnp.round(x32 / scale * qmax), -qmax, qmax)
    q = q.astype(jnp.int32)
    total = jax.lax.psum(q, axis_name)
    return total.astype(jnp.float32) * (scale / qmax)


def quantized_tree_psum(tree, axis_name: str, bits: int = 8,
                        residual=None):
    """Tree-wise quantized psum with optional error feedback.

    Returns (reduced_tree, new_residual). Pass the residual back in on
    the next step to keep the long-run quantization error unbiased.
    """
    if residual is not None:
        tree = jax.tree.map(lambda g, r: g.astype(jnp.float32) + r,
                            tree, residual)
    reduced = jax.tree.map(
        lambda g: quantized_psum(g, axis_name, bits), tree)
    # residual = what this shard failed to communicate
    n = jax.lax.psum(1, axis_name)
    new_res = jax.tree.map(
        lambda g, r: g.astype(jnp.float32) - r / n, tree, reduced)
    return reduced, new_res
