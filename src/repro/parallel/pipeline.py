"""GPipe-style pipeline parallelism over the ``pod`` mesh axis.

At 2+ pods, cross-pod ICI is the scarcest link; instead of DP over pods
(one full gradient all-reduce across pods per step) the pipeline sends
only microbatch activations over ``collective-permute`` — the multi-pod
placement alternative exposed by the launcher.

Implementation: ``shard_map`` over ``pod``; every pod holds one *stage*
(an equal slice of the layer stack, leading-axis sharded). The GPipe
schedule runs M + S - 1 ticks; at tick t stage s processes microbatch
t - s. Activations hop stages via ``ppermute`` (differentiable — its
transpose is the reverse permute, so ``jax.grad`` through a pipeline step
yields the GPipe backward schedule automatically).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map


def pipeline_apply(stage_fn, mesh: Mesh, axis: str = "pod"):
    """Build a pipelined apply: (stage_params, microbatches) -> outputs.

    ``stage_params``: pytree with leading axis = num_stages (sharded over
    ``axis``). ``microbatches``: (M, ...) array stack, logically fed to
    stage 0 and collected from the last stage; replicated in/out specs
    keep the API simple (activations are small relative to weights).
    ``stage_fn(params_for_stage, x) -> y`` with y.shape == x.shape.
    """
    n_stages = dict(zip(mesh.axis_names, mesh.devices.shape))[axis]

    def inner(stage_params, mbs):
        stage_id = jax.lax.axis_index(axis)
        m = mbs.shape[0]
        ticks = m + n_stages - 1
        local_params = jax.tree.map(lambda a: a[0], stage_params)

        def tick(carry, t):
            buf, outs = carry
            mb_idx = jnp.clip(t - stage_id, 0, m - 1)
            active = (t >= stage_id) & (t - stage_id < m)
            x_in = jnp.where(stage_id == 0,
                             mbs[jnp.clip(t, 0, m - 1)], buf)
            y = stage_fn(local_params, x_in)
            y = jnp.where(active, y, buf)
            # pass to the next stage (last stage wraps; value unused)
            nxt = jax.lax.ppermute(
                y, axis, [(i, (i + 1) % n_stages)
                          for i in range(n_stages)])
            out_slot = t - (n_stages - 1)
            is_out = (stage_id == n_stages - 1) & (out_slot >= 0)
            outs = jax.lax.dynamic_update_index_in_dim(
                outs, jnp.where(is_out, y, outs[jnp.clip(out_slot, 0,
                                                         m - 1)]),
                jnp.clip(out_slot, 0, m - 1), 0)
            return (nxt, outs), None

        buf0 = jnp.zeros_like(mbs[0])
        outs0 = jnp.zeros_like(mbs)
        (buf, outs), _ = jax.lax.scan(
            tick, (buf0, outs0), jnp.arange(ticks))
        # every stage computed an ``outs``; only the last stage's is real.
        # broadcast it: sum over stages of masked outs
        outs = jnp.where(stage_id == n_stages - 1, outs, 0.0)
        return jax.lax.psum(outs, axis)

    spec_params = P(axis)
    other_axes = [a for a in mesh.axis_names if a != axis]
    return shard_map(
        inner, mesh=mesh,
        in_specs=(spec_params, P(*([None] * 1))),
        out_specs=P(),
        check_vma=False)


def split_stages(params_list: list, n_stages: int):
    """Stack per-layer param pytrees into (n_stages, layers/stage, ...)."""
    per = len(params_list) // n_stages
    assert per * n_stages == len(params_list)
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *params_list)
    return jax.tree.map(
        lambda a: a.reshape(n_stages, per, *a.shape[1:]), stacked)
