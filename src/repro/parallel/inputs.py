"""Abstract input construction for every (arch × shape) cell.

``input_specs`` returns ShapeDtypeStruct stand-ins (weak-type-correct,
shardable, no device allocation) for a cell's step function inputs, plus
the matching NamedSharding tree — the contract the dry-run lowers against.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeSpec
from repro.models.model import init_cache
from repro.parallel.sharding import batch_axes, cache_shardings

SDS = jax.ShapeDtypeStruct


def _seq_split_encdec(cfg: ArchConfig, seq_len: int) -> tuple[int, int]:
    """Enc/dec budget split for encoder-decoder cells (documented in
    DESIGN.md: the cell's seq_len covers src frames + tgt tokens 50/50)."""
    return seq_len // 2, seq_len // 2


def train_batch_specs(cfg: ArchConfig, shape: ShapeSpec, mesh: Mesh):
    b, s = shape.global_batch, shape.seq_len
    b_ax = batch_axes(mesh, b)
    batch, shard = {}, {}
    if cfg.is_encdec:
        ss, st = _seq_split_encdec(cfg, s)
        batch["src_embeds"] = SDS((b, ss, cfg.d_model), jnp.bfloat16)
        shard["src_embeds"] = NamedSharding(mesh, P(b_ax, None, None))
        s = st
    batch["tokens"] = SDS((b, s), jnp.int32)
    batch["labels"] = SDS((b, s), jnp.int32)
    shard["tokens"] = NamedSharding(mesh, P(b_ax, None))
    shard["labels"] = NamedSharding(mesh, P(b_ax, None))
    if cfg.modality == "vlm":
        batch["vision_embeds"] = SDS((b, s, cfg.d_model), jnp.bfloat16)
        batch["vision_mask"] = SDS((b, s), jnp.bool_)
        batch["positions3"] = SDS((3, b, s), jnp.int32)
        shard["vision_embeds"] = NamedSharding(mesh, P(b_ax, None, None))
        shard["vision_mask"] = NamedSharding(mesh, P(b_ax, None))
        shard["positions3"] = NamedSharding(mesh, P(None, b_ax, None))
    return batch, shard


def decode_inputs(cfg: ArchConfig, shape: ShapeSpec, mesh: Mesh,
                  kv_quant: bool = False):
    """(token, cache) abstract inputs + shardings for one decode step."""
    b, s = shape.global_batch, shape.seq_len
    b_ax = batch_axes(mesh, b)
    src_len = _seq_split_encdec(cfg, s)[0] if cfg.is_encdec else 0
    cache = jax.eval_shape(
        lambda: init_cache(cfg, batch=b, seq_len=s, src_len=src_len,
                           kv_quant=kv_quant))
    token = SDS((b, 1), jnp.int32)
    shardings = {
        "token": NamedSharding(mesh, P(b_ax, None)),
        "cache": cache_shardings(cache, mesh, b),
    }
    return token, cache, shardings


def make_concrete_batch(cfg: ArchConfig, b: int, s: int, rng=None):
    """Small concrete batch for examples/tests (mirrors train_batch_specs)."""
    rng = np.random.default_rng(0) if rng is None else rng
    batch = {}
    if cfg.is_encdec:
        ss, st = _seq_split_encdec(cfg, s)
        batch["src_embeds"] = jnp.asarray(
            rng.normal(size=(b, ss, cfg.d_model)), jnp.bfloat16)
        s = st
    batch["tokens"] = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)
    batch["labels"] = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)
    if cfg.modality == "vlm":
        batch["vision_embeds"] = jnp.asarray(
            rng.normal(size=(b, s, cfg.d_model)), jnp.bfloat16)
        batch["vision_mask"] = jnp.asarray(rng.random((b, s)) < 0.25)
        batch["positions3"] = jnp.asarray(np.broadcast_to(
            np.arange(s, dtype=np.int32), (3, b, s)))
    return batch
