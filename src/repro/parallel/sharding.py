"""Logical-axis sharding rules → PartitionSpecs.

Every parameter carries logical axis names from its schema (``vocab``,
``embed``, ``ffn``, ``heads``, ``experts``, ...). Rules map logical axes to
mesh axes with two safeguards applied dim-by-dim:

* divisibility — a dim that doesn't divide evenly by the mesh axis size
  falls back to unsharded (e.g. 40 experts or 14 heads over a 16-way
  ``model`` axis), keeping every (arch × mesh) cell compilable;
* uniqueness — a mesh axis is used at most once per tensor.

Default layout = FSDP(``data``) × TP(``model``): weights shard their
feature dim over ``model`` and their ``embed``/reduction dim over ``data``
(ZeRO-3-style), activations shard batch over ``data`` (+``pod``) and the
sequence/residual stream over ``model`` (Megatron-style sequence
parallelism, constrained at block boundaries only so GSPMD can pick the
collective schedule inside a block).
"""

from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

#: logical axis -> preferred mesh axes, in priority order.
DEFAULT_RULES: dict[str, tuple] = {
    "vocab": ("model",),
    "ffn": ("model",),
    "experts": ("model",),
    "heads": ("model",),
    "kv_heads": (),            # usually too small; replicated
    "lru": ("model",),
    "embed": ("data",),        # FSDP / ZeRO-3 param sharding
    "head_dim": (),
    "layers": (),
    "batch": ("pod", "data"),
    "seq": ("model",),
}


def mesh_axis_sizes(mesh: Mesh) -> dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def spec_for_axes(axes: tuple, shape: tuple, mesh: Mesh,
                  rules: dict | None = None) -> P:
    """Build a PartitionSpec for one tensor, honoring both safeguards."""
    rules = DEFAULT_RULES if rules is None else rules
    sizes = mesh_axis_sizes(mesh)
    used: set[str] = set()
    parts = []
    for dim, name in enumerate(axes):
        cand = rules.get(name, ()) if name else ()
        if name == "batch":
            # batch may combine (pod, data) when both divide
            combo = [a for a in cand if a in sizes and a not in used]
            total = int(np.prod([sizes[a] for a in combo])) if combo else 1
            if combo and shape[dim] % total == 0:
                parts.append(tuple(combo) if len(combo) > 1 else combo[0])
                used.update(combo)
                continue
            combo = [a for a in combo if a == "data"]
            if combo and shape[dim] % sizes[combo[0]] == 0:
                parts.append(combo[0])
                used.add(combo[0])
                continue
            parts.append(None)
            continue
        placed = False
        for a in cand:
            if a in sizes and a not in used and shape[dim] % sizes[a] == 0:
                parts.append(a)
                used.add(a)
                placed = True
                break
        if not placed:
            parts.append(None)
    return P(*parts)


def tree_shardings(axes_tree, shape_tree, mesh: Mesh,
                   rules: dict | None = None):
    """NamedSharding tree for (axes tree, ShapeDtypeStruct tree)."""
    def walk(ax, sh):
        if isinstance(ax, tuple):
            return NamedSharding(
                mesh, spec_for_axes(ax, sh.shape, mesh, rules))
        return {k: walk(ax[k], sh[k]) for k in ax}
    return walk(axes_tree, shape_tree)


# ------------------------------------------------------- activation specs

def batch_axes(mesh: Mesh, global_batch: int):
    sizes = mesh_axis_sizes(mesh)
    cand = [a for a in ("pod", "data") if a in sizes]
    total = int(np.prod([sizes[a] for a in cand]))
    if cand and global_batch % total == 0:
        return tuple(cand) if len(cand) > 1 else cand[0]
    if "data" in sizes and global_batch % sizes["data"] == 0:
        return "data"
    return None


def activation_spec(mesh: Mesh, global_batch: int, seq_len: int,
                    seq_shard: bool = True) -> P:
    """Residual-stream constraint: (batch, seq, d_model)."""
    b_ax = batch_axes(mesh, global_batch)
    sizes = mesh_axis_sizes(mesh)
    s_ax = ("model" if seq_shard and "model" in sizes
            and seq_len % sizes["model"] == 0 else None)
    return P(b_ax, s_ax, None)


def make_activation_sharder(mesh: Mesh, global_batch: int, seq_len: int,
                            seq_shard: bool = True):
    spec = activation_spec(mesh, global_batch, seq_len, seq_shard)
    sh = NamedSharding(mesh, spec)
    def sharder(x):
        if x.ndim == 3:
            return jax.lax.with_sharding_constraint(x, sh)
        return x
    return sharder


def moe_dispatch_plan(cfg, mesh: Mesh, global_batch: int,
                      seq_len: int = 0, seq_shard: bool = True):
    """(groups, group_sharder, ep_sharder) for the grouped MoE dispatch.

    groups = the full device count participating in the token layout
    (batch shards × sequence shards), so each device owns whole dispatch
    groups — per-group capacity is per-device capacity (GShard
    semantics) and GSPMD never has to reshard the cumsum/scatter chain.
    ``group_sharder`` pins every (G, ...) dispatch tensor to that layout;
    ``ep_sharder`` constrains the (E, G·C, d) expert batch to EP over
    ``model`` when E divides it (the canonical all-to-all), else shards
    the capacity dim.
    """
    if not getattr(cfg, "is_moe", False):
        return 1, None, None
    sizes = mesh_axis_sizes(mesh)
    b_ax = batch_axes(mesh, global_batch)
    axes = [b_ax] if isinstance(b_ax, str) else list(b_ax or ())
    tp = sizes.get("model", 1)
    if (seq_shard and "model" in sizes and seq_len
            and seq_len % sizes["model"] == 0):
        axes.append("model")
    groups = int(np.prod([sizes[a] for a in axes])) if axes else 1
    g_spec = tuple(axes) if len(axes) > 1 else (axes[0] if axes else None)

    def group_sharder(a):
        spec = P(*([g_spec] + [None] * (a.ndim - 1)))
        return jax.lax.with_sharding_constraint(
            a, NamedSharding(mesh, spec))

    def ep_sharder(xe):
        e = xe.shape[0]
        if e % tp == 0:
            spec = P("model", None, None)
        elif xe.shape[1] % tp == 0:
            spec = P(None, "model", None)
        else:
            spec = P(None, None, None)
        return jax.lax.with_sharding_constraint(
            xe, NamedSharding(mesh, spec))

    return max(groups, 1), group_sharder, ep_sharder


# ------------------------------------------------------- cache specs

def cache_leaf_spec(path_names: tuple, shape: tuple, mesh: Mesh,
                    global_batch: int) -> P:
    """Sharding for decode-cache leaves, keyed by leaf name + rank."""
    name = path_names[-1]
    b_ax = batch_axes(mesh, global_batch)
    sizes = mesh_axis_sizes(mesh)
    def fit(ax, dim):
        return ax if ax in sizes and shape[dim] % sizes[ax] == 0 else None
    if name in ("k", "v", "cross_k", "cross_v"):     # (B, S, Hkv, hd)
        return P(b_ax, fit("model", 1), None, None)
    if name in ("k_scale", "v_scale"):               # (B, S, Hkv)
        return P(b_ax, fit("model", 1), None)
    if name == "c" and len(shape) == 4:              # mLSTM (B, H, K, K)
        return P(b_ax, None, fit("model", 2), None)
    if name in ("c", "n", "h", "m") and len(shape) == 3:
        return P(b_ax, None, fit("model", 2))
    if name == "n" and len(shape) == 3:
        return P(b_ax, None, fit("model", 2))
    if name == "m" and len(shape) == 2:
        return P(b_ax, None)
    if name == "conv":                               # (B, cw-1, W)
        return P(b_ax, None, fit("model", 2))
    if name == "h" and len(shape) == 2:              # (B, W)
        return P(b_ax, fit("model", 1))
    if len(shape) == 0:
        return P()
    return P(*([b_ax] + [None] * (len(shape) - 1)))


def cache_shardings(cache_tree, mesh: Mesh, global_batch: int):
    def walk(node, path):
        if isinstance(node, dict):
            return {k: walk(v, path + (k,)) for k, v in node.items()}
        if isinstance(node, list):
            return [walk(v, path + (str(i),)) for i, v in enumerate(node)]
        return NamedSharding(
            mesh, cache_leaf_spec(path, node.shape, mesh, global_batch))
    return walk(cache_tree, ())
