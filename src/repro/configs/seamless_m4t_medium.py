"""SeamlessM4T medium — encoder-decoder, audio frontend stub
[arXiv:2308.11596].

The speech frontend is a STUB per the assignment: input_specs() provides
precomputed frame embeddings of shape (batch, src_len, d_model); the
transformer backbone (12 enc + 12 dec, cross-attention) is real.
Positional encoding adapted to RoPE (orig uses learned/relative; noted in
DESIGN.md).
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="seamless-m4t-medium", family="audio",
    num_layers=12, encoder_layers=12,
    d_model=1024, num_heads=16, num_kv_heads=16,
    d_ff=4096, vocab_size=256_206,
    ffn_activation="gelu", norm="layernorm", modality="audio",
    source="arXiv:2308.11596",
))
