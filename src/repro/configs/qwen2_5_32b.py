"""Qwen2.5 32B — dense GQA decoder with QKV bias [hf:Qwen/Qwen2.5-32B]."""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="qwen2.5-32b", family="dense",
    num_layers=64, d_model=5120, num_heads=40, num_kv_heads=8,
    d_ff=27648, vocab_size=152_064, qkv_bias=True,
    ffn_activation="swiglu",
    source="hf:Qwen/Qwen2.5-32B",
))
