"""Architecture config system: one frozen dataclass per assigned arch.

Every config is registered under its public id and selectable via
``--arch <id>`` in the launchers. ``reduced()`` returns a tiny same-family
config for CPU smoke tests; the full configs are exercised only through the
dry-run (ShapeDtypeStruct, no allocation).
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field


@dataclass(frozen=True)
class ShapeSpec:
    """One input-shape cell: (kind, seq_len, global_batch)."""
    name: str
    kind: str            # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


#: The assigned LM shape set (applies to every architecture).
SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524_288, 1),
}


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // num_heads

    # attention details
    qkv_bias: bool = False
    rope_variant: str = "rope"       # rope | mrope | none
    rope_theta: float = 10_000.0
    window: int = 0                  # local-attention window (0 = full)
    logit_softcap: float = 0.0

    # ffn
    ffn_activation: str = "swiglu"   # swiglu | gelu | sq_relu | geglu

    # moe
    num_experts: int = 0
    num_shared_experts: int = 0
    top_k: int = 0
    first_layer_dense: bool = False  # deepseek-moe layer 0
    dense_d_ff: int = 0              # d_ff of that dense layer
    router_aux_coef: float = 0.01

    # layer pattern, cycled: attn | local_attn | mlstm | slstm | rglru
    block_pattern: tuple = ("attn",)

    # encoder-decoder
    encoder_layers: int = 0
    # recurrent
    lru_width: int = 0
    conv1d_width: int = 4
    # misc
    modality: str = "text"           # text | audio | vlm
    norm: str = "rmsnorm"
    tie_embeddings: bool = False
    sub_quadratic: bool = False      # may run long_500k
    source: str = ""

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim",
                               self.d_model // max(self.num_heads, 1))
        assert self.num_heads % max(self.num_kv_heads, 1) == 0, self.name
        assert len(self.block_pattern) > 0

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    def pattern_for(self, num_layers: int) -> tuple:
        p = self.block_pattern
        return tuple(p[i % len(p)] for i in range(num_layers))

    def param_count(self) -> int:
        """Exact parameter count (embedding + blocks), for 6·N·D rooflines."""
        from repro.models.model import count_params
        return count_params(self)

    def active_param_count(self) -> int:
        from repro.models.model import count_params
        return count_params(self, active_only=True)

    def reduced(self) -> "ArchConfig":
        """Tiny same-family config for CPU smoke tests."""
        changes = dict(
            num_layers=max(2, len(self.block_pattern)),
            d_model=128,
            num_heads=4,
            num_kv_heads=min(self.num_kv_heads, 2) if self.num_kv_heads > 1
            else 1,
            d_ff=0 if self.d_ff == 0 else 256,
            vocab_size=512,
            window=min(self.window, 64) if self.window else 0,
            lru_width=128 if self.lru_width else 0,
        )
        if self.is_moe:
            changes.update(num_experts=4, top_k=2,
                           num_shared_experts=min(self.num_shared_experts, 1))
        if self.is_encdec:
            changes.update(encoder_layers=2)
        return dataclasses.replace(self, **changes)


_REGISTRY: dict[str, ArchConfig] = {}


def register(cfg: ArchConfig) -> ArchConfig:
    assert cfg.name not in _REGISTRY, cfg.name
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ArchConfig:
    _ensure_loaded()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def all_configs() -> dict[str, ArchConfig]:
    _ensure_loaded()
    return dict(_REGISTRY)


def shapes_for(cfg: ArchConfig) -> list[ShapeSpec]:
    """The applicable shape cells for an arch (skip rules per DESIGN.md)."""
    out = [SHAPES["train_4k"], SHAPES["prefill_32k"], SHAPES["decode_32k"]]
    if cfg.sub_quadratic:
        out.append(SHAPES["long_500k"])
    return out


def _ensure_loaded():
    # import every config module once so registration side effects run
    import repro.configs.registry  # noqa: F401
