"""Granite 3.0 3B-A800M MoE — 40 experts top-8
[hf:ibm-granite/granite-3.0-3b-a800m-base].

The assignment line reads "MoE 40e top-8" in the shape field and "32
experts" in the comment; we follow the shape field (40 experts) and note
the discrepancy in DESIGN.md. d_ff=512 is per-expert.
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="granite-moe-3b-a800m", family="moe",
    num_layers=32, d_model=1536, num_heads=24, num_kv_heads=8,
    d_ff=512, vocab_size=49_155,
    num_experts=40, top_k=8,
    ffn_activation="swiglu", tie_embeddings=True,
    source="hf:ibm-granite/granite-3.0-3b-a800m-base",
))
