from repro.configs.base import (
    ArchConfig, ShapeSpec, SHAPES, all_configs, get_config, shapes_for)

__all__ = ["ArchConfig", "ShapeSpec", "SHAPES", "all_configs",
           "get_config", "shapes_for"]
