"""Qwen2-VL 2B — text backbone with M-RoPE; vision frontend stub
[arXiv:2409.12191].

input_specs() supplies precomputed patch embeddings merged into the token
stream via a vision mask, plus 3-section (t/h/w) M-RoPE position ids.
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="qwen2-vl-2b", family="vlm",
    num_layers=28, d_model=1536, num_heads=12, num_kv_heads=2,
    d_ff=8960, vocab_size=151_936, qkv_bias=True,
    rope_variant="mrope", ffn_activation="swiglu", modality="vlm",
    tie_embeddings=True,
    source="arXiv:2409.12191",
))
