"""RecurrentGemma 2B — RG-LRU recurrent blocks + local attention at 2:1
[arXiv:2402.19427].

26 layers cycle (rglru, rglru, local_attn); MQA (kv=1), window 2048,
GeGLU FFN. Sub-quadratic -> runs the long_500k cell.
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="recurrentgemma-2b", family="hybrid",
    num_layers=26, d_model=2560, num_heads=10, num_kv_heads=1,
    d_ff=7680, vocab_size=256_000,
    block_pattern=("rglru", "rglru", "local_attn"), window=2048,
    lru_width=2560, ffn_activation="geglu", rope_variant="rope",
    sub_quadratic=True,
    source="arXiv:2402.19427",
))
