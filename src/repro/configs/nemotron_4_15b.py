"""Nemotron-4 15B — dense GQA decoder, squared-ReLU FFN [arXiv:2402.16819]."""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="nemotron-4-15b", family="dense",
    num_layers=32, d_model=6144, num_heads=48, num_kv_heads=8,
    d_ff=24576, vocab_size=256_000,
    ffn_activation="sq_relu", rope_variant="rope",
    source="arXiv:2402.16819",
))
