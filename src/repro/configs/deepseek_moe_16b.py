"""DeepSeekMoE 16B — 2 shared + 64 routed top-6, fine-grained experts,
first layer dense [arXiv:2401.06066]."""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="deepseek-moe-16b", family="moe",
    num_layers=28, d_model=2048, num_heads=16, num_kv_heads=16,
    d_ff=1408, vocab_size=102_400,
    num_experts=64, top_k=6, num_shared_experts=2,
    first_layer_dense=True, dense_d_ff=10944,
    ffn_activation="swiglu",
    source="arXiv:2401.06066",
))
