"""Qwen2 0.5B — dense GQA decoder with QKV bias [arXiv:2407.10671]."""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="qwen2-0.5b", family="dense",
    num_layers=24, d_model=896, num_heads=14, num_kv_heads=2,
    d_ff=4864, vocab_size=151_936, qkv_bias=True,
    ffn_activation="swiglu", tie_embeddings=True,
    source="arXiv:2407.10671",
))
