"""Import every architecture config so registration side effects run."""
import repro.configs.nemotron_4_15b     # noqa: F401
import repro.configs.qwen2_0_5b         # noqa: F401
import repro.configs.qwen2_5_32b        # noqa: F401
import repro.configs.stablelm_12b       # noqa: F401
import repro.configs.xlstm_1_3b         # noqa: F401
import repro.configs.seamless_m4t_medium  # noqa: F401
import repro.configs.qwen2_vl_2b        # noqa: F401
import repro.configs.granite_moe_3b_a800m  # noqa: F401
import repro.configs.deepseek_moe_16b   # noqa: F401
import repro.configs.recurrentgemma_2b  # noqa: F401
