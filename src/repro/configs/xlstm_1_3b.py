"""xLSTM 1.3B — sLSTM + mLSTM blocks at 1:7 [arXiv:2405.04517].

d_ff = 0: xLSTM blocks carry their own up/down projections (mLSTM
projection factor 2, sLSTM gated factor 4/3). Sub-quadratic -> runs the
long_500k cell.
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="xlstm-1.3b", family="ssm",
    num_layers=48, d_model=2048, num_heads=4, num_kv_heads=4,
    d_ff=0, vocab_size=50_304, rope_variant="none",
    block_pattern=("slstm",) + ("mlstm",) * 7,
    sub_quadratic=True,
    source="arXiv:2405.04517",
))
