"""Batched serving engine: prefill + decode with KV-cache management.

Production shape: jitted prefill and decode steps (the same functions the
dry-run lowers at pod scale), a cache conversion from prefill layout to
the decode layout (including local-attention ring buffers), and greedy /
temperature sampling. Runs end-to-end on CPU with reduced configs; at pod
scale the same code paths shard per ``parallel.sharding``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import attention as attn_mod
from repro.models.model import (
    _group_layer_params, decode_step, init_cache, layer_sigs, serve_prefill)


def _ring_place(k, capacity: int):
    """Map prefill K/V (B, P, ...) into a ring buffer of ``capacity``."""
    b, p = k.shape[0], k.shape[1]
    if p <= capacity:
        pad = [(0, 0)] * k.ndim
        pad[1] = (0, capacity - p)
        return jnp.pad(k, pad)
    # slot j holds position P - capacity + ((j - P) mod capacity)
    j = np.arange(capacity)
    pos = p - capacity + ((j - p) % capacity)
    return k[:, pos]


def prefill_to_decode_cache(cfg: ArchConfig, caches, prefill_len: int,
                            capacity: int, enc_out=None, params=None,
                            enc_positions=None):
    """Convert ``serve_prefill`` caches into the ``decode_step`` layout."""
    sigs = layer_sigs(cfg)
    # flatten group structure -> per-layer entries (structure from cfg)
    from repro.models.model import layer_groups
    flat = []
    for (chunk, reps), group in zip(layer_groups(cfg), caches):
        if reps == 1:
            flat.extend(group)
        else:  # scanned: leaves stacked over reps on axis 0
            for r in range(reps):
                for blk in group:
                    flat.append(jax.tree.map(lambda a: a[r], blk))
    layer_params = _group_layer_params(cfg, params) if params else None
    layers = []
    for i, ((kind, _), entry) in enumerate(zip(sigs, flat)):
        if kind in ("attn", "local_attn"):
            window = cfg.window if kind == "local_attn" else 0
            cap = min(capacity, window) if window else capacity
            new = {"k": _ring_place(entry["k"].astype(jnp.bfloat16), cap),
                   "v": _ring_place(entry["v"].astype(jnp.bfloat16), cap)}
            if cfg.is_encdec:
                p = layer_params[i]["cross_attn"]
                ek = jnp.einsum("bsd,dhk->bshk", enc_out,
                                p["wk"].astype(enc_out.dtype))
                ev = jnp.einsum("bsd,dhk->bshk", enc_out,
                                p["wv"].astype(enc_out.dtype))
                if cfg.qkv_bias:
                    ek = ek + p["bk"].astype(ek.dtype)
                    ev = ev + p["bv"].astype(ev.dtype)
                new["cross_k"] = ek.astype(jnp.bfloat16)
                new["cross_v"] = ev.astype(jnp.bfloat16)
            layers.append(new)
        elif kind == "mlstm":
            c, n, m = entry["state"]
            layers.append({"c": c, "n": n, "m": m})
        elif kind == "slstm":
            c, n, h, m = entry["state"]
            layers.append({"c": c, "n": n, "h": h, "m": m})
        elif kind == "rglru":
            buf, h = entry["state"]
            layers.append({"conv": buf, "h": h})
    return {"pos": jnp.asarray(prefill_len, jnp.int32), "layers": layers}


class ServeEngine:
    def __init__(self, cfg: ArchConfig, params, *, max_seq_len: int = 256,
                 q_chunk: int = 64):
        self.cfg = cfg
        self.params = params
        self.max_seq_len = max_seq_len
        self.q_chunk = q_chunk
        self._decode = jax.jit(
            functools.partial(decode_step, cfg))
        self._prefill = jax.jit(functools.partial(
            serve_prefill, cfg, q_chunk=q_chunk))

    def generate(self, tokens: np.ndarray, max_new_tokens: int = 16,
                 temperature: float = 0.0, seed: int = 0,
                 src_embeds: np.ndarray | None = None) -> np.ndarray:
        """tokens: (B, P) prompt ids -> (B, P + max_new_tokens)."""
        cfg = self.cfg
        b, p = tokens.shape
        batch = {"tokens": jnp.asarray(tokens, jnp.int32)}
        enc_out = None
        if cfg.is_encdec:
            assert src_embeds is not None
            batch["src_embeds"] = jnp.asarray(src_embeds, jnp.bfloat16)
            from repro.models.model import run_stack, apply_norm  # noqa
        if cfg.modality == "vlm":
            batch["vision_mask"] = jnp.zeros((b, p), bool)
            batch["vision_embeds"] = jnp.zeros((b, p, cfg.d_model),
                                               jnp.bfloat16)
            batch["positions3"] = jnp.asarray(np.broadcast_to(
                np.arange(p, dtype=np.int32), (3, b, p)))
        logits, caches = self._prefill(self.params, batch)
        if cfg.is_encdec:
            # recompute encoder output for cross K/V projection
            from repro.models.model import forward
            enc_out = self._encoder_out(batch)
        cache = prefill_to_decode_cache(
            cfg, caches, p, self.max_seq_len, enc_out=enc_out,
            params=self.params)
        out = [jnp.asarray(tokens, jnp.int32)]
        rng = jax.random.PRNGKey(seed)
        tok = self._sample(logits[:, -1], temperature, rng)
        for i in range(max_new_tokens):
            out.append(tok)
            logits, cache = self._decode(self.params, tok, cache)
            rng, sub = jax.random.split(rng)
            tok = self._sample(logits[:, -1], temperature, sub)
        return np.asarray(jnp.concatenate(out, axis=1))

    def _encoder_out(self, batch):
        from repro.models.common import apply_norm
        from repro.models.model import layer_sigs, run_stack
        cfg = self.cfg
        src = batch["src_embeds"].astype(jnp.bfloat16)
        bs, ss, _ = src.shape
        ctx = dict(positions=jnp.broadcast_to(
            jnp.arange(ss, dtype=jnp.int32), (bs, ss)), causal=False,
            q_chunk=self.q_chunk, rec_chunk=256, want_cache=False,
            enc_out=None, sharder=None, remat=False, scan_layers=True,
            rec_unroll=False)
        enc_groups = [([layer_sigs(cfg, 1)[0]], cfg.encoder_layers)]
        x, _, _ = run_stack(cfg, self.params["encoder"], src, ctx,
                            enc_groups, prefix="")
        return apply_norm(cfg, self.params["encoder"]["out_norm"], x)

    @staticmethod
    def _sample(logits, temperature: float, rng) -> jax.Array:
        if temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        return jax.random.categorical(
            rng, logits.astype(jnp.float32) / temperature)[
                :, None].astype(jnp.int32)
