"""Generate EXPERIMENTS.md sections (§Dry-run, §Roofline) from the
dry-run JSON records. §Perf iterations are appended by hand during the
hillclimb (hypothesis → change → measure → validate logs).

    PYTHONPATH=src python -m repro.analysis.report
"""

from __future__ import annotations

import json
from collections import Counter
from pathlib import Path

from repro.analysis.roofline import (
    analyze_record, fmt_seconds, load_records, markdown_table)

ROOT = Path(__file__).resolve().parents[3]
DRYRUN = ROOT / "experiments" / "dryrun"

#: per-chunk rows shown before eliding the middle of a long schedule
_MAX_CHUNK_ROWS = 16


def streaming_section(stats) -> str:
    """Markdown for a streamed census run — the paper's Fig-9-style
    utilization analysis extended to the chunked schedule.

    ``stats`` is a :class:`repro.core.engine.EngineStats` (or anything with
    the same fields).  Per-chunk valid-item counts are the streamed
    analogue of per-shard work shares: ``chunk_max_over_mean`` close to
    1.0 means the pre-prune slicing produced an even device schedule.
    """
    items = list(stats.chunk_items)
    lines = [
        "### §Streaming schedule",
        "",
        f"backend={stats.backend} devices={stats.ndev} "
        f"orient={stats.orient} max_items={stats.max_items} — "
        f"{stats.chunks} chunks, {stats.items} work items, "
        f"peak plan bytes {stats.peak_plan_bytes} "
        f"(monolithic would ship {stats.monolithic_plan_bytes}), "
        f"chunk step compiles: {stats.step_compiles}",
        "",
        "| chunk | valid items | share of padded shape |",
        "|---|---|---|",
    ]
    shape = max(stats.chunk_shape, 1)
    show = (range(len(items)) if len(items) <= _MAX_CHUNK_ROWS else
            list(range(_MAX_CHUNK_ROWS // 2))
            + [None]
            + list(range(len(items) - _MAX_CHUNK_ROWS // 2, len(items))))
    for k in show:
        if k is None:
            lines.append("| … | … | … |")
        else:
            lines.append(f"| {k} | {items[k]} | {items[k] / shape:.1%} |")
    lines += [
        "",
        f"chunk max-over-mean imbalance: "
        f"{stats.chunk_max_over_mean:.4f} (1.0 == perfectly even)",
    ]
    return "\n".join(lines)


def dryrun_section(records: list[dict]) -> str:
    ok = [r for r in records if r.get("status") == "ok"]
    bad = [r for r in records if r.get("status") != "ok"]
    lines = [
        "## §Dry-run",
        "",
        f"{len(ok)} / {len(records)} (arch × shape × mesh) cells lower + "
        "compile successfully (SPMD partitioning on 256- and 512-device "
        "meshes; XLA CPU backend with "
        "`--xla_force_host_platform_device_count=512`).",
        "",
        "| arch | shape | mesh | compile s | args/dev | temp/dev | "
        "collective bytes/dev/step (trip-corrected) | top collectives |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in sorted(ok, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        mem = r["memory"]
        coll = r["collectives"]
        top = ", ".join(
            f"{k}×{v}" for k, v in sorted(
                coll["counts_by_kind"].items(),
                key=lambda kv: -coll["bytes_by_kind"].get(kv[0], 0))[:3])
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{r.get('compile_seconds', '?')} | "
            f"{mem['argument_bytes'] / 1e9:.2f} GB | "
            f"{mem['temp_bytes'] / 1e9:.2f} GB | "
            f"{coll['total_bytes'] / 1e9:.2f} GB | {top} |")
    if bad:
        lines += ["", "Failures:", ""]
        for r in bad:
            lines.append(f"* {r['arch']} × {r['shape']} × {r['mesh']}: "
                         f"`{r.get('error', '?')[:200]}`")
    lines += [
        "",
        "Skipped by design (DESIGN.md §5): `long_500k` for the 8 pure "
        "full-attention archs (quadratic attention at 524k context is "
        "architecturally excluded; run for xlstm-1.3b and "
        "recurrentgemma-2b).",
    ]
    return "\n".join(lines)


def roofline_section(records: list[dict]) -> str:
    rows = [analyze_record(r) for r in records]
    rows = [r for r in rows if r is not None]
    rows.sort(key=lambda r: (r.mesh, r.arch, r.shape))
    pod = [r for r in rows if r.mesh == "16x16"]
    dom = Counter(r.dominant for r in pod)
    lines = [
        "## §Roofline",
        "",
        "Terms per the brief (TPU v5e: 197 TFLOP/s bf16, 819 GB/s HBM, "
        "50 GB/s/link ICI):",
        "",
        "* `compute = HLO_FLOPs / (chips × peak)` — FLOPs from the "
        "unrolled lowering (scan-free, exact; ×4/3 for train remat).",
        "* `memory = HBM_bytes / (chips × bw)` — analytic traffic model "
        "(weights + optimizer + activation streams + KV/state caches); "
        "pre-fusion HLO byte counts are kept in the JSON as a cross-check "
        "but overstate traffic ~10×.",
        "* `collective = bytes / (chips × link_bw)` — compiled SPMD "
        "collectives, while-loop trip-count corrected "
        "(`repro.analysis.hlo`).",
        "",
        "`MF/HLO` = MODEL_FLOPS / HLO_FLOPs with MODEL_FLOPS = 6·N_active·D "
        "(train) or 2·N_active·D (serve); the gap below 1.0 is attention "
        "quadratic work + GQA/MoE overheads, above ~1.0 would flag lost "
        "useful work. `roofline frac` = ideal useful-compute time / "
        "dominant-term time — the score we hillclimb in §Perf.",
        "",
        f"Dominant-term census over single-pod cells: "
        + ", ".join(f"{k}: {v}" for k, v in dom.most_common()),
        "",
        "### Single pod (16×16 = 256 chips)",
        "",
        markdown_table([r for r in rows if r.mesh == "16x16"]),
        "",
        "### Multi-pod (2×16×16 = 512 chips; DP over `pod`)",
        "",
        markdown_table([r for r in rows if r.mesh == "2x16x16"]),
        "",
        "### Per-cell bottleneck notes (single pod)",
        "",
    ]
    for r in pod:
        lines.append(
            f"* **{r.arch} × {r.shape}** — dominant: {r.dominant} "
            f"({fmt_seconds(r.step_time_s)}/step). {r.note}.")
    return "\n".join(lines)


def variants_section() -> str:
    vdir = ROOT / "experiments" / "variants"
    if not vdir.exists():
        return ""
    recs = [r for r in load_records(vdir) if r.get("status") == "ok"]
    if not recs:
        return ""
    lines = [
        "### §Perf variant measurements (iteration log below)",
        "",
        "| arch | shape | variant | compute | memory | collective | "
        "dominant | frac | temp/dev | fits |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for rec in sorted(recs, key=lambda r: (r["arch"], r["shape"])):
        row = analyze_record(rec)
        if row is None:
            continue
        lines.append(
            f"| {row.arch} | {row.shape} | {rec.get('variant', '?')} | "
            f"{fmt_seconds(row.compute_s)} | {fmt_seconds(row.memory_s)} |"
            f" {fmt_seconds(row.collective_s)} | {row.dominant} | "
            f"{row.roofline_frac:.1%} | {row.temp_gb:.1f} GB | "
            f"{'✓' if row.fits else '✗'} |")
    lines.append("")
    return "\n".join(lines)


def main():
    records = load_records(DRYRUN)
    out = [
        "# EXPERIMENTS",
        "",
        "Artifacts: `experiments/dryrun/*.json` (one per cell), "
        "`experiments/variants/*.json` (§Perf iterations), "
        "`benchmarks/run.py` CSV (`bench_output.txt`), "
        "`tests/` (`test_output.txt`). Hardware target: TPU v5e pods "
        "(16×16 per pod); host: 1-core CPU container (compile-only "
        "dry-runs, interpret-mode kernels).",
        "",
        dryrun_section(records),
        "",
        roofline_section(records),
        "",
        variants_section(),
    ]
    perf = ROOT / "experiments" / "PERF_LOG.md"
    if perf.exists():
        out.append(perf.read_text())
    paper = ROOT / "experiments" / "PAPER_VALIDATION.md"
    if paper.exists():
        out.append(paper.read_text())
    (ROOT / "EXPERIMENTS.md").write_text("\n".join(out))
    print(f"wrote EXPERIMENTS.md with {len(records)} records")


if __name__ == "__main__":
    main()
