"""Three-term roofline analysis from the dry-run artifacts.

Per (arch × shape × mesh) cell::

    compute_term    = HLO_FLOPs   / (chips × 197 TFLOP/s bf16)
    memory_term     = HBM_bytes   / (chips × 819 GB/s)
    collective_term = coll_bytes  / (chips × 50 GB/s per ICI link)

FLOPs come from the dry-run's unrolled lowering (exact, scan-free;
multiplied by 4/3 for train cells to account for remat recompute, which
the production step enables). The HBM byte term is an analytic traffic
model (weights + optimizer + activation streams + KV cache) because
pre-fusion HLO byte counts overstate traffic by ~10×; the compiled
per-device figure is carried as a cross-check. Collective bytes come from
the compiled SPMD module with while-loop trip-count correction
(analysis.hlo).
"""

from __future__ import annotations

import json
from dataclasses import dataclass
from pathlib import Path

from repro.configs import SHAPES, get_config
from repro.models.model import count_params

PEAK_FLOPS = 197e12        # bf16 / chip (TPU v5e)
HBM_BW = 819e9             # bytes/s / chip
ICI_BW = 50e9              # bytes/s / link
REMAT_FACTOR = 4.0 / 3.0   # fwd recompute on top of fwd+bwd


def model_flops(arch: str, shape_name: str) -> float:
    """MODEL_FLOPS = 6·N·D (train) / 2·N·D (inference), N = active params."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    n_active = count_params(cfg, active_only=True)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    tokens = shape.global_batch                 # one decode step
    return 2.0 * n_active * tokens


def analytic_hbm_bytes(arch: str, shape_name: str,
                       kv_bytes: float = 2.0) -> float:
    """Global HBM traffic per step (napkin model, documented in module
    docstring). ``kv_bytes``: bytes/element of the KV cache (2 = bf16,
    1.125 = int8 + scales — the kv_quant variant)."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    p = count_params(cfg)
    p_active = count_params(cfg, active_only=True)
    d, L = cfg.d_model, cfg.num_layers

    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        # weights: fwd read + bwd read + remat re-read (bf16) for ALL
        # params (moe experts stream from HBM even if inactive per token
        # at full batch every expert is hit)
        w = p * 2 * 3
        # optimizer: grads (f32 w+r) + mu/nu read+write + param read+write
        opt = p * 4 * (2 + 4 + 2)
        # activation streams: ~14 tensor rw per layer element + remat
        act = tokens * d * L * 14 * 2 * 1.5
        return w + opt + act
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        w = p * 2
        act = tokens * d * L * 10 * 2
        kv = tokens * cfg.num_kv_heads * cfg.head_dim * 2 * L * 2 * 2
        return w + act + kv
    # decode: every step reads all (active) weights + the whole KV/state
    b = shape.global_batch
    w = p_active * 2 + (p - p_active) * 2 * min(
        1.0, b * max(cfg.top_k, 1) / max(cfg.num_experts, 1))
    kv = _cache_bytes(cfg, b, shape.seq_len, kv_bytes)
    act = b * d * L * 14 * 2
    return w + kv + act


def _cache_bytes(cfg, batch: int, seq_len: int,
                 kv_bytes: float = 2.0) -> float:
    total = 0.0
    from repro.models.model import layer_sigs
    for kind, _ in layer_sigs(cfg):
        if kind == "attn":
            total += (2 * batch * seq_len * cfg.num_kv_heads *
                      cfg.head_dim * kv_bytes)
        elif kind == "local_attn":
            s = min(seq_len, cfg.window or seq_len)
            total += (2 * batch * s * cfg.num_kv_heads * cfg.head_dim *
                      kv_bytes)
        elif kind == "mlstm":
            di = 2 * cfg.d_model
            k = di // cfg.num_heads
            total += batch * cfg.num_heads * (k * k + k + 1) * 4
        elif kind == "slstm":
            total += batch * cfg.d_model * 4 * 4
        elif kind == "rglru":
            total += batch * cfg.lru_width * 4 * cfg.conv1d_width
    if cfg.is_encdec:
        total *= 1.5      # cross K/V
    return total


@dataclass
class RooflineRow:
    arch: str
    shape: str
    mesh: str
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float
    hlo_flops: float
    useful_ratio: float
    fits: bool
    temp_gb: float
    step_time_s: float
    roofline_frac: float
    note: str


_SUGGEST = {
    "compute": ("shard padding waste / improve MXU utilization "
                "(head-dim alignment, fused kernels)"),
    "memory": ("cut HBM traffic: larger fused blocks, KV-cache "
               "quantization, weight layout reuse across steps"),
    "collective": ("reshard to cut cross-device volume: fewer FSDP "
                   "gathers (TP-first), overlap collectives with compute, "
                   "gradient compression"),
}


def analyze_record(rec: dict) -> RooflineRow | None:
    if rec.get("status") != "ok":
        return None
    arch, shape_name, mesh = rec["arch"], rec["shape"], rec["mesh"]
    chips = rec["devices"]
    shape = SHAPES[shape_name]
    cc = rec["cost_corrected"]
    scope = rec.get("cost_scope", "global")
    mult = 1.0 if scope == "global" else chips
    hlo_flops = cc.get("flops", 0.0) * mult
    coll_bytes = cc.get("collective_bytes", 0.0)
    if scope == "per_device":
        coll_bytes = coll_bytes * chips

    remat = REMAT_FACTOR if shape.kind == "train" else 1.0
    compute_s = hlo_flops * remat / (chips * PEAK_FLOPS)
    kv_bytes = (1.125 if str(rec.get("overrides", {}).get(
        "kv_quant")) == "True" else 2.0)
    memory_s = analytic_hbm_bytes(arch, shape_name, kv_bytes) / (
        chips * HBM_BW)
    collective_s = coll_bytes / (chips * ICI_BW)

    mf = model_flops(arch, shape_name)
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)
    step = max(terms.values())
    # roofline fraction: useful-compute time over the modeled step time
    ideal_compute = mf / (chips * PEAK_FLOPS)
    frac = ideal_compute / step if step > 0 else 0.0
    temp_gb = rec["memory"]["temp_bytes"] / 1e9
    return RooflineRow(
        arch=arch, shape=shape_name, mesh=mesh,
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        dominant=dominant, model_flops=mf, hlo_flops=hlo_flops,
        useful_ratio=mf / hlo_flops if hlo_flops else 0.0,
        fits=temp_gb + rec["memory"]["argument_bytes"] / 1e9 < 16.0,
        temp_gb=temp_gb, step_time_s=step, roofline_frac=frac,
        note=_SUGGEST[dominant])


def load_records(dryrun_dir) -> list[dict]:
    out = []
    for p in sorted(Path(dryrun_dir).glob("*.json")):
        try:
            out.append(json.loads(p.read_text()))
        except json.JSONDecodeError:
            continue
    return out


def fmt_seconds(s: float) -> str:
    if s >= 1.0:
        return f"{s:.2f}s"
    if s >= 1e-3:
        return f"{s * 1e3:.1f}ms"
    return f"{s * 1e6:.0f}µs"


def markdown_table(rows: list[RooflineRow]) -> str:
    hdr = ("| arch | shape | mesh | compute | memory | collective | "
           "dominant | MF/HLO | roofline frac | fits |\n"
           "|---|---|---|---|---|---|---|---|---|---|\n")
    lines = []
    for r in rows:
        lines.append(
            f"| {r.arch} | {r.shape} | {r.mesh} | "
            f"{fmt_seconds(r.compute_s)} | {fmt_seconds(r.memory_s)} | "
            f"{fmt_seconds(r.collective_s)} | **{r.dominant}** | "
            f"{r.useful_ratio:.2f} | {r.roofline_frac:.1%} | "
            f"{'✓' if r.fits else '✗'} |")
    return hdr + "\n".join(lines)
