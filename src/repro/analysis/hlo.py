"""HLO-text analysis: collective-communication bytes with correct
while-loop (lax.scan) trip-count multiplication.

XLA's ``compiled.cost_analysis()`` counts a while body **once**; for the
roofline's collective term we need bytes × trips. This parser builds the
computation call graph from ``compiled.as_text()``, extracts trip counts
from while-condition constants, and accumulates collective bytes
recursively. It is a text-level estimator: per-op "bytes" is
max(result, operands) shape size, a consistent proxy for link traffic.
"""

from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")

COLLECTIVE_KINDS = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)


def shape_bytes(text: str) -> int:
    """Sum byte sizes of every shape literal in ``text``."""
    total = 0
    for m in _SHAPE_RE.finditer(text):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class Computation:
    name: str
    collective_bytes: dict = field(default_factory=lambda: defaultdict(int))
    collective_counts: dict = field(default_factory=lambda: defaultdict(int))
    whiles: list = field(default_factory=list)     # (body, cond)
    calls: list = field(default_factory=list)      # fusions / calls / branches
    constants: list = field(default_factory=list)  # integer constants seen


def parse_computations(hlo: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    # computation headers: `%name (params...) -> type {` — params may
    # contain nested parens (tuple-typed while-body args), so match
    # greedily up to the trailing `{`
    header = re.compile(
        r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->\s*.+\{\s*$")
    for raw in hlo.splitlines():
        line = raw.strip()
        m = header.match(line)
        if m and ("=" not in line.split("(")[0]):
            cur = Computation(m.group(1))
            comps[cur.name] = cur
            continue
        if cur is None or not line or line == "}":
            continue
        # integer constants (trip-count candidates)
        for c in re.finditer(r"constant\((\d+)\)", line):
            cur.constants.append(int(c.group(1)))
        # collective ops (count the -start of async pairs only once)
        if "-done" not in line:
            for kind in COLLECTIVE_KINDS:
                if re.search(rf"\b{kind}(-start)?\(", line):
                    lhs, _, rhs = line.partition("=")
                    b = shape_bytes(line)
                    cur.collective_bytes[kind] += b
                    cur.collective_counts[kind] += 1
                    break
        # call graph edges
        wm = re.search(r"while\(.*condition=%?([\w\.\-]+).*body=%?([\w\.\-]+)",
                       line)
        if not wm:
            wm2 = re.search(
                r"while\(.*body=%?([\w\.\-]+).*condition=%?([\w\.\-]+)", line)
            if wm2:
                cur.whiles.append((wm2.group(1), wm2.group(2)))
        else:
            cur.whiles.append((wm.group(2), wm.group(1)))
        for cm in re.finditer(r"(?:calls|to_apply|branch_computations)="
                              r"[{%]?\s*%?([\w\.\-]+(?:,\s*%?[\w\.\-]+)*)",
                              line):
            for name in re.split(r",\s*%?", cm.group(1)):
                cur.calls.append(name.strip("% {}"))
    return comps


def trip_count(comps: dict, cond_name: str, default: int = 1) -> int:
    cond = comps.get(cond_name)
    if cond is None or not cond.constants:
        return default
    return max(cond.constants)


def collective_summary(hlo: str) -> dict:
    """Total collective bytes/counts with while-trip multiplication."""
    comps = parse_computations(hlo)
    entry = None
    for name, c in comps.items():
        if name.startswith("main") or entry is None:
            if name.startswith("main"):
                entry = c
    if entry is None and comps:
        entry = next(iter(comps.values()))

    memo: dict[str, tuple] = {}

    def total(name: str, stack=()) -> tuple[dict, dict]:
        if name in memo:
            return memo[name]
        if name not in comps or name in stack:
            return {}, {}
        c = comps[name]
        by = defaultdict(int, c.collective_bytes)
        ct = defaultdict(int, c.collective_counts)
        for callee in c.calls:
            sb, sc = total(callee, stack + (name,))
            for k, v in sb.items():
                by[k] += v
            for k, v in sc.items():
                ct[k] += v
        for body, cond in c.whiles:
            trips = trip_count(comps, cond)
            sb, sc = total(body, stack + (name,))
            for k, v in sb.items():
                by[k] += v * trips
            for k, v in sc.items():
                ct[k] += v * trips
        memo[name] = (dict(by), dict(ct))
        return memo[name]

    by, ct = total(entry.name) if entry else ({}, {})
    return {
        "bytes_by_kind": by,
        "counts_by_kind": ct,
        "total_bytes": sum(by.values()),
        "total_count": sum(ct.values()),
    }
