"""Pallas TPU kernels for the census hot spots (validated in interpret mode).

* ``tricode_hist`` — fused tricode -> 64-bin census histogram (the paper's
  contended census-vector increment, made contention-free).
* ``pair_codes`` — blocked sorted-row membership + in-situ 2-bit direction
  code extraction (the paper's Fig 8 pointer merge, vectorized).
* ``fused_census_partials`` — the whole per-item census pipeline (gather,
  binary search, classification, histogram) in one single-pass kernel.
"""

from repro.kernels.ops import (
    fused_census_partials, pair_codes, pair_codes_ref,
    tricode_histogram, tricode_histogram_ref)

__all__ = ["fused_census_partials", "pair_codes", "pair_codes_ref",
           "tricode_histogram", "tricode_histogram_ref"]
