"""Pallas TPU kernel: blocked sorted-row membership with code extraction.

The in-situ triad classification of the paper's Fig 8 pointer merge,
re-shaped for the VPU: for tiles of query ids Q and sorted key ids K with
packed 2-bit direction codes, emit the code of the matching key (or 0).
A (tile, 128, 128) broadcast-compare replaces the serial two-pointer walk —
O(128) redundant compares per lane bought back by full vector width, the
classic latency->bandwidth trade on TPU (DESIGN.md §2).

Rows longer than one 128-lane tile are handled by the caller (multi-tile
sweep or the jnp binary-search path); power-law tails mean most rows fit.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_B = 8      #: rows per grid step
LANES = 128


def _kernel(q_ref, k_ref, kc_ref, out_ref):
    q = q_ref[...]          # (TILE_B, 128) query ids
    k = k_ref[...]          # (TILE_B, 128) key ids (sorted, padded with -1)
    kc = kc_ref[...]        # (TILE_B, 128) key codes
    eq = (q[:, :, None] == k[:, None, :])                # (TB, 128, 128)
    out_ref[...] = jnp.sum(
        jnp.where(eq, kc[:, None, :], 0), axis=2).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def pair_codes_kernel(q: jax.Array, k: jax.Array, kc: jax.Array,
                      interpret: bool = True) -> jax.Array:
    """Per-query matched code, 0 if absent. All inputs (B, 128) int32.

    Key ids must be unique within a row (CSR rows are strictly sorted), so
    the sum over matches has at most one non-zero term.
    """
    b = q.shape[0]
    assert q.shape == k.shape == kc.shape and q.shape[1] == LANES
    assert b % TILE_B == 0, b
    return pl.pallas_call(
        _kernel,
        grid=(b // TILE_B,),
        in_specs=[pl.BlockSpec((TILE_B, LANES), lambda i: (i, 0))] * 3,
        out_specs=pl.BlockSpec((TILE_B, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, LANES), jnp.int32),
        interpret=interpret,
    )(q, k, kc)
